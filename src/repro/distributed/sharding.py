"""Per-family sharding rules (DP/TP/EP/SP) as path-pattern → PartitionSpec.

Rules are expressed over parameter-tree path strings, applied with
``tree_map_with_path`` — one rule table per family, reused for params and
both Adam moments. Batch/cache specs are built per (arch, shape) by the
registry using the helpers here. See DESIGN.md §6 for the parallelism map.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .api import _resolve_axes

DP = ("pod", "data")
TP = "model"
ALL = ("pod", "data", "model")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


# ----------------------------------------------------------------------------
# LM parameter rules
# ----------------------------------------------------------------------------
def zero1_extend(spec: P, leaf, *, min_size: int = 1 << 20, divisor: int = 32) -> P:
    """ZeRO-1: additionally shard a (master/moment) leaf over the DP axes on
    the first unsharded dim divisible by pod×data — storage only; the compute
    copy is re-gathered (bf16) by the train step."""
    if leaf.size < min_size:
        return spec
    axes = list(spec) + [None] * (leaf.ndim - len(spec))
    for i, (ax, dim) in enumerate(zip(axes, leaf.shape)):
        if ax is None and dim % divisor == 0:
            axes[i] = DP
            return P(*axes)
    return spec


def lm_param_spec(cfg, *, zero1: bool = False) -> "callable":
    tp_divides_kv = (cfg.n_kv_heads * cfg.dh) % 16 == 0 and cfg.n_kv_heads % 16 == 0
    ep = bool(cfg.moe and cfg.moe.ep_shard)

    def rule(path, leaf):
        spec = _base_rule(path, leaf)
        return zero1_extend(spec, leaf) if zero1 else spec

    def _base_rule(path, leaf):
        s = _path_str(path)
        if s.endswith("embed/emb"):
            return P(TP, None)
        if s.endswith("lm_head/w"):
            return P(None, TP)
        if "attn/wq" in s:
            return P(None, None, TP)
        if "attn/wk" in s or "attn/wv" in s:
            return P(None, None, TP) if tp_divides_kv else P(None, None, None)
        if "attn/wo" in s:
            return P(None, TP, None)
        if "ffn/gate" in s or "ffn/up" in s:
            return P(None, None, TP)
        if "ffn/down" in s:
            return P(None, TP, None)
        if "moe/router" in s:
            return P(None, None, None)
        if "moe/gate" in s or "moe/up" in s:  # [L, E, d, f]
            return P(None, TP, None, None) if ep else P(None, None, None, TP)
        if "moe/down" in s:  # [L, E, f, d]
            return P(None, TP, None, None) if ep else P(None, None, TP, None)
        return P(*([None] * leaf.ndim))

    return rule


def lm_cache_spec(cfg, batch: int, mesh_dp: int):
    """[L, B, Sc, Hk, dh] cache spec: DP on batch when divisible, else
    sequence-parallel cache (long_500k); heads or head-dim on TP."""
    from repro.models.lm import cache_head_axes

    head_axes = cache_head_axes(cfg)
    if batch % mesh_dp == 0 and batch >= mesh_dp:
        return P(None, DP, None, *head_axes)
    return P(None, None, "data", *head_axes)  # SP over cache length


# ----------------------------------------------------------------------------
# GNN / RecSys parameter rules
# ----------------------------------------------------------------------------
def gnn_param_spec(cfg):
    def rule(path, leaf):
        return P(*([None] * leaf.ndim))  # tiny params: replicate

    return rule


def recsys_param_spec(cfg, *, serving: bool = False):
    table_mode = getattr(cfg, "serve_table_mode", "row") if serving else "row"

    def rule(path, leaf):
        s = _path_str(path)
        if table_mode == "replicated" and serving:
            return P(*([None] * leaf.ndim))  # replicate-everything serving
        if s.endswith("_emb/emb") and leaf.shape[0] >= 1 << 16:
            if table_mode == "column":
                return P(None, TP)
            return P(TP, None)  # row-sharded big tables
        if "_mlp/" in s or s.startswith("mlp/") or "/mlp/" in s:
            # megatron-style alternation col/row across MLP layers
            try:
                layer_idx = int(s.split("layer_")[1].split("/")[0])
            except (IndexError, ValueError):
                layer_idx = 0
            col = layer_idx % 2 == 0
            if s.endswith("/w"):
                if leaf.shape[-1] % 16 != 0:  # final logit layer etc.
                    return P(*([None] * leaf.ndim))
                return P(None, TP) if col else P(TP, None)
            if s.endswith("/b"):
                return P(TP) if col and leaf.shape[-1] % 16 == 0 else P(None)
        return P(*([None] * leaf.ndim))

    return rule


# ----------------------------------------------------------------------------
# compressed-array (blocked CompressedIntArray) rules
# ----------------------------------------------------------------------------
# Every leaf of a CompressedIntArray leads with the block dimension, and every
# block decodes independently (per-block counts/bases carry all cross-block
# state) — so the block dim is THE sharding dim: payload/control/data get
# P(axis, None), counts/bases get P(axis). The dispatch layer then runs the
# decode per shard under shard_map with zero cross-device decode traffic
# (repro.kernels.vbyte_decode.dispatch; docs/serving.md).

def compressed_block_specs(format: str, axis=DP) -> dict:
    """Per-leaf PartitionSpecs for a blocked compressed stream, as a dict
    keyed like ``device_operands()`` (usable as shard_map in_specs)."""
    from repro.core.compressed_array import FORMAT_LEAVES

    return {nm: P(axis, None) if nm in ("payload", "control", "data", "widths")
            else P(axis)
            for nm in FORMAT_LEAVES[format]}


def compressed_array_specs(arr, axis=DP):
    """A CompressedIntArray-shaped pytree of PartitionSpecs (same treedef as
    ``arr``) — block dim on ``axis``. Feed to ``to_named`` / ``in_shardings``
    next to the abstract batch templates the registry builds."""
    import dataclasses

    return dataclasses.replace(arr, host_enc=None,
                               **compressed_block_specs(arr.format, axis))


def shard_compressed(arr, mesh: Mesh, axis="data"):
    """Place ``arr``'s block dimension across ``mesh[axis]`` (NamedSharding).

    Pads ``n_blocks`` with count=0 blocks to a multiple of the axis size so
    block-parallel ``shard_map`` decode divides evenly; padding blocks hold
    no integers, so every decode/epilogue output is unchanged. Axis names
    absent from the mesh are dropped (the ``constrain`` convention), which
    makes the same call work on 1-device test meshes (fully replicated).
    """
    import dataclasses

    import jax.numpy as jnp

    from repro.core.compressed_array import FORMAT_LEAVES

    axes = _resolve_axes((axis,), mesh)[0]
    names = (axes,) if isinstance(axes, str) else tuple(axes or ())
    n_shards = 1
    for a in names:
        n_shards *= mesh.shape[a]
    pad = (-arr.n_blocks) % max(n_shards, 1)
    leaves = {}
    for nm in FORMAT_LEAVES[arr.format]:
        x = jnp.asarray(getattr(arr, nm))
        if pad:
            x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
        spec = P(axes, *([None] * (x.ndim - 1)))
        leaves[nm] = jax.device_put(x, NamedSharding(mesh, spec))
    return dataclasses.replace(arr, **leaves)


# ----------------------------------------------------------------------------
# assembling full state / batch shardings
# ----------------------------------------------------------------------------
def tree_specs(params, rule):
    return jax.tree_util.tree_map_with_path(rule, params)


def state_specs(params, rule, *, has_ef: bool = False):
    pspec = tree_specs(params, rule)
    out = {
        "params": pspec,
        "opt": {"m": pspec, "v": pspec, "step": P()},
    }
    if has_ef:
        out["ef"] = pspec
    return out


def to_named(mesh: Mesh, spec_tree):
    def conv(s):
        return NamedSharding(mesh, P(*_resolve_axes(tuple(s), mesh)))

    return jax.tree.map(conv, spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
