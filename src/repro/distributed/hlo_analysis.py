"""Collective-traffic extraction from post-SPMD HLO text.

``cost_analysis()`` has no collective bytes, so we parse the compiled module:
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op contributes per-device *wire bytes* using standard
ring-algorithm factors (n = replica-group size):

    all-reduce        2·(n−1)/n · result_bytes
    all-gather          (n−1)/n · result_bytes      (result = gathered)
    reduce-scatter      (n−1)   · result_bytes      (result = scattered)
    all-to-all          (n−1)/n · result_bytes
    collective-permute            result_bytes      (one hop)
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL = r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
# "%name = TYPE op-name(" — result type may be a tuple
_OP_RE = re.compile(
    r"=\s*(?P<rtype>\([^)]*\)|\S+)\s+(?P<op>" + _COLL + r")(?:-start)?\(")
_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota format [num_groups,group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2  # conservative default


_WIRE_FACTOR = {
    "all-reduce": lambda n: 2 * (n - 1) / n,
    "all-gather": lambda n: (n - 1) / n,
    "reduce-scatter": lambda n: float(n - 1),
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}


def collective_stats(hlo_text: str) -> dict:
    """Per-op-kind {count, result_bytes, wire_bytes} + totals."""
    stats = defaultdict(lambda: {"count": 0, "result_bytes": 0, "wire_bytes": 0.0})
    for line in hlo_text.splitlines():
        if "-done" in line and "start" not in line:
            continue  # counted at -start
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        rb = _shape_bytes(m.group("rtype"))
        n = _group_size(line)
        wire = _WIRE_FACTOR[op](max(n, 1)) * rb
        s = stats[op]
        s["count"] += 1
        s["result_bytes"] += rb
        s["wire_bytes"] += wire
    total_wire = sum(s["wire_bytes"] for s in stats.values())
    return {"ops": dict(stats), "total_wire_bytes": total_wire}
