"""Sharding-constraint plumbing.

Model code never imports a concrete mesh: it calls ``constrain(x, *axes)``
with *logical* per-dim mesh-axis names (or None). When a mesh context is
active (set by dryrun/train/serve via ``activate_mesh``), this applies
``with_sharding_constraint``; otherwise it is a no-op, so smoke tests on one
CPU device run the exact same model code.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def current_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def activate_mesh(mesh: Mesh):
    """Thread-local mesh context; ``constrain`` builds explicit NamedShardings
    against it (no jax global mesh state is touched)."""
    prev = current_mesh()
    _state.mesh = mesh
    try:
        yield mesh
    finally:
        _state.mesh = prev


def _resolve_axes(axes, mesh: Mesh):
    """Drop axis names not present in the active mesh (e.g. 'pod' on 1 pod)."""
    out = []
    for a in axes:
        if a is None:
            out.append(None)
        elif isinstance(a, (tuple, list)):
            kept = tuple(x for x in a if x in mesh.axis_names)
            out.append(kept if kept else None)
        else:
            out.append(a if a in mesh.axis_names else None)
    return tuple(out)


def constrain(x: jax.Array, *axes):
    """with_sharding_constraint against the active mesh (no-op without one)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = P(*_resolve_axes(axes, mesh))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, *axes) -> NamedSharding:
    return NamedSharding(mesh, P(*_resolve_axes(axes, mesh)))
