"""Unified telemetry: metrics registry, per-request trace spans, exporters.

Dependency-free observability layer for the serving / query / dispatch /
ingestion stack (docs/observability.md). Three pieces:

* :class:`~repro.obs.metrics.MetricsRegistry` — counters, gauges and
  log-bucketed histograms. Mergeable (``reg.merge(other)`` folds a logical
  shard's or a subprocess sweep's registry in associatively) and
  clock-injectable (``MetricsRegistry(clock=...)``) so tests pin exact
  timelines.
* :class:`~repro.obs.trace.Span` / :func:`~repro.obs.trace.trace` —
  context-manager tracing. Nested spans form one tree per request
  (admission → validate → plan-resolve → decode dispatch →
  kernel/epilogue → skip-gallop/merge → score → top-k), each carrying
  structured attributes (format, plan label, chunk width, blocks
  decoded/skipped/pruned, epilogue name).
* exporters (:mod:`repro.obs.exporters`) — JSONL event log,
  Prometheus-style text exposition, Chrome-trace/Perfetto JSON — plus the
  ``python -m repro.obs.report`` CLI over a JSONL capture.

**The clean fast path stays bit-exact and cheap.** Nothing is recorded by
default: every instrumentation site goes through the module-level null
recorder (one global read + ``None`` check, no span objects allocated).
Telemetry activates only under :func:`install`::

    from repro import obs

    tele = obs.Telemetry()          # registry + tracer
    with obs.install(tele):         # or obs.install(tele); ... obs.uninstall()
        engine.run_workload(qs)
    print(tele.registry.to_prometheus())
    tele.tracer.write_chrome_trace("trace.json")
"""
from .metrics import Counter, Gauge, Histogram, MetricsRegistry  # noqa: F401
from .stats import latency_summary, percentile  # noqa: F401
from .trace import (  # noqa: F401
    NULL_SPAN,
    Span,
    Telemetry,
    Tracer,
    counter_inc,
    current,
    gauge_set,
    histogram_observe,
    install,
    installed,
    trace,
    uninstall,
)
