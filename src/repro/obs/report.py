"""CLI over a JSONL trace capture: ``python -m repro.obs.report trace.jsonl``.

Renders (stdout, plain text):

* a per-stage latency table — one row per span name with count and
  p50/p99/mean milliseconds plus total time, sorted hottest-first;
* a top-N hottest terms table — spans carrying a ``term`` attribute are
  aggregated by blocks decoded / ints decoded / time spent;
* a top-N hottest blocks table — per-(term, block) decode attribution when
  spans carry ``blocks`` lists.

The capture comes from ``Tracer.write_jsonl`` (e.g. ``repro.launch.serve
--metrics-out DIR`` writes ``DIR/trace.jsonl``).
"""
from __future__ import annotations

import argparse
from collections import defaultdict

from .exporters import read_jsonl
from .stats import percentile


def _table(headers: list[str], rows: list[list]) -> str:
    cells = [headers] + [[str(c) for c in r] for r in rows]
    widths = [max(len(r[i]) for r in cells) for i in range(len(headers))]
    lines = []
    for j, r in enumerate(cells):
        lines.append("  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip())
        if j == 0:
            lines.append("  ".join("-" * w for w in widths))
    return "\n".join(lines)


def stage_rows(recs: list[dict]) -> list[list]:
    """Per-stage latency rows: [stage, count, p50_ms, p99_ms, mean_ms, total_ms]."""
    by_name: dict[str, list[float]] = defaultdict(list)
    for r in recs:
        if r.get("type") == "span":
            by_name[r["name"]].append(r["dur"] * 1e3)
    rows = []
    for name, ds in by_name.items():
        rows.append([name, len(ds),
                     round(percentile(ds, 50), 3),
                     round(percentile(ds, 99), 3),
                     round(sum(ds) / len(ds), 3),
                     round(sum(ds), 3)])
    rows.sort(key=lambda r: -r[5])
    return rows


def hottest_terms(recs: list[dict], top: int = 10) -> list[list]:
    """Top terms by ints decoded: [term, spans, blocks_decoded, ints, ms]."""
    agg: dict[str, list[float]] = defaultdict(lambda: [0, 0, 0, 0.0])
    for r in recs:
        if r.get("type") != "span":
            continue
        term = r["attrs"].get("term")
        if term is None:
            continue
        a = agg[str(term)]
        a[0] += 1
        a[1] += int(r["attrs"].get("blocks_decoded", 0))
        a[2] += int(r["attrs"].get("ints_decoded", 0))
        a[3] += r["dur"] * 1e3
    rows = [[t, a[0], a[1], a[2], round(a[3], 3)] for t, a in agg.items()]
    rows.sort(key=lambda r: (-r[3], -r[2], r[0]))
    return rows[:top]


def hottest_blocks(recs: list[dict], top: int = 10) -> list[list]:
    """Top (term, block) pairs by decode count from span ``blocks`` attrs."""
    counts: dict[tuple, int] = defaultdict(int)
    for r in recs:
        if r.get("type") != "span":
            continue
        term = r["attrs"].get("term")
        blocks = r["attrs"].get("blocks")
        if term is None or not isinstance(blocks, (list, tuple)):
            continue
        for b in blocks:
            counts[(str(term), int(b))] += 1
    rows = [[t, b, n] for (t, b), n in counts.items()]
    rows.sort(key=lambda r: (-r[2], r[0], r[1]))
    return rows[:top]


def render(recs: list[dict], top: int = 10) -> str:
    n_traces = len({r["trace_id"] for r in recs if r.get("type") == "span"})
    out = [f"{sum(1 for r in recs if r.get('type') == 'span')} spans "
           f"across {n_traces} traces", ""]
    out.append("per-stage latency:")
    out.append(_table(["stage", "count", "p50_ms", "p99_ms", "mean_ms",
                       "total_ms"], stage_rows(recs)))
    terms = hottest_terms(recs, top)
    if terms:
        out += ["", f"hottest terms (top {top}):",
                _table(["term", "spans", "blocks_decoded", "ints_decoded",
                        "ms"], terms)]
    blocks = hottest_blocks(recs, top)
    if blocks:
        out += ["", f"hottest blocks (top {top}):",
                _table(["term", "block", "decodes"], blocks)]
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render per-stage latency + hottest terms/blocks "
                    "from a JSONL trace capture.")
    ap.add_argument("capture", help="trace.jsonl written by Tracer.write_jsonl")
    ap.add_argument("--top", type=int, default=10,
                    help="rows in the hottest-terms/blocks tables")
    args = ap.parse_args(argv)
    try:
        recs = read_jsonl(args.capture)
    except OSError as e:
        print(f"{args.capture}: {e.strerror or e}")
        return 1
    if not recs:
        print(f"{args.capture}: empty capture")
        return 1
    try:
        print(render(recs, args.top))
    except BrokenPipeError:  # e.g. piped into `head`
        import os
        import sys
        sys.stdout = None  # suppress the flush-on-exit error
        os.close(1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
