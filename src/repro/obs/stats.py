"""Shared percentile / latency-summary math.

One definition of p50/p99 for the whole repo: the serving engines
(``launch/serve.py``), ``benchmarks/serving.py``, ``benchmarks/index_query.py``
and ``benchmarks/ingestion.py`` all report through here, so every table uses
identical percentile semantics (linear interpolation between closest ranks,
matching ``numpy.percentile``'s default) instead of four private copies.

Pure stdlib so ``repro.obs`` stays importable without numpy.
"""
from __future__ import annotations


def percentile(samples, q: float) -> float:
    """q-th percentile (``q`` in [0, 100]) with linear interpolation.

    Matches ``numpy.percentile(samples, q)`` (default ``linear`` method)
    bit-for-bit on float inputs. Raises on an empty sample set — a summary
    over zero requests is a caller bug, not a zero.
    """
    xs = sorted(float(v) for v in samples)
    if not xs:
        raise ValueError("percentile() of empty sample set")
    if len(xs) == 1:
        return xs[0]
    rank = (q / 100.0) * (len(xs) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(xs) - 1)
    frac = rank - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


def latency_summary(lat_s, wall_s: float, n_requests: int) -> dict:
    """Shared QPS + percentile block for workload reports.

    ``lat_s`` is per-request latencies in seconds; the summary reports
    milliseconds. Same keys/rounding the serving engines have always
    emitted: ``{"qps", "p50_ms", "p99_ms", "mean_ms"}``.
    """
    lat_ms = [float(v) * 1e3 for v in lat_s]
    return {
        "qps": round(n_requests / wall_s, 1),
        "p50_ms": round(percentile(lat_ms, 50), 3),
        "p99_ms": round(percentile(lat_ms, 99), 3),
        "mean_ms": round(sum(lat_ms) / len(lat_ms), 3),
    }
