"""Per-request trace spans + the module-level telemetry switch.

A request produces one *span tree*: a root span (``request``) whose
descendants are the pipeline stages (admission → validate → plan-resolve →
decode dispatch → kernel/epilogue → skip-gallop/merge → score → top-k).
Spans carry structured attributes — format, plan label, chunk width, blocks
decoded/skipped/pruned, epilogue name — set at open time or via
``span.set(...)`` as counts become known.

**Null fast path.** The hot decode/serving code calls :func:`trace` and the
``counter_inc``/``gauge_set``/``histogram_observe`` helpers unconditionally.
With nothing installed these cost one module-global read and a ``None``
check; :func:`trace` returns the shared :data:`NULL_SPAN` singleton, so the
clean path allocates no span objects and stays bit-exact. Everything
activates only under :func:`install`, which flips the single module global::

    tele = Telemetry()
    with install(tele):
        engine.search(...)
    tele.tracer.write_chrome_trace("trace.json")

Spans can optionally mirror into ``jax.profiler.TraceAnnotation`` so the
same stage names show up inside an XLA profile
(``Telemetry(jax_annotations=True)``).
"""
from __future__ import annotations

import itertools
import threading
import time

from .metrics import MetricsRegistry


class _NullSpan:
    """Shared no-op recorder: every method returns cheaply, ``set``/``event``
    drop their arguments, and re-entering the same singleton is safe."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self

    def event(self, name, **attrs):
        return self

    def __bool__(self):  # `if span:` guards expensive attribute computation
        return False


NULL_SPAN = _NullSpan()


class Span:
    """One timed stage. Context manager; closing records the span into the
    tracer and pops it off the thread's stack."""

    __slots__ = ("tracer", "name", "attrs", "span_id", "parent_id",
                 "trace_id", "t0", "dur", "_jax_ann")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs
        self.span_id = 0
        self.parent_id = None
        self.trace_id = 0
        self.t0 = 0.0
        self.dur = 0.0
        self._jax_ann = None

    def set(self, **attrs):
        self.attrs.update(attrs)
        return self

    def event(self, name: str, **attrs):
        """Zero-duration marker inside this span (e.g. a crash point hit)."""
        self.tracer._record_event(self, name, attrs)
        return self

    def __bool__(self):
        return True

    def __enter__(self):
        # open/close are inlined here (not Tracer methods): spans are the
        # instrumented hot path and every avoided call shows up in the
        # serving overhead gate
        tr = self.tracer
        st = tr._stack()
        self.span_id = next(tr._ids)
        if st:
            top = st[-1]
            self.parent_id = top.span_id
            self.trace_id = top.trace_id
        else:
            self.parent_id = None
            self.trace_id = self.span_id  # root: trace keyed by its own id
        st.append(self)
        if tr.jax_annotations:
            try:
                import jax

                self._jax_ann = jax.profiler.TraceAnnotation(self.name)
                self._jax_ann.__enter__()
            except Exception:
                self._jax_ann = None
        self.t0 = tr.clock()
        return self

    def __exit__(self, exc_type, exc, tb):
        tr = self.tracer
        self.dur = tr.clock() - self.t0
        if self._jax_ann is not None:
            self._jax_ann.__exit__(exc_type, exc, tb)
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        st = tr._stack()
        if st and st[-1] is self:
            st.pop()
        elif self in st:  # unwound out of order (exception paths): drop tail
            del st[st.index(self):]
        # list.append is atomic under the GIL; readers take the lock and
        # only ever see a consistent prefix, so the close path is lock-free
        tr.spans.append(
            {"type": "span", "name": self.name, "ts": self.t0,
             "dur": self.dur, "span_id": self.span_id,
             "parent_id": self.parent_id, "trace_id": self.trace_id,
             "attrs": self.attrs})
        return False


class Tracer:
    """Collects finished spans as plain dict records (JSON-ready).

    Parentage comes from a thread-local open-span stack: a span opened while
    another is open on the same thread becomes its child; a span opened on
    an empty stack roots a new trace (one per request). Finished-span
    records append under a lock, so concurrent request threads can share
    one tracer.
    """

    def __init__(self, *, clock=None, jax_annotations: bool = False):
        self.clock = clock or time.perf_counter
        self.jax_annotations = jax_annotations
        self.spans: list[dict] = []
        self._lock = threading.Lock()
        self._tls = threading.local()
        # itertools.count: thread-safe id allocation without taking a lock
        # on the span-open hot path
        self._ids = itertools.count(1)

    def span(self, name: str, **attrs) -> Span:
        return Span(self, name, attrs)

    # -- span lifecycle ------------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _record_event(self, span: Span, name: str, attrs: dict):
        self.spans.append(
            {"type": "event", "name": name, "ts": self.clock(),
             "span_id": span.span_id, "trace_id": span.trace_id,
             "attrs": attrs})

    def current(self) -> Span | _NullSpan:
        st = getattr(self._tls, "stack", None)
        return st[-1] if st else NULL_SPAN

    # -- queries -------------------------------------------------------------
    def durations(self, name: str) -> list[float]:
        """Durations (seconds) of every finished span with this name."""
        with self._lock:
            return [s["dur"] for s in self.spans
                    if s["type"] == "span" and s["name"] == name]

    def trees(self) -> dict[int, list[dict]]:
        """Finished spans grouped per trace (one entry per request)."""
        out: dict[int, list[dict]] = {}
        with self._lock:
            for s in self.spans:
                if s["type"] == "span":
                    out.setdefault(s["trace_id"], []).append(s)
        return out

    # -- export --------------------------------------------------------------
    def write_jsonl(self, path):
        from .exporters import write_jsonl

        write_jsonl(self, path)

    def write_chrome_trace(self, path):
        from .exporters import write_chrome_trace

        write_chrome_trace(self, path)


class Telemetry:
    """Registry + tracer bundle sharing one clock — the unit of install."""

    def __init__(self, *, clock=None, jax_annotations: bool = False):
        self.registry = MetricsRegistry(clock=clock)
        self.tracer = Tracer(clock=clock, jax_annotations=jax_annotations)


# ---------------------------------------------------------------------------
# the module-level switch: one global, read on every instrumentation site
# ---------------------------------------------------------------------------
_ACTIVE: Telemetry | None = None


class _Installed:
    """Handle returned by :func:`install`: usable as a context manager that
    restores whatever was installed before (supports nesting in tests)."""

    __slots__ = ("_prev",)

    def __init__(self, prev):
        self._prev = prev

    def __enter__(self):
        return _ACTIVE

    def __exit__(self, *exc):
        global _ACTIVE
        _ACTIVE = self._prev
        return False


def install(tele: Telemetry) -> _Installed:
    """Activate telemetry. Plain-call (`install(t)` … `uninstall()`) or
    ``with install(t):`` both work; the ``with`` form restores the previous
    telemetry on exit."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = tele
    return _Installed(prev)


def uninstall():
    global _ACTIVE
    _ACTIVE = None


def installed() -> Telemetry | None:
    return _ACTIVE


def trace(name: str, **attrs):
    """Open a stage span — or return :data:`NULL_SPAN` when telemetry is off.

    The off path is the contract: no allocation, no branching beyond one
    global read, identical control flow for the instrumented code.
    """
    t = _ACTIVE
    if t is None:
        return NULL_SPAN
    return Span(t.tracer, name, attrs)


def current():
    """The innermost open span on this thread (NULL_SPAN when off/idle)."""
    t = _ACTIVE
    if t is None:
        return NULL_SPAN
    return t.tracer.current()


def counter_inc(name: str, n=1, **labels):
    t = _ACTIVE
    if t is not None:
        t.registry.counter(name, **labels).inc(n)


def gauge_set(name: str, v, **labels):
    t = _ACTIVE
    if t is not None:
        t.registry.gauge(name, **labels).set(v)


def histogram_observe(name: str, v, **labels):
    t = _ACTIVE
    if t is not None:
        t.registry.histogram(name, **labels).observe(v)
