"""Exporters: JSONL event log, Prometheus text exposition, Chrome trace.

Formats (docs/observability.md):

* **JSONL** — one record per line; ``{"type": "span", ...}`` rows carry
  ``ts``/``dur`` (seconds, tracer clock), ``span_id``/``parent_id``/
  ``trace_id`` and the attribute dict, ``{"type": "event", ...}`` rows are
  zero-duration markers. Lossless — ``read_jsonl`` round-trips exactly,
  and ``python -m repro.obs.report`` consumes it.
* **Prometheus** — standard text exposition. Histograms emit the cumulative
  ``_bucket{le=...}`` / ``_sum`` / ``_count`` triplet with power-of-two
  ``le`` bounds matching the log2 buckets.
* **Chrome trace** — ``{"traceEvents": [...]}`` complete (``"ph": "X"``)
  events in microseconds, one ``tid`` row per request trace so Perfetto /
  ``chrome://tracing`` renders each span tree as its own nested track.
  ``span_id``/``parent_id`` ride along in ``args`` so nesting survives a
  round-trip exactly instead of being inferred from time containment.
"""
from __future__ import annotations

import json

from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .trace import Tracer


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------
def _fmt_labels(lkey: tuple, extra: tuple = ()) -> str:
    pairs = list(lkey) + list(extra)
    if not pairs:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in pairs)
    return "{" + body + "}"


def prometheus_text(registry: MetricsRegistry) -> str:
    lines: list[str] = []
    with registry._lock:
        items = sorted(registry._metrics.items())
    seen_help = set()
    for (name, lkey), m in items:
        if isinstance(m, Counter):
            if name not in seen_help:
                lines.append(f"# TYPE {name} counter")
                seen_help.add(name)
            lines.append(f"{name}{_fmt_labels(lkey)} {m.value}")
        elif isinstance(m, Gauge):
            if name not in seen_help:
                lines.append(f"# TYPE {name} gauge")
                seen_help.add(name)
            lines.append(f"{name}{_fmt_labels(lkey)} {m.value}")
        elif isinstance(m, Histogram):
            if name not in seen_help:
                lines.append(f"# TYPE {name} histogram")
                seen_help.add(name)
            cum = 0
            for e in sorted(m.buckets):
                cum += m.buckets[e]
                le = repr(float(2.0 ** e))
                lines.append(
                    f"{name}_bucket{_fmt_labels(lkey, (('le', le),))} {cum}")
            lines.append(
                f"{name}_bucket{_fmt_labels(lkey, (('le', '+Inf'),))} "
                f"{m.count}")
            lines.append(f"{name}_sum{_fmt_labels(lkey)} {m.total}")
            lines.append(f"{name}_count{_fmt_labels(lkey)} {m.count}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> dict[str, float]:
    """Minimal exposition parser (CI smoke): ``{series: value}``. Raises on
    any malformed sample line, which is the point."""
    out: dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        series, _, value = line.rpartition(" ")
        if not series:
            raise ValueError(f"malformed exposition line: {line!r}")
        out[series] = float(value)
    return out


# ---------------------------------------------------------------------------
# JSONL span log
# ---------------------------------------------------------------------------
def write_jsonl(tracer: Tracer, path):
    with tracer._lock:
        recs = list(tracer.spans)
    with open(path, "w") as f:
        for rec in recs:
            f.write(json.dumps(rec, sort_keys=True) + "\n")


def read_jsonl(path) -> list[dict]:
    recs = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                recs.append(json.loads(line))
    return recs


# ---------------------------------------------------------------------------
# Chrome trace / Perfetto
# ---------------------------------------------------------------------------
def chrome_trace_events(tracer: Tracer) -> list[dict]:
    with tracer._lock:
        recs = list(tracer.spans)
    events = []
    for rec in recs:
        args = dict(rec["attrs"])
        args["span_id"] = rec["span_id"]
        if rec["type"] == "span":
            args["parent_id"] = rec["parent_id"]
            events.append({"name": rec["name"], "ph": "X", "pid": 0,
                           "tid": rec["trace_id"],
                           "ts": rec["ts"] * 1e6,
                           "dur": rec["dur"] * 1e6,
                           "args": args})
        else:
            events.append({"name": rec["name"], "ph": "i", "pid": 0,
                           "tid": rec["trace_id"], "ts": rec["ts"] * 1e6,
                           "s": "t", "args": args})
    return events


def write_chrome_trace(tracer: Tracer, path):
    doc = {"traceEvents": chrome_trace_events(tracer),
           "displayTimeUnit": "ms"}
    with open(path, "w") as f:
        json.dump(doc, f)


def read_chrome_trace(path) -> list[dict]:
    with open(path) as f:
        return json.load(f)["traceEvents"]
