"""Metrics registry: counters, gauges, log-bucketed histograms.

Design constraints (docs/observability.md):

* **Dependency-free** — stdlib only, importable before jax/numpy init.
* **Mergeable** — every metric supports ``merge(other)`` by pure addition
  (gauges take the latest write), so folding per-shard or per-subprocess
  registries together is associative and commutative: any merge order
  produces the same aggregate, which is what lets the benchmark sweeps
  and the logical-shard serving path aggregate without coordination.
* **Injectable clock** — ``MetricsRegistry(clock=...)`` drives every
  ``timer()`` measurement, so tests pin exact durations (and therefore
  exact histogram buckets) with a simulated clock.

Histograms are log2-bucketed: an observation ``v`` lands in the bucket
whose upper bound is the smallest power of two ``>= v`` (computed exactly
via ``math.frexp`` — no float-log drift at bucket boundaries). Bucket
counts, not samples, are what merge — a histogram is O(#distinct
magnitudes), never O(#observations).
"""
from __future__ import annotations

import math
import threading
import time

# log2 bucket exponent clamp: 2^-40 s ≈ 1 ps under any latency of
# interest, 2^64 covers any byte/int size metric
MIN_EXP = -40
MAX_EXP = 64


def bucket_exp(v: float) -> int:
    """Exponent ``e`` of the smallest power of two ``2**e >= v`` (clamped).

    Exact at boundaries: ``bucket_exp(0.25) == -2``, ``bucket_exp(8) == 3``,
    ``bucket_exp(9) == 4``. Non-positive observations land in ``MIN_EXP``.
    """
    if v <= 0:
        return MIN_EXP
    m, e = math.frexp(v)  # v = m * 2**e with 0.5 <= m < 1
    e = e - 1 if m == 0.5 else e
    return max(MIN_EXP, min(MAX_EXP, e))


class Counter:
    """Monotonic counter. ``inc`` only; merge is addition."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int | float = 1):
        self.value += n

    def merge(self, other: "Counter"):
        self.value += other.value

    def snapshot(self):
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-write-wins scalar (queue depth, epoch, delta size)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def set(self, v):
        self.value = v

    def inc(self, n=1):
        self.value += n

    def merge(self, other: "Gauge"):
        self.value = other.value  # latest write wins across merges

    def snapshot(self):
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Log2-bucketed histogram of latencies / sizes.

    ``buckets[e]`` counts observations in ``(2**(e-1), 2**e]`` (``MIN_EXP``
    also absorbs everything at or below its lower edge). Merging adds
    bucket counts — associative, so shard order never changes the result.
    """

    __slots__ = ("buckets", "count", "total", "vmin", "vmax")

    def __init__(self):
        self.buckets: dict[int, int] = {}
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, v: float):
        e = bucket_exp(v)
        self.buckets[e] = self.buckets.get(e, 0) + 1
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)

    def merge(self, other: "Histogram"):
        for e, n in other.buckets.items():
            self.buckets[e] = self.buckets.get(e, 0) + n
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile: the upper bound of the bucket the
        q-quantile observation falls in (0 for an empty histogram)."""
        if not self.count:
            return 0.0
        rank = max(1, math.ceil(q * self.count))
        seen = 0
        for e in sorted(self.buckets):
            seen += self.buckets[e]
            if seen >= rank:
                return float(2.0 ** e)
        return float(2.0 ** max(self.buckets))

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self):
        return {"type": "histogram", "count": self.count,
                "sum": self.total,
                "min": None if self.count == 0 else self.vmin,
                "max": None if self.count == 0 else self.vmax,
                "buckets": {str(e): n for e, n in sorted(self.buckets.items())}}


def _labels_key(labels: dict) -> tuple:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class _Timer:
    """Context manager observing its own wall time into a histogram."""

    __slots__ = ("_hist", "_clock", "_t0", "elapsed")

    def __init__(self, hist: Histogram, clock):
        self._hist = hist
        self._clock = clock
        self.elapsed = 0.0

    def __enter__(self):
        self._t0 = self._clock()
        return self

    def __exit__(self, *exc):
        self.elapsed = self._clock() - self._t0
        self._hist.observe(self.elapsed)
        return False


class MetricsRegistry:
    """Named, labeled metrics + structured event records.

    ``counter/gauge/histogram(name, **labels)`` create-or-return the metric
    for that (name, label-set) — label values stringify, so
    ``reg.counter("decode_calls_total", plan=p.label)`` is one series per
    plan. ``merge(other)`` folds a whole registry in (shard/subprocess
    aggregation). ``record_event`` appends a timestamped structured record
    (e.g. one crash-recovery summary per reopen); events concatenate on
    merge. All mutation is lock-protected — serving engines observe from
    request threads while a background merge records phase durations.
    """

    def __init__(self, *, clock=None):
        self.clock = clock or time.perf_counter
        self._lock = threading.Lock()
        self._metrics: dict[tuple, Counter | Gauge | Histogram] = {}
        # per-call-site fast path: raw (kind, name, labels) -> metric, so
        # the hot instrumentation helpers skip label stringification and
        # the lock after a series' first touch (dict reads are GIL-atomic)
        self._fast: dict[tuple, Counter | Gauge | Histogram] = {}
        self.events: list[dict] = []

    def _get(self, kind, name: str, labels: dict):
        fkey = (kind, name, tuple(sorted(labels.items())) if labels else ())
        m = self._fast.get(fkey)
        if m is not None:
            return m
        key = (name, _labels_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = kind()
                self._metrics[key] = m
            elif not isinstance(m, kind):
                raise TypeError(
                    f"metric {name!r}{labels} already registered as "
                    f"{type(m).__name__}, requested {kind.__name__}")
            self._fast[fkey] = m
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def timer(self, name: str, **labels) -> _Timer:
        """``with reg.timer("wal_append_seconds"): ...`` — observes the
        block's duration (by the registry's clock) into the histogram."""
        return _Timer(self.histogram(name, **labels), self.clock)

    def record_event(self, name: str, **fields):
        evt = {"event": name, "ts": self.clock(), **fields}
        with self._lock:
            self.events.append(evt)
        return evt

    def merge(self, other: "MetricsRegistry"):
        """Fold ``other`` in. Addition for counters/histograms (associative
        across any merge order), last-write for gauges, concatenation for
        events."""
        with other._lock:
            items = list(other._metrics.items())
            events = list(other.events)
        for key, m in items:
            name, lkey = key
            mine = self._get(type(m), name, dict(lkey))
            mine.merge(m)
        with self._lock:
            self.events.extend(events)

    # -- export --------------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-ready dump: ``{name{labels}: metric snapshot}`` + events."""
        with self._lock:
            items = list(self._metrics.items())
            events = list(self.events)
        out = {}
        for (name, lkey), m in sorted(items):
            label_s = ",".join(f"{k}={v}" for k, v in lkey)
            out[f"{name}{{{label_s}}}" if label_s else name] = m.snapshot()
        return {"metrics": out, "events": events}

    def to_prometheus(self) -> str:
        """Prometheus text exposition (histograms as cumulative ``le``
        buckets, the standard ``_bucket/_sum/_count`` triplet)."""
        from .exporters import prometheus_text

        return prometheus_text(self)
