"""Inverted-index construction over the compressed-array stack.

One :class:`TermPostings` per term: the sorted docid list d-gap-coded into
a blocked :class:`~repro.core.CompressedIntArray` (``differential=True`` —
per-block ``bases`` make every block independently decodable, exactly the
classic skip-block layout), plus a **skip table** (``first_doc`` /
``last_doc`` per block) so the query engine prunes at block granularity
before anything is decoded, and the document frequency for term ordering
and impact scoring.

Scoring uses **quantized impacts**: the BM25 idf of each term (the tf-free
BM25 score of a match — synthetic posting lists carry no term frequencies)
is quantized to an integer in ``[1, 2^impact_bits)``. Integer impacts make
score accumulation exact, so fused / unfused / sharded / dense / banded
query paths are bit-identical by construction (repro.index.query).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.core import CompressedIntArray

MAX_DOCID = (1 << 31) - 1  # the membership epilogue compares in int32


@dataclass(frozen=True)
class TermPostings:
    """One term's compressed posting list + block skip table."""

    term: int
    arr: CompressedIntArray  # d-gap coded, differential=True
    first_doc: np.ndarray  # uint32 [n_live_blocks] first docid per block
    last_doc: np.ndarray  # uint32 [n_live_blocks] last docid per block
    df: int  # document frequency (= arr.n)

    @property
    def n_blocks(self) -> int:
        """Live (non-padding) blocks — the skip table's length."""
        return len(self.first_doc)


@dataclass
class InvertedIndex:
    """Term id → compressed postings, plus collection-level stats."""

    terms: dict[int, TermPostings]
    n_docs: int  # collection size N (docid universe)
    block_size: int
    format: str
    impact_bits: int = 8

    def __contains__(self, term: int) -> bool:
        return term in self.terms

    def df(self, term: int) -> int:
        tp = self.terms.get(term)
        return tp.df if tp is not None else 0

    def idf(self, term: int) -> float:
        """BM25 idf: ``ln(1 + (N - df + 0.5) / (df + 0.5))``."""
        df = self.df(term)
        return math.log1p((self.n_docs - df + 0.5) / (df + 0.5))

    def impact(self, term: int) -> int:
        """Quantized integer impact in ``[1, 2^impact_bits)``.

        Scaled against the rarest possible term (df=1) so the full
        quantization range is used; every path that accumulates these
        (fused kernel, jnp grid, numpy oracle) works in exact int32.
        """
        if self.df(term) == 0:
            return 0
        idf_max = math.log1p((self.n_docs - 0.5) / 1.5)
        q = round(self.idf(term) / idf_max * ((1 << self.impact_bits) - 1))
        return max(1, int(q))

    @property
    def n_terms(self) -> int:
        return len(self.terms)

    @property
    def n_postings(self) -> int:
        return sum(tp.df for tp in self.terms.values())

    @property
    def bits_per_int(self) -> float:
        """Corpus-weighted compressed bits per posting (paper §V metric)."""
        total_bits = sum(tp.arr.bits_per_int * tp.df
                         for tp in self.terms.values() if tp.df)
        return total_bits / max(self.n_postings, 1)

    def stats(self) -> dict:
        blocks = sum(tp.arr.n_blocks for tp in self.terms.values())
        return {"n_terms": self.n_terms, "n_postings": self.n_postings,
                "n_blocks": blocks, "format": self.format,
                "block_size": self.block_size,
                "bits_per_int": round(self.bits_per_int, 2)}


def _skip_table(docids: np.ndarray, block_size: int):
    """Per-block ``(first_doc, last_doc)`` — the block-level skip table."""
    n = len(docids)
    if n == 0:
        return (np.zeros(0, np.uint32), np.zeros(0, np.uint32))
    nb = -(-n // block_size)
    first = docids[np.arange(nb) * block_size]
    last = docids[np.minimum(np.arange(1, nb + 1) * block_size, n) - 1]
    return first.astype(np.uint32), last.astype(np.uint32)


def build_index(
    lists,
    *,
    format: str = "vbyte",
    block_size: int = 128,
    n_docs: int | None = None,
    impact_bits: int = 8,
    stride_multiple: int = 128,
) -> InvertedIndex:
    """Build a compressed inverted index from per-term docid lists.

    ``lists`` is a ``{term: sorted_docids}`` mapping or a sequence (term =
    position), each list strictly increasing uint32 docids < 2^31 (e.g.
    ``repro.data.synthetic.posting_list_group``). Each list is d-gap
    coded into a blocked ``CompressedIntArray`` (``differential=True``)
    with a per-block first/last-docid skip table. ``n_docs`` defaults to
    ``max docid + 1``.
    """
    if not isinstance(lists, dict):
        lists = dict(enumerate(lists))
    terms: dict[int, TermPostings] = {}
    max_doc = -1
    for term, docs in lists.items():
        d = np.asarray(docs, dtype=np.uint64).ravel()
        if d.size:
            if int(d.max()) > MAX_DOCID:
                raise ValueError(
                    f"term {term}: docids must be < 2^31 (got {d.max()}) — "
                    "the membership epilogue compares in int32")
            if np.any(np.diff(d.astype(np.int64)) <= 0):
                raise ValueError(
                    f"term {term}: docids must be strictly increasing")
            max_doc = max(max_doc, int(d.max()))
        arr = CompressedIntArray.encode(
            d, format=format, block_size=block_size, differential=True,
            stride_multiple=stride_multiple)
        first, last = _skip_table(d, block_size)
        terms[term] = TermPostings(term=term, arr=arr, first_doc=first,
                                   last_doc=last, df=int(d.size))
    if n_docs is None:
        n_docs = max_doc + 1 if max_doc >= 0 else 1
    if n_docs > MAX_DOCID + 1:
        raise ValueError("n_docs must be ≤ 2^31")
    return InvertedIndex(terms=terms, n_docs=int(n_docs),
                         block_size=block_size, format=format,
                         impact_bits=impact_bits)
