"""Inverted-index construction over the compressed-array stack.

One :class:`TermPostings` per term: the sorted docid list d-gap-coded into
a blocked :class:`~repro.core.CompressedIntArray` (``differential=True`` —
per-block ``bases`` make every block independently decodable, exactly the
classic skip-block layout), plus a **skip table** (``first_doc`` /
``last_doc`` per block) so the query engine prunes at block granularity
before anything is decoded, and the document frequency for term ordering
and impact scoring.

Scoring uses **quantized impacts**: each term's BM25 idf is quantized to
an integer in ``[1, 2^impact_bits)``. When per-posting term frequencies
are supplied (``build_index(..., tfs=...)``) the idf impact is scaled by
the BM25 tf-saturation ``tf·(k1+1)/(tf+k1)`` per posting; the resulting
per-posting impacts are encoded into a **second blocked
CompressedIntArray** (``differential=False``) whose blocks align 1:1 with
the docid-gap blocks, plus a per-block ``max_impact`` column next to the
skip table — the block-max bound that drives MaxScore pruning
(repro.index.query, ``topk(mode="maxscore")``). With no tfs every posting
gets tf=1, whose saturation is exactly 1, so impacts degenerate to the
tf-free constant and all scoring paths stay bit-identical to the
constant-impact behaviour. Integer impacts make score accumulation exact,
so fused / unfused / sharded / dense / banded query paths are
bit-identical by construction.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from repro.core import CompressedIntArray
from repro.core.vbyte import prepare_blocked

MAX_DOCID = (1 << 31) - 1  # the membership epilogue compares in int32
BM25_K1 = 1.2  # tf-saturation shape; sat(1) == 1 exactly, keeping tf-free
#                indexes bit-identical to the constant-impact scoring


@dataclass(frozen=True)
class TermPostings:
    """One term's compressed posting list + block skip table."""

    term: int
    arr: CompressedIntArray  # d-gap coded, differential=True
    first_doc: np.ndarray  # uint32 [n_live_blocks] first docid per block
    last_doc: np.ndarray  # uint32 [n_live_blocks] last docid per block
    df: int  # document frequency (= arr.n)
    impacts: CompressedIntArray | None = None  # per-posting quantized
    #   impacts, differential=False, blocks aligned 1:1 with ``arr``
    max_impact: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.int32))  # int32 per block

    @property
    def n_blocks(self) -> int:
        """Live (non-padding) blocks — the skip table's length."""
        return len(self.first_doc)

    @property
    def ub(self) -> int:
        """Term score upper bound: the largest block-max impact."""
        return int(self.max_impact.max()) if self.max_impact.size else 0


@dataclass
class InvertedIndex:
    """Term id → compressed postings, plus collection-level stats."""

    terms: dict[int, TermPostings]
    n_docs: int  # collection size N (docid universe)
    block_size: int
    format: str
    impact_bits: int = 8
    has_tf: bool = False  # were real per-posting tfs supplied at build?

    def __contains__(self, term: int) -> bool:
        return term in self.terms

    def df(self, term: int) -> int:
        tp = self.terms.get(term)
        return tp.df if tp is not None else 0

    def idf(self, term: int) -> float:
        """BM25 idf: ``ln(1 + (N - df + 0.5) / (df + 0.5))``."""
        df = self.df(term)
        return math.log1p((self.n_docs - df + 0.5) / (df + 0.5))

    def impact(self, term: int) -> int:
        """Quantized tf-free integer impact in ``[1, 2^impact_bits)``.

        Scaled against the rarest possible term (df=1) so the full
        quantization range is used; every path that accumulates these
        (fused kernel, jnp grid, numpy oracle) works in exact int32.
        Per-posting impacts scale this by the BM25 tf saturation
        (:func:`quantize_impacts`).
        """
        return impact_value(self.n_docs, self.df(term), self.impact_bits)

    @property
    def n_terms(self) -> int:
        return len(self.terms)

    @property
    def n_postings(self) -> int:
        return sum(tp.df for tp in self.terms.values())

    @property
    def bits_per_int(self) -> float:
        """Corpus-weighted compressed bits per posting (paper §V metric)."""
        total_bits = sum(tp.arr.bits_per_int * tp.df
                         for tp in self.terms.values() if tp.df)
        return total_bits / max(self.n_postings, 1)

    def stats(self) -> dict:
        blocks = sum(tp.arr.n_blocks for tp in self.terms.values())
        return {"n_terms": self.n_terms, "n_postings": self.n_postings,
                "n_blocks": blocks, "format": self.format,
                "block_size": self.block_size,
                "bits_per_int": round(self.bits_per_int, 2),
                "has_tf": self.has_tf}


def impact_value(n_docs: int, df: int, impact_bits: int = 8) -> int:
    """The quantized tf-free impact as a pure function of ``(n_docs, df)``.

    Shared by :meth:`InvertedIndex.impact` and the live index's
    query-time scoring (``repro.index.ingest``), which must compute the
    *identical* integer for a term whose df is the merged main+delta
    count — any drift here would break the bit-identity between a
    LiveIndex query and the same query on a rebuilt-from-scratch index.
    """
    if df == 0:
        return 0
    idf = math.log1p((n_docs - df + 0.5) / (df + 0.5))
    idf_max = math.log1p((n_docs - 0.5) / 1.5)
    q = round(idf / idf_max * ((1 << impact_bits) - 1))
    return max(1, int(q))


def quantize_impacts(base_impact: int, tfs, impact_bits: int = 8,
                     k1: float = BM25_K1) -> np.ndarray:
    """Per-posting quantized impacts: ``base_impact`` (the term's tf-free
    quantized idf impact) scaled by the BM25 tf saturation
    ``tf·(k1+1)/(tf+k1)``, rounded and clipped to ``[1, 2^impact_bits)``.

    ``sat(1) == 1`` exactly, so tf=1 postings keep ``base_impact``
    unchanged — a tf-free index scores bit-identically whether the
    constant or the per-posting stream is used. Shared by the builder and
    the test oracles so quantization can never drift between them.
    """
    tf = np.asarray(tfs, dtype=np.float64)
    sat = tf * (k1 + 1.0) / (tf + k1)
    q = np.rint(base_impact * sat)
    return np.clip(q, 1, (1 << impact_bits) - 1).astype(np.int32)


def _skip_table(docids: np.ndarray, block_size: int):
    """Per-block ``(first_doc, last_doc)`` — the block-level skip table."""
    n = len(docids)
    if n == 0:
        return (np.zeros(0, np.uint32), np.zeros(0, np.uint32))
    nb = -(-n // block_size)
    first = docids[np.arange(nb) * block_size]
    last = docids[np.minimum(np.arange(1, nb + 1) * block_size, n) - 1]
    return first.astype(np.uint32), last.astype(np.uint32)


def _block_max(vals: np.ndarray, block_size: int) -> np.ndarray:
    """Per-block max of ``vals`` (int32) — the ``max_impact`` column."""
    n = len(vals)
    if n == 0:
        return np.zeros(0, np.int32)
    nb = -(-n // block_size)
    pad = np.zeros(nb * block_size, np.int32)
    pad[:n] = vals
    return pad.reshape(nb, block_size).max(axis=1)


def _check_docids(term, docs) -> np.ndarray:
    """Validate one docid list: integer dtype, in-range, increasing."""
    d = np.asarray(docs).ravel()
    if d.size == 0:
        return np.zeros(0, np.uint64)
    if d.dtype.kind not in "iu":
        raise ValueError(
            f"term {term}: docids must have an integer dtype, got "
            f"{d.dtype} — refusing to silently truncate")
    if d.dtype.kind == "i" and int(d.min()) < 0:
        raise ValueError(f"term {term}: docids must be non-negative")
    d = d.astype(np.uint64)
    if int(d.max()) > MAX_DOCID:
        raise ValueError(
            f"term {term}: docids must be < 2^31 (got {d.max()}) — "
            "the membership epilogue compares in int32")
    if np.any(np.diff(d.astype(np.int64)) <= 0):
        raise ValueError(f"term {term}: docids must be strictly increasing")
    return d


def build_index(
    lists,
    *,
    tfs=None,
    format: str = "vbyte",
    block_size: int = 128,
    n_docs: int | None = None,
    impact_bits: int = 8,
    stride_multiple: int = 128,
    checksum: bool = False,
) -> InvertedIndex:
    """Build a compressed inverted index from per-term docid lists.

    ``lists`` is a ``{term: sorted_docids}`` mapping or a sequence (term =
    position), each list strictly increasing uint32 docids < 2^31 (e.g.
    ``repro.data.synthetic.posting_list_group``). Each list is d-gap
    coded into a blocked ``CompressedIntArray`` (``differential=True``)
    with a per-block first/last-docid skip table. ``n_docs`` defaults to
    ``max docid + 1``.

    ``tfs`` optionally supplies per-posting term frequencies — a mapping
    (or parallel sequence) of integer arrays ≥ 1, one per term, aligned
    with the docid lists. Impacts are quantized per posting
    (:func:`quantize_impacts`) and encoded into a second blocked
    ``CompressedIntArray`` plus a per-block ``max_impact`` column; terms
    without a tfs entry default to tf=1 everywhere (bit-identical to the
    tf-free constant-impact index).

    ``checksum=True`` writes the per-block checksum column on both the
    docid-gap and impact streams (``CompressedIntArray.encode(...,
    checksum=True)``), enabling checksum-verified decode and the serving
    layer's segment quarantine (docs/robustness.md).

    ``format="auto"`` runs the shortest-path block-partition DP per list
    (``repro.index.partition``): each term gets its own codec (vbyte /
    streamvbyte / binpack) and its own variable-count block boundaries,
    chosen to minimize encoded bits + modeled decode cost. The emitted
    arrays are ordinary uniform-``block_size`` ``CompressedIntArray``s
    (counts ≤ block_size mask the tails), so the query engine, MaxScore,
    skip tables and the sharded serving path consume the mixed-codec index
    transparently — and the corpus bits/int can only improve on the
    uniform single-codec layout (docs/index.md §Optimal partitioning).
    """
    if not isinstance(lists, dict):
        lists = dict(enumerate(lists))
    if tfs is not None and not isinstance(tfs, dict):
        tfs = dict(enumerate(tfs))
    docids: dict[int, np.ndarray] = {}
    tf_arrs: dict[int, np.ndarray] = {}
    max_doc = -1
    for term, docs in lists.items():
        d = _check_docids(term, docs)
        if d.size:
            max_doc = max(max_doc, int(d.max()))
        docids[term] = d
        tf = None if tfs is None else tfs.get(term)
        if tf is not None:
            t = np.asarray(tf).ravel()
            if t.dtype.kind not in "iu":
                raise ValueError(
                    f"term {term}: tfs must have an integer dtype, got "
                    f"{t.dtype}")
            if t.size != d.size:
                raise ValueError(
                    f"term {term}: tfs length {t.size} != docids "
                    f"length {d.size}")
            if t.size and int(t.min()) < 1:
                raise ValueError(f"term {term}: tfs must be ≥ 1")
            tf_arrs[term] = t.astype(np.int64)
    if n_docs is None:
        n_docs = max_doc + 1 if max_doc >= 0 else 1
    if n_docs > MAX_DOCID + 1:
        raise ValueError("n_docs must be ≤ 2^31")
    index = InvertedIndex(terms={}, n_docs=int(n_docs),
                          block_size=block_size, format=format,
                          impact_bits=impact_bits, has_tf=bool(tf_arrs))
    for term, d in docids.items():
        if format == "auto":
            from repro.index.partition import (
                choose_partition, encode_partitioned)

            part = choose_partition(d, block_size=block_size)
            arr = encode_partitioned(
                d, part.bounds, format=part.format, block_size=block_size,
                differential=True, stride_multiple=stride_multiple,
                checksum=checksum)
            if d.size:
                first = d[part.bounds[:-1]].astype(np.uint32)
                last = d[part.bounds[1:] - 1].astype(np.uint32)
            else:
                first = last = np.zeros(0, np.uint32)
        else:
            # one metadata pass (validate, delta, bases, counts) shared by
            # the payload encode AND the skip table — prepare_blocked was
            # previously recomputed inside encode() and again here
            meta = prepare_blocked(d, block_size=block_size,
                                   differential=True)
            arr = CompressedIntArray.encode(
                format=format, block_size=block_size, differential=True,
                stride_multiple=stride_multiple, checksum=checksum,
                meta=meta)
            first, last = meta.skip_table()
        tp = TermPostings(term=term, arr=arr, first_doc=first,
                          last_doc=last, df=int(d.size))
        index.terms[term] = tp  # impact() below needs df registered
        tf = tf_arrs.get(term, np.ones(d.size, np.int64))
        q = quantize_impacts(index.impact(term), tf, impact_bits)
        if format == "auto":
            # impacts share the docid stream's partition so blocks stay
            # aligned 1:1 (MaxScore's block-max column indexes both)
            imp = encode_partitioned(
                q.astype(np.uint64), part.bounds, format=part.format,
                block_size=block_size, differential=False,
                stride_multiple=stride_multiple, checksum=checksum)
            mi = np.array([int(q[i:j].max(initial=0)) for i, j in
                           zip(part.bounds[:-1], part.bounds[1:])],
                          np.int32) if d.size else np.zeros(0, np.int32)
        else:
            imeta = prepare_blocked(q.astype(np.uint64),
                                    block_size=block_size,
                                    differential=False)
            imp = CompressedIntArray.encode(
                format=format, block_size=block_size, differential=False,
                stride_multiple=stride_multiple, checksum=checksum,
                meta=imeta)
            mi = _block_max(q, block_size)
        index.terms[term] = TermPostings(
            term=term, arr=arr, first_doc=first, last_doc=last,
            df=int(d.size), impacts=imp, max_impact=mi)
    return index
