"""Crash-safe streaming ingestion: a WAL-backed live index.

:class:`LiveIndex` makes the inverted index mutable without giving up the
immutable compressed segments everything else in the repo is built on.
Three layers (docs/ingestion.md):

* **Main segment** — an ordinary :class:`~repro.index.builder.InvertedIndex`
  (DP-partitioned ``format="auto"`` by default, checksummed), persisted
  under ``segments/seg_<epoch>/`` with a whole-file CRC. Immutable.
* **Delta** — an uncompressed ``doc -> {term: tf}`` map of documents added
  since the last merge, plus a **tombstone set** of main-segment docids
  deleted since. Queries merge main − tombstones ∪ delta at run time.
* **WAL** — every add/delete is appended (and fsynced) to a checksummed
  write-ahead log *before* it is applied in memory or acknowledged
  (:mod:`repro.index.wal`), so a crash at any instant replays to exactly
  the acknowledged state.

**Merge** drains the delta through ``build_index(format="auto")`` into a
fresh segment and commits it with the atomic tmp+fsync+rename protocol
(:mod:`repro.robustness.atomic_io`); the manifest replace is the single
commit point. The sequence is instrumented with named **crash points**
(:data:`CRASH_POINTS`) — the recovery fuzz suite injects a crash at every
one and proves the reopened index answers queries bit-identically to a
rebuilt-from-scratch index. Writes stay live during a merge: the delta is
rotated (frozen) together with the WAL, new ops land in the new WAL +
active delta, and the commit swaps epochs without ever blocking queries —
in-flight readers keep a refcounted :class:`Snapshot` of the old epoch.

Scoring note: query-time BM25 impacts are recomputed from the *merged*
document frequency via :func:`~repro.index.builder.impact_value` and the
raw per-posting tfs persisted next to each segment — never read from the
segment's encoded impact stream, whose quantization was fixed at the df
the term had at merge time. That is what makes a LiveIndex top-k
bit-identical to ``query.topk`` on an index rebuilt from the current
logical state, which is the oracle the fuzz suite checks against.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from dataclasses import dataclass, replace as dc_replace

import numpy as np

from repro.obs import (counter_inc as _obs_counter_inc,
                       histogram_observe as _obs_histogram_observe,
                       installed as _obs_installed)

from repro.core.compressed_array import CompressedIntArray, FORMAT_LEAVES
from repro.robustness.atomic_io import (
    TMP_PREFIX, atomic_write_json, clean_tmp, crc32_file, fsync_dir,
    fsync_file)
from repro.robustness.validate import SegmentError, WalError

from .builder import (InvertedIndex, TermPostings, build_index,
                      impact_value, quantize_impacts)
from .query import QueryStats, _decode_blocks
from .wal import open_wal, parse_wal_name, read_wal, wal_path

MANIFEST_NAME = "MANIFEST.json"
SEGMENTS_DIR = "segments"

# Named crash points of the merge/commit sequence, in order. The fuzz
# suite injects a crash at every one (tests/test_ingest.py); the recovery
# contract per point is tabulated in docs/ingestion.md §Crash points.
CRASH_POINTS = (
    "before_rotate",         # nothing rotated yet
    "after_rotate",          # new WAL exists, delta frozen
    "after_build",           # merged index built in memory only
    "segment_tmp_written",   # segment bytes durable under a tmp name
    "after_segment_rename",  # segment final-named; manifest still old
    "manifest_tmp_written",  # new manifest durable under a tmp name
    "after_manifest",        # COMMIT POINT passed; cleanup not run
    "after_cleanup",         # old WALs/segments removed
)


class CrashPoint(RuntimeError):
    """Injected crash (tests/benchmarks only). The raising ``LiveIndex``
    must be discarded — like a real crash, recovery happens by reopening
    the directory."""

    def __init__(self, name: str):
        self.name = name
        super().__init__(f"injected crash at {name!r}")


def _seg_name(epoch: int) -> str:
    return f"seg_{epoch:08d}"


def _parse_seg_name(name: str) -> int | None:
    if not name.startswith("seg_"):
        return None
    mid = name[4:]
    return int(mid) if mid.isdigit() else None


# ---------------------------------------------------------------------------
# segment persistence
# ---------------------------------------------------------------------------
def _segment_arrays(index: InvertedIndex, tfs: dict) -> dict:
    """Flatten an index (+ raw per-posting tfs) into npz-ready arrays."""
    arrays: dict[str, np.ndarray] = {}
    all_docs: list[np.ndarray] = []
    for t, tp in index.terms.items():
        pre = f"t{t}"
        for leaf in FORMAT_LEAVES[tp.arr.format]:
            arrays[f"{pre}_arr_{leaf}"] = np.asarray(getattr(tp.arr, leaf))
        if tp.arr.checksums is not None:
            arrays[f"{pre}_arr_cs"] = np.asarray(tp.arr.checksums)
        for leaf in FORMAT_LEAVES[tp.impacts.format]:
            arrays[f"{pre}_imp_{leaf}"] = np.asarray(
                getattr(tp.impacts, leaf))
        if tp.impacts.checksums is not None:
            arrays[f"{pre}_imp_cs"] = np.asarray(tp.impacts.checksums)
        arrays[f"{pre}_first"] = tp.first_doc
        arrays[f"{pre}_last"] = tp.last_doc
        arrays[f"{pre}_maxi"] = tp.max_impact
        # raw tfs, NOT the quantized impacts: the live index re-quantizes
        # at query time against the merged df (module docstring)
        arrays[f"{pre}_tf"] = np.asarray(tfs[t], dtype=np.uint32)
    return arrays


def _write_segment_files(seg_dir: str, index: InvertedIndex, tfs: dict,
                         main_docs: np.ndarray, *, epoch: int,
                         merged_wal: int, fsync: bool) -> None:
    """Write ``postings.npz`` + ``segment.json`` into ``seg_dir`` (already
    created, typically a tmp dir awaiting rename)."""
    arrays = _segment_arrays(index, tfs)
    arrays["all_docs"] = np.asarray(main_docs, dtype=np.uint32)
    npz = os.path.join(seg_dir, "postings.npz")
    np.savez(npz, **arrays)
    meta = {
        "version": 1,
        "epoch": int(epoch),
        "merged_wal": int(merged_wal),
        "npz_crc32": crc32_file(npz),
        "n_docs": int(index.n_docs),
        "block_size": int(index.block_size),
        "impact_bits": int(index.impact_bits),
        "format": index.format,
        "has_tf": bool(index.has_tf),
        "n_postings": int(index.n_postings),
        "terms": {str(t): {"format": tp.arr.format,
                           "imp_format": tp.impacts.format,
                           "n": int(tp.arr.n), "df": int(tp.df)}
                  for t, tp in index.terms.items()},
    }
    with open(os.path.join(seg_dir, "segment.json"), "w") as f:
        json.dump(meta, f, indent=1)
    if fsync:
        fsync_file(npz)
        fsync_file(os.path.join(seg_dir, "segment.json"))
        fsync_dir(seg_dir)


def read_segment_meta(seg_dir: str) -> dict:
    """Parse + CRC-verify a segment dir's metadata (raises SegmentError)."""
    try:
        with open(os.path.join(seg_dir, "segment.json")) as f:
            meta = json.load(f)
    except (OSError, ValueError) as e:
        raise SegmentError(
            f"segment {seg_dir!r}: metadata unreadable ({e})") from e
    npz = os.path.join(seg_dir, "postings.npz")
    try:
        crc = crc32_file(npz)
    except OSError as e:
        raise SegmentError(
            f"segment {seg_dir!r}: postings.npz missing ({e})") from e
    if crc != meta.get("npz_crc32"):
        raise SegmentError(
            f"segment {seg_dir!r}: postings.npz CRC "
            f"{crc:#010x} != manifest {meta.get('npz_crc32'):#010x} — "
            "truncated or corrupt")
    return meta


def load_segment(seg_dir: str):
    """Load a segment: ``(InvertedIndex, tfs {term: int64[]}, all_docs)``.

    Every failure mode — unreadable/garbage json, missing/truncated/
    bit-flipped npz (whole-file CRC), missing term keys — raises a typed
    :class:`SegmentError`; a segment never loads partially.
    """
    meta = read_segment_meta(seg_dir)
    try:
        data = np.load(os.path.join(seg_dir, "postings.npz"))
    except Exception as e:  # zipfile.BadZipFile / OSError / ValueError
        raise SegmentError(
            f"segment {seg_dir!r}: postings.npz unreadable ({e})") from e
    index = InvertedIndex(terms={}, n_docs=int(meta["n_docs"]),
                          block_size=int(meta["block_size"]),
                          format=meta["format"],
                          impact_bits=int(meta["impact_bits"]),
                          has_tf=bool(meta["has_tf"]))
    tfs: dict[int, np.ndarray] = {}
    try:
        for ts, tm in meta["terms"].items():
            t = int(ts)
            pre = f"t{t}"
            bs = index.block_size
            arr = CompressedIntArray(
                format=tm["format"], block_size=bs, differential=True,
                n=int(tm["n"]),
                **{leaf: data[f"{pre}_arr_{leaf}"]
                   for leaf in FORMAT_LEAVES[tm["format"]]})
            if f"{pre}_arr_cs" in data:
                arr = dc_replace(arr, checksums=data[f"{pre}_arr_cs"])
            imp = CompressedIntArray(
                format=tm["imp_format"], block_size=bs, differential=False,
                n=int(tm["n"]),
                **{leaf: data[f"{pre}_imp_{leaf}"]
                   for leaf in FORMAT_LEAVES[tm["imp_format"]]})
            if f"{pre}_imp_cs" in data:
                imp = dc_replace(imp, checksums=data[f"{pre}_imp_cs"])
            index.terms[t] = TermPostings(
                term=t, arr=arr, first_doc=data[f"{pre}_first"],
                last_doc=data[f"{pre}_last"], df=int(tm["df"]),
                impacts=imp, max_impact=data[f"{pre}_maxi"])
            tfs[t] = data[f"{pre}_tf"].astype(np.int64)
        all_docs = data["all_docs"].astype(np.int64)
    except KeyError as e:
        raise SegmentError(
            f"segment {seg_dir!r}: npz missing array {e} — metadata and "
            "payload disagree") from e
    return index, tfs, all_docs


# ---------------------------------------------------------------------------
# snapshot (consistent read view)
# ---------------------------------------------------------------------------
@dataclass
class Snapshot:
    """A consistent read view of one epoch, refcounted by the owner.

    Cheap to take: dicts are pointer-copied (add/delete never mutate an
    inner per-doc term map in place — they replace whole entries), and
    the main index/tfs are immutable. Release via ``LiveIndex.release``
    (or use ``search(...)`` which scopes one internally) so the owner's
    epoch accounting sees readers drain after a swap.
    """

    epoch: int
    state: str
    main: InvertedIndex
    main_tfs: dict
    tombstones: np.ndarray  # sorted int64, against main
    delta_docs: dict  # active delta: doc -> {term: tf}
    frozen_docs: dict  # frozen delta (mid-merge), doc -> {term: tf}
    frozen_tomb: frozenset  # tombstones against frozen docs (mid-merge)

    def delta_term(self, term: int):
        """Sorted ``(docs int64, tfs int64)`` of the delta layers' postings
        for ``term`` (frozen − frozen tombstones, plus active)."""
        rows = [(d, tmap[term]) for d, tmap in self.frozen_docs.items()
                if term in tmap and d not in self.frozen_tomb]
        rows += [(d, tmap[term]) for d, tmap in self.delta_docs.items()
                 if term in tmap]
        rows.sort()
        if not rows:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        a = np.asarray(rows, dtype=np.int64)
        return a[:, 0], a[:, 1]

    def delta_doc_ids(self) -> np.ndarray:
        """Sorted docids served from the delta layers (delta-hit set)."""
        ids = set(self.delta_docs)
        ids.update(d for d in self.frozen_docs if d not in self.frozen_tomb)
        return np.fromiter(sorted(ids), dtype=np.int64, count=len(ids))


# ---------------------------------------------------------------------------
# the live index
# ---------------------------------------------------------------------------
class LiveIndex:
    """Mutable inverted index over ``directory`` (see module docstring).

    Opening the directory *is* recovery: clean orphan tmps, reconcile the
    manifest with whatever segments/WALs a crash left behind (adopting a
    committed-but-uncleaned segment — roll-forward — when its WALs are
    already gone), load + CRC-verify the main segment, then replay the
    unmerged WAL suffix into the delta (``state == "replaying"`` until
    done). Every add/delete is WAL-appended and fsynced before it is
    acknowledged.
    """

    def __init__(self, directory: str, *, n_docs: int | None = None,
                 block_size: int | None = None, format: str | None = None,
                 impact_bits: int | None = None, checksum: bool | None = None,
                 fsync: bool = True, plan="auto", replay_hook=None):
        self.dir = os.path.abspath(directory)
        self.plan = plan
        self.fsync = fsync
        self.state = "replaying"
        self._lock = threading.Lock()
        # Writer lock, ordered strictly before self._lock. Held across one
        # mutation's exists-check + WAL append + in-memory apply, and by
        # merge()'s rotate and commit sections — so an op's WAL record and
        # its delta placement always land on the same side of a rotation,
        # and two adds of the same doc serialize (second one rejected).
        self._wlock = threading.Lock()
        self._refs: dict[int, int] = {}
        self._delta: dict[int, dict[int, int]] = {}
        self._tombstones: set[int] = set()  # against the main segment
        self._frozen: dict[int, dict[int, int]] | None = None
        self._frozen_tomb: set[int] = set()  # against frozen docs, mid-merge
        self.counters = {"acked_ops": 0, "replayed_ops": 0, "merges": 0,
                         "rolled_forward": 0, "wal_bytes_truncated": 0}
        os.makedirs(os.path.join(self.dir, SEGMENTS_DIR), exist_ok=True)
        self._recover(n_docs=n_docs, block_size=block_size, format=format,
                      impact_bits=impact_bits, checksum=checksum,
                      replay_hook=replay_hook)
        with self._lock:
            self.state = "serving"

    # -- recovery ----------------------------------------------------------
    def _manifest_path(self) -> str:
        return os.path.join(self.dir, MANIFEST_NAME)

    def _seg_dir(self, name: str) -> str:
        return os.path.join(self.dir, SEGMENTS_DIR, name)

    def _write_manifest(self, man: dict) -> None:
        atomic_write_json(self._manifest_path(), man, fsync=self.fsync)

    def _recover(self, *, n_docs, block_size, format, impact_bits,
                 checksum, replay_hook):
        seg_parent = os.path.join(self.dir, SEGMENTS_DIR)
        clean_tmp(self.dir)
        clean_tmp(seg_parent)

        man = None
        if os.path.exists(self._manifest_path()):
            try:
                with open(self._manifest_path()) as f:
                    man = json.load(f)
            except (OSError, ValueError) as e:
                # the manifest is the commit point: if it is garbage we
                # cannot know which epoch was acknowledged — detect.
                raise SegmentError(f"manifest unreadable ({e})") from e

        present = {e: nm for nm in os.listdir(seg_parent)
                   if (e := _parse_seg_name(nm)) is not None}
        wal_ids = sorted(i for nm in os.listdir(self.dir)
                         if (i := parse_wal_name(nm)) is not None)
        man_epoch = int(man["epoch"]) if man else 0
        man_merged = int(man["merged_wal"]) if man else 0

        orphans = sorted(e for e in present if e > man_epoch)
        if orphans:
            # a segment newer than the manifest: either an uncommitted
            # merge (crash before the manifest replace — its WALs replay
            # it, discard) or a committed merge whose manifest write we
            # can no longer see (stale/rolled-back manifest) with the
            # drained WALs already cleaned — adopt it (roll forward).
            e = max(orphans)
            try:
                ometa = read_segment_meta(self._seg_dir(present[e]))
            except SegmentError:
                ometa = None
            covered = int(ometa["merged_wal"]) if ometa else None
            needed = (set(range(man_merged + 1, covered + 1))
                      if covered is not None else set())
            wals_ok = covered is not None and needed <= set(wal_ids)
            # even with the orphan's own metadata unreadable, a WAL chain
            # that is contiguous from the manifest's watermark reproduces
            # every acknowledged op — a committed merge never changes the
            # logical state, so replaying past it is harmless
            full_history = bool(wal_ids) and wal_ids[0] == man_merged + 1 \
                and wal_ids == list(range(wal_ids[0], wal_ids[-1] + 1))
            if wals_ok or (ometa is None and full_history):
                # WAL history fully reproduces the orphan: plain replay
                for eo in orphans:
                    shutil.rmtree(self._seg_dir(present.pop(eo)))
            elif ometa is not None:
                man = {"version": 1, "epoch": e, "segments": [present[e]],
                       "merged_wal": covered, "n_docs": ometa["n_docs"],
                       "block_size": ometa["block_size"],
                       "format": ometa["format"],
                       "impact_bits": ometa["impact_bits"],
                       "checksum": (man["checksum"] if man
                                    else checksum is None or bool(checksum))}
                self._write_manifest(man)
                self.counters["rolled_forward"] = 1
                man_epoch, man_merged = e, covered
                for eo in orphans[:-1]:
                    shutil.rmtree(self._seg_dir(present.pop(eo)))
            else:
                raise SegmentError(
                    f"segment epoch {e} is newer than the manifest "
                    f"(epoch {man_epoch}) but corrupt, and the WALs that "
                    "produced it are gone — history unrecoverable")

        if man is None:
            if n_docs is None:
                raise ValueError(
                    "creating a new LiveIndex requires n_docs (the fixed "
                    "docid universe — impacts depend on it)")
            man = {"version": 1, "epoch": 0, "segments": [],
                   "merged_wal": 0, "n_docs": int(n_docs),
                   "block_size": 128 if block_size is None else int(block_size),
                   "format": "auto" if format is None else format,
                   "impact_bits": (8 if impact_bits is None
                                   else int(impact_bits)),
                   "checksum": checksum is None or bool(checksum)}
            self._write_manifest(man)
        else:
            # a recovered manifest is authoritative for the index geometry;
            # an explicit constructor argument that disagrees is a caller
            # bug (a different n_docs is a different docid universe) —
            # never silently reopen with parameters other than requested
            given = {"n_docs": n_docs, "block_size": block_size,
                     "format": format, "impact_bits": impact_bits,
                     "checksum": checksum}
            norm = {"checksum": bool, "format": str}
            clash = {k: (v, man[k]) for k, v in given.items()
                     if v is not None
                     and norm.get(k, int)(v) != norm.get(k, int)(man[k])}
            if clash:
                raise ValueError(
                    "constructor arguments conflict with the recovered "
                    "manifest: " + ", ".join(
                        f"{k}={v!r} != manifest {m!r}"
                        for k, (v, m) in sorted(clash.items())))

        self.manifest = man
        self.epoch = int(man["epoch"])
        self.n_docs = int(man["n_docs"])
        self.block_size = int(man["block_size"])
        self.format = man["format"]
        self.impact_bits = int(man["impact_bits"])
        self.checksum = bool(man["checksum"])
        merged_wal = int(man["merged_wal"])

        for nm in man["segments"]:
            if _parse_seg_name(nm) not in present:
                raise SegmentError(
                    f"manifest names segment {nm!r} which does not exist")
        listed = {_parse_seg_name(nm) for nm in man["segments"]}
        for e, nm in list(present.items()):
            if e not in listed:  # cleanup crashed mid-way: finish it
                shutil.rmtree(self._seg_dir(nm))

        if man["segments"]:
            self.main, self.main_tfs, self._main_docs = load_segment(
                self._seg_dir(man["segments"][0]))
            if self.main.n_docs != self.n_docs:
                raise SegmentError(
                    f"segment n_docs {self.main.n_docs} != manifest "
                    f"{self.n_docs}")
        else:
            self.main = build_index({}, format=self.format,
                                    block_size=self.block_size,
                                    n_docs=self.n_docs,
                                    impact_bits=self.impact_bits)
            self.main_tfs = {}
            self._main_docs = np.zeros(0, np.int64)

        # stale WALs (≤ merged watermark) are already baked into the
        # segment — a crash during post-commit cleanup leaves them behind
        for i in [i for i in wal_ids if i <= merged_wal]:
            os.remove(wal_path(self.dir, i))
        wal_ids = [i for i in wal_ids if i > merged_wal]
        if wal_ids and wal_ids != list(range(wal_ids[0], wal_ids[-1] + 1)):
            raise WalError(f"WAL sequence has gaps: {wal_ids}", format="wal")
        if wal_ids and wal_ids[0] != merged_wal + 1:
            raise WalError(
                f"oldest unmerged WAL is {wal_ids[0]}, expected "
                f"{merged_wal + 1} — history lost", format="wal")

        replayed: list[dict] = []
        for i in wal_ids[:-1] if wal_ids else []:
            p = wal_path(self.dir, i)
            ops, valid = read_wal(p)
            if valid != os.path.getsize(p):
                # only the *newest* WAL can have a torn tail: this one was
                # rotated away, meaning every record in it was acked
                raise WalError(
                    f"rotated WAL {i} has a torn tail — acknowledged "
                    "records lost", format="wal")
            replayed.extend(ops)
        active_id = wal_ids[-1] if wal_ids else merged_wal + 1
        before = (os.path.getsize(wal_path(self.dir, active_id))
                  if os.path.exists(wal_path(self.dir, active_id)) else 0)
        tail_ops, self.wal = open_wal(wal_path(self.dir, active_id),
                                      fsync=self.fsync)
        self.counters["wal_bytes_truncated"] = before - self.wal.tell()
        replayed.extend(tail_ops)
        self.wal_id = active_id

        for i, op in enumerate(replayed):
            self._apply(op, replay=True)
            if replay_hook is not None:
                # hook gets the half-open index: queries already work
                # (state == "replaying" marks them degraded)
                replay_hook(self, i, op)
        self.counters["replayed_ops"] = len(replayed)
        tele = _obs_installed()
        if tele is not None:
            # one structured crash-recovery record per reopen: what the WAL
            # replay found is capacity/incident data, not a counter
            tele.registry.record_event(
                "ingest_recovery", epoch=self.epoch,
                replayed_ops=len(replayed),
                rolled_forward=self.counters["rolled_forward"],
                wal_bytes_truncated=self.counters["wal_bytes_truncated"])
            reg = tele.registry
            reg.counter("ingest_replayed_ops_total").inc(len(replayed))
            if self.counters["rolled_forward"]:
                reg.counter("ingest_rolled_forward_total").inc()

    # -- membership --------------------------------------------------------
    def _in_main(self, doc: int) -> bool:
        i = int(np.searchsorted(self._main_docs, doc))
        return i < self._main_docs.size and int(self._main_docs[i]) == doc

    def _exists(self, doc: int) -> bool:
        if doc in self._delta:
            return True
        if self._frozen is not None and doc in self._frozen \
                and doc not in self._frozen_tomb:
            return True
        return doc not in self._tombstones and self._in_main(doc)

    def __contains__(self, doc: int) -> bool:
        return self._exists(int(doc))

    @property
    def n_delta_docs(self) -> int:
        return len(self._delta) + (len(self._frozen) if self._frozen else 0)

    @property
    def n_pending(self) -> int:
        """Ops not yet drained into a segment (delta docs + tombstones)."""
        return self.n_delta_docs + len(self._tombstones) \
            + len(self._frozen_tomb)

    def doc_count(self) -> int:
        n = int(self._main_docs.size) - len(self._tombstones) + \
            len(self._delta)
        if self._frozen is not None:
            n += len(self._frozen) - len(self._frozen_tomb)
        return n

    # -- mutation (WAL-append before ack) ----------------------------------
    def add(self, doc: int, terms) -> None:
        """Add document ``doc`` with ``{term: tf}`` postings. Durable (WAL
        appended + fsynced) before this returns. The doc must not
        currently exist — delete first to replace."""
        doc = int(doc)
        if not (0 <= doc < self.n_docs):
            raise ValueError(f"doc {doc} outside universe [0, {self.n_docs})")
        tmap = {int(t): int(tf) for t, tf in dict(terms).items()}
        if not tmap:
            raise ValueError("a document needs ≥1 term")
        for t, tf in tmap.items():
            if t < 0 or tf < 1:
                raise ValueError(f"bad posting term={t} tf={tf}")
        op = {"op": "add", "doc": doc,
              "terms": {str(t): tf for t, tf in sorted(tmap.items())}}
        # one critical section per mutation: the exists-check, the WAL
        # append and the delta placement must all see the same WAL/delta
        # generation, or a concurrent rotation could strand the op's only
        # durable record in a WAL the merge is about to retire
        with self._wlock:
            if self._exists(doc):
                raise ValueError(
                    f"doc {doc} already exists — delete it first")
            self.wal.append(op)  # durability point: ack only after this
            self._apply(op, replay=False)
            self.counters["acked_ops"] += 1

    def delete(self, doc: int) -> None:
        """Delete document ``doc``. Durable before this returns."""
        doc = int(doc)
        op = {"op": "del", "doc": doc}
        with self._wlock:
            if not self._exists(doc):
                raise KeyError(f"doc {doc} does not exist")
            self.wal.append(op)
            self._apply(op, replay=False)
            self.counters["acked_ops"] += 1

    def _apply(self, op: dict, *, replay: bool) -> None:
        """Apply one (already durable) op to the in-memory delta state.
        Replay uses the same code path as live application — that is the
        identity the crash oracle depends on. A replayed op that
        contradicts the index means the log and segments diverged:
        typed ``WalError``, never a silent wrong answer."""
        doc = int(op["doc"])
        if op["op"] == "add":
            tmap = {int(t): int(tf) for t, tf in op["terms"].items()}
            if replay and (self._exists(doc) or not tmap):
                raise WalError(
                    f"replayed add of existing doc {doc} — WAL/segment "
                    "divergence", format="wal")
            with self._lock:
                self._delta[doc] = tmap
        else:
            with self._lock:
                if doc in self._delta:
                    del self._delta[doc]
                elif self._frozen is not None and doc in self._frozen \
                        and doc not in self._frozen_tomb:
                    self._frozen_tomb.add(doc)
                elif doc not in self._tombstones and self._in_main(doc):
                    self._tombstones.add(doc)
                else:
                    raise WalError(
                        f"replayed delete of absent doc {doc} — "
                        "WAL/segment divergence", format="wal")

    # -- snapshots ---------------------------------------------------------
    def snapshot(self) -> Snapshot:
        """Refcounted consistent read view (release when done)."""
        with self._lock:
            tomb = np.fromiter(sorted(self._tombstones), dtype=np.int64,
                               count=len(self._tombstones))
            snap = Snapshot(
                epoch=self.epoch, state=self.state, main=self.main,
                main_tfs=self.main_tfs, tombstones=tomb,
                delta_docs=dict(self._delta),
                frozen_docs=dict(self._frozen) if self._frozen else {},
                frozen_tomb=frozenset(self._frozen_tomb))
            self._refs[snap.epoch] = self._refs.get(snap.epoch, 0) + 1
        return snap

    def release(self, snap: Snapshot) -> None:
        with self._lock:
            self._refs[snap.epoch] -= 1
            if self._refs[snap.epoch] == 0:
                del self._refs[snap.epoch]

    def readers(self) -> dict[int, int]:
        """Epoch → live reader count (old epochs drain after a swap)."""
        with self._lock:
            return dict(self._refs)

    # -- queries -----------------------------------------------------------
    def _term_merged(self, snap: Snapshot, term: int, stats):
        """One term's logical postings under ``snap``: sorted
        ``(docs int64, tfs int64, delta_docs int64)`` merging the decoded
        main blocks (− tombstones) with the delta layers."""
        tp = snap.main.terms.get(term)
        if tp is not None and tp.df:
            docs_m = _decode_blocks(tp, 0, tp.n_blocks, plan=self.plan,
                                    stats=stats,
                                    use_skip=True).astype(np.int64)
            tfs_m = snap.main_tfs[term]
            if snap.tombstones.size:
                pos = np.searchsorted(snap.tombstones, docs_m)
                pos = np.minimum(pos, snap.tombstones.size - 1)
                dead = snap.tombstones[pos] == docs_m
                if stats is not None:
                    stats.tombstones_applied += int(dead.sum())
                docs_m, tfs_m = docs_m[~dead], tfs_m[~dead]
        else:
            docs_m = np.zeros(0, np.int64)
            tfs_m = np.zeros(0, np.int64)
        d_docs, d_tfs = snap.delta_term(term)
        if stats is not None:
            stats.delta_postings += int(d_docs.size)
        if d_docs.size:
            docs = np.concatenate([docs_m, d_docs])
            tfs = np.concatenate([tfs_m, d_tfs])
            order = np.argsort(docs, kind="stable")
            docs, tfs = docs[order], tfs[order]
        else:
            docs, tfs = docs_m, tfs_m
        return docs, tfs, d_docs

    def search(self, terms, *, mode: str = "or", k: int = 10,
               stats: QueryStats | None = None, snap: Snapshot | None = None):
        """Query the live logical state: ``mode`` "and"/"or" return sorted
        uint32 docids; "topk" returns ``(docids uint32 [≤k], scores int32
        [≤k])`` — each bit-identical to ``repro.index.query`` on an index
        rebuilt from scratch from the same logical state (the fuzz
        oracle's definition of correct)."""
        own = snap is None
        if own:
            snap = self.snapshot()
        try:
            if stats is not None and snap.state == "replaying":
                stats.mark_degraded("replaying")
            terms = list(dict.fromkeys(terms))
            if not terms:
                raise ValueError("query needs ≥1 term")
            merged = [self._term_merged(snap, t, stats) for t in terms]
            if mode == "and":
                live = [docs for docs, _, _ in merged]
                if any(d.size == 0 for d in live):
                    out = np.zeros(0, np.int64)
                else:
                    out = live[0]
                    for d in live[1:]:
                        out = np.intersect1d(out, d, assume_unique=True)
                self._count_delta_hits(snap, out, merged, stats)
                return out.astype(np.uint32)
            if mode == "or":
                parts = [docs for docs, _, _ in merged if docs.size]
                out = (np.unique(np.concatenate(parts)) if parts
                       else np.zeros(0, np.int64))
                self._count_delta_hits(snap, out, merged, stats)
                return out.astype(np.uint32)
            if mode == "topk":
                parts = [docs for docs, _, _ in merged if docs.size]
                cand = (np.unique(np.concatenate(parts)) if parts
                        else np.zeros(0, np.int64))
                scores = np.zeros(cand.size, np.int64)
                for t, (docs, tfs, _d) in zip(terms, merged):
                    if docs.size == 0:
                        continue
                    base = impact_value(self.n_docs, int(docs.size),
                                        self.impact_bits)
                    q = quantize_impacts(base, tfs, self.impact_bits)
                    scores[np.searchsorted(cand, docs)] += q
                order = np.lexsort((cand, -scores))[:int(k)]
                top = cand[order]
                self._count_delta_hits(snap, top, merged, stats)
                return (top.astype(np.uint32),
                        scores[order].astype(np.int32))
            raise ValueError(f"unknown mode {mode!r}; expected "
                             "'and'/'or'/'topk'")
        finally:
            if own:
                self.release(snap)

    @staticmethod
    def _count_delta_hits(snap, result, merged, stats):
        if stats is None or len(result) == 0:
            return
        dd = np.unique(np.concatenate(
            [d for _, _, d in merged if d.size] or [np.zeros(0, np.int64)]))
        if dd.size:
            stats.delta_hits += int(
                np.isin(np.asarray(result, dtype=np.int64), dd).sum())

    # -- materialization (merge drain + test oracle) -----------------------
    def _merged_lists(self, *, frozen: dict, tombstones: set,
                      frozen_tomb: set = frozenset(),
                      extra: dict | None = None):
        """Term-major logical postings: main (− ``tombstones``) merged with
        ``frozen`` (− ``frozen_tomb``) and ``extra``. Returns
        ``(lists {term: int64 docs}, tfs {term: int64})`` with empty terms
        omitted — exactly what ``build_index`` (or the rebuild oracle)
        consumes."""
        extra = extra or {}
        delta_terms: dict[int, list] = {}
        for src, tomb in ((frozen, frozen_tomb), (extra, frozenset())):
            for d, tmap in src.items():
                if d in tomb:
                    continue
                for t, tf in tmap.items():
                    delta_terms.setdefault(t, []).append((d, tf))
        tomb_arr = np.fromiter(sorted(tombstones), dtype=np.int64,
                               count=len(tombstones))
        lists: dict[int, np.ndarray] = {}
        tfs: dict[int, np.ndarray] = {}
        for t in sorted(set(self.main.terms) | set(delta_terms)):
            tp = self.main.terms.get(t)
            if tp is not None and tp.df:
                docs_m = tp.arr.decode(plan=self.plan).astype(np.int64)
                tfs_m = self.main_tfs[t]
                if tomb_arr.size:
                    pos = np.minimum(np.searchsorted(tomb_arr, docs_m),
                                     tomb_arr.size - 1)
                    keep = tomb_arr[pos] != docs_m
                    docs_m, tfs_m = docs_m[keep], tfs_m[keep]
            else:
                docs_m = np.zeros(0, np.int64)
                tfs_m = np.zeros(0, np.int64)
            rows = sorted(delta_terms.get(t, []))
            if rows:
                a = np.asarray(rows, dtype=np.int64)
                docs = np.concatenate([docs_m, a[:, 0]])
                tfv = np.concatenate([tfs_m, a[:, 1]])
                order = np.argsort(docs, kind="stable")
                docs, tfv = docs[order], tfv[order]
            else:
                docs, tfv = docs_m, tfs_m
            if docs.size:
                lists[t] = docs
                tfs[t] = tfv
        return lists, tfs

    def materialize(self):
        """Current *logical* state as ``(lists, tfs)`` — what a rebuilt-
        from-scratch index would be built from (the fuzz oracle)."""
        with self._lock:
            frozen = dict(self._frozen) if self._frozen else {}
            ftomb = set(self._frozen_tomb)
            tomb = set(self._tombstones)
            extra = dict(self._delta)
        return self._merged_lists(frozen=frozen, tombstones=tomb,
                                  frozen_tomb=ftomb, extra=extra)

    # -- merge (the 8-crash-point sequence) --------------------------------
    def merge(self, *, crash_at: str | None = None, step_hook=None) -> dict:
        """Drain the delta into a fresh compressed segment and commit it.

        Writes stay live throughout (they land in the rotated WAL + active
        delta) and queries never block — the manifest replace is the
        single commit point, after which the in-memory epoch swaps.
        ``crash_at`` raises :class:`CrashPoint` at the named point
        (tests); ``step_hook(name)`` runs at every point (mid-merge query
        parity checks). See :data:`CRASH_POINTS`.
        """
        if crash_at is not None and crash_at not in CRASH_POINTS:
            raise ValueError(f"unknown crash point {crash_at!r}")
        with self._lock:
            if self.state == "merge_in_progress":
                raise RuntimeError("merge already in progress")
            self.state = "merge_in_progress"
        t_merge0 = time.perf_counter()

        # merge-phase duration histograms: the crash points already name
        # the phase boundaries, so each point() observes the time since the
        # previous one under the phase that just finished
        _phase = {"t0": time.perf_counter(), "prev": "merge_start"}

        def point(name: str) -> None:
            now = time.perf_counter()
            _obs_histogram_observe("ingest_merge_phase_seconds",
                                   now - _phase["t0"], phase=name)
            _phase["t0"], _phase["prev"] = now, name
            if step_hook is not None:
                step_hook(name)
            if crash_at == name:
                raise CrashPoint(name)

        rotated = committed = False
        try:
            point("before_rotate")
            old_wal_id = self.wal_id
            new_id = old_wal_id + 1
            _, new_writer = open_wal(wal_path(self.dir, new_id),
                                     fsync=self.fsync)
            with self._wlock:  # no writer mid-append while the WAL swaps
                with self._lock:
                    self.wal.close()
                    self.wal, self.wal_id = new_writer, new_id
                    self._frozen = self._delta
                    self._delta = {}
                    rot_tomb = set(self._tombstones)
                    self._frozen_tomb = set()
            rotated = True
            point("after_rotate")

            frozen = self._frozen
            lists, tfs = self._merged_lists(frozen=frozen,
                                            tombstones=rot_tomb)
            new_index = build_index(
                lists, tfs=tfs, format=self.format,
                block_size=self.block_size, n_docs=self.n_docs,
                impact_bits=self.impact_bits, checksum=self.checksum)
            all_docs = np.unique(np.concatenate(
                list(lists.values()) or [np.zeros(0, np.int64)]))
            point("after_build")

            new_epoch = self.epoch + 1
            seg_nm = _seg_name(new_epoch)
            seg_parent = os.path.join(self.dir, SEGMENTS_DIR)
            seg_final = self._seg_dir(seg_nm)
            tmp = os.path.join(
                seg_parent, f"{TMP_PREFIX}{seg_nm}_{os.getpid()}")
            os.makedirs(tmp)
            _write_segment_files(tmp, new_index, tfs, all_docs,
                                 epoch=new_epoch, merged_wal=old_wal_id,
                                 fsync=self.fsync)
            point("segment_tmp_written")
            os.rename(tmp, seg_final)
            if self.fsync:
                fsync_dir(seg_parent)
            point("after_segment_rename")

            man = dict(self.manifest)
            man.update(epoch=new_epoch, segments=[seg_nm],
                       merged_wal=old_wal_id)
            mtmp = os.path.join(
                self.dir, f"{TMP_PREFIX}{MANIFEST_NAME}_{os.getpid()}")
            with open(mtmp, "w") as f:
                json.dump(man, f, indent=1)
                f.flush()
                if self.fsync:
                    os.fsync(f.fileno())
            point("manifest_tmp_written")
            os.replace(mtmp, self._manifest_path())  # THE commit point
            committed = True
            if self.fsync:
                fsync_dir(self.dir)
            point("after_manifest")

            tfs_np = {t: np.asarray(v, dtype=np.int64)
                      for t, v in tfs.items()}
            with self._wlock:  # writers' _exists must see main+tombstones
                with self._lock:  # swap atomically with the epoch
                    self.main = new_index
                    self.main_tfs = tfs_np
                    self._main_docs = all_docs.astype(np.int64)
                    self.manifest = man
                    self.epoch = new_epoch
                    # tombstones drained into the segment retire; deletes
                    # that raced the merge (incl. of frozen docs, now in
                    # main) stay
                    self._tombstones = (self._tombstones - rot_tomb) \
                        | self._frozen_tomb
                    self._frozen = None
                    self._frozen_tomb = set()

            for nm in os.listdir(self.dir):
                i = parse_wal_name(nm)
                if i is not None and i <= old_wal_id:
                    os.remove(os.path.join(self.dir, nm))
            for nm in os.listdir(seg_parent):
                e = _parse_seg_name(nm)
                if e is not None and e != new_epoch:
                    shutil.rmtree(self._seg_dir(nm))
            point("after_cleanup")
            self.counters["merges"] += 1
            _obs_counter_inc("ingest_merges_total")
            _obs_histogram_observe("ingest_merge_seconds",
                                   time.perf_counter() - t_merge0)
            with self._lock:
                self.state = "serving"
            return {"epoch": new_epoch, "drained_docs": len(frozen),
                    "drained_tombstones": len(rot_tomb),
                    "n_postings": int(new_index.n_postings),
                    "bits_per_int": (round(new_index.bits_per_int, 2)
                                     if new_index.n_postings else 0.0)}
        except CrashPoint:
            # injected crash: the object is dead by contract — recovery
            # reopens the directory. Leave state at merge_in_progress so
            # misuse of the carcass is loud.
            raise
        except BaseException:
            # a real pre-commit failure (build error, disk full, step_hook
            # raise) committed nothing: un-rotate so the in-memory state is
            # exactly what serving + a retried merge expect, and discard
            # the attempt's on-disk leftovers (tmp dirs, an uncommitted
            # final-named segment) so the retry's names are free — the
            # same sweep recovery performs on reopen. Post-commit the
            # epochs may have half-swapped — stay loud like a crash.
            if not committed:
                self._rollback_merge(rotated)
                seg_parent = os.path.join(self.dir, SEGMENTS_DIR)
                clean_tmp(self.dir)
                clean_tmp(seg_parent)
                for nm in os.listdir(seg_parent):
                    e = _parse_seg_name(nm)
                    if e is not None and e > self.epoch:
                        shutil.rmtree(self._seg_dir(nm), ignore_errors=True)
            raise

    def _rollback_merge(self, rotated: bool) -> None:
        """Restore ``serving`` after a merge failed before its commit
        point. Frozen delta docs fold back into the active delta (a
        concurrent add of a frozen doc was rejected, so no collisions) and
        frozen tombstones simply erase their docs — the logical state is
        untouched. The rotated WAL stays active: the op history is split
        across old + new WALs, both above the unchanged ``merged_wal``
        watermark, so recovery and the next rotation handle it as usual."""
        with self._wlock:
            with self._lock:
                if rotated and self._frozen is not None:
                    for d, tmap in self._frozen.items():
                        if d not in self._frozen_tomb:
                            self._delta[d] = tmap
                    self._frozen = None
                    self._frozen_tomb = set()
                self.state = "serving"

    def close(self) -> None:
        self.wal.close()
