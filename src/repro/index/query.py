"""Boolean and top-k queries over the compressed inverted index.

Every query is a decode→intersect→score pipeline over the kernel stack —
posting lists are never materialized as whole docid arrays unless they ARE
the answer (a union's output):

* **Conjunctive (AND)** — terms ordered by document frequency; the rarest
  term is the *driver* and only its blocks inside the terms' common docid
  window are decoded (``stream`` epilogue). Its docids become the probe
  set, processed in fixed-width chunks: for every other term, each probe
  binary-searches the skip table (``first_doc``/``last_doc``) and only
  the blocks whose docid range actually contains a probe are gathered —
  per chunk that is ≤ ``probe_width`` blocks out of the whole list, and
  every other block is **never decoded**. The ``membership`` epilogue
  decodes the gathered blocks and emits the chunk's match bitmap
  in-kernel — the larger list's docids live and die in VMEM. This is
  small-vs-large galloping intersection with the gallop done on the skip
  table and the per-tile comparison vectorized on the VPU.
* **Disjunctive (OR)** — the union is the output, so each term's live
  blocks are decoded once (no probes to prune against) and merged.
* **Top-k** — disjunctive top-k (the default) scores term-at-a-time: the
  union pass already decodes every term's docids, so each term's
  quantized impact scatters straight onto them (TAAT — no re-decode;
  per-posting impact streams decode alongside when the index carries
  tfs). Conjunctive top-k (``mode="and"``) probes each term's impact
  per candidate (constant-score shortcut when tf-free). Required-term
  top-k (``mode="driver"``) is the scored DAAT shape: candidates are
  ``terms[0]``'s postings, and each optional term's impact accumulates
  per candidate chunk through the fused ``bm25_accum``/``bm25_accum_rows``
  (or per-posting ``bm25_weighted``/``bm25_weighted_rows``) epilogues with
  the same skip-table pruning as AND.
* **MaxScore (``mode="maxscore"``)** — block-max dynamic-pruned
  disjunctive top-k, same results as ``mode="or"`` bit-exactly. Terms
  sort ascending by their score upper bound (``TermPostings.ub``, the
  largest per-block ``max_impact``); once the running top-k holds k
  results its k-th score is the threshold θ, and the maximal prefix of
  terms whose cumulative upper bound ≤ θ becomes **non-essential**: those
  lists are never strip-decoded, only probed for candidates that can
  still pass. The remaining **essential** terms advance DAAT in docid
  strips of ≤ ``probe_width`` postings per term; inside a strip, any
  block whose ``max_impact`` plus the other terms' upper bounds < θ is
  **pruned — never decoded** (its docs can't even tie an incumbent).
  Every bound comparison is *strict*: a candidate whose best case ties θ
  must still be scored, because the final (score desc, docid asc) order
  ranks it ahead of any incumbent tied at θ with a larger docid — the
  seed phase inserts incumbents at arbitrary docids, so tied candidates
  with smaller docids do occur. Candidates surviving the
  partial-score bound are probed against non-essential terms in
  descending-bound order, re-checking the bound after each term
  (``QueryStats.probes_pruned`` counts settlements without decode).

Impacts are exact int32, so fused / unfused / sharded / dense / banded
runs are bit-identical; ties break by ascending docid. ``plan=`` is
forwarded to the dispatch layer, so queries inherit the autotuned plan
cache, both Pallas/jnp paths, dense and banded cores — and, when a term's
``CompressedIntArray`` is block-sharded over a mesh (``use_skip=False``
resident-index mode, see ``launch.serve.SearchEngine``), the ``shard_map``
block-parallel path. :class:`QueryStats` counts decoded vs skipped vs
threshold-pruned blocks, which is how tests prove pruning never decodes
non-overlapping — or beaten — blocks.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import numpy as np

import jax.numpy as jnp

from repro.kernels.vbyte_decode import dispatch
from repro.kernels.vbyte_decode.ops import normalize_probe
from repro.obs import trace as _trace
from repro.robustness.validate import Deadline  # noqa: F401  (re-exported)

from .builder import InvertedIndex, TermPostings

# maximum probe-set width per membership/scoring pass. Chunks are sized
# min(pow2(candidates), this), so a rare driver probes each term in ONE
# dispatch; the cap bounds the [tile, B, P] comparison footprint (and the
# jitted shape count — pow2 widths only).
DEFAULT_PROBE_WIDTH = 512
# MaxScore strip ramp: ×8 per round, capped. One small first strip forms
# θ cheaply; after that per-dispatch overhead dwarfs per-block decode
# cost, so the horizon grows fast — a 4096-block list takes ~4 strips,
# not ~1000. The cap bounds one pull's decode shape (2048 blocks × 128
# ints = 256Ki ints).
STRIP_RAMP = 8
MAX_STRIP_BLOCKS = 2048
# MaxScore candidate-scoring crossover: at or below this many candidates
# a term is probed through the row-gathered weighted epilogues (O(B) per
# probe, probe set in VMEM); above it, bulk decode-and-merge — the probe
# epilogues pay per gathered row, so strip-sized candidate sets would
# cost more than decoding every hit block exactly once.
MERGE_MIN_PROBES = 32


@dataclass
class QueryStats:
    """Decode accounting for one query (pruning evidence).

    ``blocks_decoded + blocks_skipped`` equals the blocks *considered* by
    skip-table routing (per decode/probe pass); ``rows_gathered`` counts
    per-probe row gathers on top (a block gathered once per probe in it —
    the real decode work of the row-aligned probe path, which is why
    ``ints_decoded`` follows rows, not unique blocks). ``blocks_pruned`` /
    ``postings_pruned`` count whole blocks (and the postings inside them)
    eliminated by the MaxScore threshold — **never decoded by any pass**:
    a block gathered by a non-essential probe/merge pass is excluded even
    if the strip cursor never reached it, so per term
    ``per_term_pruned[t] + len(per_term_blocks[t]) == n_blocks(t)`` is an
    exact disjoint partition (``per_term_blocks`` is the set of live block
    rows decoded at least once). ``probes_pruned`` counts
    (candidate × term) probes settled by the score bound alone.
    ``impact_ints_decoded`` counts per-posting impact integers decoded
    from the weight streams (MaxScore / tf-scored paths).
    """

    blocks_decoded: int = 0
    blocks_skipped: int = 0
    blocks_pruned: int = 0  # MaxScore threshold-pruned, never decoded
    rows_gathered: int = 0  # per-probe row gathers (duplicates included)
    ints_decoded: int = 0  # valid integers in decoded blocks/rows
    impact_ints_decoded: int = 0  # per-posting impacts decoded alongside
    postings_pruned: int = 0  # postings inside threshold-pruned blocks
    probes_pruned: int = 0  # candidate×term probes settled by bound alone
    decode_calls: int = 0
    per_term_decoded: dict = field(default_factory=dict)
    per_term_pruned: dict = field(default_factory=dict)
    per_term_blocks: dict = field(default_factory=dict)  # term -> set of
    #   live block rows decoded at least once (strip-pulled or gathered)
    # robustness accounting (docs/robustness.md): a degraded result is
    # still correct over the work that ran — smaller, never silently wrong
    errors: int = 0  # typed DecodeErrors hit while answering
    retries: int = 0  # transient-failure retries that succeeded
    quarantined_blocks: int = 0  # blocks of quarantined segments not served
    bound_fallbacks: int = 0  # maxscore→TAAT fallbacks (unsafe bounds)
    # live-index accounting (repro.index.ingest): postings served from the
    # uncompressed delta layer, result docs sourced from it, and main-
    # segment postings suppressed by the tombstone set at query time
    delta_postings: int = 0
    delta_hits: int = 0
    tombstones_applied: int = 0
    degraded: bool = False
    degraded_reasons: list = field(default_factory=list)

    def mark_degraded(self, reason: str):
        self.degraded = True
        if reason not in self.degraded_reasons:
            self.degraded_reasons.append(reason)

    def merge(self, other: "QueryStats"):
        """Fold a per-query stats object into this aggregate — how
        ``SearchEngine``/``run_workload`` keep one per-call degraded flag
        while still reporting workload-wide decode accounting.

        Iterates ``dataclasses.fields`` so a newly added counter merges by
        its type instead of being silently dropped; a field with no merge
        rule (unsupported type) raises at the first merge, which is the
        test-enforced contract for extending this class.
        """
        for f in dataclasses.fields(self):
            mine = getattr(self, f.name)
            theirs = getattr(other, f.name)
            if isinstance(mine, bool):
                setattr(self, f.name, mine or theirs)
            elif isinstance(mine, (int, float)):
                setattr(self, f.name, mine + theirs)
            elif isinstance(mine, dict):
                for t, v in theirs.items():
                    if isinstance(v, (set, frozenset)):
                        mine.setdefault(t, set()).update(v)
                    elif isinstance(v, (int, float)):
                        mine[t] = mine.get(t, 0) + v
                    else:
                        raise TypeError(
                            f"QueryStats.merge: no merge rule for dict "
                            f"field {f.name!r} value of type "
                            f"{type(v).__name__}")
            elif isinstance(mine, list):
                for r in theirs:  # dedup-append (degraded_reasons order)
                    if r not in mine:
                        mine.append(r)
            elif isinstance(mine, set):
                mine.update(theirs)
            else:
                raise TypeError(
                    f"QueryStats.merge: no merge rule for field "
                    f"{f.name!r} of type {type(mine).__name__} — add one "
                    f"here before adding the field")

    def span_attrs(self) -> dict:
        """Flat attribute dict for trace spans: every scalar counter plus
        the degraded flag/reasons (the per-term dicts summarize as sizes —
        span attributes stay JSON-scalar-ish; the dataclass remains the
        full-fidelity API)."""
        out = {}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, (bool, int, float)):
                out[f.name] = v
            elif isinstance(v, dict):
                out[f"{f.name}_terms"] = len(v)
            elif isinstance(v, list):
                out[f.name] = list(v)
        return out

    def count(self, term: int, decoded: int, skipped: int, ints: int):
        self.blocks_decoded += decoded
        self.blocks_skipped += skipped
        self.ints_decoded += ints
        self.decode_calls += 1
        self.per_term_decoded[term] = (
            self.per_term_decoded.get(term, 0) + decoded)

    def count_pruned(self, blocks: int, postings: int, term=None):
        self.blocks_pruned += blocks
        self.postings_pruned += postings
        if term is not None:
            self.per_term_pruned[term] = (
                self.per_term_pruned.get(term, 0) + blocks)

    def touch(self, term: int, rows):
        """Record live block rows of ``term`` decoded at least once."""
        self.per_term_blocks.setdefault(term, set()).update(
            int(r) for r in rows)


def _pow2(x: int) -> int:
    return 1 << max(x - 1, 0).bit_length()


def _expired(deadline: Deadline | None, stats: QueryStats | None,
             where: str) -> bool:
    """Deadline check at a work-unit boundary (docs/robustness.md).

    Work in flight always completes — expiry only stops *new* strips /
    terms / chunks from starting, so a timed-out query returns a smaller
    but well-defined result, flagged via ``stats.degraded``.
    """
    if deadline is None or not deadline.expired():
        return False
    if stats is not None:
        stats.mark_degraded(f"deadline:{where}")
    return True


def _overlap_blocks(tp: TermPostings, lo: int, hi: int) -> tuple[int, int]:
    """Block range ``[i0, i1)`` whose ``[first, last]`` intersects [lo, hi].

    ``first_doc``/``last_doc`` are sorted (postings are), so this is two
    binary searches — the skip-table gallop.
    """
    i0 = int(np.searchsorted(tp.last_doc, lo, side="left"))
    i1 = int(np.searchsorted(tp.first_doc, hi, side="right"))
    return i0, max(i1, i0)


def _decode_blocks(tp: TermPostings, i0: int, i1: int, *, plan, stats,
                   use_skip: bool) -> np.ndarray:
    """Decode blocks ``[i0, i1)`` of one term to sorted uint32 docids."""
    if not use_skip:
        i0, i1 = 0, tp.n_blocks
    if i1 <= i0:
        return np.zeros(0, np.uint32)
    if use_skip and (i0, i1) != (0, tp.n_blocks):
        sub = tp.arr.slice_blocks(i0, i1, pad_to=_pow2(i1 - i0))
    else:
        # whole list: decode the resident (possibly sharded) array in
        # place — slicing would just copy and re-upload every leaf
        sub = tp.arr
    if stats is not None:
        stats.count(tp.term, i1 - i0, tp.n_blocks - (i1 - i0), sub.n)
        stats.touch(tp.term, range(i0, i1))
    return sub.decode(plan=plan)


def _decode_impact_stream(tp: TermPostings, *, plan, stats) -> np.ndarray:
    """Decode the whole per-posting impact stream, aligned with the docid
    list (identical block layout — see builder)."""
    if stats is not None:
        stats.impact_ints_decoded += tp.impacts.n
        stats.decode_calls += 1
    return tp.impacts.decode(plan=plan).astype(np.int64)


def _weight_extras(weights, rows=None, *, pad=None):
    """Format-tagged weight operands for the ``bm25_weighted*`` epilogues,
    optionally row-gathered to align with a gathered main stream."""
    sub = weights if rows is None else weights.take_blocks(rows, pad_to=pad)
    ops = sub.device_operands()
    extras = {f"w_{k}": v for k, v in ops.items()
              if k in ("payload", "control", "data", "widths")}
    return extras, sub.n


def _route_probes(tp: TermPostings, chunk: np.ndarray):
    """Per-probe skip-table gallop: ``(ok mask, block id per hit probe)``.

    Each probe binary-searches ``first_doc``/``last_doc``; a probe that
    lands between two blocks' docid ranges is in no block at all and is
    settled without decoding anything. The hit probes each name the single
    block that can contain them.
    """
    pos = np.searchsorted(tp.first_doc, chunk, side="right") - 1
    ok = pos >= 0
    ok &= chunk <= tp.last_doc[np.maximum(pos, 0)]
    return ok, pos[ok]


def _probe_pass(tp: TermPostings, chunk: np.ndarray, *, impact: int,
                probe_width: int, plan, stats, use_skip: bool,
                weights=None, touched=None) -> np.ndarray:
    """Skip-gallop stage span around :func:`_probe_pass_impl`."""
    with _trace("gallop", term=tp.term, probes=len(chunk)) as sp:
        if sp and stats is not None:
            b0, i0 = stats.blocks_decoded, stats.ints_decoded
        out = _probe_pass_impl(tp, chunk, impact=impact,
                               probe_width=probe_width, plan=plan,
                               stats=stats, use_skip=use_skip,
                               weights=weights, touched=touched)
        if sp and stats is not None:
            sp.set(blocks_decoded=stats.blocks_decoded - b0,
                   ints_decoded=stats.ints_decoded - i0)
        return out


def _probe_pass_impl(tp: TermPostings, chunk: np.ndarray, *, impact: int,
                     probe_width: int, plan, stats, use_skip: bool,
                     weights=None, touched=None) -> np.ndarray:
    """One (term, candidate-chunk) pass: int32 [len(chunk)] per-candidate
    result — the membership bitmap (``impact=0``), the constant bm25
    impact contribution (``impact>0``), or the exact per-posting impact
    contribution (``weights=`` the term's impact ``CompressedIntArray``,
    decoded in the same tile pass by the ``bm25_weighted*`` epilogues).

    With skip pruning, each hit probe gathers its one candidate block and
    the block-aligned ``*_rows`` epilogue compares probe t against tile t
    only (O(B) per probe). Without (resident/sharded arrays), the whole
    list decodes under the broadcast epilogue with the probe set in VMEM.
    """
    if use_skip:
        ok, rows = _route_probes(tp, chunk)
        if rows.size == 0:  # every probe galloped past: nothing decoded
            if stats is not None:
                stats.count(tp.term, 0, tp.n_blocks, 0)
            return np.zeros(len(chunk), np.int32)
        uniq = np.unique(rows)
        if touched is not None:
            touched.update(uniq.tolist())
        if stats is not None:
            stats.touch(tp.term, uniq)
        res = np.zeros(len(chunk), np.int32)
        if uniq.size * 2 > rows.size:
            # mostly-distinct blocks: one gathered row per probe, O(B)
            # compare against its own tile. decoded+skipped covers the
            # blocks considered exactly once; the per-probe duplicates are
            # rows_gathered (ints follow rows — the real decode work).
            row_ints = int(np.asarray(tp.arr.counts)[rows].sum())
            if stats is not None:
                stats.count(tp.term, int(uniq.size),
                            tp.n_blocks - int(uniq.size), row_ints)
                stats.rows_gathered += int(rows.size)
            pad = _pow2(rows.size)
            sub = tp.arr.take_blocks(rows, pad_to=pad)
            probe = np.full((pad, 1), -1, np.int32)
            probe[: rows.size, 0] = chunk[ok].astype(np.int32)
            extras = {"probe": jnp.asarray(probe)}
            if weights is not None:
                w_extras, w_ints = _weight_extras(weights, rows, pad=pad)
                extras.update(w_extras)
                if stats is not None:
                    stats.impact_ints_decoded += w_ints
                ep_name = "bm25_weighted_rows"
            elif impact:
                extras["impact"] = jnp.asarray([[impact]], jnp.int32)
                ep_name = "bm25_accum_rows"
            else:
                ep_name = "membership_rows"
            out = dispatch.decode(sub, epilogue=ep_name,
                                  epilogue_operands=extras, plan=plan)
            res[ok] = np.asarray(out)[: rows.size, 0]
            return res
        # probes pile into few blocks (short lists): duplicating rows
        # would re-decode each block once per probe — gather each hit
        # block ONCE and run the broadcast epilogue over the chunk
        if stats is not None:
            stats.count(tp.term, int(uniq.size),
                        tp.n_blocks - int(uniq.size),
                        int(np.asarray(tp.arr.counts)[uniq].sum()))
        pad = _pow2(uniq.size)
        sub = tp.arr.take_blocks(uniq, pad_to=pad)
        w = _pow2(len(chunk))
        extras = {"probe": jnp.asarray(normalize_probe(chunk, w))}
        if weights is not None:
            w_extras, w_ints = _weight_extras(weights, uniq, pad=pad)
            extras.update(w_extras)
            if stats is not None:
                stats.impact_ints_decoded += w_ints
            ep_name = "bm25_weighted"
        elif impact:
            extras["impact"] = jnp.asarray([[impact]], jnp.int32)
            ep_name = "bm25_accum"
        else:
            ep_name = "membership"
        out = dispatch.decode(sub, epilogue=ep_name,
                              epilogue_operands=extras, plan=plan)
        res[:] = np.asarray(out).sum(axis=0, dtype=np.int32)[: len(chunk)]
        return res
    sub = tp.arr
    if touched is not None:
        touched.update(range(tp.n_blocks))
    if stats is not None:
        stats.count(tp.term, tp.n_blocks, 0, sub.n)
        stats.touch(tp.term, range(tp.n_blocks))
    extras = {"probe": jnp.asarray(normalize_probe(chunk, probe_width))}
    if weights is not None:
        w_extras, w_ints = _weight_extras(weights)
        extras.update(w_extras)
        if stats is not None:
            stats.impact_ints_decoded += w_ints
        ep_name = "bm25_weighted"
    elif impact:
        extras["impact"] = jnp.asarray([[impact]], jnp.int32)
        ep_name = "bm25_accum"
    else:
        ep_name = "membership"
    out = dispatch.decode(sub, epilogue=ep_name,
                          epilogue_operands=extras, plan=plan)
    # a docid lives in exactly one block → summing blocks is exact int32
    return np.asarray(out).sum(axis=0, dtype=np.int32)[: len(chunk)]


def _merge_pass(tp: TermPostings, chunk: np.ndarray, *, impact: int,
                plan, stats, weights=None, touched=None) -> np.ndarray:
    """Merge stage span around :func:`_merge_pass_impl`."""
    with _trace("merge", term=tp.term, candidates=len(chunk)) as sp:
        if sp and stats is not None:
            b0, i0 = stats.blocks_decoded, stats.ints_decoded
        out = _merge_pass_impl(tp, chunk, impact=impact, plan=plan,
                               stats=stats, weights=weights,
                               touched=touched)
        if sp and stats is not None:
            sp.set(blocks_decoded=stats.blocks_decoded - b0,
                   ints_decoded=stats.ints_decoded - i0)
        return out


def _merge_pass_impl(tp: TermPostings, chunk: np.ndarray, *, impact: int,
                     plan, stats, weights=None, touched=None) -> np.ndarray:
    """Bulk variant of :func:`_probe_pass` for candidate sets too large to
    probe: int64 [len(chunk)] per-candidate contribution.

    The probe epilogues pay per probe — a strip's worth of MaxScore
    candidates against a long non-essential list would gather (and decode)
    one row per candidate, re-decoding hot blocks hundreds of times across
    dozens of chunked dispatches. Here each block that contains any
    candidate decodes exactly once (a single gathered dispatch per stream)
    and the membership test is a host-side ``searchsorted`` merge —
    gathered blocks ascend, so their concatenated postings stay sorted.
    """
    res = np.zeros(len(chunk), np.int64)
    ok, rows = _route_probes(tp, chunk)
    if rows.size == 0:
        if stats is not None:
            stats.count(tp.term, 0, tp.n_blocks, 0)
        return res
    uniq = np.unique(rows)
    if touched is not None:
        touched.update(uniq.tolist())
    if stats is not None:
        stats.touch(tp.term, uniq)
    pad = _pow2(uniq.size)
    if uniq.size == uniq[-1] - uniq[0] + 1:
        sub = tp.arr.slice_blocks(uniq[0], uniq[-1] + 1, pad_to=pad)
        wsub = (weights.slice_blocks(uniq[0], uniq[-1] + 1, pad_to=pad)
                if weights is not None else None)
    else:
        sub = tp.arr.take_blocks(uniq, pad_to=pad)
        wsub = (weights.take_blocks(uniq, pad_to=pad)
                if weights is not None else None)
    if stats is not None:
        stats.count(tp.term, int(uniq.size),
                    tp.n_blocks - int(uniq.size), sub.n)
    docs = sub.decode(plan=plan)
    if wsub is not None:
        if stats is not None:
            stats.impact_ints_decoded += wsub.n
            stats.decode_calls += 1
        imps = wsub.decode(plan=plan).astype(np.int64)
    else:
        imps = np.full(docs.size, impact, np.int64)
    pos = np.searchsorted(docs, chunk[ok])
    pos = np.minimum(pos, docs.size - 1)
    hit = docs[pos] == chunk[ok]
    vals = np.where(hit, imps[pos], 0)
    res[np.flatnonzero(ok)] = vals
    return res


def _score_term(tp: TermPostings, base_impact: int, cand: np.ndarray,
                sel: np.ndarray, scores: np.ndarray, *, has_tf: bool,
                probe_width: int, plan, stats, touched=None):
    """Add term ``tp``'s exact contribution to ``scores[sel]``: bulk
    decode-and-merge for strip-sized candidate sets, chunked probe
    epilogues for small ones (one dispatch per chunk, rows in VMEM).
    ``touched`` (a set) collects the block rows actually gathered, so
    MaxScore's exit accounting never books a probe-decoded block as
    threshold-pruned."""
    with _trace("score", term=tp.term, candidates=int(sel.size)) as sp:
        if sp and stats is not None:
            b0, i0 = stats.blocks_decoded, stats.ints_decoded
        _score_term_impl(tp, base_impact, cand, sel, scores, has_tf=has_tf,
                         probe_width=probe_width, plan=plan, stats=stats,
                         touched=touched)
        if sp and stats is not None:
            sp.set(blocks_decoded=stats.blocks_decoded - b0,
                   ints_decoded=stats.ints_decoded - i0)


def _score_term_impl(tp: TermPostings, base_impact: int, cand: np.ndarray,
                     sel: np.ndarray, scores: np.ndarray, *, has_tf: bool,
                     probe_width: int, plan, stats, touched=None):
    wts = tp.impacts if has_tf else None
    if sel.size > MERGE_MIN_PROBES:
        scores[sel] += _merge_pass(
            tp, cand[sel].astype(np.uint32), impact=base_impact,
            plan=plan, stats=stats, weights=wts, touched=touched)
        return
    w = min(_pow2(sel.size), probe_width)
    for s in range(0, sel.size, w):
        ch = sel[s:s + w]
        contrib = _probe_pass(
            tp, cand[ch].astype(np.uint32), impact=base_impact,
            probe_width=w, plan=plan, stats=stats, use_skip=True,
            weights=wts, touched=touched)
        scores[ch] += contrib.astype(np.int64)


def _term_postings(index: InvertedIndex, terms) -> list[TermPostings]:
    out = []
    for t in terms:
        tp = index.terms.get(t)
        out.append(tp if tp is not None
                   else TermPostings(term=t, arr=None,
                                     first_doc=np.zeros(0, np.uint32),
                                     last_doc=np.zeros(0, np.uint32), df=0))
    return out


def conjunctive(
    index: InvertedIndex,
    terms,
    *,
    plan="auto",
    probe_width: int = DEFAULT_PROBE_WIDTH,
    stats: QueryStats | None = None,
    use_skip: bool = True,
    deadline: Deadline | None = None,
) -> np.ndarray:
    """AND query: sorted uint32 docids present in every term's postings.

    On deadline expiry the remaining terms are skipped and the
    intersection-so-far is returned — a *superset* of the exact answer,
    flagged degraded via ``stats`` (docs/robustness.md).
    """
    if not terms:
        raise ValueError("conjunctive query needs ≥1 term")
    # dedup repeated terms: AND(t, t) = t, and each repeat would re-probe
    tps = sorted(_term_postings(index, dict.fromkeys(terms)),
                 key=lambda tp: tp.df)
    if tps[0].df == 0:
        return np.zeros(0, np.uint32)
    # common docid window: outside [lo, hi] no doc can be in all terms
    lo = max(int(tp.first_doc[0]) for tp in tps)
    hi = min(int(tp.last_doc[-1]) for tp in tps)
    if lo > hi:
        return np.zeros(0, np.uint32)
    driver, rest = tps[0], tps[1:]
    i0, i1 = _overlap_blocks(driver, lo, hi)
    cand = _decode_blocks(driver, i0, i1, plan=plan, stats=stats,
                          use_skip=use_skip)
    cand = cand[(cand >= lo) & (cand <= hi)]
    for tp in rest:
        if cand.size == 0:
            break
        if _expired(deadline, stats, "and-term"):
            break
        w = min(_pow2(cand.size), probe_width)
        keep = np.zeros(cand.size, bool)
        for s in range(0, cand.size, w):
            if s and _expired(deadline, stats, "and-chunk"):
                keep[s:] = True  # unprobed candidates stay (superset)
                break
            chunk = cand[s:s + w]
            hit = _probe_pass(tp, chunk, impact=0, probe_width=w, plan=plan,
                              stats=stats, use_skip=use_skip)
            keep[s:s + len(chunk)] = hit.astype(bool)
        cand = cand[keep]
    return cand.astype(np.uint32)


def disjunctive(
    index: InvertedIndex,
    terms,
    *,
    plan="auto",
    stats: QueryStats | None = None,
    use_skip: bool = True,
    deadline: Deadline | None = None,
) -> np.ndarray:
    """OR query: sorted uint32 docids present in any term's postings.

    On deadline expiry the remaining terms are skipped: the union-so-far
    (a subset) is returned, flagged degraded via ``stats``.
    """
    if not terms:
        raise ValueError("disjunctive query needs ≥1 term")
    parts = []
    for tp in _term_postings(index, dict.fromkeys(terms)):  # dedup repeats
        if tp.df == 0:
            continue
        if parts and _expired(deadline, stats, "or-term"):
            break
        parts.append(_decode_blocks(tp, 0, tp.n_blocks, plan=plan,
                                    stats=stats, use_skip=use_skip))
    if not parts:
        return np.zeros(0, np.uint32)
    return np.unique(np.concatenate(parts)).astype(np.uint32)


def _taat_scores(index: InvertedIndex, terms, *, plan, stats, use_skip,
                 deadline: Deadline | None = None):
    """Exhaustive TAAT scoring: every term decodes once (the union pass),
    its impacts scatter onto its own docids. ``(cand int64, scores int64)``,
    exact — the reference every pruned path must match bit-for-bit. On
    deadline expiry the remaining terms never decode: candidates and
    scores cover the terms that ran (flagged degraded via ``stats``)."""
    parts = {}
    for t in dict.fromkeys(terms):
        tp = index.terms.get(t)
        if tp is None or tp.df == 0:
            continue
        if parts and _expired(deadline, stats, "taat-term"):
            break
        parts[t] = _decode_blocks(tp, 0, tp.n_blocks, plan=plan,
                                  stats=stats, use_skip=use_skip)
    if not parts:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    with _trace("score", terms=len(parts)):
        cand = np.unique(
            np.concatenate(list(parts.values()))).astype(np.int64)
        scores = np.zeros(cand.size, np.int64)
        for t, docs in parts.items():
            tp = index.terms[t]
            if index.has_tf:
                # per-posting impacts: decode the aligned weight stream
                imps = _decode_impact_stream(tp, plan=plan, stats=stats)
                scores[np.searchsorted(cand, docs.astype(np.int64))] += imps
            else:
                scores[np.searchsorted(cand, docs.astype(np.int64))] \
                    += index.impact(t)
    return cand, scores


class _StripCursor:
    """Per-term DAAT cursor for MaxScore: advances block-aligned strips,
    buffering decoded postings beyond the strip boundary."""

    def __init__(self, tp: TermPostings, has_tf: bool, base_impact: int):
        self.tp = tp
        self.has_tf = has_tf
        self.base_impact = base_impact
        self.i = 0  # next undecoded block
        self.buf_docs = np.zeros(0, np.int64)
        self.buf_imps = np.zeros(0, np.int64)
        self.pruned_rows: list = []  # block rows dropped by θ at pull time
        #   (booked at exit, minus any later gathered by a probe pass)

    @property
    def exhausted(self) -> bool:
        return self.i >= self.tp.n_blocks and self.buf_docs.size == 0

    def pull(self, hi: int, theta: int | None, other_ub, *,
             plan, stats: QueryStats):
        """Decode this term's postings ≤ ``hi`` (buffer the overshoot).

        Advances over every block starting ≤ hi; with a threshold, any
        block whose ``max_impact + other_ub < θ`` is pruned — its postings
        can't even tie an incumbent — and never strip-decoded (the strict
        ``<`` keeps θ-tying blocks: a tied doc at a smaller docid outranks
        the incumbent under the final lexsort). ``other_ub`` is the other
        terms' score bound: a scalar, or a callable mapping the block rows
        under consideration to a per-row bound (MaxScore tightens it per
        block once seeded terms' docids are known). Pruned rows are only
        buffered here (``pruned_rows``); the exit accounting books them
        after subtracting any row a later probe pass gathered anyway.
        """
        tp = self.tp
        j = int(np.searchsorted(tp.first_doc, hi, side="right"))
        rows = np.arange(self.i, max(j, self.i))
        self.i = max(j, self.i)
        if theta is not None and rows.size:
            ou = other_ub(rows) if callable(other_ub) else other_ub
            beaten = (tp.max_impact[rows].astype(np.int64)
                      + ou < theta)
            if beaten.any():
                self.pruned_rows.append(rows[beaten])
                rows = rows[~beaten]
        if rows.size:
            pad = _pow2(rows.size)
            contiguous = rows.size == rows[-1] - rows[0] + 1
            if contiguous:
                sub = tp.arr.slice_blocks(rows[0], rows[-1] + 1, pad_to=pad)
                wsub = tp.impacts.slice_blocks(rows[0], rows[-1] + 1,
                                               pad_to=pad)
            else:
                sub = tp.arr.take_blocks(rows, pad_to=pad)
                wsub = tp.impacts.take_blocks(rows, pad_to=pad)
            stats.count(tp.term, int(rows.size), 0, sub.n)
            stats.touch(tp.term, rows)
            docs = sub.decode(plan=plan).astype(np.int64)
            if self.has_tf:
                stats.impact_ints_decoded += wsub.n
                stats.decode_calls += 1
                imps = wsub.decode(plan=plan).astype(np.int64)
            else:  # tf-free: the stream would decode to this constant
                imps = np.full(docs.size, self.base_impact, np.int64)
            docs = np.concatenate([self.buf_docs, docs])
            imps = np.concatenate([self.buf_imps, imps])
        else:
            docs, imps = self.buf_docs, self.buf_imps
        cut = int(np.searchsorted(docs, hi, side="right"))
        self.buf_docs, self.buf_imps = docs[cut:], imps[cut:]
        return docs[:cut], imps[:cut]


def _seeded_bound(c, total_ub: int, seed_docs):
    """Per-row bound on the OTHER terms' contribution to cursor ``c``'s
    blocks. Seeded terms are fully decoded, so a block containing none of
    a seeded term's docids provably gets zero from it — subtracting those
    ubs is what lets θ prune essential blocks even when the global
    ``Σ other ubs`` (dominated by a rare term's saturated impact) never
    drops below θ."""
    loose = total_ub - c.tp.ub

    def bound(rows: np.ndarray) -> np.ndarray:
        ou = np.full(rows.size, loose, np.int64)
        f = c.tp.first_doc[rows]
        l = c.tp.last_doc[rows]
        for s, ds in seed_docs:
            if s is c:
                continue
            absent = (np.searchsorted(ds, l, side="right")
                      == np.searchsorted(ds, f, side="left"))
            ou -= s.tp.ub * absent
        return ou

    return bound


def _maxscore(index: InvertedIndex, terms, k: int, *, plan, probe_width,
              stats: QueryStats | None, deadline: Deadline | None = None):
    """Block-max pruned disjunctive top-k (see module docstring).

    Bit-exact with :func:`_taat_scores` + lexsort by construction: every
    pruning decision only ever discards work whose best case is *strictly
    below* the current k-th score θ. Strictness matters: the seed phase
    puts exactly-scored incumbents at arbitrary docids into the heap, so
    a later candidate whose score ties θ at a smaller docid must still be
    generated — the final lexsort ranks it ahead of the tied incumbent."""
    st = stats if stats is not None else QueryStats()
    tps = [tp for tp in _term_postings(index, dict.fromkeys(terms))
           if tp.df > 0]
    if not tps:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    for tp in tps:
        if tp.impacts is None or tp.max_impact.size != tp.n_blocks:
            raise ValueError(
                "mode='maxscore' needs per-posting impact streams and the "
                "max_impact skip column — rebuild the index with "
                "build_index (optionally passing tfs=)")
    tps.sort(key=lambda tp: (tp.ub, tp.term))  # ascending upper bound
    ubs = np.array([tp.ub for tp in tps], np.int64)
    cum_ub = np.cumsum(ubs)
    total_ub = int(cum_ub[-1])
    strip_blocks = max(1, probe_width // index.block_size)
    cursors = [_StripCursor(tp, index.has_tf, index.impact(tp.term))
               for tp in tps]
    top_d = np.zeros(0, np.int64)
    top_s = np.zeros(0, np.int64)
    # geometric strip ramp: the first strips stay small so θ forms after
    # little decode work, then the horizon doubles so long lists take
    # O(log n_blocks) dispatches instead of O(n_blocks). Block-level
    # pruning is unaffected — pull() drops beaten blocks row-by-row
    # against the θ current at pull time, whatever the strip width.
    strip = strip_blocks

    # seed θ from the tiny lists: a term whose whole list fits in one
    # strip (a rare title term — highest impacts, handful of blocks) is
    # decoded and scored exactly up front, probing every other term only
    # at its few docids. That matures θ before ANY long block streams —
    # DAAT alone would grow θ in docid order, decoding most of the long
    # lists before the high-score docs surface. Skipped when no long list
    # exists: seeding everything would just re-derive TAAT.
    seeded = np.zeros(0, np.int64)
    seed_docs = []
    # block rows of each term gathered by probe/merge passes — the exit
    # accounting subtracts these so "pruned" means never decoded anywhere
    touched: dict[int, set] = {}
    if max(tp.n_blocks for tp in tps) > 4 * strip_blocks:
        with _trace("seed"):
            seeds = [c for c in cursors if c.tp.n_blocks <= strip_blocks]
            parts = []
            for c in seeds:
                docs, imps = c.pull(int(c.tp.last_doc[-1]), None, 0,
                                    plan=plan, stats=st)
                if docs.size:
                    parts.append((docs, imps))
                    seed_docs.append((c, docs))
            if parts:
                cand = np.unique(np.concatenate([p[0] for p in parts]))
                scores = np.zeros(cand.size, np.int64)
                for docs, imps in parts:
                    scores[np.searchsorted(cand, docs)] += imps
                for c in cursors:
                    if c not in seeds:
                        _score_term(c.tp, c.base_impact, cand,
                                    np.arange(cand.size), scores,
                                    has_tf=index.has_tf,
                                    probe_width=probe_width, plan=plan,
                                    stats=st,
                                    touched=touched.setdefault(c.tp.term,
                                                               set()))
                order = np.lexsort((cand, -scores))[:k]
                top_d, top_s = cand[order], scores[order]
                seeded = cand

    timed_out = False
    while True:
        if _expired(deadline, st, "maxscore-strip"):
            # the running top-k is exact over every strip that completed —
            # return it as the degraded partial result
            timed_out = True
            break
        full = top_d.size >= k
        theta = int(top_s[k - 1]) if full else -1
        # non-essential prefix: cumulative upper bound strictly below θ —
        # a ub-tying prefix stays essential, its docs could tie-and-win
        n_ness = (int(np.searchsorted(cum_ub, theta, side="left"))
                  if full else 0)
        if n_ness >= len(tps):
            break  # Σ all ubs < θ: nothing unseen can reach the top-k
        ess = cursors[n_ness:]
        # strip horizon: each essential term advances ≤ strip blocks
        his = [int(c.tp.last_doc[min(c.i + strip, c.tp.n_blocks) - 1])
               for c in ess if c.i < c.tp.n_blocks]
        if his:
            hi = min(his)
        else:  # all essential cursors block-exhausted: drain the buffers
            bufs = [int(c.buf_docs[-1]) for c in ess if c.buf_docs.size]
            if not bufs:
                break
            hi = max(bufs)
        parts = []
        for c in ess:
            docs, imps = c.pull(hi, theta if full else None,
                                _seeded_bound(c, total_ub, seed_docs)
                                if seed_docs else total_ub - c.tp.ub,
                                plan=plan, stats=st)
            if docs.size:
                parts.append((docs, imps))
        if parts:
            cand = np.unique(np.concatenate([p[0] for p in parts]))
            if seeded.size:
                # seeded docs are already exactly scored in the heap —
                # rescoring them here would duplicate their heap entry
                pos = np.minimum(np.searchsorted(seeded, cand),
                                 seeded.size - 1)
                cand = cand[seeded[pos] != cand]
            partial = np.zeros(cand.size, np.int64)
            for docs, imps in parts:
                pos = np.searchsorted(cand, docs)
                pos = np.minimum(pos, max(cand.size - 1, 0))
                ok = (cand[pos] == docs) if cand.size else np.zeros(
                    docs.size, bool)
                partial[pos[ok]] += imps[ok]
            scores = partial
            # probe non-essential terms in descending-bound order; drop
            # candidates as soon as even a full remaining bound can't pass
            ness = sorted((cursors[i] for i in range(n_ness)),
                          key=lambda c: -c.tp.ub)
            rem_ub = np.concatenate(
                [np.cumsum([c.tp.ub for c in reversed(ness)])[::-1],
                 [0]]) if ness else np.zeros(1, np.int64)
            alive = np.ones(cand.size, bool)
            if full:
                dead = scores + int(rem_ub[0]) < theta
                st.probes_pruned += int(dead.sum()) * len(ness)
                alive &= ~dead
            for idx, c in enumerate(ness):
                sel = np.flatnonzero(alive)
                if sel.size == 0:
                    break
                _score_term(c.tp, c.base_impact, cand, sel, scores,
                            has_tf=index.has_tf, probe_width=probe_width,
                            plan=plan, stats=st,
                            touched=touched.setdefault(c.tp.term, set()))
                if full:
                    dead = alive & (scores + int(rem_ub[idx + 1]) < theta)
                    st.probes_pruned += (int(dead.sum())
                                         * (len(ness) - idx - 1))
                    alive &= ~dead
            md = np.concatenate([top_d, cand[alive]])
            ms = np.concatenate([top_s, scores[alive]])
            order = np.lexsort((md, -ms))[:k]
            top_d, top_s = md[order], ms[order]
        strip = min(strip * STRIP_RAMP, MAX_STRIP_BLOCKS)
    # exit accounting: a block was threshold-pruned iff NO pass ever
    # decoded it — neither a strip pull (pull-pruned rows + everything
    # past the cursor frontier are the candidates) nor a non-essential
    # probe/merge gather (subtracted via ``touched``), so decoded and
    # pruned block sets stay disjoint and, per term,
    # pruned + decoded-at-least-once == n_blocks exactly. A timed-out
    # query books nothing: blocks past the frontier were abandoned by the
    # deadline, not proven beaten.
    for c in cursors if not timed_out else ():
        rows = np.concatenate(
            c.pruned_rows + [np.arange(c.i, c.tp.n_blocks)]
        ).astype(np.int64)
        c.i = c.tp.n_blocks
        got = touched.get(c.tp.term)
        if got:
            rows = rows[~np.isin(rows,
                                 np.fromiter(got, np.int64, len(got)))]
        if rows.size:
            st.count_pruned(
                int(rows.size),
                int(np.asarray(c.tp.arr.counts)[rows].sum()),
                term=c.tp.term)
    return top_d, top_s


def topk(
    index: InvertedIndex,
    terms,
    k: int,
    *,
    mode: str = "or",
    plan="auto",
    probe_width: int = DEFAULT_PROBE_WIDTH,
    stats: QueryStats | None = None,
    use_skip: bool = True,
    deadline: Deadline | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Top-k scored query: ``(docids uint32 [≤k], scores int32 [≤k])``.

    Score(d) = Σ over query terms containing d of the term's quantized
    impact at d — per-posting when the index was built with tfs
    (``InvertedIndex.has_tf``), the tf-free constant otherwise.
    ``mode="or"`` (default) is term-at-a-time over the union decode.
    ``mode="maxscore"`` returns bit-identical results via block-max
    dynamic pruning — whole blocks and candidate probes that cannot beat
    the running k-th score are never decoded (see module docstring; falls
    back to exact TAAT for resident/sharded indexes, ``use_skip=False``,
    whose arrays cannot be block-gathered on the host). ``mode="and"``
    restricts to the conjunctive candidates. ``mode="driver"`` is
    required-term top-k, the scored DAAT shape: docs containing
    ``terms[0]``, ranked by total impact over all query terms via the
    fused scoring epilogues. Results are ordered by (score desc, docid
    asc) — exact integer ties are deterministic.
    """
    if isinstance(k, bool) or not isinstance(k, (int, np.integer)) or k < 1:
        raise ValueError(f"k must be a positive integer, got {k!r}")
    k = int(k)
    with _trace("topk", mode=mode, k=k) as sp:
        out = _topk_impl(index, terms, k, mode=mode, plan=plan,
                         probe_width=probe_width, stats=stats,
                         use_skip=use_skip, deadline=deadline)
        if sp and stats is not None:
            sp.set(**stats.span_attrs())
        return out


def _topk_impl(index: InvertedIndex, terms, k: int, *, mode, plan,
               probe_width, stats, use_skip, deadline):
    if mode == "or" or (mode == "maxscore" and not use_skip):
        cand, scores = _taat_scores(index, terms, plan=plan, stats=stats,
                                    use_skip=use_skip, deadline=deadline)
    elif mode == "maxscore":
        cand, scores = _maxscore(index, terms, k, plan=plan,
                                 probe_width=probe_width, stats=stats,
                                 deadline=deadline)
    elif mode == "and":
        cand = conjunctive(index, terms, plan=plan, probe_width=probe_width,
                           stats=stats, use_skip=use_skip,
                           deadline=deadline).astype(np.int64)
        if index.has_tf:
            # per-posting impacts vary per candidate: probe each term's
            # weight stream over the conjunctive candidates
            scores = np.zeros(cand.size, np.int64)
            for t in dict.fromkeys(terms):
                tp = index.terms.get(t)
                if tp is None or tp.df == 0 or cand.size == 0:
                    continue
                if _expired(deadline, stats, "and-score-term"):
                    break
                w = min(_pow2(cand.size), probe_width)
                for s in range(0, cand.size, w):
                    chunk = cand[s:s + w].astype(np.uint32)
                    scores[s:s + len(chunk)] += _probe_pass(
                        tp, chunk, impact=index.impact(t), probe_width=w,
                        plan=plan, stats=stats, use_skip=use_skip,
                        weights=tp.impacts).astype(np.int64)
        else:
            # every conjunctive candidate is in every query term, so the
            # tf-free score is one known constant — no scoring decode
            total = sum(index.impact(t) for t in dict.fromkeys(terms))
            scores = np.full(cand.size, total, np.int64)
    elif mode == "driver":
        # required-term top-k, the real DAAT shape: candidates are the
        # docs containing terms[0], ranked by total impact over ALL query
        # terms — per chunk the fused scoring epilogue decodes only
        # skip-gathered blocks of each optional term and emits its
        # impact contribution in-kernel
        tp0 = index.terms.get(terms[0])
        if tp0 is None or tp0.df == 0:
            return np.zeros(0, np.uint32), np.zeros(0, np.int32)
        cand = _decode_blocks(tp0, 0, tp0.n_blocks, plan=plan, stats=stats,
                              use_skip=use_skip).astype(np.int64)
        if index.has_tf:
            scores = _decode_impact_stream(tp0, plan=plan, stats=stats)
        else:
            scores = np.full(cand.size, index.impact(terms[0]), np.int64)
        for t in dict.fromkeys(terms[1:]):
            tp = index.terms.get(t)
            if t == terms[0] or tp is None or tp.df == 0:
                continue
            if _expired(deadline, stats, "driver-term"):
                break
            imp = index.impact(t)
            w = min(_pow2(cand.size), probe_width)
            for s in range(0, cand.size, w):
                chunk = cand[s:s + w].astype(np.uint32)
                scores[s:s + len(chunk)] += _probe_pass(
                    tp, chunk, impact=imp, probe_width=w, plan=plan,
                    stats=stats, use_skip=use_skip,
                    weights=tp.impacts if index.has_tf else None
                ).astype(np.int64)
    else:
        raise ValueError(
            f"unknown topk mode {mode!r}; expected "
            "'or'/'maxscore'/'and'/'driver'")
    with _trace("topk-select", candidates=int(cand.size)):
        order = np.lexsort((cand, -scores))[:k]
        return cand[order].astype(np.uint32), scores[order].astype(np.int32)
