"""Boolean and top-k queries over the compressed inverted index.

Every query is a decode→intersect→score pipeline over the kernel stack —
posting lists are never materialized as whole docid arrays unless they ARE
the answer (a union's output):

* **Conjunctive (AND)** — terms ordered by document frequency; the rarest
  term is the *driver* and only its blocks inside the terms' common docid
  window are decoded (``stream`` epilogue). Its docids become the probe
  set, processed in fixed-width chunks: for every other term, each probe
  binary-searches the skip table (``first_doc``/``last_doc``) and only
  the blocks whose docid range actually contains a probe are gathered —
  per chunk that is ≤ ``probe_width`` blocks out of the whole list, and
  every other block is **never decoded**. The ``membership`` epilogue
  decodes the gathered blocks and emits the chunk's match bitmap
  in-kernel — the larger list's docids live and die in VMEM. This is
  small-vs-large galloping intersection with the gallop done on the skip
  table and the per-tile comparison vectorized on the VPU.
* **Disjunctive (OR)** — the union is the output, so each term's live
  blocks are decoded once (no probes to prune against) and merged.
* **Top-k** — disjunctive top-k (the default) scores term-at-a-time: the
  union pass already decodes every term's docids, so each term's
  quantized impact scatters straight onto them (TAAT — no re-decode).
  Conjunctive top-k (``mode="and"``) is degenerate under tf-free impacts
  (every candidate is in every term → one constant score, computed
  directly). Required-term top-k (``mode="driver"``) is the scored DAAT
  shape: candidates are ``terms[0]``'s postings, and each optional
  term's impact accumulates per candidate chunk through the fused
  ``bm25_accum``/``bm25_accum_rows`` epilogues with the same skip-table
  pruning as AND. Impacts are exact int32, so fused / unfused / sharded /
  dense / banded runs are bit-identical; ties break by ascending docid.

``plan=`` is forwarded to the dispatch layer, so queries inherit the
autotuned plan cache, both Pallas/jnp paths, dense and banded cores —
and, when a term's ``CompressedIntArray`` is block-sharded over a mesh
(``use_skip=False`` resident-index mode, see ``launch.serve.SearchEngine``),
the ``shard_map`` block-parallel path. :class:`QueryStats` counts decoded
vs skipped blocks, which is how tests prove pruning never decodes
non-overlapping blocks.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax.numpy as jnp

from repro.kernels.vbyte_decode import dispatch
from repro.kernels.vbyte_decode.ops import normalize_probe

from .builder import InvertedIndex, TermPostings

# maximum probe-set width per membership/scoring pass. Chunks are sized
# min(pow2(candidates), this), so a rare driver probes each term in ONE
# dispatch; the cap bounds the [tile, B, P] comparison footprint (and the
# jitted shape count — pow2 widths only).
DEFAULT_PROBE_WIDTH = 512


@dataclass
class QueryStats:
    """Decode accounting for one query (skip-table pruning evidence)."""

    blocks_decoded: int = 0
    blocks_skipped: int = 0
    ints_decoded: int = 0  # valid integers in decoded blocks
    decode_calls: int = 0
    per_term_decoded: dict = field(default_factory=dict)

    def count(self, term: int, decoded: int, skipped: int, ints: int):
        self.blocks_decoded += decoded
        self.blocks_skipped += skipped
        self.ints_decoded += ints
        self.decode_calls += 1
        self.per_term_decoded[term] = (
            self.per_term_decoded.get(term, 0) + decoded)


def _pow2(x: int) -> int:
    return 1 << max(x - 1, 0).bit_length()


def _overlap_blocks(tp: TermPostings, lo: int, hi: int) -> tuple[int, int]:
    """Block range ``[i0, i1)`` whose ``[first, last]`` intersects [lo, hi].

    ``first_doc``/``last_doc`` are sorted (postings are), so this is two
    binary searches — the skip-table gallop.
    """
    i0 = int(np.searchsorted(tp.last_doc, lo, side="left"))
    i1 = int(np.searchsorted(tp.first_doc, hi, side="right"))
    return i0, max(i1, i0)


def _decode_blocks(tp: TermPostings, i0: int, i1: int, *, plan, stats,
                   use_skip: bool) -> np.ndarray:
    """Decode blocks ``[i0, i1)`` of one term to sorted uint32 docids."""
    if not use_skip:
        i0, i1 = 0, tp.n_blocks
    if i1 <= i0:
        return np.zeros(0, np.uint32)
    if use_skip and (i0, i1) != (0, tp.n_blocks):
        sub = tp.arr.slice_blocks(i0, i1, pad_to=_pow2(i1 - i0))
    else:
        # whole list: decode the resident (possibly sharded) array in
        # place — slicing would just copy and re-upload every leaf
        sub = tp.arr
    if stats is not None:
        stats.count(tp.term, i1 - i0, tp.n_blocks - (i1 - i0), sub.n)
    return sub.decode(plan=plan)


def _route_probes(tp: TermPostings, chunk: np.ndarray):
    """Per-probe skip-table gallop: ``(ok mask, block id per hit probe)``.

    Each probe binary-searches ``first_doc``/``last_doc``; a probe that
    lands between two blocks' docid ranges is in no block at all and is
    settled without decoding anything. The hit probes each name the single
    block that can contain them.
    """
    pos = np.searchsorted(tp.first_doc, chunk, side="right") - 1
    ok = pos >= 0
    ok &= chunk <= tp.last_doc[np.maximum(pos, 0)]
    return ok, pos[ok]


def _probe_pass(tp: TermPostings, chunk: np.ndarray, *, impact: int,
                probe_width: int, plan, stats, use_skip: bool) -> np.ndarray:
    """One (term, candidate-chunk) pass: int32 [len(chunk)] per-candidate
    result — the membership bitmap (``impact=0``), or the bm25 impact
    contribution (``impact>0`` selects the scoring epilogues).

    With skip pruning, each hit probe gathers its one candidate block and
    the block-aligned ``*_rows`` epilogue compares probe t against tile t
    only (O(B) per probe). Without (resident/sharded arrays), the whole
    list decodes under the broadcast epilogue with the probe set in VMEM.
    """
    if use_skip:
        ok, rows = _route_probes(tp, chunk)
        if rows.size == 0:  # every probe galloped past: nothing decoded
            if stats is not None:
                stats.count(tp.term, 0, tp.n_blocks, 0)
            return np.zeros(len(chunk), np.int32)
        uniq = np.unique(rows)
        res = np.zeros(len(chunk), np.int32)
        if uniq.size * 2 > rows.size:
            # mostly-distinct blocks: one gathered row per probe, O(B)
            # compare against its own tile. Accounting reflects the real
            # gathered-row work (a block decoded once per probe in it).
            if stats is not None:
                stats.count(tp.term, int(rows.size),
                            tp.n_blocks - int(uniq.size),
                            int(np.asarray(tp.arr.counts)[rows].sum()))
            pad = _pow2(rows.size)
            sub = tp.arr.take_blocks(rows, pad_to=pad)
            probe = np.full((pad, 1), -1, np.int32)
            probe[: rows.size, 0] = chunk[ok].astype(np.int32)
            extras = {"probe": jnp.asarray(probe)}
            if impact:
                extras["impact"] = jnp.asarray([[impact]], jnp.int32)
            out = dispatch.decode(
                sub, epilogue=("bm25_accum_rows" if impact
                               else "membership_rows"),
                epilogue_operands=extras, plan=plan)
            res[ok] = np.asarray(out)[: rows.size, 0]
            return res
        # probes pile into few blocks (short lists): duplicating rows
        # would re-decode each block once per probe — gather each hit
        # block ONCE and run the broadcast epilogue over the chunk
        if stats is not None:
            stats.count(tp.term, int(uniq.size),
                        tp.n_blocks - int(uniq.size),
                        int(np.asarray(tp.arr.counts)[uniq].sum()))
        sub = tp.arr.take_blocks(uniq, pad_to=_pow2(uniq.size))
        w = _pow2(len(chunk))
        extras = {"probe": jnp.asarray(normalize_probe(chunk, w))}
        if impact:
            extras["impact"] = jnp.asarray([[impact]], jnp.int32)
        out = dispatch.decode(
            sub, epilogue=("bm25_accum" if impact else "membership"),
            epilogue_operands=extras, plan=plan)
        res[:] = np.asarray(out).sum(axis=0, dtype=np.int32)[: len(chunk)]
        return res
    sub = tp.arr
    if stats is not None:
        stats.count(tp.term, tp.n_blocks, 0, sub.n)
    extras = {"probe": jnp.asarray(normalize_probe(chunk, probe_width))}
    if impact:
        extras["impact"] = jnp.asarray([[impact]], jnp.int32)
    out = dispatch.decode(
        sub, epilogue=("bm25_accum" if impact else "membership"),
        epilogue_operands=extras, plan=plan)
    # a docid lives in exactly one block → summing blocks is exact int32
    return np.asarray(out).sum(axis=0, dtype=np.int32)[: len(chunk)]


def _term_postings(index: InvertedIndex, terms) -> list[TermPostings]:
    out = []
    for t in terms:
        tp = index.terms.get(t)
        out.append(tp if tp is not None
                   else TermPostings(term=t, arr=None,
                                     first_doc=np.zeros(0, np.uint32),
                                     last_doc=np.zeros(0, np.uint32), df=0))
    return out


def conjunctive(
    index: InvertedIndex,
    terms,
    *,
    plan="auto",
    probe_width: int = DEFAULT_PROBE_WIDTH,
    stats: QueryStats | None = None,
    use_skip: bool = True,
) -> np.ndarray:
    """AND query: sorted uint32 docids present in every term's postings."""
    if not terms:
        raise ValueError("conjunctive query needs ≥1 term")
    # dedup repeated terms: AND(t, t) = t, and each repeat would re-probe
    tps = sorted(_term_postings(index, dict.fromkeys(terms)),
                 key=lambda tp: tp.df)
    if tps[0].df == 0:
        return np.zeros(0, np.uint32)
    # common docid window: outside [lo, hi] no doc can be in all terms
    lo = max(int(tp.first_doc[0]) for tp in tps)
    hi = min(int(tp.last_doc[-1]) for tp in tps)
    if lo > hi:
        return np.zeros(0, np.uint32)
    driver, rest = tps[0], tps[1:]
    i0, i1 = _overlap_blocks(driver, lo, hi)
    cand = _decode_blocks(driver, i0, i1, plan=plan, stats=stats,
                          use_skip=use_skip)
    cand = cand[(cand >= lo) & (cand <= hi)]
    for tp in rest:
        if cand.size == 0:
            break
        w = min(_pow2(cand.size), probe_width)
        keep = np.zeros(cand.size, bool)
        for s in range(0, cand.size, w):
            chunk = cand[s:s + w]
            hit = _probe_pass(tp, chunk, impact=0, probe_width=w, plan=plan,
                              stats=stats, use_skip=use_skip)
            keep[s:s + len(chunk)] = hit.astype(bool)
        cand = cand[keep]
    return cand.astype(np.uint32)


def disjunctive(
    index: InvertedIndex,
    terms,
    *,
    plan="auto",
    stats: QueryStats | None = None,
    use_skip: bool = True,
) -> np.ndarray:
    """OR query: sorted uint32 docids present in any term's postings."""
    if not terms:
        raise ValueError("disjunctive query needs ≥1 term")
    parts = []
    for tp in _term_postings(index, dict.fromkeys(terms)):  # dedup repeats
        if tp.df == 0:
            continue
        parts.append(_decode_blocks(tp, 0, tp.n_blocks, plan=plan,
                                    stats=stats, use_skip=use_skip))
    if not parts:
        return np.zeros(0, np.uint32)
    return np.unique(np.concatenate(parts)).astype(np.uint32)


def topk(
    index: InvertedIndex,
    terms,
    k: int,
    *,
    mode: str = "or",
    plan="auto",
    probe_width: int = DEFAULT_PROBE_WIDTH,
    stats: QueryStats | None = None,
    use_skip: bool = True,
) -> tuple[np.ndarray, np.ndarray]:
    """Top-k scored query: ``(docids uint32 [≤k], scores int32 [≤k])``.

    Score(d) = Σ over query terms containing d of the term's quantized
    impact (``InvertedIndex.impact``). ``mode="or"`` (default) is
    term-at-a-time over the union decode. ``mode="and"`` restricts to the
    conjunctive candidates — whose scores are then the same constant by
    definition (every candidate is in every term), computed directly.
    ``mode="driver"`` is required-term top-k, the genuinely scored DAAT
    shape: docs containing ``terms[0]``, ranked by total impact over all
    query terms via the fused ``bm25_accum``/``bm25_accum_rows``
    epilogues (see module docstring). Results are ordered by (score desc,
    docid asc) — exact integer ties are deterministic.
    """
    if mode == "or":
        # TAAT: a disjunctive candidate set *contains* every term's
        # postings, so probing it against each term would re-decode what
        # the union pass already decoded. Instead each term decodes once
        # (that decode builds the union) and scatters its impact onto its
        # own — already decoded — docids. Exact int32, same result.
        parts = {}
        for t in dict.fromkeys(terms):
            tp = index.terms.get(t)
            if tp is None or tp.df == 0:
                continue
            parts[t] = _decode_blocks(tp, 0, tp.n_blocks, plan=plan,
                                      stats=stats, use_skip=use_skip)
        if not parts:
            return np.zeros(0, np.uint32), np.zeros(0, np.int32)
        cand = np.unique(np.concatenate(list(parts.values())))
        scores = np.zeros(cand.size, np.int32)
        for t, docs in parts.items():
            scores[np.searchsorted(cand, docs)] += index.impact(t)
    elif mode == "and":
        # every conjunctive candidate is by definition in every query
        # term, so the score is the same known constant for all of them —
        # no scoring decode needed (tf-free impacts; ties → first k docids)
        cand = conjunctive(index, terms, plan=plan, probe_width=probe_width,
                           stats=stats, use_skip=use_skip)
        total = sum(index.impact(t) for t in dict.fromkeys(terms))
        scores = np.full(cand.size, total, np.int32)
    elif mode == "driver":
        # required-term top-k, the real DAAT shape: candidates are the
        # docs containing terms[0], ranked by total impact over ALL query
        # terms — per chunk the fused bm25_accum(_rows) epilogue decodes
        # only skip-gathered blocks of each optional term and emits its
        # impact contribution in-kernel
        tp0 = index.terms.get(terms[0])
        if tp0 is None or tp0.df == 0:
            return np.zeros(0, np.uint32), np.zeros(0, np.int32)
        cand = _decode_blocks(tp0, 0, tp0.n_blocks, plan=plan, stats=stats,
                              use_skip=use_skip)
        scores = np.full(cand.size, index.impact(terms[0]), np.int32)
        for t in dict.fromkeys(terms[1:]):
            tp = index.terms.get(t)
            if t == terms[0] or tp is None or tp.df == 0:
                continue
            imp = index.impact(t)
            w = min(_pow2(cand.size), probe_width)
            for s in range(0, cand.size, w):
                chunk = cand[s:s + w]
                scores[s:s + len(chunk)] += _probe_pass(
                    tp, chunk, impact=imp, probe_width=w, plan=plan,
                    stats=stats, use_skip=use_skip)
    else:
        raise ValueError(
            f"unknown topk mode {mode!r}; expected 'or'/'and'/'driver'")
    order = np.lexsort((cand, -scores))[:k]
    return cand[order].astype(np.uint32), scores[order]
