"""Compressed inverted-index query engine (docs/index.md).

The paper's motivating workload: search engines serving d-gap-compressed
posting lists. ``builder`` turns per-term sorted docid lists into a
block-compressed index (VByte or Stream VByte, skip tables per block);
``query`` runs conjunctive (AND), disjunctive (OR) and top-k scored
queries as decode→intersect→score pipelines over the existing kernel
stack — block-level pruning via the skip tables, block-max dynamic
pruning (``topk(mode="maxscore")`` over per-posting quantized impacts),
intersection and scoring fused into the decode kernel's ``membership`` /
``bm25_accum`` / ``bm25_weighted`` epilogues.

``ingest`` + ``wal`` make the index *mutable*: a WAL-backed
:class:`~repro.index.ingest.LiveIndex` layers an uncompressed delta (+
tombstones) over the immutable segments, drains it through
``build_index(format="auto")`` in crash-safe background merges, and
recovers to the exact acknowledged state from any crash
(docs/ingestion.md).
"""
from .builder import (  # noqa: F401
    InvertedIndex,
    TermPostings,
    build_index,
    impact_value,
    quantize_impacts,
)
from .ingest import (  # noqa: F401
    CRASH_POINTS,
    CrashPoint,
    LiveIndex,
    Snapshot,
)
from .query import (  # noqa: F401
    QueryStats,
    conjunctive,
    disjunctive,
    topk,
)
from .wal import (  # noqa: F401
    WalWriter,
    open_wal,
    read_wal,
)
