"""Optimal block partitioning + per-list codec choice (shortest-path DP).

The uniform blocked layout cuts every posting list into fixed
``block_size``-integer blocks. That is the right *device* shape (fixed
strides, one jit trace), but the wrong *compression* shape for binpack:
one outlier d-gap forces a whole block to its bit width. This module keeps
the device shape and frees the logical partition instead: blocks of a
``CompressedIntArray`` may hold **any** count ``≤ block_size`` (``counts``
is already a first-class mask everywhere — decoders, epilogues, sharding),
so the builder can cut blocks at outlier boundaries.

Finding the cuts is a classic shortest path (Silvestri & Venturini's
VSEncoding framing): nodes are candidate boundaries (every ``grid``-th
position, plus ``n``), an edge ``i → j`` (``j - i ≤ block_size``) is one
block holding ``values[i:j]``, and its weight is

    encoded payload bits  +  per-block metadata overhead
                          +  λ · modeled decode ops
                             (repro.launch.cost_model.codec_decode_cost)

Edge weights are O(1) per edge: VByte / Stream VByte byte counts come from
prefix sums of the per-value lengths (the gap sequence is partition-
independent — a chunk's first gap is the global gap, since ``bases[b]``
carries the preceding absolute value), and binpack's ``L · max-width``
comes from precomputed grid-cell width maxima. One DP per format, then the
cheapest format wins the list — ties (within ``slack_bits``) break toward
the cheaper decoder. The emitted per-list arrays are ordinary
``CompressedIntArray``s, so query / MaxScore / skip tables / sharded
serving consume a mixed-codec index with no new code paths.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.compressed_array import (
    CompressedIntArray, block_checksums)
from repro.core.vbyte import binpack as bpk
from repro.core.vbyte import encode as venc
from repro.core.vbyte import stream_vbyte as svb
from repro.launch.cost_model import (
    CODEC_BLOCK_OPS, CODEC_INT_OPS, codec_decode_cost)

PARTITION_FORMATS = ("vbyte", "streamvbyte", "binpack")

# Per-block metadata the uniform layout also pays but the tight payload
# accounting ignores: counts (4 B) + bases (4 B) + skip table entry (8 B).
# Charging it in the DP stops degenerate one-gap blocks.
BLOCK_OVERHEAD_BITS = 128.0

# λ: modeled decode ops → equivalent bits. Small by design — payload bits
# dominate so the bits/int scoreboard can only improve over uniform blocks;
# the ops term mainly discourages partitions with many tiny blocks beyond
# what BLOCK_OVERHEAD_BITS already does.
DEFAULT_LAMBDA = 0.02


@dataclass(frozen=True)
class Partition:
    """One list's chosen block partition + codec."""

    bounds: np.ndarray  # int64 [n_chunks + 1], bounds[0]=0, bounds[-1]=n
    format: str
    payload_bits: float  # tight encoded bits of this partition (scoreboard)
    cost: float  # full DP objective (bits + overhead + λ·decode ops)

    @property
    def counts(self) -> np.ndarray:
        return np.diff(self.bounds).astype(np.int32)

    @property
    def n_chunks(self) -> int:
        return max(len(self.bounds) - 1, 0)


def _node_positions(n: int, grid: int) -> np.ndarray:
    pos = np.arange(0, n, grid, dtype=np.int64)
    return np.append(pos, n)


def _edge_bits(enc: np.ndarray, pos: np.ndarray, max_k: int,
               format: str) -> np.ndarray:
    """Payload bits of every candidate block: ``[n_nodes - 1, max_k]``.

    Entry ``(a, k-1)`` is the block spanning nodes ``a → a + k``;
    spans past the last node get ``+inf``.
    """
    m = pos.shape[0] - 1  # edges start at nodes 0..m-1
    bits = np.full((m, max_k), np.inf)
    if format == "binpack":
        # grid-cell width maxima, then a running max over k cells
        w = bpk.bit_widths(enc).astype(np.int64)
        cell_max = np.maximum.reduceat(w, pos[:-1])
        run = cell_max.astype(np.float64)
        for k in range(1, max_k + 1):
            if k > 1:
                run = np.maximum(run[:-1], cell_max[k - 1:])
            a = np.arange(run.shape[0])
            Lk = (pos[a + k] - pos[a]).astype(np.float64)
            bits[:run.shape[0], k - 1] = 8 * np.ceil(run * Lk / 8) + 8
        return bits
    if format == "vbyte":
        plen = np.concatenate([[0], np.cumsum(venc.vbyte_lengths(enc))])
    else:
        plen = np.concatenate([[0], np.cumsum(svb.svb_lengths(enc))])
    for k in range(1, max_k + 1):
        a = np.arange(max(m - k + 1, 0))
        i, j = pos[a], pos[a + k]
        b = 8.0 * (plen[j] - plen[i])
        if format == "streamvbyte":
            b = b + 8.0 * np.ceil((j - i) / 4.0)
        bits[: a.shape[0], k - 1] = b
    return bits


def _shortest_path(pos: np.ndarray, weights: np.ndarray,
                   max_k: int) -> tuple[float, np.ndarray]:
    """DAG shortest path over boundary nodes; returns (cost, bounds)."""
    m = pos.shape[0]
    dist = np.full(m, np.inf)
    prev = np.zeros(m, np.int64)
    dist[0] = 0.0
    for a in range(m - 1):
        d = dist[a]
        if not np.isfinite(d):
            continue
        hi = min(max_k, m - 1 - a)
        cand = d + weights[a, :hi]
        for k in range(1, hi + 1):
            j = a + k
            if cand[k - 1] < dist[j]:
                dist[j] = cand[k - 1]
                prev[j] = a
    cuts = [m - 1]
    while cuts[-1] != 0:
        cuts.append(int(prev[cuts[-1]]))
    return float(dist[m - 1]), pos[np.array(cuts[::-1], np.int64)]


def choose_partition(
    docids: np.ndarray,
    *,
    block_size: int = 128,
    grid: int = 8,
    formats=PARTITION_FORMATS,
    lam: float = DEFAULT_LAMBDA,
    slack_bits: float = 0.0,
    differential: bool = True,
) -> Partition:
    """Pick the cheapest (format, block partition) for one posting list.

    Runs one shortest-path DP per candidate format over boundary nodes
    every ``grid`` positions (edge span ≤ ``block_size``). The winner is
    the format with the fewest tight payload bits at its optimal
    partition; formats within ``slack_bits`` of the minimum break the tie
    by modeled decode cost. VByte's payload bits are partition-independent,
    so the winner never compresses worse than the uniform VByte baseline.
    """
    v = venc.validate_u32(docids).ravel()
    n = int(v.size)
    if n == 0:
        return Partition(bounds=np.array([0, 0], np.int64),
                         format=formats[0], payload_bits=0.0, cost=0.0)
    enc = venc.delta_encode(v) if differential else v
    pos = _node_positions(n, grid)
    max_k = max(block_size // grid, 1)
    best = None
    for fmt in formats:
        bits = _edge_bits(enc, pos, max_k, fmt)
        # λ·decode ops per edge (linear in span + per-block tile setup);
        # node spacing ≤ grid, so every k ≤ max_k span fits block_size
        decode_ops = np.zeros_like(bits)
        for k in range(1, max_k + 1):
            a = np.arange(max(pos.shape[0] - 1 - k + 1, 0))
            Lk = (pos[a + k] - pos[a]).astype(np.float64)
            decode_ops[a, k - 1] = (CODEC_INT_OPS[fmt] * Lk
                                    + CODEC_BLOCK_OPS[fmt])
        weights = bits + BLOCK_OVERHEAD_BITS + lam * decode_ops
        cost, bounds = _shortest_path(pos, weights, max_k)
        counts = np.diff(bounds)
        pay = _partition_payload_bits(enc, bounds, fmt)
        ops = codec_decode_cost(float(n), format=fmt,
                                n_blocks=float(counts.size)).flops
        cand = Partition(bounds=bounds, format=fmt,
                         payload_bits=pay, cost=cost)
        if best is None:
            best, best_ops = cand, ops
        elif pay < best.payload_bits - slack_bits or (
                abs(pay - best.payload_bits) <= slack_bits
                and ops < best_ops):
            best, best_ops = cand, ops
    return best


def _partition_payload_bits(enc: np.ndarray, bounds: np.ndarray,
                            format: str) -> float:
    """Tight encoded bits of ``enc`` under ``bounds`` — matches the
    encodings' ``payload_bytes`` accounting exactly."""
    counts = np.diff(bounds).astype(np.int64)
    if format == "vbyte":
        return 8.0 * float(venc.vbyte_lengths(enc).sum())
    if format == "streamvbyte":
        return 8.0 * (float(svb.svb_lengths(enc).sum())
                      + float((-(-counts // 4)).sum()))
    w = bpk.bit_widths(enc).astype(np.int64)
    total = 0.0
    for i, j in zip(bounds[:-1], bounds[1:]):
        wm = int(w[i:j].max(initial=0))
        total += 8.0 * (-(-(wm * (j - i)) // 8)) + 8.0
    return total


# ---------------------------------------------------------------------------
# partitioned emission
# ---------------------------------------------------------------------------
def encode_partitioned(
    values: np.ndarray,
    bounds: np.ndarray,
    *,
    format: str,
    block_size: int = 128,
    differential: bool = True,
    stride_multiple: int = 128,
    checksum: bool = False,
) -> CompressedIntArray:
    """Encode ``values`` with the given variable-count block partition.

    Emits an ordinary uniform-``block_size`` :class:`CompressedIntArray`
    whose block ``b`` holds ``values[bounds[b]:bounds[b+1]]``
    (``counts[b] = bounds[b+1] - bounds[b] ≤ block_size``) — the same
    device shapes as the uniform encoders, so every decoder, epilogue and
    sharding rule applies unchanged. With ``differential=True`` a chunk's
    first gap is the global gap and ``bases[b]`` carries the preceding
    absolute value, exactly the uniform convention.
    """
    v = venc.validate_u32(values).ravel()
    n = int(v.size)
    bounds = np.asarray(bounds, dtype=np.int64).ravel()
    counts = np.diff(bounds).astype(np.int32)
    if counts.size == 0:
        counts = np.zeros(1, np.int32)
        bounds = np.array([0, 0], np.int64)
    if int(counts.max(initial=0)) > block_size:
        raise ValueError(f"partition chunk exceeds block_size={block_size}")
    if int(counts.sum()) != n:
        raise ValueError("partition bounds do not cover the value range")
    nb = counts.shape[0]
    enc_values = venc.delta_encode(v) if differential else v
    bases = np.zeros(nb, np.uint32)
    if differential and n:
        starts = bounds[:-1]
        live = starts > 0
        bases[live] = v[starts[live] - 1].astype(np.uint32)

    if format == "binpack":
        grid = np.zeros((nb, block_size), np.uint64)
        mask = np.arange(block_size)[None, :] < counts[:, None]
        grid[mask] = enc_values
        widths = bpk.block_widths(grid, counts)
        data = bpk.pack_blocked_data(grid, widths,
                                     stride_multiple=stride_multiple,
                                     min_stride=None)
        enc = bpk.BinpackEncoding(
            widths=widths[:, None], data=data, counts=counts, bases=bases,
            n=n, block_size=block_size, differential=differential)
    elif format == "streamvbyte":
        if block_size % 4:
            raise ValueError(f"block_size={block_size} must be a multiple of 4")
        ctrl_stride = block_size // 4
        rows_c, rows_d = [], []
        for i, j in zip(bounds[:-1], bounds[1:]):
            c, d = svb.encode_stream(enc_values[i:j])
            rows_c.append(c)
            rows_d.append(d)
        stride = max((r.size for r in rows_d), default=1)
        stride = max(-(-max(stride, 1) // stride_multiple) * stride_multiple, 1)
        stride = min(stride, block_size * svb.MAX_BYTES_PER_INT)
        control = np.zeros((nb, ctrl_stride), np.uint8)
        data = np.zeros((nb, stride), np.uint8)
        for b, (rc, rd) in enumerate(zip(rows_c, rows_d)):
            control[b, : rc.size] = rc
            data[b, : rd.size] = rd
        enc = svb.StreamVByteEncoding(
            control=control, data=data, counts=counts, bases=bases, n=n,
            block_size=block_size, differential=differential)
    elif format == "vbyte":
        rows = [venc.encode_stream(enc_values[i:j])
                for i, j in zip(bounds[:-1], bounds[1:])]
        stride = max((r.size for r in rows), default=1)
        stride = max(-(-max(stride, 1) // stride_multiple) * stride_multiple, 1)
        stride = min(stride, block_size * venc.MAX_BYTES_PER_INT)
        payload = np.zeros((nb, stride), np.uint8)
        for b, r in enumerate(rows):
            payload[b, : r.size] = r
        enc = venc.BlockedEncoding(
            payload=payload, counts=counts, bases=bases, n=n,
            block_size=block_size, differential=differential)
    else:
        raise ValueError(f"unknown format {format!r}; expected one of "
                         f"{PARTITION_FORMATS}")

    arr = CompressedIntArray._from_encoding(enc, format)
    if checksum:
        vgrid = np.zeros((nb, block_size), np.uint64)
        vgrid[np.arange(block_size)[None, :] < counts[:, None]] = v
        from dataclasses import replace

        arr = replace(arr, checksums=block_checksums(vgrid, counts))
    return arr
