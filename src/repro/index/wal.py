"""Checksummed write-ahead log for the live index.

Every mutation of a :class:`repro.index.ingest.LiveIndex` is appended here
*before* it is applied in memory or acknowledged to the caller, so that a
crash at any instant replays to exactly the acknowledged state.

Record framing (little-endian)::

    [u32 payload_length][u32 crc32(payload)][payload]

where ``payload`` is one operation as canonical JSON (sorted keys, no
whitespace), e.g. ``{"doc":7,"op":"add","terms":{"3":2}}`` or
``{"doc":7,"op":"del"}``. JSON keeps the log self-describing and
debuggable (``python -m repro.index.wal <file>`` dumps it); framing + CRC
make corruption detection independent of the payload encoding.

Reader contract (the detect-or-recover split, docs/ingestion.md):

* **Torn tail → recover.** An incomplete header, a payload extending past
  EOF, or a CRC/JSON failure on the *final* record is the signature of a
  crash mid-append: only the one record that was never acknowledged can be
  affected (``append`` fsyncs before returning). The reader truncates to
  the last valid prefix and recovery proceeds — no acked write is lost.
* **Mid-log corruption → detect.** A CRC/framing failure on a record with
  durable data *after* it cannot be a torn append — it means acknowledged
  bytes changed under us. That raises :class:`WalError`; serving wrong
  history silently is never an option.

Known limitation (inherent to any log without an external length oracle):
corruption that truncates the file *exactly* at a record boundary, or a
bogus length field that happens to claim an extent past EOF, is
indistinguishable from a torn tail and recovers the shorter prefix. The
fault classes in ``robustness/faultgen.py`` exercise the distinguishable
cases; the manifest's ``merged_wal`` watermark bounds how much history a
boundary-truncation could ever silently drop to the unmerged suffix.
"""
from __future__ import annotations

import json
import os
import struct
import time
import zlib

from repro.obs import histogram_observe as _obs_histogram_observe
from repro.robustness.atomic_io import fsync_dir
from repro.robustness.validate import WalError

_HDR = struct.Struct("<II")

# Sanity bound on one record's payload. A real op is tens to hundreds of
# bytes; anything claiming more is framing corruption, not data.
MAX_RECORD_BYTES = 1 << 20


def wal_name(wal_id: int) -> str:
    return f"wal_{wal_id:08d}.log"


def wal_path(directory: str, wal_id: int) -> str:
    return os.path.join(directory, wal_name(wal_id))


def parse_wal_name(name: str) -> int | None:
    """``wal_00000003.log`` -> 3; None for anything else."""
    if not (name.startswith("wal_") and name.endswith(".log")):
        return None
    mid = name[4:-4]
    return int(mid) if mid.isdigit() else None


def encode_record(op: dict) -> bytes:
    payload = json.dumps(op, sort_keys=True, separators=(",", ":")).encode("utf-8")
    if len(payload) > MAX_RECORD_BYTES:
        raise ValueError(f"WAL record too large: {len(payload)} bytes")
    return _HDR.pack(len(payload), zlib.crc32(payload) & 0xFFFFFFFF) + payload


class WalWriter:
    """Append-only writer. ``append`` returns only after the record is
    written, flushed and (by default) fsynced — the durability point that
    lets the caller acknowledge the op."""

    def __init__(self, path: str, *, fsync: bool = True):
        self.path = path
        self.fsync = fsync
        self._f = open(path, "ab")

    def append(self, op: dict) -> int:
        """Durably append one op; returns the byte offset after it."""
        rec = encode_record(op)
        t0 = time.perf_counter()
        self._f.write(rec)
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        _obs_histogram_observe("wal_append_seconds",
                               time.perf_counter() - t0,
                               fsync=self.fsync)
        _obs_histogram_observe("wal_record_bytes", len(rec))
        return self._f.tell()

    def tell(self) -> int:
        return self._f.tell()

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            self._f.close()


def read_wal(path: str) -> tuple[list[dict], int]:
    """Parse a WAL; returns ``(ops, valid_bytes)``.

    ``valid_bytes`` is the length of the longest valid prefix. If it is
    shorter than the file, the remainder is a torn tail (recoverable by
    truncation). Mid-log corruption raises :class:`WalError` — see module
    docstring for the exact split.
    """
    with open(path, "rb") as f:
        data = f.read()
    n = len(data)
    ops: list[dict] = []
    off = 0
    while off < n:
        if n - off < _HDR.size:
            break  # torn: header sheared mid-write
        length, crc = _HDR.unpack_from(data, off)
        end = off + _HDR.size + length
        if length > MAX_RECORD_BYTES:
            if end > n:
                break  # claims past EOF: indistinguishable from torn tail
            raise WalError(
                f"WAL record at offset {off} claims {length} bytes "
                f"(> MAX_RECORD_BYTES) with data following — framing corrupt",
                format="wal")
        if end > n:
            break  # torn: payload sheared mid-write
        payload = data[off + _HDR.size:end]
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            if end >= n:
                break  # final record garbage -> torn tail
            raise WalError(
                f"WAL CRC mismatch at offset {off} with {n - end} durable "
                f"bytes following — acknowledged data corrupted",
                format="wal", block=len(ops))
        try:
            op = json.loads(payload)
        except ValueError:
            if end >= n:
                break
            raise WalError(
                f"WAL record at offset {off} is not valid JSON with data "
                f"following", format="wal", block=len(ops))
        if not isinstance(op, dict) or op.get("op") not in ("add", "del"):
            raise WalError(f"WAL record at offset {off} has unknown op "
                           f"{op!r}", format="wal", block=len(ops))
        ops.append(op)
        off = end
    return ops, off


def open_wal(path: str, *, fsync: bool = True) -> tuple[list[dict], "WalWriter"]:
    """Open a WAL for append: replay its valid prefix, truncate any torn
    tail, and return ``(ops, writer)`` positioned at the end."""
    ops: list[dict] = []
    if os.path.exists(path):
        ops, valid = read_wal(path)
        if valid != os.path.getsize(path):
            with open(path, "r+b") as f:
                f.truncate(valid)
                f.flush()
                if fsync:
                    os.fsync(f.fileno())
    else:
        # create durably so the file survives a crash right after rotation
        with open(path, "ab") as f:
            if fsync:
                os.fsync(f.fileno())
        if fsync:
            fsync_dir(os.path.dirname(os.path.abspath(path)))
    return ops, WalWriter(path, fsync=fsync)


def main(argv=None):  # pragma: no cover - debugging aid
    import sys
    for p in (argv or sys.argv[1:]):
        ops, valid = read_wal(p)
        torn = os.path.getsize(p) - valid
        print(f"{p}: {len(ops)} records, {valid} valid bytes"
              + (f", torn tail of {torn} bytes" if torn else ""))
        for i, op in enumerate(ops):
            print(f"  [{i}] {op}")


if __name__ == "__main__":  # pragma: no cover
    main()
