"""repro: Masked VByte for TPU — multi-pod JAX training/inference framework.

Reproduction + TPU adaptation of Plaisance, Kurz & Lemire, "Vectorized VByte
Decoding" (2015), with the decoder integrated as a first-class compressed
integer substrate for LM / GNN / RecSys workloads. See DESIGN.md.
"""

__version__ = "0.1.0"
