"""Layered neighbor sampler (GraphSAGE-style fanout) over a CSR adjacency.

Real sampler, vectorized numpy — used by the minibatch_lg shape (fanout
15-10 over a Reddit-scale graph). Produces fixed-shape padded subgraph
batches for the device step. The CSR itself can be built from (or stored as)
VByte-compressed neighbor lists (see repro.data.graph).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class CSRGraph:
    indptr: np.ndarray  # int64 [n_nodes + 1]
    indices: np.ndarray  # int32 [n_edges] — sorted within each row

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def n_edges(self) -> int:
        return len(self.indices)

    @classmethod
    def from_edges(cls, src: np.ndarray, dst: np.ndarray, n_nodes: int) -> "CSRGraph":
        """CSR over outgoing edges of `dst -> src` message direction:
        row u holds the neighbors whose features u aggregates."""
        order = np.lexsort((src, dst))
        s, d = src[order], dst[order]
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.add.at(indptr, d + 1, 1)
        np.cumsum(indptr, out=indptr)
        return cls(indptr=indptr, indices=s.astype(np.int32))

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)


class NeighborSampler:
    """Uniform with-replacement fanout sampling, fully vectorized."""

    def __init__(self, graph: CSRGraph, fanouts: tuple[int, ...]):
        self.g = graph
        self.fanouts = tuple(fanouts)

    def sample(self, seeds: np.ndarray, rng: np.random.Generator):
        """Returns a compacted, padded subgraph batch.

        Output dict: feats must be attached by the caller via `node_ids`.
          node_ids  [N_sub]   original node id per compact id
          edge_src  [E_max]   compact ids (padded)
          edge_dst  [E_max]
          edge_valid[E_max]
          seed_ids  [n_seeds] compact ids of the seeds (for the loss mask)
        """
        g = self.g
        frontier = seeds.astype(np.int64)
        all_src, all_dst = [], []
        nodes = [seeds.astype(np.int64)]
        for f in self.fanouts:
            deg = g.indptr[frontier + 1] - g.indptr[frontier]
            has = deg > 0
            r = rng.random((len(frontier), f))
            offs = np.floor(r * np.maximum(deg, 1)[:, None]).astype(np.int64)
            idx = g.indptr[frontier][:, None] + offs
            nbrs = g.indices[np.minimum(idx, g.n_edges - 1)]
            nbrs = np.where(has[:, None], nbrs, -1)
            src = nbrs.reshape(-1)
            dst = np.repeat(frontier, f)
            keep = src >= 0
            all_src.append(src[keep])
            all_dst.append(dst[keep])
            frontier = np.unique(src[keep])
            nodes.append(frontier)
        node_ids, inv_all = np.unique(np.concatenate(nodes), return_inverse=False), None
        src = np.concatenate(all_src) if all_src else np.zeros(0, np.int64)
        dst = np.concatenate(all_dst) if all_dst else np.zeros(0, np.int64)
        # compact relabeling
        lookup = {int(n): i for i, n in enumerate(node_ids)}
        c_src = np.fromiter((lookup[int(x)] for x in src), np.int32, len(src))
        c_dst = np.fromiter((lookup[int(x)] for x in dst), np.int32, len(dst))
        c_seed = np.fromiter((lookup[int(x)] for x in seeds), np.int32, len(seeds))
        # pad edges to the static capacity
        e_max = self.edge_capacity(len(seeds))
        E = len(c_src)
        pad = e_max - E
        if pad < 0:
            c_src, c_dst, E, pad = c_src[:e_max], c_dst[:e_max], e_max, 0
        return {
            "node_ids": node_ids.astype(np.int64),
            "edge_src": np.pad(c_src, (0, pad)),
            "edge_dst": np.pad(c_dst, (0, pad)),
            "edge_valid": np.arange(e_max) < E,
            "seed_ids": c_seed,
        }

    def edge_capacity(self, n_seeds: int) -> int:
        cap, frontier = 0, n_seeds
        for f in self.fanouts:
            cap += frontier * f
            frontier *= f
        return cap

    def node_capacity(self, n_seeds: int) -> int:
        cap, frontier = n_seeds, n_seeds
        for f in self.fanouts:
            frontier *= f
            cap += frontier
        return cap
