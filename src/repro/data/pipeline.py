"""Compressed input pipeline: VByte token shards decoded on device.

The LM data path stores token streams VByte-compressed (Lucene-vInt style).
One training step consumes one shard of B×(S+1) tokens; the shard's blocked
payload is shipped to device and decoded by the Masked-VByte decoder (Pallas
kernel on TPU) straight into the [B, S+1] token batch — decompression rides
the training step instead of the host CPU.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.compressed_array import CompressedIntArray


class CompressedTokenPipeline:
    def __init__(self, tokens: np.ndarray, batch: int, seq_len: int,
                 *, use_kernel: bool | None = None, plan="auto",
                 block_size: int = 128):
        self.tokens = np.asarray(tokens, dtype=np.uint64)
        self.batch = batch
        self.seq_len = seq_len
        self.step_tokens = batch * (seq_len + 1)
        self.n_steps = len(self.tokens) // self.step_tokens
        # dispatch plan (repro.kernels.vbyte_decode.dispatch); use_kernel is
        # the deprecated legacy boolean alias
        if use_kernel is not None:
            from repro.core.compressed_array import warn_use_kernel

            plan = warn_use_kernel(use_kernel)
        self.plan = plan
        self.block_size = block_size
        if self.n_steps == 0:
            raise ValueError("token stream shorter than one step")

    def shard(self, step: int) -> CompressedIntArray:
        lo = (step % self.n_steps) * self.step_tokens
        return CompressedIntArray.encode(
            self.tokens[lo : lo + self.step_tokens],
            block_size=self.block_size, differential=False,
        )

    def get_batch(self, step: int) -> dict:
        """Decode shard `step` on device -> {"tokens": [B, S+1] int32}."""
        arr = self.shard(step)
        flat = arr.decode(plan=self.plan)[: self.step_tokens]
        toks = jnp.asarray(flat.astype(np.int32)).reshape(self.batch, self.seq_len + 1)
        return {"tokens": toks}

    def compression_ratio(self) -> float:
        return self.shard(0).compression_ratio

    def __iter__(self):
        for s in range(self.n_steps):
            yield self.get_batch(s)
