"""Synthetic data generators.

``posting_lists`` mirrors the paper's ClueWeb09 experiment: sorted document
ids drawn from a 50M-document universe, grouped by list length 2^K..2^{K+1}-1
— shorter lists have larger gaps and compress worse (8..16 bits/int in the
paper). Everything else generates workload-shaped data for the assigned
architectures (token streams, recsys batches, graphs).
"""
from __future__ import annotations

import numpy as np

CLUEWEB_DOCS = 50_000_000  # ClueWeb09 Cat. B document count (paper §V)


def posting_list(rng: np.random.Generator, length: int,
                 universe: int = CLUEWEB_DOCS) -> np.ndarray:
    """One sorted docid list of `length` distinct ids (uniform over universe).

    Docids are uint32 (< 2^32, the decoders' contract). Short lists sample
    exactly without replacement; from 2^22 ids up (the paper's K ≥ 22
    length groups) ``rng.choice(replace=False)``'s O(universe) permutation
    is too expensive, so the list comes from sorted-gap sampling instead:
    draw ``length`` ids in the range shrunk by ``length``, sort, and add
    ``arange`` so every gap is ≥ 1 — O(length) memory, strictly
    increasing, uniform-ish over sorted distinct samples.
    """
    if universe > 1 << 32:
        raise ValueError("universe must fit in uint32 docids")
    if length >= universe:
        return np.arange(universe, dtype=np.uint32)
    if length < 1 << 22:
        ids = rng.choice(universe, size=length, replace=False)
        return np.sort(ids).astype(np.uint32)
    # sorted-gap path: y sorted in [0, universe-length] + arange ⇒ distinct
    y = np.sort(rng.integers(0, universe - length + 1, size=length,
                             dtype=np.int64))
    return (y + np.arange(length, dtype=np.int64)).astype(np.uint32)


def posting_list_group(rng: np.random.Generator, k: int, n_lists: int,
                       universe: int = CLUEWEB_DOCS) -> list[np.ndarray]:
    """Lists with lengths in [2^K, 2^{K+1}) — the paper's grouping."""
    lengths = rng.integers(1 << k, 1 << (k + 1), size=n_lists)
    return [posting_list(rng, int(l), universe) for l in lengths]


def posting_tfs(rng: np.random.Generator, length: int, *,
                zipf_a: float = 1.35, max_tf: int = 64) -> np.ndarray:
    """Per-posting term frequencies for one list: Zipf-skewed ints ≥ 1.

    Real within-document term counts are heavy-tailed — most postings have
    tf 1–3, a few documents repeat a term many times. That skew is what
    gives MaxScore something to prune: per-block ``max_impact`` varies, so
    whole blocks fall under the top-k threshold (``repro.index.query``).
    Clipped to ``max_tf`` (BM25 saturation makes larger tfs
    indistinguishable after quantization anyway).
    """
    z = rng.zipf(zipf_a, size=length)
    return np.minimum(z, max_tf).astype(np.int64)


def token_stream(rng: np.random.Generator, n_tokens: int, vocab: int,
                 zipf_a: float = 1.2) -> np.ndarray:
    """Zipf-distributed token ids (LM data-pipeline input)."""
    z = rng.zipf(zipf_a, size=n_tokens)
    return np.minimum(z - 1, vocab - 1).astype(np.uint64)


def sorted_id_bag(rng: np.random.Generator, n: int, vocab: int) -> np.ndarray:
    """Sorted multi-hot id bag (recsys history for embedding-bag / retrieval)."""
    return np.sort(rng.choice(vocab, size=min(n, vocab), replace=False)).astype(np.uint64)


def random_graph(rng: np.random.Generator, n_nodes: int, n_edges: int,
                 d_feat: int, n_classes: int, power: float = 0.8):
    """Random graph with skewed degrees; returns dict of numpy arrays."""
    # preferential-attachment-ish: destination prob ∝ rank^-power
    ranks = np.arange(1, n_nodes + 1, dtype=np.float64) ** -power
    p = ranks / ranks.sum()
    dst = rng.choice(n_nodes, size=n_edges, p=p)
    src = rng.integers(0, n_nodes, size=n_edges)
    feats = rng.standard_normal((n_nodes, d_feat), dtype=np.float32)
    labels = rng.integers(0, n_classes, size=n_nodes).astype(np.int32)
    return {
        "edge_src": src.astype(np.int32),
        "edge_dst": dst.astype(np.int32),
        "feats": feats,
        "labels": labels,
    }


def molecule_batch(rng: np.random.Generator, batch: int, nodes_per: int,
                   edges_per: int, d_feat: int, n_classes: int):
    """Batched small graphs (graph classification), block-diagonal edge index."""
    N, E = batch * nodes_per, batch * edges_per
    offs = np.repeat(np.arange(batch) * nodes_per, edges_per)
    src = rng.integers(0, nodes_per, size=E) + offs
    dst = rng.integers(0, nodes_per, size=E) + offs
    return {
        "feats": rng.standard_normal((N, d_feat), dtype=np.float32),
        "edge_src": src.astype(np.int32),
        "edge_dst": dst.astype(np.int32),
        "graph_ids": np.repeat(np.arange(batch), nodes_per).astype(np.int32),
        "labels": rng.integers(0, n_classes, size=batch).astype(np.int32),
        "n_graphs": batch,
    }


def recsys_batch(rng: np.random.Generator, kind: str, batch: int, seq_len: int,
                 n_items: int, *, n_mask: int = 0, n_negatives: int = 1024,
                 n_users: int = 0):
    """Workload-shaped recsys training batch (ids are 1-based; 0 = padding)."""
    hist = rng.integers(1, n_items, size=(batch, seq_len + 1)).astype(np.int32)
    if kind == "sasrec":
        return {"hist": hist,
                "neg": rng.integers(1, n_items, size=(batch, seq_len)).astype(np.int32)}
    if kind == "bert4rec":
        h = hist[:, :seq_len].copy()
        mask_pos = np.stack([rng.choice(seq_len, n_mask, replace=False)
                             for _ in range(batch)]).astype(np.int32)
        targets = np.take_along_axis(h, mask_pos, axis=1)
        np.put_along_axis(h, mask_pos, n_items + 1, axis=1)  # [MASK] row
        return {"hist": h, "mask_pos": mask_pos, "targets": targets,
                "negatives": rng.integers(1, n_items, size=n_negatives).astype(np.int32)}
    if kind == "bst":
        return {"hist": hist[:, :seq_len],
                "target": rng.integers(1, n_items, size=batch).astype(np.int32),
                "label": (rng.random(batch) < 0.5).astype(np.int32)}
    if kind == "two_tower":
        return {"user_id": rng.integers(1, max(n_users, 2), size=batch).astype(np.int32),
                "hist": hist[:, :seq_len],
                "item_id": rng.integers(1, n_items, size=batch).astype(np.int32)}
    raise ValueError(kind)
