"""VByte-compressed adjacency (the paper's posting lists = neighbor lists).

Each CSR row (sorted neighbor ids) is delta-encoded independently — first gap
is the absolute id — and the concatenated gap stream is VByte-blocked. The
device decodes gaps with the Masked-VByte decoder and reconstructs ids with a
vectorized per-list prefix sum (repro.nn.gnn.decode_compressed_edges).
"""
from __future__ import annotations

import numpy as np

from repro.core.compressed_array import CompressedIntArray
from repro.core.vbyte.encode import encode_blocked

from .sampler import CSRGraph


def adjacency_gaps(csr: CSRGraph) -> np.ndarray:
    """Per-row delta stream: gaps[e] = indices[e] - indices[e-1], absolute at row starts."""
    idx = csr.indices.astype(np.int64)
    gaps = np.empty_like(idx)
    gaps[1:] = idx[1:] - idx[:-1]
    gaps[0] = idx[0] if len(idx) else 0
    starts = csr.indptr[:-1]
    starts = starts[starts < len(idx)]
    gaps[starts] = idx[starts]
    if np.any(gaps < 0):
        raise ValueError("CSR rows must be sorted for delta encoding")
    return gaps.astype(np.uint64)


def compress_adjacency(csr: CSRGraph, *, block_size: int = 128) -> dict:
    """Device-ready compressed adjacency batch fields.

    ``gaps`` is a ``CompressedIntArray`` (a pytree — it rides inside the
    batch dict straight through ``jit``): the blocked VByte gap stream with
    ``differential=True`` against precomputed running-sum ``bases``
    [n_blocks] — the gap running sum entering each block, which makes the
    global cumsum a block-local differential decode (the paper's
    inverted-index skip pointers, applied to adjacency). ``row_gap_bases``
    [n_nodes] — the running sum entering each list — makes absolute-id
    reconstruction shard-local. ~4 B each per block/row.
    """
    gaps = adjacency_gaps(csr)
    enc = encode_blocked(gaps, block_size=block_size, differential=False)
    csum = np.concatenate([[0], np.cumsum(gaps, dtype=np.uint64)]).astype(np.uint64)
    block_starts = np.arange(enc.n_blocks) * block_size
    block_starts = np.minimum(block_starts, len(gaps))
    row_starts = np.minimum(csr.indptr[:-1], len(gaps))
    gaps_arr = CompressedIntArray.from_operands(
        {"payload": enc.payload, "counts": enc.counts,
         "bases": csum[block_starts].astype(np.uint32)},  # running-sum bases
        format="vbyte", block_size=block_size, differential=True,
        n=len(gaps))
    return {
        "gaps": gaps_arr,
        "row_gap_bases": csum[row_starts].astype(np.uint32),
        "row_offsets": csr.indptr.astype(np.int32),
        "edge_valid": np.ones(csr.n_edges, bool),
        "_bits_per_edge": enc.bits_per_int
        + 32.0 * (enc.n_blocks + csr.n_nodes) / max(csr.n_edges, 1),
    }
