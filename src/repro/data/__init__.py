from . import graph, pipeline, sampler, synthetic  # noqa: F401
