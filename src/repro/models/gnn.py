"""GIN (Xu et al., arXiv:1810.00826) — node & graph classification.

Supports raw edge-index batches and VByte-compressed adjacency (the paper's
posting-list format; decoded on device — DESIGN.md §3). Full-graph, sampled
mini-batch (neighbor sampler in repro.data.sampler) and batched-small-graph
regimes share this one implementation.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.api import constrain
from repro.nn import layers as nn
from repro.nn.gnn import MESH_ALL, decode_compressed_edges, gin_layer, gin_layer_init


@dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int = 5
    d_hidden: int = 64
    d_feat: int = 1433
    n_classes: int = 7
    task: str = "node"  # "node" | "graph"
    compressed_adjacency: bool = False  # batch carries a VByte gap stream
    decode_plan: str = "auto"  # dispatch plan: auto|kernel|jnp|fused|unfused
    agg_dtype: str = "f32"  # "bf16" halves aggregation collectives (§Perf)
    feats_dtype: str = "f32"  # "bf16" halves feature all-gathers (§Perf)
    extras: dict[str, Any] = field(default_factory=dict)

    def param_count(self) -> int:
        d, h = self.d_feat, self.d_hidden
        per = lambda din: din * h + h + h * h + h + 1
        return per(d) + (self.n_layers - 1) * per(h) + h * self.n_classes + self.n_classes


def init_params(key, cfg: GNNConfig):
    keys = jax.random.split(key, cfg.n_layers + 1)
    layers = {
        f"gin_{i}": gin_layer_init(keys[i], cfg.d_feat if i == 0 else cfg.d_hidden,
                                   cfg.d_hidden)
        for i in range(cfg.n_layers)
    }
    head = {
        **nn.dense_init(keys[-1], cfg.d_hidden, cfg.n_classes),
        "b": jnp.zeros((cfg.n_classes,), jnp.float32),
    }
    return {"layers": layers, "head": head}


def _edges_from_batch(batch, cfg: GNNConfig):
    if cfg.compressed_adjacency:
        n_edges = batch["edge_valid"].shape[0]  # static edge capacity
        src, dst = decode_compressed_edges(
            batch["gaps"],  # CompressedIntArray: a pytree leaf group of the batch
            batch["row_offsets"], n_edges,
            row_gap_bases=batch.get("row_gap_bases"),
            plan=cfg.decode_plan,
        )
        # decode_compressed_edges returns (neighbor=src-of-message, list-owner=dst)
        return src, dst, batch.get("edge_valid")
    return batch["edge_src"], batch["edge_dst"], batch.get("edge_valid")


def forward(params, batch, cfg: GNNConfig, *, dtype=nn.DEFAULT_COMPUTE_DTYPE):
    """Returns per-node logits [N, C] (node task) or per-graph [G, C]."""
    import jax.numpy as jnp

    agg_dtype = jnp.bfloat16 if cfg.agg_dtype == "bf16" else jnp.float32
    h = batch["feats"].astype(dtype)
    h = constrain(h, MESH_ALL, None)
    n_nodes = h.shape[0]
    src, dst, edge_valid = _edges_from_batch(batch, cfg)
    for i in range(cfg.n_layers):
        h = gin_layer(params["layers"][f"gin_{i}"], h, src, dst,
                      n_nodes=n_nodes, edge_valid=edge_valid, dtype=dtype,
                      agg_dtype=agg_dtype)
        h = constrain(h, MESH_ALL, None)
    if cfg.task == "graph":
        # sum-pool readout per graph (n_graphs = static label count)
        h = jax.ops.segment_sum(h, batch["graph_ids"],
                                num_segments=batch["labels"].shape[0])
    logits = h @ params["head"]["w"].astype(dtype) + params["head"]["b"].astype(dtype)
    return logits.astype(jnp.float32)


def loss_fn(params, batch, cfg: GNNConfig, *, dtype=nn.DEFAULT_COMPUTE_DTYPE):
    logits = forward(params, batch, cfg, dtype=dtype)
    labels = batch["labels"]
    mask = batch.get("label_mask")
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    if mask is not None:
        nll = jnp.where(mask, nll, 0.0)
        denom = jnp.maximum(mask.sum(), 1)
    else:
        denom = nll.shape[0]
    loss = nll.sum() / denom
    acc = jnp.argmax(logits, -1) == labels
    if mask is not None:
        acc = jnp.where(mask, acc, False).sum() / denom
    else:
        acc = acc.mean()
    return loss, {"accuracy": acc}
