"""RecSys family: SASRec, BERT4Rec, BST, two-tower retrieval.

Huge row-sharded embedding tables + sequence encoders + small MLPs
(taxonomy §B.6). Id streams (user histories, retrieval candidate lists) are
VByte posting lists decoded on device; the retrieval_cand serve step decodes
a 1M-candidate compressed list *inside* the jitted graph.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.api import constrain
from repro.nn import attention as attn
from repro.nn import layers as nn
from repro.nn.layers import accum_dtype
from repro.nn.embedding_bag import bag_from_padded

DP = ("pod", "data")
TP = "model"


@dataclass(frozen=True)
class RecSysConfig:
    name: str
    kind: str  # "sasrec" | "bert4rec" | "bst" | "two_tower"
    n_items: int
    embed_dim: int
    seq_len: int
    n_blocks: int = 2
    n_heads: int = 1
    mlp_dims: tuple[int, ...] = ()
    n_users: int = 0  # two-tower
    id_dim: int = 128  # two-tower id embedding width
    n_mask: int = 0  # bert4rec masked positions per sequence
    n_negatives: int = 1024  # sampled-softmax shared negatives
    serve_candidates: int = 4096
    # serving-time embedding-table layout: "row" (baseline: row-sharded, every
    # gather pays an all-reduce) | "replicated" (bf16 tables fit at inference;
    # gathers + top-k go shard-local — §Perf retrieval hillclimb) | "column"
    serve_table_mode: str = "row"
    extras: dict[str, Any] = field(default_factory=dict)

    @property
    def vocab_rows(self) -> int:
        # +2: padding id 0 is reserved, bert4rec adds a [MASK] row at the end;
        # rounded to a multiple of 512 so row-sharding divides every mesh
        return -(-(self.n_items + 2) // 512) * 512

    @property
    def user_rows(self) -> int:
        return -(-(self.n_users + 2) // 512) * 512

    def param_count(self) -> int:
        d = self.embed_dim
        if self.kind == "two_tower":
            n = self.user_rows * self.id_dim + self.vocab_rows * self.id_dim
            dims_u = (self.id_dim * 2,) + self.mlp_dims
            dims_i = (self.id_dim,) + self.mlp_dims
            n += sum(a * b + b for a, b in zip(dims_u[:-1], dims_u[1:]))
            n += sum(a * b + b for a, b in zip(dims_i[:-1], dims_i[1:]))
            return n
        n = self.vocab_rows * d + (self.seq_len + 1) * d
        per_block = 4 * d * d + 2 * (d * d + d) + 4 * d  # attn + pw-ffn + norms
        n += self.n_blocks * per_block
        if self.kind == "bst":
            dims = ((self.seq_len + 1) * d,) + self.mlp_dims + (1,)
            n += sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
        return n

    def dense_flops_per_example(self) -> int:
        """Approx fwd FLOPs per scored example (roofline MODEL_FLOPS basis)."""
        d = self.embed_dim
        if self.kind == "two_tower":
            dims_u = (self.id_dim * 2,) + self.mlp_dims
            dims_i = (self.id_dim,) + self.mlp_dims
            mm = sum(a * b for a, b in zip(dims_u[:-1], dims_u[1:]))
            mm += sum(a * b for a, b in zip(dims_i[:-1], dims_i[1:]))
            return 2 * mm
        L = self.seq_len + (1 if self.kind == "bst" else 0)
        per_block = 2 * L * (6 * d * d) + 2 * 2 * L * L * d  # proj+ffn, qk+pv
        n = self.n_blocks * per_block
        if self.kind == "bst":
            dims = (L * d,) + self.mlp_dims + (1,)
            n += 2 * sum(a * b for a, b in zip(dims[:-1], dims[1:]))
        return n


# ----------------------------------------------------------------------------
# shared sequence encoder (pre-LN transformer blocks over item embeddings)
# ----------------------------------------------------------------------------
def _block_init(key, d: int):
    kq, kk, kv, ko, k1, k2 = jax.random.split(key, 6)
    return {
        "ln1": nn.layernorm_init(d),
        "attn": {
            "wq": nn.dense_init(kq, d, d),
            "wk": nn.dense_init(kk, d, d),
            "wv": nn.dense_init(kv, d, d),
            "wo": nn.dense_init(ko, d, d),
        },
        "ln2": nn.layernorm_init(d),
        "ffn": {
            "w1": {**nn.dense_init(k1, d, d), "b": jnp.zeros((d,), jnp.float32)},
            "w2": {**nn.dense_init(k2, d, d), "b": jnp.zeros((d,), jnp.float32)},
        },
    }


def _encode_seq(blocks, x, cfg: RecSysConfig, *, causal: bool, dtype):
    B, L, d = x.shape
    H = cfg.n_heads
    dh = d // H
    qc = kc = max(16, 1 << (L - 1).bit_length())  # whole seq in one chunk
    for i in range(cfg.n_blocks):
        blk = blocks[f"block_{i}"]
        h = nn.layernorm(blk["ln1"], x, dtype=dtype)
        q = nn.dense(blk["attn"]["wq"], h, dtype=dtype).reshape(B, L, H, dh)
        k = nn.dense(blk["attn"]["wk"], h, dtype=dtype).reshape(B, L, H, dh)
        v = nn.dense(blk["attn"]["wv"], h, dtype=dtype).reshape(B, L, H, dh)
        o = attn.flash_attention(q, k, v, causal=causal, q_chunk=min(qc, L),
                                 kv_chunk=min(kc, L), dtype=dtype)
        x = x + nn.dense(blk["attn"]["wo"], o.reshape(B, L, d), dtype=dtype)
        h = nn.layernorm(blk["ln2"], x, dtype=dtype)
        f = blk["ffn"]
        h = jax.nn.relu(h @ f["w1"]["w"].astype(dtype) + f["w1"]["b"].astype(dtype))
        h = h @ f["w2"]["w"].astype(dtype) + f["w2"]["b"].astype(dtype)
        x = x + h
        x = constrain(x, DP, None, None)
    return x


def init_params(key, cfg: RecSysConfig):
    ki, kp, kb, ku, km, kt = jax.random.split(key, 6)
    d = cfg.embed_dim
    if cfg.kind == "two_tower":  # no sequence encoder: bag + towers only
        return {
            "user_emb": nn.embedding_init(ku, cfg.user_rows, cfg.id_dim),
            "item_id_emb": nn.embedding_init(km, cfg.vocab_rows, cfg.id_dim),
            "user_mlp": nn.mlp_init(ku, (cfg.id_dim * 2,) + cfg.mlp_dims),
            "item_mlp": nn.mlp_init(km, (cfg.id_dim,) + cfg.mlp_dims),
        }
    params = {
        "item_emb": nn.embedding_init(ki, cfg.vocab_rows, d),
        "pos_emb": nn.embedding_init(kp, cfg.seq_len + 1, d),
        "blocks": {
            f"block_{i}": _block_init(k, d)
            for i, k in enumerate(jax.random.split(kb, cfg.n_blocks))
        },
        "final_ln": nn.layernorm_init(d),
    }
    if cfg.kind == "bst":
        params["mlp"] = nn.mlp_init(kt, ((cfg.seq_len + 1) * d,) + cfg.mlp_dims + (1,))
    return params


def _seq_repr(params, hist, cfg: RecSysConfig, *, causal: bool, dtype):
    """hist [B, L] -> hidden [B, L, d] with positional embeddings."""
    B, L = hist.shape
    x = nn.embedding_lookup(params["item_emb"], hist, dtype=dtype)
    x = x + nn.embedding_lookup(params["pos_emb"],
                                jnp.arange(L, dtype=jnp.int32)[None], dtype=dtype)
    x = constrain(x, DP, None, None)
    x = _encode_seq(params["blocks"], x, cfg, causal=causal, dtype=dtype)
    return nn.layernorm(params["final_ln"], x, dtype=dtype)


def _item_scores(params, h, item_ids, dtype):
    """h [..., d] · emb[item_ids] [..., C, d] -> [..., C] (dot-product head)."""
    vecs = nn.embedding_lookup(params["item_emb"], item_ids, dtype=dtype)
    return jnp.einsum("...d,...cd->...c", h, vecs, preferred_element_type=accum_dtype())


# ----------------------------------------------------------------------------
# losses (train_step targets)
# ----------------------------------------------------------------------------
def loss_fn(params, batch, cfg: RecSysConfig, *, dtype=nn.DEFAULT_COMPUTE_DTYPE):
    if cfg.kind == "sasrec":
        return _sasrec_loss(params, batch, cfg, dtype)
    if cfg.kind == "bert4rec":
        return _bert4rec_loss(params, batch, cfg, dtype)
    if cfg.kind == "bst":
        return _bst_loss(params, batch, cfg, dtype)
    if cfg.kind == "two_tower":
        return _two_tower_loss(params, batch, cfg, dtype)
    raise ValueError(cfg.kind)


def _sasrec_loss(params, batch, cfg, dtype):
    """Next-item binary CE with one sampled negative per step (SASRec §3.5)."""
    hist = batch["hist"]  # [B, L+1]
    neg = batch["neg"]  # [B, L]
    inputs, pos = hist[:, :-1], hist[:, 1:]
    h = _seq_repr(params, inputs, cfg, causal=True, dtype=dtype)
    pos_s = _item_scores(params, h, pos[..., None], dtype)[..., 0]
    neg_s = _item_scores(params, h, neg[..., None], dtype)[..., 0]
    valid = pos != 0
    lp = jax.nn.log_sigmoid(pos_s)
    ln = jax.nn.log_sigmoid(-neg_s)
    loss = -jnp.where(valid, lp + ln, 0.0).sum() / jnp.maximum(valid.sum(), 1)
    auc_proxy = jnp.where(valid, (pos_s > neg_s), False).sum() / jnp.maximum(valid.sum(), 1)
    return loss, {"pairwise_acc": auc_proxy}


def _bert4rec_loss(params, batch, cfg, dtype):
    """Masked-item sampled softmax with shared negatives (+ target in slot 0)."""
    hist = batch["hist"]  # [B, L] with [MASK]=n_items+1 at masked slots
    mask_pos = batch["mask_pos"]  # [B, M]
    targets = batch["targets"]  # [B, M]
    negatives = batch["negatives"]  # [Nneg]
    h = _seq_repr(params, hist, cfg, causal=False, dtype=dtype)
    hm = jnp.take_along_axis(h, mask_pos[..., None], axis=1)  # [B, M, d]
    pos_s = _item_scores(params, hm, targets[..., None], dtype)[..., 0]  # [B, M]
    neg_v = nn.embedding_lookup(params["item_emb"], negatives, dtype=dtype)  # [N, d]
    neg_s = jnp.einsum("bmd,nd->bmn", hm, neg_v, preferred_element_type=accum_dtype())
    logits = jnp.concatenate([pos_s[..., None], neg_s], axis=-1)  # [B, M, 1+N]
    valid = targets != 0
    nll = jax.nn.logsumexp(logits, -1) - logits[..., 0]
    loss = jnp.where(valid, nll, 0.0).sum() / jnp.maximum(valid.sum(), 1)
    hit = logits[..., 0] >= logits.max(-1)
    return loss, {"hit_at_1": jnp.where(valid, hit, False).sum() / jnp.maximum(valid.sum(), 1)}


def _bst_loss(params, batch, cfg, dtype):
    """CTR binary cross-entropy (BST: transformer over history + target item)."""
    logit = bst_forward(params, batch["hist"], batch["target"], cfg, dtype=dtype)
    label = batch["label"].astype(jnp.float32)
    loss = -jnp.mean(label * jax.nn.log_sigmoid(logit)
                     + (1 - label) * jax.nn.log_sigmoid(-logit))
    acc = jnp.mean((logit > 0) == (label > 0.5))
    return loss, {"accuracy": acc}


def bst_forward(params, hist, target, cfg: RecSysConfig, *, dtype=nn.DEFAULT_COMPUTE_DTYPE):
    seq = jnp.concatenate([hist, target[:, None]], axis=1)  # [B, L+1]
    h = _seq_repr(params, seq, cfg, causal=False, dtype=dtype)
    B = h.shape[0]
    flat = h.reshape(B, -1)
    return nn.mlp(params["mlp"], flat, act=jax.nn.leaky_relu, dtype=dtype)[:, 0].astype(jnp.float32)


def user_tower(params, user_id, hist, cfg: RecSysConfig, *, dtype=nn.DEFAULT_COMPUTE_DTYPE):
    u = nn.embedding_lookup(params["user_emb"], user_id, dtype=dtype)  # [B, id_dim]
    bag = bag_from_padded(params["item_id_emb"]["emb"], hist, mode="mean", dtype=dtype)
    x = jnp.concatenate([u, bag], axis=-1)
    v = nn.mlp(params["user_mlp"], x, final_act=False, dtype=dtype)
    return v / jnp.maximum(jnp.linalg.norm(v.astype(jnp.float32), axis=-1, keepdims=True), 1e-6).astype(dtype)


def user_tower_compressed(params, user_id, hists,
                          cfg: RecSysConfig, *,
                          plan="auto", dtype=nn.DEFAULT_COMPUTE_DTYPE):
    """User tower over compressed histories: fused one-pass embedding bag.

    ``hists`` is the ragged encoding of the batch's history bags —
    ``CompressedIntArray.encode_ragged(histories, block_size=seq_len)`` —
    one block per user (the array is a pytree; pass it straight through
    jit). The mean-bag is the decode kernel's ``bag_sum`` epilogue: history
    ids never round-trip through HBM between decode and gather (they do in
    ``user_tower``'s padded path). Matches ``user_tower`` exactly when the
    padded histories hold the same ids (pad id 0 excluded) and the bags
    were encoded with ``block_size == seq_len``.
    """
    from repro.nn.embedding_bag import embedding_bag_compressed

    u = nn.embedding_lookup(params["user_emb"], user_id, dtype=dtype)  # [B, id_dim]
    bag = embedding_bag_compressed(
        params["item_id_emb"]["emb"], hists, mode="mean", plan=plan,
        dtype=dtype)[: u.shape[0]]
    x = jnp.concatenate([u, bag.astype(dtype)], axis=-1)
    v = nn.mlp(params["user_mlp"], x, final_act=False, dtype=dtype)
    return v / jnp.maximum(jnp.linalg.norm(v.astype(jnp.float32), axis=-1, keepdims=True), 1e-6).astype(dtype)


def item_tower(params, item_ids, cfg: RecSysConfig, *, dtype=nn.DEFAULT_COMPUTE_DTYPE):
    x = nn.embedding_lookup(params["item_id_emb"], item_ids, dtype=dtype)
    v = nn.mlp(params["item_mlp"], x, final_act=False, dtype=dtype)
    return v / jnp.maximum(jnp.linalg.norm(v.astype(jnp.float32), axis=-1, keepdims=True), 1e-6).astype(dtype)


def _two_tower_loss(params, batch, cfg, dtype):
    """In-batch sampled softmax (Yi et al., RecSys'19), temperature-scaled."""
    u = user_tower(params, batch["user_id"], batch["hist"], cfg, dtype=dtype)
    i = item_tower(params, batch["item_id"], cfg, dtype=dtype)
    u = constrain(u, DP, None)
    i = constrain(i, DP, None)
    temp = 0.05
    logits = (u @ i.T).astype(jnp.float32) / temp  # [B, B]
    labels = jnp.arange(u.shape[0])
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))
    acc = jnp.mean(jnp.argmax(logits, -1) == labels)
    return loss, {"in_batch_top1": acc}


# ----------------------------------------------------------------------------
# serve steps
# ----------------------------------------------------------------------------
def serve_scores(params, batch, cfg: RecSysConfig, *, dtype=nn.DEFAULT_COMPUTE_DTYPE):
    """Online/bulk scoring against a candidate set (serve_p99 / serve_bulk)."""
    if cfg.kind == "bst":
        return bst_forward(params, batch["hist"], batch["target"], cfg, dtype=dtype)
    if cfg.kind == "two_tower":
        u = user_tower(params, batch["user_id"], batch["hist"], cfg, dtype=dtype)
        i = item_tower(params, batch["cands"], cfg, dtype=dtype)  # [C]
        return (u @ i.T).astype(jnp.float32)
    causal = cfg.kind == "sasrec"
    h = _seq_repr(params, batch["hist"], cfg, causal=causal, dtype=dtype)
    return _item_scores(params, h[:, -1], batch["cands"], dtype)  # [B, C]


def _cand_array(batch):
    """The compressed candidate list from a serve batch.

    The pytree-native form is ``batch["cands"]``: the ``CompressedIntArray``
    itself (either format — its static aux data carries format/block_size/
    differential). The legacy unpacked ``cand_payload``/``cand_control``…
    keys are still accepted with a ``DeprecationWarning``.
    """
    from repro.core.compressed_array import CompressedIntArray

    if "cands" in batch:
        return batch["cands"]
    import warnings

    warnings.warn(
        "cand_payload/cand_control/... batch keys are deprecated; pass the "
        "CompressedIntArray itself as batch['cands']", DeprecationWarning,
        stacklevel=3)
    import numpy as np

    def legacy_n(counts):
        try:  # the real count when concrete; capacity when traced (n is
            return int(np.asarray(counts).sum())  # unused by the serve path)
        except TypeError:
            return counts.shape[0] * 128
    if "cand_control" in batch:
        return CompressedIntArray.from_operands(
            {"control": batch["cand_control"], "data": batch["cand_data"],
             "counts": batch["cand_counts"], "bases": batch["cand_bases"]},
            format="streamvbyte", block_size=128, differential=True,
            n=legacy_n(batch["cand_counts"]))
    return CompressedIntArray.from_operands(
        {"payload": batch["cand_payload"], "counts": batch["cand_counts"],
         "bases": batch["cand_bases"]},
        format="vbyte", block_size=128, differential=True,
        n=legacy_n(batch["cand_counts"]))


def retrieval_scores_compressed(params, batch, cfg: RecSysConfig, *, top_k: int = 100,
                                plan="auto", use_kernel: bool | None = None,
                                dtype=nn.DEFAULT_COMPUTE_DTYPE):
    """retrieval_cand: score 1 query against a compressed candidate list.

    The sorted candidate id list (delta-coded, VByte or Stream VByte —
    ``batch["cands"]``, a ``CompressedIntArray``) is decoded *inside* the
    serving graph. For the dot-product heads (sasrec/bert4rec) the scoring
    itself is the decode kernel's ``dot_score`` epilogue: ids gather item
    vectors and dot against the query in VMEM, so the [C, d]
    candidate-vector matrix never materializes in HBM — only ids and scores
    come out. Tower/ranker heads (two_tower, bst) decode-then-score. (The
    resident-corpus serving loop lives one level up, in
    ``repro.launch.serve.ServingEngine``, which serves the two-tower path
    through the same fused ``dot_score`` epilogue against a precomputed
    item-vector table.)

    ``plan`` is the dispatch plan; ``use_kernel`` the deprecated legacy
    boolean alias. For VByte candidates off-TPU, ``"auto"`` resolves to the
    gather-lowered ``"ref"`` decoder for every kind: the scatter-based
    masked path emits a cross-shard scatter-add (an all-reduce of the
    [n_cand] id array) under GSPMD, while the searchsorted/gather lowering
    stays block-local (§Perf retrieval iteration 2).
    """
    from repro.kernels.vbyte_decode import dispatch

    cands_arr = _cand_array(batch)
    fmt = cands_arr.format
    if use_kernel is not None:
        from repro.core.compressed_array import warn_use_kernel

        plan = warn_use_kernel(use_kernel)
        if plan == "jnp" and fmt == "vbyte":
            plan = "ref"
    if (plan == "auto" and fmt == "vbyte"
            and dispatch.default_plan().path != "pallas"):
        # off-TPU, ALL kinds keep the block-local ref decode (dot-score
        # kinds run it unfused: ref grid + dot_score as a second dispatch)
        plan = "ref"

    if cfg.kind in ("sasrec", "bert4rec"):
        # one-pass fused path: decode → gather item vectors → dot, in-kernel
        h = _seq_repr(params, batch["hist"], cfg, causal=cfg.kind == "sasrec",
                      dtype=dtype)[:, -1]  # [1, d]
        table = params["item_emb"]["emb"].astype(dtype)
        ids, scores = dispatch.decode(
            cands_arr, epilogue="dot_score",
            epilogue_operands={"table": table, "query": h}, plan=plan)
        cands = constrain(ids.reshape(-1), ("pod", "data", "model"))
        scores = constrain(scores.reshape(-1), ("pod", "data", "model"))
    else:
        cands = dispatch.decode(cands_arr, plan=plan)
        cands = cands.reshape(-1).astype(jnp.int32)  # padded with 0 = pad row
        cands = constrain(cands, ("pod", "data", "model"))
        C = cands.shape[0]
        if cfg.kind == "two_tower":
            u = user_tower(params, batch["user_id"], batch["hist"], cfg, dtype=dtype)
            i = item_tower(params, cands, cfg, dtype=dtype)  # [C, v]
            scores = (i @ u[0]).astype(jnp.float32)
        else:  # bst: every candidate runs through the ranker with the history
            hist = jnp.broadcast_to(batch["hist"], (C, cfg.seq_len))
            scores = bst_forward(params, hist, cands, cfg, dtype=dtype)
    top_s, top_i = jax.lax.top_k(scores, top_k)
    return scores, (top_s, jnp.take(cands, top_i))
