"""Architecture × shape registry: configs, abstract inputs, step fns, shardings.

Every dry-run cell, smoke test and benchmark goes through here, so shapes and
shardings are defined in exactly one place. ``build_cell(arch, shape)``
returns everything needed to ``jax.jit(fn, in_shardings=...).lower(*args)``.
"""
from __future__ import annotations

import dataclasses
import functools
import importlib
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.shapes import GNN_SHAPES, LM_SHAPES, RECSYS_SHAPES, ShapeDef
from repro.distributed import sharding as shd
from repro.train import OptimizerConfig, init_train_state, make_train_step

SDS = jax.ShapeDtypeStruct

ARCH_IDS = {
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "yi-6b": "repro.configs.yi_6b",
    "glm4-9b": "repro.configs.glm4_9b",
    "gin-tu": "repro.configs.gin_tu",
    "sasrec": "repro.configs.sasrec",
    "two-tower-retrieval": "repro.configs.two_tower_retrieval",
    "bert4rec": "repro.configs.bert4rec",
    "bst": "repro.configs.bst",
}


def _module(arch_id: str):
    return importlib.import_module(ARCH_IDS[arch_id])


def list_archs() -> list[str]:
    return list(ARCH_IDS)


def family_of(arch_id: str) -> str:
    return _module(arch_id).FAMILY


def skips_of(arch_id: str) -> dict[str, str]:
    return dict(_module(arch_id).SKIPS)


def shapes_of(arch_id: str) -> dict[str, ShapeDef]:
    fam = family_of(arch_id)
    return {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": RECSYS_SHAPES}[fam]


def all_cells(include_skipped: bool = False):
    """Yield (arch_id, shape_name, skip_reason|None) for the 40 cells."""
    for arch in list_archs():
        skips = skips_of(arch)
        for shape in shapes_of(arch):
            reason = skips.get(shape)
            if reason is None or include_skipped:
                yield arch, shape, reason


# ----------------------------------------------------------------------------
# config resolution (per-shape overrides; mesh-dependent knobs)
# ----------------------------------------------------------------------------
def resolve_config(arch_id: str, shape_name: str, *, dp_degree: int = 1,
                   overrides: dict[str, Any] | None = None):
    mod = _module(arch_id)
    cfg = mod.CONFIG
    fam = mod.FAMILY
    shape = shapes_of(arch_id)[shape_name]
    if fam == "gnn":
        cfg = dataclasses.replace(
            cfg,
            d_feat=shape.dims["d_feat"],
            n_classes=shape.dims["n_classes"],
            task=shape.dims.get("task", "node"),
            compressed_adjacency=shape.dims.get("compressed_adjacency", False),
        )
    if fam == "lm" and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=max(dp_degree, 1))
        )
    if overrides:
        # nested override for moe settings
        moe_over = {k[4:]: v for k, v in overrides.items() if k.startswith("moe.")}
        flat_over = {k: v for k, v in overrides.items() if "." not in k}
        if moe_over and getattr(cfg, "moe", None) is not None:
            cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, **moe_over))
        if flat_over:
            cfg = dataclasses.replace(cfg, **flat_over)
    return cfg


# ----------------------------------------------------------------------------
# abstract params / state
# ----------------------------------------------------------------------------
def _family_init(fam: str):
    if fam == "lm":
        from repro.models import lm

        return lm.init_params
    if fam == "gnn":
        from repro.models import gnn

        return gnn.init_params
    from repro.models import recsys

    return recsys.init_params


def abstract_params(cfg, fam: str, *, dtype=None):
    init = _family_init(fam)
    out = jax.eval_shape(lambda: init(jax.random.PRNGKey(0), cfg))
    if dtype is not None:
        out = jax.tree.map(
            lambda s: SDS(s.shape, dtype) if jnp.issubdtype(s.dtype, jnp.floating) else s,
            out,
        )
    return out


def abstract_train_state(cfg, fam: str):
    init = _family_init(fam)
    return jax.eval_shape(
        lambda: init_train_state(init(jax.random.PRNGKey(0), cfg))
    )


# ----------------------------------------------------------------------------
# batch builders: (ShapeDtypeStruct tree, PartitionSpec tree)
# ----------------------------------------------------------------------------
DP, TP, ALL = shd.DP, shd.TP, shd.ALL


def _split(entries: dict[str, tuple]):
    batch = {k: SDS(s, d) for k, (s, d, _) in entries.items()}
    specs = {k: p for k, (s, d, p) in entries.items()}
    return batch, specs


def _lm_batch(cfg, shape: ShapeDef):
    B = shape.dims["global_batch"]
    S = shape.dims["seq_len"]
    if shape.step == "train":
        return _split({"tokens": ((B, S + 1), jnp.int32, P(DP, None))})
    if shape.step == "prefill":
        return _split({"tokens": ((B, S), jnp.int32, P(DP, None))})
    if shape.step == "decode":
        bspec = P(DP) if B >= 16 else P(None)
        return _split({"tokens": ((B,), jnp.int32, bspec)})
    raise ValueError(shape.step)


def _lm_cache(cfg, shape: ShapeDef, mesh_dp: int):
    from repro.models import lm

    B = shape.dims["global_batch"]
    S = shape.dims["seq_len"]
    sc = lm.cache_size(cfg, S)
    kv = SDS((cfg.n_layers, B, sc, cfg.n_kv_heads, cfg.dh), jnp.bfloat16)
    spec = shd.lm_cache_spec(cfg, B, mesh_dp)
    cache = {"k": kv, "v": kv, "index": SDS((), jnp.int32)}
    specs = {"k": spec, "v": spec, "index": P()}
    return cache, specs


def _gnn_batch(cfg, shape: ShapeDef):
    d = shape.dims
    N, E, F = d["n_nodes"], d["n_edges"], d["d_feat"]
    shard = d.get("task", "node") == "node"  # molecule batch: replicate (tiny)
    nspec = P(ALL, None) if shard else P(None, None)
    espec = P(ALL) if shard else P(None)
    fdtype = jnp.bfloat16 if cfg.feats_dtype == "bf16" else jnp.float32
    entries = {
        "feats": ((N, F), fdtype, nspec),
        "labels": ((N if cfg.task == "node" else d["batch_graphs"],), jnp.int32,
                   espec if cfg.task == "node" else P(None)),
        "edge_valid": ((E,), jnp.bool_, espec),
    }
    if cfg.task == "node":
        entries["label_mask"] = ((N,), jnp.bool_, espec)
    else:
        entries["graph_ids"] = ((N,), jnp.int32, P(None))
    if cfg.compressed_adjacency:
        stride = d["payload_stride"]
        nb = -(-E // 128)
        nb = -(-nb // 512) * 512  # block-shardable
        entries.update({
            "row_gap_bases": ((N,), jnp.uint32, P(None)),  # skip bases: replicated
            "row_offsets": ((N + 1,), jnp.int32, P(None)),
        })
        batch, specs = _split(entries)
        # the gap stream rides in the batch as a CompressedIntArray pytree:
        # abstract leaves (SDS) for lowering, P leaves for the shardings —
        # both trees share the array's treedef (block dim over the mesh)
        batch["gaps"] = _abstract_compressed(
            {"payload": ((nb, stride), jnp.uint8), "counts": ((nb,), jnp.int32),
             "bases": ((nb,), jnp.uint32)},
            format="vbyte", differential=True, n=E)
        specs["gaps"] = shd.compressed_array_specs(batch["gaps"], axis=ALL)
        return batch, specs
    entries.update({
        "edge_src": ((E,), jnp.int32, espec),
        "edge_dst": ((E,), jnp.int32, espec),
    })
    return _split(entries)


def _recsys_batch(cfg, shape: ShapeDef):
    d = shape.dims
    B = d["batch"]
    L = cfg.seq_len
    k = cfg.kind
    if shape.step == "train":
        if k == "sasrec":
            return _split({
                "hist": ((B, L + 1), jnp.int32, P(DP, None)),
                "neg": ((B, L), jnp.int32, P(DP, None)),
            })
        if k == "bert4rec":
            return _split({
                "hist": ((B, L), jnp.int32, P(DP, None)),
                "mask_pos": ((B, cfg.n_mask), jnp.int32, P(DP, None)),
                "targets": ((B, cfg.n_mask), jnp.int32, P(DP, None)),
                "negatives": ((cfg.n_negatives,), jnp.int32, P(None)),
            })
        if k == "bst":
            return _split({
                "hist": ((B, L), jnp.int32, P(DP, None)),
                "target": ((B,), jnp.int32, P(DP)),
                "label": ((B,), jnp.int32, P(DP)),
            })
        if k == "two_tower":
            return _split({
                "user_id": ((B,), jnp.int32, P(DP)),
                "hist": ((B, L), jnp.int32, P(DP, None)),
                "item_id": ((B,), jnp.int32, P(DP)),
            })
    if shape.step == "serve":
        C = cfg.serve_candidates
        if k == "bst":
            return _split({
                "hist": ((B, L), jnp.int32, P(DP, None)),
                "target": ((B,), jnp.int32, P(DP)),
            })
        if k == "two_tower":
            return _split({
                "user_id": ((B,), jnp.int32, P(DP)),
                "hist": ((B, L), jnp.int32, P(DP, None)),
                "cands": ((C,), jnp.int32, P(None)),
            })
        return _split({
            "hist": ((B, L), jnp.int32, P(DP, None)),
            "cands": ((B, C), jnp.int32, P(DP, None)),
        })
    if shape.step == "retrieval":
        nc = d["n_candidates"]
        nb = nc // 128
        stride = d["payload_stride"]
        entries = {
            "hist": ((1, L), jnp.int32, P(None, None)),
        }
        if k == "two_tower":
            entries["user_id"] = ((1,), jnp.int32, P(None))
        batch, specs = _split(entries)
        # candidate list: the CompressedIntArray itself is the batch entry
        # (pytree — SDS leaves for lowering, block dim sharded over the mesh)
        batch["cands"] = _abstract_compressed(
            {"payload": ((nb, stride), jnp.uint8), "counts": ((nb,), jnp.int32),
             "bases": ((nb,), jnp.uint32)},
            format="vbyte", differential=True, n=nc)
        specs["cands"] = shd.compressed_array_specs(batch["cands"], axis=ALL)
        return batch, specs
    raise ValueError((cfg.kind, shape.step))


def _abstract_compressed(leaves: dict, *, format: str, differential: bool,
                         n: int, block_size: int = 128):
    """CompressedIntArray of ShapeDtypeStructs (an abstract batch template)."""
    from repro.core.compressed_array import CompressedIntArray

    return CompressedIntArray.from_operands(
        {nm: SDS(s, dt) for nm, (s, dt) in leaves.items()},
        format=format, block_size=block_size, differential=differential, n=n)


# ----------------------------------------------------------------------------
# cells
# ----------------------------------------------------------------------------
@dataclass
class Cell:
    arch_id: str
    shape: ShapeDef
    family: str
    cfg: Any
    fn: Callable  # positional-args step function
    args: tuple  # abstract args (ShapeDtypeStruct trees)
    arg_specs: tuple  # PartitionSpec trees matching args
    donate: tuple[int, ...] = ()
    assembly: dict = None  # step-assembly options (e.g. zero1) for the cost model

    def in_shardings(self, mesh: Mesh):
        return shd.to_named(mesh, self.arg_specs)


DEFAULT_OPT = OptimizerConfig()


# overrides that configure the *step assembly*, not the model config
_STEP_OVERRIDES = ("zero1", "prefill_impl", "prefill_chunk", "grad_bf16")


def build_cell(arch_id: str, shape_name: str, *, mesh_dp: int = 32,
               overrides: dict[str, Any] | None = None,
               opt_cfg: OptimizerConfig = DEFAULT_OPT) -> Cell:
    fam = family_of(arch_id)
    shape = shapes_of(arch_id)[shape_name]
    overrides = dict(overrides or {})
    step_over = {k: overrides.pop(k) for k in _STEP_OVERRIDES if k in overrides}
    cfg = resolve_config(arch_id, shape_name, dp_degree=mesh_dp, overrides=overrides)

    if fam == "lm":
        from repro.models import lm

        batch, bspec = _lm_batch(cfg, shape)
        if shape.step == "train":
            from repro.distributed.api import constrain

            zero1 = bool(step_over.get("zero1", False))
            state = abstract_train_state(cfg, fam)
            aparams = jax.eval_shape(
                lambda: _family_init(fam)(jax.random.PRNGKey(0), cfg))
            master_spec = shd.tree_specs(aparams, shd.lm_param_spec(cfg, zero1=zero1))
            sspec = {"params": master_spec,
                     "opt": {"m": master_spec, "v": master_spec, "step": P()}}
            compute_cast = grad_transform = None
            if zero1:
                compute_spec = shd.tree_specs(aparams, shd.lm_param_spec(cfg))

                def compute_cast(params):  # one bf16 all-gather per step
                    return jax.tree.map(
                        lambda p, s: constrain(p.astype(jnp.bfloat16), *tuple(s)),
                        params, compute_spec,
                        is_leaf=lambda x: hasattr(x, "dtype"))

                def grad_transform(g):  # bf16 reduce-scatter to master layout
                    return jax.tree.map(
                        lambda x, s: constrain(x.astype(jnp.bfloat16), *tuple(s)),
                        g, master_spec, is_leaf=lambda x: hasattr(x, "dtype"))

            step = make_train_step(
                functools.partial(lm.loss_fn, cfg=cfg), opt_cfg,
                microbatch=cfg.microbatch,
                compute_cast=compute_cast, grad_transform=grad_transform,
            )
            return Cell(arch_id, shape, fam, cfg, step, (state, batch),
                        (sspec, bspec), donate=(0,), assembly={"zero1": zero1})
        params = abstract_params(cfg, fam, dtype=jnp.bfloat16)
        pspec = shd.tree_specs(params, shd.lm_param_spec(cfg))
        if shape.step == "prefill":
            if step_over.get("prefill_impl") == "chunked":
                fn = functools.partial(
                    _lm_prefill_chunked_fn, cfg=cfg,
                    chunk=int(step_over.get("prefill_chunk", 4096)))
            else:
                fn = functools.partial(_lm_prefill_fn, cfg=cfg,
                                       seq=shape.dims["seq_len"])
            return Cell(arch_id, shape, fam, cfg, fn, (params, batch["tokens"]),
                        (pspec, bspec["tokens"]))
        cache, cspec = _lm_cache(cfg, shape, mesh_dp)
        fn = functools.partial(_lm_decode_fn, cfg=cfg)
        return Cell(arch_id, shape, fam, cfg, fn,
                    (params, cache, batch["tokens"]),
                    (pspec, cspec, bspec["tokens"]), donate=(1,))

    if fam == "gnn":
        from repro.models import gnn

        batch, bspec = _gnn_batch(cfg, shape)
        state = abstract_train_state(cfg, fam)
        sspec = shd.state_specs(
            jax.eval_shape(lambda: _family_init(fam)(jax.random.PRNGKey(0), cfg)),
            shd.gnn_param_spec(cfg),
        )
        step = make_train_step(functools.partial(gnn.loss_fn, cfg=cfg), opt_cfg)
        return Cell(arch_id, shape, fam, cfg, step, (state, batch),
                    (sspec, bspec), donate=(0,))

    from repro.models import recsys

    batch, bspec = _recsys_batch(cfg, shape)
    if shape.step == "train":
        state = abstract_train_state(cfg, fam)
        sspec = shd.state_specs(
            jax.eval_shape(lambda: _family_init(fam)(jax.random.PRNGKey(0), cfg)),
            shd.recsys_param_spec(cfg),
        )
        aparams = jax.eval_shape(
            lambda: _family_init(fam)(jax.random.PRNGKey(0), cfg))
        zero1 = bool(step_over.get("zero1", False))
        compute_cast = grad_transform = None
        if zero1:
            # ZeRO-1 for embedding tables: master/moments DP-sharded, bf16
            # compute copy + bf16 grad reduce-scatter (a post-hoc grad cast
            # alone cannot change the wire format of GSPMD's backward
            # all-reduce — measured, see EXPERIMENTS §Perf; the resharding
            # constrain is what puts bf16 on the wire)
            base_rule = shd.recsys_param_spec(cfg)
            master_rule = lambda p, l: shd.zero1_extend(base_rule(p, l), l)
            master_spec = shd.tree_specs(aparams, master_rule)
            compute_spec = shd.tree_specs(aparams, base_rule)
            sspec = {"params": master_spec,
                     "opt": {"m": master_spec, "v": master_spec, "step": P()}}
            from repro.distributed.api import constrain

            def compute_cast(params):
                return jax.tree.map(
                    lambda p, s: constrain(p.astype(jnp.bfloat16), *tuple(s)),
                    params, compute_spec)

            def grad_transform(g):
                return jax.tree.map(
                    lambda x, s: constrain(x.astype(jnp.bfloat16), *tuple(s)),
                    g, master_spec)

        step = make_train_step(functools.partial(recsys.loss_fn, cfg=cfg), opt_cfg,
                               compute_cast=compute_cast,
                               grad_transform=grad_transform)
        return Cell(arch_id, shape, fam, cfg, step, (state, batch),
                    (sspec, bspec), donate=(0,),
                    assembly={"zero1": zero1})
    params = abstract_params(cfg, fam, dtype=jnp.bfloat16)
    pspec = shd.tree_specs(params, shd.recsys_param_spec(cfg, serving=True))
    if shape.step == "serve":
        fn = functools.partial(_recsys_serve_fn, cfg=cfg)
    else:
        fn = functools.partial(_recsys_retrieval_fn, cfg=cfg)
    return Cell(arch_id, shape, fam, cfg, fn, (params, batch), (pspec, bspec))


# top-level partials (picklable, stable names in HLO)
def _lm_prefill_fn(params, tokens, *, cfg, seq):
    from repro.models import lm

    return lm.prefill(params, tokens, cfg, cache_capacity=seq)


def _lm_prefill_chunked_fn(params, tokens, *, cfg, chunk):
    from repro.models import lm

    return lm.prefill_chunked(params, tokens, cfg, chunk=chunk)


def _lm_decode_fn(params, cache, tokens, *, cfg):
    from repro.models import lm

    return lm.decode_step(params, cache, tokens, cfg)


def _recsys_serve_fn(params, batch, *, cfg):
    from repro.models import recsys

    return recsys.serve_scores(params, batch, cfg)


def _recsys_retrieval_fn(params, batch, *, cfg):
    from repro.models import recsys

    return recsys.retrieval_scores_compressed(params, batch, cfg)


# ----------------------------------------------------------------------------
# reduced configs for CPU smoke tests
# ----------------------------------------------------------------------------
def reduced_config(arch_id: str):
    """Tiny same-family config: a few layers/experts, small dims/tables."""
    mod = _module(arch_id)
    cfg, fam = mod.CONFIG, mod.FAMILY
    if fam == "lm":
        moe = cfg.moe and dataclasses.replace(
            cfg.moe, n_experts=min(cfg.moe.n_experts, 4),
            top_k=min(cfg.moe.top_k, 2), d_ff=64, capacity_factor=2.0,
        )
        return dataclasses.replace(
            cfg, n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)), head_dim=16,
            d_ff=128, vocab=512, moe=moe, window=cfg.window and 16,
            q_chunk=16, kv_chunk=16, loss_chunk=8,
        )
    if fam == "gnn":
        return dataclasses.replace(cfg, n_layers=2, d_hidden=16,
                                   d_feat=12, n_classes=3)
    return dataclasses.replace(
        cfg, n_items=1000, n_users=max(cfg.n_users and 1000, 0),
        embed_dim=16, id_dim=16, seq_len=min(cfg.seq_len, 12),
        n_blocks=1, n_heads=2 if cfg.kind != "sasrec" else 1,
        mlp_dims=(32, 16) if cfg.mlp_dims else (),
        n_mask=min(cfg.n_mask, 3) if cfg.n_mask else 0, n_negatives=16,
        serve_candidates=32,
    )
