"""Transformer LM family: dense + MoE, GQA, RoPE, sliding-window.

One code path covers all five assigned LM archs (olmoe, mixtral, h2o-danube,
yi, glm4). Layers are stacked on a leading L axis and iterated with
``lax.scan`` (+ per-layer remat) — keeps HLO size O(1) in depth, which is what
makes the 512-device dry-run compile fast.

Entry points: ``init_params``, ``loss_fn`` (train), ``prefill`` (inference
prefill, returns KV cache), ``decode_step`` (single-token serve with KV cache,
ring-buffer for sliding-window archs).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.ad_checkpoint import checkpoint_name

from repro.distributed.api import constrain
from repro.nn import attention as attn
from repro.nn import layers as nn
from repro.nn import moe as moe_lib

DP = ("pod", "data")  # logical batch axes
TP = "model"


@dataclass(frozen=True)
class MoESettings:
    n_experts: int
    top_k: int
    d_ff: int  # per-expert hidden dim
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    ep_shard: bool = False  # expert-parallel iff E % model_axis == 0
    dispatch_groups: int = 1  # set to DP degree by the launcher (local dispatch)


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    rope_theta: float = 10000.0
    rotary_fraction: float = 1.0
    window: int | None = None  # sliding-window attention (Mistral-style)
    moe: MoESettings | None = None
    norm_eps: float = 1e-5
    remat: bool = True
    # "full": recompute everything in bwd (min memory, refwd repeats the TP
    # psums). "save_block_outputs": checkpoint the two psum'd block outputs
    # per layer — refwd TP collectives vanish (wire x2/3) for ~2·t·d·L bytes
    # of extra residuals (§Perf mixtral hillclimb iteration 3).
    remat_policy: str = "full"
    q_chunk: int = 512
    kv_chunk: int = 1024
    banded_attention: bool = False  # SWA band slicing (perf lever, §Perf)
    loss_chunk: int = 512
    microbatch: int = 1  # gradient-accumulation microbatches per train step
    extras: dict[str, Any] = field(default_factory=dict)

    @property
    def dh(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def rotary_dim(self) -> int:
        rd = int(self.dh * self.rotary_fraction)
        return rd - rd % 2

    def param_count(self) -> int:
        d, dh, v = self.d_model, self.dh, self.vocab
        att = d * dh * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.moe:
            ffn = self.moe.n_experts * 3 * d * self.moe.d_ff + d * self.moe.n_experts
        else:
            ffn = 3 * d * self.d_ff
        per_layer = att + ffn + 2 * d
        return self.n_layers * per_layer + 2 * v * d + d

    def active_param_count(self) -> int:
        if not self.moe:
            return self.param_count()
        d = self.d_model
        att = d * self.dh * (self.n_heads * 2 + self.n_kv_heads * 2)
        ffn = self.moe.top_k * 3 * d * self.moe.d_ff + d * self.moe.n_experts
        return self.n_layers * (att + ffn + 2 * d) + 2 * self.vocab * d + d


# ----------------------------------------------------------------------------
# init
# ----------------------------------------------------------------------------
def _layer_init(key, cfg: LMConfig):
    d, dh = cfg.d_model, cfg.dh
    kq, kk, kv, ko, kf = jax.random.split(key, 5)
    p = {
        "attn_norm": nn.rmsnorm_init(d),
        "attn": {
            "wq": nn.dense_init(kq, d, cfg.n_heads * dh),
            "wk": nn.dense_init(kk, d, cfg.n_kv_heads * dh),
            "wv": nn.dense_init(kv, d, cfg.n_kv_heads * dh),
            "wo": nn.dense_init(ko, cfg.n_heads * dh, d),
        },
        "ffn_norm": nn.rmsnorm_init(d),
    }
    if cfg.moe:
        p["moe"] = moe_lib.moe_init(kf, d, cfg.moe.d_ff, cfg.moe.n_experts)
    else:
        p["ffn"] = nn.swiglu_ffn_init(kf, d, cfg.d_ff)
    return p


def init_params(key, cfg: LMConfig):
    ke, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys)
    return {
        "embed": nn.embedding_init(ke, cfg.vocab, cfg.d_model),
        "layers": layers,
        "final_norm": nn.rmsnorm_init(cfg.d_model),
        "lm_head": nn.dense_init(kh, cfg.d_model, cfg.vocab),
    }


# ----------------------------------------------------------------------------
# forward (train / prefill)
# ----------------------------------------------------------------------------
def _attention_block(layer, x, positions, cfg: LMConfig, dtype):
    B, S, d = x.shape
    h = nn.rmsnorm(layer["attn_norm"], x, eps=cfg.norm_eps, dtype=dtype)
    q = nn.dense(layer["attn"]["wq"], h, dtype=dtype).reshape(B, S, cfg.n_heads, cfg.dh)
    k = nn.dense(layer["attn"]["wk"], h, dtype=dtype).reshape(B, S, cfg.n_kv_heads, cfg.dh)
    v = nn.dense(layer["attn"]["wv"], h, dtype=dtype).reshape(B, S, cfg.n_kv_heads, cfg.dh)
    q = attn.apply_rope(q, positions, cfg.rope_theta, cfg.rotary_dim)
    k = attn.apply_rope(k, positions, cfg.rope_theta, cfg.rotary_dim)
    q = constrain(q, DP, None, TP, None)
    k = constrain(k, DP, None, None, None)
    o = attn.flash_attention(
        q, k, v, causal=True, window=cfg.window,
        q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
        banded=cfg.banded_attention, dtype=dtype,
    )
    o = nn.dense(layer["attn"]["wo"], o.reshape(B, S, cfg.n_heads * cfg.dh), dtype=dtype)
    o = checkpoint_name(o, "attn_out")  # TP psum output (remat_policy)
    return x + o, (k, v)


def _ffn_block(layer, x, cfg: LMConfig, dtype):
    B, S, d = x.shape
    h = nn.rmsnorm(layer["ffn_norm"], x, eps=cfg.norm_eps, dtype=dtype)
    if cfg.moe:
        out, aux = moe_lib.moe_apply(
            layer["moe"], h.reshape(B * S, d),
            top_k=cfg.moe.top_k, capacity_factor=cfg.moe.capacity_factor,
            ep_shard=cfg.moe.ep_shard,
            dispatch_groups=cfg.moe.dispatch_groups, dtype=dtype,
        )
        out = checkpoint_name(out.reshape(B, S, d), "ffn_out")
        return x + out, aux
    h = nn.swiglu_ffn(layer["ffn"], h, dtype=dtype)
    h = constrain(h, DP, None, None)
    h = checkpoint_name(h, "ffn_out")  # TP psum output (remat_policy)
    return x + h, {"moe_aux_loss": jnp.float32(0.0), "moe_drop_frac": jnp.float32(0.0)}


def forward(params, tokens, cfg: LMConfig, *, collect_cache: bool = False,
            dtype=nn.DEFAULT_COMPUTE_DTYPE):
    """tokens [B, S] -> (hidden [B, S, d], aux, kv [L, ...] if collect_cache)."""
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    x = nn.embedding_lookup(params["embed"], tokens, dtype=dtype)
    x = constrain(x, DP, None, None)

    def layer_fn(x, layer):
        x, (k, v) = _attention_block(layer, x, positions, cfg, dtype)
        x, aux = _ffn_block(layer, x, cfg, dtype)
        x = constrain(x, DP, None, None)
        ys = (aux, (k, v) if collect_cache else None)
        return x, ys

    if cfg.remat and cfg.remat_policy == "save_block_outputs":
        body = jax.checkpoint(
            layer_fn,
            policy=jax.checkpoint_policies.save_only_these_names(
                "attn_out", "ffn_out"),
        )
    elif cfg.remat:
        body = jax.checkpoint(layer_fn)
    else:
        body = layer_fn
    x, (auxs, kvs) = lax.scan(body, x, params["layers"])
    x = nn.rmsnorm(params["final_norm"], x, eps=cfg.norm_eps, dtype=dtype)
    aux = {k: jnp.mean(v) for k, v in auxs.items()}
    return x, aux, kvs


def loss_fn(params, batch, cfg: LMConfig, *, dtype=nn.DEFAULT_COMPUTE_DTYPE):
    """batch: {"tokens": [B, S+1] int32}. Mean next-token cross-entropy."""
    tokens = batch["tokens"]
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    hidden, aux, _ = forward(params, inputs, cfg, dtype=dtype)
    B, S, d = hidden.shape

    n_chunks = max(1, S // cfg.loss_chunk) if S % cfg.loss_chunk == 0 else 1
    hs = hidden.reshape(B, n_chunks, S // n_chunks, d).transpose(1, 0, 2, 3)
    ts = targets.reshape(B, n_chunks, S // n_chunks).transpose(1, 0, 2)

    def chunk_loss(carry, xs):
        h, t = xs
        logits = nn.dense(params["lm_head"], h, dtype=dtype)  # [B, c, V]
        logits = constrain(logits, DP, None, TP)
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        true = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - true), None

    total, _ = lax.scan(chunk_loss, jnp.float32(0.0), (hs, ts))
    loss = total / (B * S)
    if cfg.moe:
        loss = loss + cfg.moe.aux_loss_coef * aux["moe_aux_loss"]
    return loss, aux


# ----------------------------------------------------------------------------
# inference: prefill + single-token decode (KV cache)
# ----------------------------------------------------------------------------
def cache_size(cfg: LMConfig, seq_len: int) -> int:
    """Ring buffer of `window` slots for SWA archs, else full length."""
    return min(seq_len, cfg.window) if cfg.window else seq_len


def init_cache(cfg: LMConfig, batch: int, seq_len: int, dtype=jnp.bfloat16):
    sc = cache_size(cfg, seq_len)
    shape = (cfg.n_layers, batch, sc, cfg.n_kv_heads, cfg.dh)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "index": jnp.zeros((), jnp.int32),  # absolute position of next token
    }


def cache_head_axes(cfg: LMConfig, tp: int = 16):
    """(Hk axis, dh axis) sharding for the KV cache — heads when divisible,
    else head-dim (GSPMD psums the scores over the contracted shards)."""
    if cfg.n_kv_heads % tp == 0:
        return (TP, None)
    if cfg.dh % 8 == 0 or cfg.dh % 16 == 0:
        return (None, TP)
    return (None, None)


def prefill(params, tokens, cfg: LMConfig, *, cache_capacity: int | None = None,
            dtype=nn.DEFAULT_COMPUTE_DTYPE):
    """Run the prompt; return (last-token logits [B, V], cache)."""
    B, S = tokens.shape
    hidden, _, (ks, vs) = forward(params, tokens, cfg, collect_cache=True, dtype=dtype)
    # cache leaves the prefill step sequence-sharded over the model axis
    # (one reshard per layer at the scan boundary; decode re-shards on load)
    ks = constrain(ks, None, DP, TP, None, None)  # [L, B, S, Hk, dh]
    vs = constrain(vs, None, DP, TP, None, None)
    sc = cache_size(cfg, cache_capacity or S)
    if sc < S:  # SWA ring: keep last `sc` positions, aligned to slot = pos % sc
        ks, vs = ks[:, :, S - sc :], vs[:, :, S - sc :]
        shift = S % sc  # slot of position S-sc is (S-sc)%sc = S%sc
        ks = jnp.roll(ks, shift, axis=2)
        vs = jnp.roll(vs, shift, axis=2)
    elif sc > S:
        pad = sc - S
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    logits = nn.dense(params["lm_head"], hidden[:, -1], dtype=dtype)
    logits = constrain(logits, DP, TP)
    cache = {"k": ks, "v": vs, "index": jnp.int32(S)}
    return logits.astype(jnp.float32), cache


def prefill_chunked(params, tokens, cfg: LMConfig, *, chunk: int = 4096,
                    dtype=nn.DEFAULT_COMPUTE_DTYPE):
    """Sarathi-style chunked prefill: the prompt runs through the model in
    sequence chunks, each attending to the KV cache filled so far. Activation
    and MoE-dispatch memory scale with `chunk`, not the prompt length —
    the fix for MoE prefill memory (EXPERIMENTS.md §Dry-run notes). With a
    sliding window + banded attention, compute also drops to O(S·window).

    Returns (last-token logits [B, V], cache) — same contract as prefill().
    """
    B, S = tokens.shape
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    Hk, dh = cfg.n_kv_heads, cfg.dh
    # SWA fast path: with window <= chunk, chunk ci only needs chunk ci-1's
    # KV; the carry is one chunk per layer, not the full prompt (and the
    # final ring cache IS the last window of the prompt).
    swa_local = bool(cfg.window) and cfg.window <= chunk
    if swa_local:
        kv_shape = (cfg.n_layers, B, chunk, Hk, dh)
    else:
        kv_shape = (cfg.n_layers, B, S, Hk, dh)
    ks0 = jnp.zeros(kv_shape, dtype)
    vs0 = jnp.zeros(kv_shape, dtype)

    def chunk_step(carry, ci):
        ks, vs = carry
        offset = ci * chunk
        toks = lax.dynamic_slice_in_dim(tokens, offset, chunk, axis=1)
        x = nn.embedding_lookup(params["embed"], toks, dtype=dtype)
        x = constrain(x, DP, None, None)
        positions = offset + jnp.arange(chunk, dtype=jnp.int32)[None, :]
        prev_valid = jnp.repeat(ci > 0, chunk)

        def layer_fn(x, xs):
            layer, kc, vc = xs  # this layer's cache (chunk or full length)
            h = nn.rmsnorm(layer["attn_norm"], x, eps=cfg.norm_eps, dtype=dtype)
            q = nn.dense(layer["attn"]["wq"], h, dtype=dtype).reshape(
                B, chunk, cfg.n_heads, dh)
            k = nn.dense(layer["attn"]["wk"], h, dtype=dtype).reshape(B, chunk, Hk, dh)
            v = nn.dense(layer["attn"]["wv"], h, dtype=dtype).reshape(B, chunk, Hk, dh)
            q = attn.apply_rope(q, positions, cfg.rope_theta, cfg.rotary_dim)
            k = attn.apply_rope(k, positions, cfg.rope_theta, cfg.rotary_dim)
            k = k.astype(dtype)
            v = v.astype(dtype)
            if swa_local:
                kv_k = jnp.concatenate([kc, k], axis=1)  # [B, 2*chunk, ...]
                kv_v = jnp.concatenate([vc, v], axis=1)
                o = attn.flash_attention(
                    q, kv_k, kv_v, causal=True, window=cfg.window,
                    q_chunk=min(cfg.q_chunk, chunk), kv_chunk=cfg.kv_chunk,
                    q_offset=offset, kv_offset=offset - chunk,
                    kv_valid=jnp.concatenate(
                        [prev_valid, jnp.ones((chunk,), bool)]),
                    dtype=dtype)
                kc, vc = k, v  # next chunk sees this one
            else:
                kc = lax.dynamic_update_slice_in_dim(kc, k, offset, axis=1)
                vc = lax.dynamic_update_slice_in_dim(vc, v, offset, axis=1)
                o = attn.flash_attention(
                    q, kc, vc, causal=True, window=cfg.window,
                    q_chunk=min(cfg.q_chunk, chunk), kv_chunk=cfg.kv_chunk,
                    banded=cfg.banded_attention, q_offset=offset, dtype=dtype)
            o = nn.dense(layer["attn"]["wo"],
                         o.reshape(B, chunk, cfg.n_heads * dh), dtype=dtype)
            x = x + o
            x, _ = _ffn_block(layer, x, cfg, dtype)
            x = constrain(x, DP, None, None)
            return x, (kc, vc)

        x, (ks, vs) = lax.scan(layer_fn, x, (params["layers"], ks, vs))
        x = nn.rmsnorm(params["final_norm"], x, eps=cfg.norm_eps, dtype=dtype)
        logits = nn.dense(params["lm_head"], x[:, -1], dtype=dtype)
        logits = constrain(logits, DP, TP)
        return (ks, vs), logits

    (ks, vs), logits_all = lax.scan(chunk_step, (ks0, vs0),
                                    jnp.arange(nc, dtype=jnp.int32))
    logits = logits_all[-1]
    sc = cache_size(cfg, S)
    if swa_local:  # carry holds the last chunk = positions [S-chunk, S)
        ks, vs = ks[:, :, chunk - sc:], vs[:, :, chunk - sc:]
        shift = S % sc  # align slot = pos % sc (ring convention)
        ks = jnp.roll(ks, shift, axis=2)
        vs = jnp.roll(vs, shift, axis=2)
    elif sc < S:  # SWA ring conversion (same as prefill())
        ks, vs = ks[:, :, S - sc:], vs[:, :, S - sc:]
        shift = S % sc
        ks = jnp.roll(ks, shift, axis=2)
        vs = jnp.roll(vs, shift, axis=2)
    ks = constrain(ks, None, DP, TP, None, None)
    vs = constrain(vs, None, DP, TP, None, None)
    return logits.astype(jnp.float32), {"k": ks, "v": vs, "index": jnp.int32(S)}


def decode_step(params, cache, tokens, cfg: LMConfig, *, dtype=nn.DEFAULT_COMPUTE_DTYPE):
    """One serve step: tokens [B] -> (logits [B, V], updated cache)."""
    B = tokens.shape[0]
    d, dh, Hk = cfg.d_model, cfg.dh, cfg.n_kv_heads
    pos = cache["index"]  # absolute position of the new token
    sc = cache["k"].shape[2]
    slot = pos % sc if cfg.window else pos
    n_valid = jnp.minimum(pos + 1, sc)
    valid = jnp.arange(sc, dtype=jnp.int32) < n_valid

    x = nn.embedding_lookup(params["embed"], tokens, dtype=dtype)  # [B, d]
    x = constrain(x, DP, None)
    posv = jnp.full((B, 1), pos, jnp.int32)

    def layer_fn(x, xs):
        layer, kc, vc = xs
        h = nn.rmsnorm(layer["attn_norm"], x, eps=cfg.norm_eps, dtype=dtype)
        q = nn.dense(layer["attn"]["wq"], h, dtype=dtype).reshape(B, 1, cfg.n_heads, dh)
        k = nn.dense(layer["attn"]["wk"], h, dtype=dtype).reshape(B, 1, Hk, dh)
        v = nn.dense(layer["attn"]["wv"], h, dtype=dtype).reshape(B, 1, Hk, dh)
        q = attn.apply_rope(q, posv, cfg.rope_theta, cfg.rotary_dim)[:, 0]
        k = attn.apply_rope(k, posv, cfg.rope_theta, cfg.rotary_dim)[:, 0]
        kc = attn.cache_update(kc, k, slot)
        vc = attn.cache_update(vc, v[:, 0], slot)
        o = attn.decode_attention(q, kc, vc, valid, dtype=dtype)
        x = x + nn.dense(layer["attn"]["wo"], o.reshape(B, cfg.n_heads * dh), dtype=dtype)
        x2, _ = _ffn_block(layer, x[:, None], cfg, dtype)
        return x2[:, 0], (kc, vc)

    x, (ks, vs) = lax.scan(layer_fn, x, (params["layers"], cache["k"], cache["v"]))
    x = nn.rmsnorm(params["final_norm"], x, eps=cfg.norm_eps, dtype=dtype)
    logits = nn.dense(params["lm_head"], x, dtype=dtype)
    logits = constrain(logits, DP, TP)
    new_cache = {"k": ks, "v": vs, "index": pos + 1}
    return logits.astype(jnp.float32), new_cache
