from .elastic import MeshPlan, plan_mesh, reshard_plan  # noqa: F401
from .heartbeat import StragglerDetector  # noqa: F401
