from .elastic import MeshPlan, plan_mesh, reshard_plan, shard_intervals  # noqa: F401
from .heartbeat import StragglerDetector  # noqa: F401
