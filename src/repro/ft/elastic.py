"""Elastic re-meshing: recompute the mesh for a degraded chip count and plan
checkpoint resharding old-grid -> new-grid.

Recovery flow on real hardware: detector flags dead hosts -> coordinator
picks the largest usable chip count -> ``plan_mesh`` factorizes it ->
``reshard_plan`` maps every new shard to slices of checkpointed old shards ->
hosts restore only the bytes they own. Tested by simulation.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axis_names: tuple[str, ...]

    @property
    def n_chips(self) -> int:
        return math.prod(self.shape)


def plan_mesh(n_chips: int, *, model_parallel: int = 16,
              multi_pod_size: int = 256) -> MeshPlan:
    """Largest (pod, data, model) factorization fitting n_chips.

    Keeps the model axis fixed (sharding-rule compatible) and shrinks
    data/pod: the elastic dimension is data parallelism, as in production
    systems — TP degree is baked into layout, DP is not.
    """
    if n_chips < model_parallel:
        raise ValueError(f"need at least {model_parallel} chips for TP")
    usable_data = n_chips // model_parallel
    if usable_data * model_parallel > multi_pod_size:
        pods = (usable_data * model_parallel) // multi_pod_size
        data = multi_pod_size // model_parallel
        return MeshPlan((pods, data, model_parallel), ("pod", "data", "model"))
    return MeshPlan((usable_data, model_parallel), ("data", "model"))


def shard_intervals(dim: int, parts: int) -> list[tuple[int, int]]:
    """GSPMD-style equal chunks (dim divisible or padded)."""
    chunk = -(-dim // parts)
    return [(i * chunk, min((i + 1) * chunk, dim)) for i in range(parts)]


def reshard_plan(dim: int, old_parts: int, new_parts: int) -> list[list[tuple[int, int, int]]]:
    """For each new shard: [(old_shard, old_lo, old_hi)] source slices.

    Offsets are relative to the old shard's local array. Coverage of the new
    shard is complete and non-overlapping (asserted in tests).
    """
    old = shard_intervals(dim, old_parts)
    plan = []
    for lo, hi in shard_intervals(dim, new_parts):
        srcs = []
        for s, (olo, ohi) in enumerate(old):
            a, b = max(lo, olo), min(hi, ohi)
            if a < b:
                srcs.append((s, a - olo, b - olo))
        plan.append(srcs)
    return plan
