"""Straggler detection from per-host step heartbeats.

On a real deployment each host posts (host_id, step, wall_time) to a shared
store after every step; the coordinator runs this detector and triggers
either checkpoint-restart without the lost host (elastic.plan_mesh) or data
re-balancing for slow-but-alive hosts. Here the store is in-memory and tests
drive it with simulated timelines (single-process container).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


@dataclass
class HostRecord:
    step: int = -1
    last_seen: float = 0.0
    step_times: list[float] = field(default_factory=list)


class StragglerDetector:
    def __init__(self, *, window: int = 20, slow_factor: float = 2.0,
                 dead_factor: float = 5.0):
        self.hosts: dict[str, HostRecord] = {}
        self.window = window
        self.slow_factor = slow_factor
        self.dead_factor = dead_factor

    def heartbeat(self, host: str, step: int, now: float | None = None):
        now = time.monotonic() if now is None else now
        rec = self.hosts.setdefault(host, HostRecord())
        if rec.step >= 0 and step > rec.step:
            rec.step_times.append((now - rec.last_seen) / (step - rec.step))
            rec.step_times = rec.step_times[-self.window :]
        rec.step, rec.last_seen = step, now

    def median_step_time(self) -> float:
        times = sorted(
            t for r in self.hosts.values() for t in r.step_times[-self.window :]
        )
        return times[len(times) // 2] if times else float("inf")

    def stragglers(self, now: float | None = None) -> dict[str, str]:
        """host -> 'slow' | 'dead' classification."""
        now = time.monotonic() if now is None else now
        med = self.median_step_time()
        out = {}
        for host, rec in self.hosts.items():
            if rec.step_times and rec.step_times[-1] > self.slow_factor * med:
                out[host] = "slow"
            if now - rec.last_seen > self.dead_factor * max(med, 1e-9):
                out[host] = "dead"
        return out
