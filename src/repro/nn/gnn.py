"""GIN message passing via edge-index scatter (segment_sum).

JAX sparse is BCOO-only, so message passing is implemented directly over an
edge list: gather source features, segment-sum into destinations (taxonomy
§GNN, SpMM regime). Adjacency arrives either as raw (src, dst) arrays or as a
VByte-compressed gap stream (the paper's posting-list format — adjacency
lists ARE posting lists) decoded on device by ``decode_compressed_edges``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.api import constrain

from .layers import DEFAULT_COMPUTE_DTYPE, dense_init, truncated_normal_init

MESH_ALL = ("pod", "data", "model")  # flatten the whole mesh over nodes/edges


def gin_layer_init(key, d_in: int, d_out: int):
    k1, k2 = jax.random.split(key)
    return {
        "eps": jnp.zeros((), jnp.float32),  # learnable ε (GIN-ε)
        "mlp1": dense_init(k1, d_in, d_out),
        "b1": jnp.zeros((d_out,), jnp.float32),
        "mlp2": dense_init(k2, d_out, d_out),
        "b2": jnp.zeros((d_out,), jnp.float32),
    }


def gin_layer(params, h: jax.Array, src: jax.Array, dst: jax.Array, *,
              n_nodes: int, edge_valid: jax.Array | None = None,
              dtype=DEFAULT_COMPUTE_DTYPE, agg_dtype=jnp.float32) -> jax.Array:
    """h' = MLP((1 + ε)·h + Σ_{j∈N(i)} h_j) — sum aggregator (GIN).

    ``agg_dtype`` is the message/aggregation precision. f32 is the baseline;
    bf16 halves the cross-shard aggregation collectives (§Perf gin-tu
    hillclimb) — the f32 residual upcast otherwise hoists above the
    all-reduce and doubles its wire bytes.
    """
    msgs = jnp.take(h, src, axis=0).astype(agg_dtype)  # [E, d]
    if edge_valid is not None:
        msgs = jnp.where(edge_valid[:, None], msgs, 0)
    agg = jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
    agg = constrain(agg, MESH_ALL, None)
    # keep the ε-residual in agg_dtype: a f32 scalar here promotes the whole
    # aggregation pipeline and XLA hoists the upcast ABOVE the cross-shard
    # all-reduce, doubling its wire bytes (§Perf gin-tu iteration 2)
    scale = (1.0 + params["eps"]).astype(agg_dtype)
    x = (scale * h.astype(agg_dtype) + agg).astype(dtype)
    x = jax.nn.relu(x @ params["mlp1"]["w"].astype(dtype) + params["b1"].astype(dtype))
    x = x @ params["mlp2"]["w"].astype(dtype) + params["b2"].astype(dtype)
    return jax.nn.relu(x)


def decode_compressed_edges(gaps, row_offsets, n_edges,
                            *, row_gap_bases=None,
                            plan="auto", use_kernel: bool | None = None):
    """Decode a per-list delta-encoded VByte adjacency stream on device.

    ``gaps`` is the blocked gap stream as a ``CompressedIntArray``
    (``repro.data.graph.compress_adjacency`` builds it): each node's sorted
    neighbor list is delta-encoded independently (first gap = absolute id)
    and the concatenated gap stream is VByte-blocked, with ``gaps.bases``
    holding the *gap-stream running sum* at each block start
    (host-precomputed, 4 B/block) so the global inclusive cumsum is a fused
    per-block differential decode — no cross-block (hence cross-shard)
    prefix dependency. ``row_gap_bases`` [n_nodes] holds the running sum at
    each list start (4 B/row — the paper's skip-pointer idea applied to
    adjacency rows, §Perf gin-tu iteration 3). With it, the per-edge
    ``incl - row_gap_base`` subtraction is FUSED into the decode kernel's
    differential epilogue (``adjacency_rebase``): the edge-base grid is
    computed from metadata alone (no decode dependency), and the global
    cumsum stream never touches HBM. Without it, the per-list bases are
    gathered from the decoded stream (legacy global path).

    ``plan`` selects the dispatch path (``repro.kernels.vbyte_decode.
    dispatch``); ``use_kernel`` is the deprecated legacy boolean alias.

    Returns (src [E], dst [E]) int32 edge index.
    """
    from repro.kernels.vbyte_decode import dispatch

    if use_kernel is not None:
        from repro.core.compressed_array import warn_use_kernel

        plan = warn_use_kernel(use_kernel)
    nb = gaps.n_blocks
    block_size = gaps.block_size

    # edge e belongs to list l(e): row_offsets[l] <= e < row_offsets[l+1].
    # Pure-metadata computation — runs BEFORE (in parallel with) the decode.
    e_idx = jnp.arange(n_edges, dtype=jnp.int32)
    src = jnp.searchsorted(row_offsets, e_idx, side="right").astype(jnp.int32) - 1

    if row_gap_bases is not None:
        # fused one-pass path: per-edge rebase inside the kernel epilogue
        base = jnp.take(row_gap_bases, src).astype(jnp.uint32)  # [E]
        base = jnp.pad(base, (0, nb * block_size - n_edges))
        edge_base = jax.lax.bitcast_convert_type(base, jnp.int32)
        dst_grid = dispatch.decode(
            gaps, epilogue="adjacency_rebase",
            epilogue_operands={"edge_base": edge_base.reshape(nb, block_size)},
            plan=plan)
        dst = dst_grid.reshape(-1)[:n_edges]
        return dst, src  # neighbors are sources aggregated into the list owner

    # legacy global path: differential decode against per-block running-sum
    # bases = global inclusive cumsum of gaps, computed block-locally; the
    # per-list bases are then gathered from the decoded stream itself.
    incl = dispatch.decode(gaps, plan=plan)
    incl = incl.reshape(-1)[:n_edges].astype(jnp.uint32)
    gaps_v = incl - jnp.concatenate([jnp.zeros((1,), jnp.uint32), incl[:-1]])
    excl = incl - gaps_v
    base = jnp.take(excl, jnp.take(row_offsets, src))
    dst = (incl - base).astype(jnp.int32)
    return dst, src  # neighbors are sources aggregated into the list owner
