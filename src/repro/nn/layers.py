"""Minimal functional layer library (no flax — params are plain pytrees).

Every layer is an ``init(key, ...) -> params`` plus a pure ``apply`` function.
Compute dtype is bf16 by default (TPU target); params are stored f32
(master copy) and cast at use — see DESIGN.md §7.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

DEFAULT_COMPUTE_DTYPE = jnp.bfloat16

# f32 MXU accumulation on TPU. XLA-CPU's DotThunk cannot *execute*
# bf16×bf16→f32 (lowering is fine — the 512-device dry-run keeps f32
# accumulation in the HLO), so CPU execution falls back to the default
# accumulator. Evaluated lazily to avoid initializing the backend at import.
_ACCUM = "unset"


def accum_dtype():
    global _ACCUM
    if _ACCUM == "unset":
        _ACCUM = jnp.float32 if jax.default_backend() == "tpu" else None
    return _ACCUM


def truncated_normal_init(key, shape, stddev, dtype=jnp.float32):
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def dense_init(key, in_dim: int, out_dim: int, *, stddev: float | None = None):
    stddev = stddev if stddev is not None else 1.0 / math.sqrt(in_dim)
    return {"w": truncated_normal_init(key, (in_dim, out_dim), stddev)}


def dense(params, x, *, dtype=DEFAULT_COMPUTE_DTYPE):
    return x.astype(dtype) @ params["w"].astype(dtype)


def embedding_init(key, vocab: int, dim: int, *, stddev: float = 0.02):
    return {"emb": truncated_normal_init(key, (vocab, dim), stddev)}


def embedding_lookup(params, ids, *, dtype=DEFAULT_COMPUTE_DTYPE):
    return jnp.take(params["emb"].astype(dtype), ids, axis=0)


def rmsnorm_init(dim: int):
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rmsnorm(params, x, *, eps: float = 1e-5, dtype=DEFAULT_COMPUTE_DTYPE):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * params["scale"]).astype(dtype)


def layernorm_init(dim: int):
    return {"scale": jnp.ones((dim,), jnp.float32), "bias": jnp.zeros((dim,), jnp.float32)}


def layernorm(params, x, *, eps: float = 1e-6, dtype=DEFAULT_COMPUTE_DTYPE):
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    normed = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (normed * params["scale"] + params["bias"]).astype(dtype)


def swiglu_ffn_init(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, d_ff),
        "up": dense_init(k2, d_model, d_ff),
        "down": dense_init(k3, d_ff, d_model),
    }


def swiglu_ffn(params, x, *, dtype=DEFAULT_COMPUTE_DTYPE):
    g = dense(params["gate"], x, dtype=dtype)
    u = dense(params["up"], x, dtype=dtype)
    return dense(params["down"], jax.nn.silu(g) * u, dtype=dtype)


def mlp_init(key, dims: tuple[int, ...]):
    """Plain MLP tower (recsys): dims = (in, h1, ..., out)."""
    keys = jax.random.split(key, len(dims) - 1)
    return {
        f"layer_{i}": {
            **dense_init(keys[i], dims[i], dims[i + 1]),
            "b": jnp.zeros((dims[i + 1],), jnp.float32),
        }
        for i in range(len(dims) - 1)
    }


def mlp(params, x, *, act=jax.nn.relu, final_act: bool = False,
        dtype=DEFAULT_COMPUTE_DTYPE):
    n = len(params)
    for i in range(n):
        p = params[f"layer_{i}"]
        x = x.astype(dtype) @ p["w"].astype(dtype) + p["b"].astype(dtype)
        if i < n - 1 or final_act:
            x = act(x)
    return x
