"""Attention: GQA + RoPE + sliding-window, flash-style chunked softmax.

``flash_attention`` is a pure-JAX online-softmax attention (lax.scan over KV
chunks inside a scan over Q chunks) — O(S·chunk) activation memory, which is
what lets prefill_32k compile at 32k context without an attention kernel.
With ``banded=True`` and a sliding window, each Q chunk only visits the
KV chunks inside its band via dynamic_slice (compute drops from O(S²) to
O(S·window) — the SWA hillclimb lever).

``decode_attention`` is the single-token KV-cache path used by serve_step;
sliding-window archs use a ring-buffer cache of size ``window`` (Mistral's
rolling buffer), which is what makes long_500k O(window) memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .layers import DEFAULT_COMPUTE_DTYPE, accum_dtype

NEG_INF = -1e30


# ----------------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0,
               rotary_dim: int | None = None) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    rd = rotary_dim or d
    inv_freq = rope_frequencies(rd, theta)  # [rd/2]
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # [..., S, rd/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, rd/2]
    sin = jnp.sin(angles)[..., None, :]
    xr = x[..., :rd].astype(jnp.float32)
    x1, x2 = xr[..., : rd // 2], xr[..., rd // 2 :]
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    out = jnp.concatenate([rotated, x[..., rd:].astype(jnp.float32)], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# Flash-style training / prefill attention
# ----------------------------------------------------------------------------
def _band_mask(q_pos, k_pos, *, causal: bool, window: int | None):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


def flash_attention(
    q: jax.Array,  # [B, Sq, H, D]
    k: jax.Array,  # [B, Skv, Hk, D]
    v: jax.Array,  # [B, Skv, Hk, D]
    *,
    causal: bool = True,
    window: int | None = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    banded: bool = False,
    q_offset: int = 0,
    kv_offset: int = 0,  # absolute position of k[0] (chunked-prefill windows)
    kv_valid: jax.Array | None = None,  # bool [Skv]: which kv slots exist
    dtype=DEFAULT_COMPUTE_DTYPE,
) -> jax.Array:
    """Online-softmax chunked attention. Returns [B, Sq, H, D]."""
    B, Sq, H, D = q.shape
    _, Skv, Hk, _ = k.shape
    G = H // Hk
    scale = D ** -0.5

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    assert Sq % q_chunk == 0 and Skv % kv_chunk == 0, (Sq, q_chunk, Skv, kv_chunk)
    nq = Sq // q_chunk

    # [B, Hk, G, S, D] layout: grouped query heads over shared KV heads
    qg = (q.astype(dtype) * scale).reshape(B, Sq, Hk, G, D).transpose(0, 2, 3, 1, 4)
    kg = k.astype(dtype).transpose(0, 2, 1, 3)  # [B, Hk, Skv, D]
    vg = v.astype(dtype).transpose(0, 2, 1, 3)

    if banded and window is not None:
        # each q chunk reads a static-length KV band via dynamic_slice
        band = min(Skv, ((window + q_chunk + kv_chunk - 1) // kv_chunk) * kv_chunk)
    else:
        band = Skv
    nk = band // kv_chunk

    def q_step(qi):
        q_start = qi * q_chunk
        q_pos = q_offset + q_start + jnp.arange(q_chunk)
        qc = lax.dynamic_slice_in_dim(qg, q_start, q_chunk, axis=3)  # [B,Hk,G,qc,D]

        if band < Skv:
            band_start = jnp.clip(q_offset + q_start + q_chunk - band - kv_offset,
                                  0, Skv - band)
        else:
            band_start = 0
        kband = lax.dynamic_slice_in_dim(kg, band_start, band, axis=2)
        vband = lax.dynamic_slice_in_dim(vg, band_start, band, axis=2)
        valid_band = (lax.dynamic_slice_in_dim(kv_valid, band_start, band)
                      if kv_valid is not None else None)

        def kv_step(carry, ki):
            m_prev, l_prev, acc = carry
            k_start = ki * kv_chunk
            kc = lax.dynamic_slice_in_dim(kband, k_start, kv_chunk, axis=2)
            vc = lax.dynamic_slice_in_dim(vband, k_start, kv_chunk, axis=2)
            k_pos = kv_offset + band_start + k_start + jnp.arange(kv_chunk)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qc, kc,
                           preferred_element_type=accum_dtype())
            mask = _band_mask(q_pos, k_pos, causal=causal, window=window)
            if valid_band is not None:
                mask &= lax.dynamic_slice_in_dim(valid_band, k_start, kv_chunk)[None]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_prev, s.max(axis=-1))
            alpha = jnp.exp(m_prev - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_prev * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(dtype), vc,
                            preferred_element_type=accum_dtype())
            acc = acc * alpha[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, Hk, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hk, G, q_chunk), jnp.float32)
        acc0 = jnp.zeros((B, Hk, G, q_chunk, D), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, acc0), jnp.arange(nk))
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(dtype)

    if nq == 1:
        out = q_step(jnp.int32(0))[:, :, :, None]  # [B,Hk,G,1(qchunks),qc,D]
        out = out.reshape(B, Hk, G, Sq, D)
    else:
        outs = lax.map(q_step, jnp.arange(nq))  # [nq,B,Hk,G,qc,D]
        out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hk, G, Sq, D)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)


# ----------------------------------------------------------------------------
# Single-token decode with KV cache
# ----------------------------------------------------------------------------
def decode_attention(
    q: jax.Array,  # [B, H, D] — current token's queries (RoPE already applied)
    k_cache: jax.Array,  # [B, Sc, Hk, D]
    v_cache: jax.Array,  # [B, Sc, Hk, D]
    valid: jax.Array,  # bool [Sc] or [B, Sc] — which cache slots participate
    *,
    dtype=DEFAULT_COMPUTE_DTYPE,
) -> jax.Array:
    B, H, D = q.shape
    Sc, Hk = k_cache.shape[1], k_cache.shape[2]
    G = H // Hk
    qg = (q.astype(dtype) * D ** -0.5).reshape(B, Hk, G, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache.astype(dtype),
                   preferred_element_type=accum_dtype())
    if valid.ndim == 1:
        valid = valid[None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(dtype)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(dtype),
                     preferred_element_type=accum_dtype())
    return out.reshape(B, H, D).astype(dtype)


def cache_update(cache: jax.Array, new: jax.Array, slot: jax.Array) -> jax.Array:
    """Write new [B, Hk, D] into cache [B, Sc, Hk, D] at time slot (ring-safe)."""
    return lax.dynamic_update_slice_in_dim(cache, new[:, None], slot, axis=1)
