from . import attention, layers, moe  # noqa: F401
