"""EmbeddingBag built from gather + segment-sum.

JAX has no native EmbeddingBag (taxonomy §B.6/B.11) — this IS part of the
system: ragged multi-hot id bags are looked up with ``jnp.take`` and reduced
by ``jax.ops.segment_sum`` / ``segment_max``. The id lists themselves are
stored VByte-compressed (sorted ids → deltas) and decoded on device by the
paper's kernel before hitting this op.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import DEFAULT_COMPUTE_DTYPE


def embedding_bag(
    table: jax.Array,  # [V, d]
    ids: jax.Array,  # [N] int32 flat id stream
    segment_ids: jax.Array,  # [N] int32 bag index per id (sorted)
    n_bags: int,
    *,
    mode: str = "sum",
    weights: jax.Array | None = None,  # [N] per-sample weights
    valid: jax.Array | None = None,  # [N] bool mask for padded ids
    dtype=DEFAULT_COMPUTE_DTYPE,
) -> jax.Array:
    """Returns [n_bags, d]."""
    vecs = jnp.take(table.astype(dtype), ids, axis=0)  # [N, d]
    if weights is not None:
        vecs = vecs * weights[:, None].astype(dtype)
    if valid is not None:
        vecs = jnp.where(valid[:, None], vecs, 0)
    if mode == "sum":
        return jax.ops.segment_sum(vecs, segment_ids, num_segments=n_bags)
    if mode == "mean":
        s = jax.ops.segment_sum(vecs, segment_ids, num_segments=n_bags)
        ones = jnp.ones_like(ids, dtype) if valid is None else valid.astype(dtype)
        cnt = jax.ops.segment_sum(ones, segment_ids, num_segments=n_bags)
        return s / jnp.maximum(cnt, 1)[:, None]
    if mode == "max":
        if valid is not None:
            vecs = jnp.where(valid[:, None], vecs, -jnp.inf)
        out = jax.ops.segment_max(vecs, segment_ids, num_segments=n_bags)
        return jnp.where(jnp.isfinite(out), out, 0)
    raise ValueError(f"unknown mode {mode!r}")


def bag_from_padded(
    table: jax.Array,  # [V, d]
    padded_ids: jax.Array,  # [B, L] int32, padded with pad_id
    *,
    pad_id: int = 0,
    mode: str = "sum",
    dtype=DEFAULT_COMPUTE_DTYPE,
) -> jax.Array:
    """EmbeddingBag over fixed-width padded bags (the dense-batch fast path)."""
    B, L = padded_ids.shape
    vecs = jnp.take(table.astype(dtype), padded_ids, axis=0)  # [B, L, d]
    valid = (padded_ids != pad_id)[..., None]
    vecs = jnp.where(valid, vecs, 0)
    if mode == "sum":
        return vecs.sum(axis=1)
    if mode == "mean":
        return vecs.sum(axis=1) / jnp.maximum(valid.sum(axis=1), 1)
    raise ValueError(f"unknown mode {mode!r}")
