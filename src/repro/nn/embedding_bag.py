"""EmbeddingBag built from gather + segment-sum, plus the fused one-pass path.

JAX has no native EmbeddingBag (taxonomy §B.6/B.11) — this IS part of the
system: ragged multi-hot id bags are looked up with ``jnp.take`` and reduced
by ``jax.ops.segment_sum`` / ``segment_max``. The id lists themselves are
stored VByte-compressed (sorted ids → deltas).

Two consumption paths exist for compressed bags:

* decode → ``embedding_bag`` (the functions below): the decoded uint32
  stream round-trips through HBM between the decode kernel and the gather.
* ``embedding_bag_compressed``: the gather-sum runs INSIDE the decode
  kernel's epilogue (``repro.kernels.vbyte_decode`` ``bag_sum``) — one bag
  per compressed block, ids never leave VMEM. This is the one-pass path the
  dispatch layer picks by default.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import DEFAULT_COMPUTE_DTYPE


def embedding_bag(
    table: jax.Array,  # [V, d]
    ids: jax.Array,  # [N] int32 flat id stream
    segment_ids: jax.Array,  # [N] int32 bag index per id (sorted)
    n_bags: int,
    *,
    mode: str = "sum",
    weights: jax.Array | None = None,  # [N] per-sample weights
    valid: jax.Array | None = None,  # [N] bool mask for padded ids
    dtype=DEFAULT_COMPUTE_DTYPE,
) -> jax.Array:
    """Returns [n_bags, d]."""
    vecs = jnp.take(table.astype(dtype), ids, axis=0)  # [N, d]
    if weights is not None:
        vecs = vecs * weights[:, None].astype(dtype)
    if valid is not None:
        vecs = jnp.where(valid[:, None], vecs, 0)
    if mode == "sum":
        return jax.ops.segment_sum(vecs, segment_ids, num_segments=n_bags)
    if mode == "mean":
        s = jax.ops.segment_sum(vecs, segment_ids, num_segments=n_bags)
        ones = jnp.ones_like(ids, dtype) if valid is None else valid.astype(dtype)
        cnt = jax.ops.segment_sum(ones, segment_ids, num_segments=n_bags)
        return s / jnp.maximum(cnt, 1)[:, None]
    if mode == "max":
        if valid is not None:
            vecs = jnp.where(valid[:, None], vecs, -jnp.inf)
        out = jax.ops.segment_max(vecs, segment_ids, num_segments=n_bags)
        return jnp.where(jnp.isfinite(out), out, 0)
    raise ValueError(f"unknown mode {mode!r}")


def embedding_bag_compressed(
    table: jax.Array,  # [V, d]
    bags,  # CompressedIntArray (one bag per block; see encode_ragged), or dict
    *,
    format: str | None = None,
    block_size: int | None = None,
    differential: bool | None = None,
    mode: str = "sum",
    plan="auto",
    dtype=DEFAULT_COMPUTE_DTYPE,
) -> jax.Array:
    """Fused EmbeddingBag over a compressed id stream: one bag per block.

    ``bags`` is the ``CompressedIntArray`` from ``encode_ragged(...)`` (or
    any blocked layout where block b is bag b) — format/block metadata ride
    on the array, so the kwargs are only needed with a raw operand dict.
    Returns ``[n_blocks, d]``. The decode→``jnp.take``→``segment_sum``
    chain this replaces decodes the ids to HBM first; here the gather-sum
    is the decode kernel's epilogue and the ids stay in VMEM. A sharded
    ``bags`` (``CompressedIntArray.shard``) reduces each bag on the shard
    that owns its block.
    """
    from repro.kernels.vbyte_decode import dispatch

    counts = bags["counts"] if isinstance(bags, dict) else bags.counts
    out = dispatch.decode(
        bags,
        format=format,
        block_size=block_size,
        differential=differential,
        epilogue="bag_sum",
        epilogue_operands={"table": table.astype(dtype)},
        plan=plan,
    )
    if mode == "sum":
        return out
    if mode == "mean":
        counts = jnp.reshape(counts, (-1,)).astype(out.dtype)
        return out / jnp.maximum(counts, 1)[:, None]
    raise ValueError(f"unknown mode {mode!r} (fused path supports sum|mean)")


def bag_from_padded(
    table: jax.Array,  # [V, d]
    padded_ids: jax.Array,  # [B, L] int32, padded with pad_id
    *,
    pad_id: int = 0,
    mode: str = "sum",
    dtype=DEFAULT_COMPUTE_DTYPE,
) -> jax.Array:
    """EmbeddingBag over fixed-width padded bags (the dense-batch fast path)."""
    B, L = padded_ids.shape
    vecs = jnp.take(table.astype(dtype), padded_ids, axis=0)  # [B, L, d]
    valid = (padded_ids != pad_id)[..., None]
    vecs = jnp.where(valid, vecs, 0)
    if mode == "sum":
        return vecs.sum(axis=1)
    if mode == "mean":
        return vecs.sum(axis=1) / jnp.maximum(valid.sum(axis=1), 1)
    raise ValueError(f"unknown mode {mode!r}")
