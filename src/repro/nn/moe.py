"""Mixture-of-Experts FFN: top-k routing, capacity-based sort dispatch.

Dropless-ish GShard-style dispatch without the [tokens, E, C] one-hot tensor:
per-(token, k) expert slots are ranked with a stable argsort, written into an
[E, C, d] buffer, processed with stacked per-expert einsums, and combined
with router gates. Tokens past expert capacity are dropped (capacity factor
1.25 default).

**Local dispatch**: ranking/capacity run inside ``dispatch_groups`` vmapped
groups (set to the DP degree by the launcher). Under GSPMD this keeps the
argsort/scatter shard-local — a global sort would otherwise lower to a
distributed sorting network across the whole batch (production MoE systems
all dispatch per DP shard for exactly this reason). The group axis is
batch-sharded; the expert axis is sharded when ``ep_shard`` (EP), otherwise
d_ff is sharded inside each expert (TP). The token→expert resharding between
the two layouts is where GSPMD emits the all-to-all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.api import constrain

from .layers import DEFAULT_COMPUTE_DTYPE, accum_dtype, truncated_normal_init

DP = ("pod", "data")


def moe_init(key, d_model: int, d_ff: int, n_experts: int):
    kr, kg, ku, kd = jax.random.split(key, 4)
    se = d_model ** -0.5
    sf = d_ff ** -0.5
    return {
        "router": {"w": truncated_normal_init(kr, (d_model, n_experts), se)},
        "gate": {"w": truncated_normal_init(kg, (n_experts, d_model, d_ff), se)},
        "up": {"w": truncated_normal_init(ku, (n_experts, d_model, d_ff), se)},
        "down": {"w": truncated_normal_init(kd, (n_experts, d_ff, d_model), sf)},
    }


def _positions_within_expert(flat_e: jax.Array, n_experts: int) -> jax.Array:
    """Rank of each dispatch row within its expert (token order), via argsort."""
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    idx = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]]
    )
    seg_start = lax.cummax(jnp.where(is_start, idx, 0))
    pos_sorted = idx - seg_start
    return jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)


def _dispatch_group(x, top_e, top_p, *, n_experts: int, capacity: int, dtype):
    """One dispatch group: x [Tg, d] -> (buf [E*C, d], dst [Tg*K], gates)."""
    Tg, d = x.shape
    K = top_e.shape[-1]
    E, C = n_experts, capacity
    flat_e = top_e.reshape(-1).astype(jnp.int32)
    pos = _positions_within_expert(flat_e, E)
    keep = pos < C
    dst = jnp.where(keep, flat_e * C + pos, E * C)  # E*C = drop bin

    token_of_row = jnp.repeat(jnp.arange(Tg, dtype=jnp.int32), K)
    inv = jnp.full((E * C,), Tg, jnp.int32).at[dst].set(token_of_row, mode="drop")
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    buf = jnp.take(x_pad, jnp.minimum(inv, Tg), axis=0).astype(dtype)  # [E*C, d]
    gates = jnp.where(keep, top_p.reshape(-1), 0.0).astype(dtype)
    return buf, dst, gates, keep


def moe_apply(
    params,
    x: jax.Array,  # [T, d]
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    renormalize: bool = True,  # mixtral/olmoe renormalize top-k probs
    ep_shard: bool = False,  # expert-parallel (E divides model axis)
    dispatch_groups: int = 1,  # set to DP degree: keeps ranking shard-local
    model_axis: str = "model",
    dtype=DEFAULT_COMPUTE_DTYPE,
):
    """Returns (out [T, d], aux_metrics dict with load-balance loss)."""
    T, d = x.shape
    E = params["gate"]["w"].shape[0]
    K = top_k
    G = dispatch_groups if T % dispatch_groups == 0 else 1
    Tg = T // G
    C = max(1, int(Tg * K * capacity_factor / E))

    logits = x.astype(jnp.float32) @ params["router"]["w"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # [T, E]
    top_p, top_e = lax.top_k(probs, K)  # [T, K]
    if renormalize:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    xg = x.reshape(G, Tg, d)
    eg = top_e.reshape(G, Tg, K)
    pg = top_p.reshape(G, Tg, K)
    buf, dst, gates, keep = jax.vmap(
        lambda a, b, c: _dispatch_group(a, b, c, n_experts=E, capacity=C, dtype=dtype)
    )(xg, eg, pg)

    buf = buf.reshape(G, E, C, d)
    ep = model_axis if ep_shard else None
    buf = constrain(buf, DP, ep, None, None)

    g = jnp.einsum("gecd,edf->gecf", buf, params["gate"]["w"].astype(dtype),
                   preferred_element_type=accum_dtype()).astype(dtype)
    u = jnp.einsum("gecd,edf->gecf", buf, params["up"]["w"].astype(dtype),
                   preferred_element_type=accum_dtype()).astype(dtype)
    h = jax.nn.silu(g) * u
    h = constrain(h, DP, ep, None, None if ep_shard else model_axis)
    y = jnp.einsum("gecf,efd->gecd", h, params["down"]["w"].astype(dtype),
                   preferred_element_type=accum_dtype()).astype(dtype)
    y = constrain(y, DP, ep, None, None)
    y = y.reshape(G, E * C, d)

    # combine: gather each dispatch row's expert output, weight by its gate
    def combine(y_g, dst_g, gates_g):
        y_pad = jnp.concatenate([y_g, jnp.zeros((1, d), y_g.dtype)], axis=0)
        rows = jnp.take(y_pad, jnp.minimum(dst_g, E * C), axis=0)  # [Tg*K, d]
        return (rows * gates_g[:, None]).reshape(Tg, K, d).sum(axis=1)

    out = jax.vmap(combine)(y, dst, gates).reshape(T, d)
    out = constrain(out, DP, None)

    # switch-style load-balance loss (global, cheap)
    flat_e = top_e.reshape(-1)
    frac = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (T * K)
    mean_p = probs.mean(axis=0)
    aux_loss = E * jnp.sum(frac * mean_p)
    dropped = 1.0 - keep.mean()
    return out, {"moe_aux_loss": aux_loss, "moe_drop_frac": dropped}
