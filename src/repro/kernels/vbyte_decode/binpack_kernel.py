"""Pallas TPU kernel: blocked binpack decode with fused differential sum.

The two VByte kernels spend their routing budget *finding* integer
boundaries — continuation-bit prefix sums (``kernel.py``) or control-stream
length prefix sums (``stream_kernel.py``). Binpack (Lemire & Boytsov's
binary packing) has no boundaries to find: every integer of a width-``w``
block starts at bit ``j·w``, so this kernel has **no prefix sum over
lengths at all** — the byte→integer routing collapses to one static-index
one-hot gather:

  * bit position ``j·w`` and byte offset ``(j·w) >> 3`` via plain VPU
    integer math on the broadcast width column (no matmul, no scan),
  * the ≤40-bit window holding each value is fetched by ONE ``[T, B, S]``
    one-hot **MXU** gather against five statically-shifted copies of the
    data tile, byte-packed into two f32 operands: ``grp012 = b0 + b1·2^8 +
    b2·2^16 < 2^24`` (f32-exact, single-nonzero one-hot rows) and
    ``grp34 = b3 + b4·2^8 < 2^16`` — two batched matmuls total,
  * extraction is a branch-free ``(lo24 >> s) | (hi16 << (24 - s))`` with
    ``s ∈ 0..7`` (shift amounts stay in 1..24 — no 32-bit-shift hazard)
    masked to ``w`` bits,
  * fused differential prefix sum via the shared triangular-matmul helper.

This is why binpack wins on dense low-width gap blocks: the per-tile MXU
work is two ``[T,B,S]`` contractions and zero routing scans, versus the
VByte kernels' prefix-sum + scatter pipelines. All tensors live in VMEM;
``chunk_width`` is accepted for dispatch parity and ignored — there is no
length scan to chunk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .kernel import prefix_sum_tile

GATHER_BYTES = 5  # shift ≤ 7 bits + width ≤ 32 bits spans at most 5 bytes


def _shift_left_cols(x: jax.Array, k: int) -> jax.Array:
    """x[..., i+k] with zero fill — static slices only (Mosaic-safe)."""
    t, s = x.shape
    if k == 0:
        return x
    return jnp.concatenate([x[:, k:], jnp.zeros((t, k), x.dtype)], axis=1)


def binpack_decode_tile(widths: jax.Array, data: jax.Array, counts: jax.Array,
                        *, block_size: int,
                        chunk_width: int | None = None
                        ) -> tuple[jax.Array, jax.Array]:
    """Decode one VMEM tile of binpack-packed bytes.

    ``widths`` is the ``uint8 [T, 1]`` per-block bit-width column, ``data``
    the ``uint8 [T, S]`` packed tile, ``counts`` the ``int32 [T, 1]``
    valid-integer counts. Same ``(out int32 [T, B], valid bool [T, B])``
    contract as ``kernel.decode_tile`` — every fused epilogue plugs in
    unchanged.

    Byte offsets are clamped to ``S - 1``: valid integers end inside
    ``ceil(count·w/8) ≤ S`` bytes by construction, so a clamped read only
    feeds bits the width mask discards or lanes the valid mask zeroes.
    """
    del chunk_width  # positions are affine in j — nothing to chunk
    T, S = data.shape
    B = block_size

    w = widths.astype(jnp.int32)  # [T, 1]
    jrow = lax.broadcasted_iota(jnp.int32, (T, B), 1)
    bitpos = jrow * w  # [T, B], < B·32 = 2^12 at B=128
    byte0 = jnp.minimum(bitpos >> 3, S - 1)
    shift = bitpos & 7

    # five statically-shifted data copies, byte-packed into two operands so
    # the 5-byte window costs two MXU contractions instead of five
    b = data.astype(jnp.int32)
    d = [_shift_left_cols(b, k) for k in range(GATHER_BYTES)]
    grp012 = (d[0] + (d[1] << 8) + (d[2] << 16)).astype(jnp.float32)  # < 2^24
    grp34 = (d[3] + (d[4] << 8)).astype(jnp.float32)  # < 2^16

    # one-hot MXU gather: lo24[t,j] = grp012[t, byte0[t,j]] (rows have a
    # single nonzero and operands < 2^24, so f32 accumulation is exact)
    ivec = lax.broadcasted_iota(jnp.int32, (T, B, S), 2)
    onehot = (byte0[:, :, None] == ivec).astype(jnp.float32)  # [T, B, S]
    dnums = (((2,), (1,)), ((0,), (0,)))  # contract over S, batch over T
    lo24 = lax.dot_general(onehot, grp012, dnums,
                           preferred_element_type=jnp.float32).astype(jnp.int32)
    hi16 = lax.dot_general(onehot, grp34, dnums,
                           preferred_element_type=jnp.float32).astype(jnp.int32)

    # lo24 < 2^24 is non-negative (>> is logical); 24 - shift ∈ 17..24;
    # (1 << 31) - 1 wraps to 0x7FFFFFFF in int32 — still the right mask,
    # and w = 32 takes the all-ones branch
    val = (lo24 >> shift) | (hi16 << (24 - shift))
    mask = jnp.where(w >= 32, jnp.int32(-1),
                     (jnp.int32(1) << jnp.minimum(w, 31)) - 1)
    out = val & mask

    valid = jrow < counts  # [T, B] < [T, 1]
    out = jnp.where(valid, out, 0)
    return out, valid


def _binpack_decode_tile_kernel(widths_ref, data_ref, counts_ref, bases_ref,
                                out_ref, *, block_size: int,
                                differential: bool,
                                chunk_width: int | None):
    out, valid = binpack_decode_tile(widths_ref[...], data_ref[...],
                                     counts_ref[...], block_size=block_size,
                                     chunk_width=chunk_width)
    if differential:
        out = prefix_sum_tile(out, valid, bases_ref[...])
    out_ref[...] = out


def binpack_decode_blocked_pallas(
    widths: jax.Array,  # uint8 [n_blocks, 1]
    data: jax.Array,  # uint8 [n_blocks, stride]
    counts: jax.Array,  # int32 [n_blocks, 1]
    bases: jax.Array,  # int32 [n_blocks, 1] (bitcast of uint32)
    *,
    block_size: int,
    differential: bool,
    block_tile: int = 8,
    chunk_width: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Raw pallas_call wrapper; see ops.binpack_decode_blocked."""
    nb, stride = data.shape
    if widths.shape != (nb, 1):
        raise ValueError(f"widths shape {widths.shape} != ({nb}, 1)")
    if nb % block_tile:
        raise ValueError(f"n_blocks={nb} must be a multiple of block_tile={block_tile}")
    grid = (nb // block_tile,)
    kernel = functools.partial(
        _binpack_decode_tile_kernel, block_size=block_size,
        differential=differential, chunk_width=chunk_width,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_tile, 1), lambda g: (g, 0)),
            pl.BlockSpec((block_tile, stride), lambda g: (g, 0)),
            pl.BlockSpec((block_tile, 1), lambda g: (g, 0)),
            pl.BlockSpec((block_tile, 1), lambda g: (g, 0)),
        ],
        out_specs=pl.BlockSpec((block_tile, block_size), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block_size), jnp.int32),
        interpret=interpret,
    )(widths, data, counts, bases)
