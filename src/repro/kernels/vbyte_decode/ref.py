"""Pure-jnp oracle for the Pallas vbyte-decode kernel's blocked semantics.

Deliberately implemented with a *different* strategy than both the kernel
(one-hot MXU scatter) and ``repro.core.vbyte.masked`` (segment-sum): here each
output integer *gathers* its ≤5 source bytes via searchsorted offsets. Three
independent implementations agreeing is the correctness story.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_U32 = jnp.uint32


def _decode_one_block(payload_row: jax.Array, count: jax.Array, base: jax.Array,
                      block_size: int, differential: bool) -> jax.Array:
    S = payload_row.shape[0]
    b = payload_row.astype(jnp.int32)
    end = 1 - (b >> 7)  # terminator flags
    term_count = jnp.cumsum(end)  # inclusive count of terminators
    j = jnp.arange(block_size, dtype=jnp.int32)
    # index of the j-th terminator byte (end of integer j)
    term_idx = jnp.searchsorted(term_count, j + 1, side="left").astype(jnp.int32)
    start = jnp.concatenate([jnp.zeros((1,), jnp.int32), term_idx[:-1] + 1])
    length = term_idx - start + 1
    k = jnp.arange(5, dtype=jnp.int32)[None, :]
    src = jnp.clip(start[:, None] + k, 0, S - 1)
    bytes_jk = jnp.take(payload_row, src).astype(_U32)
    used = k < length[:, None]
    vals = jnp.where(used, (bytes_jk & _U32(0x7F)) << (7 * k).astype(_U32), _U32(0))
    out = vals.sum(axis=1, dtype=_U32)
    out = jnp.where(j < count, out, _U32(0))
    if differential:
        out = base.astype(_U32) + jnp.cumsum(out, dtype=_U32)
        out = jnp.where(j < count, out, _U32(0))
    return out


@functools.partial(jax.jit, static_argnames=("block_size", "differential"))
def vbyte_decode_blocked_ref(payload: jax.Array, counts: jax.Array, bases: jax.Array,
                             *, block_size: int, differential: bool) -> jax.Array:
    """uint32[n_blocks, block_size], zero-padded — gather-based oracle."""
    fn = functools.partial(
        _decode_one_block, block_size=block_size, differential=differential
    )
    return jax.vmap(fn)(payload, counts.astype(jnp.int32), bases)
