"""Autotuned dispatch for the blocked decode kernels (both formats).

Single entry point (:func:`decode`) that picks the execution plan — Pallas
kernel vs vectorized jnp decoder, fused vs unfused epilogue, ``block_tile``
— replacing the ad-hoc ``use_kernel`` booleans that used to be threaded
through ``compressed_array.py``, ``models/recsys.py`` and ``nn/gnn.py``.

A :class:`DecodePlan` names one concrete path:

* ``path="pallas"`` — the Pallas kernels (Mosaic on TPU, interpret on CPU).
* ``path="jnp"``    — the vectorized jnp decoders (XLA-CPU SIMD proxy).
* ``fused=True``    — decode and consumer epilogue run as ONE program: the
  fused Pallas kernel on TPU, or a single jit (one XLA executable, no
  materialized id-stream round-trip between dispatches) on CPU.
* ``fused=False``   — two programs: decode the ``uint32 [n_blocks, B]``
  grid, then apply the epilogue in a second dispatch (the legacy shape of
  every call site before this layer existed).

``plan="auto"`` consults a small measured autotune cache persisted under
``experiments/autotune.json`` (:func:`autotune` populates it; run via
``python -m benchmarks.run --only fused``). With no cache entry the
heuristic default is the fused path on the current backend. Legacy string
plans keep old call sites working: ``"kernel"`` → Pallas, ``"jnp"`` → jnp,
``"fused"``/``"unfused"`` force fusion on the default path.

**Sharded block-parallel decode.** Because every block decodes
independently (per-block ``counts``/``bases`` carry all cross-block
state), a compressed stream whose block dimension is placed across a mesh
axis (``CompressedIntArray.shard(mesh, axis="data")``) decodes where it
lives: :func:`decode` detects block-sharded operands and runs the chosen
single-device plan **per shard** under ``shard_map`` — same decode-tile
code, zero cross-device decode traffic, so the sharded result is bit-exact
with the single-device path by construction (fused epilogues included:
each block's bag/score/rebase output is block-local). ``plan="sharded"``
forces this path (raises if the operands aren't sharded); otherwise it is
auto-selected. Detection needs concrete arrays — call :func:`decode`
outside any enclosing ``jit`` (it jits internally) to use it.
"""
from __future__ import annotations

import functools
import json
import os
import time
from dataclasses import asdict, dataclass, replace

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.obs import counter_inc as _obs_counter_inc, trace as _obs_trace

from repro.core.vbyte import binpack_masked as bpk_masked
from repro.core.vbyte import masked as vmasked
from repro.core.vbyte import stream_masked as svb_masked

from . import epilogues as eplib
from .ops import (binpack_decode_blocked, normalize_block_meta,
                  stream_vbyte_decode_blocked, vbyte_decode_blocked)

# cache lives under the repo's experiments/ dir (resolved relative to this
# file, NOT the process cwd — library call sites run from anywhere); the
# REPRO_AUTOTUNE_CACHE env var overrides. Falls back to a cwd-relative path
# when the source tree layout isn't present (installed package).
_SRC_DIR = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))  # <repo>/src in-tree
DEFAULT_CACHE_PATH = (
    os.path.join(os.path.dirname(_SRC_DIR), "experiments", "autotune.json")
    if os.path.basename(_SRC_DIR) == "src"
    else "experiments/autotune.json")

# broadcast epilogue operands (embedding tables) above this size cannot be
# VMEM-resident per grid step on TPU; the fused Pallas plan falls back to
# pallas-decode + jnp epilogue (a vocab-tiled grid dimension with masked
# partial sums is the real fix — see docs/kernels.md §TPU notes)
VMEM_BROADCAST_BUDGET = 4 << 20


@dataclass(frozen=True)
class DecodePlan:
    """One concrete decode execution plan (see module docstring).

    ``chunk`` is the banded-scatter chunk width W: ``None`` runs the dense
    O(S·B) routing, an integer W the chunked O(S·W) routing (see
    ``banded.py``). On the Pallas path it selects the banded tile cores;
    on the jnp path the chunked prefix decomposition of the vectorized
    decoders. Both produce bit-identical uint32 grids, so the axis is a
    pure perf knob — which is why it lives on the autotuned plan.
    """

    path: str  # "pallas" | "jnp" | "ref" (gather-lowered; GSPMD-friendly)
    fused: bool = True
    block_tile: int = 8
    chunk: int | None = None  # banded-scatter chunk width W (None = dense)

    def __post_init__(self):
        if self.path not in ("pallas", "jnp", "ref"):
            raise ValueError(f"unknown plan path {self.path!r}")
        if self.chunk is not None and (self.chunk <= 0 or self.chunk % 8):
            raise ValueError(
                f"plan chunk width must be a positive multiple of 8 or "
                f"None; got {self.chunk!r}")

    @property
    def label(self) -> str:
        return f"{self.path}{'_fused' if self.fused else '_unfused'}" \
               + (f"_bt{self.block_tile}" if self.path == "pallas" else "") \
               + (f"_w{self.chunk}" if self.chunk is not None else "")


# ---------------------------------------------------------------------------
# plan resolution + persisted autotune cache
# ---------------------------------------------------------------------------
_CACHE: dict | None = None
_CACHE_FILE: str | None = None

# Autotune-cache schema version. Bumped to 2 when "binpack" became a third
# format: older caches were measured in a two-format world (candidate sets,
# default chunk widths, and cost trade-offs that no longer hold) and carry
# no schema tag at all, so version-mismatched entries are dropped on load
# and the plan resolver falls back to the heuristic default instead of
# mis-resolving from a stale measurement.
CACHE_SCHEMA = 2


def cache_path() -> str:
    return os.environ.get("REPRO_AUTOTUNE_CACHE", DEFAULT_CACHE_PATH)


def cache_key(format: str, epilogue: str, block_size: int,
              backend: str | None = None) -> str:
    backend = backend or jax.default_backend()
    return f"{backend}/{format}/{epilogue}/bs{block_size}"


def _migrate_cache(raw: dict) -> dict:
    """Drop entries from a different (or missing) schema version."""
    if not isinstance(raw, dict):
        return {}
    return {k: v for k, v in raw.items()
            if isinstance(v, dict) and v.get("schema") == CACHE_SCHEMA}


def load_cache(path: str | None = None, *, reload: bool = False) -> dict:
    global _CACHE, _CACHE_FILE
    path = path or cache_path()
    if _CACHE is None or _CACHE_FILE != path or reload:
        _CACHE_FILE = path
        try:
            with open(path) as f:
                _CACHE = _migrate_cache(json.load(f))
        except (OSError, ValueError):
            _CACHE = {}
    return _CACHE


# per-format default banded chunk width: the smallest W that clears the
# ≥4x modeled routing-MAC reduction at default shapes without shrinking
# the MXU tiles below usefulness (docs/kernels.md §Banded chunked scatter).
# binpack has no length scan — the chunk axis doesn't exist for it.
DEFAULT_CHUNK = {"vbyte": 64, "streamvbyte": 32, "binpack": None}


def default_plan(epilogue: str = "stream",
                 format: str = "vbyte") -> DecodePlan:
    """Heuristic when the cache has no measurement for a workload."""
    if jax.default_backend() == "tpu":
        return DecodePlan("pallas", fused=True, block_tile=8,
                          chunk=DEFAULT_CHUNK.get(format, 64))
    # CPU proxy: interpret-mode Pallas is a correctness path, not a perf
    # path; the jnp decoders vectorize through XLA-CPU. Fusion still wins
    # (one executable, no id-stream round-trip) — see benchmarks.json.
    return DecodePlan("jnp", fused=True)


def _clamp_chunk(chunk: int | None, block_size: int) -> int | None:
    """Shrink a heuristic chunk width to the workload's block size (a band
    can't be wider than the output row); None when no multiple of 8 fits."""
    if chunk is None or chunk <= block_size:
        return chunk
    clamped = (block_size // 8) * 8
    return clamped or None


def resolve_plan(plan, *, format: str, epilogue: str,
                 block_size: int) -> DecodePlan:
    if isinstance(plan, DecodePlan):
        return plan
    if plan in (None, "auto"):
        entry = load_cache().get(cache_key(format, epilogue, block_size))
        if entry and "plan" in entry:
            _obs_counter_inc("plan_cache_total", result="hit")
            p = entry["plan"]
            return DecodePlan(p["path"], p["fused"], p.get("block_tile", 8),
                              p.get("chunk"))
        _obs_counter_inc("plan_cache_total", result="miss")
        d = default_plan(epilogue, format)
        return replace(d, chunk=_clamp_chunk(d.chunk, block_size))
    if plan in ("kernel", "pallas"):
        return DecodePlan("pallas", fused=True)
    if plan == "jnp":
        return DecodePlan("jnp", fused=True)
    if plan == "ref":
        return DecodePlan("ref", fused=False)
    if plan == "fused":
        return DecodePlan(default_plan(epilogue, format).path, fused=True)
    if plan == "unfused":
        return DecodePlan(default_plan(epilogue, format).path, fused=False)
    if plan == "banded":
        return replace(default_plan(epilogue, format),
                       chunk=_clamp_chunk(DEFAULT_CHUNK.get(format, 64),
                                          block_size))
    if plan == "dense":
        return replace(default_plan(epilogue, format), chunk=None)
    raise ValueError(
        f"unknown plan {plan!r}; expected a DecodePlan or one of "
        "'auto', 'kernel', 'pallas', 'jnp', 'fused', 'unfused', "
        "'banded', 'dense'")


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------
def _decode_grid(operands: dict, *, format: str, block_size: int,
                 differential: bool, plan: DecodePlan) -> jax.Array:
    """Step-1 decode to the uint32 [n_blocks, block_size] grid."""
    if plan.path == "pallas":
        fn = {"vbyte": vbyte_decode_blocked,
              "streamvbyte": stream_vbyte_decode_blocked,
              "binpack": binpack_decode_blocked}[format]
        return fn(**operands, block_size=block_size, differential=differential,
                  block_tile=plan.block_tile, chunk_width=plan.chunk)
    if plan.path == "ref":
        if format != "vbyte":
            raise ValueError(
                "plan path 'ref' (the gather-lowered decoder) only exists "
                f"for format='vbyte'; got {format!r} — stream_masked is "
                "already gather-based, use path 'jnp'")
        # gather-lowered decoder: the scatter-based masked path emits a
        # cross-shard scatter-add under GSPMD; the searchsorted/gather
        # lowering stays block-local (§Perf retrieval iteration 2)
        from .ref import vbyte_decode_blocked_ref

        return vbyte_decode_blocked_ref(
            **operands, block_size=block_size, differential=differential)
    dec = {"vbyte": vmasked.decode_blocked,
           "streamvbyte": svb_masked.decode_blocked,
           "binpack": bpk_masked.decode_blocked}[format]
    return dec(**operands, block_size=block_size, differential=differential,
               chunk_width=plan.chunk)


@functools.partial(
    jax.jit, static_argnames=("format", "epilogue", "block_size",
                              "differential", "chunk_width")
)
def _jnp_fused(operands: dict, extras: dict, *, format: str, epilogue: str,
               block_size: int, differential: bool,
               chunk_width: int | None = None):
    """Fused CPU path: decode + epilogue in ONE XLA executable.

    The optimization barrier pins the decoded grid as a fusion boundary:
    without it XLA-CPU may inline the whole decode into the epilogue's
    gather-index computation (producer recompute), which is slower than
    keeping the grid as an in-executable intermediate. The grid still never
    crosses a dispatch boundary — that round trip is what fusion removes.
    """
    dec = {"vbyte": vmasked.decode_blocked,
           "streamvbyte": svb_masked.decode_blocked,
           "binpack": bpk_masked.decode_blocked}[format]
    grid = dec(**operands, block_size=block_size, differential=differential,
               chunk_width=chunk_width)
    grid = lax.optimization_barrier(grid)
    return eplib.apply_grid(epilogue, grid, operands["counts"], extras)


@functools.partial(jax.jit, static_argnames=("epilogue",))
def _apply_only(grid: jax.Array, counts: jax.Array, extras: dict, *,
                epilogue: str):
    """Unfused step 2: the epilogue as its own dispatch (reference shape)."""
    return eplib.apply_grid(epilogue, grid, counts, extras)


def _execute(operands: dict, extras: dict, *, format: str, epilogue: str,
             block_size: int, differential: bool, plan: DecodePlan,
             interpret: bool | None = None):
    """Run one resolved plan on (already validated/normalized) operands.

    This is the single-device execution body; the sharded path runs exactly
    this function per shard under ``shard_map``, which is what makes the
    sharded decode bit-exact with the single-device one by construction.
    """
    ep = eplib.get_epilogue(epilogue)
    if epilogue == "stream":
        return _decode_grid(operands, format=format, block_size=block_size,
                            differential=differential, plan=plan)

    if plan.path == "pallas" and plan.fused:
        # broadcast extras (tables) must be VMEM-resident per grid step;
        # past the budget, degrade to pallas-decode + jnp epilogue instead
        # of failing Mosaic compilation (docs/kernels.md §TPU notes)
        broadcast_bytes = sum(
            int(np.prod(v.shape)) * v.dtype.itemsize
            for k, v in extras.items() if k not in ep.tiled_extras)
        if broadcast_bytes <= VMEM_BROADCAST_BUDGET:
            return eplib.fused_decode(
                operands, extras, format=format, epilogue=epilogue,
                block_size=block_size, differential=differential,
                block_tile=plan.block_tile, chunk_width=plan.chunk,
                interpret=interpret)
        plan = DecodePlan("pallas", fused=False, block_tile=plan.block_tile,
                          chunk=plan.chunk)
    if plan.path == "jnp" and plan.fused:
        return _jnp_fused(operands, extras, format=format, epilogue=epilogue,
                          block_size=block_size, differential=differential,
                          chunk_width=plan.chunk)
    # unfused: decode grid, then the epilogue as a second dispatch
    grid = _decode_grid(operands, format=format, block_size=block_size,
                        differential=differential, plan=plan)
    return _apply_only(grid, operands["counts"], extras, epilogue=epilogue)


# ---------------------------------------------------------------------------
# sharded block-parallel execution (shard_map over the block dimension)
# ---------------------------------------------------------------------------
def operand_mesh_axes(operands: dict):
    """``(mesh, block_axes)`` when every operand's block dim is sharded over
    a >1-device mesh axis with ``NamedSharding``; ``None`` otherwise.

    Tracers (operands seen under an enclosing ``jit``) have no concrete
    sharding — detection then returns ``None`` and the single-device body
    runs, which GSPMD partitions as usual.
    """
    mesh = None
    axes = None
    for v in operands.values():
        try:
            sh = v.sharding
        except Exception:
            return None
        if not isinstance(sh, NamedSharding):
            return None
        spec = tuple(sh.spec) + (None,) * (v.ndim - len(sh.spec))
        a = spec[0]
        a = (a,) if isinstance(a, str) else tuple(a or ())
        if any(x is not None for x in spec[1:]):
            return None  # only block-dim sharding is block-parallel-safe
        if mesh is None:
            mesh, axes = sh.mesh, a
        elif sh.mesh != mesh or a != axes:
            return None
    if mesh is None or not axes:
        return None
    n_shards = 1
    for name in axes:
        n_shards *= mesh.shape[name]
    return (mesh, axes) if n_shards > 1 else None


@functools.lru_cache(maxsize=128)
def _build_sharded_fn(mesh, axes: tuple, format: str, epilogue: str,
                      block_size: int, differential: bool, plan: DecodePlan,
                      interpret: bool | None, multi_query: bool,
                      extra_keys: tuple = ()):
    """jit(shard_map(execute-body)) for one (mesh, workload) — cached so
    repeated serving calls reuse one trace. Exposed for tests (the compiled
    HLO must contain no cross-device collectives). ``extra_keys`` is the
    actual epilogue-operand key set for this call (epilogues with optional
    operands, e.g. the format-tagged weight streams, vary it)."""
    ep = eplib.get_epilogue(epilogue)
    spec_block = P(axes, None)
    in_operands = {k: spec_block for k in eplib.FORMAT_OPERANDS[format]}
    in_operands.update(counts=P(axes), bases=P(axes))
    in_extras = {k: (spec_block if k in ep.tiled_extras else P())
                 for k in extra_keys}
    if epilogue == "dot_score":
        out_specs = (spec_block,
                     P(axes, None, None) if multi_query else spec_block)
    elif epilogue == "checksum":
        # (decoded grid, per-block checksum column) — both block-leading
        out_specs = (spec_block, spec_block)
    else:
        # stream / bag_sum / adjacency_rebase / membership / bm25_accum:
        # one [nb, ·] output whose leading dim is the block dim
        out_specs = spec_block

    body = functools.partial(
        _execute, format=format, epilogue=epilogue, block_size=block_size,
        differential=differential, plan=plan, interpret=interpret)
    return jax.jit(shard_map(
        lambda operands, extras: body(operands, extras),
        mesh=mesh, in_specs=(in_operands, in_extras), out_specs=out_specs,
        check_rep=False))


def decode(
    operands,  # CompressedIntArray, or device_operands()-style dict
    *,
    format: str | None = None,
    block_size: int | None = None,
    differential: bool | None = None,
    epilogue: str = "stream",
    epilogue_operands: dict | None = None,
    plan: DecodePlan | str | None = "auto",
    interpret: bool | None = None,
):
    """Decode a blocked compressed stream, optionally fused into a consumer.

    ``operands`` is either a ``CompressedIntArray`` (format/block_size/
    differential come from its static aux data) or the raw operand dict
    (``payload`` | ``control``/``data`` + ``counts``/``bases``), in which
    case the three metadata kwargs are required.

    Returns the epilogue's output: the ``uint32 [n_blocks, block_size]``
    grid for ``epilogue="stream"``, ``[n_blocks, d]`` bag sums for
    ``"bag_sum"``, ``(ids, scores)`` for ``"dot_score"``, rebased edge ids
    for ``"adjacency_rebase"``.

    When the operands' block dimension is sharded over a >1-device mesh
    axis (``CompressedIntArray.shard``), the plan runs per shard under
    ``shard_map`` — block-parallel, no cross-device decode traffic.
    ``plan="sharded"`` forces that path and raises if operands aren't
    sharded.
    """
    from repro.core.compressed_array import CompressedIntArray

    if isinstance(operands, CompressedIntArray):
        arr = operands
        operands = arr.device_operands()
        format = arr.format if format is None else format
        block_size = arr.block_size if block_size is None else block_size
        differential = (arr.differential if differential is None
                        else differential)
    if format is None or block_size is None or differential is None:
        raise ValueError(
            "format=/block_size=/differential= are required when operands "
            "are a raw dict (pass a CompressedIntArray to omit them)")
    if format not in eplib.FORMAT_OPERANDS:
        raise ValueError(f"unknown format {format!r}; expected one of "
                         f"{tuple(eplib.FORMAT_OPERANDS)}")
    ep = eplib.get_epilogue(epilogue)
    extras = dict(epilogue_operands or {})
    ep.check(differential, extras)
    force_sharded = plan == "sharded"
    p = resolve_plan("auto" if force_sharded else plan, format=format,
                     epilogue=epilogue, block_size=block_size)

    fmt_keys = eplib.FORMAT_OPERANDS[format] + ("counts", "bases")
    missing = [k for k in fmt_keys if k not in operands]
    if missing:
        raise ValueError(f"format {format!r} operands missing {missing}")
    nb = operands[fmt_keys[0]].shape[0]
    operands = {k: operands[k] for k in fmt_keys}
    operands["counts"] = normalize_block_meta("counts", operands["counts"], nb)
    operands["bases"] = normalize_block_meta("bases", operands["bases"], nb)

    mesh_axes = operand_mesh_axes(operands)
    if force_sharded and mesh_axes is None:
        raise ValueError(
            "plan='sharded' requires operands whose block dimension is "
            "sharded over a >1-device mesh axis — use "
            "CompressedIntArray.shard(mesh, axis=...) first")
    _obs_counter_inc("decode_calls_total", plan=p.label, format=format,
                     epilogue=epilogue)
    with _obs_trace("decode", format=format, plan=p.label, epilogue=epilogue,
                    blocks=int(nb), chunk=p.chunk,
                    sharded=mesh_axes is not None):
        if mesh_axes is not None:
            mesh, axes = mesh_axes
            q = extras["query"] if epilogue == "dot_score" else None
            multi_query = bool(q is not None and q.size // q.shape[-1] > 1)
            fn = _build_sharded_fn(mesh, axes, format, epilogue, block_size,
                                   differential, p, interpret, multi_query,
                                   tuple(sorted(extras)))
            return fn(operands, extras)
        return _execute(operands, extras, format=format, epilogue=epilogue,
                        block_size=block_size, differential=differential,
                        plan=p, interpret=interpret)


# ---------------------------------------------------------------------------
# measured autotune
# ---------------------------------------------------------------------------
def _time_call(fn, *, reps: int, warmup: int) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn())
    del out
    return (time.perf_counter() - t0) / reps


def _synthetic_workload(format: str, *, n_blocks: int, block_size: int,
                        vocab: int, d: int, seed: int):
    from repro.core.compressed_array import CompressedIntArray

    rng = np.random.default_rng(seed)
    n = n_blocks * block_size
    values = np.sort(rng.integers(0, vocab, size=n)).astype(np.uint64)
    arr = CompressedIntArray.encode(values, format=format,
                                    block_size=block_size, differential=True)
    operands = arr.device_operands()
    nb = arr.n_blocks
    probe = jnp.asarray(np.sort(rng.choice(vocab, size=min(128, vocab),
                                           replace=False))
                        .astype(np.int32)[None, :])
    # aligned per-posting weight stream (quantized impacts): same block
    # layout as the main array, non-differential, values < 2^8
    impacts = rng.integers(1, 256, size=n).astype(np.uint64)
    imp_arr = CompressedIntArray.encode(impacts, format=format,
                                        block_size=block_size,
                                        differential=False)
    w_ops = {f"w_{k}": v for k, v in imp_arr.device_operands().items()
             if k in ("payload", "control", "data", "widths")}
    extras = {
        "bag_sum": {"table": jnp.asarray(
            rng.standard_normal((vocab, d)).astype(np.float32))},
        "dot_score": {"table": jnp.asarray(
            rng.standard_normal((vocab, d)).astype(np.float32)),
            "query": jnp.asarray(
                rng.standard_normal((1, d)).astype(np.float32))},
        "adjacency_rebase": {"edge_base": jnp.asarray(
            rng.integers(0, vocab, (nb, block_size)).astype(np.int32))},
        "membership": {"probe": probe},
        "bm25_accum": {"probe": probe,
                       "impact": jnp.asarray([[7]], jnp.int32)},
        "membership_rows": {"probe": jnp.asarray(
            rng.integers(0, vocab, (nb, 1)).astype(np.int32))},
        "bm25_accum_rows": {"probe": jnp.asarray(
            rng.integers(0, vocab, (nb, 1)).astype(np.int32)),
            "impact": jnp.asarray([[7]], jnp.int32)},
        "bm25_weighted": {"probe": probe, **w_ops},
        "bm25_weighted_rows": {"probe": jnp.asarray(
            rng.integers(0, vocab, (nb, 1)).astype(np.int32)), **w_ops},
        "stream": {},
        "checksum": {},
    }
    return operands, extras, arr.bits_per_int


def autotune(
    *,
    formats=("vbyte", "streamvbyte", "binpack"),
    epilogue_names=("stream", "bag_sum", "dot_score", "adjacency_rebase",
                    "membership", "bm25_accum", "membership_rows",
                    "bm25_accum_rows", "bm25_weighted",
                    "bm25_weighted_rows", "checksum"),
    block_size: int = 128,
    n_blocks: int = 64,
    vocab: int = 4096,
    d: int = 64,
    reps: int = 5,
    warmup: int = 2,
    include_pallas: bool | None = None,
    cache_file: str | None = None,
    seed: int = 0,
) -> dict:
    """Measure candidate plans per (format, epilogue) and persist the best.

    On CPU the Pallas candidates run in interpret mode (orders of magnitude
    off their Mosaic speed), so they are excluded unless ``include_pallas``
    is forced — the CPU cache then records the jnp fused-vs-unfused choice,
    and a TPU run of the same function writes its own keys.
    """
    backend = jax.default_backend()
    if include_pallas is None:
        include_pallas = backend == "tpu"
    cache_file = cache_file or cache_path()
    cache = dict(load_cache(cache_file))

    for fmt in formats:
        operands, extras_by_ep, bits = _synthetic_workload(
            fmt, n_blocks=n_blocks, block_size=block_size, vocab=vocab, d=d,
            seed=seed)
        for ep_name in epilogue_names:
            if ep_name == "stream":
                # no consumer: fused vs unfused is the same program — the
                # decoder path, block tile and banded chunk width are the
                # real degrees of freedom
                w0 = DEFAULT_CHUNK.get(fmt, 64)
                candidates = [DecodePlan("jnp", True),
                              DecodePlan("jnp", True, chunk=w0)]
                if fmt == "vbyte":
                    candidates.append(DecodePlan("ref", False))
                if include_pallas:
                    candidates += [DecodePlan("pallas", True, bt, chunk=w)
                                   for bt in (8, 16)
                                   for w in dict.fromkeys((None, 32, w0))]
                    # the banded cores' smaller one-hot/triangular VMEM
                    # footprint is what makes tiles past 8 blocks fit
                    candidates += [DecodePlan("pallas", True, 32, chunk=w0)]
            else:
                w0 = DEFAULT_CHUNK.get(fmt, 64)
                candidates = [DecodePlan("jnp", True), DecodePlan("jnp", False),
                              DecodePlan("jnp", True, chunk=w0)]
                if include_pallas:
                    candidates += [DecodePlan("pallas", True, bt, chunk=w)
                                   for bt in (8, 16) for w in (None, w0)]
                    candidates += [DecodePlan("pallas", True, 32, chunk=w0),
                                   DecodePlan("pallas", False, 8)]
            # binpack has no chunk axis (DEFAULT_CHUNK[fmt] is None), which
            # collapses banded candidates onto their dense twins — dedupe
            candidates = list({c.label: c for c in candidates}.values())
            timings = {}
            for cand in candidates:
                fn = functools.partial(
                    decode, operands, format=fmt, block_size=block_size,
                    differential=True, epilogue=ep_name,
                    epilogue_operands=extras_by_ep[ep_name], plan=cand)
                timings[cand.label] = round(
                    _time_call(fn, reps=reps, warmup=warmup) * 1e3, 4)
            best = min(candidates, key=lambda c: timings[c.label])
            cache[cache_key(fmt, ep_name, block_size, backend)] = {
                "schema": CACHE_SCHEMA,
                "plan": asdict(best),
                "candidates_ms": timings,
                "backend": backend,
                "workload": {"n_blocks": n_blocks, "block_size": block_size,
                             "vocab": vocab, "d": d,
                             "bits_per_int": round(bits, 2)},
                "measured_at": time.strftime("%Y-%m-%d %H:%M:%S"),
            }

    os.makedirs(os.path.dirname(cache_file) or ".", exist_ok=True)
    with open(cache_file, "w") as f:
        json.dump(cache, f, indent=1, sort_keys=True)
    load_cache(cache_file, reload=True)
    return cache
