from .dispatch import DecodePlan, autotune, decode, resolve_plan  # noqa: F401
from .epilogues import EPILOGUES, apply_grid, fused_decode  # noqa: F401
from .ops import (  # noqa: F401
    binpack_decode_blocked,
    normalize_block_meta,
    normalize_probe,
    stream_vbyte_decode_blocked,
    vbyte_decode_blocked,
)
from .ref import vbyte_decode_blocked_ref  # noqa: F401
