from .ops import stream_vbyte_decode_blocked, vbyte_decode_blocked  # noqa: F401
from .ref import vbyte_decode_blocked_ref  # noqa: F401
