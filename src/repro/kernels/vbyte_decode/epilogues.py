"""Pluggable fused decode→consume epilogues for the blocked decode kernels.

The paper's decoder is memory-bound: once the mask/shuffle math is
restructured (kernel.py, stream_kernel.py), the cost is the byte stream in
and the uint32 stream out. Every real consumer in this repo — embedding-bag
over id bags, retrieval dot-scoring, adjacency reconstruction — immediately
gathers/reduces that uint32 stream back out of HBM. Fusing the consumer into
the kernel epilogue removes the decoded stream's HBM round-trip entirely:
the ids live and die in VMEM (the Stream VByte lesson — keep routing
metadata next to the compute — applied one level up the stack).

An :class:`Epilogue` is a pure function over the decode-tile contract

    ``(vals int32 [..., B], valid bool [..., B], **extras) -> out``

plus the Pallas plumbing metadata (extra-operand block specs, output
shapes). The SAME ``apply`` function executes inside the Pallas kernel body
(on a ``[block_tile, B]`` VMEM tile) and on the full ``[n_blocks, B]`` jnp
grid (:func:`apply_grid`, the unfused reference / CPU path) — so the fused
and unfused paths agree bit-exactly by construction.

Registered epilogues:

* ``stream``           — raw decoded integers (the identity epilogue; the
                         fused differential prefix sum of PR 0 is the
                         ``differential=True`` flavor of this).
* ``bag_sum``          — gather-sum embedding bag: one bag per block;
                         ``out[t] = Σ_j valid·table[ids[t,j]]`` in VMEM.
* ``dot_score``        — retrieval scoring: decoded candidate ids gather
                         item vectors and dot against a query; returns
                         ``(ids, scores)`` so the [C, d] candidate-vector
                         matrix never exists in HBM.
* ``adjacency_rebase`` — GNN adjacency: per-edge ``incl - row_gap_base``
                         subtraction fused into the differential epilogue.
* ``membership``       — inverted-index intersection: decode a postings
                         tile and emit a match bitmap against a sorted
                         probe set resident in VMEM, so the larger list's
                         docids never leave the kernel (repro.index.query).
* ``bm25_accum``       — inverted-index scoring: decode gaps, rebase to
                         docids (the differential prefix sum), and emit
                         each probe candidate's quantized impact
                         contribution; summing the per-block outputs
                         accumulates the term's score exactly (int32).
* ``bm25_weighted``    — per-posting-impact scoring: decode the docid-gap
                         tile AND its aligned quantized-impact tile in the
                         same kernel pass (the impact stream is a second
                         blocked compressed array with identical per-block
                         counts), and emit each probe candidate's exact
                         int32 impact contribution. The weight operands are
                         format-tagged tiled extras — ``w_payload`` (vbyte),
                         ``w_control``/``w_data`` (streamvbyte), or
                         ``w_widths``/``w_data`` (binpack) — so the
                         weighted epilogue works for every format under one
                         name. Drives MaxScore top-k (repro.index.query).
* ``checksum``         — validated decode: the decoded integers plus a
                         per-block position-weighted checksum
                         ``cs[b] = Σ_j vals[b,j]·(2j+1) mod 2^32`` computed
                         in the same tile pass, compared host-side against
                         the encode-time column (repro.robustness.validate)
                         — stream-validation at the cost of one epilogue,
                         not a second HBM round-trip.
* ``membership_rows`` / ``bm25_accum_rows`` / ``bm25_weighted_rows`` —
                         the block-aligned variants:
                         ``probe`` is a **tiled** ``[n_blocks, 1]`` extra
                         (one candidate per gathered block — the skip
                         table already knows the only block that can
                         contain each probe), so the comparison is
                         O(B) per probe instead of O(n_blocks·B). The
                         broadcast variants above remain the path for
                         resident/sharded postings that can't be
                         probe-gathered on the host.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .binpack_kernel import binpack_decode_tile
from .kernel import decode_tile, prefix_sum_tile
from .stream_kernel import stream_decode_tile

FORMAT_OPERANDS = {
    "vbyte": ("payload",),
    "streamvbyte": ("control", "data"),
    "binpack": ("widths", "data"),
}


# ---------------------------------------------------------------------------
# epilogue bodies — pure jnp on the decode-tile contract. Reductions are per
# output element (axis-local), so tile-vs-grid leading dims don't change the
# accumulation order: fused == unfused bit-exactly.
# ---------------------------------------------------------------------------
def _stream_apply(vals, valid):
    return vals


def _bag_sum_apply(vals, valid, *, table):
    T, B = vals.shape
    ids = jnp.where(valid, vals, 0)  # masked slots gather row 0, zeroed below
    vecs = jnp.take(table, ids.reshape(-1), axis=0, mode="clip")
    vecs = vecs.reshape(T, B, -1)
    vecs = jnp.where(valid[:, :, None], vecs, 0)
    return vecs.sum(axis=1)  # [T, d]


def _dot_score_apply(vals, valid, *, table, query):
    T, B = vals.shape
    ids = jnp.where(valid, vals, 0)  # pad slots score id 0 (the pad row)
    vecs = jnp.take(table, ids.reshape(-1), axis=0, mode="clip")
    vecs = vecs.reshape(T, B, -1)
    q = query.reshape(-1, query.shape[-1])  # [n_queries, d]
    if q.shape[0] == 1:  # single query: scores [T, B] (the original contract)
        return ids, jnp.einsum("tbd,d->tb", vecs, q[0]).astype(jnp.float32)
    # microbatched queries (the serving engine's bucket): scores [T, B, q]
    return ids, jnp.einsum("tbd,qd->tbq", vecs, q).astype(jnp.float32)


def _checksum_apply(vals, valid):
    # cs[t] = Σ_j valid · vals[t,j] · (2j+1)  (mod 2^32). int32 products and
    # sums wrap two's-complement, which is bit-identical to the host's
    # uint32 mod-2^32 arithmetic; odd positional weights make the sum
    # order-sensitive (a swap of two unequal values changes it). Count-0
    # (padding) blocks checksum to 0.
    B = vals.shape[-1]
    w = (2 * jnp.arange(B, dtype=jnp.int32) + 1)[None, :]
    cs = jnp.where(valid, vals * w, 0).sum(axis=1, dtype=jnp.int32)
    return vals, cs[:, None]


def _adjacency_rebase_apply(vals, valid, *, edge_base):
    # u32 wrap-around subtraction ≡ int32 subtraction, bitwise
    return jnp.where(valid, vals - edge_base, 0)


def _membership_apply(vals, valid, *, probe):
    # probe: int32 [1, P] sorted docids, padded with -1 (never matches —
    # docids are < 2^31 so decoded vals are non-negative as int32). The
    # [T, B, P] equality broadcast is the in-VMEM analogue of galloping
    # intersection: every decoded slot is checked against every probe slot
    # on the VPU, and the decoded tile never leaves the kernel.
    p = probe.reshape(-1)
    v = jnp.where(valid, vals, -1)  # masked slots never match
    hit = (v[:, :, None] == p[None, None, :]) & (p[None, None, :] >= 0)
    return hit.any(axis=1).astype(jnp.int32)  # [T, P] match bitmap


def _bm25_accum_apply(vals, valid, *, probe, impact):
    # impact: int32 [1, 1] quantized per-term impact. A docid lives in at
    # most one block, so summing the [n_blocks, P] output over blocks
    # accumulates each candidate's exact int32 score contribution.
    return _membership_apply(vals, valid, probe=probe) * impact.reshape(())


def _membership_rows_apply(vals, valid, *, probe):
    # probe: int32 [T, 1] — block t's single candidate (tiled extra; -1 in
    # padding rows never matches). One O(B) compare per probe, because the
    # host-side skip gallop already routed each probe to its only
    # possible block.
    v = jnp.where(valid, vals, -1)
    hit = (v == probe) & (probe >= 0)  # [T, B], probe broadcasts over B
    return hit.any(axis=1, keepdims=True).astype(jnp.int32)  # [T, 1]


def _bm25_accum_rows_apply(vals, valid, *, probe, impact):
    return (_membership_rows_apply(vals, valid, probe=probe)
            * impact.reshape(()))


def _decode_weight_tile(valid, w_payload=None, w_control=None, w_data=None,
                        w_widths=None):
    """Decode the aligned per-posting weight tile in the same kernel pass.

    The weight stream is a second blocked compressed array whose blocks
    align 1:1 with the main stream, so the main tile's ``valid`` mask IS
    the weight tile's count vector — no extra metadata operands. The
    format discriminator is which operands arrived: ``w_widths`` → binpack,
    ``w_payload`` → vbyte, ``w_control``+``w_data`` → streamvbyte. Always
    decodes dense (``chunk_width=None``): the weight stride is short
    (impacts are < 2^impact_bits) and the tile cores are bit-exact for
    any routing geometry.
    """
    counts = valid.astype(jnp.int32).sum(axis=1, keepdims=True)
    B = valid.shape[-1]
    if w_widths is not None and w_data is not None:
        w, _ = binpack_decode_tile(w_widths, w_data, counts,
                                   block_size=B, chunk_width=None)
    elif w_payload is not None:
        w, _ = decode_tile(w_payload, counts, block_size=B, chunk_width=None)
    elif w_control is not None and w_data is not None:
        w, _ = stream_decode_tile(w_control, w_data, counts,
                                  block_size=B, chunk_width=None)
    else:
        raise ValueError(
            "weighted epilogue needs w_payload (vbyte), "
            "w_control + w_data (streamvbyte), or "
            "w_widths + w_data (binpack) extras")
    return jnp.where(valid, w, 0)


def _bm25_weighted_apply(vals, valid, *, probe, w_payload=None,
                         w_control=None, w_data=None, w_widths=None):
    # out[t, i] = Σ_j (vals[t,j] == probe[i]) · weight[t,j] — a docid lives
    # in at most one block, so summing over blocks gives each candidate's
    # exact int32 per-posting-impact contribution.
    w = _decode_weight_tile(valid, w_payload, w_control, w_data, w_widths)
    p = probe.reshape(-1)
    v = jnp.where(valid, vals, -1)
    hit = (v[:, :, None] == p[None, None, :]) & (p[None, None, :] >= 0)
    return (hit.astype(jnp.int32) * w[:, :, None]).sum(axis=1)  # [T, P]


def _bm25_weighted_rows_apply(vals, valid, *, probe, w_payload=None,
                              w_control=None, w_data=None, w_widths=None):
    # probe: int32 [T, 1] — block t's single candidate (see *_rows above).
    w = _decode_weight_tile(valid, w_payload, w_control, w_data, w_widths)
    v = jnp.where(valid, vals, -1)
    hit = (v == probe) & (probe >= 0)  # [T, B]
    return (hit.astype(jnp.int32) * w).sum(axis=1, keepdims=True)  # [T, 1]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def _grid_out(nb, B, bt, dtype):
    return (jax.ShapeDtypeStruct((nb, B), dtype),
            pl.BlockSpec((bt, B), lambda g: (g, 0)))


def _whole_spec(arr):
    """Broadcast operand: the full array is resident every grid step."""
    return pl.BlockSpec(arr.shape, lambda g: (0,) * arr.ndim)


@dataclass(frozen=True)
class Epilogue:
    """One fused decode→consume epilogue (see module docstring)."""

    name: str
    apply: Callable[..., Any]
    extras: tuple[str, ...] = ()
    optional_extras: tuple[str, ...] = ()  # e.g. format-tagged weight operands
    tiled_extras: tuple[str, ...] = ()  # extras sliced per tile like the grid
    requires_differential: bool | None = None  # None = either
    # (n_blocks, block_size, block_tile, extras dict) -> (out_shape, out_spec)
    # — single structs or tuples of structs for multi-output epilogues
    out_info: Callable[..., tuple] = None

    def extra_names(self, extras: dict) -> tuple[str, ...]:
        """Operand order for this call: required, then present optionals."""
        return self.extras + tuple(k for k in self.optional_extras
                                   if k in extras)

    def check_extras(self, extras: dict) -> None:
        missing = [k for k in self.extras if k not in extras]
        allowed = set(self.extras) | set(self.optional_extras)
        extra = [k for k in extras if k not in allowed]
        if missing or extra:
            raise ValueError(
                f"epilogue {self.name!r} takes operands {self.extras} "
                f"(+ optional {self.optional_extras}); "
                f"missing {missing}, unexpected {extra}")

    def check(self, differential: bool, extras: dict) -> None:
        self.check_extras(extras)
        if (self.requires_differential is not None
                and differential != self.requires_differential):
            raise ValueError(
                f"epilogue {self.name!r} requires "
                f"differential={self.requires_differential}")


def _stream_out(nb, B, bt, extras):
    return _grid_out(nb, B, bt, jnp.int32)


def _bag_sum_out(nb, B, bt, extras):
    d = extras["table"].shape[1]
    return (jax.ShapeDtypeStruct((nb, d), extras["table"].dtype),
            pl.BlockSpec((bt, d), lambda g: (g, 0)))


def _dot_score_out(nb, B, bt, extras):
    ids, ids_spec = _grid_out(nb, B, bt, jnp.int32)
    nq = extras["query"].size // extras["query"].shape[-1]
    if nq == 1:
        scores, scores_spec = _grid_out(nb, B, bt, jnp.float32)
    else:
        scores = jax.ShapeDtypeStruct((nb, B, nq), jnp.float32)
        scores_spec = pl.BlockSpec((bt, B, nq), lambda g: (g, 0, 0))
    return (ids, scores), (ids_spec, scores_spec)


def _checksum_out(nb, B, bt, extras):
    return ((jax.ShapeDtypeStruct((nb, B), jnp.int32),
             jax.ShapeDtypeStruct((nb, 1), jnp.int32)),
            (pl.BlockSpec((bt, B), lambda g: (g, 0)),
             pl.BlockSpec((bt, 1), lambda g: (g, 0))))


def _probe_out(nb, B, bt, extras):
    P = extras["probe"].shape[-1]
    return (jax.ShapeDtypeStruct((nb, P), jnp.int32),
            pl.BlockSpec((bt, P), lambda g: (g, 0)))


def _rows_out(nb, B, bt, extras):
    return (jax.ShapeDtypeStruct((nb, 1), jnp.int32),
            pl.BlockSpec((bt, 1), lambda g: (g, 0)))


EPILOGUES = {
    "stream": Epilogue("stream", _stream_apply, out_info=_stream_out),
    "bag_sum": Epilogue("bag_sum", _bag_sum_apply, extras=("table",),
                        out_info=_bag_sum_out),
    "dot_score": Epilogue("dot_score", _dot_score_apply,
                          extras=("table", "query"), out_info=_dot_score_out),
    "checksum": Epilogue("checksum", _checksum_apply, out_info=_checksum_out),
    "adjacency_rebase": Epilogue(
        "adjacency_rebase", _adjacency_rebase_apply, extras=("edge_base",),
        tiled_extras=("edge_base",), requires_differential=True,
        out_info=_stream_out),
    "membership": Epilogue("membership", _membership_apply,
                           extras=("probe",), out_info=_probe_out),
    "bm25_accum": Epilogue("bm25_accum", _bm25_accum_apply,
                           extras=("probe", "impact"), out_info=_probe_out),
    "membership_rows": Epilogue(
        "membership_rows", _membership_rows_apply, extras=("probe",),
        tiled_extras=("probe",), out_info=_rows_out),
    "bm25_accum_rows": Epilogue(
        "bm25_accum_rows", _bm25_accum_rows_apply,
        extras=("probe", "impact"), tiled_extras=("probe",),
        out_info=_rows_out),
    "bm25_weighted": Epilogue(
        "bm25_weighted", _bm25_weighted_apply, extras=("probe",),
        optional_extras=("w_payload", "w_control", "w_data", "w_widths"),
        tiled_extras=("w_payload", "w_control", "w_data", "w_widths"),
        out_info=_probe_out),
    "bm25_weighted_rows": Epilogue(
        "bm25_weighted_rows", _bm25_weighted_rows_apply, extras=("probe",),
        optional_extras=("w_payload", "w_control", "w_data", "w_widths"),
        tiled_extras=("probe", "w_payload", "w_control", "w_data", "w_widths"),
        out_info=_rows_out),
}


def get_epilogue(name: str) -> Epilogue:
    if name not in EPILOGUES:
        raise ValueError(f"unknown epilogue {name!r}; "
                         f"expected one of {tuple(EPILOGUES)}")
    return EPILOGUES[name]


# ---------------------------------------------------------------------------
# jnp grid path: the unfused reference (and the CPU fused-jit body)
# ---------------------------------------------------------------------------
def apply_grid(epilogue: str, grid_u32: jax.Array, counts: jax.Array,
               extras: dict | None = None):
    """Apply an epilogue to an already-decoded ``uint32 [n_blocks, B]`` grid.

    This is the decode→jnp-consume reference the fused kernels must match
    bit-exactly (same ``apply`` body, full grid instead of VMEM tiles).
    """
    ep = get_epilogue(epilogue)
    extras = extras or {}
    ep.check_extras(extras)
    vals = lax.bitcast_convert_type(grid_u32, jnp.int32)
    B = grid_u32.shape[1]
    valid = (jnp.arange(B, dtype=jnp.int32)[None, :]
             < counts.reshape(-1, 1).astype(jnp.int32))
    return ep.apply(vals, valid, **extras)


# ---------------------------------------------------------------------------
# fused Pallas path: decode-tile core + epilogue in one kernel
# ---------------------------------------------------------------------------
def fused_decode_pallas(
    format: str,
    fmt_arrays: tuple,  # ("payload",) or ("control", "data") uint8 arrays
    counts: jax.Array,  # int32 [n_blocks, 1]
    bases: jax.Array,  # int32 [n_blocks, 1] (bitcast of uint32)
    extras: dict,
    *,
    epilogue: str,
    block_size: int,
    differential: bool,
    block_tile: int = 8,
    chunk_width: int | None = None,
    interpret: bool = False,
):
    """Raw pallas_call builder: one pass over (decode tile → epilogue)."""
    ep = get_epilogue(epilogue)
    nb = fmt_arrays[0].shape[0]
    if nb % block_tile:
        raise ValueError(f"n_blocks={nb} must be a multiple of "
                         f"block_tile={block_tile}")
    grid = (nb // block_tile,)
    n_fmt = len(fmt_arrays)
    extra_names = ep.extra_names(extras)

    fmt_specs = [pl.BlockSpec((block_tile, a.shape[1]), lambda g: (g, 0))
                 for a in fmt_arrays]
    meta_specs = [pl.BlockSpec((block_tile, 1), lambda g: (g, 0))] * 2
    extra_specs = [
        pl.BlockSpec((block_tile, extras[k].shape[1]), lambda g: (g, 0))
        if k in ep.tiled_extras else _whole_spec(extras[k])
        for k in extra_names
    ]
    out_shape, out_specs = ep.out_info(nb, block_size, block_tile, extras)
    multi = isinstance(out_shape, tuple)

    def kernel(*refs):
        counts_ref, bases_ref = refs[n_fmt], refs[n_fmt + 1]
        extra_vals = {k: refs[n_fmt + 2 + i][...]
                      for i, k in enumerate(extra_names)}
        out_refs = refs[n_fmt + 2 + len(extra_names):]
        if format == "vbyte":
            vals, valid = decode_tile(refs[0][...], counts_ref[...],
                                      block_size=block_size,
                                      chunk_width=chunk_width)
        elif format == "binpack":
            vals, valid = binpack_decode_tile(refs[0][...], refs[1][...],
                                              counts_ref[...],
                                              block_size=block_size,
                                              chunk_width=chunk_width)
        else:
            vals, valid = stream_decode_tile(refs[0][...], refs[1][...],
                                             counts_ref[...],
                                             block_size=block_size,
                                             chunk_width=chunk_width)
        if differential:
            vals = prefix_sum_tile(vals, valid, bases_ref[...])
        res = ep.apply(vals, valid, **extra_vals)
        for r, oref in zip(res if multi else (res,), out_refs):
            oref[...] = r

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=fmt_specs + meta_specs + extra_specs,
        out_specs=list(out_specs) if multi else out_specs,
        out_shape=list(out_shape) if multi else out_shape,
        interpret=interpret,
    )(*fmt_arrays, counts, bases, *(extras[k] for k in extra_names))


@functools.partial(
    jax.jit,
    static_argnames=("format", "epilogue", "block_size", "differential",
                     "block_tile", "chunk_width", "interpret"),
)
def fused_decode(
    operands: dict,  # format operands incl. counts/bases (device_operands())
    extras: dict,  # epilogue operands, e.g. {"table": ...}
    *,
    format: str,
    epilogue: str,
    block_size: int,
    differential: bool,
    block_tile: int = 8,
    chunk_width: int | None = None,
    interpret: bool | None = None,
):
    """Public fused decode→epilogue entry (jit'd; both formats).

    ``operands`` is exactly ``CompressedIntArray.device_operands()``;
    ``counts``/``bases`` may be ``[n_blocks]`` or ``[n_blocks, 1]`` (see
    ops.normalize_block_meta). Pads ``n_blocks`` to ``block_tile`` (padded
    blocks have count 0) and trims every output back.
    """
    from .ops import _auto_interpret, normalize_block_meta

    ep = get_epilogue(epilogue)
    ep.check(differential, extras)
    if interpret is None:
        interpret = _auto_interpret()
    fmt_names = FORMAT_OPERANDS.get(format)
    if fmt_names is None:
        raise ValueError(f"unknown format {format!r}")
    fmt_arrays = tuple(operands[k] for k in fmt_names)
    nb = fmt_arrays[0].shape[0]
    counts = normalize_block_meta("counts", operands["counts"], nb)
    bases = normalize_block_meta("bases", operands["bases"], nb)

    pad = (-nb) % block_tile
    if pad:
        fmt_arrays = tuple(jnp.pad(a, ((0, pad), (0, 0))) for a in fmt_arrays)
        counts = jnp.pad(counts, ((0, pad),))
        bases = jnp.pad(bases, ((0, pad),))
        extras = {k: (jnp.pad(v, ((0, pad), (0, 0)))
                      if k in ep.tiled_extras else v)
                  for k, v in extras.items()}

    counts2 = counts.astype(jnp.int32)[:, None]
    bases2 = lax.bitcast_convert_type(bases.astype(jnp.uint32), jnp.int32)[:, None]
    out = fused_decode_pallas(
        format, fmt_arrays, counts2, bases2, extras,
        epilogue=epilogue, block_size=block_size, differential=differential,
        block_tile=block_tile, chunk_width=chunk_width, interpret=interpret,
    )
    if isinstance(out, (tuple, list)):
        return tuple(o[:nb] for o in out)
    return out[:nb]
