"""Chunked banded-scatter primitives shared by both decode-tile cores.

The dense decode cores route bytes to output slots with a ``[T, S, B]``
one-hot (every byte against every output) and recover ``out_idx`` with a
full ``[S, S]`` triangular matmul — O(S·B) and O(S²) work for a job the
paper does in O(bytes) with pshufb. The structural fact that makes routing
cheap is the **chunk-band invariant**:

    ``out_idx`` is monotone non-decreasing along the byte axis and
    increments by at most 1 per byte, so the bytes of chunk ``c`` (a run of
    ``W`` consecutive byte lanes) can only land in the ``W`` output slots
    ``[chunk_base[c], chunk_base[c] + W)``, where ``chunk_base[c]`` is the
    number of terminator flags in chunks ``0..c-1``.

Routing therefore decomposes into

1. a **chunked prefix sum**: within-chunk exclusive prefix of the
   terminator/length flags via a ``[W, W]`` strict-triangular matmul
   (O(S·W) MACs instead of O(S²)) plus a tiny ``[n_chunks, n_chunks]``
   cross-chunk base combine,
2. a **banded one-hot scatter**: a ``[T, n_chunks, W, W]`` one-hot routes
   each chunk's bytes into its W-slot band (O(S·W) MACs per matmul instead
   of O(S·B)),
3. a **cross-chunk combine**: each chunk's band is placed at its
   data-dependent ``chunk_base`` offset by a barrel shift (log₂ static
   shifts + selects, pure VPU) and the overlapped bands are added in int32
   — integers that straddle a chunk boundary get partial sums from both
   chunks landing on the same global slot, and the int32 add recombines
   them exactly (mod 2³²).

Everything here is pure jnp/lax on statically-shaped values (static slices
and concatenates only), so it runs inside a Pallas kernel body and on the
full jnp grid alike. f32 matmul exactness: every per-slot per-chunk
accumulation is a sum of at most 5 halfword pieces (< 2²⁰ ≪ 2²⁴) and every
prefix-sum operand is a small count (< 2¹³), so the MXU results are exact;
cross-chunk sums happen after the int32 cast, wrapping ≡ mod 2³².

:func:`routing_cost` is the tracked FLOP/VMEM model of dense vs banded
routing (``benchmarks/run.py --only decode`` persists it per plan).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax


def normalize_chunk_width(chunk_width: int, block_size: int) -> int:
    """Validate a chunk width: positive multiple of 8, at most block_size."""
    W = int(chunk_width)
    if W <= 0 or W % 8:
        raise ValueError(
            f"chunk_width must be a positive multiple of 8; got {chunk_width}")
    if W > block_size:
        raise ValueError(
            f"chunk_width {W} exceeds block_size {block_size}: a chunk's "
            "output band would be wider than the output itself")
    return W


def pad_cols(x: jax.Array, multiple: int) -> jax.Array:
    """Zero-pad the last axis up to a multiple (static concatenate only)."""
    S = x.shape[-1]
    pad = (-S) % multiple
    if not pad:
        return x
    return jnp.concatenate(
        [x, jnp.zeros(x.shape[:-1] + (pad,), x.dtype)], axis=-1)


def chunked_prefix(flags: jax.Array, W: int) -> tuple[jax.Array, jax.Array]:
    """Chunked exclusive prefix sum of small non-negative int32 values.

    ``flags`` is ``int32 [T, Sp]`` with ``Sp % W == 0`` and per-row sums
    < 2²⁴ (f32-exact). Returns ``(loc, base)``: ``loc int32 [T, nC, W]`` is
    the within-chunk exclusive prefix, ``base int32 [T, nC]`` the sum over
    all earlier chunks — the global exclusive prefix is ``base[..., None]
    + loc``. Cost: O(Sp·W) MACs + O(nC²) for the base combine, replacing
    the dense [Sp, Sp] triangular matmul's O(Sp²).
    """
    T, Sp = flags.shape
    nC = Sp // W
    f = flags.reshape(T, nC, W).astype(jnp.float32)
    ii = lax.broadcasted_iota(jnp.int32, (W, W), 0)
    jj = lax.broadcasted_iota(jnp.int32, (W, W), 1)
    tri = (ii < jj).astype(jnp.float32)  # [W, W], strict upper
    loc = lax.dot_general(
        f, tri, (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(jnp.int32)  # [T, nC, W]
    totals = loc[:, :, -1] + flags.reshape(T, nC, W)[:, :, -1]  # [T, nC]
    cc = lax.broadcasted_iota(jnp.int32, (nC, nC), 0)
    dd = lax.broadcasted_iota(jnp.int32, (nC, nC), 1)
    tric = (cc < dd).astype(jnp.float32)
    base = lax.dot(
        totals.astype(jnp.float32), tric, preferred_element_type=jnp.float32
    ).astype(jnp.int32)  # [T, nC]
    return loc, base


def place_bands(bands: jax.Array, offsets: jax.Array,
                out_width: int) -> jax.Array:
    """Sum W-wide bands into a [T, out_width] row at data-dependent offsets.

    ``bands`` int32 ``[T, G, Wb]``, ``offsets`` int32 ``[T, G]``; band
    ``(t, g)`` contributes ``bands[t, g, l]`` to output column
    ``offsets[t, g] + l``. Implemented as a barrel shift — ⌈log₂⌉ static
    zero-fill right-shifts gated per band by the offset's bits — followed
    by an int32 sum over bands (exact mod 2³²; overlapping bands, e.g.
    integers straddling a chunk boundary, recombine here). Columns past
    ``out_width`` fall off the end; callers guarantee live values stay
    in range (masked contributions are zero).
    """
    T, G, Wb = bands.shape
    x = bands
    if Wb < out_width:
        x = jnp.concatenate(
            [x, jnp.zeros((T, G, out_width - Wb), x.dtype)], axis=-1)
    elif Wb > out_width:
        # a band wider than the output row: columns ≥ out_width can only
        # hold masked zeros (live values index < out_width by contract)
        x = x[..., :out_width]
    off = jnp.clip(offsets, 0, out_width)[:, :, None]  # [T, G, 1]
    k = 1
    while k <= out_width:
        bit = (off // k) % 2
        if k < out_width:
            shifted = jnp.concatenate(
                [jnp.zeros((T, G, k), x.dtype), x[..., : out_width - k]],
                axis=-1)
        else:
            shifted = jnp.zeros_like(x)
        x = jnp.where(bit == 1, shifted, x)
        k *= 2
    return x.sum(axis=1)  # [T, out_width] int32, wrap-around exact


def banded_scatter_u32(loc: jax.Array, lo: jax.Array, hi: jax.Array,
                       base: jax.Array, out_width: int) -> jax.Array:
    """Banded one-hot MXU scatter of 16-bit-split contributions.

    ``loc`` int32 ``[T, nC, W]`` within-band slot per byte, ``lo``/``hi``
    int32 ``[T, nC, W]`` halfword contributions (each < 2¹⁶, at most 5 per
    (chunk, slot): f32-exact), ``base`` int32 ``[T, nC]`` band offsets.
    Returns int32 ``[T, out_width]`` = lo + (hi << 16), exact mod 2³².
    """
    T, nC, W = loc.shape
    lvec = lax.broadcasted_iota(jnp.int32, (T, nC, W, W), 3)
    onehot = (loc[:, :, :, None] == lvec).astype(jnp.float32)  # [T,nC,W,W]
    dn = (((2,), (2,)), ((0, 1), (0, 1)))  # contract bytes, batch (T, nC)
    lo_b = lax.dot_general(
        onehot, lo.astype(jnp.float32), dn,
        preferred_element_type=jnp.float32).astype(jnp.int32)
    hi_b = lax.dot_general(
        onehot, hi.astype(jnp.float32), dn,
        preferred_element_type=jnp.float32).astype(jnp.int32)
    return (place_bands(lo_b, base, out_width)
            + (place_bands(hi_b, base, out_width) << 16))


# ---------------------------------------------------------------------------
# FLOP / VMEM model — the tracked "modeled scatter MACs" numbers
# ---------------------------------------------------------------------------
def routing_cost(format: str, *, S: int, B: int, W: int | None,
                 T: int = 8) -> dict:
    """Model the byte→integer routing cost of one decode tile.

    ``mxu_macs`` counts multiply-accumulates of the routing matmuls — the
    prefix-sum triangular contractions, one-hot gathers and the two
    16-bit-split scatter matmuls (the unit the docs quote: the dense cores
    spend ~S·B MACs *per scatter matmul*). ``vpu_ops`` counts the per-lane
    compare/select traffic that is not a contraction: one-hot equality
    tests, the Stream VByte rank tensor, and the barrel-shift band
    combine. VMEM counts routing intermediates that scale with the one-hot
    (f32 one-hots, triangular constants, band buffers), not the
    payload/output tiles common to both paths.

    ``W=None`` models the dense core. Numbers are per tile of ``T`` blocks;
    divide by T for per-block, as quoted in docs/kernels.md.
    """
    if format not in ("vbyte", "streamvbyte", "binpack"):
        raise ValueError(f"unknown format {format!r}")
    f32 = 4
    if format == "binpack":
        # binpack has no length scan, so there is no banded variant (W is
        # ignored): the routing is one [T,B,S] one-hot gather realized as
        # two byte-packed contractions, plus pure VPU index/shift math
        mxu = {"window_gather": 2 * T * B * S}  # lo24 + hi16 matmuls
        vpu = {
            "onehot_build": T * B * S,  # byte-offset equality tests
            "shift_mask": 4 * T * B,  # bitpos, shift, recombine, mask
        }
        vmem = {
            "onehot": T * B * S * f32,
            "shifted_copies": 2 * T * S * f32,  # grp012 + grp34 operands
        }
        return {
            "mxu_macs": mxu,
            "mxu_total": sum(mxu.values()),
            "vpu_ops": vpu,
            "vpu_total": sum(vpu.values()),
            "vmem_bytes": vmem,
            "vmem_total": sum(vmem.values()),
        }
    if W is None:
        if format == "vbyte":
            mxu = {
                "prefix_out_idx": T * S * S,      # [T,S]×[S,S] strict tri
                "scatter": 2 * T * S * B,         # lo + hi one-hot matmuls
            }
            vpu = {"onehot_build": T * S * B}
            vmem = {
                "onehot": T * S * B * f32,
                "tri": S * S * f32,
            }
        else:
            C = B // 4
            mxu = {
                "control_expand": T * C * B,      # [T,C]×[C,B] one-hot
                "prefix_starts": T * B * B,       # [T,B]×[B,B] strict tri
                "owner_start_gather": T * S * B,  # [T,S,B]×[T,B] one-hot
                "scatter": 2 * T * S * B,
            }
            vpu = {
                "owner_rank": T * S * B,          # [T,S,B] compare+sum
                "onehot_build": T * S * B,
            }
            vmem = {
                "onehot": T * S * B * f32,
                "rank_tensor": T * S * B * f32,
                "tri": B * B * f32,
            }
    else:
        nC = -(-S // W)
        Sp = nC * W
        logB = max(1, math.ceil(math.log2(max(2, B + 1))))
        if format == "vbyte":
            mxu = {
                "prefix_out_idx": T * Sp * W + T * nC * nC,
                "scatter": 2 * T * Sp * W,
            }
            vpu = {
                "onehot_build": T * nC * W * W,
                "band_combine": 2 * T * nC * B * logB,
            }
            vmem = {
                "onehot": T * nC * W * W * f32,
                "tri": (W * W + nC * nC) * f32,
                "bands": 2 * T * nC * B * f32,
            }
        else:
            ng = -(-B // W)
            logS = max(1, math.ceil(math.log2(max(2, Sp + 1))))
            mxu = {
                # control expand is a static ×4 broadcast in the banded
                # core — no matmul
                "prefix_starts": T * ng * W * W + T * ng * ng,
                "prefix_out_idx": T * Sp * W + T * nC * nC,
                "scatter": 2 * T * Sp * W,
            }
            vpu = {
                "ends_band_build": T * ng * W * 4 * W,  # compare+sum
                "ends_place": T * ng * Sp * logS,
                "onehot_build": T * nC * W * W,
                "band_combine": 2 * T * nC * B * logB,
            }
            vmem = {
                "onehot": T * nC * W * W * f32,
                "ends_band": T * ng * 4 * W * f32,
                "tri": (W * W + 2 * max(ng, nC) ** 2) * f32,
                "bands": 2 * T * nC * B * f32,
            }
    return {
        "mxu_macs": mxu,
        "mxu_total": sum(mxu.values()),
        "vpu_ops": vpu,
        "vpu_total": sum(vpu.values()),
        "vmem_bytes": vmem,
        "vmem_total": sum(vmem.values()),
    }


def routing_reduction(format: str, *, S: int, B: int, W: int,
                      T: int = 8) -> float:
    """Dense-over-banded modeled scatter-MAC ratio (the headline ≥4×)."""
    dense = routing_cost(format, S=S, B=B, W=None, T=T)["mxu_total"]
    banded = routing_cost(format, S=S, B=B, W=W, T=T)["mxu_total"]
    return dense / banded
