"""Pallas TPU kernel: blocked Masked-VByte decode with fused differential sum.

TPU-native realization of the paper's decoder (DESIGN.md §2). Per grid step a
(T, S)-byte VMEM tile (T blocks × S payload bytes — 8×640 = 5120 bytes,
~427× the paper's 12-byte unit, amortizing per-step overhead the way the
paper's 48-byte mask pipeline amortizes pmovmskb latency) is decoded entirely
branch-free:

  * continuation bits via one vectorized compare (pmovmskb analogue),
  * byte→integer routing via a strict-triangular f32 matmul prefix sum
    (replaces the 2^12-entry lookup table),
  * within-integer positions via the ≤5-byte closed form
    (replaces the 170 pshufb control masks),
  * reassembly via a one-hot **MXU** scatter — the systolic array plays the
    role of pshufb (this is the TPU shuffle engine),
  * fused differential prefix sum via triangular matmul (the paper's
    pslldq/paddd doubling tree).

32-bit exactness on an f32 MXU is preserved by splitting every 32-bit word
into 16-bit halves before each matmul: per-output sums stay < 2^24 (f32-exact)
and are recombined with wrap-around int32 adds (≡ mod 2^32, i.e. uint32).

``chunk_width=W`` swaps the dense O(S²)+O(S·B) routing for the chunked
banded scatter (``banded.py``): out_idx is monotone with increments ≤ 1,
so a W-byte chunk's outputs live in one W-slot band — O(S·W) routing MACs,
bit-identical output (docs/kernels.md §Banded chunked scatter).

All tensors live in VMEM; block dims are multiples of (8, 128) lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .banded import (banded_scatter_u32, chunked_prefix, normalize_chunk_width,
                     pad_cols)


def _shift_right(x: jax.Array, k: int) -> jax.Array:
    """x[..., i-k] with zero fill — static slices only (Mosaic-safe)."""
    t, s = x.shape
    return jnp.concatenate([jnp.zeros((t, k), x.dtype), x[:, : s - k]], axis=1)


def _row_cumsum_exact_u32(x: jax.Array, incl_tri: jax.Array) -> jax.Array:
    """Inclusive row cumsum of int32 values, exact mod 2^32 via 16-bit split."""
    lo = (x & 0xFFFF).astype(jnp.float32)
    hi = ((x >> 16) & 0xFFFF).astype(jnp.float32)
    lo_s = lax.dot(lo, incl_tri, preferred_element_type=jnp.float32).astype(jnp.int32)
    hi_s = lax.dot(hi, incl_tri, preferred_element_type=jnp.float32).astype(jnp.int32)
    return lo_s + (hi_s << 16)


def decode_tile(payload: jax.Array, counts: jax.Array, *, block_size: int,
                chunk_width: int | None = None) -> tuple[jax.Array, jax.Array]:
    """Decode one VMEM tile of Masked-VByte bytes — the shared decode-tile core.

    ``payload`` is the raw ``uint8 [T, S]`` tile, ``counts`` the ``int32
    [T, 1]`` valid-integer counts. Returns ``(out, valid)``: ``out`` int32
    ``[T, B]`` (bitcast of uint32, masked rows zeroed) and ``valid`` bool
    ``[T, B]``. Pure jnp/lax — callable both from a Pallas kernel body and
    from host-level code; every fused epilogue consumes this contract.

    ``chunk_width=None`` runs the dense O(S²)+O(S·B) routing (full
    triangular prefix matmul + [T, S, B] one-hot scatter). An integer ``W``
    selects the chunked banded-scatter routing (``banded.py``): out_idx is
    monotone and increments ≤1 per byte, so chunk ``c``'s bytes land only
    in slots ``[chunk_base[c], chunk_base[c]+W)`` — O(S·W) MACs, identical
    uint32 output bit-for-bit.
    """
    T, S = payload.shape
    B = block_size

    b = payload.astype(jnp.int32)  # [T, S] bytes
    cont = b >> 7
    end = 1 - cont

    # within-integer byte position (≤ 4): closed form over preceding cont
    # flags — static shifts over the full row, so integers whose bytes
    # straddle a chunk boundary see their true position either way
    c1 = _shift_right(cont, 1)
    c2 = _shift_right(cont, 2)
    c3 = _shift_right(cont, 3)
    c4 = _shift_right(cont, 4)
    pos = c1 * (1 + c2 * (1 + c3 * (1 + c4)))

    contrib = (b & 0x7F) << (7 * pos)  # int32, wraps ≡ uint32

    if chunk_width is None:
        # dense routing: exclusive prefix sum over the full byte axis
        # (out_idx[t,i] = #terminators < i) + full-width one-hot scatter
        ii = lax.broadcasted_iota(jnp.int32, (S, S), 0)
        jj = lax.broadcasted_iota(jnp.int32, (S, S), 1)
        strict_tri = (ii < jj).astype(jnp.float32)  # [S,S], U[k,i]=1 iff k<i
        out_idx = lax.dot(
            end.astype(jnp.float32), strict_tri,
            preferred_element_type=jnp.float32).astype(jnp.int32)

        keep = out_idx < counts  # [T,S] < [T,1]
        contrib = jnp.where(keep, contrib, 0)
        out_idx = jnp.where(keep, out_idx, B - 1)  # clamp masked bytes

        # one-hot MXU scatter: out[t,j] = Σ_i [out_idx[t,i]==j]·contrib[t,i]
        jvec = lax.broadcasted_iota(jnp.int32, (T, S, B), 2)
        onehot = (out_idx[:, :, None] == jvec).astype(jnp.float32)  # [T,S,B]
        dnums = (((1,), (1,)), ((0,), (0,)))  # contract over S, batch over T
        lo = (contrib & 0xFFFF).astype(jnp.float32)
        hi = ((contrib >> 16) & 0xFFFF).astype(jnp.float32)
        lo_sum = lax.dot_general(onehot, lo, dnums,
                                 preferred_element_type=jnp.float32)
        hi_sum = lax.dot_general(onehot, hi, dnums,
                                 preferred_element_type=jnp.float32)
        out = lo_sum.astype(jnp.int32) + (hi_sum.astype(jnp.int32) << 16)
    else:
        W = normalize_chunk_width(chunk_width, B)
        # chunked prefix: loc = #terminators earlier in the chunk (the
        # within-band slot, < W by construction), base = #terminators in
        # earlier chunks. Padding flags are zeros, so bases are unaffected.
        end_p = pad_cols(end, W)  # [T, Sp]
        Sp = end_p.shape[1]
        nC = Sp // W
        loc, base = chunked_prefix(end_p, W)
        out_idx = (base[:, :, None] + loc).reshape(T, Sp)[:, :S]

        keep = out_idx < counts  # [T,S] < [T,1]
        contrib = jnp.where(keep, contrib, 0)
        lo = pad_cols(contrib & 0xFFFF, W).reshape(T, nC, W)
        hi = pad_cols((contrib >> 16) & 0xFFFF, W).reshape(T, nC, W)
        # banded one-hot scatter into W-slot bands + barrel-shift combine;
        # straddling integers recombine via the overlapped int32 band add
        out = banded_scatter_u32(loc, lo, hi, base, B)

    jrow = lax.broadcasted_iota(jnp.int32, (T, B), 1)
    valid = jrow < counts
    out = jnp.where(valid, out, 0)
    return out, valid


def prefix_sum_tile(out: jax.Array, valid: jax.Array, bases: jax.Array) -> jax.Array:
    """Fused differential epilogue: inclusive row cumsum (mod 2^32) + bases.

    ``out`` int32 [T, B] gap values, ``bases`` int32 [T, 1] carry-in
    (bitcast of uint32). Shared by both format kernels.
    """
    B = out.shape[-1]
    kk = lax.broadcasted_iota(jnp.int32, (B, B), 0)
    ll = lax.broadcasted_iota(jnp.int32, (B, B), 1)
    incl_tri = (kk <= ll).astype(jnp.float32)
    out = _row_cumsum_exact_u32(out, incl_tri) + bases
    return jnp.where(valid, out, 0)


def _decode_tile_kernel(payload_ref, counts_ref, bases_ref, out_ref, *,
                        block_size: int, differential: bool,
                        chunk_width: int | None):
    out, valid = decode_tile(payload_ref[...], counts_ref[...],
                             block_size=block_size, chunk_width=chunk_width)
    if differential:
        out = prefix_sum_tile(out, valid, bases_ref[...])
    out_ref[...] = out


def decode_blocked_pallas(
    payload: jax.Array,  # uint8 [n_blocks, stride]
    counts: jax.Array,  # int32 [n_blocks, 1]
    bases: jax.Array,  # int32 [n_blocks, 1] (bitcast of uint32)
    *,
    block_size: int,
    differential: bool,
    block_tile: int = 8,
    chunk_width: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Raw pallas_call wrapper; see ops.vbyte_decode_blocked for the public API."""
    nb, stride = payload.shape
    if nb % block_tile:
        raise ValueError(f"n_blocks={nb} must be a multiple of block_tile={block_tile}")
    grid = (nb // block_tile,)
    kernel = functools.partial(
        _decode_tile_kernel, block_size=block_size, differential=differential,
        chunk_width=chunk_width,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_tile, stride), lambda g: (g, 0)),
            pl.BlockSpec((block_tile, 1), lambda g: (g, 0)),
            pl.BlockSpec((block_tile, 1), lambda g: (g, 0)),
        ],
        out_specs=pl.BlockSpec((block_tile, block_size), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block_size), jnp.int32),
        interpret=interpret,
    )(payload, counts, bases)
