"""Public jit'd wrappers for the Pallas decode kernels (both formats).

On CPU (this container) the kernels execute in interpret mode; on TPU they
compile through Mosaic. ``vbyte_decode_blocked`` matches
``ref.vbyte_decode_blocked_ref`` and ``repro.core.vbyte.masked.decode_blocked``;
``stream_vbyte_decode_blocked`` matches
``repro.core.vbyte.stream_masked.decode_blocked``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .binpack_kernel import binpack_decode_blocked_pallas
from .kernel import decode_blocked_pallas
from .stream_kernel import stream_decode_blocked_pallas


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def normalize_block_meta(name: str, x: jax.Array, n_blocks: int) -> jax.Array:
    """Validate per-block metadata (``counts``/``bases``) shape; return 1-D.

    The public contract accepts ``[n_blocks]`` or ``[n_blocks, 1]`` (the
    kernels' internal tile shape). Anything else — wrong length, transposed,
    extra dims — raises a clear ValueError instead of a silent reshape.
    """
    shape = tuple(x.shape)
    if shape == (n_blocks,):
        return x
    if shape == (n_blocks, 1):
        return x[:, 0]
    raise ValueError(
        f"{name} must have shape [n_blocks] or [n_blocks, 1] with "
        f"n_blocks={n_blocks}; got {shape}")


def normalize_probe(probe, width: int):
    """Validate + pad a sorted probe set for the membership/bm25 epilogues.

    ``probe`` is a 1-D sorted array of docids (< 2^31 — the in-kernel
    comparison runs in int32). Returns ``int32 [1, width]`` padded with -1
    (the never-matches sentinel the epilogue masks out). Raises on unsorted,
    too-long, or out-of-range inputs instead of silently mis-matching.
    """
    import numpy as np

    p = np.asarray(probe).reshape(-1)
    if p.size > width:
        raise ValueError(f"probe has {p.size} ids > width={width}")
    if p.size:
        if p.min() < 0 or int(p.max()) >= 1 << 31:
            raise ValueError("probe docids must be in [0, 2^31) — the "
                             "membership epilogue compares in int32")
        if np.any(np.diff(p.astype(np.int64)) < 0):
            raise ValueError("probe must be sorted (non-decreasing)")
    out = np.full((1, width), -1, np.int32)
    out[0, : p.size] = p.astype(np.int32)
    return out


@functools.partial(
    jax.jit, static_argnames=("block_size", "differential", "block_tile",
                              "chunk_width", "interpret")
)
def vbyte_decode_blocked(
    payload: jax.Array,  # uint8 [n_blocks, stride]
    counts: jax.Array,  # int   [n_blocks] or [n_blocks, 1]
    bases: jax.Array,  # uint32/int32 [n_blocks] or [n_blocks, 1]
    *,
    block_size: int,
    differential: bool,
    block_tile: int = 8,
    chunk_width: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Decode a blocked VByte payload to uint32[n_blocks, block_size]."""
    if interpret is None:
        interpret = _auto_interpret()
    nb, stride = payload.shape
    counts = normalize_block_meta("counts", counts, nb)
    bases = normalize_block_meta("bases", bases, nb)

    pad = (-nb) % block_tile
    if pad:
        payload = jnp.pad(payload, ((0, pad), (0, 0)))
        counts = jnp.pad(counts, ((0, pad),))
        bases = jnp.pad(bases, ((0, pad),))

    counts2 = counts.astype(jnp.int32)[:, None]
    bases2 = jax.lax.bitcast_convert_type(bases.astype(jnp.uint32), jnp.int32)[:, None]

    out = decode_blocked_pallas(
        payload,
        counts2,
        bases2,
        block_size=block_size,
        differential=differential,
        block_tile=block_tile,
        chunk_width=chunk_width,
        interpret=interpret,
    )
    out = jax.lax.bitcast_convert_type(out, jnp.uint32)
    return out[:nb]


@functools.partial(
    jax.jit, static_argnames=("block_size", "differential", "block_tile",
                              "chunk_width", "interpret")
)
def stream_vbyte_decode_blocked(
    control: jax.Array,  # uint8 [n_blocks, block_size // 4]
    data: jax.Array,  # uint8 [n_blocks, data_stride]
    counts: jax.Array,  # int   [n_blocks] or [n_blocks, 1]
    bases: jax.Array,  # uint32/int32 [n_blocks] or [n_blocks, 1]
    *,
    block_size: int,
    differential: bool,
    block_tile: int = 8,
    chunk_width: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Decode a blocked Stream-VByte payload to uint32[n_blocks, block_size]."""
    if interpret is None:
        interpret = _auto_interpret()
    nb, _ = control.shape
    counts = normalize_block_meta("counts", counts, nb)
    bases = normalize_block_meta("bases", bases, nb)

    pad = (-nb) % block_tile
    if pad:
        control = jnp.pad(control, ((0, pad), (0, 0)))
        data = jnp.pad(data, ((0, pad), (0, 0)))
        counts = jnp.pad(counts, ((0, pad),))
        bases = jnp.pad(bases, ((0, pad),))

    counts2 = counts.astype(jnp.int32)[:, None]
    bases2 = jax.lax.bitcast_convert_type(bases.astype(jnp.uint32), jnp.int32)[:, None]

    out = stream_decode_blocked_pallas(
        control,
        data,
        counts2,
        bases2,
        block_size=block_size,
        differential=differential,
        block_tile=block_tile,
        chunk_width=chunk_width,
        interpret=interpret,
    )
    out = jax.lax.bitcast_convert_type(out, jnp.uint32)
    return out[:nb]


@functools.partial(
    jax.jit, static_argnames=("block_size", "differential", "block_tile",
                              "chunk_width", "interpret")
)
def binpack_decode_blocked(
    widths: jax.Array,  # uint8 [n_blocks, 1] or [n_blocks]
    data: jax.Array,  # uint8 [n_blocks, stride]
    counts: jax.Array,  # int   [n_blocks] or [n_blocks, 1]
    bases: jax.Array,  # uint32/int32 [n_blocks] or [n_blocks, 1]
    *,
    block_size: int,
    differential: bool,
    block_tile: int = 8,
    chunk_width: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Decode a blocked binpack payload to uint32[n_blocks, block_size]."""
    if interpret is None:
        interpret = _auto_interpret()
    nb, _ = data.shape
    widths = normalize_block_meta("widths", widths, nb)[:, None].astype(jnp.uint8)
    counts = normalize_block_meta("counts", counts, nb)
    bases = normalize_block_meta("bases", bases, nb)

    pad = (-nb) % block_tile
    if pad:
        widths = jnp.pad(widths, ((0, pad), (0, 0)))
        data = jnp.pad(data, ((0, pad), (0, 0)))
        counts = jnp.pad(counts, ((0, pad),))
        bases = jnp.pad(bases, ((0, pad),))

    counts2 = counts.astype(jnp.int32)[:, None]
    bases2 = jax.lax.bitcast_convert_type(bases.astype(jnp.uint32), jnp.int32)[:, None]

    out = binpack_decode_blocked_pallas(
        widths,
        data,
        counts2,
        bases2,
        block_size=block_size,
        differential=differential,
        block_tile=block_tile,
        chunk_width=chunk_width,
        interpret=interpret,
    )
    out = jax.lax.bitcast_convert_type(out, jnp.uint32)
    return out[:nb]
