"""Pallas TPU kernel: blocked Stream-VByte decode with fused differential sum.

The Masked-VByte kernel (``kernel.py``) spends its first half *recovering*
integer boundaries from continuation bits — the recurrence the paper calls
the expensive part of VByte decoding. Stream VByte stores those boundaries
explicitly as 2-bit codes in a control stream, so this kernel skips the
continuation-bit machinery entirely:

  * control bytes expand to per-integer codes via a one-hot **MXU** matmul
    (each of the 4 packed lanes selects its control byte) + static shifts,
  * integer lengths = code + 1, masked past ``count``,
  * byte→integer routing is a strict-triangular f32 matmul prefix sum over
    the *lengths* (in the VByte kernel the same matmul runs over terminator
    flags — here the operand comes straight from the control stream),
  * each data byte finds its owner by comparing its index against the start
    offsets (branch-free rank computation), and its in-integer position is
    ``i - start[owner]`` with the owner's start gathered by a one-hot MXU
    matmul,
  * reassembly reuses the 16-bit-split one-hot MXU scatter: lo halfword
    collects positions 0–1, hi halfword positions 2–3, recombined with a
    wrap-around int32 shift-add (≡ mod 2^32, i.e. uint32) — all per-output
    f32 accumulations stay < 2^16 ≪ 2^24, so the MXU is exact,
  * fused differential prefix sum via the shared triangular-matmul helper.

All tensors live in VMEM; shapes are static; padding control codes are zeros
(code 0 = length 1) so masking by ``count`` is load-bearing, as everywhere
else in this repo.

``chunk_width=W`` replaces the O(S·B) rank/gather/scatter routing above
with the chunked banded scatter: per-integer end flags are banded into
byte space (a W-integer chunk spans ≤ 4W data bytes), after which the
byte→integer machinery is exactly the Masked-VByte banded core — O(S·W)
MACs, bit-identical output (docs/kernels.md §Banded chunked scatter).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .banded import (banded_scatter_u32, chunked_prefix, normalize_chunk_width,
                     pad_cols, place_bands)
from .kernel import prefix_sum_tile

MAX_BYTES_PER_INT = 4


def _shift_right_fill(x: jax.Array, k: int, fill: int) -> jax.Array:
    """x[..., i-k] with constant fill — static slices only (Mosaic-safe)."""
    t, s = x.shape
    return jnp.concatenate(
        [jnp.full((t, k), fill, x.dtype), x[:, : s - k]], axis=1)


def stream_decode_tile(control: jax.Array, data: jax.Array, counts: jax.Array,
                       *, block_size: int,
                       chunk_width: int | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """Decode one VMEM tile of Stream-VByte (control, data) bytes.

    Same ``(out int32 [T, B], valid bool [T, B])`` contract as
    ``kernel.decode_tile`` — the shared decode-tile core every fused
    epilogue plugs into.

    ``chunk_width=None`` runs the dense routing: the full ``[T, S, B]``
    owner-rank tensor (every data byte compared against every integer's
    start) reused as a one-hot for the owner-start gather and the two
    scatter matmuls. An integer ``W`` selects the chunked banded routing:
    per-integer **end flags** are scattered into byte space through narrow
    ``[T, ng, W, 4W]`` bands (an integer chunk of W integers spans ≤ 4W
    data bytes), after which the byte→integer machinery is exactly the
    Masked-VByte banded core — chunked prefix of the end flags, closed-form
    in-integer positions, ``[T, nC, W, W]`` banded scatter. O(S·W) instead
    of O(S·B), identical uint32 output bit-for-bit.
    """
    T, C = control.shape
    _, S = data.shape
    B = block_size

    ctrl = control.astype(jnp.int32)  # [T, C]

    # expand control bytes C -> B: column j reads ctrl[:, j // 4].
    if chunk_width is None:
        # dense core: a one-hot f32 matmul plays the role of the unpack
        # shuffle (ctrl < 256: f32-exact)
        cc = lax.broadcasted_iota(jnp.int32, (C, B), 0)
        jj = lax.broadcasted_iota(jnp.int32, (C, B), 1)
        expand = (jj // 4 == cc).astype(jnp.float32)  # [C, B]
        packed = lax.dot(
            ctrl.astype(jnp.float32), expand,
            preferred_element_type=jnp.float32).astype(jnp.int32)  # [T, B]
    else:
        # banded core: the unpack is a static ×4 lane broadcast — zero MACs
        packed = jnp.broadcast_to(ctrl[:, :, None], (T, C, 4)).reshape(T, B)

    jrow = lax.broadcasted_iota(jnp.int32, (T, B), 1)
    code = (packed >> (2 * (jrow % 4))) & 3
    valid_int = jrow < counts  # [T, B] < [T, 1]
    length = jnp.where(valid_int, code + 1, 0)

    if chunk_width is None:
        out = _dense_stream_routing(data, length, valid_int, S, B, T)
    else:
        out = _banded_stream_routing(
            data, length, valid_int, counts,
            W=normalize_chunk_width(chunk_width, B), S=S, B=B, T=T)

    out = jnp.where(valid_int, out, 0)
    return out, valid_int


def _dense_stream_routing(data, length, valid_int, S, B, T):
    """Dense O(S·B) routing: full rank tensor + one-hot gather/scatter."""
    # start offset of every integer: exclusive prefix sum over lengths
    # (strict-triangular MXU matmul; sums ≤ 4·B ≪ 2^24, f32-exact)
    kk = lax.broadcasted_iota(jnp.int32, (B, B), 0)
    ll = lax.broadcasted_iota(jnp.int32, (B, B), 1)
    strict_tri = (kk < ll).astype(jnp.float32)
    starts = lax.dot(
        length.astype(jnp.float32), strict_tri, preferred_element_type=jnp.float32
    ).astype(jnp.int32)  # [T, B]
    total = jnp.sum(length, axis=1, keepdims=True)  # [T, 1] valid data bytes

    # owner of data byte i: rank of i among start offsets (branch-free).
    # out_idx[t,i] = #{j : valid_int[t,j] and starts[t,j] <= i} - 1
    ib = lax.broadcasted_iota(jnp.int32, (T, S, B), 1)
    started = (starts[:, None, :] <= ib) & valid_int[:, None, :]
    out_idx = jnp.sum(started.astype(jnp.int32), axis=2) - 1  # [T, S]

    irow = lax.broadcasted_iota(jnp.int32, (T, S), 1)
    valid_byte = irow < total  # padding bytes own nothing

    # in-integer position: i - starts[owner], owner's start gathered by a
    # one-hot MXU matmul (starts ≤ S ≤ a few thousand: f32-exact)
    jvec = lax.broadcasted_iota(jnp.int32, (T, S, B), 2)
    onehot = (out_idx[:, :, None] == jvec).astype(jnp.float32)  # [T, S, B]
    dnums = (((2,), (1,)), ((0,), (0,)))  # contract over B, batch over T
    owner_start = lax.dot_general(
        onehot, starts.astype(jnp.float32), dnums,
        preferred_element_type=jnp.float32,
    ).astype(jnp.int32)  # [T, S]
    pos = jnp.clip(irow - owner_start, 0, MAX_BYTES_PER_INT - 1)

    # contributions, split by 16-bit halfword before the MXU scatter:
    # positions 0-1 build the low halfword, positions 2-3 the high one.
    byte = data.astype(jnp.int32)
    lo = jnp.where(valid_byte & (pos < 2), byte << (8 * pos), 0)
    hi = jnp.where(valid_byte & (pos >= 2), byte << (8 * (pos - 2)), 0)

    # one-hot MXU scatter: out[t,j] = Σ_i [out_idx[t,i]==j]·contrib[t,i]
    sdnums = (((1,), (1,)), ((0,), (0,)))  # contract over S, batch over T
    lo_sum = lax.dot_general(
        onehot, lo.astype(jnp.float32), sdnums, preferred_element_type=jnp.float32
    )
    hi_sum = lax.dot_general(
        onehot, hi.astype(jnp.float32), sdnums, preferred_element_type=jnp.float32
    )
    return lo_sum.astype(jnp.int32) + (hi_sum.astype(jnp.int32) << 16)  # [T, B]


def _banded_stream_routing(data, length, valid_int, counts, *, W, S, B, T):
    """Chunked O(S·W) routing via end flags in byte space.

    Stage 1 — integer-axis chunking: chunked prefix of the lengths gives
    every integer's start; an integer chunk of W integers spans at most
    4W data bytes, so each integer's end flag (at ``start+len-1``) lands
    inside a [4W]-wide band anchored at the chunk's first start. The bands
    are summed into byte space at their (data-dependent) anchors by the
    shared barrel-shift placement.

    Stage 2 — byte-axis chunking: with end flags materialized, the owner
    of byte i is the number of flags strictly before i and the in-integer
    position has the Masked-VByte closed form (lengths ≤ 4 close it after
    three terms), so the chunked prefix + banded one-hot scatter of
    ``banded.py`` finish the job exactly as in ``kernel.decode_tile``.
    """
    # integer starts via chunked prefix over the lengths (B axis, padded to
    # a chunk multiple; padding lengths are zero so starts stay == total)
    len_p = pad_cols(length, W)  # [T, Bp]
    Bp = len_p.shape[1]
    ng = Bp // W
    loc_l, base_l = chunked_prefix(len_p, W)
    starts_p = (base_l[:, :, None] + loc_l).reshape(T, Bp)

    # end flag of integer j sits at starts[j] + length[j] - 1; scatter the
    # flags through [ng, W, 4W] bands anchored at each chunk's first start
    end_pos = starts_p + len_p - 1  # [T, Bp]; invalid ints masked below
    byte_base = starts_p.reshape(T, ng, W)[:, :, 0]  # [T, ng] anchors
    local_end = end_pos.reshape(T, ng, W) - byte_base[:, :, None]
    ovec = lax.broadcasted_iota(jnp.int32, (T, ng, W, 4 * W), 3)
    is_end = ((local_end[:, :, :, None] == ovec)
              & (len_p.reshape(T, ng, W)[:, :, :, None] > 0))
    ends_band = jnp.sum(is_end.astype(jnp.int32), axis=2)  # [T, ng, 4W]
    Sp = S + ((-S) % W)
    ends = place_bands(ends_band, byte_base, Sp)  # [T, Sp] end flags

    # in-integer position: closed form over preceding non-end flags
    # (lengths ≤ 4 ⇒ three terms); byte -1 is treated as an end (fill=1)
    e1 = _shift_right_fill(ends, 1, 1)
    e2 = _shift_right_fill(ends, 2, 1)
    e3 = _shift_right_fill(ends, 3, 1)
    pos = (1 - e1) * (1 + (1 - e2) * (1 + (1 - e3)))  # [T, Sp]
    pos = pos[:, :S]

    # owner of byte i = #end flags strictly before i (chunked prefix);
    # bytes past the last valid end flag get out_idx == count ⇒ masked
    loc_b, base_b = chunked_prefix(ends, W)
    nC = Sp // W
    out_idx = (base_b[:, :, None] + loc_b).reshape(T, Sp)[:, :S]
    keep = out_idx < counts  # [T, S] < [T, 1]

    byte = data.astype(jnp.int32)
    lo = jnp.where(keep & (pos < 2), byte << (8 * pos), 0)
    hi = jnp.where(keep & (pos >= 2), byte << (8 * (pos - 2)), 0)
    lo = pad_cols(lo, W).reshape(T, nC, W)
    hi = pad_cols(hi, W).reshape(T, nC, W)
    return banded_scatter_u32(loc_b, lo, hi, base_b, B)


def _stream_decode_tile_kernel(control_ref, data_ref, counts_ref, bases_ref,
                               out_ref, *, block_size: int, differential: bool,
                               chunk_width: int | None):
    out, valid = stream_decode_tile(control_ref[...], data_ref[...],
                                    counts_ref[...], block_size=block_size,
                                    chunk_width=chunk_width)
    if differential:
        out = prefix_sum_tile(out, valid, bases_ref[...])
    out_ref[...] = out


def stream_decode_blocked_pallas(
    control: jax.Array,  # uint8 [n_blocks, block_size // 4]
    data: jax.Array,  # uint8 [n_blocks, data_stride]
    counts: jax.Array,  # int32 [n_blocks, 1]
    bases: jax.Array,  # int32 [n_blocks, 1] (bitcast of uint32)
    *,
    block_size: int,
    differential: bool,
    block_tile: int = 8,
    chunk_width: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Raw pallas_call wrapper; see ops.stream_vbyte_decode_blocked."""
    nb, C = control.shape
    _, stride = data.shape
    if C * 4 != block_size:
        raise ValueError(f"control width {C} != block_size/4 = {block_size // 4}")
    if nb % block_tile:
        raise ValueError(f"n_blocks={nb} must be a multiple of block_tile={block_tile}")
    grid = (nb // block_tile,)
    kernel = functools.partial(
        _stream_decode_tile_kernel, block_size=block_size,
        differential=differential, chunk_width=chunk_width
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_tile, C), lambda g: (g, 0)),
            pl.BlockSpec((block_tile, stride), lambda g: (g, 0)),
            pl.BlockSpec((block_tile, 1), lambda g: (g, 0)),
            pl.BlockSpec((block_tile, 1), lambda g: (g, 0)),
        ],
        out_specs=pl.BlockSpec((block_tile, block_size), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block_size), jnp.int32),
        interpret=interpret,
    )(control, data, counts, bases)
