"""Pallas TPU kernel: blocked Stream-VByte decode with fused differential sum.

The Masked-VByte kernel (``kernel.py``) spends its first half *recovering*
integer boundaries from continuation bits — the recurrence the paper calls
the expensive part of VByte decoding. Stream VByte stores those boundaries
explicitly as 2-bit codes in a control stream, so this kernel skips the
continuation-bit machinery entirely:

  * control bytes expand to per-integer codes via a one-hot **MXU** matmul
    (each of the 4 packed lanes selects its control byte) + static shifts,
  * integer lengths = code + 1, masked past ``count``,
  * byte→integer routing is a strict-triangular f32 matmul prefix sum over
    the *lengths* (in the VByte kernel the same matmul runs over terminator
    flags — here the operand comes straight from the control stream),
  * each data byte finds its owner by comparing its index against the start
    offsets (branch-free rank computation), and its in-integer position is
    ``i - start[owner]`` with the owner's start gathered by a one-hot MXU
    matmul,
  * reassembly reuses the 16-bit-split one-hot MXU scatter: lo halfword
    collects positions 0–1, hi halfword positions 2–3, recombined with a
    wrap-around int32 shift-add (≡ mod 2^32, i.e. uint32) — all per-output
    f32 accumulations stay < 2^16 ≪ 2^24, so the MXU is exact,
  * fused differential prefix sum via the shared triangular-matmul helper.

All tensors live in VMEM; shapes are static; padding control codes are zeros
(code 0 = length 1) so masking by ``count`` is load-bearing, as everywhere
else in this repo.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .kernel import prefix_sum_tile

MAX_BYTES_PER_INT = 4


def stream_decode_tile(control: jax.Array, data: jax.Array, counts: jax.Array,
                       *, block_size: int) -> tuple[jax.Array, jax.Array]:
    """Decode one VMEM tile of Stream-VByte (control, data) bytes.

    Same ``(out int32 [T, B], valid bool [T, B])`` contract as
    ``kernel.decode_tile`` — the shared decode-tile core every fused
    epilogue plugs into.
    """
    T, C = control.shape
    _, S = data.shape
    B = block_size

    ctrl = control.astype(jnp.int32)  # [T, C]

    # expand control bytes C -> B: column j reads ctrl[:, j // 4]. A one-hot
    # f32 matmul plays the role of the unpack shuffle (ctrl < 256: f32-exact).
    cc = lax.broadcasted_iota(jnp.int32, (C, B), 0)
    jj = lax.broadcasted_iota(jnp.int32, (C, B), 1)
    expand = (jj // 4 == cc).astype(jnp.float32)  # [C, B]
    packed = lax.dot(
        ctrl.astype(jnp.float32), expand, preferred_element_type=jnp.float32
    ).astype(jnp.int32)  # [T, B]

    jrow = lax.broadcasted_iota(jnp.int32, (T, B), 1)
    code = (packed >> (2 * (jrow % 4))) & 3
    valid_int = jrow < counts  # [T, B] < [T, 1]
    length = jnp.where(valid_int, code + 1, 0)

    # start offset of every integer: exclusive prefix sum over lengths
    # (strict-triangular MXU matmul; sums ≤ 4·B ≪ 2^24, f32-exact)
    kk = lax.broadcasted_iota(jnp.int32, (B, B), 0)
    ll = lax.broadcasted_iota(jnp.int32, (B, B), 1)
    strict_tri = (kk < ll).astype(jnp.float32)
    starts = lax.dot(
        length.astype(jnp.float32), strict_tri, preferred_element_type=jnp.float32
    ).astype(jnp.int32)  # [T, B]
    total = jnp.sum(length, axis=1, keepdims=True)  # [T, 1] valid data bytes

    # owner of data byte i: rank of i among start offsets (branch-free).
    # out_idx[t,i] = #{j : valid_int[t,j] and starts[t,j] <= i} - 1
    ib = lax.broadcasted_iota(jnp.int32, (T, S, B), 1)
    started = (starts[:, None, :] <= ib) & valid_int[:, None, :]
    out_idx = jnp.sum(started.astype(jnp.int32), axis=2) - 1  # [T, S]

    irow = lax.broadcasted_iota(jnp.int32, (T, S), 1)
    valid_byte = irow < total  # padding bytes own nothing

    # in-integer position: i - starts[owner], owner's start gathered by a
    # one-hot MXU matmul (starts ≤ S ≤ a few thousand: f32-exact)
    jvec = lax.broadcasted_iota(jnp.int32, (T, S, B), 2)
    onehot = (out_idx[:, :, None] == jvec).astype(jnp.float32)  # [T, S, B]
    dnums = (((2,), (1,)), ((0,), (0,)))  # contract over B, batch over T
    owner_start = lax.dot_general(
        onehot, starts.astype(jnp.float32), dnums,
        preferred_element_type=jnp.float32,
    ).astype(jnp.int32)  # [T, S]
    pos = jnp.clip(irow - owner_start, 0, MAX_BYTES_PER_INT - 1)

    # contributions, split by 16-bit halfword before the MXU scatter:
    # positions 0-1 build the low halfword, positions 2-3 the high one.
    byte = data.astype(jnp.int32)
    lo = jnp.where(valid_byte & (pos < 2), byte << (8 * pos), 0)
    hi = jnp.where(valid_byte & (pos >= 2), byte << (8 * (pos - 2)), 0)

    # one-hot MXU scatter: out[t,j] = Σ_i [out_idx[t,i]==j]·contrib[t,i]
    sdnums = (((1,), (1,)), ((0,), (0,)))  # contract over S, batch over T
    lo_sum = lax.dot_general(
        onehot, lo.astype(jnp.float32), sdnums, preferred_element_type=jnp.float32
    )
    hi_sum = lax.dot_general(
        onehot, hi.astype(jnp.float32), sdnums, preferred_element_type=jnp.float32
    )
    out = lo_sum.astype(jnp.int32) + (hi_sum.astype(jnp.int32) << 16)  # [T, B]

    out = jnp.where(valid_int, out, 0)
    return out, valid_int


def _stream_decode_tile_kernel(control_ref, data_ref, counts_ref, bases_ref,
                               out_ref, *, block_size: int, differential: bool):
    out, valid = stream_decode_tile(control_ref[...], data_ref[...],
                                    counts_ref[...], block_size=block_size)
    if differential:
        out = prefix_sum_tile(out, valid, bases_ref[...])
    out_ref[...] = out


def stream_decode_blocked_pallas(
    control: jax.Array,  # uint8 [n_blocks, block_size // 4]
    data: jax.Array,  # uint8 [n_blocks, data_stride]
    counts: jax.Array,  # int32 [n_blocks, 1]
    bases: jax.Array,  # int32 [n_blocks, 1] (bitcast of uint32)
    *,
    block_size: int,
    differential: bool,
    block_tile: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Raw pallas_call wrapper; see ops.stream_vbyte_decode_blocked."""
    nb, C = control.shape
    _, stride = data.shape
    if C * 4 != block_size:
        raise ValueError(f"control width {C} != block_size/4 = {block_size // 4}")
    if nb % block_tile:
        raise ValueError(f"n_blocks={nb} must be a multiple of block_tile={block_tile}")
    grid = (nb // block_tile,)
    kernel = functools.partial(
        _stream_decode_tile_kernel, block_size=block_size, differential=differential
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_tile, C), lambda g: (g, 0)),
            pl.BlockSpec((block_tile, stride), lambda g: (g, 0)),
            pl.BlockSpec((block_tile, 1), lambda g: (g, 0)),
            pl.BlockSpec((block_tile, 1), lambda g: (g, 0)),
        ],
        out_specs=pl.BlockSpec((block_tile, block_size), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block_size), jnp.int32),
        interpret=interpret,
    )(control, data, counts, bases)
