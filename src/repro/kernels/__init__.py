"""Pallas TPU kernels for the compute hot-spot the paper optimizes:
vectorized VByte decoding (with fused differential prefix sum)."""
