"""Two-tower retrieval [Yi et al., RecSys'19 (YouTube)] — embed_dim 256,
tower MLP 1024-512-256, dot-product interaction, in-batch sampled softmax.
Id embeddings 128-wide over 2^23 users / 2^23 items (row-sharded).
retrieval_cand decodes a VByte-compressed 1M-candidate posting list inside
the serving graph.
"""
from repro.models.recsys import RecSysConfig

CONFIG = RecSysConfig(
    name="two-tower-retrieval",
    kind="two_tower",
    n_items=1 << 23,
    n_users=1 << 23,
    embed_dim=256,
    id_dim=128,
    seq_len=50,
    mlp_dims=(1024, 512, 256),
    serve_candidates=4096,
)

FAMILY = "recsys"
SKIPS = {}
