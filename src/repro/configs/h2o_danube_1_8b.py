"""H2O-Danube-1.8B [arXiv:2401.16818; hf h2oai/h2o-danube-1.8b-base].

24L, d_model 2560, 32 heads (GQA kv=8), d_ff 6912, vocab 32000.
Llama+Mistral mix: sliding-window attention (4096) → long_500k runs.
head_dim = 2560/32 = 80.
"""
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    microbatch=4,
    name="h2o-danube-1.8b",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab=32000,
    rope_theta=10000.0,
    window=4096,
)

FAMILY = "lm"
SKIPS = {}
