"""GLM4-9B [hf THUDM/glm-4-9b].

40L, d_model 4096, 32 heads (GQA kv=2), d_ff 13696, vocab 151552, RoPE with
partial rotary (half the head dims). Pure full attention → long_500k skipped.
Simplification noted in DESIGN.md: GLM4's post-attention residual config is
mapped onto the shared pre-norm block (same FLOP/byte profile).
"""
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    microbatch=8,
    name="glm4-9b",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=151552,
    rope_theta=10000.0,
    rotary_fraction=0.5,
)

FAMILY = "lm"
SKIPS = {"long_500k": "pure full attention — no sub-quadratic path (spec: skip)"}
