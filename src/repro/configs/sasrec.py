"""SASRec [arXiv:1808.09781] — embed_dim 50, 2 blocks, 1 head, seq 50,
causal self-attention, next-item binary CE with sampled negatives.
Item vocabulary scaled to 2^20 rows (taxonomy §B.6 huge-table regime);
histories are VByte posting lists in the data pipeline.
"""
from repro.models.recsys import RecSysConfig

CONFIG = RecSysConfig(
    name="sasrec",
    kind="sasrec",
    n_items=1 << 20,
    embed_dim=50,
    seq_len=50,
    n_blocks=2,
    n_heads=1,
    serve_candidates=1024,
)

FAMILY = "recsys"
SKIPS = {}
