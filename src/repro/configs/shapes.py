"""Assigned input-shape sets (one per architecture family).

Sizes that feed node/edge-sharded tensors are padded up to multiples of 512
(= |pod×data×model| of the multi-pod mesh) with validity masks — the loaders
pad identically, so dry-run shapes match runtime shapes exactly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any


@dataclass(frozen=True)
class ShapeDef:
    name: str
    step: str  # "train" | "prefill" | "decode" | "serve" | "retrieval"
    dims: dict[str, Any] = field(default_factory=dict)


def _pad512(n: int) -> int:
    return -(-n // 512) * 512


# -- LM transformers ---------------------------------------------------------
LM_SHAPES = {
    "train_4k": ShapeDef("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    "prefill_32k": ShapeDef("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
    "decode_32k": ShapeDef("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
    "long_500k": ShapeDef("long_500k", "decode", {"seq_len": 524288, "global_batch": 1}),
}

# -- GNN (gin-tu) -------------------------------------------------------------
# d_feat / n_classes are dataset properties of each shape's public source:
# cora (full_graph_sm), reddit (minibatch_lg), ogbn-products, synthetic molecules.
GNN_SHAPES = {
    "full_graph_sm": ShapeDef("full_graph_sm", "train", {
        "n_nodes": _pad512(2708), "n_edges": _pad512(10556),
        "d_feat": 1433, "n_classes": 7, "compressed_adjacency": True,
        "payload_stride": 128, "raw_nodes": 2708, "raw_edges": 10556,
    }),
    "minibatch_lg": ShapeDef("minibatch_lg", "train", {
        # 1024 seeds, fanout 15-10 over a Reddit-scale graph (232965 nodes,
        # 114.6M edges, d_feat 602, 41 classes); padded sampler capacities.
        "n_nodes": _pad512(1024 * (1 + 15 + 150)), "n_edges": _pad512(1024 * (15 + 150)),
        "d_feat": 602, "n_classes": 41, "compressed_adjacency": False,
        "batch_nodes": 1024, "fanout": (15, 10),
        "graph_nodes": 232965, "graph_edges": 114615892,
    }),
    "ogb_products": ShapeDef("ogb_products", "train", {
        "n_nodes": _pad512(2449029), "n_edges": _pad512(61859140),
        "d_feat": 100, "n_classes": 47, "compressed_adjacency": True,
        "payload_stride": 384, "raw_nodes": 2449029, "raw_edges": 61859140,
    }),
    "molecule": ShapeDef("molecule", "train", {
        "n_nodes": 128 * 30, "n_edges": 128 * 64, "d_feat": 16, "n_classes": 2,
        "compressed_adjacency": False, "task": "graph", "batch_graphs": 128,
    }),
}

# -- RecSys -------------------------------------------------------------------
RECSYS_SHAPES = {
    "train_batch": ShapeDef("train_batch", "train", {"batch": 65536}),
    "serve_p99": ShapeDef("serve_p99", "serve", {"batch": 512}),
    "serve_bulk": ShapeDef("serve_bulk", "serve", {"batch": 262144}),
    "retrieval_cand": ShapeDef("retrieval_cand", "retrieval", {
        "batch": 1, "n_candidates": 1 << 20, "payload_stride": 256,
    }),
}
