"""GIN [arXiv:1810.00826] — 5 layers, d_hidden 64, sum aggregator, learnable ε.

d_feat / n_classes / adjacency mode vary per shape (cora, reddit-scale
sampled, ogbn-products, batched molecules) — resolved by the registry.
Adjacency for the full-graph shapes is VByte-compressed (DESIGN.md §3/§5:
the most paper-representative integration).
"""
from repro.models.gnn import GNNConfig

CONFIG = GNNConfig(
    name="gin-tu",
    n_layers=5,
    d_hidden=64,
)

FAMILY = "gnn"
SKIPS = {}
