"""BST — Behavior Sequence Transformer (Alibaba) [arXiv:1905.06874].

embed_dim 32, seq 20 (+ target item), 1 block, 8 heads, MLP 1024-512-256 →
CTR logit. Item vocabulary 2^23 rows. retrieval_cand runs the full ranker
per candidate (pointwise CTR scoring).
"""
from repro.models.recsys import RecSysConfig

CONFIG = RecSysConfig(
    name="bst",
    kind="bst",
    n_items=1 << 23,
    embed_dim=32,
    seq_len=20,
    n_blocks=1,
    n_heads=8,
    mlp_dims=(1024, 512, 256),
    serve_candidates=1024,
)

FAMILY = "recsys"
SKIPS = {}
