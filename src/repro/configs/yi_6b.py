"""Yi-6B [arXiv:2403.04652; hf 01-ai/Yi-6B].

32L, d_model 4096, 32 heads (GQA kv=4), d_ff 11008, vocab 64000, RoPE theta
5e6. Pure full attention → long_500k skipped (DESIGN.md §5).
"""
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    microbatch=8,
    name="yi-6b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab=64000,
    rope_theta=5e6,
)

FAMILY = "lm"
SKIPS = {"long_500k": "pure full attention — no sub-quadratic path (spec: skip)"}
