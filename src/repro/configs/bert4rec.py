"""BERT4Rec [arXiv:1904.06690] — embed_dim 64, 2 blocks, 2 heads, seq 200,
bidirectional encoder, masked-item prediction (15% → 30 positions) with
shared sampled negatives (encoder-only: its shape set has no decode step).
"""
from repro.models.recsys import RecSysConfig

CONFIG = RecSysConfig(
    name="bert4rec",
    kind="bert4rec",
    n_items=1 << 20,
    embed_dim=64,
    seq_len=200,
    n_blocks=2,
    n_heads=2,
    n_mask=30,
    n_negatives=1024,
    serve_candidates=1024,
)

FAMILY = "recsys"
SKIPS = {}
