"""OLMoE-1B-7B [arXiv:2409.02060; hf allenai/OLMoE-1B-7B-0924].

16L, d_model 2048, 16 heads (GQA kv=16 — i.e. MHA), per-expert d_ff 1024,
vocab 50304, 64 experts top-8. Full attention → long_500k skipped
(DESIGN.md §5). Expert-parallel: 64 experts % 16 TP shards == 0.
"""
from repro.models.lm import LMConfig, MoESettings

CONFIG = LMConfig(
    microbatch=4,
    name="olmoe-1b-7b",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,  # unused (MoE)
    vocab=50304,
    rope_theta=10000.0,
    moe=MoESettings(n_experts=64, top_k=8, d_ff=1024, ep_shard=True),
)

FAMILY = "lm"
SKIPS = {"long_500k": "pure full attention — no sub-quadratic path (spec: skip)"}
