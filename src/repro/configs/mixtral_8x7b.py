"""Mixtral-8x7B [arXiv:2401.04088; hf mistralai/Mixtral-8x7B-v0.1].

32L, d_model 4096, 32 heads (GQA kv=8), per-expert d_ff 14336, vocab 32000,
8 experts top-2, sliding-window attention (window 4096, rolling-buffer KV
cache) → long_500k runs. 8 experts < 16 TP shards → tensor-parallel inside
experts (d_ff sharded), experts replicated across the model axis.
"""
from repro.models.lm import LMConfig, MoESettings

CONFIG = LMConfig(
    microbatch=8,
    name="mixtral-8x7b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,  # unused (MoE)
    vocab=32000,
    rope_theta=1e6,
    window=4096,
    moe=MoESettings(n_experts=8, top_k=2, d_ff=14336, ep_shard=False),
)

FAMILY = "lm"
SKIPS = {}
