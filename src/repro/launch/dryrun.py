import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell:  jit(step, in_shardings).lower(*ShapeDtypeStructs).compile()
on the 16×16 single-pod mesh and the 2×16×16 multi-pod mesh — no array is
ever allocated. Records memory_analysis(), cost_analysis() and the parsed
collective schedule into a JSON per cell (consumed by EXPERIMENTS.md §Dry-run
/ §Roofline and the perf loop).

Usage:
  python -m repro.launch.dryrun --arch olmoe-1b-7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k \
      --override banded_attention=true --tag banded
"""
import argparse
import json
import time
import traceback

import jax

from repro.distributed.api import activate_mesh
from repro.distributed.hlo_analysis import collective_stats
from repro.launch import cost_model as cm
from repro.launch import roofline_math as rm
from repro.launch.mesh import dp_degree, make_production_mesh
from repro.models import registry


def _parse_overrides(items):
    out = {}
    for kv in items or []:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                out[k] = cast(v)
                break
            except ValueError:
                continue
        else:
            out[k] = {"true": True, "false": False}.get(v.lower(), v)
    return out


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             overrides: dict | None = None, keep_hlo: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    cell = registry.build_cell(arch, shape, mesh_dp=dp_degree(mesh),
                               overrides=overrides)
    record = {
        "arch": arch, "shape": shape, "step": cell.shape.step,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "n_chips": int(n_chips), "overrides": overrides or {},
    }
    t0 = time.time()
    with activate_mesh(mesh):
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings(mesh),
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        record["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 2)

    mem = compiled.memory_analysis()
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        record[attr] = int(getattr(mem, attr, 0) or 0)
    record["peak_bytes_per_device"] = (
        record["argument_size_in_bytes"] + record["output_size_in_bytes"]
        + record["temp_size_in_bytes"] - record["alias_size_in_bytes"]
    )

    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_ = float(cost.get("bytes accessed", 0.0))
    record["hlo_flops_per_device"] = flops
    record["hlo_bytes_per_device"] = bytes_

    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    record["collectives"] = coll["ops"]
    record["wire_bytes_parsed"] = coll["total_wire_bytes"]
    if keep_hlo:
        record["hlo_lines"] = len(hlo.splitlines())

    # corrected per-device cost: XLA-CPU HloCostAnalysis counts scan bodies
    # once and charges gathers for their WHOLE operand (see cost_model.py) —
    # raw numbers kept above for comparison.
    corr = cm.cell_cost(cell, n_chips=n_chips, dp=dp_degree(mesh))
    record["corrected_flops_per_device"] = corr.flops
    record["corrected_bytes_per_device"] = corr.bytes
    # wire policy per family: LM lowers through scans (parsed under-counts ->
    # max with the analytic model); GNN collectives are bf16 in the model but
    # XLA-CPU *promotes bf16 all-reduce to f32* (TPU does them natively) ->
    # trust the analytic model; recsys is scan-free and gather-dominated ->
    # trust the parsed ops.
    if cell.family == "lm":
        wire = max(corr.wire_bytes, coll["total_wire_bytes"])
    elif cell.family == "gnn":
        wire = corr.wire_bytes
    else:
        wire = coll["total_wire_bytes"]
    record["wire_bytes_per_device"] = wire

    mf = rm.model_flops_global(cell) / n_chips
    # bytes: LM lowers through scans (raw under-counts -> take max); gnn and
    # recsys are scan-free but gather-heavy (raw over-counts whole embedding
    # tables / node arrays per gather -> trust the analytic model)
    eff_bytes = max(corr.bytes, bytes_) if cell.family == "lm" else corr.bytes
    roof = rm.make_roofline(max(corr.flops, flops), eff_bytes, wire, mf)
    record["roofline"] = roof.to_dict()
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--override", action="append", default=[])
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    overrides = _parse_overrides(args.override)
    os.makedirs(args.out, exist_ok=True)

    cells = (
        [(a, s) for a, s, _ in registry.all_cells()]
        if args.all else [(args.arch, args.shape)]
    )
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = ("multi" if mp else "single") + (f"_{args.tag}" if args.tag else "")
            name = f"{arch}__{shape}__{tag}"
            path = os.path.join(args.out, name + ".json")
            try:
                rec = run_cell(arch, shape, multi_pod=mp, overrides=overrides)
                rec["tag"] = args.tag
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                r = rec["roofline"]
                print(f"[OK] {name}: compile={rec['compile_s']}s "
                      f"flops/dev={rec['hlo_flops_per_device']:.3e} "
                      f"dominant={r['dominant']} "
                      f"roofline_frac={r['roofline_fraction']:.3f} "
                      f"peak_mem={rec['peak_bytes_per_device']/2**30:.2f}GiB",
                      flush=True)
            except Exception as e:  # a failing cell is a bug; record + continue
                failures += 1
                with open(path + ".err", "w") as f:
                    f.write(traceback.format_exc())
                print(f"[FAIL] {name}: {type(e).__name__}: {e}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
