"""Production mesh definitions.

Defined as functions (never module-level constants) so importing this module
never touches jax device state. The dry-run forces 512 host-platform devices
*before* any jax import (see dryrun.py); everything else sees 1 CPU device.
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh needs {n} devices, found {len(devices)} — run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 (dryrun.py does this)"
        )
    return jax.make_mesh(shape, axes, devices=devices[:n])


def dp_degree(mesh) -> int:
    n = 1
    for name in ("pod", "data"):
        if name in mesh.axis_names:
            n *= mesh.shape[name]
    return n


def make_host_mesh():
    """1-device mesh for CPU smoke tests (same axis names as single-pod)."""
    return jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])
