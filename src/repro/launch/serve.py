"""Serving launcher: prefill + decode loop (LM) or scoring (recsys).

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced --tokens 16
    PYTHONPATH=src python -m repro.launch.serve --arch two-tower-retrieval --reduced
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.distributed.api import activate_mesh
from repro.launch.mesh import make_host_mesh
from repro.models import registry


def serve_lm(cfg, tokens_to_gen: int, batch: int):
    from repro.models import lm

    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (batch, 16)), jnp.int32)
    prefill = jax.jit(lambda p, t: lm.prefill(p, t, cfg,
                                              cache_capacity=16 + tokens_to_gen))
    decode = jax.jit(lambda p, c, t: lm.decode_step(p, c, t, cfg))
    logits, cache = prefill(params, prompt)
    out = []
    t0 = time.time()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(tokens_to_gen):
        out.append(tok)
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(tok)
    dt = (time.time() - t0) / tokens_to_gen
    print(f"generated {tokens_to_gen} tokens x batch {batch}: "
          f"{dt*1e3:.1f} ms/token ({batch/dt:.0f} tok/s aggregate)")
    print("sample:", np.asarray(jnp.stack(out, 1))[0, :12])


def serve_recsys(cfg, batch: int):
    from repro.data.synthetic import recsys_batch
    from repro.models import recsys

    params = recsys.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    serve = jax.jit(lambda p, b: recsys.serve_scores(p, b, cfg))
    if cfg.kind == "bst":
        b = {"hist": jnp.asarray(rng.integers(1, cfg.n_items, (batch, cfg.seq_len)),
                                 jnp.int32),
             "target": jnp.asarray(rng.integers(1, cfg.n_items, batch), jnp.int32)}
    elif cfg.kind == "two_tower":
        b = {"user_id": jnp.asarray(rng.integers(1, 100, batch), jnp.int32),
             "hist": jnp.asarray(rng.integers(1, cfg.n_items,
                                              (batch, cfg.seq_len)), jnp.int32),
             "cands": jnp.asarray(rng.integers(1, cfg.n_items,
                                               cfg.serve_candidates), jnp.int32)}
    else:
        b = {"hist": jnp.asarray(rng.integers(1, cfg.n_items,
                                              (batch, cfg.seq_len)), jnp.int32),
             "cands": jnp.asarray(rng.integers(1, cfg.n_items,
                                               (batch, cfg.serve_candidates)),
                                  jnp.int32)}
    scores = jax.block_until_ready(serve(params, b))
    t0 = time.time()
    for _ in range(10):
        scores = jax.block_until_ready(serve(params, b))
    dt = (time.time() - t0) / 10
    print(f"scored batch {batch}: {dt*1e3:.2f} ms/request "
          f"(scores shape {scores.shape})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    fam = registry.family_of(args.arch)
    cfg = registry.reduced_config(args.arch)
    with activate_mesh(make_host_mesh()):
        if fam == "lm":
            serve_lm(cfg, args.tokens, args.batch)
        elif fam == "recsys":
            serve_recsys(cfg, args.batch)
        else:
            raise SystemExit("gnn has no serve step (train-only shapes)")


if __name__ == "__main__":
    main()
