"""Serving launcher: LM prefill+decode loop, recsys scoring, the batched
compressed serving engine (:class:`ServingEngine`), and the inverted-index
search engine (:class:`SearchEngine`).

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced --tokens 16
    PYTHONPATH=src python -m repro.launch.serve --arch two-tower-retrieval --reduced
    PYTHONPATH=src python -m repro.launch.serve --arch two-tower-retrieval \
        --reduced --devices 8 --requests 256
    PYTHONPATH=src python -m repro.launch.serve --arch search --requests 64
    PYTHONPATH=src python -m repro.launch.serve --arch search --devices 8
    PYTHONPATH=src python -m repro.launch.serve --arch search --devices 8 \
        --degraded-smoke    # kill 1 of 8 shards, assert flagged partials
    PYTHONPATH=src python -m repro.launch.serve --arch search \
        --ingest-smoke      # WAL ingest, crash a merge, recover, parity

The two-tower arch runs the ``ServingEngine``: a compressed candidate
corpus resident on the mesh (``CompressedIntArray.shard`` — block dim over
the data axis), retrieval requests microbatched to a fixed set of jitted
bucket shapes, and scoring through the fused ``dot_score`` decode epilogue
against a precomputed item-vector table. It prints aggregate QPS and
p50/p99 request latency and merges them into ``experiments/benchmarks.json``
(the cross-PR perf trajectory). See docs/serving.md.

``--devices N`` forces N host-platform devices (sets XLA_FLAGS before jax
initializes), which is how the sharded engine is exercised on CPU.
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.obs import counter_inc as _obs_counter_inc, trace as _obs_trace
# moved to repro.obs.stats (one percentile definition repo-wide);
# re-exported here because engines and benchmarks historically import it
# from this module
from repro.obs.stats import latency_summary  # noqa: F401


def serve_lm(cfg, tokens_to_gen: int, batch: int):
    import numpy as np

    import jax
    import jax.numpy as jnp

    from repro.models import lm

    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (batch, 16)), jnp.int32)
    prefill = jax.jit(lambda p, t: lm.prefill(p, t, cfg,
                                              cache_capacity=16 + tokens_to_gen))
    decode = jax.jit(lambda p, c, t: lm.decode_step(p, c, t, cfg))
    logits, cache = prefill(params, prompt)
    out = []
    t0 = time.time()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(tokens_to_gen):
        out.append(tok)
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(tok)
    dt = (time.time() - t0) / tokens_to_gen
    print(f"generated {tokens_to_gen} tokens x batch {batch}: "
          f"{dt*1e3:.1f} ms/token ({batch/dt:.0f} tok/s aggregate)")
    print("sample:", np.asarray(jnp.stack(out, 1))[0, :12])


def serve_recsys(cfg, batch: int):
    import numpy as np

    import jax
    import jax.numpy as jnp

    from repro.models import recsys

    params = recsys.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    serve = jax.jit(lambda p, b: recsys.serve_scores(p, b, cfg))
    if cfg.kind == "bst":
        b = {"hist": jnp.asarray(rng.integers(1, cfg.n_items, (batch, cfg.seq_len)),
                                 jnp.int32),
             "target": jnp.asarray(rng.integers(1, cfg.n_items, batch), jnp.int32)}
    elif cfg.kind == "two_tower":
        b = {"user_id": jnp.asarray(rng.integers(1, 100, batch), jnp.int32),
             "hist": jnp.asarray(rng.integers(1, cfg.n_items,
                                              (batch, cfg.seq_len)), jnp.int32),
             "cands": jnp.asarray(rng.integers(1, cfg.n_items,
                                               cfg.serve_candidates), jnp.int32)}
    else:
        b = {"hist": jnp.asarray(rng.integers(1, cfg.n_items,
                                              (batch, cfg.seq_len)), jnp.int32),
             "cands": jnp.asarray(rng.integers(1, cfg.n_items,
                                               (batch, cfg.serve_candidates)),
                                  jnp.int32)}
    scores = jax.block_until_ready(serve(params, b))
    t0 = time.time()
    for _ in range(10):
        scores = jax.block_until_ready(serve(params, b))
    dt = (time.time() - t0) / 10
    print(f"scored batch {batch}: {dt*1e3:.2f} ms/request "
          f"(scores shape {scores.shape})")


# ---------------------------------------------------------------------------
# the batched compressed serving engine
# ---------------------------------------------------------------------------
class ServingEngine:
    """Serve retrieval / embedding-bag requests from a sharded compressed corpus.

    Architecture (docs/serving.md):

    * **Resident corpus** — the candidate id list lives compressed on the
      mesh: ``CompressedIntArray.shard(mesh, axis="data")`` places the block
      dimension across devices, and every decode runs block-parallel under
      ``shard_map`` where the bytes sit (no re-upload per request, no
      cross-device decode traffic).
    * **Precomputed item table** — the two-tower item tower runs ONCE over
      the vocabulary at engine build; serving gathers from the resulting
      ``[V, d]`` table inside the fused ``dot_score`` decode epilogue, so a
      request costs user-tower + decode-gather-dot + top-k.
    * **Bucketed microbatching** — requests are grouped to the next bucket
      size (default 1/2/4/8) and padded, so every serving step hits one of a
      fixed set of jitted shapes — no retracing in steady state. The decoded
      corpus is shared by the whole microbatch: the ``dot_score`` epilogue
      takes the bucket's ``[b, d]`` query matrix in one pass.

    ``retrieve(user_ids, hists)`` serves one microbatch; ``run_workload``
    drives a request list through the bucketing loop and reports aggregate
    QPS and per-request p50/p99 latency.
    """

    def __init__(self, params, cfg, corpus, *, mesh=None, axis="data",
                 top_k: int = 10, buckets=(1, 2, 4, 8),
                 plan="auto", dtype=None):
        import numpy as np

        import jax
        import jax.numpy as jnp

        from repro.models import recsys
        from repro.nn import layers as nnl

        self._np, self._jax, self._jnp = np, jax, jnp
        self.cfg = cfg
        self.params = params
        self.top_k = top_k
        self.plan = plan
        self.buckets = tuple(sorted(buckets))
        self.dtype = dtype or nnl.DEFAULT_COMPUTE_DTYPE
        self.mesh = mesh

        # resident corpus: sharded over the mesh axis, or (single device)
        # placed once — either way requests never re-upload the bytes
        self.corpus = (corpus.shard(mesh, axis=axis) if mesh is not None
                       else corpus.replace_leaves(**corpus.device_operands()))

        # precompute the item-vector table once: item_tower over the whole
        # (rounded) vocabulary. Row 0 is the pad row; dot_score pad slots
        # gather it, and retrieve() masks id==0 before top-k.
        item_ids = jnp.arange(cfg.vocab_rows, dtype=jnp.int32)
        table = jax.jit(
            lambda p: recsys.item_tower(p, item_ids, cfg, dtype=self.dtype)
        )(params).astype(self.dtype)
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P

            table = jax.device_put(table, NamedSharding(mesh, P()))
        self.item_table = jax.block_until_ready(table)

        # per-bucket jitted user tower + top-k post; the fused decode jits
        # itself per (mesh, workload) inside the dispatch layer
        self._user_fn = jax.jit(
            lambda p, uid, hist: recsys.user_tower(p, uid, hist, cfg,
                                                   dtype=self.dtype))
        self._topk_fn = jax.jit(self._mask_and_topk)
        self._stats = []
        # liveness: one heartbeat per served microbatch; run_workload
        # reports the detector's straggler classification (empty when
        # healthy — the coordinator hook for elastic re-meshing, ft/)
        from repro.ft import StragglerDetector

        self.detector = StragglerDetector()
        self._step = 0

    # -- retrieval ---------------------------------------------------------
    def _mask_and_topk(self, ids, scores):
        jnp = self._jnp
        flat_ids = ids.reshape(-1)  # [C]
        if scores.ndim == 2:  # single query: [nb, B]
            s = scores.reshape(1, -1)
        else:  # [nb, B, b] -> [b, C]
            s = scores.reshape(-1, scores.shape[-1]).T
        s = jnp.where(flat_ids[None, :] == 0, -jnp.inf, s)  # mask pad slots
        top_s, top_i = self._jax.lax.top_k(s, self.top_k)
        return top_s, jnp.take(flat_ids, top_i)

    def bucket_of(self, k: int) -> int:
        for b in self.buckets:
            if b >= k:
                return b
        return self.buckets[-1]

    def retrieve(self, user_ids, hists):
        """Serve one microbatch: [b] user ids + [b, L] histories →
        (scores [b, k], item ids [b, k]). b must be one of the buckets."""
        from repro.kernels.vbyte_decode import dispatch

        u = self._user_fn(self.params, user_ids, hists)  # [b, d]
        ids, scores = dispatch.decode(
            self.corpus, epilogue="dot_score",
            epilogue_operands={"table": self.item_table, "query": u},
            plan=self.plan)
        return self._topk_fn(ids, scores)

    # -- embedding-bag endpoint -------------------------------------------
    def embed_bags(self, bags, *, format="vbyte"):
        """Pooled embeddings for ragged id bags (one request = one bag).

        The bag list is compressed on the host (one block per bag, ragged
        layout) and reduced in the decode kernel's ``bag_sum`` epilogue —
        the microbatched analogue of ``user_tower_compressed``'s history
        path. Returns ``[len(bags), d]``.
        """
        from repro.core import CompressedIntArray
        from repro.nn.embedding_bag import embedding_bag_compressed

        k = len(bags)
        b = self.bucket_of(k)
        padded = list(bags) + [[] for _ in range(b - k)]
        arr = CompressedIntArray.encode_ragged(
            padded, format=format, block_size=self.cfg.seq_len,
            differential=False)
        out = embedding_bag_compressed(
            self.params["item_id_emb"]["emb"], arr, mode="mean",
            plan=self.plan, dtype=self.dtype)
        return out[:k]

    # -- workload driver ---------------------------------------------------
    def warmup(self):
        """Compile every bucket shape up front (excluded from latencies)."""
        np, jnp = self._np, self._jnp
        rng = np.random.default_rng(0)
        for b in self.buckets:
            uid = jnp.asarray(rng.integers(1, max(self.cfg.n_users, 2), b),
                              jnp.int32)
            hist = jnp.asarray(
                rng.integers(1, self.cfg.n_items, (b, self.cfg.seq_len)),
                jnp.int32)
            self._jax.block_until_ready(self.retrieve(uid, hist))

    def run_workload(self, requests, *, max_batch: int | None = None) -> dict:
        """Drive (user_id, hist) requests through the microbatching loop.

        Requests are drained greedily up to the largest bucket, padded to
        the bucket shape, and served. This is a closed-loop drain of a
        pre-built request list, so the reported p50/p99 are per-request
        **service** latencies (host marshal + engine step for the request's
        microbatch); queueing delay behind earlier batches is not included —
        aggregate QPS over the whole drain captures that side.
        """
        np, jnp, jax = self._np, self._jnp, self._jax
        # a microbatch can never exceed the largest jitted bucket shape
        max_batch = min(max_batch or self.buckets[-1], self.buckets[-1])
        lat = []
        i = 0
        t_start = time.perf_counter()
        while i < len(requests):
            take = min(max_batch, len(requests) - i)
            b = self.bucket_of(take)
            chunk = requests[i:i + take]
            t0 = time.perf_counter()
            with _obs_trace("microbatch", bucket=int(b), requests=int(take)):
                uid = np.full(b, 1, np.int32)
                hist = np.ones((b, self.cfg.seq_len), np.int32)
                for j, (u, h) in enumerate(chunk):
                    uid[j] = u
                    hist[j] = h
                top_s, top_i = self.retrieve(jnp.asarray(uid),
                                             jnp.asarray(hist))
                jax.block_until_ready((top_s, top_i))
            _obs_counter_inc("serve_requests_total", take, engine="serving")
            dt = time.perf_counter() - t0
            lat.extend([dt] * take)  # whole microbatch completes together
            self.detector.heartbeat("serve-host", self._step)
            self._step += 1
            i += take
        wall = time.perf_counter() - t_start
        stats = {
            "n_requests": len(requests),
            "n_devices": (int(self.mesh.devices.size)
                          if self.mesh is not None else 1),
            **latency_summary(lat, wall, len(requests)),
            "top_k": self.top_k,
            "corpus_n": self.corpus.n,
            "buckets": list(self.buckets),
            "stragglers": self.detector.stragglers(),
        }
        self._stats.append(stats)
        return stats


# ---------------------------------------------------------------------------
# the inverted-index search engine
# ---------------------------------------------------------------------------
class SearchEngine:
    """Serve boolean / top-k queries from a resident compressed inverted index.

    Architecture (docs/index.md):

    * **Resident index** — per-term compressed posting lists stay loaded
      for the engine's lifetime. Single-device, the term leaves stay host-
      side so the skip tables can slice out just the overlapping block
      ranges before upload (block-level pruning). With a ``mesh``, every
      term's block dimension is sharded across the devices instead
      (``CompressedIntArray.shard``) and each query decodes block-parallel
      under ``shard_map`` where the bytes live (``use_skip=False`` — the
      mesh replaces host slicing as the parallelism mechanism); the
      per-shard ``bm25_accum`` partials come back as one sharded
      ``[n_blocks, P]`` output whose host-side block-sum is the partial
      top-k merge.
    * **Microbatched queries** — candidate sets are processed in fixed
      ``probe_width`` chunks, so every membership/scoring step hits a
      bounded set of jitted shapes — no steady-state retracing, the
      query-engine analogue of ``ServingEngine``'s request buckets.

    ``search(terms, mode=...)`` serves one query; ``run_workload`` drives a
    query list and reports QPS, p50/p99 latency, and decode-vs-skip block
    accounting.

    **Degraded-mode serving** (docs/robustness.md): with ``validate=True``
    every term's streams are validated at startup — terms whose payload /
    metadata / checksum column fails are **quarantined** (dropped from
    queries, which come back flagged ``degraded``), terms whose
    ``max_impact`` bound is unsafe are kept but force a
    ``topk_maxscore`` → exhaustive-TAAT fallback (exact, just slower).
    Per-request ``Deadline`` budgets (``deadline_s``), bounded
    retry-with-backoff on transient :class:`DecodeError`\\ s (a failure
    carrying term coordinates quarantines that segment and the query is
    re-answered from the rest), and a logical-shard health layer
    (``n_shards`` + :class:`~repro.ft.StragglerDetector`: ``heartbeat`` /
    ``check_health`` / ``kill_shard`` / ``heal``) keep the engine answering
    — partial and flagged, never hung, never silently wrong.
    """

    def __init__(self, index, *, mesh=None, axis="data", top_k: int = 10,
                 plan="auto", probe_width: int = 512,
                 validate: bool = False, deep_validate: bool = False,
                 deadline_s: float | None = None, max_retries: int = 2,
                 backoff_s: float = 0.0, fault_hook=None,
                 n_shards: int = 0, clock=None):
        from dataclasses import replace as _dc_replace

        from repro.ft import StragglerDetector, shard_intervals

        self.index = index
        self.mesh = mesh
        self.top_k = top_k
        self.plan = plan
        self.probe_width = probe_width
        self.use_skip = mesh is None
        # -- robustness state ------------------------------------------------
        self.deadline_s = deadline_s
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.fault_hook = fault_hook  # fault_hook(attempt, terms, mode):
        #   raise DecodeError to inject a failure for attempt k (tests/CI)
        self.clock = clock or time.monotonic
        self.quarantined: dict = {}  # term -> reason (startup or at-serve)
        self.bound_unsafe: set = set()  # terms with unsafe max_impact bounds
        self.serve_stats = {"errors": 0, "retries": 0,
                            "quarantined_terms": 0, "quarantined_blocks": 0,
                            "bound_fallbacks": 0, "degraded_responses": 0}
        # logical shards: the sorted term list partitioned into n_shards
        # contiguous intervals (ft.elastic.shard_intervals) — the unit of
        # simulated host loss. A dead shard's terms are dropped from
        # queries (flagged degraded) until heal() re-partitions ownership.
        self.term_order = sorted(index.terms)
        self.n_shards = int(n_shards)
        self.detector = StragglerDetector()
        self.dead_shards: set = set()
        self.shard_of: dict = {}
        if self.n_shards:
            self._assign_shards(shard_intervals(len(self.term_order),
                                                self.n_shards))
        if validate:
            self._validate_index(deep=deep_validate)
        if mesh is not None:
            # shard every term's blocks across the mesh, once, up front —
            # the per-posting impact stream too (same block layout, so the
            # weighted scoring epilogues see aligned shards)
            sharded = {}
            for t, tp in index.terms.items():
                if tp.df:
                    arr = tp.arr.shard(mesh, axis=axis)
                    imp = (tp.impacts.shard(mesh, axis=axis)
                           if tp.impacts is not None else None)
                else:
                    arr, imp = tp.arr, tp.impacts
                sharded[t] = _dc_replace(tp, arr=arr, impacts=imp)
            self.index = _dc_replace(index, terms=sharded)
        self._stats = []

    # -- startup validation / quarantine ----------------------------------
    def _validate_index(self, *, deep: bool):
        """Gate every term at startup (docs/robustness.md).

        Structure + stream validation, skip-table/df invariants, and — when
        the stream carries a checksum column — a checksum-verified decode
        through the fused epilogue. Failing terms are quarantined. A
        :class:`BoundViolationError` (unsafe ``max_impact``, only checked
        with ``deep=True``) instead marks the term ``bound_unsafe``: its
        results are still exact under every mode except MaxScore pruning,
        so the engine keeps it and falls back to exhaustive TAAT.
        """
        from repro.robustness import (BoundViolationError, DecodeError,
                                      decode_checked, validate_array,
                                      validate_meta)

        for t in self.term_order:
            tp = self.index.terms[t]
            if not tp.df:
                continue
            try:
                validate_array(tp.arr, term=t)
                if tp.impacts is not None:
                    validate_array(tp.impacts, term=t)
                if tp.arr.checksums is not None:
                    decode_checked(tp.arr, plan=self.plan, term=t)
                if tp.impacts is not None and tp.impacts.checksums is not None:
                    decode_checked(tp.impacts, plan=self.plan, term=t)
                validate_meta(tp, deep=deep)
            except BoundViolationError:
                self.bound_unsafe.add(t)
            except DecodeError as e:
                self._quarantine(t, str(e))

    def _bump(self, key: str, n: int = 1, **labels):
        """Increment one robustness counter: the ``serve_stats`` dict (the
        stable in-process API benchmarks/tests read and reset) and, when
        telemetry is installed, the ``serve_<key>_total`` labeled counter
        in the metrics registry (docs/observability.md)."""
        self.serve_stats[key] += n
        _obs_counter_inc(f"serve_{key}_total", n, engine="search", **labels)

    def _quarantine(self, term, reason: str):
        if term in self.quarantined:
            return
        self.quarantined[term] = reason
        self._bump("quarantined_terms")
        tp = self.index.terms.get(term)
        if tp is not None:
            self._bump("quarantined_blocks", tp.n_blocks)

    # -- logical-shard health (ft.heartbeat + ft.elastic) ------------------
    def _assign_shards(self, intervals):
        self.shards = list(intervals)
        self.shard_of = {t: s for s, (lo, hi) in enumerate(self.shards)
                         for t in self.term_order[lo:hi]}

    def heartbeat(self, shard: int, step: int, now: float | None = None):
        """One liveness beat from a logical shard (tests drive sim time)."""
        self.detector.heartbeat(f"shard{shard}", step,
                                self.clock() if now is None else now)

    def check_health(self, now: float | None = None) -> dict:
        """Classify shards via the straggler detector; newly-'dead' shards
        are killed (their terms drop from queries until :meth:`heal`)."""
        report = self.detector.stragglers(
            self.clock() if now is None else now)
        for host, state in report.items():
            if state == "dead" and host.startswith("shard"):
                self.dead_shards.add(int(host[len("shard"):]))
        return report

    def kill_shard(self, shard: int):
        """Simulate losing one logical shard (CI degraded-serving smoke)."""
        self.dead_shards.add(int(shard))

    def heal(self):
        """Re-partition term ownership over the surviving shards.

        Uses :func:`repro.ft.elastic.reshard_plan` to map each new interval
        onto slices of the old partition (returned for inspection), then
        reassigns every term to a live owner — after healing no query is
        degraded by shard loss (the terms were host-resident all along;
        what died was the logical serving owner).
        """
        from repro.ft import reshard_plan, shard_intervals

        if not self.dead_shards:
            return []
        n_alive = self.n_shards - len(self.dead_shards)
        if n_alive <= 0:
            raise RuntimeError("no live shards left to heal onto")
        plan = reshard_plan(len(self.term_order), self.n_shards, n_alive)
        for s in self.dead_shards:
            self.detector.hosts.pop(f"shard{s}", None)
        self.n_shards = n_alive
        self._assign_shards(shard_intervals(len(self.term_order), n_alive))
        self.dead_shards = set()
        return plan

    # -- queries -----------------------------------------------------------
    def _run_query(self, terms, mode: str, stats, deadline):
        from repro.index import conjunctive, disjunctive, topk

        if not terms:  # everything quarantined / dead: empty, well-typed
            import numpy as np

            empty = np.zeros(0, np.uint32)
            return (empty if mode in ("and", "or")
                    else (empty, np.zeros(0, np.int32)))
        kw = dict(plan=self.plan, stats=stats, use_skip=self.use_skip,
                  deadline=deadline)
        if mode == "and":
            return conjunctive(self.index, terms,
                               probe_width=self.probe_width, **kw)
        if mode == "or":
            return disjunctive(self.index, terms, **kw)
        if mode in ("topk", "topk_driver", "topk_maxscore"):
            sub = {"topk": "or", "topk_driver": "driver",
                   "topk_maxscore": "maxscore"}[mode]
            return topk(self.index, terms, self.top_k, mode=sub,
                        probe_width=self.probe_width, **kw)
        raise ValueError(f"unknown query mode {mode!r}")

    def search(self, terms, mode: str = "and", *, stats=None, deadline=None):
        """One query. ``mode``: 'and' | 'or' → sorted uint32 docids;
        'topk' (disjunctive TAAT) | 'topk_maxscore' (block-max pruned,
        bit-identical results) | 'topk_driver' (required-term DAAT) →
        (docids, int32 scores), ordered (score desc, docid asc).

        Hardened path: quarantined / dead-shard terms are dropped (query
        flagged ``degraded`` via ``stats``), unsafe-bound terms force
        ``topk_maxscore`` → exhaustive TAAT, a :class:`DecodeError` raised
        mid-answer is retried up to ``max_retries`` times (term-coordinate
        failures quarantine the segment first), and an expired ``deadline``
        (or ``deadline_s`` default) yields a smaller, flagged result. The
        query never hangs and never returns silently-wrong data.
        """
        from repro.index import QueryStats
        from repro.robustness import Deadline, DecodeError

        with _obs_trace("request", mode=mode, terms=len(terms)) as rspan:
            with _obs_trace("admission"):
                qst = QueryStats()  # per-call: degraded flag is per query
                if deadline is None and self.deadline_s is not None:
                    deadline = Deadline(self.deadline_s, clock=self.clock)
                live = []
                for t in dict.fromkeys(terms):
                    if t in self.quarantined:
                        qst.mark_degraded(f"quarantined-term:{t}")
                        tp = self.index.terms.get(t)
                        qst.quarantined_blocks += tp.n_blocks if tp else 0
                    elif self.shard_of.get(t) in self.dead_shards:
                        qst.mark_degraded(f"dead-shard:{self.shard_of[t]}")
                    else:
                        live.append(t)
                eff = mode
                if mode == "topk_maxscore" and any(t in self.bound_unsafe
                                                   for t in live):
                    eff = "topk"  # exhaustive TAAT: exact without bounds
                    qst.bound_fallbacks += 1
                    self._bump("bound_fallbacks")
            with _obs_trace("execute", mode=eff):
                attempt = 0
                while True:
                    try:
                        if self.fault_hook is not None:
                            self.fault_hook(attempt, live, eff)
                        out = self._run_query(live, eff, qst, deadline)
                        break
                    except DecodeError as e:
                        qst.errors += 1
                        self._bump("errors", error=type(e).__name__)
                        term = getattr(e, "term", None)
                        if term is not None and term in live:
                            # the segment itself is bad — quarantine it and
                            # answer the query from the remaining terms
                            self._quarantine(term, str(e))
                            live = [t for t in live if t != term]
                            qst.mark_degraded(f"quarantined-term:{term}")
                        elif attempt >= self.max_retries:
                            qst.mark_degraded("retries-exhausted")
                            out = self._run_query([], eff, qst, deadline)
                            break
                        else:
                            attempt += 1
                            qst.retries += 1
                            self._bump("retries")
                            if self.backoff_s:
                                time.sleep(self.backoff_s * attempt)
            with _obs_trace("finalize"):
                _obs_counter_inc("serve_requests_total", mode=mode,
                                 engine="search")
                if qst.degraded:
                    self._bump("degraded_responses")
                    for r in qst.degraded_reasons:
                        cat, _, where = r.partition(":")
                        _obs_counter_inc("serve_degraded_total", reason=cat,
                                         engine="search")
                        if cat == "deadline":
                            _obs_counter_inc("serve_deadline_hits_total",
                                             where=where, engine="search")
                if rspan:
                    rspan.set(mode_effective=eff, degraded=qst.degraded,
                              n_results=int(len(out[0]) if isinstance(
                                  out, tuple) else len(out)))
                if stats is not None:
                    stats.merge(qst)
            return out

    def warmup(self, queries):
        """Run each (mode, terms) query once to compile its shapes."""
        for mode, terms in queries:
            self.search(terms, mode)

    def run_workload(self, queries) -> dict:
        """Drive (mode, terms) queries sequentially; aggregate QPS/latency
        plus the skip-table decode accounting over the whole workload.
        Each query posts a heartbeat for every live logical shard, so a
        killed shard goes stale and ``check_health`` classifies it dead."""
        from repro.index import QueryStats

        st = QueryStats()
        serve_before = dict(self.serve_stats)
        lat = []
        n_results = 0
        step = 0
        t_start = time.perf_counter()
        for mode, terms in queries:
            t0 = time.perf_counter()
            out = self.search(terms, mode, stats=st)
            lat.append(time.perf_counter() - t0)
            n_results += len(out[0] if isinstance(out, tuple) else out)
            for s in range(self.n_shards):
                if s not in self.dead_shards:
                    self.heartbeat(s, step)
            step += 1
        wall = time.perf_counter() - t_start
        # blocks considered = decoded + skip-table-skipped (both per
        # decode/probe pass) + threshold-pruned (never decoded by ANY
        # pass — disjoint from decoded, the partition the accounting
        # tests prove per term)
        total_blocks = (st.blocks_decoded + st.blocks_skipped
                        + st.blocks_pruned)
        total_postings = st.ints_decoded + st.postings_pruned
        stats = {
            "n_queries": len(queries),
            "n_devices": (int(self.mesh.devices.size)
                          if self.mesh is not None else 1),
            **latency_summary(lat, wall, len(queries)),
            "n_results": int(n_results),
            "blocks_decoded": st.blocks_decoded,
            "block_skip_rate": round(st.blocks_skipped / total_blocks, 3)
                               if total_blocks else 0.0,
            "pruned_block_rate": round(st.blocks_pruned / total_blocks, 3)
                                 if total_blocks else 0.0,
            "pruned_impact_rate": round(st.postings_pruned / total_postings,
                                        3) if total_postings else 0.0,
            "probes_pruned": st.probes_pruned,
            "rows_gathered": st.rows_gathered,
            "ints_decoded": st.ints_decoded,
            "impact_ints_decoded": st.impact_ints_decoded,
            "decoded_ints_per_s": round(st.ints_decoded / wall, 1),
            "index": self.index.stats(),
            # robustness accounting over this workload (docs/robustness.md)
            "errors": st.errors,
            "retries": st.retries,
            "degraded_responses": (self.serve_stats["degraded_responses"]
                                   - serve_before["degraded_responses"]),
            "quarantined_terms": self.serve_stats["quarantined_terms"],
            "quarantined_blocks": self.serve_stats["quarantined_blocks"],
            "bound_fallbacks": st.bound_fallbacks,
            "dead_shards": sorted(self.dead_shards),
        }
        self._stats.append(stats)
        return stats


def search_queries(rng, index, n_queries: int, *,
                   terms_per_query=(1, 2, 3, 5),
                   modes=("and", "or", "topk", "topk_driver",
                          "topk_maxscore")) -> list:
    """Synthetic query mix over an index's terms: (mode, terms) pairs."""
    term_ids = sorted(index.terms)
    out = []
    for i in range(n_queries):
        k = int(rng.choice(terms_per_query))
        terms = [int(t) for t in
                 rng.choice(term_ids, size=min(k, len(term_ids)),
                            replace=False)]
        out.append((modes[i % len(modes)], terms))
    return out


def stage_latency_summary(tracer, stages=("decode", "gallop", "merge",
                                          "score", "topk", "topk-select",
                                          "seed", "request", "admission",
                                          "execute")) -> dict:
    """Per-stage latency block from a tracer's finished spans: for each
    stage name with ≥1 span, count + p50/p99/mean milliseconds. This is the
    ``observability`` benchmarks.json section and the report headline."""
    from repro.obs.stats import percentile

    out = {}
    for name in stages:
        ds = [d * 1e3 for d in tracer.durations(name)]
        if ds:
            out[name] = {"count": len(ds),
                         "p50_ms": round(percentile(ds, 50), 3),
                         "p99_ms": round(percentile(ds, 99), 3),
                         "mean_ms": round(sum(ds) / len(ds), 3)}
    return out


def write_metrics_out(tele, out_dir: str) -> dict:
    """Export one telemetry capture: Prometheus exposition
    (``metrics.prom``), the JSONL span log (``trace.jsonl``), and the
    Chrome/Perfetto trace (``trace-chrome.json``). Returns the paths."""
    os.makedirs(out_dir, exist_ok=True)
    paths = {"prometheus": os.path.join(out_dir, "metrics.prom"),
             "jsonl": os.path.join(out_dir, "trace.jsonl"),
             "chrome": os.path.join(out_dir, "trace-chrome.json")}
    with open(paths["prometheus"], "w") as f:
        f.write(tele.registry.to_prometheus())
    tele.tracer.write_jsonl(paths["jsonl"])
    tele.tracer.write_chrome_trace(paths["chrome"])
    return paths


def serve_search(*, queries: int, group_k: int = 10, n_lists: int = 16,
                 top_k: int = 10, record: bool = True, seed: int = 0,
                 metrics_out: str | None = None) -> dict:
    """Build a synthetic posting-list index and drive a query workload.

    ``metrics_out=DIR`` installs a telemetry capture around the measured
    workload and writes the three exports there (see
    :func:`write_metrics_out`); the per-stage latency breakdown is merged
    into benchmarks.json as the ``observability`` section.
    """
    import numpy as np

    import jax

    from repro.data.synthetic import posting_list_group, posting_tfs
    from repro.index import build_index

    rng = np.random.default_rng(seed)
    universe = 1 << 22
    lists = dict(enumerate(
        posting_list_group(rng, group_k, n_lists, universe=universe)))
    tfs = {t: posting_tfs(rng, len(v)) for t, v in lists.items()}
    index = build_index(lists, tfs=tfs, n_docs=universe)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",)) if n_dev > 1 else None
    print(f"index: {index.n_terms} terms, {index.n_postings} postings, "
          f"{index.bits_per_int:.2f} bits/int over {n_dev} device(s)")

    engine = SearchEngine(index, mesh=mesh, top_k=top_k)
    qs = search_queries(rng, index, queries)
    engine.warmup(qs)  # compile every query's shapes; timing is steady-state
    tele = None
    if metrics_out:
        from repro import obs

        tele = obs.Telemetry()
        obs.install(tele)
    try:
        stats = engine.run_workload(qs)
    finally:
        if tele is not None:
            from repro import obs

            obs.uninstall()
    print(f"served {stats['n_queries']} queries on {stats['n_devices']} "
          f"device(s): {stats['qps']} QPS, p50 {stats['p50_ms']} ms, "
          f"p99 {stats['p99_ms']} ms, block skip rate "
          f"{stats['block_skip_rate']}, pruned block rate "
          f"{stats['pruned_block_rate']}")
    if tele is not None:
        paths = write_metrics_out(tele, metrics_out)
        obs_stats = {
            "n_queries": len(qs),
            "n_traces": len(tele.tracer.trees()),
            "stages": stage_latency_summary(tele.tracer),
        }
        print(f"telemetry capture -> {metrics_out} "
              f"({obs_stats['n_traces']} span trees)")
        if record:
            record_benchmark("observability", obs_stats)
        stats = dict(stats, observability=obs_stats, metrics_paths=paths)
    if record:
        path = record_benchmark("search_engine",
                                {k: v for k, v in stats.items()
                                 if k not in ("observability",
                                              "metrics_paths")})
        print(f"recorded -> {path}")
    return stats


def serve_search_degraded(*, queries: int = 32, group_k: int = 8,
                          n_lists: int = 16, n_shards: int = 8,
                          top_k: int = 10, record: bool = True,
                          seed: int = 0) -> dict:
    """CI degraded-serving smoke (docs/robustness.md).

    Builds a checksummed index served over ``n_shards`` logical shards,
    runs a healthy workload, then silences one shard's heartbeats until the
    straggler detector classifies it dead — queries touching its terms must
    come back as *flagged partial results* (smaller, ``degraded``, never an
    exception or a hang). ``heal()`` re-partitions ownership over the
    survivors and the same workload must return bit-identical to the
    healthy baseline. Raises ``AssertionError`` on any violation.
    """
    import numpy as np

    import jax

    from repro.data.synthetic import posting_list_group, posting_tfs
    from repro.index import QueryStats, build_index

    rng = np.random.default_rng(seed)
    universe = 1 << 20
    lists = dict(enumerate(
        posting_list_group(rng, group_k, n_lists, universe=universe)))
    tfs = {t: posting_tfs(rng, len(v)) for t, v in lists.items()}
    index = build_index(lists, tfs=tfs, n_docs=universe, checksum=True)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",)) if n_dev > 1 else None

    sim = {"t": 0.0}  # injectable clock: the smoke is deterministic

    def clock():
        sim["t"] += 1e-3  # every observation ticks, like a real clock
        return sim["t"]

    engine = SearchEngine(index, mesh=mesh, top_k=top_k, validate=True,
                          n_shards=n_shards, clock=clock)
    print(f"degraded smoke: {index.n_terms} terms over {n_shards} logical "
          f"shards, {n_dev} device(s), validate=True "
          f"(quarantined={engine.serve_stats['quarantined_terms']})")
    assert not engine.quarantined and not engine.bound_unsafe

    victim = 3
    lo, hi = engine.shards[victim]
    victim_terms = engine.term_order[lo:hi]
    qs = search_queries(rng, index, queries)
    qs.append(("or", [victim_terms[0]]))  # at least one query is hit
    engine.warmup(qs)

    clean = [engine.search(terms, mode) for mode, terms in qs]
    healthy = engine.run_workload(qs)  # every query beats all 8 shards
    assert healthy["degraded_responses"] == 0, healthy

    # the victim goes silent while the survivors keep beating: its
    # staleness blows past dead_factor × median step time and
    # check_health (not a manual kill) takes it out of rotation
    for i in range(5):
        sim["t"] += 1.0
        for s in range(n_shards):
            if s != victim:
                engine.heartbeat(s, 1000 + i)
    report = engine.check_health()
    assert report.get(f"shard{victim}") == "dead", report
    assert engine.dead_shards == {victim}

    degraded = 0
    for (mode, terms), ref in zip(qs, clean):
        st = QueryStats()
        out = engine.search(terms, mode, stats=st)
        touched = any(t in victim_terms for t in terms)
        assert st.degraded == touched, (mode, terms)
        if touched:
            degraded += 1
            # partial: the surviving terms' exact answer, a well-formed
            # subset of the healthy result for or/topk modes
            ids = out[0] if isinstance(out, tuple) else out
            ref_ids = ref[0] if isinstance(ref, tuple) else ref
            if mode == "or":
                assert np.isin(ids, ref_ids).all()
        else:
            a = out if isinstance(out, tuple) else (out,)
            b = ref if isinstance(ref, tuple) else (ref,)
            assert all(np.array_equal(x, y) for x, y in zip(a, b))
    assert degraded > 0
    print(f"killed shard {victim}: {degraded}/{len(qs)} responses flagged "
          "degraded, the rest bit-identical to healthy")

    plan = engine.heal()
    assert engine.dead_shards == set() and len(plan) == engine.n_shards
    for (mode, terms), ref in zip(qs, clean):
        st = QueryStats()
        out = engine.search(terms, mode, stats=st)
        assert not st.degraded
        a = out if isinstance(out, tuple) else (out,)
        b = ref if isinstance(ref, tuple) else (ref,)
        assert all(np.array_equal(x, y) for x, y in zip(a, b))
    stats = {
        "n_queries": len(qs),
        "n_shards": n_shards,
        "n_devices": n_dev,
        "degraded_responses": degraded,
        "healed_shards": engine.n_shards,
        **{k: v for k, v in engine.serve_stats.items()},
    }
    print(f"healed onto {engine.n_shards} shards: all {len(qs)} responses "
          "bit-identical to healthy — degraded-serving smoke OK")
    if record:
        path = record_benchmark("search_degraded_smoke", stats)
        print(f"recorded -> {path}")
    return stats


class LiveSearchEngine:
    """Serving facade over a mutable :class:`repro.index.ingest.LiveIndex`.

    The static ``SearchEngine`` above serves one immutable index; this one
    serves the live logical state (main segment − tombstones ∪ delta) and
    surfaces the ingestion layer's degraded states the same way the rest
    of the serving stack does (docs/ingestion.md):

    * ``replaying`` — the index is still replaying its WAL after a
      restart; answers are correct for the replayed prefix and flagged
      degraded via ``QueryStats``.
    * ``merge_in_progress`` — a background merge is draining the delta;
      queries keep full fidelity (bit-identical to quiescent — the fuzz
      suite proves it), the flag is reported in workload stats for
      capacity planning.

    Mutations (``add``/``delete``) proxy to the live index and are durable
    (WAL-appended + fsynced) before they return.
    """

    def __init__(self, live, *, top_k: int = 10):
        self.live = live
        self.top_k = top_k
        self._stats: list[dict] = []

    def add(self, doc, terms):
        self.live.add(doc, terms)

    def delete(self, doc):
        self.live.delete(doc)

    def search(self, terms, mode: str = "and", *, stats=None):
        with _obs_trace("request", mode=mode, terms=len(terms),
                        engine="live") as rspan:
            _obs_counter_inc("serve_requests_total", mode=mode,
                             engine="live")
            if mode == "topk":
                out = self.live.search(terms, mode="topk", k=self.top_k,
                                       stats=stats)
            else:
                out = self.live.search(terms, mode=mode, stats=stats)
            if rspan and stats is not None:
                rspan.set(degraded=stats.degraded,
                          state=self.live.state)
            return out

    def run_workload(self, queries) -> dict:
        """Drive (mode, terms) queries; aggregate QPS/latency plus the
        live-index accounting (delta-sourced hits, tombstone suppressions,
        merge/replay states)."""
        from repro.index import QueryStats

        st = QueryStats()
        lat = []
        n_results = 0
        degraded = 0
        merging = 0
        t_start = time.perf_counter()
        for mode, terms in queries:
            q = QueryStats()
            t0 = time.perf_counter()
            out = self.search(terms, mode, stats=q)
            lat.append(time.perf_counter() - t0)
            n_results += len(out[0] if isinstance(out, tuple) else out)
            degraded += int(q.degraded)
            merging += int(self.live.state == "merge_in_progress")
            st.merge(q)
        wall = time.perf_counter() - t_start
        stats = {
            "n_queries": len(queries),
            **latency_summary(lat, wall, len(queries)),
            "n_results": int(n_results),
            "epoch": self.live.epoch,
            "state": self.live.state,
            "merge_in_progress_queries": merging,
            "n_delta_docs": self.live.n_delta_docs,
            "pending_ops": self.live.n_pending,
            "doc_count": self.live.doc_count(),
            "blocks_decoded": st.blocks_decoded,
            "ints_decoded": st.ints_decoded,
            "delta_postings": st.delta_postings,
            "delta_hits": st.delta_hits,
            "tombstones_applied": st.tombstones_applied,
            "degraded_responses": degraded,
        }
        self._stats.append(stats)
        return stats


def _ingest_ops(rng, *, n_ops: int, universe: int, n_terms: int):
    """A seeded add/delete op stream plus the resulting logical state."""
    state: dict[int, dict[int, int]] = {}
    ops = []
    for _ in range(n_ops):
        if state and rng.random() < 0.25:
            doc = int(rng.choice(sorted(state)))
            ops.append(("del", doc, None))
            del state[doc]
        else:
            doc = int(rng.integers(universe))
            if doc in state:
                continue
            k = int(rng.integers(1, 5))
            terms = {int(t): int(rng.integers(1, 5))
                     for t in rng.choice(n_terms, size=k, replace=False)}
            ops.append(("add", doc, terms))
            state[doc] = terms
    return ops, state


def _rebuild_oracle(state: dict, *, universe: int, block_size: int = 128):
    """Rebuilt-from-scratch index over a logical doc→terms state — the
    definition of correct the live index is compared against."""
    import numpy as np

    from repro.index import build_index

    lists: dict[int, list] = {}
    tfs: dict[int, list] = {}
    for doc in sorted(state):
        for t, tf in state[doc].items():
            lists.setdefault(t, []).append(doc)
            tfs.setdefault(t, []).append(tf)
    return build_index(
        {t: np.asarray(v, np.int64) for t, v in lists.items()},
        tfs={t: np.asarray(v, np.int64) for t, v in tfs.items()},
        format="auto", n_docs=universe, block_size=block_size,
        checksum=True)


def serve_ingest_smoke(*, ops: int = 200, queries: int = 24,
                       top_k: int = 10, record: bool = True,
                       seed: int = 0) -> dict:
    """CI end-to-end ingestion smoke (docs/ingestion.md).

    Ingest a seeded add/delete stream into a WAL-backed ``LiveIndex``,
    **crash** the background merge at a seeded-random named crash point,
    recover by reopening the directory, and assert query parity —
    AND/OR/top-k bit-identical to an index rebuilt from scratch from the
    acknowledged logical state — before and after the crash, during the
    (retried) merge at every crash point, and after it commits. Raises
    ``AssertionError`` on any divergence.
    """
    import shutil
    import tempfile

    import numpy as np

    from repro.index import CRASH_POINTS, CrashPoint, LiveIndex, QueryStats
    from repro.index import query as iq

    rng = np.random.default_rng(seed)
    universe = 50_000
    n_terms = 12
    workdir = tempfile.mkdtemp(prefix="ingest_smoke_")
    try:
        live = LiveIndex(workdir, n_docs=universe, fsync=False)
        stream, state = _ingest_ops(rng, n_ops=ops, universe=universe,
                                    n_terms=n_terms)
        for kind, doc, terms in stream:
            (live.add(doc, terms) if kind == "add" else live.delete(doc))

        qs = []
        for _ in range(queries):
            k = int(rng.integers(1, 4))
            terms = [int(t) for t in rng.choice(n_terms, size=k,
                                                replace=False)]
            qs.append((("and", "or", "topk")[int(rng.integers(3))], terms))

        def assert_parity(ix, tag):
            oracle = _rebuild_oracle(state, universe=universe)
            for mode, terms in qs:
                if mode == "and":
                    a, b = ix.search(terms, mode="and"), \
                        iq.conjunctive(oracle, terms)
                elif mode == "or":
                    a, b = ix.search(terms, mode="or"), \
                        iq.disjunctive(oracle, terms)
                else:
                    a = ix.search(terms, mode="topk", k=top_k)
                    b = iq.topk(oracle, terms, top_k, mode="or")
                aa = a if isinstance(a, tuple) else (a,)
                bb = b if isinstance(b, tuple) else (b,)
                assert all(np.array_equal(x, y) for x, y in zip(aa, bb)), \
                    (tag, mode, terms)

        assert_parity(live, "pre-crash")
        crash_at = str(rng.choice(CRASH_POINTS))
        try:
            live.merge(crash_at=crash_at)
            raise AssertionError("injected crash did not fire")
        except CrashPoint:
            pass
        live.close()
        print(f"ingested {len(stream)} ops ({live.counters['acked_ops']} "
              f"acked), crashed merge at {crash_at!r}")

        live = LiveIndex(workdir, fsync=False)  # recovery IS the reopen
        assert_parity(live, f"recovered({crash_at})")
        # retry the merge; queries at every named point stay bit-identical
        live.merge(step_hook=lambda name: assert_parity(
            live, f"mid-merge({name})"))
        assert_parity(live, "post-merge")

        engine = LiveSearchEngine(live, top_k=top_k)
        wl = engine.run_workload(qs)
        # a couple of live writes + a degraded replay check
        doc = int(rng.integers(universe))
        while doc in state:
            doc = int(rng.integers(universe))
        engine.add(doc, {0: 1})
        state[doc] = {0: 1}
        assert_parity(live, "post-workload-write")
        # a plain restart replays the unmerged write and serves it; a
        # query issued *during* replay is flagged degraded("replaying")
        live.close()
        replay_flags = []

        def replay_probe(ix, i, op):
            q = QueryStats()
            ix.search([0], mode="or", stats=q)
            replay_flags.append((q.degraded, list(q.degraded_reasons)))

        live = LiveIndex(workdir, fsync=False, replay_hook=replay_probe)
        assert replay_flags and all(
            d and r == ["replaying"] for d, r in replay_flags), replay_flags
        assert_parity(live, "post-restart")
        stats = {
            "n_ops": len(stream),
            "n_queries": len(qs),
            "crash_point": crash_at,
            "recovered_replayed_ops": live.counters["replayed_ops"],
            "rolled_forward": live.counters["rolled_forward"],
            **{k: wl[k] for k in ("qps", "p50_ms", "p99_ms", "delta_hits",
                                  "tombstones_applied", "doc_count",
                                  "epoch") if k in wl},
        }
        live.close()
        print(f"recovery parity OK at {crash_at!r} + all "
              f"{len(CRASH_POINTS)} mid-merge points — ingest smoke OK")
        if record:
            path = record_benchmark("ingest_smoke", stats)
            print(f"recorded -> {path}")
        return stats
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


def _repo_benchmarks_path() -> str:
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))  # <repo>/src
    root = os.path.dirname(src) if os.path.basename(src) == "src" else "."
    return os.path.join(root, "experiments", "benchmarks.json")


def record_benchmark(section: str, payload, path: str | None = None):
    """Merge one section into the tracked benchmarks JSON (run.py's format)."""
    path = path or _repo_benchmarks_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    try:
        with open(path) as f:
            merged = json.load(f)
    except (OSError, ValueError):
        merged = {}
    merged[section] = payload
    merged["updated_at"] = time.strftime("%Y-%m-%d %H:%M:%S")
    with open(path, "w") as f:
        json.dump(merged, f, indent=1)
    return path


def serve_engine(cfg, *, requests: int, candidates: int, top_k: int = 10,
                 record: bool = True, seed: int = 0) -> dict:
    """Build the sharded compressed engine and drive a synthetic workload."""
    import numpy as np

    import jax

    from repro.core import CompressedIntArray
    from repro.models import recsys

    rng = np.random.default_rng(seed)
    params = recsys.init_params(jax.random.PRNGKey(0), cfg)

    n_cand = min(candidates, cfg.n_items - 1)
    cands = np.sort(rng.choice(np.arange(1, cfg.n_items), n_cand,
                               replace=False)).astype(np.uint64)
    corpus = CompressedIntArray.encode(cands, differential=True)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",)) if n_dev > 1 else None
    print(f"corpus: {corpus.n} candidate ids, {corpus.bits_per_int:.2f} "
          f"bits/int ({corpus.compression_ratio:.2f}x vs uint32), "
          f"{corpus.n_blocks} blocks over {n_dev} device(s)")

    engine = ServingEngine(params, cfg, corpus, mesh=mesh, top_k=top_k)
    engine.warmup()

    reqs = [(int(rng.integers(1, max(cfg.n_users, 2))),
             rng.integers(1, cfg.n_items, cfg.seq_len).astype(np.int32))
            for _ in range(requests)]
    stats = engine.run_workload(reqs)
    print(f"served {stats['n_requests']} requests on {stats['n_devices']} "
          f"device(s): {stats['qps']} QPS, "
          f"p50 {stats['p50_ms']} ms, p99 {stats['p99_ms']} ms "
          f"(top-{top_k} of {stats['corpus_n']} compressed candidates)")

    # embedding-bag endpoint smoke (microbatched ragged bags)
    bags = [np.sort(rng.choice(np.arange(1, cfg.n_items),
                               rng.integers(1, cfg.seq_len + 1),
                               replace=False)) for _ in range(5)]
    emb = engine.embed_bags(bags)
    print(f"embedding-bag endpoint: {len(bags)} bags -> {emb.shape}")

    if record:
        path = record_benchmark("serving_engine", stats)
        print(f"recorded -> {path}")
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host-platform devices (sharded engine)")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--candidates", type=int, default=1 << 16)
    ap.add_argument("--top-k", type=int, default=10)
    ap.add_argument("--no-record", action="store_true",
                    help="skip merging engine stats into benchmarks.json")
    ap.add_argument("--metrics-out", default=None, metavar="DIR",
                    help="search arch: capture telemetry over the workload "
                         "and write metrics.prom / trace.jsonl / "
                         "trace-chrome.json to DIR (docs/observability.md)")
    ap.add_argument("--degraded-smoke", action="store_true",
                    help="search arch: kill one logical shard mid-workload "
                         "and assert flagged partial results + healing")
    ap.add_argument("--ingest-smoke", action="store_true",
                    help="search arch: ingest a WAL-backed live index, "
                         "crash the merge at a random point, recover, and "
                         "assert query parity vs a rebuilt index")
    args = ap.parse_args()

    if args.devices:
        # appended LAST so it wins over any inherited duplicate (XLA takes
        # the final occurrence of a repeated flag)
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}"
        ).strip()

    # jax must initialize AFTER the device-count flag is set
    if args.arch == "search":
        if args.ingest_smoke:
            serve_ingest_smoke(ops=max(args.requests, 50),
                               top_k=args.top_k,
                               record=not args.no_record)
            return
        if args.degraded_smoke:
            serve_search_degraded(queries=args.requests, top_k=args.top_k,
                                  record=not args.no_record)
        else:
            serve_search(queries=args.requests, top_k=args.top_k,
                         record=not args.no_record,
                         metrics_out=args.metrics_out)
        return

    from repro.distributed.api import activate_mesh
    from repro.launch.mesh import make_host_mesh
    from repro.models import registry

    fam = registry.family_of(args.arch)
    cfg = registry.reduced_config(args.arch)
    if fam == "lm":
        with activate_mesh(make_host_mesh()):
            serve_lm(cfg, args.tokens, args.batch)
    elif fam == "recsys":
        if cfg.kind == "two_tower":
            serve_engine(cfg, requests=args.requests,
                         candidates=args.candidates, top_k=args.top_k,
                         record=not args.no_record)
        else:
            with activate_mesh(make_host_mesh()):
                serve_recsys(cfg, args.batch)
    else:
        raise SystemExit("gnn has no serve step (train-only shapes)")


if __name__ == "__main__":
    main()
