"""Roofline terms from the compiled dry-run artifact (DESIGN.md §7).

Hardware constants (TPU v5e-class, from the task spec):
  197 TFLOP/s bf16 per chip · 819 GB/s HBM · ~50 GB/s/link ICI.

``cost_analysis()`` of the SPMD-partitioned module reports per-device
HLO FLOPs / bytes; collective wire bytes come from the HLO parser.
MODEL_FLOPS is the analytic "useful" compute (6·N·D dense / 6·N_active·D
MoE for LM training; per-family approximations otherwise) — the
MODEL_FLOPS / HLO_FLOPs ratio exposes remat/dispatch/padding waste.
"""
from __future__ import annotations

from dataclasses import dataclass

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link (1 link assumed — conservative)


@dataclass(frozen=True)
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    model_flops_per_device: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops_per_device / max(self.flops_per_device, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Useful FLOPs over what the dominant term's hardware could do in
        the bound step time — the 'score' fraction (≈ projected MFU when
        compute-bound)."""
        return self.model_flops_per_device / (self.step_time_s * PEAK_FLOPS)

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "model_flops_per_device": self.model_flops_per_device,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "step_time_bound_s": self.step_time_s,
        }


def make_roofline(flops: float, bytes_: float, wire_bytes: float,
                  model_flops_per_device: float) -> Roofline:
    return Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_ / HBM_BW,
        collective_s=wire_bytes / ICI_BW,
        flops_per_device=flops,
        bytes_per_device=bytes_,
        wire_bytes_per_device=wire_bytes,
        model_flops_per_device=model_flops_per_device,
    )


# ----------------------------------------------------------------------------
# analytic MODEL_FLOPS per cell (global; divide by chips for per-device)
# ----------------------------------------------------------------------------
def _attn_flops(cfg, S: int, B: int) -> float:
    """Useful attention matmul FLOPs (fwd): QKᵀ + PV, causal/window-aware."""
    eff = min(cfg.window, S) if cfg.window else S / 2.0
    return 4.0 * cfg.n_layers * B * cfg.n_heads * cfg.dh * S * eff


def model_flops_global(cell) -> float:
    fam, cfg, dims, step = cell.family, cell.cfg, cell.shape.dims, cell.shape.step
    if fam == "lm":
        n_active = cfg.active_param_count()
        if step == "train":
            tokens = dims["global_batch"] * dims["seq_len"]
            f = 6.0 * n_active * tokens
            f += _attn_flops(cfg, dims["seq_len"], dims["global_batch"]) * 3  # fwd+bwd
            return f
        if step == "prefill":
            tokens = dims["global_batch"] * dims["seq_len"]
            return 2.0 * n_active * tokens + _attn_flops(cfg, dims["seq_len"],
                                                         dims["global_batch"])
        # decode: 1 token/seq + attention over the (ring-capped) cache
        from repro.models.lm import cache_size

        B = dims["global_batch"]
        sc = cache_size(cfg, dims["seq_len"])
        att = 4.0 * cfg.n_layers * B * sc * cfg.n_heads * cfg.dh
        return 2.0 * n_active * B + att
    if fam == "gnn":
        h, L = cfg.d_hidden, cfg.n_layers
        n_nodes, n_edges = dims["n_nodes"], dims["n_edges"]
        mlp = 2 * (cfg.d_feat * h + h * h) + 2 * (L - 1) * (h * h + h * h)
        msg = 2 * L * n_edges * max(cfg.d_feat, h) / max(n_nodes, 1)  # per node
        return 3.0 * n_nodes * (mlp + msg)  # fwd+bwd
    # recsys
    per_ex = cfg.dense_flops_per_example()
    if step == "train":
        return 3.0 * dims["batch"] * per_ex
    if step == "serve":
        return float(dims["batch"]) * per_ex
    # retrieval: every candidate is embedded/scored
    C = dims["n_candidates"]
    if cfg.kind == "two_tower":
        dims_i = (cfg.id_dim,) + cfg.mlp_dims
        item_fwd = 2 * sum(a * b for a, b in zip(dims_i[:-1], dims_i[1:]))
        return float(C) * (item_fwd + 2 * cfg.mlp_dims[-1])
    if cfg.kind == "bst":
        return float(C) * per_ex
    return float(C) * 2 * cfg.embed_dim  # dot-product scoring
