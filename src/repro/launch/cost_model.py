"""Analytic per-device cost model: corrected HLO FLOPs / HBM bytes / wire bytes.

Why this exists: XLA-CPU's HloCostAnalysis counts each ``while``-loop body
**once** — with layers/microbatches/attention chunks all under ``lax.scan``,
``compiled.cost_analysis()`` under-counts by the trip counts (verified in
EXPERIMENTS.md §Dry-run: raw ≈ corrected / n_layers·µ). The dry-run therefore
reports BOTH the raw numbers and this model, which enumerates every matmul /
gather / collective the lowered program executes, multiplied by its actual
trip count. Assumptions (documented per term):

  * scores/softmax of flash-attention stay in VMEM (TPU fusion) — only
    q/k/v/o tensors hit HBM;
  * weights are stored f32 and re-read per microbatch pass (fwd, remat-refwd,
    bwd = 3 reads) — matching the lowered scan structure;
  * AdamW touches 12 f32 words/param/step (p,m,v read+write) + grad r/w;
  * TP collectives fire per layer per microbatch (row-parallel psum of the
    [tokens, d] activations, bf16), DP gradient all-reduce fires once on f32
    grads — matching where GSPMD places them (verified on the HLO text).
"""
from __future__ import annotations

from dataclasses import dataclass

BF16 = 2
F32 = 4


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    wire_bytes: float = 0.0

    def __add__(self, o):
        return Cost(self.flops + o.flops, self.bytes + o.bytes,
                    self.wire_bytes + o.wire_bytes)

    def scale(self, k: float):
        return Cost(self.flops * k, self.bytes * k, self.wire_bytes * k)


# ----------------------------------------------------------------------------
# VByte decode cost: fused vs unfused epilogues
# ----------------------------------------------------------------------------
# The blocked decode is memory-bound (~30 branch-free int-ops/int at VPU/MXU
# rates far above the byte stream). Traffic per int:
#   * compressed read: ~2 B (typ. ~16.9 bits/int on ClueWeb-like gaps)
#   * unfused only: the decoded uint32 stream is written to HBM (4 B) and
#     immediately re-read by the consumer gather/reduce (4 B) — the round
#     trip the fused epilogues (kernels/vbyte_decode/epilogues.py) remove.
# Measured on the CPU proxy (experiments/benchmarks.json, `fused` section):
# the one-pass bag-sum runs faster than decode→take→segment-sum by roughly
# the ratio this 10 B → 2 B decode-side traffic model predicts once the
# (path-independent) table-gather traffic is added back in.
DECODE_INT_OPS = 30
DECODE_READ_B = 2.0
DECODE_RT_B = 8.0  # unfused-only: u32 HBM write + consumer re-read

# Per-codec decode-tile costs, split into a per-int term and a per-block
# term (the tile's fixed routing/one-hot setup, amortized over the block).
# Relative weights follow the decode cores: vbyte pays the boundary-recovery
# prefix sums (DECODE_INT_OPS), streamvbyte skips the continuation scan but
# still routes bytes through the control-driven gather, binpack is a static
# shift/mask with no boundary recovery at all. These price the edges of the
# index builder's shortest-path block partition (repro.index.partition) —
# a partition with many tiny blocks pays CODEC_BLOCK_OPS once per block.
CODEC_INT_OPS = {"vbyte": float(DECODE_INT_OPS), "streamvbyte": 18.0,
                 "binpack": 8.0}
CODEC_BLOCK_OPS = {"vbyte": 320.0, "streamvbyte": 256.0, "binpack": 96.0}


def decode_cost(n_ints: float, *, fused: bool) -> Cost:
    """Per-device decode cost; ``fused`` = consumer runs in the kernel epilogue."""
    b = DECODE_READ_B + (0.0 if fused else DECODE_RT_B)
    return Cost(DECODE_INT_OPS * n_ints, b * n_ints)


def codec_decode_cost(n_ints: float, *, format: str = "vbyte",
                      fused: bool = True, n_blocks: float = 0.0) -> Cost:
    """Per-codec decode cost (per-int + per-block tile terms).

    Same traffic model as :func:`decode_cost`; the FLOP side is the
    codec-specific int-op weight plus the per-block tile setup. Used by the
    index builder's block-partition DP to trade encoded bits against
    modeled decode time.
    """
    ops = (CODEC_INT_OPS.get(format, float(DECODE_INT_OPS)) * n_ints
           + CODEC_BLOCK_OPS.get(format, 0.0) * n_blocks)
    b = DECODE_READ_B + (0.0 if fused else DECODE_RT_B)
    return Cost(ops, b * n_ints)


def _ring(n: int, nbytes: float, *, reduce: bool = False) -> float:
    if n <= 1:
        return 0.0
    return (2 if reduce else 1) * (n - 1) / n * nbytes


# ----------------------------------------------------------------------------
# LM
# ----------------------------------------------------------------------------
def _lm_layer_params_local(cfg, tp: int) -> tuple[float, float]:
    """(stored param count/device, active-matmul param count/device) per layer."""
    d, dh = cfg.d_model, cfg.dh
    kv_shard = cfg.n_kv_heads % tp == 0
    attn = d * cfg.n_heads * dh * 2 / tp + d * cfg.n_kv_heads * dh * 2 / (tp if kv_shard else 1)
    if cfg.moe:
        stored = attn + 3 * cfg.moe.n_experts * d * cfg.moe.d_ff / tp + d * cfg.moe.n_experts
        active = attn + 3 * cfg.moe.top_k * cfg.moe.capacity_factor * d * cfg.moe.d_ff / tp \
            + d * cfg.moe.n_experts
    else:
        stored = active = attn + 3 * d * cfg.d_ff / tp
    return stored, active


def lm_cost(cfg, shape, *, n_chips: int, dp: int, tp: int = 16,
            assembly: dict | None = None) -> Cost:
    assembly = assembly or {}
    dims, step = shape.dims, shape.step
    B, S = dims["global_batch"], dims["seq_len"]
    d, dh, V = cfg.d_model, cfg.dh, cfg.vocab
    L = cfg.n_layers
    h_loc = max(cfg.n_heads // tp, 1)
    stored_l, active_l = _lm_layer_params_local(cfg, tp)
    P_emb_head = 2 * V * d / tp
    P_stored = L * stored_l + P_emb_head + d

    if step in ("train", "prefill"):
        mu = cfg.microbatch if step == "train" else 1
        B_mu = max(B // dp, 1) / mu  # local batch per microstep
        t = B_mu * S  # local tokens per microstep
        s_kv = min(cfg.window + cfg.q_chunk, S) if (cfg.window and cfg.banded_attention) else S
        c = Cost()

        # per layer per microstep, forward
        f_mm = 2 * t * active_l
        f_attn = 4 * B_mu * h_loc * dh * S * s_kv
        w_bytes = stored_l * F32
        a_attn = 6 * t * h_loc * dh * BF16  # q,k,v,o (+rope) traffic
        f_act = t * cfg.moe.d_ff / tp * cfg.moe.top_k * cfg.moe.capacity_factor if cfg.moe \
            else t * cfg.d_ff / tp
        a_bytes = (8 * t * d + 3 * f_act) * BF16 + a_attn
        if cfg.moe:  # dispatch/combine buffer traffic (gather + scatter, x2 passes)
            a_bytes += 4 * t * cfg.moe.top_k * cfg.moe.capacity_factor * d * BF16
        fwd = Cost(f_mm + f_attn, w_bytes + a_bytes)
        # TP collectives: 2 row-parallel psums of [t, d] bf16 per layer
        fwd.wire_bytes = 2 * _ring(tp, t * d * BF16, reduce=True)
        if cfg.moe and cfg.moe.ep_shard:
            # token->expert all-to-all (dispatch + combine)
            fwd.wire_bytes += 2 * _ring(tp, t * cfg.moe.top_k
                                        * cfg.moe.capacity_factor * d * BF16) / (tp - 1)

        if step == "prefill":
            layer = fwd
            passes = 1.0
        else:
            refwd = fwd
            if getattr(cfg, "remat_policy", "full") == "save_block_outputs":
                # block outputs checkpointed: refwd recomputes internals but
                # not the psum'd output projections -> no refwd collectives
                refwd = Cost(0.9 * (f_mm + f_attn), w_bytes + a_bytes, 0.0)
            bwd = Cost(2 * (f_mm + f_attn),
                       w_bytes + stored_l * F32 + 1.7 * a_bytes, 2 * fwd.wire_bytes)
            layer = fwd + refwd + bwd
            passes = 3.0  # head/embed has no remat: fwd+bwd(2x)

        c = c + layer.scale(L * mu)

        # lm head (+ loss) and embedding
        head = Cost(2 * t * d * V / tp * passes,
                    (2 * V * d / tp) * F32 * (2 if step == "train" else 1)
                    + t * V / tp * F32 * (2 if step == "train" else 0.0)
                    + t * d * BF16 * 3)
        if step == "prefill":  # only last-token logits
            head = Cost(2 * B_mu * d * V / tp, (V * d / tp) * F32 + B_mu * V / tp * F32)
        emb = Cost(0, t * d * BF16 * (2 if step == "train" else 1))
        c = c + (head + emb).scale(mu)

        if step == "train":
            if assembly.get("zero1"):
                # ZeRO-1: master+moments sharded dp-ways; bf16 weight
                # all-gather once/step; bf16 grad reduce-scatter per µstep
                c = c + Cost(12 * P_stored / dp, 13 * P_stored / dp * F32
                             + P_stored * BF16,
                             _ring(dp, P_stored * BF16)  # weight AG
                             + mu * _ring(dp, P_stored * BF16))  # grad RS/µstep
            else:
                # baseline: f32 grad all-reduce over DP, dense AdamW
                c = c + Cost(12 * P_stored, 13 * P_stored * F32,
                             _ring(dp, P_stored * F32, reduce=True))
        return c

    # decode: one token, KV cache resident
    from repro.models.lm import cache_size

    sc = cache_size(cfg, S)
    if B >= dp:
        B_loc, sc_loc = B / dp, sc
    else:
        B_loc, sc_loc = B, sc / dp  # SP cache sharding (long_500k)
    kv_shard = cfg.n_kv_heads % tp == 0
    kvh_loc = cfg.n_kv_heads / tp if kv_shard else cfg.n_kv_heads
    dh_loc = dh if kv_shard else dh / tp
    t = B_loc
    f_mm = 2 * t * (L * active_l + 2 * V * d / tp / 2)  # + head (no embed flops)
    f_attn = 4 * L * B_loc * h_loc * dh * sc_loc
    w_bytes = (L * stored_l + P_emb_head) * BF16  # serve weights bf16
    cache_bytes = 2 * L * B_loc * sc_loc * kvh_loc * dh_loc * BF16  # read K+V
    act = L * 12 * t * d * BF16
    wire = L * 2 * _ring(tp, t * d * BF16, reduce=True)
    if not kv_shard:  # scores psum over dh-sharded cache
        wire += L * 2 * _ring(tp, B_loc * cfg.n_heads * sc_loc * F32 / tp, reduce=True)
    return Cost(f_mm + f_attn, w_bytes + cache_bytes + act, wire)


# ----------------------------------------------------------------------------
# GNN
# ----------------------------------------------------------------------------
def gnn_cost(cfg, shape, *, n_chips: int, dp: int, tp: int = 16) -> Cost:
    dims = shape.dims
    N, E, F = dims["n_nodes"], dims["n_edges"], dims["d_feat"]
    h, L = cfg.d_hidden, cfg.n_layers
    shard = n_chips if dims.get("task", "node") == "node" else 1
    N_loc, E_loc = N / shard, E / shard
    agg_b = BF16 if getattr(cfg, "agg_dtype", "f32") == "bf16" else F32
    # per layer: gather msgs [E, din] + segment_sum + 2-layer MLP
    c = Cost()
    for i in range(L):
        din = F if i == 0 else h
        mm = 2 * N_loc * (din * h + h * h)
        # msgs gather reads from the all-gathered h replica (N·din resident
        # write + E_loc row reads) + scatter-add into the partial [N, din]
        gather = (N + E_loc) * din * agg_b + N * din * agg_b
        acts = 4 * N_loc * (din + h) * BF16
        # segment_sum across shards: every device holds a FULL [N, din]
        # partial (random dst), all-reduced; + the h all-gather itself.
        # Wire factor 1.3 calibrated to the parsed HLO op count (13 AG +
        # 13 AR across 5 layers fwd+bwd = ~1.3 AR/AG pairs per layer-pass).
        wire = _ring(n_chips if shard > 1 else 1, N * din * agg_b, reduce=True)
        wire += _ring(n_chips if shard > 1 else 1, N * din * agg_b)
        c = c + Cost(mm * 3.0, (gather + acts) * 3.0, wire * 1.3)  # fwd+bwd(2x)
    if cfg.compressed_adjacency:
        # adjacency_rebase epilogue: fused unless the plan forces two passes
        fused = getattr(cfg, "decode_plan", "auto") != "unfused"
        c = c + decode_cost(E_loc, fused=fused)
    P = cfg.param_count()
    c = c + Cost(12 * P, 13 * P * F32, _ring(n_chips, P * F32, reduce=True))
    return c


# ----------------------------------------------------------------------------
# RecSys
# ----------------------------------------------------------------------------
def recsys_cost(cfg, shape, *, n_chips: int, dp: int, tp: int = 16) -> Cost:
    dims, step = shape.dims, shape.step
    per_ex = cfg.dense_flops_per_example()
    d = cfg.embed_dim

    if step == "train":
        B_loc = dims["batch"] / dp
        ids_per_ex = cfg.seq_len + 2
        emb_dim = cfg.id_dim if cfg.kind == "two_tower" else d
        gather = B_loc * ids_per_ex * emb_dim * F32 * 3  # fwd read + bwd scatter
        # dense AdamW touches the WHOLE table: the baseline's memory wall
        P = cfg.param_count()
        P_loc = P / tp  # tables row-sharded; small rest replicated (≈)
        opt = Cost(12 * P_loc, 13 * P_loc * F32,
                   _ring(dp, P_loc * F32, reduce=True))
        act = B_loc * per_ex / (2 * 256) * BF16  # rough: flops / 256-wide reuse
        return Cost(3 * B_loc * per_ex, gather + act, 0.0) + opt

    if step == "serve":
        B_loc = dims["batch"] / dp
        C = cfg.serve_candidates
        w = cfg.param_count() - (cfg.vocab_rows * d if cfg.kind != "two_tower" else 0)
        gather = B_loc * (cfg.seq_len + 1 + C) * d * BF16
        return Cost(B_loc * per_ex + 2 * B_loc * C * d, gather + w * BF16 / n_chips, 0.0)

    # retrieval: decode 1M ids + embed + score, sharded over the whole mesh
    C_loc = dims["n_candidates"] / n_chips
    if cfg.kind == "two_tower":
        dims_i = (cfg.id_dim,) + cfg.mlp_dims
        f = 2 * sum(a * b for a, b in zip(dims_i[:-1], dims_i[1:])) + 2 * cfg.mlp_dims[-1]
        emb_read = C_loc * cfg.id_dim * BF16
    elif cfg.kind == "bst":
        f = per_ex
        emb_read = C_loc * (cfg.seq_len + 1) * d * BF16
    else:
        f = 2 * d
        emb_read = C_loc * d * BF16
    # dot-product heads run the fused dot_score epilogue (ids+scores out,
    # no decoded-id round trip and no [C, d] candidate matrix in HBM);
    # tower/ranker heads (two_tower, bst) still decode-then-score.
    # (table-row gather reads, emb_read, are path-independent: the epilogue
    # still pulls the rows from HBM — it skips writing the gathered [C, d]
    # matrix back out, which the old model never charged for anyway)
    fused = cfg.kind in ("sasrec", "bert4rec")
    decode = decode_cost(C_loc, fused=fused)
    topk_wire = _ring(n_chips, 100 * 8 * 2)  # top-k exchange, negligible
    return decode + Cost(C_loc * f, emb_read + C_loc * F32, topk_wire)


def cell_cost(cell, *, n_chips: int, dp: int, tp: int = 16) -> Cost:
    if cell.family == "lm":
        return lm_cost(cell.cfg, cell.shape, n_chips=n_chips, dp=dp, tp=tp,
                       assembly=getattr(cell, "assembly", None))
    fn = {"gnn": gnn_cost, "recsys": recsys_cost}[cell.family]
    return fn(cell.cfg, cell.shape, n_chips=n_chips, dp=dp, tp=tp)
