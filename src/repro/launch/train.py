"""Production training launcher.

Assembles: arch config (registry) + mesh + sharded train_step + compressed
data pipeline + checkpoint/restart + straggler detection. On real hardware
each host runs this under `jax.distributed.initialize`; on this container it
drives reduced configs on the 1-device mesh (the 512-device path is exercised
by dryrun.py, which shares all of this code through the registry).

    PYTHONPATH=src python -m repro.launch.train --arch gin-tu --steps 20 --reduced
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.checkpoint import CheckpointManager
from repro.distributed.api import activate_mesh
from repro.ft import StragglerDetector
from repro.launch.mesh import dp_degree, make_host_mesh, make_production_mesh
from repro.models import registry
from repro.train import OptimizerConfig, init_train_state, make_train_step


def make_batch_fn(arch: str, cfg, shape, rng):
    """Host data source feeding the sharded step (synthetic generators)."""
    fam = registry.family_of(arch)
    import jax.numpy as jnp

    if fam == "lm":
        from repro.data.pipeline import CompressedTokenPipeline
        from repro.data.synthetic import token_stream

        B, S = 4, 64
        pipe = CompressedTokenPipeline(
            token_stream(rng, B * (S + 1) * 32, cfg.vocab), B, S)
        return lambda step: pipe.get_batch(step)
    if fam == "gnn":
        from repro.data.synthetic import random_graph

        g = random_graph(rng, 256, 2048, cfg.d_feat, cfg.n_classes)
        batch = {"feats": jnp.asarray(g["feats"]),
                 "edge_src": jnp.asarray(g["edge_src"]),
                 "edge_dst": jnp.asarray(g["edge_dst"]),
                 "labels": jnp.asarray(g["labels"]),
                 "label_mask": jnp.ones(256, bool)}
        return lambda step: batch
    from repro.data.synthetic import recsys_batch

    def fn(step):
        b = recsys_batch(rng, cfg.kind, 16, cfg.seq_len, cfg.n_items,
                         n_mask=cfg.n_mask, n_negatives=cfg.n_negatives,
                         n_users=cfg.n_users)
        return {k: jnp.asarray(v) for k, v in b.items()}
    return fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config on the 1-device host mesh (CPU)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--peak-lr", type=float, default=3e-4)
    args = ap.parse_args()

    fam = registry.family_of(args.arch)
    if args.reduced:
        mesh = make_host_mesh()
        cfg = registry.reduced_config(args.arch)
        if fam == "lm":
            import dataclasses
            cfg = dataclasses.replace(cfg, microbatch=1)
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        shape = list(registry.shapes_of(args.arch))[0]
        cfg = registry.resolve_config(args.arch, shape, dp_degree=dp_degree(mesh))

    init = registry._family_init(fam)
    loss_mod = {"lm": "repro.models.lm", "gnn": "repro.models.gnn",
                "recsys": "repro.models.recsys"}[fam]
    import importlib
    loss_fn = importlib.import_module(loss_mod).loss_fn

    rng = np.random.default_rng(0)
    opt = OptimizerConfig(peak_lr=args.peak_lr, warmup_steps=5,
                          total_steps=args.steps)
    with activate_mesh(mesh):
        params = init(jax.random.PRNGKey(0), cfg)
        state = init_train_state(params, grad_compression=args.grad_compression)
        step_fn = jax.jit(make_train_step(
            lambda p, b: loss_fn(p, b, cfg), opt,
            grad_compression=args.grad_compression))

        mgr = CheckpointManager(args.ckpt_dir, keep=2) if args.ckpt_dir else None
        start = 0
        if mgr is not None:
            restored, at = mgr.restore_latest(state)
            if restored is not None:
                state = jax.tree.map(jax.numpy.asarray, restored)
                start = at + 1
                print(f"[resume] from step {at}")

        det = StragglerDetector()
        batch_fn = make_batch_fn(args.arch, cfg, None, rng)
        t0 = time.time()
        for step in range(start, args.steps):
            state, metrics = step_fn(state, batch_fn(step))
            det.heartbeat("host0", step)
            if step % 5 == 0 or step == args.steps - 1:
                print(f"step {step:>4} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f}")
            if mgr is not None and step and step % args.ckpt_every == 0:
                mgr.save(step, state, async_=True)
        stragglers = det.stragglers()
        if mgr is not None:
            mgr.wait()
            mgr.save(args.steps - 1, state)
        dt = (time.time() - t0) / max(args.steps - start, 1)
        print(f"done: {dt*1e3:.1f} ms/step, stragglers={stragglers}")


if __name__ == "__main__":
    main()
