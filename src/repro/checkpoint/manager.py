"""Checkpointing: atomic, resumable, async-capable, VByte-compressed ints.

Layout: <dir>/step_<N>/{manifest.json, leaves.npz} written through the
shared crash-consistent protocol (:func:`repro.robustness.atomic_io.
atomic_write_dir` — tmp dir + per-file fsync + rename), so partial writes
never carry the final directory name. Integer leaves are
zigzag+VByte-compressed inside the npz (the paper's codec applied to
checkpoint state — DESIGN.md §3).

Restart: ``restore_latest(example_state)`` → (state, step). Restore is
hardened against storage faults: a truncated/corrupt ``leaves.npz`` or
``manifest.json`` raises a typed
:class:`~repro.robustness.validate.CheckpointError`, and
``restore_latest`` skips backwards to the newest *intact* step instead of
crashing (docs/robustness.md §Durability).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zipfile

import numpy as np

import jax

from repro.core.vbyte.encode import encode_stream
from repro.core.vbyte.ref import decode_stream_scalar
from repro.core.vbyte.masked import decode_stream
from repro.robustness.atomic_io import atomic_write_dir
from repro.robustness.validate import CheckpointError

import jax.numpy as jnp

_INT_KINDS = ("i", "u")


def _zigzag(x: np.ndarray) -> np.ndarray:
    x64 = x.astype(np.int64)
    return ((x64 << 1) ^ (x64 >> 63)).astype(np.uint64)


def _unzigzag(z: np.ndarray) -> np.ndarray:
    z = z.astype(np.int64)  # values < 2^33 after zigzag of int32 range
    return (z >> 1) ^ -(z & 1)


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, compress_ints: bool = True):
        self.dir = directory
        self.keep = keep
        self.compress_ints = compress_ints
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state, *, async_: bool = False):
        leaves = jax.tree_util.tree_flatten_with_path(state)[0]
        host = [(_path_str(p), np.asarray(x)) for p, x in leaves]  # snapshot now
        if async_:
            self.wait()
            self._thread = threading.Thread(target=self._write, args=(step, host))
            self._thread.start()
        else:
            self._write(step, host)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_leaves):
        arrays, manifest = {}, {"step": step, "leaves": []}
        for i, (name, arr) in enumerate(host_leaves):
            key = f"leaf_{i}"
            entry = {"name": name, "key": key, "dtype": str(arr.dtype),
                     "shape": list(arr.shape), "codec": "raw"}
            if (self.compress_ints and arr.dtype.kind in _INT_KINDS
                    and arr.size > 0 and arr.dtype.itemsize <= 8):
                z = _zigzag(arr.reshape(-1))
                if z.size and int(z.max()) <= 0xFFFFFFFF:
                    stream = encode_stream(z)
                    if stream.nbytes < arr.nbytes:  # only keep wins
                        arrays[key] = stream
                        entry["codec"] = "vbyte_zigzag"
            if entry["codec"] == "raw":
                if arr.dtype == jnp.bfloat16:
                    arrays[key] = arr.view(np.uint16)
                    entry["codec"] = "bf16_as_u16"
                else:
                    arrays[key] = arr
            manifest["leaves"].append(entry)

        def fill(tmp):
            np.savez(os.path.join(tmp, "leaves.npz"), **arrays)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)

        atomic_write_dir(os.path.join(self.dir, f"step_{step:08d}"), fill)
        self._prune()

    def _prune(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def restore(self, step: int, example_state):
        """Restore one step; raises :class:`CheckpointError` if its
        manifest/leaves are unreadable or inconsistent (truncated npz,
        garbage json, missing keys, shape/codec mismatches)."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
            data = np.load(os.path.join(d, "leaves.npz"))
            leaves = []
            for entry in manifest["leaves"]:
                raw = data[entry["key"]]
                dt = np.dtype(entry["dtype"]) if entry["dtype"] != "bfloat16" else None
                shape = tuple(entry["shape"])
                if entry["codec"] == "vbyte_zigzag":
                    n = int(np.prod(shape)) if shape else 1
                    z = decode_stream_scalar(raw, n) if n < 4096 else np.asarray(
                        decode_stream(jnp.asarray(raw), n, nbytes=len(raw))[0]
                    ).astype(np.uint64)
                    arr = _unzigzag(z).astype(dt).reshape(shape)
                elif entry["codec"] == "bf16_as_u16":
                    arr = raw.view(jnp.bfloat16).reshape(shape)
                else:
                    arr = raw.astype(dt).reshape(shape)
                leaves.append(arr)
        except (OSError, ValueError, KeyError, TypeError, IndexError,
                zipfile.BadZipFile) as e:
            raise CheckpointError(
                f"checkpoint step {step} unreadable: {e}") from e
        treedef = jax.tree_util.tree_structure(example_state)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def restore_latest(self, example_state):
        """Newest *intact* checkpoint: a step whose files are truncated or
        corrupt is skipped (the fault is typed, the fallback silent-safe —
        an older consistent state beats a crash loop on a broken one)."""
        for step in reversed(self.steps()):
            try:
                return self.restore(step, example_state), step
            except CheckpointError:
                continue
        return None, -1
