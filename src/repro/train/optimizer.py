"""AdamW + global-norm clipping + warmup-cosine schedule, from scratch.

(optax is not vendored in the target environment — DESIGN.md §9.5.)
Master params f32; moments f32; update math f32 throughout.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.peak_lr * (cfg.min_lr_frac
                         + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, opt_state, cfg: OptimizerConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        step_dir = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step_dir + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [x[0] for x in new])
    new_m = jax.tree.unflatten(treedef, [x[1] for x in new])
    new_v = jax.tree.unflatten(treedef, [x[2] for x in new])
    return (
        new_params,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
