"""TrainState + train_step factory shared by all model families."""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .grad_compress import compress_grads_with_ef, init_ef_state
from .optimizer import OptimizerConfig, adamw_update, init_opt_state


def init_train_state(params, *, grad_compression: bool = False) -> dict:
    state = {"params": params, "opt": init_opt_state(params)}
    if grad_compression:
        state["ef"] = init_ef_state(params)
    return state


def make_train_step(loss_fn: Callable, opt_cfg: OptimizerConfig,
                    *, grad_compression: bool = False, donate: bool = True,
                    microbatch: int = 1, compute_cast: Callable | None = None,
                    grad_transform: Callable | None = None):
    """loss_fn(params, batch) -> (loss, aux). Returns jit-able step fn.

    ``microbatch > 1`` splits the batch leading dim and accumulates grads in
    f32 over a lax.scan (gradient accumulation) — activation memory drops
    ~linearly while keeping the same global-batch semantics.

    ZeRO-1 hooks (see distributed.sharding.zero1_extend):
      * ``compute_cast(master)`` builds the bf16 compute copy constrained to
        the compute sharding — applied ONCE per step, outside the microbatch
        scan, so GSPMD emits one weight all-gather per step;
      * ``grad_transform(g)`` casts grads bf16 + constrains them to the
        master (DP-sharded) layout — applied per microbatch so the
        accumulator lives sharded (reduce-scatter on the wire).
    """

    def _grads(params, batch):
        if microbatch <= 1:
            (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            if grad_transform:
                g = grad_transform(g)
            return (l, aux), g

        def split(x):
            b = x.shape[0]
            if b % microbatch:
                raise ValueError(f"batch dim {b} not divisible by microbatch")
            return x.reshape(microbatch, b // microbatch, *x.shape[1:])

        leaves, treedef = jax.tree.flatten(batch)
        # shared side inputs (e.g. a negatives table) are closed over, not split
        shared = [x.ndim == 1 and x.shape[0] % microbatch != 0 for x in leaves]
        xs = tuple(split(x) for x, sh in zip(leaves, shared) if not sh)

        def body(carry, xs_leaves):
            gsum, lsum, auxsum = carry
            it = iter(xs_leaves)
            full = jax.tree.unflatten(
                treedef, [x if sh else next(it) for x, sh in zip(leaves, shared)]
            )
            (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params, full)
            if grad_transform:
                g = grad_transform(g)
            gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
            auxsum = jax.tree.map(lambda a, b: a + b, auxsum, aux)
            return (gsum, lsum + l, auxsum), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if grad_transform:  # accumulator adopts the (sharded) master layout
            g0 = jax.tree.map(lambda z: z.astype(jnp.float32), grad_transform(g0))
        l0 = jnp.float32(0.0)
        aux0 = jax.tree.map(lambda s: jnp.zeros((), jnp.float32),
                            jax.eval_shape(lambda: loss_fn(params, batch)[1]))
        (gsum, lsum, auxsum), _ = jax.lax.scan(body, (g0, l0, aux0), xs)
        inv = 1.0 / microbatch
        return (lsum * inv, jax.tree.map(lambda a: a * inv, auxsum)), jax.tree.map(
            lambda g: g * inv, gsum)

    def train_step(state: dict, batch: Any) -> tuple[dict, dict]:
        compute_params = (compute_cast(state["params"]) if compute_cast
                          else state["params"])
        (loss, aux), grads = _grads(compute_params, batch)
        new_state = dict(state)
        if grad_compression:
            grads, new_ef = compress_grads_with_ef(grads, state["ef"])
            new_state["ef"] = new_ef
        params, opt, opt_metrics = adamw_update(
            state["params"], grads, state["opt"], opt_cfg
        )
        new_state["params"] = params
        new_state["opt"] = opt
        metrics = {"loss": loss, **opt_metrics,
                   **{k: jnp.asarray(v) for k, v in aux.items()}}
        return new_state, metrics

    return train_step


def jit_train_step(train_step, *, in_shardings=None, out_shardings=None):
    kw = {}
    if in_shardings is not None:
        kw["in_shardings"] = in_shardings
    if out_shardings is not None:
        kw["out_shardings"] = out_shardings
    return jax.jit(train_step, donate_argnums=(0,), **kw)
