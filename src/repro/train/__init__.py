from . import grad_compress, optimizer, train_state  # noqa: F401
from .optimizer import OptimizerConfig  # noqa: F401
from .train_state import init_train_state, make_train_step  # noqa: F401
