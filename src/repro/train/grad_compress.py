"""Fixed-rate int8 gradient compression with error feedback.

Why not VByte here: VByte output length is data-dependent, which breaks
fixed-shape SPMD collectives (DESIGN.md §3 "explicit non-application").
Instead gradients are quantized to int8 with a per-leaf scale before the
data-parallel reduction and the quantization residual is carried into the
next step (error feedback, à la 1-bit Adam lineage).

Two integration points:
  * ``quantize_tree``/``dequantize_tree`` + EF — used inside train_step
    (GSPMD emits the actual reduction; the quantization models the wire
    format and keeps convergence honest).
  * ``compressed_psum`` — an explicit shard_map collective that performs the
    int8 ring reduction manually (int32 accumulation), for manual-collective
    pipelines and tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (q int8, scale f32)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads_with_ef(grads, ef_state):
    """Quantize grads + error feedback. Returns (dequantized grads, new EF)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = quantize(gf)
        deq = dequantize(q, s)
        return deq, gf - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [x[0] for x in out]),
            jax.tree.unflatten(treedef, [x[1] for x in out]))


def init_ef_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-on-the-wire psum: quantize, reduce int32, dequantize.

    For use inside shard_map. The scale is agreed via a (cheap) f32 psum-max;
    payload moves as int8 (4x less ICI traffic than f32)."""
    q, scale = quantize(x)
    scale = jax.lax.pmax(scale, axis_name)  # shared wire scale
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return acc.astype(jnp.float32) * scale
