"""Public API: device-resident compressed integer arrays.

``CompressedIntArray`` is the framework's first-class compressed-id type
(DESIGN.md §3): posting lists, token streams, adjacency lists, user
histories and retrieval candidate lists are all stored in this form and
decoded on device by the vectorized Masked-VByte decoder (or its Pallas
kernel, see ``repro.kernels.vbyte_decode``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import numpy as np

import jax.numpy as jnp

from .vbyte import encode as venc
from .vbyte import masked as vmasked
from .vbyte import ref as vref


@dataclass(frozen=True)
class CompressedIntArray:
    """A VByte-compressed, block-decodable array of uint32."""

    enc: venc.BlockedEncoding

    # -- construction -----------------------------------------------------
    @classmethod
    def encode(
        cls,
        values: np.ndarray,
        *,
        block_size: int = 128,
        differential: bool = False,
        stride_multiple: int = 128,
    ) -> "CompressedIntArray":
        return cls(
            venc.encode_blocked(
                values,
                block_size=block_size,
                differential=differential,
                stride_multiple=stride_multiple,
            )
        )

    # -- metadata ----------------------------------------------------------
    @property
    def n(self) -> int:
        return self.enc.n

    @property
    def n_blocks(self) -> int:
        return self.enc.n_blocks

    @property
    def bits_per_int(self) -> float:
        return self.enc.bits_per_int

    @property
    def compression_ratio(self) -> float:
        """Raw uint32 bytes / tight compressed bytes (the paper's framing)."""
        return 4.0 * self.n / max(self.enc.payload_bytes, 1)

    # -- device form --------------------------------------------------------
    def device_operands(self) -> dict[str, Any]:
        """Arrays consumed by the decoders / the Pallas kernel."""
        return {
            "payload": jnp.asarray(self.enc.payload),
            "counts": jnp.asarray(self.enc.counts),
            "bases": jnp.asarray(self.enc.bases),
        }

    # -- decoding ------------------------------------------------------------
    def decode(self, *, use_kernel: bool = False) -> np.ndarray:
        """Decode to uint32[n] (host-visible)."""
        if use_kernel:
            from repro.kernels.vbyte_decode import ops as kops

            out = kops.vbyte_decode_blocked(
                **self.device_operands(),
                block_size=self.enc.block_size,
                differential=self.enc.differential,
            )
        else:
            out = vmasked.decode_blocked(
                **self.device_operands(),
                block_size=self.enc.block_size,
                differential=self.enc.differential,
            )
        flat = np.asarray(out).reshape(-1)[: self.n]
        return flat.astype(np.uint32)

    def decode_scalar_oracle(self) -> np.ndarray:
        """Algorithm-1 decode (slow; tests/benchmarks only)."""
        out = vref.decode_blocked_scalar(
            self.enc.payload,
            self.enc.counts,
            self.enc.bases,
            self.enc.block_size,
            differential=self.enc.differential,
        )
        return out.reshape(-1)[: self.n].astype(np.uint32)
