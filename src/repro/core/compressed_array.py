"""Public API: device-resident compressed integer arrays.

``CompressedIntArray`` is the framework's first-class compressed-id type
(DESIGN.md §3): posting lists, token streams, adjacency lists, user
histories and retrieval candidate lists are all stored in this form and
decoded on device by a vectorized decoder or its Pallas kernel
(``repro.kernels.vbyte_decode``).

Two on-device formats are supported, selected with ``format=``:

* ``"vbyte"`` (default) — the classic format of Plaisance, Kurz & Lemire:
  7 payload bits per byte, the high bit a continuation flag. Densest for
  small gaps (1 byte spans values < 2^7) and the paper's own format, but
  the decoder must recover integer boundaries from the continuation bits
  (``repro.core.vbyte.masked``). Blocked operands:
  ``payload [n_blocks, stride]`` + ``counts`` + ``bases``.

* ``"streamvbyte"`` — Stream VByte (Lemire, Kurz & Rupp): 2-bit length
  codes live in a separate control stream and every data byte carries a
  full 8 payload bits, so the decoder skips the continuation-bit scan
  entirely (``repro.core.vbyte.stream_masked``,
  ``repro.kernels.vbyte_decode.stream_kernel``). Costs 2 control bits per
  integer and rounds each integer to whole bytes (1 byte spans values
  < 2^8, ≤4 bytes total), so compression is within ~2 bits/int of VByte on
  typical gap distributions — and decode is faster because byte→integer
  routing comes straight from the control stream.

Rule of thumb (see docs/formats.md): pick ``"vbyte"`` when bits/int is the
binding constraint, ``"streamvbyte"`` when decode throughput is. Both
formats share the blocked SPMD layout (``block_size`` integers per block,
per-block ``counts``/``bases``) so every block decodes independently, and
both support fused differential (delta) decoding of sorted id lists.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Union

import numpy as np

import jax.numpy as jnp

from .vbyte import encode as venc
from .vbyte import ref as vref
from .vbyte import stream_vbyte as svb

FORMATS = ("vbyte", "streamvbyte")


@dataclass(frozen=True)
class CompressedIntArray:
    """A compressed, block-decodable array of uint32 (VByte or Stream VByte)."""

    enc: Union[venc.BlockedEncoding, svb.StreamVByteEncoding]

    # -- construction -----------------------------------------------------
    @classmethod
    def encode(
        cls,
        values: np.ndarray,
        *,
        format: str = "vbyte",
        block_size: int = 128,
        differential: bool = False,
        stride_multiple: int = 128,
    ) -> "CompressedIntArray":
        if format == "vbyte":
            enc = venc.encode_blocked(
                values,
                block_size=block_size,
                differential=differential,
                stride_multiple=stride_multiple,
            )
        elif format == "streamvbyte":
            enc = svb.encode_blocked(
                values,
                block_size=block_size,
                differential=differential,
                stride_multiple=stride_multiple,
            )
        else:
            raise ValueError(f"unknown format {format!r}; expected one of {FORMATS}")
        return cls(enc)

    @classmethod
    def encode_ragged(
        cls,
        lists,
        *,
        format: str = "vbyte",
        block_size: int = 128,
        differential: bool = False,
        stride_multiple: int = 128,
    ) -> "CompressedIntArray":
        """Encode ragged id bags: block b holds list b (≤ block_size ids).

        The one-bag-per-block layout feeds the fused bag-sum / dot-score
        kernel epilogues (``repro.kernels.vbyte_decode.dispatch``) — one
        kernel block reduces straight to one output row, so the decoded ids
        never leave VMEM. With ``differential=True`` each (sorted) list is
        delta-encoded independently, first gap absolute, ``bases`` all zero.
        """
        if format == "vbyte":
            enc = venc.encode_ragged_blocked(
                lists, block_size=block_size, differential=differential,
                stride_multiple=stride_multiple)
        elif format == "streamvbyte":
            enc = svb.encode_ragged_blocked(
                lists, block_size=block_size, differential=differential,
                stride_multiple=stride_multiple)
        else:
            raise ValueError(f"unknown format {format!r}; expected one of {FORMATS}")
        return cls(enc)

    # -- metadata ----------------------------------------------------------
    @property
    def format(self) -> str:
        return (
            "streamvbyte"
            if isinstance(self.enc, svb.StreamVByteEncoding)
            else "vbyte"
        )

    @property
    def ragged(self) -> bool:
        return getattr(self.enc, "ragged", False)

    @property
    def n(self) -> int:
        return self.enc.n

    @property
    def n_blocks(self) -> int:
        return self.enc.n_blocks

    @property
    def bits_per_int(self) -> float:
        return self.enc.bits_per_int

    @property
    def compression_ratio(self) -> float:
        """Raw uint32 bytes / tight compressed bytes (the paper's framing)."""
        return 4.0 * self.n / max(self.enc.payload_bytes, 1)

    # -- device form --------------------------------------------------------
    def device_operands(self) -> dict[str, Any]:
        """Arrays consumed by the decoders / the Pallas kernels."""
        if self.format == "streamvbyte":
            return {
                "control": jnp.asarray(self.enc.control),
                "data": jnp.asarray(self.enc.data),
                "counts": jnp.asarray(self.enc.counts),
                "bases": jnp.asarray(self.enc.bases),
            }
        return {
            "payload": jnp.asarray(self.enc.payload),
            "counts": jnp.asarray(self.enc.counts),
            "bases": jnp.asarray(self.enc.bases),
        }

    # -- decoding ------------------------------------------------------------
    def decode_blocked(self, *, plan="auto"):
        """Decode on device to the padded uint32[n_blocks, block_size] grid.

        ``plan`` is a dispatch plan name or ``DecodePlan``
        (``repro.kernels.vbyte_decode.dispatch``): ``"auto"`` consults the
        autotune cache, ``"kernel"``/``"jnp"`` force the Pallas / pure-jnp
        path.
        """
        from repro.kernels.vbyte_decode import dispatch

        return dispatch.decode(
            self.device_operands(),
            format=self.format,
            block_size=self.enc.block_size,
            differential=self.enc.differential,
            plan=plan,
        )

    def decode(self, *, use_kernel: bool | None = None, plan="auto") -> np.ndarray:
        """Decode to uint32[n] (host-visible).

        ``use_kernel`` is the legacy boolean (True → Pallas kernel, False →
        jnp decoder); it maps onto the dispatch plan and is kept for
        back-compat. Prefer ``plan=``.
        """
        if use_kernel is not None:
            plan = "kernel" if use_kernel else "jnp"
        grid = np.asarray(self.decode_blocked(plan=plan))
        if self.ragged:  # block b holds list b: concatenate the valid prefixes
            mask = (np.arange(self.enc.block_size)[None, :]
                    < np.asarray(self.enc.counts)[:, None])
            return grid[mask].astype(np.uint32)
        return grid.reshape(-1)[: self.n].astype(np.uint32)

    def decode_scalar_oracle(self) -> np.ndarray:
        """Byte-at-a-time reference decode (slow; tests/benchmarks only)."""
        if self.format == "streamvbyte":
            out = svb.decode_blocked_scalar(
                self.enc.control,
                self.enc.data,
                self.enc.counts,
                self.enc.bases,
                self.enc.block_size,
                differential=self.enc.differential,
            )
        else:
            out = vref.decode_blocked_scalar(
                self.enc.payload,
                self.enc.counts,
                self.enc.bases,
                self.enc.block_size,
                differential=self.enc.differential,
            )
        return out.reshape(-1)[: self.n].astype(np.uint32)
