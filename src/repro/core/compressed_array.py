"""Public API: device-resident compressed integer arrays.

``CompressedIntArray`` is the framework's first-class compressed-id type
(DESIGN.md §3): posting lists, token streams, adjacency lists, user
histories and retrieval candidate lists are all stored in this form and
decoded on device by a vectorized decoder or its Pallas kernel
(``repro.kernels.vbyte_decode``).

The array is a **registered JAX pytree**: the blocked operand arrays
(``payload`` — or ``control``/``data`` for Stream VByte — plus ``counts``
and ``bases``) are traced leaves, while ``format`` / ``block_size`` /
``differential`` / ``n`` / ``ragged`` are static aux data. That means a
``CompressedIntArray`` passes through ``jit`` / ``grad`` / ``scan`` /
``shard_map`` like any other array — call sites hand the array itself to
models and kernels instead of unpacking ``device_operands()`` dicts, and
two arrays with the same shapes share one jit trace.

Three on-device formats are supported, selected with ``format=``:

* ``"vbyte"`` (default) — the classic format of Plaisance, Kurz & Lemire:
  7 payload bits per byte, the high bit a continuation flag. Densest for
  small gaps (1 byte spans values < 2^7) and the paper's own format, but
  the decoder must recover integer boundaries from the continuation bits
  (``repro.core.vbyte.masked``). Blocked operands:
  ``payload [n_blocks, stride]`` + ``counts`` + ``bases``.

* ``"streamvbyte"`` — Stream VByte (Lemire, Kurz & Rupp): 2-bit length
  codes live in a separate control stream and every data byte carries a
  full 8 payload bits, so the decoder skips the continuation-bit scan
  entirely (``repro.core.vbyte.stream_masked``,
  ``repro.kernels.vbyte_decode.stream_kernel``). Costs 2 control bits per
  integer and rounds each integer to whole bytes (1 byte spans values
  < 2^8, ≤4 bytes total), so compression is within ~2 bits/int of VByte on
  typical gap distributions — and decode is faster because byte→integer
  routing comes straight from the control stream.

* ``"binpack"`` — binary packing (Lemire & Boytsov): every block's values
  are packed at the block's max bit width ``w``, recorded in a one-byte
  per-block width column. Integer ``j`` starts at bit ``j·w`` — affine,
  so decode needs **no boundary recovery and no length prefix sum at
  all** (``repro.core.vbyte.binpack_masked``,
  ``repro.kernels.vbyte_decode.binpack_kernel``): the fastest decode of
  the three. Compression is width-outlier-sensitive (one large gap costs
  the whole block), which the index builder's optimal block partition
  turns back into a win (``repro.index.partition``). Blocked operands:
  ``widths [n_blocks, 1]`` + ``data [n_blocks, stride]`` + ``counts`` +
  ``bases``.

Rule of thumb (see docs/formats.md): pick ``"vbyte"`` when bits/int is the
binding constraint, ``"streamvbyte"`` for fast decode on mixed-width gaps,
``"binpack"`` for the fastest decode on width-homogeneous blocks. All
formats share the blocked SPMD layout (``block_size`` integers per block,
per-block ``counts``/``bases``) so every block decodes independently, and
all support fused differential (delta) decoding of sorted id lists.

Because blocks are independent, the block dimension is also the natural
**sharding** dimension: ``arr.shard(mesh, axis="data")`` places the block
dim of every leaf across a mesh axis with ``NamedSharding``, and the
dispatch layer (``repro.kernels.vbyte_decode.dispatch``) decodes each
shard's blocks where they live via ``shard_map`` — no cross-device decode
traffic (see docs/serving.md).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field, replace
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from .vbyte import binpack as bpk
from .vbyte import encode as venc
from .vbyte import ref as vref
from .vbyte import stream_vbyte as svb

FORMATS = ("vbyte", "streamvbyte", "binpack")

# pytree leaves per format, in flatten order (the block dim leads every leaf)
FORMAT_LEAVES = {
    "vbyte": ("payload", "counts", "bases"),
    "streamvbyte": ("control", "data", "counts", "bases"),
    "binpack": ("widths", "data", "counts", "bases"),
}

def block_checksums(grid: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Per-block position-weighted checksum of a decoded value grid.

    ``cs[b] = (Σ_{j < counts[b]} grid[b, j] · (2j+1)) mod 2^32``, returned
    as ``int32 [n_blocks]`` (bit pattern of the uint32 sum). Odd positional
    weights make the sum order-sensitive. Computed in uint64 — products are
    ≤ 2^32·(2·block_size) and blocks are short, so the sum never overflows
    before the final mask. The device twin is the fused ``checksum``
    epilogue (``kernels/vbyte_decode/epilogues.py``), whose int32
    two's-complement arithmetic wraps bit-identically.
    """
    g = np.asarray(grid, dtype=np.uint64) & np.uint64(0xFFFFFFFF)
    B = g.shape[1]
    w = (2 * np.arange(B, dtype=np.uint64) + 1)[None, :]
    valid = np.arange(B)[None, :] < np.asarray(counts).reshape(-1, 1)
    cs = (g * w * valid).sum(axis=1, dtype=np.uint64)
    return (cs & np.uint64(0xFFFFFFFF)).astype(np.uint32).view(np.int32)


_USE_KERNEL_MSG = (
    "use_kernel= is deprecated; pass plan= instead "
    "(use_kernel=True -> plan='kernel', use_kernel=False -> plan='jnp'; "
    "see repro.kernels.vbyte_decode.dispatch)")


def warn_use_kernel(use_kernel: bool) -> str:
    """Map the legacy ``use_kernel`` boolean to a plan name, with a warning."""
    warnings.warn(_USE_KERNEL_MSG, DeprecationWarning, stacklevel=3)
    return "kernel" if use_kernel else "jnp"


@dataclass(frozen=True)
class CompressedIntArray:
    """A compressed, block-decodable array of uint32 (VByte or Stream VByte).

    Leaves (traced; any of numpy / jax / ShapeDtypeStruct / PartitionSpec —
    the class is a pytree container, not an array wrapper):

    * ``payload`` — ``uint8 [n_blocks, stride]`` (``format="vbyte"`` only)
    * ``control`` — ``uint8 [n_blocks, block_size // 4]`` (streamvbyte)
    * ``widths``  — ``uint8 [n_blocks, 1]`` per-block bit width (binpack)
    * ``data``    — ``uint8 [n_blocks, data_stride]`` (streamvbyte/binpack)
    * ``counts``  — ``int32 [n_blocks]`` valid integers per block
    * ``bases``   — ``uint32 [n_blocks]`` differential carry-in

    Static aux data (part of the jit trace key, never traced): ``format``,
    ``block_size``, ``differential``, ``n``, ``ragged``.
    """

    payload: Any = None  # vbyte
    control: Any = None  # streamvbyte
    widths: Any = None  # binpack
    data: Any = None  # streamvbyte / binpack
    counts: Any = None
    bases: Any = None
    format: str = "vbyte"
    block_size: int = 128
    differential: bool = False
    n: int = 0
    ragged: bool = False  # one independent list (bag) per block
    # original host-side encoding (BlockedEncoding / StreamVByteEncoding);
    # carries exact-size accounting (payload_bytes). NOT a pytree child —
    # arrays reconstructed inside jit/shard_map have host_enc=None.
    host_enc: Any = field(default=None, compare=False, repr=False)
    # optional per-block checksum column (int32 [n_blocks], see
    # block_checksums) written by encode(..., checksum=True) and verified by
    # repro.robustness.validate.decode_checked in the same decode tile pass.
    # Off-tree like host_enc: host metadata, dropped on pytree unflatten.
    checksums: Any = field(default=None, compare=False, repr=False)

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten_with_keys(self):
        names = FORMAT_LEAVES[self.format]
        children = tuple(
            (jax.tree_util.GetAttrKey(nm), getattr(self, nm)) for nm in names)
        aux = (self.format, self.block_size, self.differential, self.n,
               self.ragged)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        fmt, block_size, differential, n, ragged = aux
        kw = dict(zip(FORMAT_LEAVES[fmt], children))
        return cls(format=fmt, block_size=block_size,
                   differential=differential, n=n, ragged=ragged, **kw)

    # -- construction -----------------------------------------------------
    @classmethod
    def _from_encoding(cls, enc, format: str) -> "CompressedIntArray":
        names = FORMAT_LEAVES[format]
        kw = {nm: getattr(enc, nm) for nm in names}
        return cls(format=format, block_size=enc.block_size,
                   differential=enc.differential, n=enc.n,
                   ragged=getattr(enc, "ragged", False), host_enc=enc, **kw)

    @classmethod
    def from_operands(
        cls,
        operands: dict[str, Any],
        *,
        format: str = "vbyte",
        block_size: int = 128,
        differential: bool = False,
        n: int | None = None,
        ragged: bool = False,
    ) -> "CompressedIntArray":
        """Wrap existing blocked operand arrays (no re-encoding).

        ``operands`` holds the format leaves (``payload`` or
        ``control``/``data``, plus ``counts``/``bases``). ``n`` defaults to
        ``sum(counts)`` when the counts are concrete. The leaves may also be
        ``ShapeDtypeStruct``s or ``PartitionSpec``s — useful for building
        abstract batch templates and sharding-spec trees with the same
        treedef as a real array.
        """
        if format not in FORMAT_LEAVES:
            raise ValueError(
                f"unknown format {format!r}; expected one of {FORMATS}")
        names = FORMAT_LEAVES[format]
        missing = [k for k in names if k not in operands]
        if missing:
            raise ValueError(f"format {format!r} operands missing {missing}")
        if n is None:
            try:
                n = int(np.asarray(operands["counts"]).sum())
            except TypeError:
                raise ValueError(
                    "n= is required when counts are abstract") from None
        return cls(format=format, block_size=block_size,
                   differential=differential, n=n, ragged=ragged,
                   **{nm: operands[nm] for nm in names})

    @classmethod
    def encode(
        cls,
        values: np.ndarray | None = None,
        *,
        format: str = "vbyte",
        block_size: int = 128,
        differential: bool = False,
        stride_multiple: int = 128,
        wrap: bool = False,
        checksum: bool = False,
        meta=None,
    ) -> "CompressedIntArray":
        """Encode ``values`` (or a pre-computed ``BlockedMeta`` via
        ``meta=``, sharing one metadata pass with the skip-table path)."""
        encoders = {"vbyte": venc.encode_blocked,
                    "streamvbyte": svb.encode_blocked,
                    "binpack": bpk.encode_blocked}
        if format not in encoders:
            raise ValueError(f"unknown format {format!r}; expected one of {FORMATS}")
        if meta is None:
            meta = venc.prepare_blocked(
                values, block_size=block_size, differential=differential,
                wrap=wrap)
        enc = encoders[format](stride_multiple=stride_multiple, meta=meta)
        arr = cls._from_encoding(enc, format)
        if checksum:
            # checksum the *decoded* (absolute) values: pad the input to the
            # block grid — identical for all formats and both differential
            # flavors, since decode always recovers the absolute values
            v = meta.values
            grid = np.zeros((enc.counts.shape[0], meta.block_size), np.uint64)
            grid.reshape(-1)[: v.size] = v
            arr = replace(arr, checksums=block_checksums(grid, enc.counts))
        return arr

    @classmethod
    def encode_ragged(
        cls,
        lists,
        *,
        format: str = "vbyte",
        block_size: int = 128,
        differential: bool = False,
        stride_multiple: int = 128,
        wrap: bool = False,
        checksum: bool = False,
    ) -> "CompressedIntArray":
        """Encode ragged id bags: block b holds list b (≤ block_size ids).

        The one-bag-per-block layout feeds the fused bag-sum / dot-score
        kernel epilogues (``repro.kernels.vbyte_decode.dispatch``) — one
        kernel block reduces straight to one output row, so the decoded ids
        never leave VMEM. With ``differential=True`` each (sorted) list is
        delta-encoded independently, first gap absolute, ``bases`` all zero.
        """
        encoders = {"vbyte": venc.encode_ragged_blocked,
                    "streamvbyte": svb.encode_ragged_blocked,
                    "binpack": bpk.encode_ragged_blocked}
        if format not in encoders:
            raise ValueError(f"unknown format {format!r}; expected one of {FORMATS}")
        enc = encoders[format](
            lists, block_size=block_size, differential=differential,
            stride_multiple=stride_multiple, wrap=wrap)
        arr = cls._from_encoding(enc, format)
        if checksum:
            vpad, counts = venc.ragged_block_values(
                lists, block_size=block_size, differential=False, wrap=wrap)
            arr = replace(arr, checksums=block_checksums(vpad, counts))
        return arr

    # -- metadata ----------------------------------------------------------
    @property
    def enc(self):
        """The host-side encoding object (exact-size accounting). ``None``
        for arrays reconstructed from traced/abstract leaves."""
        return self.host_enc

    def _require_host_enc(self, what: str):
        if self.host_enc is None:
            raise RuntimeError(
                f"{what} needs the host-side encoding, which this "
                "CompressedIntArray no longer carries (it was rebuilt from "
                "pytree leaves, e.g. inside jit). Compute it on the array "
                "returned by encode()/encode_ragged().")
        return self.host_enc

    @property
    def n_blocks(self) -> int:
        return self.counts.shape[0]

    @property
    def bits_per_int(self) -> float:
        return self._require_host_enc("bits_per_int").bits_per_int

    @property
    def compression_ratio(self) -> float:
        """Raw uint32 bytes / tight compressed bytes (the paper's framing)."""
        enc = self._require_host_enc("compression_ratio")
        return 4.0 * self.n / max(enc.payload_bytes, 1)

    @property
    def sharding(self):
        """The NamedSharding of the block dimension (None when unsharded)."""
        s = getattr(self.counts, "sharding", None)
        return s

    # -- device form --------------------------------------------------------
    def device_operands(self) -> dict[str, Any]:
        """Arrays consumed by the decoders / the Pallas kernels."""
        return {nm: jnp.asarray(getattr(self, nm))
                for nm in FORMAT_LEAVES[self.format]}

    def shard(self, mesh, axis="data") -> "CompressedIntArray":
        """Place the block dimension of every leaf across ``mesh[axis]``.

        Returns a new array whose leaves carry ``NamedSharding``s (block dim
        over ``axis``, trailing dims replicated). ``n_blocks`` is padded with
        count=0 blocks to a multiple of the axis size so ``shard_map``
        decode divides evenly — padding blocks decode to nothing. The
        dispatch layer auto-selects the block-parallel ``shard_map`` decode
        path when it sees sharded operands (``repro.kernels.vbyte_decode.
        dispatch``); see docs/serving.md.
        """
        from repro.distributed.sharding import shard_compressed

        return shard_compressed(self, mesh, axis=axis)

    def replace_leaves(self, **leaves) -> "CompressedIntArray":
        """New array with some leaves substituted (host_enc dropped if any
        leaf changed shape is the caller's concern; sizes stay as declared)."""
        return replace(self, **leaves)

    def slice_blocks(self, start: int, stop: int, *,
                     pad_to: int | None = None) -> "CompressedIntArray":
        """Contiguous block range ``[start, stop)`` as a new array.

        Blocks decode independently (per-block ``counts``/``bases`` carry
        all cross-block state), so any contiguous range is itself a valid
        compressed array — this is what the inverted index's skip-table
        pruning decodes instead of whole posting lists (repro.index.query).
        ``pad_to`` appends count-0 blocks up to a fixed block count so
        pruned decodes hit a bounded set of jitted shapes. Host-side
        (numpy) slicing; ``host_enc`` is dropped.
        """
        return self.take_blocks(np.arange(start, stop), pad_to=pad_to)

    def take_blocks(self, blocks, *, pad_to: int | None = None
                    ) -> "CompressedIntArray":
        """Arbitrary block subset (row gather) as a new array.

        Like :meth:`slice_blocks` but for a non-contiguous block set —
        what skip-table pruning decodes when the probe set is spread out:
        only blocks whose docid range contains a probe are gathered, in
        order, everything else is never decoded. ``pad_to`` appends
        count-0 blocks to a fixed block count (bounded jitted shapes).
        """
        idx = np.asarray(blocks, dtype=np.int64).reshape(-1)
        names = FORMAT_LEAVES[self.format]
        leaves = {}
        for nm in names:
            a = np.asarray(getattr(self, nm))[idx]
            if pad_to is not None and a.shape[0] < pad_to:
                pad = ((0, pad_to - a.shape[0]),) + ((0, 0),) * (a.ndim - 1)
                a = np.pad(a, pad)
            leaves[nm] = a
        cs = self.checksums
        if cs is not None:
            cs = np.asarray(cs)[idx]  # count-0 pad blocks checksum to 0
            if pad_to is not None and cs.shape[0] < pad_to:
                cs = np.pad(cs, ((0, pad_to - cs.shape[0]),))
        return replace(self, host_enc=None, checksums=cs,
                       n=int(leaves["counts"].sum()), **leaves)

    # -- decoding ------------------------------------------------------------
    def decode_blocked(self, *, plan="auto"):
        """Decode on device to the padded uint32[n_blocks, block_size] grid.

        ``plan`` is a dispatch plan name or ``DecodePlan``
        (``repro.kernels.vbyte_decode.dispatch``): ``"auto"`` consults the
        autotune cache, ``"kernel"``/``"jnp"`` force the Pallas / pure-jnp
        path, ``"sharded"`` forces the block-parallel ``shard_map`` path
        (auto-selected anyway when the operands are sharded).
        """
        from repro.kernels.vbyte_decode import dispatch

        return dispatch.decode(self, plan=plan)

    def decode(self, *, use_kernel: bool | None = None, plan="auto",
               check: bool = False) -> np.ndarray:
        """Decode to uint32[n] (host-visible).

        ``use_kernel`` is the deprecated legacy boolean (True → Pallas
        kernel, False → jnp decoder); it maps onto the dispatch plan and
        emits a ``DeprecationWarning``. Use ``plan=``.

        ``check=True`` decodes through the fused ``checksum`` epilogue and
        verifies the per-block column written by ``encode(checksum=True)``
        in the same tile pass, raising
        :class:`repro.robustness.validate.ChecksumError` (with block
        coordinates) on mismatch — see docs/robustness.md.
        """
        if use_kernel is not None:
            plan = warn_use_kernel(use_kernel)
        if check:
            from repro.robustness.validate import decode_checked

            grid = np.asarray(decode_checked(self, plan=plan))
        else:
            grid = np.asarray(self.decode_blocked(plan=plan))
        # concatenate each block's valid prefix. (Not a flat [:n] trim —
        # that silently corrupts outputs when a partial block precedes a
        # full one, as a non-contiguous take_blocks gather can produce.)
        mask = (np.arange(self.block_size)[None, :]
                < np.asarray(self.counts)[:, None])
        return grid[mask].astype(np.uint32)

    def decode_scalar_oracle(self) -> np.ndarray:
        """Byte-at-a-time reference decode (slow; tests/benchmarks only)."""
        if self.format == "streamvbyte":
            out = svb.decode_blocked_scalar(
                np.asarray(self.control),
                np.asarray(self.data),
                np.asarray(self.counts),
                np.asarray(self.bases),
                self.block_size,
                differential=self.differential,
            )
        elif self.format == "binpack":
            out = bpk.decode_blocked_scalar(
                np.asarray(self.widths),
                np.asarray(self.data),
                np.asarray(self.counts),
                np.asarray(self.bases),
                self.block_size,
                differential=self.differential,
            )
        else:
            out = vref.decode_blocked_scalar(
                np.asarray(self.payload),
                np.asarray(self.counts),
                np.asarray(self.bases),
                self.block_size,
                differential=self.differential,
            )
        # concatenate valid prefixes (same rule as decode(): partial blocks
        # may precede full ones, e.g. in optimally-partitioned arrays)
        mask = (np.arange(self.block_size)[None, :]
                < np.asarray(self.counts)[:, None])
        return out[mask].astype(np.uint32)


jax.tree_util.register_pytree_with_keys(
    CompressedIntArray,
    CompressedIntArray.tree_flatten_with_keys,
    CompressedIntArray.tree_unflatten,
)
