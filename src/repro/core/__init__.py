from . import vbyte  # noqa: F401
from .compressed_array import CompressedIntArray  # noqa: F401
