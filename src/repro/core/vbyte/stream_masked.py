"""Vectorized Stream-VByte decoder in JAX — no continuation-bit scan at all.

Where ``masked.py`` recovers integer boundaries from the payload itself
(continuation bits → prefix sums → closed-form positions), the Stream VByte
format hands the decoder the boundaries for free: 2-bit codes in a separate
control stream *are* the lengths. The whole decode collapses to

  code_j    = (control[j//4] >> 2*(j%4)) & 3          (static gather/unpack)
  len_j     = (code_j + 1) · [j < count]              (tail masking)
  start_j   = Σ_{k<j} len_k                           (exclusive prefix sum)
  out_j     = Σ_{k<len_j} data[start_j + k] << 8k     (≤4-byte gather, full
                                                       8 bits per byte)
  differential: out = base + inclusive_cumsum(out)    (fused, as before)

No per-byte data-dependent masks, no 2^12 tables, no pshufb analogue — the
control stream replaces all of it, which is exactly why the format decodes
faster than Masked VByte on every architecture the Stream VByte paper
measures. Padding control codes are zeros (code 0 = length 1), so masking by
``j < count`` is load-bearing just like in the VByte path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_U32 = jnp.uint32
MAX_BYTES_PER_INT = 4


def control_codes(control: jax.Array, block_size: int) -> jax.Array:
    """Unpack 2-bit codes: uint8[..., B//4] -> int32[..., B] (LSB-first)."""
    j = jnp.arange(block_size, dtype=jnp.int32)
    packed = jnp.take(control, j // 4, axis=-1).astype(jnp.int32)
    return (packed >> (2 * (j % 4))) & 3


def integer_lengths(codes: jax.Array, counts: jax.Array | None = None) -> jax.Array:
    """Data-byte lengths per integer (1..4), zeroed past ``counts``."""
    lens = codes + 1
    if counts is None:
        return lens
    j = jnp.arange(codes.shape[-1], dtype=jnp.int32)
    return jnp.where(j < jnp.asarray(counts, jnp.int32)[..., None], lens, 0)


def start_offsets(lengths: jax.Array,
                  chunk_width: int | None = None) -> jax.Array:
    """Exclusive prefix sum of lengths: each integer's first data byte.

    ``chunk_width`` computes it through the chunked (banded) decomposition
    mirroring the Pallas kernels — identical values by construction.
    """
    if chunk_width is None:
        return jnp.cumsum(lengths, axis=-1, dtype=jnp.int32) - lengths
    from repro.core.vbyte.masked import chunked_exclusive_cumsum

    return chunked_exclusive_cumsum(lengths, chunk_width)


def gather_values(data: jax.Array, starts: jax.Array, lengths: jax.Array) -> jax.Array:
    """Reassemble uint32 values: out_j = Σ_{k<len_j} data[start_j+k] << 8k."""
    S = data.shape[-1]
    k = jnp.arange(MAX_BYTES_PER_INT, dtype=jnp.int32)
    src = jnp.minimum(starts[..., None] + k, S - 1)  # clamp: masked below
    flat = jnp.take_along_axis(
        data, src.reshape(*data.shape[:-1], -1), axis=-1
    ).reshape(*starts.shape, MAX_BYTES_PER_INT).astype(_U32)
    used = k < lengths[..., None]
    contrib = jnp.where(used, flat << (8 * k).astype(_U32), _U32(0))
    return contrib.sum(axis=-1, dtype=_U32)


@functools.partial(
    jax.jit, static_argnames=("block_size", "differential", "chunk_width"))
def decode_blocked(
    control: jax.Array,
    data: jax.Array,
    counts: jax.Array,
    bases: jax.Array,
    *,
    block_size: int,
    differential: bool,
    chunk_width: int | None = None,
) -> jax.Array:
    """Vectorized blocked Stream-VByte decode: uint32[n_blocks, block_size].

    All blocks decode in parallel. Zero-padded rows; block b row j valid iff
    j < counts[b]. ``chunk_width`` routes the length prefix sum through the
    chunked (banded) decomposition — same values bit-for-bit.
    """
    B = block_size
    codes = control_codes(control, B)  # [nb, B]
    lens = integer_lengths(codes, counts)
    starts = start_offsets(lens, chunk_width)
    out = gather_values(data, starts, lens)

    j = jnp.arange(B, dtype=jnp.int32)[None, :]
    row_valid = j < counts[:, None].astype(jnp.int32)
    out = jnp.where(row_valid, out, _U32(0))
    if differential:
        out = bases[:, None].astype(_U32) + jnp.cumsum(out, axis=-1, dtype=_U32)
        out = jnp.where(row_valid, out, _U32(0))
    return out


def decode_stream(
    control: jax.Array,
    data: jax.Array,
    n_max: int,
    *,
    n: jax.Array | int | None = None,
    differential: bool = False,
    base: jax.Array | int = 0,
) -> jax.Array:
    """Decode a single (control, data) stream pair to uint32[n_max].

    ``control`` must hold at least ``ceil(n_max/4)`` bytes (zero-pad past the
    valid region); ``n`` is the number of valid integers (default: n_max).
    """
    n = n_max if n is None else n
    out = decode_blocked(
        control[None, : -(-n_max // 4)],
        data[None, :],
        jnp.asarray([n], jnp.int32),
        jnp.asarray([base], _U32),
        block_size=-(-n_max // 4) * 4,
        differential=differential,
    )
    return out[0, :n_max]
