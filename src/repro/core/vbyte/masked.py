"""MASKED VBYTE, adapted to TPU — the paper's contribution in vectorized JAX.

The x86 algorithm (paper §IV) is: pmovmskb extracts 16 continuation bits →
a 12-bit mask slice indexes a 2^12 table of (consumed bytes, shuffle index) →
pshufb routes payload bytes to fixed lanes → masked shifts + ORs reassemble
integers → a SIMD prefix sum fuses differential decoding.

TPU has neither pshufb nor pmovmskb, and scalar table lookups serialize
(DESIGN.md §2). The transferable insight is *branch-free, data-parallel mask
processing*; here every step is an arithmetic identity over whole byte tiles:

  continuation mask   c_i   = byte_i >> 7                 (the pmovmskb analogue,
                                                           kept vectorized, never packed)
  terminator flag     end_i = 1 - c_i
  output index        out_idx_i = Σ_{k<i} end_k           (exclusive prefix sum —
                                                           replaces the 2^12 lookup)
  in-integer position pos_i = c_{i-1}(1 + c_{i-2}(1 + c_{i-3}(1 + c_{i-4})))
                                                          (closed form: ≤5 bytes/int,
                                                           replaces the 170 pshufb masks)
  contribution        contrib_i = (byte_i & 0x7F) << 7·pos_i
  reassembly          out_j = Σ_{i: out_idx_i = j} contrib_i   (segment-sum / one-hot
                                                                matmul — the MXU is the
                                                                TPU's shuffle unit)
  differential        out = base + inclusive_cumsum(out)  (fused, as in the paper)

All shapes are static; tail/padding bytes are masked via ``out_idx < count``
(padding zero bytes *look like* terminators of 0, so masking is load-bearing).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_U32 = jnp.uint32


def continuation_bits(data: jax.Array) -> jax.Array:
    """Vectorized pmovmskb analogue: 1 where the byte continues, else 0."""
    return (data.astype(_U32) >> 7).astype(jnp.int32)


def in_integer_positions(cont: jax.Array) -> jax.Array:
    """pos_i = number of consecutive continuation bytes immediately before i.

    VByte(32-bit) integers span ≤5 bytes so the recurrence closes after four
    shifted terms — static shifts only, no scan (Mosaic/VPU friendly).
    """
    def shifted(k: int) -> jax.Array:
        pad = [(0, 0)] * (cont.ndim - 1) + [(k, 0)]
        return jnp.pad(cont, pad)[..., : cont.shape[-1]]

    c1, c2, c3, c4 = shifted(1), shifted(2), shifted(3), shifted(4)
    return c1 * (1 + c2 * (1 + c3 * (1 + c4)))


def byte_contributions(data: jax.Array, pos: jax.Array) -> jax.Array:
    """(byte & 0x7F) << 7*pos, as uint32 (wraps mod 2^32 like the paper's 32-bit lanes)."""
    return (data.astype(_U32) & _U32(0x7F)) << (7 * pos).astype(_U32)


def decode_stream(
    data: jax.Array,
    n_max: int,
    *,
    nbytes: jax.Array | int | None = None,
    differential: bool = False,
    base: jax.Array | int = 0,
) -> tuple[jax.Array, jax.Array]:
    """Vectorized decode of a single VByte stream.

    Args:
      data: uint8[S] byte stream (may be zero-padded past ``nbytes``).
      n_max: static output capacity.
      nbytes: number of valid bytes (defaults to all of ``data``).
      differential: fuse the prefix sum over decoded gaps (paper §IV last ¶).
      base: carry-in absolute value for differential decoding.

    Returns:
      (out uint32[n_max] zero-padded, n_decoded int32)
    """
    S = data.shape[-1]
    idx = jnp.arange(S, dtype=jnp.int32)
    valid_byte = idx < (jnp.int32(S) if nbytes is None else jnp.asarray(nbytes, jnp.int32))

    cont = continuation_bits(data) * valid_byte
    end = (1 - cont) * valid_byte
    out_idx = jnp.cumsum(end, dtype=jnp.int32) - end  # exclusive prefix sum
    pos = in_integer_positions(cont)
    contrib = byte_contributions(data, pos)

    n_decoded = jnp.minimum(jnp.sum(end, dtype=jnp.int32), jnp.int32(n_max))
    keep = valid_byte & (out_idx < n_max)
    contrib = jnp.where(keep, contrib, _U32(0))
    ids = jnp.where(keep, out_idx, n_max - 1 if n_max else 0)

    out = jax.ops.segment_sum(contrib, ids, num_segments=n_max)

    j = jnp.arange(n_max, dtype=jnp.int32)
    out = jnp.where(j < n_decoded, out, _U32(0))
    if differential:
        out = jnp.asarray(base, _U32) + jnp.cumsum(out, dtype=_U32)
        out = jnp.where(j < n_decoded, out, _U32(0))
    return out, n_decoded


def chunked_exclusive_cumsum(x: jax.Array, chunk_width: int) -> jax.Array:
    """Exclusive row cumsum computed chunk-by-chunk (the banded structure).

    Identical values to ``cumsum(x) - x`` — the within-chunk prefix plus
    the sum of earlier chunks is the global prefix — so decoders built on
    it stay bit-exact with the dense ones by construction. This is the jnp
    mirror of the Pallas kernels' ``banded.chunked_prefix`` (which runs the
    same decomposition through [W, W] triangular MXU matmuls).
    """
    *lead, S = x.shape
    W = int(chunk_width)
    pad = (-S) % W
    xp = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad)]) if pad else x
    nC = xp.shape[-1] // W
    c = xp.reshape(*lead, nC, W)
    loc = jnp.cumsum(c, axis=-1, dtype=jnp.int32) - c
    totals = loc[..., -1] + c[..., -1]
    base = jnp.cumsum(totals, axis=-1, dtype=jnp.int32) - totals
    out = (base[..., None] + loc).reshape(*lead, nC * W)
    return out[..., :S]


@functools.partial(
    jax.jit, static_argnames=("block_size", "differential", "chunk_width"))
def decode_blocked(
    payload: jax.Array,
    counts: jax.Array,
    bases: jax.Array,
    *,
    block_size: int,
    differential: bool,
    chunk_width: int | None = None,
) -> jax.Array:
    """Vectorized decode of the blocked layout: uint32[n_blocks, block_size].

    All blocks decode in parallel (the SPMD adaptation of the paper's
    sequential 48-byte mask pipeline). Zero-padded rows; block b row j valid
    iff j < counts[b]. ``chunk_width`` routes the byte→integer prefix sum
    through the chunked (banded) decomposition the Pallas kernels use —
    same values bit-for-bit, see ``chunked_exclusive_cumsum``.
    """
    nb, S = payload.shape
    B = block_size

    cont = continuation_bits(payload)  # padding zeros ⇒ cont=0 (handled by count mask)
    end = 1 - cont
    if chunk_width is None:
        out_idx = jnp.cumsum(end, axis=-1, dtype=jnp.int32) - end
    else:
        out_idx = chunked_exclusive_cumsum(end, chunk_width)
    pos = in_integer_positions(cont)
    contrib = byte_contributions(payload, pos)

    keep = out_idx < counts[:, None].astype(jnp.int32)
    contrib = jnp.where(keep, contrib, _U32(0))
    ids_in_block = jnp.minimum(out_idx, B - 1)
    flat_ids = (jnp.arange(nb, dtype=jnp.int32)[:, None] * B + ids_in_block).reshape(-1)
    out = jax.ops.segment_sum(
        contrib.reshape(-1), flat_ids, num_segments=nb * B
    ).reshape(nb, B)

    j = jnp.arange(B, dtype=jnp.int32)[None, :]
    row_valid = j < counts[:, None].astype(jnp.int32)
    out = jnp.where(row_valid, out, _U32(0))
    if differential:
        out = bases[:, None].astype(_U32) + jnp.cumsum(out, axis=-1, dtype=_U32)
        out = jnp.where(row_valid, out, _U32(0))
    return out


def count_integers(data: jax.Array, nbytes: jax.Array | int | None = None) -> jax.Array:
    """Number of complete integers in a stream = number of terminator bytes."""
    S = data.shape[-1]
    valid = (
        jnp.ones((S,), jnp.int32)
        if nbytes is None
        else (jnp.arange(S, dtype=jnp.int32) < jnp.asarray(nbytes, jnp.int32)).astype(jnp.int32)
    )
    return jnp.sum((1 - continuation_bits(data)) * valid, dtype=jnp.int32)
