"""Vectorized binpack decoder in JAX — static shift/mask, no scans at all.

Binpack is the degenerate-friendly end of the decode spectrum: where
Masked VByte must *recover* integer boundaries (continuation-bit prefix
sums) and Stream VByte is *told* them (control stream + length prefix
sum), binpack's boundaries are affine — value ``j`` of a width-``w``
block starts at bit ``j·w``. The whole decode is

  bitpos_j = j · w                       (static integer math, no cumsum)
  byte0_j  = bitpos_j >> 3,  shift_j = bitpos_j & 7
  word40_j = data[byte0_j .. byte0_j+4]  (5-byte gather, clamped)
  out_j    = (word40_j >> shift_j) & ((1 << w) - 1)
  differential: out = base + inclusive_cumsum(out)   (fused, as before)

The 40-bit gathered word is carried as two int32 halves to keep every
operation inside exact 32-bit lanes: ``lo24`` (bytes 0–2, < 2^24) and
``hi16`` (bytes 3–4, < 2^16), recombined as
``(lo24 >> s) | (hi16 << (24 - s))`` with ``s ∈ 0..7`` so no shift ever
reaches the 32-bit hazard. Bits wrapped past bit 31 by the ``hi16``
shift are bits ≥ 32 of the value, which cannot exist for ``w ≤ 32``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_U32 = jnp.uint32
GATHER_BYTES = 5  # shift ≤ 7 bits + width ≤ 32 bits spans at most 5 bytes


def block_bit_positions(widths: jax.Array, block_size: int) -> jax.Array:
    """bitpos[b, j] = j · w_b, int32 [n_blocks, block_size] (max 4096·8)."""
    w = jnp.asarray(widths).reshape(-1).astype(jnp.int32)
    j = jnp.arange(block_size, dtype=jnp.int32)
    return j[None, :] * w[:, None]


def gather_words(data: jax.Array, byte0: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Gather the 40-bit window at each byte offset as (lo24, hi16) int32.

    Out-of-range bytes are clamped to the last column; the clamped bytes
    only ever contribute bits the width mask discards (valid values end by
    construction inside ``ceil(count·w/8) ≤ stride`` bytes).
    """
    S = data.shape[-1]
    k = jnp.arange(GATHER_BYTES, dtype=jnp.int32)
    src = jnp.minimum(byte0[..., None] + k, S - 1)
    b = jnp.take_along_axis(
        data, src.reshape(*data.shape[:-1], -1), axis=-1
    ).reshape(*byte0.shape, GATHER_BYTES).astype(jnp.int32)
    lo24 = b[..., 0] | (b[..., 1] << 8) | (b[..., 2] << 16)
    hi16 = b[..., 3] | (b[..., 4] << 8)
    return lo24, hi16


def extract_values(lo24: jax.Array, hi16: jax.Array, shift: jax.Array,
                   widths: jax.Array) -> jax.Array:
    """(word40 >> shift) & width_mask, in exact int32 lanes → uint32."""
    w = jnp.asarray(widths).reshape(-1).astype(jnp.int32)[:, None]
    # lo24 < 2^24 is non-negative, so >> is a logical shift; 24 - shift
    # stays in 17..24, never a full-width shift
    val = (lo24 >> shift) | (hi16 << (24 - shift))
    # (1 << 31) - 1 wraps to 0x7FFFFFFF in int32 — still the right mask;
    # w = 32 needs all 32 bits, i.e. mask -1 (the shift amount is clamped
    # so the dead branch never shifts by a full lane width)
    mask = jnp.where(w >= 32, jnp.int32(-1),
                     (jnp.int32(1) << jnp.minimum(w, 31)) - 1)
    return (val & mask).astype(_U32)


@functools.partial(
    jax.jit, static_argnames=("block_size", "differential", "chunk_width"))
def decode_blocked(
    widths: jax.Array,
    data: jax.Array,
    counts: jax.Array,
    bases: jax.Array,
    *,
    block_size: int,
    differential: bool,
    chunk_width: int | None = None,
) -> jax.Array:
    """Vectorized blocked binpack decode: uint32[n_blocks, block_size].

    All blocks decode in parallel at their own width. Zero-padded rows;
    block b row j valid iff j < counts[b]. ``chunk_width`` is accepted for
    dispatch-signature parity but ignored: there is no length prefix sum
    to chunk.
    """
    del chunk_width  # no scan to decompose — positions are affine in j
    B = block_size
    bitpos = block_bit_positions(widths, B)  # [nb, B]
    lo24, hi16 = gather_words(data, bitpos >> 3)
    out = extract_values(lo24, hi16, bitpos & 7, widths)

    j = jnp.arange(B, dtype=jnp.int32)[None, :]
    row_valid = j < counts[:, None].astype(jnp.int32)
    out = jnp.where(row_valid, out, _U32(0))
    if differential:
        out = bases[:, None].astype(_U32) + jnp.cumsum(out, axis=-1, dtype=_U32)
        out = jnp.where(row_valid, out, _U32(0))
    return out


def decode_stream(
    widths: jax.Array,
    data: jax.Array,
    n_max: int,
    *,
    n: jax.Array | int | None = None,
    differential: bool = False,
    base: jax.Array | int = 0,
) -> jax.Array:
    """Decode a single width-``widths[0]`` packed stream to uint32[n_max]."""
    n = n_max if n is None else n
    out = decode_blocked(
        jnp.asarray(widths).reshape(1, 1),
        data[None, :],
        jnp.asarray([n], jnp.int32),
        jnp.asarray([base], _U32),
        block_size=n_max,
        differential=differential,
    )
    return out[0, :n_max]
