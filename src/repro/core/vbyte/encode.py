"""Host-side VByte encoding (numpy, vectorized).

Implements the format of Plaisance, Kurz & Lemire (2015), §I:

    Starting from the least significant bits, an integer is written seven
    bits per byte; the most significant bit of each byte is 1 in all bytes
    except the last (the terminator), where it is 0.

Two layouts are produced:

* **stream**: the paper's byte stream — ``concat(vbyte(x) for x in values)``.
* **blocked**: fixed-shape SPMD layout (DESIGN.md §2) — ``block_size``
  integers per block, each block padded to a common byte ``stride``; per-block
  ``counts`` (tail masking) and ``bases`` (differential-coding carry) make
  every block independently decodable, which is what lets 1000+ chips decode
  in parallel.

Encoding is vectorized: no python loop over integers.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

MAX_BYTES_PER_INT = 5  # 32-bit integers need at most ceil(32/7) = 5 bytes
_LEN_THRESHOLDS = np.array([1 << 7, 1 << 14, 1 << 21, 1 << 28], dtype=np.uint64)
_U32_MAX = 0xFFFFFFFF


def validate_u32(values, *, wrap: bool = False, what: str = "encoder input") -> np.ndarray:
    """Validate encoder input and return it as ``uint64`` in ``[0, 2^32)``.

    Both on-device formats encode 32-bit unsigned integers; anything else —
    float dtypes, negative values, values ≥ 2^32 — used to be silently
    truncated/wrapped by the ``uint64`` cast, which turns caller bugs into
    wrong-but-well-formed streams. Reject them with a clear ``ValueError``
    instead. ``wrap=True`` is the explicit escape hatch: truncate floats and
    reduce mod 2^32 (two's-complement for signed inputs), matching the
    decoder oracles' wraparound semantics.
    """
    a = np.asarray(values)
    if not (np.issubdtype(a.dtype, np.integer) or a.dtype == np.bool_):
        if not wrap:
            raise ValueError(
                f"{what} must be an integer array, got dtype {a.dtype} "
                "(pass wrap=True to truncate explicitly)")
        a = a.astype(np.int64)
    if wrap:
        if np.issubdtype(a.dtype, np.signedinteger):
            a = a.astype(np.int64).astype(np.uint64)
        return a.astype(np.uint64) & np.uint64(_U32_MAX)
    if a.size and np.issubdtype(a.dtype, np.signedinteger) and int(a.min()) < 0:
        raise ValueError(
            f"{what} must be non-negative, got min {int(a.min())} "
            "(pass wrap=True to wrap mod 2^32 explicitly)")
    a = a.astype(np.uint64)
    if a.size and int(a.max()) > _U32_MAX:
        raise ValueError(
            f"{what} must be < 2^32, got max {int(a.max())} "
            "(pass wrap=True to wrap mod 2^32 explicitly)")
    return a


def vbyte_lengths(values: np.ndarray) -> np.ndarray:
    """Number of encoded bytes for each value (1..5)."""
    v = np.asarray(values, dtype=np.uint64)
    return (np.searchsorted(_LEN_THRESHOLDS, v, side="right") + 1).astype(np.int64)


def _byte_matrix(values: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Return ([n, 5] uint8 byte matrix incl. continuation bits, [n] lengths)."""
    v = np.asarray(values, dtype=np.uint64)
    if v.ndim != 1:
        raise ValueError(f"expected 1-D values, got shape {v.shape}")
    if v.size and int(v.max()) > 0xFFFFFFFF:
        raise ValueError("VByte encoder supports 32-bit unsigned integers")
    lengths = vbyte_lengths(v)
    shifts = np.arange(MAX_BYTES_PER_INT, dtype=np.uint64) * np.uint64(7)
    chunks = (v[:, None] >> shifts[None, :]) & np.uint64(0x7F)  # [n, 5]
    k = np.arange(MAX_BYTES_PER_INT, dtype=np.int64)
    cont = k[None, :] < (lengths[:, None] - 1)  # continuation flag per byte
    data = chunks.astype(np.uint8) | (cont.astype(np.uint8) << 7)
    return data, lengths


def encode_stream(values: np.ndarray, *, wrap: bool = False) -> np.ndarray:
    """Encode to the paper's tight byte stream. Returns uint8[total_bytes]."""
    data, lengths = _byte_matrix(validate_u32(values, wrap=wrap))
    keep = np.arange(MAX_BYTES_PER_INT)[None, :] < lengths[:, None]
    return data[keep]  # row-major boolean take preserves byte order


def delta_encode(values: np.ndarray) -> np.ndarray:
    """Successive differences (x1-0, x2-x1, ...) per the paper's convention.

    Requires a non-decreasing sequence (sorted ids, possibly with repeats).
    """
    v = np.asarray(values, dtype=np.uint64)
    if v.size and np.any(np.diff(v.astype(np.int64)) < 0):
        raise ValueError("differential coding requires a non-decreasing sequence")
    return np.diff(v, prepend=np.uint64(0))


def delta_decode(gaps: np.ndarray) -> np.ndarray:
    return np.cumsum(np.asarray(gaps, dtype=np.uint64)).astype(np.uint64)


@dataclass(frozen=True)
class BlockedEncoding:
    """Fixed-shape blocked VByte encoding (see module docstring)."""

    payload: np.ndarray  # uint8 [n_blocks, stride]
    counts: np.ndarray  # int32 [n_blocks] — valid integers per block
    bases: np.ndarray  # uint32 [n_blocks] — differential carry-in (0 if not differential)
    n: int  # total integers
    block_size: int
    differential: bool
    ragged: bool = False  # one independent list (bag) per block

    @property
    def n_blocks(self) -> int:
        return self.payload.shape[0]

    @property
    def stride(self) -> int:
        return self.payload.shape[1]

    @property
    def payload_bytes(self) -> int:
        """Tight compressed size (excludes block padding): the paper's metric."""
        return int(vbyte_lengths(self._encoded_values()).sum()) if self.n else 0

    def _encoded_values(self) -> np.ndarray:
        # re-derive gap/raw values from the payload for size accounting
        from .ref import decode_stream_scalar  # local import to avoid cycle

        out = []
        for b in range(self.n_blocks):
            out.append(decode_stream_scalar(self.payload[b], int(self.counts[b])))
        return np.concatenate(out) if out else np.zeros(0, np.uint64)

    @property
    def device_bytes(self) -> int:
        """Bytes actually shipped to device (payload incl. padding + metadata)."""
        return self.payload.nbytes + self.counts.nbytes + self.bases.nbytes

    @property
    def bits_per_int(self) -> float:
        return 8.0 * self.payload_bytes / max(self.n, 1)


@dataclass(frozen=True)
class BlockedMeta:
    """Single-pass blocked-layout metadata shared by all three encoders.

    The index builder used to recompute ``blocked_metadata`` (validate,
    delta-encode, bases, counts) once for the payload encode and again for
    the skip table — profiled hot on large builds. ``prepare_blocked``
    computes it once; every ``encode_blocked`` accepts it via ``meta=`` and
    :meth:`skip_table` derives the per-block first/last values from the
    same pass.
    """

    values: np.ndarray  # validated uint64 absolute values
    enc_values: np.ndarray  # what gets packed (gaps when differential)
    bases: np.ndarray  # uint32 [n_blocks]
    counts: np.ndarray  # int32 [n_blocks]
    n: int
    n_blocks: int
    block_size: int
    differential: bool

    def skip_table(self) -> tuple[np.ndarray, np.ndarray]:
        """Per-block (first, last) absolute values — uint32 [n_blocks] each."""
        if self.n == 0:
            z = np.zeros(0, np.uint32)
            return z, z
        idx = np.arange(self.n_blocks)
        first = self.values[idx * self.block_size]
        last = self.values[np.minimum((idx + 1) * self.block_size, self.n) - 1]
        return first.astype(np.uint32), last.astype(np.uint32)


def prepare_blocked(
    values: np.ndarray,
    *,
    block_size: int = 128,
    differential: bool = False,
    wrap: bool = False,
) -> BlockedMeta:
    """Validate + derive blocked metadata once, for reuse across encoders."""
    v = validate_u32(values, wrap=wrap).ravel()
    n = int(v.size)
    n_blocks = max(1, -(-n // block_size))
    enc_values, bases, counts = blocked_metadata(
        v, n_blocks=n_blocks, block_size=block_size, differential=differential)
    return BlockedMeta(
        values=v, enc_values=enc_values, bases=bases, counts=counts, n=n,
        n_blocks=n_blocks, block_size=block_size, differential=differential)


def blocked_metadata(
    v: np.ndarray, *, n_blocks: int, block_size: int, differential: bool
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Shared blocked-layout metadata: ``(encoded_values, bases, counts)``.

    With ``differential=True`` the *gaps* are what get encoded and each
    block's ``bases[b]`` holds the absolute value preceding the block, so
    ``decode(block b) = bases[b] + cumsum(gaps in block b)`` — every block is
    independent (the TPU analogue of inverted-index skip blocks). Used by
    both the VByte and Stream-VByte encoders.
    """
    n = int(v.size)
    if differential:
        enc_values = delta_encode(v)
        # carry-in for block b = last absolute value of block b-1
        last_idx = np.minimum(np.arange(1, n_blocks) * block_size, max(n, 1)) - 1
        bases = np.zeros(n_blocks, dtype=np.uint32)
        if n:
            bases[1:] = v[last_idx].astype(np.uint32)
    else:
        enc_values = v
        bases = np.zeros(n_blocks, dtype=np.uint32)

    counts = np.full(n_blocks, block_size, dtype=np.int32)
    if n:
        counts[-1] = n - (n_blocks - 1) * block_size
    else:
        counts[0] = 0
    return enc_values, bases, counts


def scatter_blocked_payload(
    data: np.ndarray,
    lengths: np.ndarray,
    *,
    n_blocks: int,
    block_size: int,
    max_bytes: int,
    stride_multiple: int,
    min_stride: int | None,
) -> np.ndarray:
    """Scatter per-integer byte rows into a dense ``[n_blocks, stride]`` grid.

    ``data`` is ``uint8[n, max_bytes]`` (row i holds integer i's encoded
    bytes, first ``lengths[i]`` valid). The stride is the max block byte
    count rounded up for aligned VMEM tiles. Shared by both formats.
    """
    n = data.shape[0]
    pad_n = n_blocks * block_size
    lengths_p = np.zeros(pad_n, dtype=np.int64)
    lengths_p[:n] = lengths
    block_bytes = lengths_p.reshape(n_blocks, block_size).sum(axis=1)
    stride = int(block_bytes.max(initial=1))
    stride = max(stride, min_stride or 0, 1)
    stride = -(-stride // stride_multiple) * stride_multiple
    if stride > block_size * max_bytes:
        stride = block_size * max_bytes

    payload = np.zeros((n_blocks, stride), dtype=np.uint8)
    if n:
        # destination offset of every encoded byte, all vectorized
        within = np.arange(max_bytes)[None, :]
        keep = within < lengths[:, None]  # [n, max_bytes]
        block_id = np.arange(n) // block_size
        # byte offset of each integer inside its block:
        # exclusive cumsum of lengths, reset at every block boundary
        csum = np.concatenate([[0], np.cumsum(lengths)[:-1]])
        block_start = np.repeat(
            np.concatenate([[0], np.cumsum(block_bytes)[:-1]]), block_size
        )[:n]
        off_in_block = csum - block_start
        dst = block_id[:, None] * stride + off_in_block[:, None] + within
        payload.reshape(-1)[dst[keep]] = data[keep]
    return payload


def encode_blocked(
    values: np.ndarray | None = None,
    *,
    block_size: int = 128,
    differential: bool = False,
    stride_multiple: int = 128,
    min_stride: int | None = None,
    wrap: bool = False,
    meta: BlockedMeta | None = None,
) -> BlockedEncoding:
    """Encode into the blocked layout (see blocked_metadata).

    ``meta`` accepts a pre-computed :class:`BlockedMeta` so the builder's
    encode → skip-table path runs the metadata pass once per list.
    """
    if meta is None:
        meta = prepare_blocked(values, block_size=block_size,
                               differential=differential, wrap=wrap)
    data, lengths = _byte_matrix(meta.enc_values)
    payload = scatter_blocked_payload(
        data,
        lengths,
        n_blocks=meta.n_blocks,
        block_size=meta.block_size,
        max_bytes=MAX_BYTES_PER_INT,
        stride_multiple=stride_multiple,
        min_stride=min_stride,
    )

    return BlockedEncoding(
        payload=payload,
        counts=meta.counts,
        bases=meta.bases,
        n=meta.n,
        block_size=meta.block_size,
        differential=meta.differential,
    )


def ragged_block_values(
    lists, *, block_size: int, differential: bool, wrap: bool = False
) -> tuple[np.ndarray, np.ndarray]:
    """Shared ragged-bag layout: one independent list per block.

    Returns ``(values [n_lists, block_size] uint64, counts [n_lists] int32)``
    with each row holding list i (delta-encoded per row when
    ``differential`` — first gap is the absolute id, so ``bases`` stay 0 and
    every bag decodes self-contained, exactly the adjacency-row convention).
    Used by both the VByte and Stream-VByte ragged encoders.
    """
    n_lists = max(1, len(lists))
    counts = np.zeros(n_lists, dtype=np.int32)
    vpad = np.zeros((n_lists, block_size), dtype=np.uint64)
    for i, lst in enumerate(lists):
        if np.asarray(lst).size == 0:
            continue  # empty bag: dtype carries no intent (e.g. [] padding)
        a = validate_u32(lst, wrap=wrap, what=f"list {i}").ravel()
        if a.size > block_size:
            raise ValueError(
                f"list {i} has {a.size} ids > block_size={block_size}")
        counts[i] = a.size
        if differential:
            a = delta_encode(a)
        vpad[i, : a.size] = a
    return vpad, counts


def encode_ragged_blocked(
    lists,
    *,
    block_size: int = 128,
    differential: bool = False,
    stride_multiple: int = 128,
    min_stride: int | None = None,
    wrap: bool = False,
) -> BlockedEncoding:
    """Encode ragged id bags: block b holds list b (≤ block_size ids).

    The layout feeds the fused bag-sum/dot-score epilogues directly: one
    kernel block = one bag = one output row. ``counts`` carry the ragged
    lengths; ``bases`` are all zero (per-row differential is self-based).
    """
    vpad, counts = ragged_block_values(
        lists, block_size=block_size, differential=differential, wrap=wrap)
    n_lists = vpad.shape[0]
    data, lengths = _byte_matrix(vpad.reshape(-1))
    lengths = lengths.reshape(n_lists, block_size)
    lengths[np.arange(block_size)[None, :] >= counts[:, None]] = 0
    payload = scatter_blocked_payload(
        data,
        lengths.reshape(-1),
        n_blocks=n_lists,
        block_size=block_size,
        max_bytes=MAX_BYTES_PER_INT,
        stride_multiple=stride_multiple,
        min_stride=min_stride,
    )
    return BlockedEncoding(
        payload=payload,
        counts=counts,
        bases=np.zeros(n_lists, dtype=np.uint32),
        n=int(counts.sum()),
        block_size=block_size,
        differential=differential,
        ragged=True,
    )
