"""Host-side binary-packing (binpack) encoding (numpy, vectorized).

Implements the blocked fixed-width bit packing of Lemire & Boytsov
(*Decoding billions of integers per second through vectorization*, §4):
each block's integers are packed at the block's **max bit width**
``w ∈ {0..32}``, LSB-first — value ``j`` occupies bits
``[j·w, (j+1)·w)`` of the block's byte row — with the width stored in a
tiny per-block **width column** (one byte per block). Decode needs no
continuation-bit scan and no length prefix sum at all: every value's bit
position is the affine ``j·w``, so the decoder is a static shift/mask per
lane (``binpack_masked.py``, ``kernels/vbyte_decode/binpack_kernel.py``).

Trade-off vs the byte-aligned formats (docs/formats.md §binpack): one
outlier gap forces the whole block to its width, so uniform big blocks
compress worse on skewed gaps — which is exactly what the index builder's
shortest-path block partition (``repro.index.partition``) exploits by
cutting blocks at outlier boundaries.

Layouts mirror ``encode.py``/``stream_vbyte.py``:

* **blocked**: ``widths uint8 [n_blocks, 1]`` + ``data uint8 [n_blocks,
  stride]`` + per-block ``counts``/``bases``. The width column keeps the
  block dim leading like every other leaf, so sharding/gather/pad
  machinery is format-agnostic.

Encoding is vectorized per width group: no python loop over integers.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

MAX_BYTES_PER_INT = 4  # a 32-bit value packs into at most 32 bits
MAX_WIDTH = 32
_POW2 = (np.uint64(1) << np.arange(1, MAX_WIDTH, dtype=np.uint64)).astype(
    np.uint64)  # thresholds 2^1..2^31 for bit_length via searchsorted


def bit_widths(values: np.ndarray) -> np.ndarray:
    """Bit length of each value (0 for 0, 32 for values ≥ 2^31)."""
    v = np.asarray(values, dtype=np.uint64)
    # bit_length(v) = #{k ≥ 0 : 2^k ≤ v}; searchsorted over 2^1..2^31 gives
    # bit_length - 1 for v ≥ 1 (exact integer compares, no float log2)
    w = np.searchsorted(_POW2, v, side="right").astype(np.int64) + 1
    return np.where(v == 0, 0, w)


def block_widths(enc_values: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Per-block max bit width over the valid prefix: uint8 [n_blocks]."""
    nb, B = enc_values.shape
    valid = np.arange(B)[None, :] < np.asarray(counts).reshape(-1, 1)
    wv = bit_widths(enc_values) * valid
    return wv.max(axis=1, initial=0).astype(np.uint8)


def pack_rows(vals: np.ndarray, w: int) -> np.ndarray:
    """Pack ``uint64 [g, B]`` rows at width ``w``: ``uint8 [g, ceil(B·w/8)]``.

    LSB-first within each value and across the row bit stream, so the final
    partial byte's high bits are zero — the canonical padding the validator
    checks (``repro.robustness.validate``).
    """
    g, B = vals.shape
    if w == 0:
        return np.zeros((g, 0), np.uint8)
    bits = ((vals[:, :, None] >> np.arange(w, dtype=np.uint64)) & np.uint64(1))
    bits = bits.astype(np.uint8).reshape(g, B * w)
    pad = (-bits.shape[1]) % 8
    if pad:
        bits = np.pad(bits, ((0, 0), (0, pad)))
    return np.packbits(bits, axis=1, bitorder="little")


def pack_blocked_data(
    enc_values: np.ndarray,  # uint64 [n_blocks, block_size], zero-padded
    widths: np.ndarray,  # uint8 [n_blocks]
    *,
    stride_multiple: int,
    min_stride: int | None,
) -> np.ndarray:
    """Pack every block at its own width into a dense ``[n_blocks, stride]``.

    Blocks are grouped by width so each group packs in one vectorized pass.
    Padded value slots are zero, so bits past ``counts·w`` are zero too.
    """
    nb, B = enc_values.shape
    row_bytes = -(-(widths.astype(np.int64) * B) // 8)
    stride = int(row_bytes.max(initial=1))
    stride = max(stride, min_stride or 0, 1)
    stride = -(-stride // stride_multiple) * stride_multiple
    if stride > B * MAX_BYTES_PER_INT:
        stride = B * MAX_BYTES_PER_INT
    data = np.zeros((nb, stride), np.uint8)
    for w in np.unique(widths):
        rows = np.flatnonzero(widths == w)
        packed = pack_rows(enc_values[rows], int(w))
        data[rows, : packed.shape[1]] = packed
    return data


@dataclass(frozen=True)
class BinpackEncoding:
    """Fixed-shape blocked binpack encoding (see module docstring)."""

    widths: np.ndarray  # uint8 [n_blocks, 1] — per-block bit width
    data: np.ndarray  # uint8 [n_blocks, stride]
    counts: np.ndarray  # int32 [n_blocks] — valid integers per block
    bases: np.ndarray  # uint32 [n_blocks] — differential carry-in
    n: int  # total integers
    block_size: int
    differential: bool
    ragged: bool = False  # one independent list (bag) per block

    @property
    def n_blocks(self) -> int:
        return self.data.shape[0]

    @property
    def stride(self) -> int:
        return self.data.shape[1]

    @property
    def payload_bytes(self) -> int:
        """Tight compressed size: packed bits (rounded up per block) plus
        the one-byte-per-block width column."""
        if self.n == 0:
            return 0
        w = self.widths.reshape(-1).astype(np.int64)
        c = self.counts.astype(np.int64)
        return int((-(-(w * c) // 8)).sum()) + self.n_blocks

    @property
    def device_bytes(self) -> int:
        """Bytes actually shipped to device (incl. padding + metadata)."""
        return (self.widths.nbytes + self.data.nbytes
                + self.counts.nbytes + self.bases.nbytes)

    @property
    def bits_per_int(self) -> float:
        return 8.0 * self.payload_bytes / max(self.n, 1)


def encode_blocked(
    values: np.ndarray | None = None,
    *,
    block_size: int = 128,
    differential: bool = False,
    stride_multiple: int = 128,
    min_stride: int | None = None,
    wrap: bool = False,
    meta=None,
) -> BinpackEncoding:
    """Encode ``values`` into the blocked binpack layout.

    Same block semantics as ``encode.encode_blocked``: with
    ``differential=True`` the gaps are packed and ``bases[b]`` holds the
    absolute value preceding block ``b``. ``meta`` accepts a pre-computed
    :class:`~repro.core.vbyte.encode.BlockedMeta` (the shared single-pass
    metadata the index builder reuses across the encode → skip-table path).
    """
    from .encode import prepare_blocked

    if meta is None:
        meta = prepare_blocked(values, block_size=block_size,
                               differential=differential, wrap=wrap)
    block_size, differential = meta.block_size, meta.differential
    grid = np.zeros((meta.n_blocks * block_size,), np.uint64)
    grid[: meta.n] = meta.enc_values
    grid = grid.reshape(meta.n_blocks, block_size)
    widths = block_widths(grid, meta.counts)
    data = pack_blocked_data(grid, widths, stride_multiple=stride_multiple,
                             min_stride=min_stride)
    return BinpackEncoding(
        widths=widths[:, None],
        data=data,
        counts=meta.counts,
        bases=meta.bases,
        n=meta.n,
        block_size=block_size,
        differential=differential,
    )


def encode_ragged_blocked(
    lists,
    *,
    block_size: int = 128,
    differential: bool = False,
    stride_multiple: int = 128,
    min_stride: int | None = None,
    wrap: bool = False,
) -> BinpackEncoding:
    """Encode ragged id bags: block b holds list b (≤ block_size ids).

    Binpack twin of ``encode.encode_ragged_blocked`` — the same
    one-bag-per-block layout for the fused epilogues, each bag packed at
    its own max width.
    """
    from .encode import ragged_block_values

    vpad, counts = ragged_block_values(
        lists, block_size=block_size, differential=differential, wrap=wrap)
    # zero the padded slots so they cannot inflate the block width
    vpad = vpad * (np.arange(block_size)[None, :] < counts[:, None])
    widths = block_widths(vpad, counts)
    data = pack_blocked_data(vpad, widths, stride_multiple=stride_multiple,
                             min_stride=min_stride)
    return BinpackEncoding(
        widths=widths[:, None],
        data=data,
        counts=counts,
        bases=np.zeros(vpad.shape[0], np.uint32),
        n=int(counts.sum()),
        block_size=block_size,
        differential=differential,
        ragged=True,
    )


def decode_block_scalar(data_row: np.ndarray, width: int, count: int, *,
                        differential: bool = False, base: int = 0
                        ) -> np.ndarray:
    """Scalar oracle for one block: bit-at-a-time unpack of ``count`` values."""
    out = np.zeros(count, np.uint64)
    prev = np.uint64(base)
    for j in range(count):
        x = np.uint64(0)
        for k in range(width):
            bitpos = j * width + k
            bit = (int(data_row[bitpos >> 3]) >> (bitpos & 7)) & 1
            x |= np.uint64(bit) << np.uint64(k)
        if differential:
            prev = np.uint64((prev + x) & np.uint64(0xFFFFFFFF))
            out[j] = prev
        else:
            out[j] = x
    return out


def decode_blocked_scalar(widths: np.ndarray, data: np.ndarray,
                          counts: np.ndarray, bases: np.ndarray,
                          block_size: int, *, differential: bool
                          ) -> np.ndarray:
    """Oracle for the blocked layout: [n_blocks, block_size] uint64."""
    nb = data.shape[0]
    w = np.asarray(widths).reshape(-1)
    out = np.zeros((nb, block_size), np.uint64)
    for b in range(nb):
        c = int(counts[b])
        out[b, :c] = decode_block_scalar(
            data[b], int(w[b]), c, differential=differential,
            base=int(bases[b]))
    return out
