"""Scalar reference decoders — the paper's Algorithm 1 (conventional VByte).

``decode_stream_scalar`` is the pure-python/numpy oracle used by every test.
``decode_stream_scalar_jax`` is the same algorithm as a ``lax.while_loop`` —
branch-per-byte with a loop-carried dependence, so XLA cannot vectorize it.
It is the faithful "conventional decoder" baseline the paper measures MASKED
VBYTE against (§V), and it is what our benchmarks compare the vectorized
decoder to.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax


def decode_stream_scalar(data: np.ndarray, n: int, *, differential: bool = False,
                         base: int = 0) -> np.ndarray:
    """Decode ``n`` integers from a VByte byte stream (Algorithm 1)."""
    data = np.asarray(data, dtype=np.uint8)
    out = np.zeros(n, dtype=np.uint64)
    i = 0
    prev = np.uint64(base)
    for j in range(n):
        x = np.uint64(0)
        shift = np.uint64(0)
        while True:
            b = np.uint64(data[i])
            i += 1
            x |= (b & np.uint64(0x7F)) << shift
            if b < 128:
                break
            shift += np.uint64(7)
        if differential:
            prev = np.uint64((prev + x) & np.uint64(0xFFFFFFFF))
            out[j] = prev
        else:
            # 32-bit lanes like the paper: a 5-byte stream with >32 payload
            # bits wraps mod 2^32, matching every vectorized decoder
            out[j] = x & np.uint64(0xFFFFFFFF)
    return out


def consumed_bytes(data: np.ndarray, n: int) -> int:
    """Bytes consumed decoding the first ``n`` integers of a stream."""
    data = np.asarray(data, dtype=np.uint8)
    seen = 0
    for i, b in enumerate(data):
        if b < 128:
            seen += 1
            if seen == n:
                return i + 1
    if n == 0:
        return 0
    raise ValueError("stream ended before n integers were decoded")


def decode_stream_scalar_jax(data: jax.Array, n_max: int, *, differential: bool = False,
                             base=0, nbytes=None):
    """Algorithm 1 as a jax while_loop: one byte per iteration, fully serial.

    Returns ``(out[n_max] uint32, n_decoded)``. Fixed-shape: decodes at most
    ``n_max`` integers or until the stream is exhausted.
    """
    data = data.astype(jnp.uint32)
    nbytes = data.shape[0] if nbytes is None else jnp.asarray(nbytes, jnp.int32)

    def cond(state):
        i, j, _, _, _, _ = state
        return jnp.logical_and(i < nbytes, j < n_max)

    def body(state):
        i, j, acc, shift, prev, out = state
        b = data[i]
        acc = acc | ((b & 0x7F) << shift)
        done = b < 128
        value = jnp.where(differential, prev + acc, acc)
        out = jnp.where(done, out.at[j].set(value), out)
        prev = jnp.where(done, value, prev)
        j = j + done.astype(jnp.int32)
        acc = jnp.where(done, 0, acc)
        shift = jnp.where(done, 0, shift + 7)
        return (i + 1, j, acc, shift, prev, out)

    out0 = jnp.zeros((n_max,), jnp.uint32)
    state = (
        jnp.int32(0),
        jnp.int32(0),
        jnp.uint32(0),
        jnp.uint32(0),
        jnp.uint32(base),
        out0,
    )
    _, j, _, _, _, out = lax.while_loop(cond, body, state)
    return out, j


def decode_blocked_scalar(payload: np.ndarray, counts: np.ndarray, bases: np.ndarray,
                          block_size: int, *, differential: bool) -> np.ndarray:
    """Oracle for the blocked layout: [n_blocks, block_size] uint64, zero-padded."""
    n_blocks = payload.shape[0]
    out = np.zeros((n_blocks, block_size), dtype=np.uint64)
    for b in range(n_blocks):
        c = int(counts[b])
        out[b, :c] = decode_stream_scalar(
            payload[b], c, differential=differential, base=int(bases[b])
        )
    return out
