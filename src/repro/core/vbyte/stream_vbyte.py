"""Host-side Stream VByte encoding (numpy, vectorized).

Implements the format of Lemire, Kurz & Rupp, *Stream VByte: Faster
Byte-Oriented Integer Compression* — the successor to the VByte format this
repo reproduces. Classic VByte interleaves length information (continuation
bits) with payload bits, so a decoder must scan byte-by-byte to find integer
boundaries; that scan is exactly what the Masked-VByte paper spends its SIMD
machinery recovering from. Stream VByte removes the scan at the *format*
level instead: lengths move into a separate **control stream** of 2-bit
codes (``code = encoded_bytes - 1``, four codes per control byte, packed
LSB-first), and the **data stream** holds each integer's 1–4 little-endian
payload bytes back to back, with all 8 bits of every byte carrying payload.

Two layouts are produced, mirroring ``encode.py``:

* **stream**: ``(control uint8[ceil(n/4)], data uint8[sum(lengths)])``.
* **blocked**: fixed-shape SPMD layout — ``block_size`` integers per block
  (``block_size % 4 == 0`` so control bytes never straddle blocks), control
  ``[n_blocks, block_size // 4]``, data padded to a common ``data_stride``,
  plus per-block ``counts``/``bases`` exactly like ``BlockedEncoding``.

Encoding is vectorized: no python loop over integers.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

MAX_BYTES_PER_INT = 4  # 32-bit integers need at most 4 whole bytes
_LEN_THRESHOLDS = np.array([1 << 8, 1 << 16, 1 << 24], dtype=np.uint64)


def svb_lengths(values: np.ndarray) -> np.ndarray:
    """Number of encoded data bytes for each value (1..4)."""
    v = np.asarray(values, dtype=np.uint64)
    return (np.searchsorted(_LEN_THRESHOLDS, v, side="right") + 1).astype(np.int64)


def _byte_matrix(values: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Return ([n, 4] uint8 little-endian byte matrix, [n] lengths)."""
    v = np.asarray(values, dtype=np.uint64)
    if v.ndim != 1:
        raise ValueError(f"expected 1-D values, got shape {v.shape}")
    if v.size and int(v.max()) > 0xFFFFFFFF:
        raise ValueError("Stream VByte encoder supports 32-bit unsigned integers")
    lengths = svb_lengths(v)
    shifts = np.arange(MAX_BYTES_PER_INT, dtype=np.uint64) * np.uint64(8)
    data = ((v[:, None] >> shifts[None, :]) & np.uint64(0xFF)).astype(np.uint8)
    return data, lengths


def pack_control(codes: np.ndarray) -> np.ndarray:
    """Pack 2-bit codes (0..3) into control bytes, 4 per byte, LSB-first.

    ``len(codes)`` must be a multiple of 4 (pad with zeros first).
    """
    c = np.asarray(codes, dtype=np.uint8)
    if c.size % 4:
        raise ValueError("pad codes to a multiple of 4 before packing")
    q = c.reshape(-1, 4)
    return (q[:, 0] | (q[:, 1] << 2) | (q[:, 2] << 4) | (q[:, 3] << 6)).astype(np.uint8)


def unpack_control(control: np.ndarray, n: int) -> np.ndarray:
    """Inverse of :func:`pack_control`: first ``n`` 2-bit codes."""
    c = np.asarray(control, dtype=np.uint8)
    shifts = np.arange(4, dtype=np.uint8) * np.uint8(2)
    codes = ((c[:, None] >> shifts[None, :]) & np.uint8(3)).reshape(-1)
    return codes[:n].astype(np.int64)


def encode_stream(values: np.ndarray, *, wrap: bool = False) -> tuple[np.ndarray, np.ndarray]:
    """Encode to the paper's two tight streams: ``(control, data)``."""
    from .encode import validate_u32

    data, lengths = _byte_matrix(validate_u32(values, wrap=wrap))
    n = data.shape[0]
    codes = np.zeros(-(-max(n, 1) // 4) * 4, dtype=np.uint8)
    codes[:n] = (lengths - 1).astype(np.uint8)
    control = pack_control(codes)[: -(-n // 4)] if n else np.zeros(0, np.uint8)
    keep = np.arange(MAX_BYTES_PER_INT)[None, :] < lengths[:, None]
    return control, data[keep]


def decode_stream_scalar(control: np.ndarray, data: np.ndarray, n: int, *,
                         differential: bool = False, base: int = 0) -> np.ndarray:
    """Scalar oracle: decode ``n`` integers from (control, data) streams."""
    control = np.asarray(control, dtype=np.uint8)
    data = np.asarray(data, dtype=np.uint8)
    out = np.zeros(n, dtype=np.uint64)
    off = 0
    prev = np.uint64(base)
    for j in range(n):
        code = (int(control[j // 4]) >> (2 * (j % 4))) & 3
        length = code + 1
        x = np.uint64(0)
        for k in range(length):
            x |= np.uint64(data[off + k]) << np.uint64(8 * k)
        off += length
        if differential:
            prev = np.uint64((prev + x) & np.uint64(0xFFFFFFFF))
            out[j] = prev
        else:
            out[j] = x
    return out


@dataclass(frozen=True)
class StreamVByteEncoding:
    """Fixed-shape blocked Stream-VByte encoding (see module docstring)."""

    control: np.ndarray  # uint8 [n_blocks, block_size // 4]
    data: np.ndarray  # uint8 [n_blocks, data_stride]
    counts: np.ndarray  # int32 [n_blocks] — valid integers per block
    bases: np.ndarray  # uint32 [n_blocks] — differential carry-in (0 if not differential)
    n: int  # total integers
    block_size: int
    differential: bool
    ragged: bool = False  # one independent list (bag) per block

    @property
    def n_blocks(self) -> int:
        return self.control.shape[0]

    @property
    def stride(self) -> int:
        return self.data.shape[1]

    @property
    def payload_bytes(self) -> int:
        """Tight compressed size: data bytes + control bytes (no padding)."""
        if self.n == 0:
            return 0
        shifts = np.arange(4, dtype=np.uint8) * np.uint8(2)
        codes = (self.control[:, :, None] >> shifts) & np.uint8(3)
        codes = codes.reshape(self.n_blocks, self.block_size).astype(np.int64)
        valid = np.arange(self.block_size)[None, :] < self.counts[:, None]
        data_bytes = int(((codes + 1) * valid).sum())
        control_bytes = int((-(-self.counts.astype(np.int64) // 4)).sum())
        return data_bytes + control_bytes

    @property
    def device_bytes(self) -> int:
        """Bytes actually shipped to device (incl. padding + metadata)."""
        return (self.control.nbytes + self.data.nbytes
                + self.counts.nbytes + self.bases.nbytes)

    @property
    def bits_per_int(self) -> float:
        return 8.0 * self.payload_bytes / max(self.n, 1)


def encode_blocked(
    values: np.ndarray | None = None,
    *,
    block_size: int = 128,
    differential: bool = False,
    stride_multiple: int = 128,
    min_stride: int | None = None,
    wrap: bool = False,
    meta=None,
) -> StreamVByteEncoding:
    """Encode ``values`` into the blocked Stream-VByte layout.

    Same block semantics as ``encode.encode_blocked``: with
    ``differential=True`` the gaps are encoded and ``bases[b]`` holds the
    absolute value preceding block ``b``, so every block decodes
    independently. ``meta`` accepts a pre-computed
    :class:`~repro.core.vbyte.encode.BlockedMeta` (single shared metadata
    pass across the builder's encode → skip-table path).
    """
    from .encode import prepare_blocked, scatter_blocked_payload

    if meta is None:
        meta = prepare_blocked(values, block_size=block_size,
                               differential=differential, wrap=wrap)
    block_size, differential = meta.block_size, meta.differential
    if block_size % 4:
        raise ValueError(f"block_size={block_size} must be a multiple of 4")
    n, n_blocks = meta.n, meta.n_blocks
    data_mat, lengths = _byte_matrix(meta.enc_values)

    # control stream: codes padded with 0 for tail slots, 4 codes per byte
    codes = np.zeros(n_blocks * block_size, dtype=np.uint8)
    codes[:n] = (lengths - 1).astype(np.uint8)
    control = pack_control(codes).reshape(n_blocks, block_size // 4)

    # data stream: dense bytes per block, padded to a common stride
    data = scatter_blocked_payload(
        data_mat,
        lengths,
        n_blocks=n_blocks,
        block_size=block_size,
        max_bytes=MAX_BYTES_PER_INT,
        stride_multiple=stride_multiple,
        min_stride=min_stride,
    )

    return StreamVByteEncoding(
        control=control,
        data=data,
        counts=meta.counts,
        bases=meta.bases,
        n=n,
        block_size=block_size,
        differential=differential,
    )


def encode_ragged_blocked(
    lists,
    *,
    block_size: int = 128,
    differential: bool = False,
    stride_multiple: int = 128,
    min_stride: int | None = None,
    wrap: bool = False,
) -> StreamVByteEncoding:
    """Encode ragged id bags: block b holds list b (≤ block_size ids).

    Stream-VByte twin of ``encode.encode_ragged_blocked`` — same one-bag-
    per-block layout for the fused bag-sum/dot-score epilogues, with the
    lengths in the control stream (pad slots get code 0; masking by
    ``counts`` is load-bearing as everywhere else).
    """
    if block_size % 4:
        raise ValueError(f"block_size={block_size} must be a multiple of 4")
    from .encode import ragged_block_values, scatter_blocked_payload

    vpad, counts = ragged_block_values(
        lists, block_size=block_size, differential=differential, wrap=wrap)
    n_lists = vpad.shape[0]
    data_mat, lengths = _byte_matrix(vpad.reshape(-1))
    lengths = lengths.reshape(n_lists, block_size)
    pad_slot = np.arange(block_size)[None, :] >= counts[:, None]
    lengths[pad_slot] = 0

    codes = (np.maximum(lengths, 1) - 1).astype(np.uint8)  # pad slots: code 0
    control = pack_control(codes.reshape(-1)).reshape(n_lists, block_size // 4)
    data = scatter_blocked_payload(
        data_mat,
        lengths.reshape(-1),
        n_blocks=n_lists,
        block_size=block_size,
        max_bytes=MAX_BYTES_PER_INT,
        stride_multiple=stride_multiple,
        min_stride=min_stride,
    )
    return StreamVByteEncoding(
        control=control,
        data=data,
        counts=counts,
        bases=np.zeros(n_lists, dtype=np.uint32),
        n=int(counts.sum()),
        block_size=block_size,
        differential=differential,
        ragged=True,
    )


def decode_blocked_scalar(control: np.ndarray, data: np.ndarray, counts: np.ndarray,
                          bases: np.ndarray, block_size: int, *,
                          differential: bool) -> np.ndarray:
    """Oracle for the blocked layout: [n_blocks, block_size] uint64, zero-padded."""
    n_blocks = control.shape[0]
    out = np.zeros((n_blocks, block_size), dtype=np.uint64)
    for b in range(n_blocks):
        c = int(counts[b])
        out[b, :c] = decode_stream_scalar(
            control[b], data[b], c, differential=differential, base=int(bases[b])
        )
    return out
