from . import encode, masked, ref  # noqa: F401
from .encode import (  # noqa: F401
    BlockedEncoding,
    delta_decode,
    delta_encode,
    encode_blocked,
    encode_stream,
    vbyte_lengths,
)
