from . import (  # noqa: F401
    binpack,
    binpack_masked,
    encode,
    masked,
    ref,
    stream_masked,
    stream_vbyte,
)
from .binpack import (  # noqa: F401
    BinpackEncoding,
    bit_widths,
)
from .encode import (  # noqa: F401
    BlockedEncoding,
    BlockedMeta,
    delta_decode,
    delta_encode,
    encode_blocked,
    encode_stream,
    prepare_blocked,
    vbyte_lengths,
)
from .stream_vbyte import (  # noqa: F401
    StreamVByteEncoding,
    svb_lengths,
)
