from . import encode, masked, ref, stream_masked, stream_vbyte  # noqa: F401
from .encode import (  # noqa: F401
    BlockedEncoding,
    delta_decode,
    delta_encode,
    encode_blocked,
    encode_stream,
    vbyte_lengths,
)
from .stream_vbyte import (  # noqa: F401
    StreamVByteEncoding,
    svb_lengths,
)
