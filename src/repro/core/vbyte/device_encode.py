"""Device-side vectorized VByte *encoder* (pure jnp).

The inverse of the masked decoder, with the same branch-free structure:
per-value byte lengths from threshold compares (the decoder's continuation
mask, run backwards), destination offsets from a prefix sum, and a
scatter-set of payload bytes. Used for on-device checkpoint compression and
re-encoding pipelines; the host path (``encode.py``, numpy) remains the
bulk-ingest tool.

Emits the blocked layout directly: uint8[n_blocks, stride] + counts + bases.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_U32 = jnp.uint32
_THRESH = (1 << 7, 1 << 14, 1 << 21, 1 << 28)


def vbyte_lengths_device(values: jax.Array) -> jax.Array:
    """Encoded byte count per value (1..5), vectorized."""
    v = values.astype(_U32)
    n = jnp.ones(v.shape, jnp.int32)
    for t in _THRESH:
        n = n + (v >= _U32(t)).astype(jnp.int32)
    return n


@functools.partial(jax.jit, static_argnames=("block_size", "stride", "differential"))
def encode_blocked_device(
    values: jax.Array,  # uint32[n] (n % block_size == 0, pad with zeros)
    *,
    block_size: int = 128,
    stride: int = 640,  # must fit the worst block: block_size * 5
    differential: bool = False,
) -> dict:
    """Encode to the blocked layout on device.

    Returns {"payload": u8[nb, stride], "counts": i32[nb], "bases": u32[nb]}
    — bit-compatible with the host encoder given the same stride, and
    round-trippable through every decoder in this package.
    """
    n = values.shape[0]
    assert n % block_size == 0, (n, block_size)
    nb = n // block_size
    v = values.astype(_U32).reshape(nb, block_size)

    if differential:
        first = v[:, :1]
        gaps = jnp.concatenate([first, v[:, 1:] - v[:, :-1]], axis=1)
        prev_last = jnp.concatenate([jnp.zeros((1,), _U32), v[:-1, -1]])
        gaps = gaps.at[:, 0].set(v[:, 0] - prev_last)  # cross-block delta
        bases = prev_last
        enc = gaps
    else:
        enc = v
        bases = jnp.zeros((nb,), _U32)

    lengths = vbyte_lengths_device(enc)  # [nb, B]
    offs = jnp.cumsum(lengths, axis=1) - lengths  # byte offset per value

    # payload byte k of value j: (enc >> 7k) & 0x7F, continuation bit if k<len-1
    k = jnp.arange(5, dtype=jnp.int32)
    chunks = (enc[..., None] >> (7 * k).astype(_U32)) & _U32(0x7F)  # [nb, B, 5]
    cont = (k[None, None] < lengths[..., None] - 1).astype(_U32) << _U32(7)
    data = (chunks | cont).astype(jnp.uint8)
    used = k[None, None] < lengths[..., None]

    dst = offs[..., None] + k[None, None]  # [nb, B, 5]
    dst = jnp.where(used, dst, stride)  # drop unused slots
    row = jnp.arange(nb, dtype=jnp.int32)[:, None, None]
    flat = (row * (stride + 1) + jnp.minimum(dst, stride)).reshape(-1)
    payload = jnp.zeros((nb * (stride + 1),), jnp.uint8).at[flat].set(
        data.reshape(-1), mode="drop", unique_indices=True)
    payload = payload.reshape(nb, stride + 1)[:, :stride]

    counts = jnp.full((nb,), block_size, jnp.int32)
    return {"payload": payload, "counts": counts, "bases": bases}
