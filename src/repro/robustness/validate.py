"""Stream validation: typed decode errors, host validators, checked decode.

Every decoder in the repo — scalar oracle, jnp grids, both Pallas kernels —
is branch-free arithmetic over whatever bytes it is handed: a truncated
payload, a flipped continuation bit, or a corrupted Stream VByte control
byte produces *defined garbage*, never a crash. That is the right contract
for the kernels (the paper's §2 point is that lengths are data-dependent, so
the fast path cannot afford to branch on malformed input), but it means
corruption flows silently into skip tables, BM25 scores and served results.
This module is the detection layer on top:

* **Error taxonomy** — :class:`DecodeError` subclasses carrying
  ``format``/``block``/``term`` coordinates, so a failing segment can be
  quarantined instead of taking the whole index down
  (``repro.launch.serve``).
* **Host validators** — :func:`validate_structure` (block metadata),
  :func:`validate_stream` (per-block byte-level format checks: truncation,
  overlong continuation runs, non-canonical encodings, control/data length
  mismatches), :func:`validate_meta` (skip-table monotonicity, ``df``,
  block-max ``max_impact`` invariants of a ``TermPostings``).
* **Checked decode** — :func:`decode_checked`: decode through the fused
  ``checksum`` epilogue and compare the per-block column written by
  ``CompressedIntArray.encode(checksum=True)``. The checksum
  ``cs[b] = Σ_j vals[b,j]·(2j+1) mod 2^32`` is verified in the same decode
  tile pass (one epilogue, no second HBM round-trip); the odd positional
  weights are invertible mod 2^32, so *any single-value corruption is
  always detected* (a change δ≠0 at slot j shifts the checksum by
  δ·(2j+1) ≠ 0).

Also home of :class:`Deadline`, the injectable-clock per-request budget the
query engine and serving layer check at strip/chunk boundaries.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.compressed_array import CompressedIntArray, block_checksums
from repro.core.vbyte import binpack as bpk
from repro.core.vbyte import stream_vbyte as svb


# ---------------------------------------------------------------------------
# error taxonomy
# ---------------------------------------------------------------------------
class DecodeError(ValueError):
    """A compressed stream (or its metadata) failed validation.

    Carries coordinates — ``format``, ``block`` (index within the stream's
    block dimension), ``term`` (owning posting-list term, when known) — so
    callers can quarantine the failing segment (docs/robustness.md).
    """

    def __init__(self, message: str, *, format: str | None = None,
                 block: int | None = None, term=None):
        self.format = format
        self.block = block
        self.term = term
        coords = [f"format={format!r}" if format else None,
                  f"block={block}" if block is not None else None,
                  f"term={term!r}" if term is not None else None]
        coords = ", ".join(c for c in coords if c)
        super().__init__(f"{message} [{coords}]" if coords else message)


class TruncatedPayloadError(DecodeError):
    """The payload ends before the block's ``counts`` integers terminate."""


class OverlongRunError(DecodeError):
    """A continuation run spans more than 5 bytes (no 32-bit terminator)."""


class NonCanonicalError(DecodeError):
    """A value is encoded in more bytes than the format requires."""


class ControlMismatchError(DecodeError):
    """Stream VByte control-claimed data length exceeds the data stride."""


class BlockMetaError(DecodeError):
    """Block metadata is inconsistent (counts, bases, skip table, bounds)."""


class BoundViolationError(BlockMetaError):
    """A block's ``max_impact`` understates its true impact max — the
    MaxScore pruning invariant. Pruning with an understated bound silently
    drops true top-k results, so the serving layer maps this error to an
    exhaustive-TAAT fallback (exact, just slower) instead of quarantine."""


class ChecksumError(DecodeError):
    """Decoded values disagree with the stored per-block checksum column."""


class WalError(DecodeError):
    """A write-ahead-log record is structurally invalid *mid-log*: a CRC or
    framing failure on a record that has durable data after it, or a
    replayed operation that contradicts index state. A torn *tail* (the
    one unacknowledged record a crash can legitimately shear) is not an
    error — the reader truncates it and recovers the acked prefix
    (docs/ingestion.md §WAL format)."""


class SegmentError(DecodeError):
    """A persisted index segment — or the manifest naming it — is missing,
    truncated, corrupt, or stale. Raised by :mod:`repro.index.ingest` at
    load when the whole-file CRC or per-term metadata disagrees with the
    bytes on disk, and when recovery cannot reconstruct a consistent
    segment set (an adopted-orphan candidate that is itself corrupt)."""


class CheckpointError(DecodeError):
    """A checkpoint step's ``manifest.json``/``leaves.npz`` is unreadable
    or internally inconsistent. ``restore_latest`` treats this as
    skip-to-previous-intact-step, not a crash
    (repro.checkpoint.manager)."""


# ---------------------------------------------------------------------------
# deadlines (used by repro.index.query and repro.launch.serve)
# ---------------------------------------------------------------------------
@dataclass
class Deadline:
    """A per-request time budget with an injectable clock.

    ``expired()`` is checked at work-unit boundaries (per decoded chunk /
    per term / per MaxScore strip) — work in flight always completes, so a
    deadline never yields a torn result, only a *smaller* one flagged
    ``degraded`` (docs/robustness.md §Degraded-mode semantics). ``clock``
    is injectable so tests expire deadlines deterministically.
    """

    budget_s: float
    clock: callable = time.monotonic
    start: float = field(default=None)  # type: ignore[assignment]
    hit: bool = False  # set once expired() first returns True

    def __post_init__(self):
        if self.start is None:
            self.start = self.clock()

    def expired(self) -> bool:
        if not self.hit and self.clock() - self.start >= self.budget_s:
            self.hit = True
        return self.hit

    def remaining(self) -> float:
        return max(0.0, self.budget_s - (self.clock() - self.start))


# ---------------------------------------------------------------------------
# host-side validators
# ---------------------------------------------------------------------------
def validate_structure(arr: CompressedIntArray, *, term=None) -> None:
    """Block-metadata invariants that need no byte-level decoding.

    Raises :class:`BlockMetaError` when ``counts`` fall outside
    ``[0, block_size]``, when they don't sum to ``n``, or when ``bases``
    are nonzero for a non-differential (or ragged) stream.
    """
    fmt = arr.format
    counts = np.asarray(arr.counts)
    bad = np.flatnonzero((counts < 0) | (counts > arr.block_size))
    if bad.size:
        raise BlockMetaError(
            f"count {int(counts[bad[0]])} outside [0, {arr.block_size}]",
            format=fmt, block=int(bad[0]), term=term)
    if int(counts.sum()) != arr.n:
        raise BlockMetaError(
            f"counts sum to {int(counts.sum())} but n={arr.n}",
            format=fmt, term=term)
    if not arr.differential or arr.ragged:
        bases = np.asarray(arr.bases)
        bad = np.flatnonzero(bases != 0)
        if bad.size:
            raise BlockMetaError(
                "nonzero base on a stream whose blocks are self-based",
                format=fmt, block=int(bad[0]), term=term)


def _validate_vbyte_block(p: np.ndarray, c: int, b: int, term) -> None:
    term_pos = np.flatnonzero(p < 128)
    if term_pos.size < c:
        # fewer terminator bytes than claimed integers — either the stream
        # was cut, or a flipped continuation bit merged two integers
        raise TruncatedPayloadError(
            f"payload holds {term_pos.size} terminated integers, "
            f"counts claim {c}", format="vbyte", block=b, term=term)
    ends = term_pos[:c]
    starts = np.concatenate(([0], ends[:-1] + 1))
    lens = ends - starts + 1
    bad = np.flatnonzero(lens > 5)
    if bad.size:
        raise OverlongRunError(
            f"integer {int(bad[0])} spans {int(lens[bad[0]])} bytes "
            "(max 5 for 32-bit values)", format="vbyte", block=b, term=term)
    top = p[ends].astype(np.int64)
    # multi-byte integer whose most-significant 7-bit group is zero would
    # fit in fewer bytes; a 5-byte integer with >4 payload bits in the top
    # group overflows 32 bits (the decoders wrap it mod 2^32)
    bad = np.flatnonzero(((lens > 1) & (top == 0))
                         | ((lens == 5) & (top > 0x0F)))
    if bad.size:
        j = int(bad[0])
        raise NonCanonicalError(
            f"integer {j} ({int(lens[j])} bytes, top group "
            f"{int(top[j]):#x}) is not canonically encoded",
            format="vbyte", block=b, term=term)


def _validate_svb_block(control: np.ndarray, data: np.ndarray, c: int,
                        b: int, term) -> None:
    lengths = svb.unpack_control(control, c) + 1
    total = int(lengths.sum())
    if total > data.shape[0]:
        raise ControlMismatchError(
            f"control stream claims {total} data bytes, stride is "
            f"{data.shape[0]}", format="streamvbyte", block=b, term=term)
    # canonical: the top claimed byte of every multi-byte integer must be
    # nonzero, else the control code overstates the length
    ends = np.cumsum(lengths) - 1
    top = data[ends].astype(np.int64)
    bad = np.flatnonzero((lengths > 1) & (top == 0))
    if bad.size:
        j = int(bad[0])
        raise NonCanonicalError(
            f"integer {j} ({int(lengths[j])} bytes) has a zero top byte — "
            "control code overstates its length",
            format="streamvbyte", block=b, term=term)


def _validate_binpack_block(w: int, data: np.ndarray, c: int, b: int,
                            term) -> None:
    if w > bpk.MAX_WIDTH:
        raise BlockMetaError(
            f"width byte {w} exceeds the 32-bit maximum",
            format="binpack", block=b, term=term)
    used = -(-(w * c) // 8)
    if used > data.shape[0]:
        raise TruncatedPayloadError(
            f"width {w} × {c} values needs {used} bytes, stride is "
            f"{data.shape[0]}", format="binpack", block=b, term=term)
    vals = bpk.decode_block_scalar(data, w, c)
    if w and int(bpk.bit_widths(vals).max(initial=0)) < w:
        raise NonCanonicalError(
            f"width byte claims {w} bits but the widest value fits in "
            f"{int(bpk.bit_widths(vals).max(initial=0))} — width is "
            "overstated", format="binpack", block=b, term=term)
    # canonical padding: bits of the last used byte past c·w must be zero
    tail_bits = (w * c) & 7
    if used and tail_bits and int(data[used - 1]) >> tail_bits:
        raise NonCanonicalError(
            f"nonzero padding bits above bit {w * c} in the last packed "
            "byte", format="binpack", block=b, term=term)


def validate_stream(arr: CompressedIntArray, *, term=None,
                    blocks=None) -> None:
    """Byte-level format validation of every (or the given) block.

    VByte: the block must hold ``counts[b]`` terminated integers
    (:class:`TruncatedPayloadError`), no continuation run may exceed 5
    bytes (:class:`OverlongRunError`), and every integer must be canonical
    (:class:`NonCanonicalError`). Stream VByte: the control-claimed data
    length must fit the data stride (:class:`ControlMismatchError`) and
    every multi-byte integer must use its claimed width
    (:class:`NonCanonicalError`). Binpack: the width byte must be ≤ 32
    (:class:`BlockMetaError`), the packed bits must fit the data stride
    (:class:`TruncatedPayloadError`), the width must be tight for the
    block's widest value, and the final partial byte's padding bits must
    be zero (:class:`NonCanonicalError` — the zero-padding canon makes a
    bit flip in the dead bits of a *used* byte detectable). Padding bytes
    beyond the last claimed integer are *not* checked — the decoders mask
    them, so their content is provably harmless.
    """
    counts = np.asarray(arr.counts)
    idx = range(counts.shape[0]) if blocks is None else blocks
    if arr.format == "vbyte":
        payload = np.asarray(arr.payload)
        for b in idx:
            c = int(counts[b])
            if c:
                _validate_vbyte_block(payload[b], c, int(b), term)
    elif arr.format == "binpack":
        widths = np.asarray(arr.widths).reshape(-1)
        data = np.asarray(arr.data)
        for b in idx:
            c = int(counts[b])
            if c:
                _validate_binpack_block(int(widths[b]), data[b], c,
                                        int(b), term)
    else:
        control = np.asarray(arr.control)
        data = np.asarray(arr.data)
        for b in idx:
            c = int(counts[b])
            if c:
                _validate_svb_block(control[b], data[b], c, int(b), term)


def validate_array(arr: CompressedIntArray, *, term=None) -> None:
    """Structure + stream validation (the serving layer's startup gate)."""
    validate_structure(arr, term=term)
    validate_stream(arr, term=term)


def validate_meta(tp, *, deep: bool = False) -> None:
    """Skip-table / impact invariants of one ``TermPostings``.

    Cheap checks: per-block ``first_doc <= last_doc``, strictly increasing
    across non-empty blocks (docids are sorted and unique), ``df`` equal to
    the stream's ``n``. With ``deep=True`` the postings and impacts are
    scalar-decoded and the skip table and ``max_impact`` column are checked
    against the actual block contents — in particular ``max_impact[b]``
    must bound block ``b``'s true impact max, the invariant MaxScore prunes
    with (a violated bound silently drops results, so the engine falls back
    to exhaustive TAAT when this raises — docs/robustness.md).
    """
    term = tp.term
    counts = np.asarray(tp.arr.counts)
    live = np.flatnonzero(counts > 0)
    first = np.asarray(tp.first_doc).astype(np.int64)
    last = np.asarray(tp.last_doc).astype(np.int64)
    bad = live[first[live] > last[live]]
    if bad.size:
        raise BlockMetaError(
            f"skip table first_doc {int(first[bad[0]])} > last_doc "
            f"{int(last[bad[0]])}", block=int(bad[0]), term=term)
    if live.size > 1:
        gap = np.flatnonzero(first[live][1:] <= last[live][:-1])
        if gap.size:
            b = int(live[gap[0] + 1])
            raise BlockMetaError(
                "skip table not monotone: first_doc[b] <= last_doc of the "
                "previous non-empty block", block=b, term=term)
    if tp.df != int(counts.sum()):
        raise BlockMetaError(
            f"df={tp.df} but posting blocks hold {int(counts.sum())} ids",
            term=term)
    if not deep:
        return
    grid = _scalar_grid(tp.arr)
    B = tp.arr.block_size
    valid = np.arange(B)[None, :] < counts[:, None]
    for b in live:
        docs = grid[b, : counts[b]]
        if int(docs[0]) != int(first[b]) or int(docs[-1]) != int(last[b]):
            raise BlockMetaError(
                f"skip table ({int(first[b])}, {int(last[b])}) disagrees "
                f"with decoded block range ({int(docs[0])}, "
                f"{int(docs[-1])})", block=int(b), term=term)
    if tp.impacts is not None and tp.max_impact is not None:
        imp = _scalar_grid(tp.impacts)
        actual = np.where(valid, imp, 0).max(axis=1).astype(np.int64)
        mi = np.asarray(tp.max_impact).astype(np.int64)
        bad = np.flatnonzero(mi < actual)
        if bad.size:
            b = int(bad[0])
            raise BoundViolationError(
                f"max_impact {int(mi[b])} < actual block max "
                f"{int(actual[b])} — MaxScore bounds are unsafe",
                block=b, term=term)


def _scalar_grid(arr: CompressedIntArray) -> np.ndarray:
    """Scalar-oracle decode to the padded block grid (host, trusted path)."""
    flat = arr.decode_scalar_oracle()
    counts = np.asarray(arr.counts)
    grid = np.zeros((counts.shape[0], arr.block_size), np.uint32)
    mask = np.arange(arr.block_size)[None, :] < counts[:, None]
    grid[mask] = flat
    return grid


# ---------------------------------------------------------------------------
# checksum-verified decode (the fused `checksum` epilogue's host half)
# ---------------------------------------------------------------------------
def decode_checked(arr: CompressedIntArray, *, plan="auto",
                   term=None) -> np.ndarray:
    """Decode to the ``uint32 [n_blocks, block_size]`` grid, verified.

    Runs the fused ``checksum`` epilogue — the decoded tile and its
    position-weighted per-block checksum come out of the *same* kernel pass
    — and compares against the column stored at encode time
    (``encode(checksum=True)``). Raises :class:`ChecksumError` with the
    first mismatching block. Works across the whole parity matrix
    (pallas/jnp × fused/unfused × dense/banded × sharded); on clean input
    the returned grid is bit-exact with ``decode_blocked``'s (same decode
    core, identity epilogue on the value path).

    Sharded arrays may carry more device blocks than checksum rows
    (``shard()`` pads the block dim with count-0 blocks, which checksum to
    0 by construction) — only stored rows are compared, padding rows must
    be 0.
    """
    from repro.kernels.vbyte_decode import dispatch

    if arr.checksums is None:
        raise ValueError(
            "array carries no checksum column — encode with checksum=True "
            "(or validate via validate_array/scalar re-decode instead)")
    vals, cs = dispatch.decode(arr, epilogue="checksum", plan=plan)
    cs = np.asarray(cs).reshape(-1).astype(np.uint32)
    stored = np.asarray(arr.checksums).reshape(-1).astype(np.uint32)
    k = min(stored.shape[0], cs.shape[0])
    bad = np.flatnonzero(cs[:k] != stored[:k])
    if bad.size == 0 and cs.shape[0] > k:
        bad = k + np.flatnonzero(cs[k:] != 0)  # shard-padding blocks
    if bad.size:
        b = int(bad[0])
        want = int(stored[b]) if b < k else 0
        raise ChecksumError(
            f"block checksum {int(cs[b]):#010x} != stored {want:#010x} "
            f"({bad.size} corrupt block(s))",
            format=arr.format, block=b, term=term)
    return np.asarray(vals).astype(np.int64).astype(np.uint32).reshape(
        cs.shape[0], arr.block_size)


def expected_checksums(arr: CompressedIntArray) -> np.ndarray:
    """Recompute the checksum column from a trusted scalar decode
    (tests/tools; the fast path is the fused epilogue above)."""
    return block_checksums(_scalar_grid(arr), np.asarray(arr.counts))
