"""Crash-consistent filesystem primitives (tmp + fsync + rename).

One implementation of the atomic-write protocol, shared by every layer
that persists state: :mod:`repro.checkpoint.manager` (train-state
checkpoints) and :mod:`repro.index.ingest` (index segments, the ingestion
manifest). The protocol is the classic POSIX one:

1. write the complete content under a temporary name in the *same*
   directory (same filesystem — rename must not degrade to copy),
2. flush + ``fsync`` the content so the bytes are durable before the name,
3. ``rename``/``replace`` onto the final name (atomic on POSIX: readers
   see either the old complete state or the new complete state, never a
   torn mix),
4. ``fsync`` the parent directory so the *name* survives a crash too.

A crash at any point leaves either the old state intact (tmp names are
ignored and garbage-collected by :func:`clean_tmp`) or the new state
complete. Nothing in between is ever visible under a final name — which
is exactly the invariant the recovery fuzz tests inject crashes to check
(docs/ingestion.md §Crash points).
"""
from __future__ import annotations

import json
import os
import shutil
import time
import zlib

TMP_PREFIX = ".tmp_"


def _tmp_name(final_path: str) -> str:
    d, base = os.path.split(os.path.abspath(final_path))
    return os.path.join(d, f"{TMP_PREFIX}{base}_{os.getpid()}_{time.time_ns()}")


def fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def fsync_dir(path: str) -> None:
    """Durably persist directory entries (created/renamed names)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def atomic_write_bytes(path: str, data: bytes, *, fsync: bool = True) -> None:
    """Atomically replace ``path`` with ``data`` (tmp + fsync + rename)."""
    tmp = _tmp_name(path)
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        if fsync:
            os.fsync(f.fileno())
    os.replace(tmp, path)
    if fsync:
        fsync_dir(os.path.dirname(os.path.abspath(path)))


def atomic_write_json(path: str, obj, *, fsync: bool = True) -> None:
    atomic_write_bytes(path, json.dumps(obj, indent=1).encode("utf-8"),
                       fsync=fsync)


def atomic_write_dir(final_dir: str, fill, *, fsync: bool = True) -> None:
    """Atomically materialize a directory: ``fill(tmp_dir)`` writes the
    complete content, then the tmp dir is fsynced file-by-file and renamed
    onto ``final_dir``. Used for checkpoint steps and index segments —
    partial writes never carry the final name.

    When ``final_dir`` already exists, POSIX offers no atomic non-empty
    directory swap: the old version is first renamed *away* to a tmp name,
    the new one renamed in, and only then is the old tree deleted. A crash
    in the (two-rename) window leaves no final name — readers that replace
    a live directory must tolerate its momentary absence by falling back
    to an older step (``CheckpointManager.restore_latest`` does); the old
    content is never deleted before the new name is durably in place."""
    tmp = _tmp_name(final_dir)
    os.makedirs(tmp)
    try:
        fill(tmp)
        if fsync:
            for root, _dirs, files in os.walk(tmp):
                for f in files:
                    fsync_file(os.path.join(root, f))
                fsync_dir(root)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    old = None
    if os.path.exists(final_dir):
        old = _tmp_name(final_dir)
        os.rename(final_dir, old)
    try:
        os.rename(tmp, final_dir)
    except BaseException:
        if old is not None:  # put the previous version back under its name
            os.rename(old, final_dir)
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    if fsync:
        fsync_dir(os.path.dirname(os.path.abspath(final_dir)))
    if old is not None:
        shutil.rmtree(old, ignore_errors=True)


def clean_tmp(directory: str) -> int:
    """Garbage-collect orphaned tmp files/dirs left by a crash mid-write.

    Safe at any time: tmp names are never referenced by a manifest or a
    final name, so removing them can only reclaim space. Returns the
    number of entries removed."""
    removed = 0
    try:
        entries = os.listdir(directory)
    except OSError:
        return 0
    for e in entries:
        if e.startswith(TMP_PREFIX):
            p = os.path.join(directory, e)
            if os.path.isdir(p):
                shutil.rmtree(p, ignore_errors=True)
            else:
                try:
                    os.remove(p)
                except OSError:
                    pass
            removed += 1
    return removed


def crc32_file(path: str) -> int:
    """Whole-file CRC32 — the cheap integrity stamp segment manifests
    store next to their npz payloads (detects truncation and bit rot
    deterministically at load; see repro.index.ingest)."""
    crc = 0
    with open(path, "rb") as f:
        while True:
            chunk = f.read(1 << 20)
            if not chunk:
                return crc & 0xFFFFFFFF
            crc = zlib.crc32(chunk, crc)
