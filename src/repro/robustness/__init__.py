"""Hardened decode: stream validation, fault injection, degraded serving.

Three layers (docs/robustness.md):

* :mod:`repro.robustness.validate` — the error taxonomy (typed
  :class:`DecodeError` subclasses carrying block/term coordinates), host-side
  stream/metadata validators for both formats, and checksum-verified decode
  (:func:`decode_checked`) riding the fused ``checksum`` epilogue.
* :mod:`repro.robustness.faultgen` — the seeded corruption generator driving
  the detect-or-defined-value property tests (tests/test_robustness.py).
* degraded-mode serving lives with the engines in ``repro.launch.serve``
  (quarantine, deadlines, retry, shard loss), built on these validators.
"""
from .validate import (  # noqa: F401
    BlockMetaError,
    BoundViolationError,
    ChecksumError,
    ControlMismatchError,
    Deadline,
    DecodeError,
    NonCanonicalError,
    OverlongRunError,
    TruncatedPayloadError,
    decode_checked,
    validate_array,
    validate_meta,
    validate_stream,
    validate_structure,
)
