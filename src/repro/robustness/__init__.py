"""Hardened decode: stream validation, fault injection, degraded serving.

Three layers (docs/robustness.md):

* :mod:`repro.robustness.validate` — the error taxonomy (typed
  :class:`DecodeError` subclasses carrying block/term coordinates), host-side
  stream/metadata validators for both formats, and checksum-verified decode
  (:func:`decode_checked`) riding the fused ``checksum`` epilogue.
* :mod:`repro.robustness.faultgen` — the seeded corruption generator driving
  the detect-or-defined-value property tests (tests/test_robustness.py).
* :mod:`repro.robustness.atomic_io` — the one crash-consistent write
  protocol (tmp + fsync + rename) shared by checkpoints and index
  segments, so durability is tested in a single place.
* degraded-mode serving lives with the engines in ``repro.launch.serve``
  (quarantine, deadlines, retry, shard loss), built on these validators.
"""
from .atomic_io import (  # noqa: F401
    atomic_write_bytes,
    atomic_write_dir,
    atomic_write_json,
    clean_tmp,
    crc32_file,
    fsync_dir,
)
from .validate import (  # noqa: F401
    BlockMetaError,
    BoundViolationError,
    CheckpointError,
    ChecksumError,
    ControlMismatchError,
    Deadline,
    DecodeError,
    NonCanonicalError,
    OverlongRunError,
    SegmentError,
    TruncatedPayloadError,
    WalError,
    decode_checked,
    validate_array,
    validate_meta,
    validate_stream,
    validate_structure,
)
