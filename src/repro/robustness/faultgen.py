"""Seeded corruption generator for the robustness property tests.

Every corruption class takes a *clean* ``CompressedIntArray`` (typically
encoded with ``checksum=True``) and a seed, and returns a
:class:`Corruption` — the corrupted array plus the coordinates of what was
broken — or ``None`` when the class doesn't apply to the array (e.g.
``continuation_flip`` on Stream VByte, ``base_corrupt`` on a
non-differential stream). Corruptions only ever touch *used* bytes (bytes
the decoder actually consumes for the claimed ``counts``) — flipping
padding is provably harmless by the masking contract and tells the tests
nothing.

The test contract (tests/test_robustness.py) for every class × format ×
plan is **detect-or-defined-value**:

* *detected* — ``validate_structure``/``validate_stream``/``decode_checked``
  raises a typed :class:`~repro.robustness.validate.DecodeError` subclass,
  or
* *provably harmless* — with checksums disabled, every vectorized plan
  decodes the corrupted stream to the same defined value (no crash, dense
  and banded bit-identical), so serving can degrade instead of dying.

Index-level corruptions (skip table, ``max_impact`` bound, impact payload)
operate on a ``TermPostings`` and return a replaced copy; whole-shard loss
is injected at the serving layer (``SearchEngine.kill_shard``).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Callable

import numpy as np

from repro.core.compressed_array import CompressedIntArray
from repro.core.vbyte import ref as vref
from repro.core.vbyte import stream_vbyte as svb


@dataclass(frozen=True)
class Corruption:
    """One injected fault: the corrupted array + what/where."""

    arr: CompressedIntArray
    cls: str
    block: int
    detail: str


def _leaf(arr: CompressedIntArray, name: str) -> np.ndarray:
    return np.array(np.asarray(getattr(arr, name)))  # writable copy


def _rebuild(arr: CompressedIntArray, **leaves) -> CompressedIntArray:
    return replace(arr, host_enc=None, **leaves)


def _pick_block(arr: CompressedIntArray, rng: np.random.Generator) -> int:
    """A block with at least one claimed integer (corrupting an empty
    block's padding is harmless by construction)."""
    live = np.flatnonzero(np.asarray(arr.counts) > 0)
    if live.size == 0:
        raise ValueError("array has no non-empty block to corrupt")
    return int(rng.choice(live))


def _used_bytes(arr: CompressedIntArray, b: int) -> int:
    """Bytes the decoder consumes in block ``b`` for the claimed count."""
    c = int(np.asarray(arr.counts)[b])
    if arr.format == "vbyte":
        return vref.consumed_bytes(np.asarray(arr.payload)[b], c)
    if arr.format == "binpack":
        w = int(np.asarray(arr.widths).reshape(-1)[b])
        return max(-(-(w * c) // 8), 1)  # ≥1 so bit_flip/byte_drop apply
    lengths = svb.unpack_control(np.asarray(arr.control)[b], c) + 1
    return int(lengths.sum())


# --- stream-level corruption classes ---------------------------------------
def _bit_flip(arr, rng):
    b = _pick_block(arr, rng)
    name = "payload" if arr.format == "vbyte" else "data"
    leaf = _leaf(arr, name)
    i = int(rng.integers(_used_bytes(arr, b)))
    bit = int(rng.integers(8))
    leaf[b, i] ^= 1 << bit
    return Corruption(_rebuild(arr, **{name: leaf}), "bit_flip", b,
                      f"{name}[{b},{i}] ^= 1<<{bit}")


def _byte_drop(arr, rng):
    # drop one used byte: the tail shifts left, the last byte pads with 0 —
    # models a short read / lost byte mid-segment
    b = _pick_block(arr, rng)
    name = "payload" if arr.format == "vbyte" else "data"
    leaf = _leaf(arr, name)
    used = _used_bytes(arr, b)
    i = int(rng.integers(used))
    leaf[b, i:-1] = leaf[b, i + 1:]
    leaf[b, -1] = 0
    return Corruption(_rebuild(arr, **{name: leaf}), "byte_drop", b,
                      f"{name}[{b},{i}] dropped, tail shifted")


def _payload_truncate(arr, rng):
    # vbyte-only: turn the tail of the used region into an unterminated
    # continuation run, as if the stream were cut mid-integer
    if arr.format != "vbyte":
        return None
    b = _pick_block(arr, rng)
    leaf = _leaf(arr, "payload")
    used = _used_bytes(arr, b)
    i = int(rng.integers(max(used - 2, 0), used))
    leaf[b, i:] = 0xFF
    return Corruption(_rebuild(arr, payload=leaf), "payload_truncate", b,
                      f"payload[{b},{i}:] = 0xFF (no terminator)")


def _continuation_flip(arr, rng):
    if arr.format != "vbyte":
        return None
    b = _pick_block(arr, rng)
    leaf = _leaf(arr, "payload")
    i = int(rng.integers(_used_bytes(arr, b)))
    leaf[b, i] ^= 0x80
    return Corruption(_rebuild(arr, payload=leaf), "continuation_flip", b,
                      f"payload[{b},{i}] continuation bit flipped")


def _control_corrupt(arr, rng):
    if arr.format != "streamvbyte":
        return None
    b = _pick_block(arr, rng)
    c = int(np.asarray(arr.counts)[b])
    leaf = _leaf(arr, "control")
    i = int(rng.integers(-(-c // 4)))  # a control byte with live codes
    leaf[b, i] ^= int(rng.integers(1, 256))
    return Corruption(_rebuild(arr, control=leaf), "control_corrupt", b,
                      f"control[{b},{i}] xored")


def _count_over(arr, rng):
    b = _pick_block(arr, rng)
    counts = _leaf(arr, "counts")
    if int(counts[b]) >= arr.block_size:
        counts[b] = arr.block_size  # keep in range; sum mismatch remains
        counts[(b + 1) % counts.shape[0]] += 1
    else:
        counts[b] += 1
    return Corruption(_rebuild(arr, counts=counts), "count_over", b,
                      f"counts[{b}] inflated (sum != n)")


def _count_under(arr, rng):
    b = _pick_block(arr, rng)
    counts = _leaf(arr, "counts")
    counts[b] -= 1
    return Corruption(_rebuild(arr, counts=counts), "count_under", b,
                      f"counts[{b}] deflated (sum != n)")


def _base_corrupt(arr, rng):
    if not arr.differential or arr.ragged:
        return None
    counts = np.asarray(arr.counts)
    live = np.flatnonzero(counts > 0)
    live = live[live > 0]  # block 0's base is 0 by convention
    if live.size == 0:
        return None
    b = int(rng.choice(live))
    bases = _leaf(arr, "bases")
    bases[b] ^= np.uint32(1 << int(rng.integers(31)))
    return Corruption(_rebuild(arr, bases=bases), "base_corrupt", b,
                      f"bases[{b}] bit-flipped")


def _width_inflate(arr, rng):
    # binpack-only: overstate a block's width byte by one — the decoder
    # reads shifted garbage; the validator's tight-width canon catches it
    if arr.format != "binpack":
        return None
    b = _pick_block(arr, rng)
    widths = _leaf(arr, "widths")
    if int(widths[b, 0]) >= 32:
        return None
    widths[b, 0] += 1
    return Corruption(_rebuild(arr, widths=widths), "width_inflate", b,
                      f"widths[{b}] inflated by 1")


def _width_deflate(arr, rng):
    # binpack-only: understate the width — values alias into each other
    if arr.format != "binpack":
        return None
    ws = np.asarray(arr.widths).reshape(-1)
    live = np.flatnonzero((np.asarray(arr.counts) > 0) & (ws > 0))
    if live.size == 0:
        return None
    b = int(rng.choice(live))
    widths = _leaf(arr, "widths")
    widths[b, 0] -= 1
    return Corruption(_rebuild(arr, widths=widths), "width_deflate", b,
                      f"widths[{b}] deflated by 1")


def _width_range(arr, rng):
    # binpack-only: width byte outside [0, 32] entirely
    if arr.format != "binpack":
        return None
    b = _pick_block(arr, rng)
    widths = _leaf(arr, "widths")
    widths[b, 0] = 200
    return Corruption(_rebuild(arr, widths=widths), "width_range", b,
                      f"widths[{b}] = 200 (out of range)")


def _checksum_corrupt(arr, rng):
    if arr.checksums is None:
        return None
    b = _pick_block(arr, rng)
    cs = _leaf(arr, "checksums")
    cs[b] ^= np.int32(1 << int(rng.integers(31)))
    return Corruption(_rebuild(arr, checksums=cs), "checksum_corrupt", b,
                      f"checksums[{b}] bit-flipped")


STREAM_CLASSES: dict[str, Callable[..., Any]] = {
    "bit_flip": _bit_flip,
    "byte_drop": _byte_drop,
    "payload_truncate": _payload_truncate,
    "continuation_flip": _continuation_flip,
    "control_corrupt": _control_corrupt,
    "width_inflate": _width_inflate,
    "width_deflate": _width_deflate,
    "width_range": _width_range,
    "count_over": _count_over,
    "count_under": _count_under,
    "base_corrupt": _base_corrupt,
    "checksum_corrupt": _checksum_corrupt,
}


def corrupt(arr: CompressedIntArray, cls: str, seed: int) -> Corruption | None:
    """Apply one named corruption class with a fixed seed.

    Returns ``None`` when the class doesn't apply to this array (wrong
    format / no checksum column / not differential).
    """
    try:
        fn = STREAM_CLASSES[cls]
    except KeyError:
        raise ValueError(f"unknown corruption class {cls!r}; expected one "
                         f"of {tuple(STREAM_CLASSES)}") from None
    return fn(arr, np.random.default_rng(seed))


# --- index-level corruption classes (TermPostings) -------------------------
def corrupt_skip_table(tp, seed: int):
    """Break skip-table monotonicity: swap a block's first/last bounds."""
    rng = np.random.default_rng(seed)
    b = _pick_block(tp.arr, rng)
    first = np.array(np.asarray(tp.first_doc))
    last = np.array(np.asarray(tp.last_doc))
    first[b], last[b] = last[b] + 1, first[b]
    return replace(tp, first_doc=first, last_doc=last)


def corrupt_max_impact(tp, seed: int):
    """Understate a block's ``max_impact`` bound (the MaxScore invariant
    violation: pruning with it silently drops true top-k results)."""
    rng = np.random.default_rng(seed)
    mi = np.array(np.asarray(tp.max_impact))
    live = np.flatnonzero(mi > 0)
    b = int(rng.choice(live)) if live.size else 0
    mi[b] = 0
    return replace(tp, max_impact=mi)


def corrupt_impacts(tp, seed: int):
    """Bit-flip a used byte of the per-posting impact stream."""
    if tp.impacts is None:
        return None
    c = _bit_flip(tp.impacts, np.random.default_rng(seed))
    return replace(tp, impacts=c.arr)


INDEX_CLASSES = {
    "skip_corrupt": corrupt_skip_table,
    "max_impact_under": corrupt_max_impact,
    "impact_bit_flip": corrupt_impacts,
}
