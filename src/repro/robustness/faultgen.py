"""Seeded corruption generator for the robustness property tests.

Every corruption class takes a *clean* ``CompressedIntArray`` (typically
encoded with ``checksum=True``) and a seed, and returns a
:class:`Corruption` — the corrupted array plus the coordinates of what was
broken — or ``None`` when the class doesn't apply to the array (e.g.
``continuation_flip`` on Stream VByte, ``base_corrupt`` on a
non-differential stream). Corruptions only ever touch *used* bytes (bytes
the decoder actually consumes for the claimed ``counts``) — flipping
padding is provably harmless by the masking contract and tells the tests
nothing.

The test contract (tests/test_robustness.py) for every class × format ×
plan is **detect-or-defined-value**:

* *detected* — ``validate_structure``/``validate_stream``/``decode_checked``
  raises a typed :class:`~repro.robustness.validate.DecodeError` subclass,
  or
* *provably harmless* — with checksums disabled, every vectorized plan
  decodes the corrupted stream to the same defined value (no crash, dense
  and banded bit-identical), so serving can degrade instead of dying.

Index-level corruptions (skip table, ``max_impact`` bound, impact payload)
operate on a ``TermPostings`` and return a replaced copy; whole-shard loss
is injected at the serving layer (``SearchEngine.kill_shard``).

**Durability corruption classes** (``DURABILITY_CLASSES``) extend the same
discipline from in-memory streams to the storage layer: they mutate a
closed ``LiveIndex`` directory — torn/bit-rotted WAL records, truncated or
flipped segment payloads, garbage/stale/missing manifests — under a
**detect-or-recover** contract (reopen either reconstructs the exact
acknowledged state or raises a typed ``WalError``/``SegmentError``; see
docs/ingestion.md).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Callable

import numpy as np

from repro.core.compressed_array import CompressedIntArray
from repro.core.vbyte import ref as vref
from repro.core.vbyte import stream_vbyte as svb


@dataclass(frozen=True)
class Corruption:
    """One injected fault: the corrupted array + what/where."""

    arr: CompressedIntArray
    cls: str
    block: int
    detail: str


def _leaf(arr: CompressedIntArray, name: str) -> np.ndarray:
    return np.array(np.asarray(getattr(arr, name)))  # writable copy


def _rebuild(arr: CompressedIntArray, **leaves) -> CompressedIntArray:
    return replace(arr, host_enc=None, **leaves)


def _pick_block(arr: CompressedIntArray, rng: np.random.Generator) -> int:
    """A block with at least one claimed integer (corrupting an empty
    block's padding is harmless by construction)."""
    live = np.flatnonzero(np.asarray(arr.counts) > 0)
    if live.size == 0:
        raise ValueError("array has no non-empty block to corrupt")
    return int(rng.choice(live))


def _used_bytes(arr: CompressedIntArray, b: int) -> int:
    """Bytes the decoder consumes in block ``b`` for the claimed count."""
    c = int(np.asarray(arr.counts)[b])
    if arr.format == "vbyte":
        return vref.consumed_bytes(np.asarray(arr.payload)[b], c)
    if arr.format == "binpack":
        w = int(np.asarray(arr.widths).reshape(-1)[b])
        return max(-(-(w * c) // 8), 1)  # ≥1 so bit_flip/byte_drop apply
    lengths = svb.unpack_control(np.asarray(arr.control)[b], c) + 1
    return int(lengths.sum())


# --- stream-level corruption classes ---------------------------------------
def _bit_flip(arr, rng):
    b = _pick_block(arr, rng)
    name = "payload" if arr.format == "vbyte" else "data"
    leaf = _leaf(arr, name)
    i = int(rng.integers(_used_bytes(arr, b)))
    bit = int(rng.integers(8))
    leaf[b, i] ^= 1 << bit
    return Corruption(_rebuild(arr, **{name: leaf}), "bit_flip", b,
                      f"{name}[{b},{i}] ^= 1<<{bit}")


def _byte_drop(arr, rng):
    # drop one used byte: the tail shifts left, the last byte pads with 0 —
    # models a short read / lost byte mid-segment
    b = _pick_block(arr, rng)
    name = "payload" if arr.format == "vbyte" else "data"
    leaf = _leaf(arr, name)
    used = _used_bytes(arr, b)
    i = int(rng.integers(used))
    leaf[b, i:-1] = leaf[b, i + 1:]
    leaf[b, -1] = 0
    return Corruption(_rebuild(arr, **{name: leaf}), "byte_drop", b,
                      f"{name}[{b},{i}] dropped, tail shifted")


def _payload_truncate(arr, rng):
    # vbyte-only: turn the tail of the used region into an unterminated
    # continuation run, as if the stream were cut mid-integer
    if arr.format != "vbyte":
        return None
    b = _pick_block(arr, rng)
    leaf = _leaf(arr, "payload")
    used = _used_bytes(arr, b)
    i = int(rng.integers(max(used - 2, 0), used))
    leaf[b, i:] = 0xFF
    return Corruption(_rebuild(arr, payload=leaf), "payload_truncate", b,
                      f"payload[{b},{i}:] = 0xFF (no terminator)")


def _continuation_flip(arr, rng):
    if arr.format != "vbyte":
        return None
    b = _pick_block(arr, rng)
    leaf = _leaf(arr, "payload")
    i = int(rng.integers(_used_bytes(arr, b)))
    leaf[b, i] ^= 0x80
    return Corruption(_rebuild(arr, payload=leaf), "continuation_flip", b,
                      f"payload[{b},{i}] continuation bit flipped")


def _control_corrupt(arr, rng):
    if arr.format != "streamvbyte":
        return None
    b = _pick_block(arr, rng)
    c = int(np.asarray(arr.counts)[b])
    leaf = _leaf(arr, "control")
    i = int(rng.integers(-(-c // 4)))  # a control byte with live codes
    leaf[b, i] ^= int(rng.integers(1, 256))
    return Corruption(_rebuild(arr, control=leaf), "control_corrupt", b,
                      f"control[{b},{i}] xored")


def _count_over(arr, rng):
    b = _pick_block(arr, rng)
    counts = _leaf(arr, "counts")
    if int(counts[b]) >= arr.block_size:
        counts[b] = arr.block_size  # keep in range; sum mismatch remains
        counts[(b + 1) % counts.shape[0]] += 1
    else:
        counts[b] += 1
    return Corruption(_rebuild(arr, counts=counts), "count_over", b,
                      f"counts[{b}] inflated (sum != n)")


def _count_under(arr, rng):
    b = _pick_block(arr, rng)
    counts = _leaf(arr, "counts")
    counts[b] -= 1
    return Corruption(_rebuild(arr, counts=counts), "count_under", b,
                      f"counts[{b}] deflated (sum != n)")


def _base_corrupt(arr, rng):
    if not arr.differential or arr.ragged:
        return None
    counts = np.asarray(arr.counts)
    live = np.flatnonzero(counts > 0)
    live = live[live > 0]  # block 0's base is 0 by convention
    if live.size == 0:
        return None
    b = int(rng.choice(live))
    bases = _leaf(arr, "bases")
    bases[b] ^= np.uint32(1 << int(rng.integers(31)))
    return Corruption(_rebuild(arr, bases=bases), "base_corrupt", b,
                      f"bases[{b}] bit-flipped")


def _width_inflate(arr, rng):
    # binpack-only: overstate a block's width byte by one — the decoder
    # reads shifted garbage; the validator's tight-width canon catches it
    if arr.format != "binpack":
        return None
    b = _pick_block(arr, rng)
    widths = _leaf(arr, "widths")
    if int(widths[b, 0]) >= 32:
        return None
    widths[b, 0] += 1
    return Corruption(_rebuild(arr, widths=widths), "width_inflate", b,
                      f"widths[{b}] inflated by 1")


def _width_deflate(arr, rng):
    # binpack-only: understate the width — values alias into each other
    if arr.format != "binpack":
        return None
    ws = np.asarray(arr.widths).reshape(-1)
    live = np.flatnonzero((np.asarray(arr.counts) > 0) & (ws > 0))
    if live.size == 0:
        return None
    b = int(rng.choice(live))
    widths = _leaf(arr, "widths")
    widths[b, 0] -= 1
    return Corruption(_rebuild(arr, widths=widths), "width_deflate", b,
                      f"widths[{b}] deflated by 1")


def _width_range(arr, rng):
    # binpack-only: width byte outside [0, 32] entirely
    if arr.format != "binpack":
        return None
    b = _pick_block(arr, rng)
    widths = _leaf(arr, "widths")
    widths[b, 0] = 200
    return Corruption(_rebuild(arr, widths=widths), "width_range", b,
                      f"widths[{b}] = 200 (out of range)")


def _checksum_corrupt(arr, rng):
    if arr.checksums is None:
        return None
    b = _pick_block(arr, rng)
    cs = _leaf(arr, "checksums")
    cs[b] ^= np.int32(1 << int(rng.integers(31)))
    return Corruption(_rebuild(arr, checksums=cs), "checksum_corrupt", b,
                      f"checksums[{b}] bit-flipped")


STREAM_CLASSES: dict[str, Callable[..., Any]] = {
    "bit_flip": _bit_flip,
    "byte_drop": _byte_drop,
    "payload_truncate": _payload_truncate,
    "continuation_flip": _continuation_flip,
    "control_corrupt": _control_corrupt,
    "width_inflate": _width_inflate,
    "width_deflate": _width_deflate,
    "width_range": _width_range,
    "count_over": _count_over,
    "count_under": _count_under,
    "base_corrupt": _base_corrupt,
    "checksum_corrupt": _checksum_corrupt,
}


def corrupt(arr: CompressedIntArray, cls: str, seed: int) -> Corruption | None:
    """Apply one named corruption class with a fixed seed.

    Returns ``None`` when the class doesn't apply to this array (wrong
    format / no checksum column / not differential).
    """
    try:
        fn = STREAM_CLASSES[cls]
    except KeyError:
        raise ValueError(f"unknown corruption class {cls!r}; expected one "
                         f"of {tuple(STREAM_CLASSES)}") from None
    return fn(arr, np.random.default_rng(seed))


# --- index-level corruption classes (TermPostings) -------------------------
def corrupt_skip_table(tp, seed: int):
    """Break skip-table monotonicity: swap a block's first/last bounds."""
    rng = np.random.default_rng(seed)
    b = _pick_block(tp.arr, rng)
    first = np.array(np.asarray(tp.first_doc))
    last = np.array(np.asarray(tp.last_doc))
    first[b], last[b] = last[b] + 1, first[b]
    return replace(tp, first_doc=first, last_doc=last)


def corrupt_max_impact(tp, seed: int):
    """Understate a block's ``max_impact`` bound (the MaxScore invariant
    violation: pruning with it silently drops true top-k results)."""
    rng = np.random.default_rng(seed)
    mi = np.array(np.asarray(tp.max_impact))
    live = np.flatnonzero(mi > 0)
    b = int(rng.choice(live)) if live.size else 0
    mi[b] = 0
    return replace(tp, max_impact=mi)


def corrupt_impacts(tp, seed: int):
    """Bit-flip a used byte of the per-posting impact stream."""
    if tp.impacts is None:
        return None
    c = _bit_flip(tp.impacts, np.random.default_rng(seed))
    return replace(tp, impacts=c.arr)


INDEX_CLASSES = {
    "skip_corrupt": corrupt_skip_table,
    "max_impact_under": corrupt_max_impact,
    "impact_bit_flip": corrupt_impacts,
}


# --- durability corruption classes (LiveIndex directory) --------------------
# These operate on a *closed* ``repro.index.ingest.LiveIndex`` directory —
# the WAL files, segment dirs and manifest on disk — and model storage
# faults rather than in-memory stream corruption. The contract is
# **detect-or-recover** (tests/test_ingest.py): reopening the directory
# either recovers to the exact acknowledged state (``expect="recover"``,
# minus ``ops_lost`` trailing ops for the sheared-tail classes, which model
# a crash *during* an append that was never acknowledged) or raises a
# typed ``WalError``/``SegmentError`` (``expect="detect"``). Silently
# serving wrong history is never an outcome.

@dataclass(frozen=True)
class DirCorruption:
    """One injected durability fault on a LiveIndex directory."""

    cls: str
    path: str  # file corrupted
    detail: str
    expect: str  # "recover" | "detect"
    ops_lost: int = 0  # trailing unacked-op shear (torn-tail classes only)


def _live_wals(directory: str):
    """Unmerged WAL paths in id order, with their record spans."""
    import json as _json
    import os as _os

    from repro.index.wal import parse_wal_name, wal_path

    with open(_os.path.join(directory, "MANIFEST.json")) as f:
        merged = int(_json.load(f)["merged_wal"])
    ids = sorted(i for nm in _os.listdir(directory)
                 if (i := parse_wal_name(nm)) is not None and i > merged)
    return [wal_path(directory, i) for i in ids]


def _record_spans(path: str):
    """Byte spans ``[(start, end), ...]`` of each valid WAL record."""
    import struct

    with open(path, "rb") as f:
        data = f.read()
    hdr = struct.Struct("<II")
    spans, off = [], 0
    while off + hdr.size <= len(data):
        length, _ = hdr.unpack_from(data, off)
        end = off + hdr.size + length
        if end > len(data):
            break
        spans.append((off, end))
        off = end
    return spans


def _wal_torn_tail(directory, rng):
    """A crash mid-append: a half-written record at the tail of the active
    WAL. No acknowledged op is affected — recovery truncates it."""
    import os as _os
    wals = _live_wals(directory)
    if not wals:
        return None
    path = wals[-1]
    junk = bytes(rng.integers(0, 256, size=int(rng.integers(1, 7)),
                              dtype=np.uint8))
    with open(path, "ab") as f:
        f.write(junk)  # shorter than a header: unmistakably torn
    return DirCorruption("wal_torn_tail", path,
                         f"{len(junk)} partial bytes appended",
                         expect="recover", ops_lost=0)


def _wal_tail_shear(directory, rng):
    """A crash that tore the *final* append mid-record: truncate inside the
    last record. That op was still in flight (ack follows the fsync), so
    recovery legitimately rolls back exactly one op."""
    wals = _live_wals(directory)
    if not wals:
        return None
    path = wals[-1]
    spans = _record_spans(path)
    if not spans:
        return None
    s, e = spans[-1]
    cut = int(rng.integers(s + 1, e))
    with open(path, "r+b") as f:
        f.truncate(cut)
    return DirCorruption("wal_tail_shear", path,
                         f"truncated at {cut} inside record [{s},{e})",
                         expect="recover", ops_lost=1)


def _wal_record_flip(directory, rng):
    """Bit rot in an acknowledged, non-final WAL record — durable data
    after it proves this is not a torn append. Must detect (WalError)."""
    for path in _live_wals(directory):
        spans = _record_spans(path)
        if len(spans) >= 2:
            s, e = spans[int(rng.integers(len(spans) - 1))]
            i = int(rng.integers(s + 8, e))  # payload byte, not header
            with open(path, "r+b") as f:
                f.seek(i)
                b = f.read(1)[0]
                f.seek(i)
                f.write(bytes([b ^ (1 << int(rng.integers(8)))]))
            return DirCorruption("wal_record_flip", path,
                                 f"payload byte {i} bit-flipped",
                                 expect="detect")
    return None


def _wal_length_corrupt(directory, rng):
    """Corrupt a non-final record's length field (framing), keeping the
    claimed extent inside the file so it cannot pass as a torn tail.
    The mis-framed payload fails its CRC — must detect."""
    for path in _live_wals(directory):
        spans = _record_spans(path)
        if len(spans) >= 2:
            s, e = spans[int(rng.integers(len(spans) - 1))]
            new_len = max((e - s - 8) // 2, 1)  # shrink: stays in-file
            with open(path, "r+b") as f:
                f.seek(s)
                f.write(int(new_len).to_bytes(4, "little"))
            return DirCorruption("wal_length_corrupt", path,
                                 f"record at {s} length rewritten to "
                                 f"{new_len}", expect="detect")
    return None


def _segment_paths(directory):
    import json as _json
    import os as _os
    with open(_os.path.join(directory, "MANIFEST.json")) as f:
        man = _json.load(f)
    return [_os.path.join(directory, "segments", nm)
            for nm in man["segments"]]


def _segment_truncate(directory, rng):
    """Truncated segment payload (short write / lost extent). The
    whole-file CRC in segment.json must catch it — detect."""
    import os as _os
    segs = _segment_paths(directory)
    if not segs:
        return None
    npz = _os.path.join(segs[0], "postings.npz")
    size = _os.path.getsize(npz)
    cut = int(rng.integers(1, size))
    with open(npz, "r+b") as f:
        f.truncate(cut)
    return DirCorruption("segment_truncate", npz,
                         f"truncated {size} -> {cut} bytes", expect="detect")


def _segment_bit_flip(directory, rng):
    """Bit rot inside the segment payload — CRC must catch it."""
    import os as _os
    segs = _segment_paths(directory)
    if not segs:
        return None
    npz = _os.path.join(segs[0], "postings.npz")
    size = _os.path.getsize(npz)
    i = int(rng.integers(size))
    with open(npz, "r+b") as f:
        f.seek(i)
        b = f.read(1)[0]
        f.seek(i)
        f.write(bytes([b ^ (1 << int(rng.integers(8)))]))
    return DirCorruption("segment_bit_flip", npz,
                         f"byte {i} bit-flipped", expect="detect")


def _segment_meta_garbage(directory, rng):
    """Unparseable segment metadata for a manifest-listed segment —
    nothing to roll forward to, must detect."""
    import os as _os
    segs = _segment_paths(directory)
    if not segs:
        return None
    meta = _os.path.join(segs[0], "segment.json")
    with open(meta, "wb") as f:
        f.write(b"{ not json" + bytes(rng.integers(32, 127, size=8,
                                                   dtype=np.uint8)))
    return DirCorruption("segment_meta_garbage", meta,
                         "segment.json overwritten with garbage",
                         expect="detect")


def _manifest_garbage(directory, rng):
    """Unparseable manifest: the commit point itself is unreadable, so the
    acknowledged epoch is unknowable — must detect."""
    import os as _os
    path = _os.path.join(directory, "MANIFEST.json")
    with open(path, "wb") as f:
        f.write(bytes(rng.integers(0, 256, size=24, dtype=np.uint8)))
    return DirCorruption("manifest_garbage", path,
                         "MANIFEST.json overwritten with garbage",
                         expect="detect")


def _manifest_stale(directory, rng):
    """The manifest rolled back to a pre-merge version (e.g. restored from
    an old backup) while the merged segment survived and its drained WALs
    are gone. Recovery must adopt the newer segment (roll forward) — the
    segment is the only durable copy of that history."""
    import json as _json
    import os as _os
    path = _os.path.join(directory, "MANIFEST.json")
    with open(path) as f:
        man = _json.load(f)
    if man["epoch"] < 1 or not man["segments"]:
        return None  # needs a committed merge to stale away
    old = dict(man)
    old.update(epoch=man["epoch"] - 1, segments=[],
               merged_wal=max(man["merged_wal"] - 1, 0))
    with open(path, "w") as f:
        _json.dump(old, f)
    return DirCorruption("manifest_stale", path,
                         f"manifest rolled back to epoch {old['epoch']}",
                         expect="recover")


def _manifest_missing(directory, rng):
    """The manifest vanished entirely after a committed merge. Same roll-
    forward contract: the surviving segment + WAL suffix reconstruct the
    acknowledged state."""
    import json as _json
    import os as _os
    path = _os.path.join(directory, "MANIFEST.json")
    with open(path) as f:
        man = _json.load(f)
    if man["epoch"] < 1 or not man["segments"]:
        return None
    _os.remove(path)
    return DirCorruption("manifest_missing", path, "MANIFEST.json deleted",
                         expect="recover")


DURABILITY_CLASSES: dict[str, Callable[..., Any]] = {
    "wal_torn_tail": _wal_torn_tail,
    "wal_tail_shear": _wal_tail_shear,
    "wal_record_flip": _wal_record_flip,
    "wal_length_corrupt": _wal_length_corrupt,
    "segment_truncate": _segment_truncate,
    "segment_bit_flip": _segment_bit_flip,
    "segment_meta_garbage": _segment_meta_garbage,
    "manifest_garbage": _manifest_garbage,
    "manifest_stale": _manifest_stale,
    "manifest_missing": _manifest_missing,
}


def corrupt_dir(directory: str, cls: str, seed: int) -> DirCorruption | None:
    """Apply one named durability fault to a closed LiveIndex directory.

    Returns ``None`` when the class doesn't apply (no unmerged WAL
    records, no committed segment to corrupt, ...).
    """
    try:
        fn = DURABILITY_CLASSES[cls]
    except KeyError:
        raise ValueError(f"unknown durability class {cls!r}; expected one "
                         f"of {tuple(DURABILITY_CLASSES)}") from None
    return fn(directory, np.random.default_rng(seed))
