"""Quickstart: boolean + top-k search over compressed posting lists.

    PYTHONPATH=src python examples/search_postings.py

Builds a compressed inverted index from ClueWeb09-style synthetic posting
lists (the paper's workload), then answers AND / OR / top-k queries as
decode→intersect→score pipelines: skip tables prune non-overlapping blocks
before decode, and the ``membership`` / ``bm25_accum`` kernel epilogues
intersect and score inside the decode kernel (docs/index.md).
"""
import numpy as np

from repro.data.synthetic import posting_list, posting_list_group, posting_tfs
from repro.index import QueryStats, build_index, conjunctive, disjunctive, topk

rng = np.random.default_rng(0)
universe = 1 << 20

# 1. synthetic posting lists, lengths in [2^10, 2^11) — one list per "term",
# plus a rare "title" term, two long "body" terms, and per-posting term
# frequencies (the Zipf skew that gives MaxScore's block-max threshold
# something to prune). The body terms are long on purpose: the 8-bit
# quantizer ceilings any list whose idf is within ~1/2.2 of the rare
# term's at the same 255 that drives θ, and strict pruning (correctly)
# refuses bound ties — only lists long enough that their saturated
# impacts sit *under* θ can be pruned (docs/index.md §Block-max pruning)
lists = dict(enumerate(posting_list_group(rng, 10, 8, universe=universe)))
lists[100] = posting_list(rng, 320, universe=universe)
lists[200] = posting_list(rng, 1 << 15, universe=universe)
lists[201] = posting_list(rng, 1 << 15, universe=universe)
tfs = {t: posting_tfs(rng, len(v)) for t, v in lists.items()}
index = build_index(lists, tfs=tfs, n_docs=universe)
print(f"index: {index.n_terms} terms, {index.n_postings} postings, "
      f"{index.bits_per_int:.2f} bits/int (d-gap VByte, blocked + skip tables)")

# 2. conjunctive (AND): rarest term drives, the others are probed through the
# fused membership epilogue; the skip table prunes blocks before decode
stats = QueryStats()
hits = conjunctive(index, [0, 1], stats=stats)
print(f"AND(0, 1): {len(hits)} docs, decoded {stats.blocks_decoded} blocks, "
      f"skipped {stats.blocks_skipped}")

# 3. disjunctive (OR): the union is the answer, every live block decodes once
print(f"OR(0, 1): {len(disjunctive(index, [0, 1]))} docs")

# 4. top-k under per-posting quantized BM25 impacts (exact int32
# accumulation via the fused bm25 epilogues — ties break by docid,
# deterministically)
ids, scores = topk(index, [0, 1, 2], k=5)
print("top-5 of OR(0, 1, 2):")
for d, s in zip(ids, scores):
    print(f"  doc {d:>8}  score {s}")

# 5. block-max pruned top-k (MaxScore DAAT): bit-identical to mode="or"
# (ties included — every bound test is strict), but blocks whose max
# impact can't even tie the running k-th score are never decoded by any
# pass — QueryStats.blocks_pruned is the evidence (docs/index.md)
stats = QueryStats()
mids, mscores = topk(index, [100, 200, 201], k=5, mode="maxscore",
                     stats=stats)
oids, oscores = topk(index, [100, 200, 201], k=5, mode="or")
assert np.array_equal(mids, oids) and np.array_equal(mscores, oscores)
print(f"maxscore top-5 of OR(100, 200, 201): identical results, "
      f"decoded {stats.blocks_decoded} blocks, pruned {stats.blocks_pruned} "
      f"({stats.postings_pruned} postings) without decoding")

# 6. same queries through the resident SearchEngine (microbatched probes;
# pass a mesh to shard every term's blocks across devices instead)
from repro.launch.serve import SearchEngine, search_queries

engine = SearchEngine(index, top_k=5)
queries = search_queries(rng, index, 12)
engine.warmup(queries[:3])
s = engine.run_workload(queries)
print(f"engine: {s['qps']} QPS over {s['n_queries']} mixed queries, "
      f"block skip rate {s['block_skip_rate']}")
