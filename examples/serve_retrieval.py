"""Two-tower retrieval serving from a sharded compressed corpus.

Demonstrates the ``ServingEngine`` (repro.launch.serve): the candidate
corpus stays VByte-compressed and resident on the device mesh
(``CompressedIntArray.shard`` — block dim across devices), incoming
requests are microbatched to a fixed set of jitted bucket shapes, and
scoring runs through the fused ``dot_score`` decode epilogue against a
precomputed item-vector table — decode, gather and dot happen where each
shard's blocks live, with no cross-device decode traffic.

    PYTHONPATH=src python examples/serve_retrieval.py --requests 64
    # sharded across 8 forced host devices:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_retrieval.py --requests 64
"""
import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import CompressedIntArray
from repro.launch.serve import ServingEngine
from repro.models import recsys
from repro.models.registry import reduced_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--candidates", type=int, default=1 << 16)
    ap.add_argument("--top-k", type=int, default=10)
    args = ap.parse_args()

    cfg = reduced_config("two-tower-retrieval")
    import dataclasses
    cfg = dataclasses.replace(cfg, n_items=1 << 20, n_users=1 << 16)
    params = recsys.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    # the candidate corpus for today's retrieval: sorted ids, delta+VByte —
    # encoded once, sharded once, then resident for every request
    cands = np.sort(rng.choice(np.arange(1, cfg.n_items), args.candidates,
                               replace=False)).astype(np.uint64)
    corpus = CompressedIntArray.encode(cands, differential=True)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",)) if n_dev > 1 else None
    print(f"corpus: {corpus.n} ids, {corpus.bits_per_int:.2f} bits/int "
          f"({corpus.compression_ratio:.2f}x), {corpus.n_blocks} blocks "
          f"sharded over {n_dev} device(s)")

    engine = ServingEngine(params, cfg, corpus, mesh=mesh, top_k=args.top_k)
    engine.warmup()

    # single microbatch, inspected: the array itself went through jit — no
    # cand_payload/cand_counts/cand_bases unpacking anywhere
    uid = jnp.asarray([rng.integers(1, cfg.n_users)], jnp.int32)
    hist = jnp.asarray(rng.integers(1, cfg.n_items, (1, cfg.seq_len)),
                       jnp.int32)
    top_s, top_i = engine.retrieve(uid, hist)
    print(f"top-{args.top_k} items {np.asarray(top_i)[0, :5]}... "
          f"scores {np.asarray(top_s)[0, :3]}")

    # a request stream through the bucketed microbatching loop
    reqs = [(int(rng.integers(1, cfg.n_users)),
             rng.integers(1, cfg.n_items, cfg.seq_len).astype(np.int32))
            for _ in range(args.requests)]
    stats = engine.run_workload(reqs)
    print(f"{stats['n_requests']} requests on {stats['n_devices']} device(s): "
          f"{stats['qps']} QPS, p50 {stats['p50_ms']} ms, "
          f"p99 {stats['p99_ms']} ms "
          f"({args.candidates / (stats['mean_ms'] / 1e3) / 1e6:.1f}M "
          f"candidates scored/s/request)")


if __name__ == "__main__":
    main()
