"""Two-tower retrieval serving over a VByte-compressed candidate list.

Batched requests: each request decodes a (shared) compressed 64k-candidate
posting list inside the jitted serving graph, embeds the candidates with the
item tower, and returns the top-k items for the user.

    PYTHONPATH=src python examples/serve_retrieval.py --requests 8
"""
import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import CompressedIntArray
from repro.models import recsys
from repro.models.registry import reduced_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--candidates", type=int, default=1 << 16)
    ap.add_argument("--top-k", type=int, default=10)
    args = ap.parse_args()

    cfg = reduced_config("two-tower-retrieval")
    import dataclasses
    cfg = dataclasses.replace(cfg, n_items=1 << 20, n_users=1 << 16)
    params = recsys.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    # the candidate corpus for today's retrieval: sorted ids, delta+VByte
    cands = np.sort(rng.choice(np.arange(1, cfg.n_items), args.candidates,
                               replace=False)).astype(np.uint64)
    arr = CompressedIntArray.encode(cands, differential=True)
    ops = arr.device_operands()
    print(f"candidate list: {arr.n} ids, {arr.bits_per_int:.2f} bits/int "
          f"({arr.compression_ratio:.2f}x)")

    serve = jax.jit(lambda p, b: recsys.retrieval_scores_compressed(
        p, b, cfg, top_k=args.top_k))

    t0 = time.time()
    for req in range(args.requests):
        batch = {
            "cand_payload": ops["payload"], "cand_counts": ops["counts"],
            "cand_bases": ops["bases"],
            "user_id": jnp.asarray([rng.integers(1, cfg.n_users)], jnp.int32),
            "hist": jnp.asarray(rng.integers(1, cfg.n_items,
                                             (1, cfg.seq_len)), jnp.int32),
        }
        scores, (top_s, top_i) = serve(params, batch)
        jax.block_until_ready(top_i)
        if req < 3:
            print(f"req {req}: top-{args.top_k} items "
                  f"{np.asarray(top_i)[:5]}... scores {np.asarray(top_s)[:3]}")
    dt = (time.time() - t0) / args.requests
    print(f"{args.requests} requests, {dt*1e3:.1f} ms/request "
          f"({args.candidates/dt/1e6:.1f}M candidates scored/s)")


if __name__ == "__main__":
    main()
