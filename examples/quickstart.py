"""Quickstart: VByte posting lists on device in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax

from repro.core import CompressedIntArray
from repro.core.vbyte import encode as venc
from repro.data.synthetic import CLUEWEB_DOCS

rng = np.random.default_rng(0)

# 1. a sorted docid posting list (the paper's setting)
docids = np.sort(rng.choice(CLUEWEB_DOCS, size=100_000, replace=False)).astype(np.uint64)

# 2. differential (gap) VByte encoding, blocked for SPMD decode
arr = CompressedIntArray.encode(docids, differential=True)
print(f"{arr.n} ids -> {arr.enc.payload_bytes} bytes "
      f"({arr.bits_per_int:.2f} bits/int, {arr.compression_ratio:.2f}x vs uint32)")

# 3. decode on device with the vectorized Masked-VByte decoder
decoded = arr.decode()
assert np.array_equal(decoded.astype(np.uint64), docids)
print("masked decode round-trips ✓")

# 4. same decode through the Pallas TPU kernel (interpret mode on CPU)
decoded_k = arr.decode(plan="kernel")
assert np.array_equal(decoded_k, decoded)
print("pallas kernel agrees ✓")

# 5. the array is a JAX pytree: pass it straight through jit — payloads are
# traced leaves, format/block metadata is static, so same-shape arrays with
# new data reuse one compiled program
decode_grid = jax.jit(lambda a: a.decode_blocked(plan="jnp"))
grid = decode_grid(arr)
assert np.array_equal(np.asarray(grid).reshape(-1)[: arr.n], decoded)
print("jit(decode) over the pytree array ✓")

# 6. shard the block dimension across every available device and decode
# block-parallel where the bytes live (shard_map, no cross-device traffic).
# On 1 device this is a no-op placement; run under
# XLA_FLAGS=--xla_force_host_platform_device_count=8 to see it split.
mesh = jax.make_mesh((len(jax.devices()),), ("data",))
sharded = arr.shard(mesh, axis="data")
assert np.array_equal(sharded.decode(), decoded)
print(f"sharded decode over {len(jax.devices())} device(s) agrees ✓")

# 7. the paper's byte format, by hand (Table 1)
for v in (1, 128, 16384):
    print(f"vbyte({v}) = {[bin(b) for b in venc.encode_stream(np.array([v], np.uint64))]}")

# 8. the faster-to-decode successor format: Stream VByte (docs/formats.md).
# 2-bit length codes live in a separate control stream, so the decoder skips
# the continuation-bit scan entirely — trade ~1-2 bits/int for decode speed.
svb = CompressedIntArray.encode(docids, format="streamvbyte", differential=True)
assert np.array_equal(svb.decode(plan="kernel").astype(np.uint64), docids)
print(f"streamvbyte: {svb.bits_per_int:.2f} bits/int, kernel round-trips ✓")

# 9. binary packing: each block stores its gaps at the block's max bit
# width — no per-int framing at all, so it is usually both the smallest
# AND the fastest to decode on locally-uniform gaps (docs/formats.md).
# `build_index(..., format="auto")` picks codec + block boundaries per
# posting list with a shortest-path DP (docs/index.md §Optimal
# partitioning).
bpk = CompressedIntArray.encode(docids, format="binpack", differential=True)
assert np.array_equal(bpk.decode(plan="kernel").astype(np.uint64), docids)
print(f"binpack: {bpk.bits_per_int:.2f} bits/int, kernel round-trips ✓")
