"""Quickstart: VByte posting lists on device in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import CompressedIntArray
from repro.core.vbyte import encode as venc
from repro.data.synthetic import CLUEWEB_DOCS

rng = np.random.default_rng(0)

# 1. a sorted docid posting list (the paper's setting)
docids = np.sort(rng.choice(CLUEWEB_DOCS, size=100_000, replace=False)).astype(np.uint64)

# 2. differential (gap) VByte encoding, blocked for SPMD decode
arr = CompressedIntArray.encode(docids, differential=True)
print(f"{arr.n} ids -> {arr.enc.payload_bytes} bytes "
      f"({arr.bits_per_int:.2f} bits/int, {arr.compression_ratio:.2f}x vs uint32)")

# 3. decode on device with the vectorized Masked-VByte decoder
decoded = arr.decode()
assert np.array_equal(decoded.astype(np.uint64), docids)
print("masked decode round-trips ✓")

# 4. same decode through the Pallas TPU kernel (interpret mode on CPU)
decoded_k = arr.decode(use_kernel=True)
assert np.array_equal(decoded_k, decoded)
print("pallas kernel agrees ✓")

# 5. the paper's byte format, by hand (Table 1)
for v in (1, 128, 16384):
    print(f"vbyte({v}) = {[bin(b) for b in venc.encode_stream(np.array([v], np.uint64))]}")

# 6. the faster-to-decode successor format: Stream VByte (docs/formats.md).
# 2-bit length codes live in a separate control stream, so the decoder skips
# the continuation-bit scan entirely — trade ~1-2 bits/int for decode speed.
svb = CompressedIntArray.encode(docids, format="streamvbyte", differential=True)
assert np.array_equal(svb.decode(use_kernel=True).astype(np.uint64), docids)
print(f"streamvbyte: {svb.bits_per_int:.2f} bits/int, kernel round-trips ✓")
