"""GIN training with VByte-compressed adjacency (full-graph) and with the
neighbor sampler (mini-batch) — the paper's posting lists as neighbor lists.

    PYTHONPATH=src python examples/train_gnn.py --steps 50
"""
import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.data.graph import compress_adjacency
from repro.data.sampler import CSRGraph, NeighborSampler
from repro.data.synthetic import random_graph
from repro.models import gnn
from repro.train import OptimizerConfig, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--nodes", type=int, default=2000)
    ap.add_argument("--edges", type=int, default=20000)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    g = random_graph(rng, args.nodes, args.edges, 32, 7)
    csr = CSRGraph.from_edges(g["edge_src"], g["edge_dst"], args.nodes)
    comp = compress_adjacency(csr)
    print(f"adjacency: {csr.n_edges} edges at "
          f"{comp.pop('_bits_per_edge'):.2f} bits/edge (VByte, per-list delta)")

    cfg = gnn.GNNConfig(name="gin", n_layers=3, d_hidden=64, d_feat=32,
                        n_classes=7, compressed_adjacency=True)
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"feats": jnp.asarray(g["feats"]), "labels": jnp.asarray(g["labels"]),
             "label_mask": jnp.ones(args.nodes, bool),
             "edge_valid": jnp.ones(csr.n_edges, bool),
             # comp["gaps"] is a CompressedIntArray — a pytree, so tree.map
             # uploads its leaves like any other batch entry
             **jax.tree.map(jnp.asarray, comp)}

    state = init_train_state(params)
    step_fn = jax.jit(make_train_step(
        lambda p, b: gnn.loss_fn(p, b, cfg),
        OptimizerConfig(peak_lr=5e-3, warmup_steps=5, total_steps=args.steps)))
    t0 = time.time()
    for step in range(args.steps):
        state, m = step_fn(state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"full-graph step {step:>3} loss={float(m['loss']):.4f} "
                  f"acc={float(m['accuracy']):.3f}")
    print(f"{(time.time()-t0)/args.steps*1e3:.1f} ms/step (compressed adjacency "
          "decoded on device every step)")

    # mini-batch regime with the real neighbor sampler (minibatch_lg shape)
    sampler = NeighborSampler(csr, fanouts=(10, 5))
    cfg_mb = gnn.GNNConfig(name="gin-mb", n_layers=2, d_hidden=64, d_feat=32,
                           n_classes=7)
    params_mb = gnn.init_params(jax.random.PRNGKey(1), cfg_mb)
    state_mb = init_train_state(params_mb)
    step_mb = jax.jit(make_train_step(
        lambda p, b: gnn.loss_fn(p, b, cfg_mb),
        OptimizerConfig(peak_lr=5e-3, warmup_steps=5, total_steps=args.steps)))
    n_cap = None
    for step in range(10):
        seeds = rng.choice(args.nodes, 256, replace=False)
        sub = sampler.sample(seeds, rng)
        n = len(sub["node_ids"])
        n_cap = n_cap or sampler.node_capacity(256)
        feats = np.zeros((n_cap, 32), np.float32)
        feats[:n] = g["feats"][sub["node_ids"]]
        labels = np.zeros(n_cap, np.int32)
        labels[:n] = g["labels"][sub["node_ids"]]
        mask = np.zeros(n_cap, bool)
        mask[sub["seed_ids"]] = True
        mb = {"feats": jnp.asarray(feats), "labels": jnp.asarray(labels),
              "label_mask": jnp.asarray(mask),
              "edge_src": jnp.asarray(sub["edge_src"]),
              "edge_dst": jnp.asarray(sub["edge_dst"]),
              "edge_valid": jnp.asarray(sub["edge_valid"])}
        state_mb, m = step_mb(state_mb, mb)
        if step % 3 == 0:
            print(f"minibatch step {step:>2} loss={float(m['loss']):.4f} "
                  f"({int(sub['edge_valid'].sum())} sampled edges)")


if __name__ == "__main__":
    main()
