"""End-to-end LM training on a VByte-compressed token pipeline.

Train a small LM (default ~10M params for CPU; --params-100m for the ~100M
configuration) for a few hundred steps with checkpoint/restart:

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 200 --resume  # restart
"""
import argparse
import time

import numpy as np

import jax

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import CompressedTokenPipeline
from repro.data.synthetic import token_stream
from repro.models import lm
from repro.train import OptimizerConfig, init_train_state, make_train_step


def build_cfg(big: bool) -> lm.LMConfig:
    if big:  # ~100M params
        return lm.LMConfig(name="lm-100m", n_layers=8, d_model=768, n_heads=12,
                           n_kv_heads=4, d_ff=2048, vocab=50304,
                           q_chunk=128, kv_chunk=128, loss_chunk=128)
    return lm.LMConfig(name="lm-10m", n_layers=4, d_model=256, n_heads=8,
                       n_kv_heads=4, d_ff=688, vocab=8192,
                       q_chunk=128, kv_chunk=128, loss_chunk=128)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=255)
    ap.add_argument("--params-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = build_cfg(args.params_100m)
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params)")

    rng = np.random.default_rng(0)
    tokens = token_stream(rng, args.batch * (args.seq + 1) * 64, cfg.vocab)
    pipe = CompressedTokenPipeline(tokens, args.batch, args.seq, plan="kernel")
    print(f"pipeline: {pipe.n_steps} shards, "
          f"compression {pipe.compression_ratio():.2f}x")

    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params)
    opt = OptimizerConfig(peak_lr=3e-4, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(lambda p, b: lm.loss_fn(p, b, cfg), opt))

    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    start = 0
    if args.resume:
        restored, at = mgr.restore_latest(state)
        if restored is not None:
            state = jax.tree.map(lambda x: jax.numpy.asarray(x), restored)
            start = at + 1
            print(f"resumed from step {at}")

    t0 = time.time()
    for step in range(start, args.steps):
        state, metrics = step_fn(state, pipe.get_batch(step))
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:>4} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"({(time.time()-t0)/(step-start+1):.2f}s/step)")
        if step and step % args.ckpt_every == 0:
            mgr.save(step, state, async_=True)
    mgr.wait()
    mgr.save(args.steps - 1, state)
    print(f"final loss {float(metrics['loss']):.4f}; "
          f"checkpoints at {args.ckpt_dir}: steps {mgr.steps()}")


if __name__ == "__main__":
    main()
