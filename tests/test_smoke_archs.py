"""Per-assigned-architecture smoke tests: REDUCED config of the same family,
one real forward/train step on CPU, asserting output shapes + finite values.
(The FULL configs are exercised via the dry-run only — ShapeDtypeStructs.)"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.synthetic import molecule_batch, random_graph, recsys_batch
from repro.models import gnn, lm, recsys, registry
from repro.train import OptimizerConfig, init_train_state, make_train_step

pytestmark = pytest.mark.slow  # heavyweight model/system tier (deselected from tier-1)

LM_ARCHS = ["olmoe-1b-7b", "mixtral-8x7b", "h2o-danube-1.8b", "yi-6b", "glm4-9b"]
RECSYS_ARCHS = ["sasrec", "two-tower-retrieval", "bert4rec", "bst"]
OPT = OptimizerConfig(peak_lr=1e-3, warmup_steps=1)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_arch_smoke(rng, arch):
    cfg = registry.reduced_config(arch)
    assert cfg.moe is None or cfg.moe.n_experts <= 4
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 33)), jnp.int32)}
    step = jax.jit(make_train_step(lambda p, b: lm.loss_fn(p, b, cfg), OPT))
    state, metrics = step(init_train_state(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert all(np.all(np.isfinite(np.asarray(x, np.float32)))
               for x in jax.tree.leaves(state["params"]))
    # serve path: prefill + 2 decode steps
    lg, cache = lm.prefill(params, batch["tokens"][:, :16], cfg, cache_capacity=32)
    assert lg.shape == (2, cfg.vocab) and np.all(np.isfinite(np.asarray(lg)))
    for t in (16, 17):
        lg, cache = lm.decode_step(params, cache, batch["tokens"][:, t], cfg)
        assert np.all(np.isfinite(np.asarray(lg)))
    assert int(cache["index"]) == 18


@pytest.mark.parametrize("shape_name", ["full_graph_sm", "molecule"])
def test_gnn_arch_smoke(rng, shape_name):
    base = registry.reduced_config("gin-tu")
    if shape_name == "molecule":
        cfg = gnn.GNNConfig(name=base.name, n_layers=base.n_layers,
                            d_hidden=base.d_hidden, d_feat=5, n_classes=2,
                            task="graph")
        mb = molecule_batch(rng, 8, 6, 12, 5, 2)
        batch = {k: jnp.asarray(v) for k, v in mb.items() if k != "n_graphs"}
    else:
        cfg = base
        g = random_graph(rng, 64, 256, cfg.d_feat, cfg.n_classes)
        batch = {"feats": jnp.asarray(g["feats"]),
                 "edge_src": jnp.asarray(g["edge_src"]),
                 "edge_dst": jnp.asarray(g["edge_dst"]),
                 "labels": jnp.asarray(g["labels"]),
                 "label_mask": jnp.ones(64, bool)}
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(lambda p, b: gnn.loss_fn(p, b, cfg), OPT))
    state, metrics = step(init_train_state(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    logits = gnn.forward(params, batch, cfg)
    expect = (8, 2) if shape_name == "molecule" else (64, cfg.n_classes)
    assert logits.shape == expect


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_arch_smoke(rng, arch):
    cfg = registry.reduced_config(arch)
    params = recsys.init_params(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in recsys_batch(
        rng, cfg.kind, 16, cfg.seq_len, cfg.n_items, n_mask=cfg.n_mask,
        n_negatives=cfg.n_negatives, n_users=cfg.n_users).items()}
    step = jax.jit(make_train_step(lambda p, b: recsys.loss_fn(p, b, cfg), OPT))
    state, metrics = step(init_train_state(params), batch)
    assert np.isfinite(float(metrics["loss"])), arch
    for x in jax.tree.leaves(state["params"]):
        assert np.all(np.isfinite(np.asarray(x, np.float32)))


def test_all_cells_enumerate_40():
    cells = list(registry.all_cells(include_skipped=True))
    assert len(cells) == 40
    skipped = [c for c in cells if c[2] is not None]
    assert len(skipped) == 3  # olmoe / yi / glm4 long_500k
    assert all(s == "long_500k" for _, s, _ in skipped)


def test_registry_builds_every_cell_abstract():
    """Every non-skipped cell must produce coherent abstract args + specs."""
    for arch, shape, _ in registry.all_cells():
        cell = registry.build_cell(arch, shape, mesh_dp=16)
        flat_args = jax.tree.leaves(cell.args)
        flat_specs = jax.tree.leaves(cell.arg_specs,
                                     is_leaf=lambda x: hasattr(x, "_normalized_spec")
                                     or type(x).__name__ == "PartitionSpec")
        assert len(flat_args) == len(flat_specs), (arch, shape)
        assert all(hasattr(a, "shape") for a in flat_args)
