"""GIN: layer math vs numpy, compressed adjacency == raw edges, training."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.graph import compress_adjacency
from repro.data.sampler import CSRGraph
from repro.data.synthetic import molecule_batch, random_graph
from repro.models import gnn
from repro.nn.gnn import decode_compressed_edges, gin_layer, gin_layer_init
from repro.train import OptimizerConfig, init_train_state, make_train_step

pytestmark = pytest.mark.slow  # heavyweight model/system tier (deselected from tier-1)


def test_gin_layer_matches_numpy(rng):
    N, E, d, h = 10, 30, 4, 8
    params = gin_layer_init(jax.random.PRNGKey(0), d, h)
    feats = rng.standard_normal((N, d), dtype=np.float32)
    src = rng.integers(0, N, E).astype(np.int32)
    dst = rng.integers(0, N, E).astype(np.int32)
    out = gin_layer(params, jnp.asarray(feats), jnp.asarray(src), jnp.asarray(dst),
                    n_nodes=N, dtype=jnp.float32)
    agg = np.zeros((N, d), np.float32)
    np.add.at(agg, dst, feats[src])
    x = (1.0 + np.float32(params["eps"])) * feats + agg
    x = np.maximum(x @ np.asarray(params["mlp1"]["w"]) + np.asarray(params["b1"]), 0)
    x = x @ np.asarray(params["mlp2"]["w"]) + np.asarray(params["b2"])
    ref = np.maximum(x, 0)
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-4)


def test_compressed_adjacency_equals_raw(rng):
    g = random_graph(rng, 200, 1000, 8, 3)
    csr = CSRGraph.from_edges(g["edge_src"], g["edge_dst"], 200)
    comp = compress_adjacency(csr)
    n_edges = csr.n_edges
    src, dst = decode_compressed_edges(
        comp["gaps"], jnp.asarray(comp["row_offsets"]), n_edges)
    # decoded (neighbor, owner) pairs must equal the CSR content
    own = np.repeat(np.arange(200), np.diff(csr.indptr))
    np.testing.assert_array_equal(np.asarray(dst), own)
    np.testing.assert_array_equal(np.asarray(src), csr.indices)


def test_gnn_training_node_and_graph(rng):
    # node classification
    g = random_graph(rng, 64, 256, 12, 3)
    cfg = gnn.GNNConfig(name="t", n_layers=2, d_hidden=16, d_feat=12, n_classes=3)
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"feats": jnp.asarray(g["feats"]), "edge_src": jnp.asarray(g["edge_src"]),
             "edge_dst": jnp.asarray(g["edge_dst"]), "labels": jnp.asarray(g["labels"]),
             "label_mask": jnp.ones(64, bool)}
    state = init_train_state(params)
    step = jax.jit(make_train_step(lambda p, b: gnn.loss_fn(p, b, cfg),
                                   OptimizerConfig(peak_lr=1e-2, warmup_steps=1)))
    state, m0 = step(state, batch)
    for _ in range(10):
        state, m = step(state, batch)
    assert float(m["loss"]) < float(m0["loss"])

    # graph classification (molecule regime)
    mb = molecule_batch(rng, 8, 6, 12, 5, 2)
    cfg2 = gnn.GNNConfig(name="t2", n_layers=2, d_hidden=16, d_feat=5, n_classes=2,
                         task="graph")
    p2 = gnn.init_params(jax.random.PRNGKey(1), cfg2)
    batch2 = {k: jnp.asarray(v) for k, v in mb.items() if k != "n_graphs"}
    loss, aux = gnn.loss_fn(p2, batch2, cfg2)
    assert np.isfinite(float(loss))


def test_gnn_compressed_model_path(rng):
    """Full model consuming a compressed-adjacency batch == raw batch."""
    g = random_graph(rng, 50, 300, 6, 3)
    csr = CSRGraph.from_edges(g["edge_src"], g["edge_dst"], 50)
    comp = compress_adjacency(csr)
    cfg_raw = gnn.GNNConfig(name="r", n_layers=2, d_hidden=8, d_feat=6, n_classes=3)
    cfg_cmp = gnn.GNNConfig(name="c", n_layers=2, d_hidden=8, d_feat=6, n_classes=3,
                            compressed_adjacency=True)
    params = gnn.init_params(jax.random.PRNGKey(0), cfg_raw)
    own = np.repeat(np.arange(50), np.diff(csr.indptr)).astype(np.int32)
    raw_batch = {"feats": jnp.asarray(g["feats"]),
                 "edge_src": jnp.asarray(csr.indices.astype(np.int32)),
                 "edge_dst": jnp.asarray(own),
                 "labels": jnp.asarray(g["labels"]), "label_mask": jnp.ones(50, bool)}
    cmp_batch = {"feats": raw_batch["feats"], "labels": raw_batch["labels"],
                 "label_mask": raw_batch["label_mask"],
                 "edge_valid": jnp.ones(csr.n_edges, bool),
                 # the gaps CompressedIntArray is a pytree: tree.map uploads
                 # its leaves like any other batch entry
                 **jax.tree.map(jnp.asarray,
                                {k: v for k, v in comp.items()
                                 if not k.startswith("_")})}
    lr, _ = gnn.loss_fn(params, raw_batch, cfg_raw, dtype=jnp.float32)
    lc, _ = gnn.loss_fn(params, cmp_batch, cfg_cmp, dtype=jnp.float32)
    assert abs(float(lr) - float(lc)) < 1e-5
