"""Banded chunked-scatter decode cores: bit-exactness against the dense
cores, the gather oracle and the jnp decoders, across every edge the band
decomposition must preserve — count=0 blocks, uniform max-length blocks
(all-5-byte vbyte / all-4-byte streamvbyte), integers straddling chunk
boundaries, ragged tails, non-dividing chunk widths — plus the dispatch
plan axis (fused epilogues, differential on/off, jnp chunked grids) and
the chunk-width validation contract."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import CompressedIntArray
from repro.core.vbyte import masked as vmasked
from repro.core.vbyte import stream_masked as svb_masked
from repro.kernels.vbyte_decode import (dispatch, stream_vbyte_decode_blocked,
                                        vbyte_decode_blocked,
                                        vbyte_decode_blocked_ref)
from repro.kernels.vbyte_decode.banded import (normalize_chunk_width,
                                               place_bands, routing_cost,
                                               routing_reduction)
from repro.kernels.vbyte_decode.dispatch import DecodePlan
from repro.kernels.vbyte_decode.kernel import decode_tile
from repro.kernels.vbyte_decode.stream_kernel import stream_decode_tile

from conftest import make_valid_stream


def _tile_operands(vals, fmt, block_size, **enc):
    arr = CompressedIntArray.encode(vals, format=fmt, block_size=block_size,
                                    **enc)
    ops = arr.device_operands()
    counts2 = jnp.asarray(
        np.asarray(ops["counts"]).reshape(-1, 1).astype(np.int32))
    return arr, ops, counts2


def _assert_banded_equals_dense(vals, fmt, block_size, chunk_width, **enc):
    arr, ops, counts2 = _tile_operands(vals, fmt, block_size, **enc)
    if fmt == "vbyte":
        args = (jnp.asarray(ops["payload"]), counts2)
        dense, vd = decode_tile(*args, block_size=block_size)
        band, vb = decode_tile(*args, block_size=block_size,
                               chunk_width=chunk_width)
    else:
        args = (jnp.asarray(ops["control"]), jnp.asarray(ops["data"]), counts2)
        dense, vd = stream_decode_tile(*args, block_size=block_size)
        band, vb = stream_decode_tile(*args, block_size=block_size,
                                      chunk_width=chunk_width)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(band))
    np.testing.assert_array_equal(np.asarray(vd), np.asarray(vb))
    # and the decoded prefix round-trips to the input values
    flat = np.asarray(band).reshape(-1)[: len(vals)].astype(np.uint32)
    np.testing.assert_array_equal(flat.astype(np.uint64),
                                  vals.astype(np.uint64) & 0xFFFFFFFF)
    return arr


# ---------------------------------------------------------------------------
# core parity sweeps
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fmt", ["vbyte", "streamvbyte"])
@pytest.mark.parametrize("chunk_width", [8, 16, 24, 32, 64, 128])
def test_banded_equals_dense_mixed_lengths(rng, fmt, chunk_width):
    vals = make_valid_stream(rng, 1000)
    _assert_banded_equals_dense(vals, fmt, 128, chunk_width)


@pytest.mark.parametrize("fmt", ["vbyte", "streamvbyte"])
@pytest.mark.parametrize("block_size,chunk_width", [(8, 8), (32, 16), (64, 24)])
def test_banded_small_blocks(rng, fmt, block_size, chunk_width):
    vals = make_valid_stream(rng, 333)
    _assert_banded_equals_dense(vals, fmt, block_size, chunk_width)


@pytest.mark.parametrize("fmt", ["vbyte", "streamvbyte"])
def test_banded_tight_strides(rng, fmt):
    # stride_multiple=8 gives non-128-aligned payload strides that the
    # chunk grid must pad internally
    vals = make_valid_stream(rng, 300)
    _assert_banded_equals_dense(vals, fmt, 64, 48, stride_multiple=8)


def test_banded_all_five_byte_blocks():
    # every integer 2^32-1: vbyte blocks are uniformly 5 bytes/int, so
    # every chunk boundary splits an integer — the straddle-combine path
    # carries (almost) every output
    vals = np.full(257, 2**32 - 1, np.uint64)
    for W in (8, 32, 64):
        _assert_banded_equals_dense(vals, "vbyte", 128, W)


def test_banded_all_four_byte_blocks():
    # uniform 4-byte stream blocks: 4W data bytes per W-integer chunk —
    # the tight end of the ends-band bound
    vals = np.full(257, 2**32 - 1, np.uint64)
    for W in (8, 32, 64):
        _assert_banded_equals_dense(vals, "streamvbyte", 128, W)


def test_banded_all_one_byte_blocks():
    # all-zero values: 1 byte/int, maximal terminator density — chunk
    # bases grow fastest and the last chunks hold only padding
    vals = np.zeros(300, np.uint64)
    for fmt in ("vbyte", "streamvbyte"):
        _assert_banded_equals_dense(vals, fmt, 128, 32)


def test_banded_straddle_forced(rng):
    # W=8 with 2-5 byte integers: nearly every chunk boundary cuts an
    # integer in half; both chunks' partial sums must recombine exactly
    vals = make_valid_stream(rng, 400, max_bits=32)
    vals |= 1 << 14  # ≥3 bytes in vbyte, ≥2 data bytes in streamvbyte
    for fmt in ("vbyte", "streamvbyte"):
        _assert_banded_equals_dense(vals, fmt, 128, 8)


@pytest.mark.parametrize("fmt", ["vbyte", "streamvbyte"])
@pytest.mark.parametrize("n", [1, 7, 129, 1000])
def test_banded_ragged_tails(rng, fmt, n):
    vals = make_valid_stream(rng, n)
    _assert_banded_equals_dense(vals, fmt, 128, 32)


def test_banded_count_zero_blocks(rng):
    # append all-padding blocks (count 0, zero payload) to real operands —
    # the shape the sharded path's block padding produces
    vals = make_valid_stream(rng, 260)
    for fmt in ("vbyte", "streamvbyte"):
        arr, ops, _ = _tile_operands(vals, fmt, 128)
        padded = {
            k: jnp.asarray(np.concatenate(
                [np.asarray(v), np.zeros((2,) + np.asarray(v).shape[1:],
                                         np.asarray(v).dtype)]))
            for k, v in ops.items()
        }
        kw = dict(block_size=128, differential=False)
        if fmt == "vbyte":
            dense = vbyte_decode_blocked(**padded, **kw)
            band = vbyte_decode_blocked(**padded, chunk_width=32, **kw)
        else:
            dense = stream_vbyte_decode_blocked(**padded, **kw)
            band = stream_vbyte_decode_blocked(**padded, chunk_width=32, **kw)
        np.testing.assert_array_equal(np.asarray(dense), np.asarray(band))
        assert not np.asarray(band)[-2:].any()  # count-0 rows decode to 0


# ---------------------------------------------------------------------------
# kernel wrappers, oracles, differential
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fmt", ["vbyte", "streamvbyte"])
@pytest.mark.parametrize("differential", [False, True])
def test_banded_kernel_vs_oracles(rng, fmt, differential):
    if differential:
        vals = np.sort(rng.integers(0, 2**31, size=777)).astype(np.uint64)
    else:
        vals = make_valid_stream(rng, 777)
    arr = CompressedIntArray.encode(vals, format=fmt,
                                    differential=differential)
    ops = arr.device_operands()
    kw = dict(block_size=128, differential=differential)
    if fmt == "vbyte":
        band = vbyte_decode_blocked(**ops, chunk_width=64, **kw)
        ref = vbyte_decode_blocked_ref(**ops, **kw)
        msk = vmasked.decode_blocked(**ops, **kw)
    else:
        band = stream_vbyte_decode_blocked(**ops, chunk_width=64, **kw)
        ref = svb_masked.decode_blocked(**ops, **kw)
        msk = svb_masked.decode_blocked(**ops, chunk_width=64, **kw)
    np.testing.assert_array_equal(np.asarray(band), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(band), np.asarray(msk))


@pytest.mark.parametrize("fmt", ["vbyte", "streamvbyte"])
def test_jnp_chunked_grid_equals_dense(rng, fmt):
    # the chunked prefix decomposition of the vectorized jnp decoders is
    # value-identical to the plain cumsum by construction
    vals = make_valid_stream(rng, 500)
    arr = CompressedIntArray.encode(vals, format=fmt)
    ops = arr.device_operands()
    dec = vmasked.decode_blocked if fmt == "vbyte" else svb_masked.decode_blocked
    kw = dict(block_size=128, differential=False)
    a = dec(**ops, **kw)
    for W in (24, 32, 128):
        b = dec(**ops, chunk_width=W, **kw)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# dispatch plan axis + fused epilogues
# ---------------------------------------------------------------------------
def test_plan_chunk_axis_label_and_validation():
    assert DecodePlan("pallas", True, 8, 64).label == "pallas_fused_bt8_w64"
    assert DecodePlan("jnp", False, chunk=32).label == "jnp_unfused_w32"
    assert DecodePlan("jnp", True).label == "jnp_fused"
    with pytest.raises(ValueError):
        DecodePlan("pallas", True, 8, 12)  # not a multiple of 8
    with pytest.raises(ValueError):
        DecodePlan("pallas", True, 8, -8)
    with pytest.raises(ValueError):
        normalize_chunk_width(256, 128)  # band wider than the output
    assert normalize_chunk_width(64, 128) == 64


def test_default_chunk_clamped_to_block_size(rng):
    # heuristic chunk widths (DEFAULT_CHUNK, plan="banded") must shrink to
    # the workload's block size instead of tripping the band-width check
    assert dispatch._clamp_chunk(64, 32) == 32
    assert dispatch._clamp_chunk(64, 24) == 24
    assert dispatch._clamp_chunk(32, 128) == 32
    assert dispatch._clamp_chunk(None, 8) is None
    assert dispatch._clamp_chunk(64, 4) is None
    for fmt in ("vbyte", "streamvbyte"):
        plan = dispatch.resolve_plan("banded", format=fmt,
                                     epilogue="stream", block_size=8)
        assert plan.chunk is None or plan.chunk <= 8
        vals = make_valid_stream(rng, 100)
        arr = CompressedIntArray.encode(vals, format=fmt, block_size=8)
        np.testing.assert_array_equal(arr.decode(plan="banded"),
                                      arr.decode(plan="dense"))


def test_plan_strings_banded_dense(rng):
    vals = np.sort(rng.integers(0, 10000, size=300)).astype(np.uint64)
    for fmt in ("vbyte", "streamvbyte"):
        arr = CompressedIntArray.encode(vals, format=fmt, differential=True)
        a = arr.decode(plan="banded")
        b = arr.decode(plan="dense")
        c = arr.decode(plan="jnp")
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, c)


def test_plan_resolution_with_chunk_cache_entry(tmp_path, monkeypatch):
    import json

    cache = {"cpu/vbyte/stream/bs128": {
        "schema": dispatch.CACHE_SCHEMA,  # untagged entries are migrated away
        "plan": {"path": "jnp", "fused": True, "block_tile": 8, "chunk": 32}}}
    p = tmp_path / "autotune.json"
    p.write_text(json.dumps(cache))
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(p))
    dispatch.load_cache(str(p), reload=True)
    try:
        plan = dispatch.resolve_plan("auto", format="vbyte",
                                     epilogue="stream", block_size=128)
        if jax.default_backend() == "cpu":
            assert plan.chunk == 32
        # legacy entries without "chunk" resolve to dense
        plan2 = dispatch.resolve_plan(
            "auto", format="vbyte", epilogue="dot_score", block_size=128)
        assert plan2.chunk is None or isinstance(plan2.chunk, int)
    finally:
        dispatch.load_cache(reload=True)


@pytest.mark.parametrize("fmt", ["vbyte", "streamvbyte"])
@pytest.mark.parametrize("epilogue", ["bag_sum", "dot_score",
                                      "adjacency_rebase"])
def test_banded_fused_epilogues_parity(rng, fmt, epilogue):
    vals = np.sort(rng.integers(0, 2048, size=300)).astype(np.uint64)
    arr = CompressedIntArray.encode(vals, format=fmt, differential=True)
    ops = arr.device_operands()
    table = jnp.asarray(rng.standard_normal((2048, 8)).astype(np.float32))
    extras = {
        "bag_sum": {"table": table},
        "dot_score": {"table": table, "query": jnp.asarray(
            rng.standard_normal((1, 8)).astype(np.float32))},
        "adjacency_rebase": {"edge_base": jnp.asarray(
            rng.integers(0, 2048, (arr.n_blocks, 128)).astype(np.int32))},
    }[epilogue]
    outs = []
    for plan in (DecodePlan("pallas", True, 8, chunk=32),
                 DecodePlan("jnp", True, chunk=32),
                 "unfused"):
        o = dispatch.decode(ops, format=fmt, block_size=128,
                            differential=True, epilogue=epilogue,
                            epilogue_operands=extras, plan=plan)
        outs.append([np.asarray(x) for x in
                     (o if isinstance(o, tuple) else (o,))])
    for other in outs[1:]:
        for x, y in zip(outs[0], other):
            np.testing.assert_array_equal(x, y)


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >1 device (CI sharded job forces 8)")
def test_banded_sharded_parity(rng):
    vals = np.sort(rng.integers(0, 2**20, size=1200)).astype(np.uint64)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    for fmt in ("vbyte", "streamvbyte"):
        arr = CompressedIntArray.encode(vals, format=fmt, differential=True)
        sh = arr.shard(mesh)
        single = dispatch.decode(arr, plan=DecodePlan("jnp", True, chunk=32))
        sharded = dispatch.decode(sh, plan=DecodePlan("jnp", True, chunk=32))
        np.testing.assert_array_equal(
            np.asarray(single), np.asarray(sharded)[: arr.n_blocks])


# ---------------------------------------------------------------------------
# banded primitives + cost model
# ---------------------------------------------------------------------------
def test_place_bands_overlap_and_clip():
    bands = jnp.asarray(np.array([[[1, 2, 0], [3, 4, 5]]], np.int32))
    off = jnp.asarray(np.array([[1, 2]], np.int32))
    out = np.asarray(place_bands(bands, off, 6))
    # band 0 -> cols 1..3, band 1 -> cols 2..4 (overlap at 2..3 adds)
    np.testing.assert_array_equal(out, [[0, 1, 5, 4, 5, 0]])
    # offsets ≥ out_width push the whole band off the end
    out2 = np.asarray(place_bands(bands, jnp.asarray([[6, 7]], jnp.int32), 6))
    np.testing.assert_array_equal(out2, np.zeros((1, 6), np.int32))


def test_routing_cost_model_reduction():
    # the headline acceptance numbers: ≥4x modeled routing-MAC reduction
    # at the default shapes with the per-format default chunk widths
    assert routing_reduction("vbyte", S=640, B=128, W=64) >= 4.0
    assert routing_reduction("streamvbyte", S=512, B=128, W=32) >= 4.0
    d = routing_cost("vbyte", S=640, B=128, W=None)
    b = routing_cost("vbyte", S=640, B=128, W=64)
    assert b["vmem_total"] < d["vmem_total"] / 2  # the VMEM shrink is real
    assert b["vpu_total"] <= d["vpu_total"]
    with pytest.raises(ValueError):
        routing_cost("nope", S=640, B=128, W=64)
