"""Cross-decoder parity: for each format, the scalar oracle, the vectorized
jnp decoder and the Pallas interpret-mode kernel must agree **bit-exactly**
on randomized blocked inputs — parameterized over block_size, differential,
and ragged tails. This is the acceptance gate for the Stream-VByte tentpole:
``encode(format="streamvbyte").decode(plan="kernel")`` == scalar oracle on
>=10k randomized values."""
import numpy as np
import pytest

from repro.core import CompressedIntArray

from conftest import u32_cases


def _random_values(rng, n, differential):
    if differential:
        return np.sort(rng.integers(0, 2**31, size=n)).astype(np.uint64)
    bits = rng.integers(0, 33, size=n).astype(np.uint64)
    v = rng.integers(0, 1 << 62, size=n, dtype=np.uint64) >> (np.uint64(62) - bits)
    return np.minimum(v, np.uint64(2**32 - 1))


def _assert_parity(vals, fmt, block_size, differential):
    arr = CompressedIntArray.encode(vals, format=fmt, block_size=block_size,
                                    differential=differential)
    oracle = arr.decode_scalar_oracle()
    masked = arr.decode(plan="jnp")
    kernel = arr.decode(plan="kernel")
    np.testing.assert_array_equal(masked, oracle)
    np.testing.assert_array_equal(kernel, oracle)
    np.testing.assert_array_equal(oracle.astype(np.uint64), vals)


@pytest.mark.parametrize("fmt", ["vbyte", "streamvbyte", "binpack"])
@pytest.mark.parametrize("differential", [False, True])
@pytest.mark.parametrize("block_size", [8, 128])
# ragged tails: n chosen to land mid-block, one-past-boundary, and multi-block
@pytest.mark.parametrize("n", [1, 129, 517])
def test_parity_randomized(rng, fmt, differential, block_size, n):
    vals = _random_values(rng, n, differential)
    _assert_parity(vals, fmt, block_size, differential)


@pytest.mark.parametrize("fmt", ["vbyte", "streamvbyte", "binpack"])
def test_parity_property_cases(fmt):
    for case, vals in u32_cases(n_cases=10, max_len=300, seed=99):
        arr = CompressedIntArray.encode(vals, format=fmt, block_size=32)
        np.testing.assert_array_equal(arr.decode(), arr.decode_scalar_oracle(),
                                      err_msg=case)


def test_streamvbyte_kernel_acceptance(rng):
    """ISSUE acceptance: streamvbyte kernel decode bit-exact with the scalar
    oracle on >=10k randomized values spanning every byte-length regime."""
    vals = _random_values(rng, 10_240, False)
    arr = CompressedIntArray.encode(vals, format="streamvbyte")
    kernel = arr.decode(plan="kernel")
    np.testing.assert_array_equal(kernel, arr.decode_scalar_oracle())
    np.testing.assert_array_equal(kernel.astype(np.uint64), vals)


def test_streamvbyte_kernel_acceptance_differential(rng):
    vals = _random_values(rng, 10_240, True)
    arr = CompressedIntArray.encode(vals, format="streamvbyte",
                                    differential=True)
    kernel = arr.decode(plan="kernel")
    np.testing.assert_array_equal(kernel, arr.decode_scalar_oracle())
    np.testing.assert_array_equal(kernel.astype(np.uint64), vals)


@pytest.mark.parametrize("differential", [False, True])
def test_binpack_kernel_acceptance(rng, differential):
    """ISSUE acceptance: binpack kernel decode bit-exact with the scalar
    oracle on >=10k randomized values spanning every width regime."""
    vals = _random_values(rng, 10_240, differential)
    arr = CompressedIntArray.encode(vals, format="binpack",
                                    differential=differential)
    kernel = arr.decode(plan="kernel")
    np.testing.assert_array_equal(kernel, arr.decode_scalar_oracle())
    np.testing.assert_array_equal(kernel.astype(np.uint64), vals)


def test_partitioned_parity_and_compression(rng):
    """DP-partitioned arrays (variable counts mid-array) decode bit-exactly
    on every path, and the chosen codec never compresses worse than the
    uniform VByte baseline (the ISSUE's scoreboard guarantee)."""
    from repro.index.partition import choose_partition, encode_partitioned

    gaps = rng.integers(1, 9, 4000).astype(np.uint64)
    gaps[rng.random(4000) < 0.01] += 500_000  # outliers cut block widths
    vals = np.cumsum(gaps).astype(np.uint64)
    part = choose_partition(vals, block_size=128)
    arr = encode_partitioned(vals, part.bounds, format=part.format,
                             differential=True)
    uniform = CompressedIntArray.encode(vals, format="vbyte",
                                        differential=True)
    np.testing.assert_array_equal(arr.decode(plan="jnp"),
                                  vals.astype(np.uint32))
    np.testing.assert_array_equal(arr.decode(plan="kernel"),
                                  vals.astype(np.uint32))
    np.testing.assert_array_equal(arr.decode_scalar_oracle(),
                                  vals.astype(np.uint32))
    assert arr.bits_per_int <= uniform.bits_per_int + 1e-9
