"""Sharded block-parallel decode: bit-exact parity with the single-device
path for both formats (plain, differential, ragged, count=0 blocks, fused
epilogues), no cross-device collectives in the compiled decode, and the
ServingEngine over a multi-device mesh.

These tests need >1 device; CI runs them under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (see the `sharded`
job). On a single-device run they skip.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import CompressedIntArray
from repro.kernels.vbyte_decode import dispatch
from repro.kernels.vbyte_decode.dispatch import DecodePlan

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >1 device; run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")

FMTS = ["vbyte", "streamvbyte", "binpack"]
B = 32  # block size


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((len(jax.devices()),), ("data",))


def _tuple(x):
    return x if isinstance(x, tuple) else (x,)


# ---------------------------------------------------------------------------
# stream decode parity
# ---------------------------------------------------------------------------
@multi_device
@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("differential", [False, True])
# 2*B+7: ragged tail; B-1: single partial block; 40*B+3: blocks ≫ devices
@pytest.mark.parametrize("n", [B - 1, 2 * B + 7, 40 * B + 3])
def test_sharded_stream_parity(rng, mesh, fmt, differential, n):
    vals = np.sort(rng.integers(0, 2**20, n)).astype(np.uint64)
    if not differential:
        vals = rng.integers(0, 2**32, n).astype(np.uint64)
    arr = CompressedIntArray.encode(vals, format=fmt, block_size=B,
                                    differential=differential)
    ref = np.asarray(arr.decode_blocked(plan="jnp"))
    sh = arr.shard(mesh)
    assert sh.n_blocks % len(jax.devices()) == 0  # padded to divide the mesh
    out = np.asarray(dispatch.decode(sh, plan="sharded"))
    np.testing.assert_array_equal(out[: arr.n_blocks], ref)
    assert not out[arr.n_blocks:].any()  # padding blocks decode to nothing
    # the flat decode (and the auto-selected path) agree too
    np.testing.assert_array_equal(sh.decode(), vals.astype(np.uint32))


@multi_device
@pytest.mark.parametrize("fmt", FMTS)
def test_sharded_ragged_with_empty_bags(rng, mesh, fmt):
    """Ragged layout: count=0 bags interleaved; sharded == single-device."""
    lists = [np.sort(rng.choice(np.arange(1, 500), size=k, replace=False))
             .astype(np.uint64)
             for k in rng.integers(0, B + 1, size=11)]
    lists[2] = np.zeros(0, np.uint64)
    lists[10] = np.zeros(0, np.uint64)
    arr = CompressedIntArray.encode_ragged(lists, format=fmt, block_size=B,
                                           differential=True)
    sh = arr.shard(mesh)
    np.testing.assert_array_equal(sh.decode(), arr.decode())
    ref = np.asarray(arr.decode_blocked(plan="jnp"))
    out = np.asarray(sh.decode_blocked())
    np.testing.assert_array_equal(out[: arr.n_blocks], ref)


@multi_device
def test_plan_sharded_requires_sharded_operands(rng):
    arr, _ = CompressedIntArray.encode(
        np.arange(100, dtype=np.uint64)), None
    with pytest.raises(ValueError, match="requires operands"):
        dispatch.decode(arr, plan="sharded")


# ---------------------------------------------------------------------------
# fused epilogue parity
# ---------------------------------------------------------------------------
@multi_device
@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("plan", ["jnp", "kernel"])
def test_sharded_fused_epilogues_parity(rng, mesh, fmt, plan):
    vals = np.sort(rng.integers(0, 512, 10 * B + 9)).astype(np.uint64)
    table = jnp.asarray(rng.standard_normal((512, 16)).astype(np.float32))
    q1 = jnp.asarray(rng.standard_normal((1, 16)).astype(np.float32))
    q4 = jnp.asarray(rng.standard_normal((4, 16)).astype(np.float32))
    arr = CompressedIntArray.encode(vals, format=fmt, block_size=B,
                                    differential=True)
    sh = arr.shard(mesh)
    nb = arr.n_blocks
    eb = jnp.asarray(rng.integers(0, 512, (sh.n_blocks, B)).astype(np.int32))
    cases = [
        ("bag_sum", {"table": table}, {"table": table}),
        ("dot_score", {"table": table, "query": q1}, None),
        ("dot_score", {"table": table, "query": q4}, None),  # microbatched
        ("adjacency_rebase", {"edge_base": eb}, {"edge_base": eb[:nb]}),
    ]
    for ep, eops, ref_eops in cases:
        ref = dispatch.decode(arr, epilogue=ep,
                              epilogue_operands=ref_eops or eops, plan=plan)
        out = dispatch.decode(sh, epilogue=ep, epilogue_operands=eops,
                              plan=plan)
        for r, o in zip(_tuple(ref), _tuple(out)):
            r, o = np.asarray(r), np.asarray(o)
            np.testing.assert_array_equal(r, o[: r.shape[0]],
                                          err_msg=f"{fmt}/{ep}/{plan}")


@multi_device
def test_multi_query_dot_score_equals_per_query(rng, mesh):
    """The [b, d] query microbatch scores == b single-query passes."""
    vals = np.sort(rng.integers(0, 256, 4 * B)).astype(np.uint64)
    table = jnp.asarray(rng.standard_normal((256, 8)).astype(np.float32))
    qs = jnp.asarray(rng.standard_normal((3, 8)).astype(np.float32))
    sh = CompressedIntArray.encode(vals, block_size=B,
                                   differential=True).shard(mesh)
    ids_b, scores_b = dispatch.decode(
        sh, epilogue="dot_score",
        epilogue_operands={"table": table, "query": qs})
    assert scores_b.ndim == 3  # [nb, B, 3]
    for j in range(3):
        ids_1, scores_1 = dispatch.decode(
            sh, epilogue="dot_score",
            epilogue_operands={"table": table, "query": qs[j:j + 1]})
        np.testing.assert_array_equal(np.asarray(ids_b), np.asarray(ids_1))
        np.testing.assert_array_equal(np.asarray(scores_b)[..., j],
                                      np.asarray(scores_1))


# ---------------------------------------------------------------------------
# no cross-device decode traffic
# ---------------------------------------------------------------------------
@multi_device
@pytest.mark.parametrize("fmt", FMTS)
def test_sharded_decode_compiles_without_collectives(rng, mesh, fmt):
    """The whole point of block-parallel decode: the compiled program moves
    no decoded (or compressed) bytes between devices."""
    vals = np.sort(rng.integers(0, 2**18, 16 * B)).astype(np.uint64)
    sh = CompressedIntArray.encode(vals, format=fmt, block_size=B,
                                   differential=True).shard(mesh)
    fn = dispatch._build_sharded_fn(
        mesh, ("data",), fmt, "stream", B, True, DecodePlan("jnp", True),
        None, False)
    txt = fn.lower(sh.device_operands(), {}).compile().as_text()
    for coll in ("all-reduce", "all-gather", "collective-permute",
                 "all-to-all", "reduce-scatter"):
        assert coll not in txt, f"{fmt} sharded decode emitted {coll}"


# ---------------------------------------------------------------------------
# the serving engine on a mesh
# ---------------------------------------------------------------------------
@multi_device
def test_serving_engine_matches_direct_scoring(rng, mesh):
    from repro.launch.serve import ServingEngine
    from repro.models import recsys
    from repro.models.registry import reduced_config

    cfg = reduced_config("two-tower-retrieval")
    params = recsys.init_params(jax.random.PRNGKey(0), cfg)
    cands = np.sort(rng.choice(np.arange(1, cfg.n_items), 300,
                               replace=False)).astype(np.uint64)
    corpus = CompressedIntArray.encode(cands, differential=True)
    engine = ServingEngine(params, cfg, corpus, mesh=mesh, top_k=5)
    engine.warmup()

    uid = jnp.asarray([7, 3], jnp.int32)
    hist = jnp.asarray(rng.integers(1, cfg.n_items, (2, cfg.seq_len)),
                       jnp.int32)
    top_s, top_i = engine.retrieve(uid, hist)
    assert top_s.shape == (2, 5) and top_i.shape == (2, 5)
    top_i, top_s = np.asarray(top_i), np.asarray(top_s)
    assert np.all(np.isin(top_i, cands))  # pad slots masked out
    assert np.all(np.diff(top_s, axis=1) <= 1e-6)  # descending

    # direct reference: same user vectors against the same item table, in
    # the engine's compute dtype (bf16 gathers/dots, like the epilogue)
    u = engine._user_fn(params, uid, hist)  # [2, d] bf16
    vecs = jnp.take(engine.item_table, jnp.asarray(cands.astype(np.int32)),
                    axis=0)
    direct = np.asarray(jnp.einsum("cd,rd->cr", vecs, u).astype(jnp.float32))
    for r in range(2):
        order = np.argsort(-direct[:, r], kind="stable")[:5]
        np.testing.assert_allclose(top_s[r], direct[order, r],
                                   rtol=1e-6, atol=1e-6)

    stats = engine.run_workload(
        [(1, rng.integers(1, cfg.n_items, cfg.seq_len).astype(np.int32))
         for _ in range(9)],
        max_batch=16)  # above the largest bucket: must clamp, not crash
    assert stats["n_requests"] == 9 and stats["qps"] > 0
    assert stats["p99_ms"] >= stats["p50_ms"] > 0
    assert stats["n_devices"] == len(jax.devices())


@multi_device
def test_engine_embedding_bag_endpoint(rng, mesh):
    from repro.launch.serve import ServingEngine
    from repro.models import recsys
    from repro.models.registry import reduced_config
    from repro.nn.embedding_bag import bag_from_padded

    cfg = reduced_config("two-tower-retrieval")
    params = recsys.init_params(jax.random.PRNGKey(0), cfg)
    corpus = CompressedIntArray.encode(
        np.arange(1, 200, dtype=np.uint64), differential=True)
    engine = ServingEngine(params, cfg, corpus, mesh=mesh)
    bags = [np.sort(rng.choice(np.arange(1, cfg.n_items), size=k,
                               replace=False))
            for k in (4, 1, cfg.seq_len)]
    out = np.asarray(engine.embed_bags(bags))
    assert out.shape == (3, cfg.id_dim)
    padded = np.zeros((3, cfg.seq_len), np.int32)
    for i, l in enumerate(bags):
        padded[i, : len(l)] = l
    ref = np.asarray(bag_from_padded(
        params["item_id_emb"]["emb"], jnp.asarray(padded), mode="mean",
        dtype=engine.dtype))
    np.testing.assert_allclose(out, ref, rtol=1e-2, atol=1e-2)
