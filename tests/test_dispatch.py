"""Dispatch layer: plan resolution, the persisted autotune cache, and the
counts/bases shape contract at the ops boundary."""
import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import CompressedIntArray
from repro.kernels.vbyte_decode import dispatch, normalize_block_meta
from repro.kernels.vbyte_decode.dispatch import DecodePlan


# ---------------------------------------------------------------------------
# counts/bases shape contract
# ---------------------------------------------------------------------------
def test_normalize_block_meta_accepts_both_shapes():
    flat = jnp.arange(4, dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(normalize_block_meta("counts", flat, 4)), np.arange(4))
    np.testing.assert_array_equal(
        np.asarray(normalize_block_meta("counts", flat[:, None], 4)),
        np.arange(4))


@pytest.mark.parametrize("bad_shape", [(3,), (4, 2), (1, 4), (4, 1, 1)])
def test_normalize_block_meta_rejects(bad_shape):
    x = jnp.zeros(bad_shape, jnp.int32)
    with pytest.raises(ValueError, match=r"counts must have shape \[n_blocks\]"):
        normalize_block_meta("counts", x, 4)


@pytest.mark.parametrize("plan", ["jnp", "kernel"])
def test_decoders_accept_column_metadata(rng, plan):
    """[n_blocks, 1] counts/bases decode identically to [n_blocks]."""
    vals = np.sort(rng.integers(0, 2**20, 200)).astype(np.uint64)
    for fmt in ("vbyte", "streamvbyte"):
        arr = CompressedIntArray.encode(vals, format=fmt, differential=True)
        ops = dict(arr.device_operands())
        ref = arr.decode(plan=plan)
        ops["counts"] = ops["counts"][:, None]
        ops["bases"] = ops["bases"][:, None]
        out = dispatch.decode(ops, format=fmt, block_size=128,
                              differential=True, plan=plan)
        np.testing.assert_array_equal(
            np.asarray(out).reshape(-1)[: arr.n].astype(np.uint32), ref)


def test_decode_rejects_wrong_length_counts(rng):
    vals = np.sort(rng.integers(0, 2**20, 200)).astype(np.uint64)
    arr = CompressedIntArray.encode(vals, differential=True)
    ops = dict(arr.device_operands())
    ops["counts"] = ops["counts"][:-1]
    with pytest.raises(ValueError, match="counts must have shape"):
        dispatch.decode(ops, format="vbyte", block_size=128,
                        differential=True, plan="jnp")


# ---------------------------------------------------------------------------
# plan resolution
# ---------------------------------------------------------------------------
def test_resolve_plan_aliases():
    kw = dict(format="vbyte", epilogue="bag_sum", block_size=128)
    assert dispatch.resolve_plan("kernel", **kw) == DecodePlan("pallas", True)
    assert dispatch.resolve_plan("jnp", **kw) == DecodePlan("jnp", True)
    assert dispatch.resolve_plan("unfused", **kw).fused is False
    assert dispatch.resolve_plan("fused", **kw).fused is True
    custom = DecodePlan("pallas", False, 16)
    assert dispatch.resolve_plan(custom, **kw) is custom
    with pytest.raises(ValueError, match="unknown plan"):
        dispatch.resolve_plan("warp-speed", **kw)
    with pytest.raises(ValueError, match="unknown plan path"):
        DecodePlan("cuda", True)


def test_epilogue_operand_validation(rng):
    vals = np.sort(rng.integers(0, 512, 64)).astype(np.uint64)
    arr = CompressedIntArray.encode(vals, block_size=32, differential=True)
    ops = arr.device_operands()
    with pytest.raises(ValueError, match="unknown epilogue"):
        dispatch.decode(ops, format="vbyte", block_size=32, differential=True,
                        epilogue="frobnicate")
    with pytest.raises(ValueError, match="missing \\['table'\\]"):
        dispatch.decode(ops, format="vbyte", block_size=32, differential=True,
                        epilogue="bag_sum", epilogue_operands={})
    with pytest.raises(ValueError, match="requires differential=True"):
        dispatch.decode(ops, format="vbyte", block_size=32, differential=False,
                        epilogue="adjacency_rebase",
                        epilogue_operands={"edge_base": jnp.zeros((2, 32),
                                                                 jnp.int32)})


# ---------------------------------------------------------------------------
# measured autotune cache
# ---------------------------------------------------------------------------
def test_autotune_persists_and_auto_plan_reads_cache(tmp_path, monkeypatch):
    cache_file = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(cache_file))
    cache = dispatch.autotune(
        formats=("vbyte",), epilogue_names=("bag_sum",), block_size=32,
        n_blocks=8, vocab=256, d=8, reps=1, warmup=1,
        cache_file=str(cache_file))
    key = dispatch.cache_key("vbyte", "bag_sum", 32)
    assert key in cache and "plan" in cache[key]
    on_disk = json.loads(cache_file.read_text())
    assert on_disk[key]["candidates_ms"]

    # "auto" resolves to the measured best, not the heuristic default
    dispatch.load_cache(str(cache_file), reload=True)
    plan = dispatch.resolve_plan("auto", format="vbyte", epilogue="bag_sum",
                                 block_size=32)
    assert plan == DecodePlan(**on_disk[key]["plan"])
    # unmeasured workloads fall back to the heuristic
    fallback = dispatch.resolve_plan("auto", format="streamvbyte",
                                     epilogue="dot_score", block_size=32)
    expected = dispatch.default_plan("dot_score", "streamvbyte")
    assert fallback == dispatch.replace(
        expected, chunk=dispatch._clamp_chunk(expected.chunk, 32))
    dispatch.load_cache(reload=True)  # restore global cache state


def test_cache_migration_drops_stale_schema_entries(tmp_path, monkeypatch):
    """A two-format-era cache (no per-entry schema tag, or an old one) must
    be invalidated on load: stale plans were measured before binpack joined
    the format registry and can resolve to a plan shape that no longer
    matches the codec (e.g. a banded chunk for a format with no length
    scan). Every stale entry falls back to the heuristic default."""
    cache_file = tmp_path / "autotune.json"
    key = dispatch.cache_key("vbyte", "bag_sum", 32)
    old_key = dispatch.cache_key("streamvbyte", "dot_score", 32)
    cache_file.write_text(json.dumps({
        key: {"plan": {"path": "jnp", "fused": False, "chunk": 64}},
        old_key: {"schema": 1,
                  "plan": {"path": "pallas", "fused": True, "chunk": 64}},
        "garbage": "not-a-dict",
    }))
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(cache_file))
    cache = dispatch.load_cache(str(cache_file), reload=True)
    assert cache == {}  # versionless + old-schema + junk all dropped

    for fmt, epi in (("vbyte", "bag_sum"), ("streamvbyte", "dot_score"),
                     ("binpack", "bag_sum")):
        plan = dispatch.resolve_plan("auto", format=fmt, epilogue=epi,
                                     block_size=32)
        expected = dispatch.default_plan(epi, fmt)
        assert plan == dispatch.replace(
            expected, chunk=dispatch._clamp_chunk(expected.chunk, 32))

    # current-schema entries survive the same migration pass untouched
    good = {"schema": dispatch.CACHE_SCHEMA,
            "plan": {"path": "jnp", "fused": True, "chunk": None},
            "candidates_ms": {}}
    cache_file.write_text(json.dumps({key: good, old_key: {"schema": 0}}))
    cache = dispatch.load_cache(str(cache_file), reload=True)
    assert cache == {key: good}
    dispatch.load_cache(reload=True)  # restore global cache state


def test_auto_plan_decodes_correctly(rng):
    """End to end: plan='auto' (whatever the cache says) is bit-correct."""
    vals = np.sort(rng.integers(0, 512, 100)).astype(np.uint64)
    for fmt in ("vbyte", "streamvbyte"):
        arr = CompressedIntArray.encode(vals, format=fmt, block_size=32,
                                        differential=True)
        out = arr.decode(plan="auto")
        np.testing.assert_array_equal(out.astype(np.uint64), vals)
