"""RecSys models: losses train, serve scores, compressed retrieval parity."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import CompressedIntArray
from repro.data.synthetic import recsys_batch
from repro.models import recsys
from repro.models.registry import reduced_config
from repro.train import OptimizerConfig, init_train_state, make_train_step

pytestmark = pytest.mark.slow  # heavyweight model/system tier (deselected from tier-1)

KINDS = ["sasrec", "bert4rec", "bst", "two_tower"]
ARCH_OF = {"sasrec": "sasrec", "bert4rec": "bert4rec", "bst": "bst",
           "two_tower": "two-tower-retrieval"}


def small_cfg(kind):
    return reduced_config(ARCH_OF[kind])


@pytest.mark.parametrize("kind", KINDS)
def test_training_reduces_loss(rng, kind):
    cfg = small_cfg(kind)
    params = recsys.init_params(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in recsys_batch(
        rng, kind, 32, cfg.seq_len, cfg.n_items, n_mask=cfg.n_mask,
        n_negatives=cfg.n_negatives, n_users=cfg.n_users).items()}
    state = init_train_state(params)
    step = jax.jit(make_train_step(lambda p, b: recsys.loss_fn(p, b, cfg),
                                   OptimizerConfig(peak_lr=5e-3, warmup_steps=1)))
    state, m0 = step(state, batch)
    for _ in range(8):
        state, m = step(state, batch)
    assert float(m["loss"]) < float(m0["loss"]), kind
    assert np.isfinite(float(m["grad_norm"]))


@pytest.mark.parametrize("kind", KINDS)
def test_serve_scores_shapes(rng, kind):
    cfg = small_cfg(kind)
    params = recsys.init_params(jax.random.PRNGKey(0), cfg)
    B, C = 4, cfg.serve_candidates
    if kind == "bst":
        batch = {"hist": jnp.asarray(rng.integers(1, cfg.n_items, (B, cfg.seq_len)),
                                     dtype=jnp.int32),
                 "target": jnp.asarray(rng.integers(1, cfg.n_items, B), dtype=jnp.int32)}
        out = recsys.serve_scores(params, batch, cfg)
        assert out.shape == (B,)
    elif kind == "two_tower":
        batch = {"user_id": jnp.asarray(rng.integers(1, 100, B), dtype=jnp.int32),
                 "hist": jnp.asarray(rng.integers(0, cfg.n_items, (B, cfg.seq_len)),
                                     dtype=jnp.int32),
                 "cands": jnp.asarray(rng.integers(1, cfg.n_items, C), dtype=jnp.int32)}
        out = recsys.serve_scores(params, batch, cfg)
        assert out.shape == (B, C)
    else:
        batch = {"hist": jnp.asarray(rng.integers(1, cfg.n_items, (B, cfg.seq_len)),
                                     dtype=jnp.int32),
                 "cands": jnp.asarray(rng.integers(1, cfg.n_items, (B, C)),
                                      dtype=jnp.int32)}
        out = recsys.serve_scores(params, batch, cfg)
        assert out.shape == (B, C)
    assert np.all(np.isfinite(np.asarray(out)))


@pytest.mark.parametrize("kind", ["two_tower", "sasrec"])
def test_retrieval_compressed_matches_direct(rng, kind):
    """Decoding the candidate list inside the graph == scoring raw ids."""
    cfg = small_cfg(kind)
    params = recsys.init_params(jax.random.PRNGKey(0), cfg)
    n_cand = 256
    cands = np.sort(rng.choice(np.arange(1, cfg.n_items), n_cand, replace=False))
    arr = CompressedIntArray.encode(cands.astype(np.uint64), differential=True)
    batch = {"cands": arr,  # the CompressedIntArray itself is the batch entry
             "hist": jnp.asarray(rng.integers(1, cfg.n_items, (1, cfg.seq_len)),
                                 dtype=jnp.int32)}
    if kind == "two_tower":
        batch["user_id"] = jnp.asarray([7], dtype=jnp.int32)
    scores, (top_s, top_i) = recsys.retrieval_scores_compressed(
        params, batch, cfg, top_k=10)
    assert scores.shape[0] >= n_cand
    # direct scoring of the same ids
    if kind == "two_tower":
        u = recsys.user_tower(params, batch["user_id"], batch["hist"], cfg)
        i = recsys.item_tower(params, jnp.asarray(cands.astype(np.int32)), cfg)
        direct = np.asarray((i @ u[0]).astype(jnp.float32))
    else:
        h = recsys._seq_repr(params, batch["hist"], cfg, causal=True,
                             dtype=jnp.bfloat16)[:, -1]
        import repro.nn.layers as nnl
        vecs = nnl.embedding_lookup(params["item_emb"],
                                    jnp.asarray(cands.astype(np.int32)))
        direct = np.asarray((vecs @ h[0]).astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(scores[:n_cand]), direct, atol=1e-2)
    assert np.all(np.isfinite(np.asarray(top_s)))
