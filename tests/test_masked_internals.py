"""Property tests on the vectorized decoders' internal invariants — the
arithmetic identities that replace the papers' lookup tables (DESIGN.md §2),
for both the Masked-VByte path and the Stream-VByte control-stream path.
Seeded case generators from conftest — no hypothesis dependency."""
import numpy as np

import jax.numpy as jnp

from repro.core.vbyte import encode as venc
from repro.core.vbyte import stream_vbyte as svb
from repro.core.vbyte.masked import (byte_contributions, continuation_bits,
                                     in_integer_positions)
from repro.core.vbyte.stream_masked import (control_codes, integer_lengths,
                                            start_offsets)

from conftest import u32_cases


_PAD_BYTES = 640  # fixed stream size: every case hits the same jitted shapes


def _cases(**kw):
    kw.setdefault("n_cases", 50)
    kw.setdefault("max_len", 100)
    kw.setdefault("min_len", 1)
    return u32_cases(**kw)


def _padded(stream):
    """Zero-pad to a fixed length (zeros are inert: cont=0, contrib=0)."""
    out = np.zeros(_PAD_BYTES, np.uint8)
    out[: len(stream)] = stream
    return jnp.asarray(out)


# -- Masked-VByte internals ---------------------------------------------------
def test_positions_match_byte_lengths():
    """pos must count 0,1,2,... within each encoded integer."""
    for case, vals in _cases():
        stream = venc.encode_stream(vals)
        lengths = venc.vbyte_lengths(vals)
        expected = np.concatenate([np.arange(l) for l in lengths])
        cont = continuation_bits(_padded(stream)[None])
        pos = np.asarray(in_integer_positions(cont))[0, : len(stream)]
        np.testing.assert_array_equal(pos, expected, err_msg=case)


def test_contributions_sum_to_value():
    """Σ contributions over each integer's bytes == the integer (mod 2^32)."""
    for case, vals in _cases():
        stream = venc.encode_stream(vals)
        data = _padded(stream)[None]
        cont = continuation_bits(data)
        pos = in_integer_positions(cont)
        contrib = np.asarray(byte_contributions(data, pos))[0, : len(stream)]
        end = 1 - np.asarray(cont)[0, : len(stream)]
        out_idx = np.cumsum(end) - end
        sums = np.zeros(len(vals), np.uint64)
        np.add.at(sums, out_idx, contrib.astype(np.uint64))
        np.testing.assert_array_equal(sums & 0xFFFFFFFF, vals, err_msg=case)


def test_terminator_count_equals_integer_count():
    for case, vals in _cases():
        stream = venc.encode_stream(vals)
        cont = np.asarray(continuation_bits(_padded(stream)))[: len(stream)]
        assert int((1 - cont).sum()) == len(vals), case


def test_wraparound_identity():
    """uint32 wraparound in the 16-bit-split MXU path == modular arithmetic."""
    vals = np.array([2**32 - 1, 2**31, 0x89ABCDEF], np.uint64)
    from repro.core.compressed_array import CompressedIntArray

    arr = CompressedIntArray.encode(vals, block_size=8)
    assert np.array_equal(arr.decode(plan="kernel").astype(np.uint64), vals)


# -- Stream-VByte internals ---------------------------------------------------
def test_control_codes_roundtrip_pack():
    """jnp unpack of the packed control stream == the encoder's codes."""
    B = 128  # fixed block: every case hits the same jitted shapes
    for case, vals in _cases():
        lengths = svb.svb_lengths(vals)
        codes = np.zeros(B, np.uint8)
        codes[: len(vals)] = (lengths - 1).astype(np.uint8)
        packed = svb.pack_control(codes)
        got = np.asarray(control_codes(jnp.asarray(packed)[None], B))[0]
        np.testing.assert_array_equal(got, codes, err_msg=case)
        np.testing.assert_array_equal(
            svb.unpack_control(packed, len(vals)), lengths - 1, err_msg=case)


def test_svb_start_offsets_match_byte_layout():
    """start_j must equal the cumulative data bytes before integer j."""
    for case, vals in _cases():
        lengths = svb.svb_lengths(vals)
        enc = svb.encode_blocked(vals, block_size=128, stride_multiple=128)
        codes = control_codes(jnp.asarray(enc.control), enc.block_size)
        lens = integer_lengths(codes, jnp.asarray(enc.counts))
        starts = np.asarray(start_offsets(lens))[0]
        expected = np.concatenate([[0], np.cumsum(lengths)[:-1]])
        np.testing.assert_array_equal(starts[: len(vals)], expected, err_msg=case)


def test_svb_lengths_are_whole_bytes():
    """Stream-VByte length = ceil(bit_length/8), clamped to [1, 4]."""
    for case, vals in _cases():
        lens = svb.svb_lengths(vals)
        expected = [max(1, -(-int(v).bit_length() // 8)) for v in vals]
        np.testing.assert_array_equal(lens, expected, err_msg=case)


def test_svb_wraparound_identity():
    vals = np.array([2**32 - 1, 2**31, 0x89ABCDEF], np.uint64)
    from repro.core.compressed_array import CompressedIntArray

    arr = CompressedIntArray.encode(vals, format="streamvbyte", block_size=8)
    assert np.array_equal(arr.decode(plan="kernel").astype(np.uint64), vals)
