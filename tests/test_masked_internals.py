"""Property tests on the vectorized decoder's internal invariants — the
arithmetic identities that replace the paper's lookup tables (DESIGN.md §2)."""
import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.vbyte import encode as venc
from repro.core.vbyte.masked import (byte_contributions, continuation_bits,
                                     in_integer_positions)

u32_lists = st.lists(st.integers(min_value=0, max_value=2**32 - 1),
                     min_size=1, max_size=100)


@given(u32_lists)
@settings(max_examples=50, deadline=None)
def test_positions_match_byte_lengths(values):
    """pos must count 0,1,2,... within each encoded integer."""
    vals = np.array(values, np.uint64)
    stream = venc.encode_stream(vals)
    lengths = venc.vbyte_lengths(vals)
    expected = np.concatenate([np.arange(l) for l in lengths])
    cont = continuation_bits(jnp.asarray(stream)[None])
    pos = np.asarray(in_integer_positions(cont))[0]
    np.testing.assert_array_equal(pos, expected)


@given(u32_lists)
@settings(max_examples=50, deadline=None)
def test_contributions_sum_to_value(values):
    """Σ contributions over each integer's bytes == the integer (mod 2^32)."""
    vals = np.array(values, np.uint64)
    stream = jnp.asarray(venc.encode_stream(vals))
    cont = continuation_bits(stream[None])
    pos = in_integer_positions(cont)
    contrib = np.asarray(byte_contributions(stream[None], pos))[0].astype(np.uint64)
    end = 1 - np.asarray(cont)[0]
    out_idx = np.cumsum(end) - end
    sums = np.zeros(len(vals), np.uint64)
    np.add.at(sums, out_idx, contrib)
    np.testing.assert_array_equal(sums & 0xFFFFFFFF, vals)


@given(u32_lists)
@settings(max_examples=50, deadline=None)
def test_terminator_count_equals_integer_count(values):
    vals = np.array(values, np.uint64)
    stream = venc.encode_stream(vals)
    cont = np.asarray(continuation_bits(jnp.asarray(stream)))
    assert int((1 - cont).sum()) == len(vals)


def test_wraparound_identity():
    """uint32 wraparound in the 16-bit-split MXU path == modular arithmetic."""
    vals = np.array([2**32 - 1, 2**31, 0x89ABCDEF], np.uint64)
    from repro.core.compressed_array import CompressedIntArray

    arr = CompressedIntArray.encode(vals, block_size=8)
    assert np.array_equal(arr.decode(use_kernel=True).astype(np.uint64), vals)
