"""MoE dispatch invariants."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.nn.moe import _positions_within_expert, moe_apply, moe_init

pytestmark = pytest.mark.slow  # heavyweight model/system tier (deselected from tier-1)


def dense_reference(params, x, top_k, renormalize=True):
    """Compute the mixture exactly: every expert on every token, gated."""
    logits = x @ params["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, top_k)
    if renormalize:
        top_p = top_p / top_p.sum(-1, keepdims=True)
    g = jnp.einsum("td,edf->tef", x, params["gate"]["w"])
    u = jnp.einsum("td,edf->tef", x, params["up"]["w"])
    h = jax.nn.silu(g) * u
    y = jnp.einsum("tef,efd->ted", h, params["down"]["w"])  # [T, E, d]
    out = jnp.zeros_like(x)
    for k in range(top_k):
        out = out + top_p[:, k, None] * jnp.take_along_axis(
            y, top_e[:, k, None, None].repeat(x.shape[1], -1), axis=1)[:, 0]
    return out


def test_positions_within_expert():
    flat = jnp.array([1, 0, 1, 1, 0, 2], jnp.int32)
    pos = _positions_within_expert(flat, 3)
    assert pos.tolist() == [0, 0, 1, 2, 1, 0]


@pytest.mark.parametrize("groups", [1, 4])
def test_moe_matches_dense_reference_no_drops(groups):
    key = jax.random.PRNGKey(0)
    params = moe_init(key, 16, 32, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    out, aux = moe_apply(params, x, top_k=2, capacity_factor=8.0,  # no drops
                         dispatch_groups=groups, dtype=jnp.float32)
    ref = dense_reference(params, x, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)
    assert float(aux["moe_drop_frac"]) == 0.0


def test_moe_capacity_drops_counted():
    params = moe_init(jax.random.PRNGKey(0), 16, 32, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    _, aux = moe_apply(params, x, top_k=2, capacity_factor=0.25,
                       dtype=jnp.float32)
    assert float(aux["moe_drop_frac"]) > 0.0
    assert 0.0 < float(aux["moe_aux_loss"]) < 10.0


def test_moe_grads_finite():
    params = moe_init(jax.random.PRNGKey(0), 16, 32, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))

    def loss(p):
        out, aux = moe_apply(p, x, top_k=2, dtype=jnp.float32)
        return jnp.sum(out ** 2) + aux["moe_aux_loss"]

    g = jax.grad(loss)(params)
    assert all(np.all(np.isfinite(np.asarray(t))) for t in jax.tree.leaves(g))
