"""Robustness property tests: detect-or-defined-value (docs/robustness.md).

The contract, per corruption class × format × vectorized plan: a corrupted
stream is either **detected** — a typed :class:`DecodeError` subclass with
block/term coordinates from the validators or the checksum-verified decode
— or **provably harmless** — every plan decodes it to the same defined
value (no crash, dense and banded bit-identical), so the serving layer can
degrade instead of dying. With the checksum column present, *every*
corruption class must land on the detected side.

Also covers: encode-time input validation (satellite of the same PR),
checksum survival through ``take_blocks``/``slice_blocks`` and the pytree
protocol, deadline-degraded query semantics, and the hardened
``SearchEngine`` paths (retry, quarantine, bound fallback, shard loss).
"""
import dataclasses

import numpy as np
import pytest

from conftest import make_valid_stream

from repro.core import CompressedIntArray
from repro.core.vbyte import encode as venc
from repro.core.vbyte import stream_vbyte as svb
from repro.index import QueryStats, build_index, conjunctive, disjunctive, topk
from repro.kernels.vbyte_decode import dispatch
from repro.robustness import (BlockMetaError, BoundViolationError,
                              ChecksumError, Deadline, DecodeError,
                              decode_checked, validate_array, validate_meta)
from repro.robustness import faultgen
from repro.robustness.validate import expected_checksums

FORMATS = ("vbyte", "streamvbyte", "binpack")
PLANS = ("jnp", "banded")  # the vectorized grid plans (dense + banded)
SEEDS = (0, 1, 2)


def _clean_array(fmt, *, n=200, block_size=64, differential=False,
                 checksum=True, seed=0):
    rng = np.random.default_rng(seed)
    vals = make_valid_stream(rng, n,
                             max_bits=30 if fmt == "streamvbyte" else 32)
    if differential:
        vals = np.cumsum(vals % 997).astype(np.uint64)  # sorted, in-range
    return CompressedIntArray.encode(vals, format=fmt, block_size=block_size,
                                     differential=differential,
                                     checksum=checksum)


# ---------------------------------------------------------------------------
# encode-time input validation (core/vbyte/encode.py, stream_vbyte.py)
# ---------------------------------------------------------------------------
class TestEncodeValidation:
    @pytest.mark.parametrize("fmt", FORMATS)
    def test_negative_rejected(self, fmt):
        with pytest.raises(ValueError, match="non-negative"):
            CompressedIntArray.encode(np.array([3, -1, 5]), format=fmt)

    @pytest.mark.parametrize("fmt", FORMATS)
    def test_out_of_range_rejected(self, fmt):
        with pytest.raises(ValueError, match="2\\^32"):
            CompressedIntArray.encode(np.array([1, 2**32], np.uint64),
                                      format=fmt)

    @pytest.mark.parametrize("fmt", FORMATS)
    def test_float_rejected(self, fmt):
        with pytest.raises(ValueError, match="integer"):
            CompressedIntArray.encode(np.array([1.5, 2.5]), format=fmt)

    @pytest.mark.parametrize("fmt", FORMATS)
    def test_wrap_escape_hatch(self, fmt):
        # wrap=True is the explicit opt-in: values wrap mod 2^32
        vals = np.array([-1, 2**32 + 5, 7], np.object_).astype(np.int64)
        arr = CompressedIntArray.encode(vals, format=fmt, wrap=True)
        np.testing.assert_array_equal(
            arr.decode(), np.array([2**32 - 1, 5, 7], np.uint32))

    def test_stream_encoders_validate(self):
        for enc in (venc.encode_stream, svb.encode_stream):
            with pytest.raises(ValueError, match="non-negative"):
                enc(np.array([-2]))
            enc(np.array([-2]), wrap=True)  # escape hatch

    @pytest.mark.parametrize("fmt", FORMATS)
    def test_ragged_lists_validated_with_coordinates(self, fmt):
        with pytest.raises(ValueError, match="list 1"):
            CompressedIntArray.encode_ragged([[1, 2], [3, -4]], format=fmt)

    def test_error_message_names_the_fix(self):
        with pytest.raises(ValueError, match="wrap=True"):
            venc.encode_blocked(np.array([-1]))


# ---------------------------------------------------------------------------
# checksum column: round-trip, epilogue parity, block ops, pytree
# ---------------------------------------------------------------------------
class TestChecksum:
    @pytest.mark.parametrize("fmt", FORMATS)
    @pytest.mark.parametrize("differential", (False, True))
    def test_column_matches_scalar_recompute(self, fmt, differential):
        arr = _clean_array(fmt, differential=differential)
        np.testing.assert_array_equal(arr.checksums, expected_checksums(arr))

    @pytest.mark.parametrize("fmt", FORMATS)
    @pytest.mark.parametrize("plan", PLANS)
    def test_checked_decode_bit_exact_with_unchecked(self, fmt, plan):
        arr = _clean_array(fmt)
        grid = decode_checked(arr, plan=plan)
        ref = np.asarray(arr.decode_blocked(plan=plan))
        np.testing.assert_array_equal(grid, ref.astype(np.uint32))

    @pytest.mark.parametrize("fmt", FORMATS)
    def test_checksum_epilogue_fused_unfused_parity(self, fmt):
        arr = _clean_array(fmt)
        _, cs_f = dispatch.decode(arr, epilogue="checksum", plan="fused")
        _, cs_u = dispatch.decode(arr, epilogue="checksum", plan="unfused")
        np.testing.assert_array_equal(np.asarray(cs_f), np.asarray(cs_u))

    @pytest.mark.parametrize("fmt", FORMATS)
    def test_take_and_slice_blocks_carry_checksums(self, fmt):
        arr = _clean_array(fmt, n=500, block_size=64)
        sub = arr.take_blocks(np.array([5, 1, 3]))
        np.testing.assert_array_equal(
            sub.checksums, np.asarray(arr.checksums)[[5, 1, 3]])
        decode_checked(sub, plan="jnp")  # still verifies
        sl = arr.slice_blocks(2, 6, pad_to=8)
        assert np.asarray(sl.checksums).shape[0] == 8
        assert not np.asarray(sl.checksums)[4:].any()  # pad blocks -> 0
        decode_checked(sl, plan="jnp")

    @pytest.mark.parametrize("fmt", FORMATS)
    def test_pytree_roundtrip_drops_checksums_like_host_enc(self, fmt):
        import jax

        arr = _clean_array(fmt)
        leaves, treedef = jax.tree_util.tree_flatten(arr)
        back = jax.tree_util.tree_unflatten(treedef, leaves)
        # off-tree host metadata: dropped inside jit/shard_map, and the
        # unchecked decode of the rebuilt array is unchanged
        assert back.checksums is None
        np.testing.assert_array_equal(np.asarray(back.decode_blocked()),
                                      np.asarray(arr.decode_blocked()))

    def test_decode_checked_requires_column(self):
        arr = _clean_array("vbyte", checksum=False)
        with pytest.raises(ValueError, match="checksum=True"):
            decode_checked(arr)

    def test_builder_threads_checksum_to_both_streams(self):
        rng = np.random.default_rng(0)
        docs = np.unique(rng.integers(0, 1 << 20, 400))
        index = build_index({0: docs}, tfs={0: 1 + (np.arange(docs.size) % 5)},
                            n_docs=1 << 20, checksum=True)
        tp = index.terms[0]
        assert tp.arr.checksums is not None
        assert tp.impacts.checksums is not None
        decode_checked(tp.arr, plan="jnp")
        decode_checked(tp.impacts, plan="jnp")


# ---------------------------------------------------------------------------
# the fuzz contract: every corruption class is detect-or-defined-value
# ---------------------------------------------------------------------------
def _detect(arr, term=None):
    """Run the full detection stack; return the typed error or None."""
    try:
        validate_array(arr, term=term)
        if arr.checksums is not None:
            decode_checked(arr, plan="jnp", term=term)
        return None
    except DecodeError as e:
        return e


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("cls", sorted(faultgen.STREAM_CLASSES))
@pytest.mark.parametrize("seed", SEEDS)
def test_corruption_detected_with_checksums(fmt, cls, seed):
    """With the checksum column present, every applicable corruption class
    must be *detected* — a typed DecodeError carrying coordinates."""
    differential = cls == "base_corrupt"
    arr = _clean_array(fmt, differential=differential, seed=seed)
    c = faultgen.corrupt(arr, cls, seed)
    if c is None:
        pytest.skip(f"{cls} does not apply to {fmt}")
    err = _detect(c.arr, term=42)
    assert isinstance(err, DecodeError), (cls, c.detail)
    assert err.term == 42 or err.block is not None, (cls, str(err))
    # and the clean twin still passes: detection is not a false positive
    assert _detect(arr, term=42) is None


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("cls", sorted(faultgen.STREAM_CLASSES))
@pytest.mark.parametrize("seed", SEEDS)
def test_corruption_detect_or_defined_without_checksums(fmt, cls, seed):
    """Without checksums, a corruption that slips past the host validators
    must decode to the same *defined* value on every vectorized plan."""
    differential = cls == "base_corrupt"
    arr = _clean_array(fmt, differential=differential, checksum=False,
                       seed=seed)
    c = faultgen.corrupt(arr, cls, seed)
    if c is None:
        pytest.skip(f"{cls} does not apply to {fmt} without checksums")
    if _detect(c.arr) is not None:
        return  # detected: the strong outcome
    grids = [np.asarray(c.arr.decode_blocked(plan=p)) for p in PLANS]
    np.testing.assert_array_equal(grids[0], grids[1])
    # the scalar oracle agrees on the valid prefix, too: defined garbage,
    # identical everywhere — serving can quarantine and move on
    flat = c.arr.decode(plan=PLANS[0])
    assert flat.shape == (c.arr.n,) and flat.dtype == np.uint32


@pytest.mark.parametrize("cls", sorted(faultgen.INDEX_CLASSES))
def test_index_corruption_detected(cls):
    rng = np.random.default_rng(0)
    docs = np.unique(rng.integers(0, 1 << 20, 600))
    index = build_index({7: docs}, tfs={7: 1 + (np.arange(docs.size) % 7)},
                        n_docs=1 << 20, checksum=True)
    tp = faultgen.INDEX_CLASSES[cls](index.terms[7], seed=3)
    with pytest.raises(DecodeError) as ei:
        validate_meta(tp, deep=True)
        if tp.impacts is not None:
            decode_checked(tp.impacts, plan="jnp", term=7)
    if cls == "max_impact_under":
        assert isinstance(ei.value, BoundViolationError)
    assert ei.value.term == 7 or ei.value.block is not None


def test_single_value_corruption_always_caught():
    """The odd positional weights are invertible mod 2^32: ANY single-slot
    delta shifts the checksum. Exhaustively perturb every slot."""
    arr = _clean_array("vbyte", n=16, block_size=8)
    grid = np.asarray(arr.decode_blocked(plan="jnp")).astype(np.uint64)
    counts = np.asarray(arr.counts)
    from repro.core.compressed_array import block_checksums

    clean = block_checksums(grid, counts)
    rng = np.random.default_rng(0)
    for b in range(grid.shape[0]):
        for j in range(int(counts[b])):
            g = grid.copy()
            g[b, j] ^= np.uint64(1) << np.uint64(rng.integers(32))
            assert block_checksums(g, counts)[b] != clean[b]


def test_partitioned_array_detect_or_defined():
    """DP-partitioned (variable-count) arrays pass the validators clean and
    keep the detect contract under corruption."""
    from repro.index.partition import choose_partition, encode_partitioned

    rng = np.random.default_rng(3)
    gaps = rng.integers(1, 9, 900).astype(np.uint64)
    gaps[rng.random(900) < 0.02] += 100_000
    vals = np.cumsum(gaps).astype(np.uint64)
    part = choose_partition(vals, block_size=64)
    arr = encode_partitioned(vals, part.bounds, format=part.format,
                             block_size=64, differential=True,
                             checksum=True)
    assert _detect(arr) is None
    for cls in ("bit_flip", "count_under", "width_deflate"):
        c = faultgen.corrupt(arr, cls, 0)
        if c is None:
            continue
        assert isinstance(_detect(c.arr), DecodeError), cls


# ---------------------------------------------------------------------------
# deadline-degraded query semantics
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def small_index():
    rng = np.random.default_rng(0)
    lists = {t: np.unique(rng.integers(0, 1 << 16, 300)) for t in range(4)}
    tfs = {t: 1 + (np.arange(len(v)) % 6) for t, v in lists.items()}
    return build_index(lists, tfs=tfs, n_docs=1 << 16, checksum=True)


def _expired_deadline():
    return Deadline(0.0, clock=lambda: 1.0, start=0.0)


class TestDeadlines:
    def test_deadline_expiry_is_monotonic(self):
        t = {"v": 0.0}
        d = Deadline(5.0, clock=lambda: t["v"])
        assert not d.expired() and d.remaining() == 5.0
        t["v"] = 6.0
        assert d.expired() and d.remaining() == 0.0
        t["v"] = 0.0  # clock regression cannot un-expire (`hit` latches)
        assert d.expired()

    def test_conjunctive_expired_returns_flagged_superset(self, small_index):
        exact = conjunctive(small_index, [0, 1, 2])
        st = QueryStats()
        out = conjunctive(small_index, [0, 1, 2], stats=st,
                          deadline=_expired_deadline())
        assert st.degraded and any(r.startswith("deadline:")
                                   for r in st.degraded_reasons)
        assert np.isin(exact, out).all()  # AND degrades to a superset

    def test_disjunctive_expired_returns_flagged_subset(self, small_index):
        exact = disjunctive(small_index, [0, 1, 2])
        st = QueryStats()
        out = disjunctive(small_index, [0, 1, 2], stats=st,
                          deadline=_expired_deadline())
        assert st.degraded
        assert np.isin(out, exact).all()  # OR degrades to a subset

    def test_topk_expired_flags_and_returns_defined(self, small_index):
        st = QueryStats()
        ids, scores = topk(small_index, [0, 1, 2, 3], 10, mode="maxscore",
                           stats=st, deadline=_expired_deadline())
        assert st.degraded
        assert ids.dtype == np.uint32 and scores.dtype == np.int32
        assert ids.shape == scores.shape

    def test_no_deadline_is_bit_exact_and_unflagged(self, small_index):
        st = QueryStats()
        a = topk(small_index, [0, 1, 2], 10, mode="maxscore", stats=st)
        b = topk(small_index, [0, 1, 2], 10, mode="or")
        assert not st.degraded
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# the hardened SearchEngine (launch/serve.py)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def engine_index():
    rng = np.random.default_rng(1)
    lists = {t: np.unique(rng.integers(0, 1 << 18, 400)) for t in range(8)}
    tfs = {t: 1 + (np.arange(len(v)) % 5) for t, v in lists.items()}
    return build_index(lists, tfs=tfs, n_docs=1 << 18, checksum=True)


class TestHardenedEngine:
    def _mk(self, index, **kw):
        from repro.launch.serve import SearchEngine

        return SearchEngine(index, **kw)

    def test_transient_fault_retried_to_exact_result(self, engine_index):
        def hook(attempt, terms, mode):
            if attempt == 0:
                raise ChecksumError("injected", format="vbyte", block=0)

        eng = self._mk(engine_index, fault_hook=hook, max_retries=2)
        st = QueryStats()
        out = eng.search([0, 1], "topk_maxscore", stats=st)
        ref = self._mk(engine_index).search([0, 1], "topk_maxscore")
        for a, b in zip(out, ref):
            np.testing.assert_array_equal(a, b)
        assert st.retries == 1 and st.errors == 1 and not st.degraded

    def test_retries_exhausted_degrades_never_hangs(self, engine_index):
        def hook(attempt, terms, mode):
            raise ChecksumError("persistent")

        eng = self._mk(engine_index, fault_hook=hook, max_retries=2)
        st = QueryStats()
        out = eng.search([0, 1], "or", stats=st)
        assert out.size == 0
        assert st.degraded and "retries-exhausted" in st.degraded_reasons
        assert st.errors == 3 and st.retries == 2
        assert eng.serve_stats["degraded_responses"] == 1

    def test_term_coordinate_fault_quarantines_segment(self, engine_index):
        def hook(attempt, terms, mode):
            if 1 in terms:
                raise ChecksumError("bad segment", block=2, term=1)

        eng = self._mk(engine_index, fault_hook=hook)
        st = QueryStats()
        out = eng.search([0, 1], "or", stats=st)
        np.testing.assert_array_equal(
            out, self._mk(engine_index).search([0], "or"))
        assert 1 in eng.quarantined and st.degraded
        assert st.quarantined_blocks == 0  # charged at fault time, not twice
        st2 = QueryStats()
        eng.search([1], "or", stats=st2)  # later queries skip it up front
        assert st2.degraded and st2.quarantined_blocks > 0

    def test_startup_validation_quarantines_corrupt_stream(self, engine_index):
        terms = dict(engine_index.terms)
        bad = faultgen.corrupt(terms[2].arr, "bit_flip", 5)
        terms[2] = dataclasses.replace(terms[2], arr=bad.arr)
        index = dataclasses.replace(engine_index, terms=terms)
        eng = self._mk(index, validate=True)
        assert 2 in eng.quarantined
        assert eng.serve_stats["quarantined_blocks"] == terms[2].n_blocks
        st = QueryStats()
        out = eng.search([2, 3], "or", stats=st)
        np.testing.assert_array_equal(
            out, self._mk(engine_index).search([3], "or"))
        assert st.degraded

    def test_unsafe_bound_forces_exact_taat_fallback(self, engine_index):
        terms = dict(engine_index.terms)
        terms[3] = faultgen.corrupt_max_impact(terms[3], 7)
        index = dataclasses.replace(engine_index, terms=terms)
        eng = self._mk(index, validate=True, deep_validate=True)
        assert 3 in eng.bound_unsafe and 3 not in eng.quarantined
        st = QueryStats()
        out = eng.search([3, 4], "topk_maxscore", stats=st)
        # the fallback answers from the SAME (bound-corrupt) index — exact
        # because TAAT never consults max_impact — matching the clean index
        ref = self._mk(engine_index).search([3, 4], "topk")
        for a, b in zip(out, ref):
            np.testing.assert_array_equal(a, b)
        assert st.bound_fallbacks == 1 and not st.degraded

    def test_dead_shard_partial_results_then_heal(self, engine_index):
        eng = self._mk(engine_index, n_shards=4)
        victim_terms = eng.term_order[slice(*eng.shards[1])]
        clean = eng.search(list(engine_index.terms), "or")
        eng.kill_shard(1)
        st = QueryStats()
        out = eng.search(list(engine_index.terms), "or", stats=st)
        assert st.degraded and any(r.startswith("dead-shard:")
                                   for r in st.degraded_reasons)
        assert np.isin(out, clean).all() and out.size < clean.size
        plan = eng.heal()
        assert len(plan) == 3 and not eng.dead_shards
        assert all(eng.shard_of[t] < 3 for t in victim_terms)
        st2 = QueryStats()
        np.testing.assert_array_equal(
            eng.search(list(engine_index.terms), "or", stats=st2), clean)
        assert not st2.degraded

    def test_engine_deadline_budget_flags_response(self, engine_index):
        t = {"v": 0.0}

        def clock():
            t["v"] += 0.3
            return t["v"]

        eng = self._mk(engine_index, deadline_s=0.1, clock=clock)
        st = QueryStats()
        eng.search([0, 1, 2], "or", stats=st)
        assert st.degraded
        assert eng.serve_stats["degraded_responses"] == 1

    def test_stats_merge_aggregates_per_query(self):
        agg, one = QueryStats(), QueryStats()
        one.count(3, decoded=2, skipped=1, ints=10)
        one.mark_degraded("deadline:test")
        one.retries = 2
        agg.merge(one)
        agg.merge(one)
        assert agg.blocks_decoded == 4 and agg.retries == 4
        assert agg.degraded and agg.degraded_reasons == ["deadline:test"]


# ---------------------------------------------------------------------------
# SearchEngine over a LiveIndex-merged segment (format="auto", DP-partitioned)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def merged_segment(tmp_path_factory):
    """An index produced by the ingestion path: stream docs into a
    LiveIndex, background-merge into a ``format="auto"`` segment, reload
    it from disk — the exact artifact serving sees after a merge."""
    import os

    from repro.index import LiveIndex
    from repro.index.ingest import load_segment

    d = str(tmp_path_factory.mktemp("live") / "ix")
    rng = np.random.default_rng(4)
    live = LiveIndex(d, n_docs=1 << 16, fsync=False)
    for doc in np.unique(rng.integers(0, 1 << 16, 500)):
        live.add(int(doc), {int(t): int(rng.integers(1, 5))
                            for t in rng.choice(8, rng.integers(1, 4),
                                                replace=False)})
    live.merge()
    seg = os.path.join(d, "segments", sorted(os.listdir(
        os.path.join(d, "segments")))[0])
    index, _tfs, _docs = load_segment(seg)
    live.close()
    return index


class TestEngineOnMergedSegment:
    def _mk(self, index, **kw):
        from repro.launch.serve import SearchEngine

        return SearchEngine(index, **kw)

    def test_startup_validation_passes_clean_merged_segment(self, merged_segment):
        assert merged_segment.format == "auto"
        # the DP partitioner assigned real per-term codecs round-tripped
        # through segment persistence
        fmts = {tp.arr.format for tp in merged_segment.terms.values()}
        assert fmts and fmts <= set(FORMATS)
        eng = self._mk(merged_segment, validate=True, deep_validate=True)
        assert not eng.quarantined and not eng.bound_unsafe
        st = QueryStats()
        out = eng.search([0, 1, 2], "topk_maxscore", stats=st)
        ref = topk(merged_segment, [0, 1, 2], 10, mode="or")
        for a, b in zip(out, ref):
            np.testing.assert_array_equal(a, b)
        assert not st.degraded

    def test_startup_quarantine_on_corrupt_merged_term(self, merged_segment):
        terms = dict(merged_segment.terms)
        bad = faultgen.corrupt(terms[5].arr, "bit_flip", 11)
        terms[5] = dataclasses.replace(terms[5], arr=bad.arr)
        index = dataclasses.replace(merged_segment, terms=terms)
        eng = self._mk(index, validate=True)
        assert 5 in eng.quarantined
        st = QueryStats()
        out = eng.search([5, 6], "or", stats=st)
        np.testing.assert_array_equal(
            out, self._mk(merged_segment).search([6], "or"))
        assert st.degraded

    def test_heal_after_shard_loss_on_merged_segment(self, merged_segment):
        eng = self._mk(merged_segment, n_shards=3)
        all_terms = list(merged_segment.terms)
        clean = eng.search(all_terms, "or")
        eng.kill_shard(0)
        st = QueryStats()
        partial = eng.search(all_terms, "or", stats=st)
        assert st.degraded and partial.size < clean.size
        eng.heal()
        assert not eng.dead_shards
        np.testing.assert_array_equal(eng.search(all_terms, "or"), clean)
