"""Flash attention vs naive oracle; RoPE; decode attention; cache update."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.nn.attention import (apply_rope, cache_update, decode_attention,
                                flash_attention)


def naive_attention(q, k, v, causal=True, window=None):
    B, S, H, D = q.shape
    Hk = k.shape[2]
    G = H // Hk
    qf = q.astype(jnp.float32).reshape(B, S, Hk, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32)) * D ** -0.5
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    m = jnp.ones((S, S), bool)
    if causal:
        m &= i >= j
    if window is not None:
        m &= (i - j) < window
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, D)


def _qkv(key, B=2, S=128, H=4, Hk=2, D=16):
    k1, k2, k3 = jax.random.split(key, 3)
    return (jax.random.normal(k1, (B, S, H, D)),
            jax.random.normal(k2, (B, S, Hk, D)),
            jax.random.normal(k3, (B, S, Hk, D)))


@pytest.mark.parametrize("window,banded", [(None, False), (32, False), (32, True),
                                           (128, True)])
@pytest.mark.parametrize("qc,kc", [(32, 32), (64, 16), (128, 128)])
def test_flash_matches_naive(window, banded, qc, kc):
    q, k, v = _qkv(jax.random.PRNGKey(0))
    out = flash_attention(q, k, v, causal=True, window=window, q_chunk=qc,
                          kv_chunk=kc, banded=banded, dtype=jnp.float32)
    ref = naive_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_mha_no_gqa():
    q, k, v = _qkv(jax.random.PRNGKey(1), H=4, Hk=4)
    out = flash_attention(q, k, v, q_chunk=32, kv_chunk=32, dtype=jnp.float32)
    ref = naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_rope_preserves_norm_and_relative():
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 2, 16))
    pos = jnp.arange(8)[None]
    y = apply_rope(x, pos)
    np.testing.assert_allclose(  # rotation: per-pair norms preserved
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)
    # relative property: <R_m q, R_n k> depends only on n-m
    q = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 1, 1, 16))
    def dot_at(m, n):
        qm = apply_rope(q, jnp.array([[m]]))
        kn = apply_rope(k, jnp.array([[n]]))
        return float(jnp.sum(qm * kn))
    assert abs(dot_at(3, 5) - dot_at(10, 12)) < 1e-4


def test_rope_partial_rotary():
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 4, 2, 16))
    y = apply_rope(x, jnp.arange(4)[None], rotary_dim=8)
    np.testing.assert_allclose(np.asarray(x[..., 8:]), np.asarray(y[..., 8:]),
                               atol=1e-6)  # non-rotary dims untouched


def test_decode_attention_matches_full():
    B, S, H, Hk, D = 2, 16, 4, 2, 8
    q, k, v = _qkv(jax.random.PRNGKey(6), B, S, H, Hk, D)
    full = naive_attention(q, k, v, causal=True)
    out = decode_attention(q[:, -1], k, v, jnp.arange(S) < S, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, -1]), atol=2e-5)


def test_cache_update_slot():
    cache = jnp.zeros((2, 8, 2, 4))
    new = jnp.ones((2, 2, 4))
    out = cache_update(cache, new, jnp.int32(3))
    assert float(out[:, 3].sum()) == 2 * 2 * 4
    assert float(out.sum()) == 2 * 2 * 4
