"""Device encoder round-trips through every decoder, and matches the host
encoder bit-for-bit at equal stride."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.vbyte import encode as host_enc
from repro.core.vbyte.device_encode import encode_blocked_device
from repro.core.vbyte.masked import decode_blocked
from repro.kernels.vbyte_decode import vbyte_decode_blocked

from conftest import make_valid_stream


def _pad(vals, block):
    padn = (-len(vals)) % block
    return np.concatenate([vals, np.zeros(padn, vals.dtype)]), padn


@pytest.mark.parametrize("differential", [False, True])
@pytest.mark.parametrize("n", [128, 256, 1024])
def test_device_encode_roundtrip(rng, differential, n):
    if differential:
        vals = np.sort(rng.integers(0, 2**31, size=n)).astype(np.uint64)
    else:
        vals = make_valid_stream(rng, n)
    out = encode_blocked_device(jnp.asarray(vals.astype(np.uint32)),
                                block_size=128, stride=640,
                                differential=differential)
    dec = decode_blocked(out["payload"], out["counts"], out["bases"],
                         block_size=128, differential=differential)
    np.testing.assert_array_equal(
        np.asarray(dec).reshape(-1)[:n].astype(np.uint64), vals)
    ker = vbyte_decode_blocked(out["payload"], out["counts"], out["bases"],
                               block_size=128, differential=differential)
    np.testing.assert_array_equal(np.asarray(ker), np.asarray(dec))


def test_device_encoder_matches_host_bytes(rng):
    vals = make_valid_stream(rng, 256)
    host = host_enc.encode_blocked(vals, block_size=128, differential=False,
                                   stride_multiple=640, min_stride=640)
    dev = encode_blocked_device(jnp.asarray(vals.astype(np.uint32)),
                                block_size=128, stride=640)
    np.testing.assert_array_equal(np.asarray(dev["payload"]), host.payload)
    np.testing.assert_array_equal(np.asarray(dev["bases"]), host.bases)


@given(st.lists(st.integers(min_value=0, max_value=2**32 - 1),
                min_size=1, max_size=200))
@settings(max_examples=25, deadline=None)
def test_prop_device_encode_roundtrip(values):
    vals = np.array(values, np.uint64)
    padded, padn = _pad(vals, 64)
    out = encode_blocked_device(jnp.asarray(padded.astype(np.uint32)),
                                block_size=64, stride=320)
    dec = decode_blocked(out["payload"], out["counts"], out["bases"],
                         block_size=64, differential=False)
    np.testing.assert_array_equal(
        np.asarray(dec).reshape(-1)[:len(vals)].astype(np.uint64), vals)
