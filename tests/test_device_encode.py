"""Device encoder round-trips through every decoder, and matches the host
encoder bit-for-bit at equal stride. Seeded case generators from conftest —
no hypothesis dependency."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.vbyte import encode as host_enc
from repro.core.vbyte.device_encode import encode_blocked_device
from repro.core.vbyte.masked import decode_blocked
from repro.kernels.vbyte_decode import vbyte_decode_blocked

from conftest import make_valid_stream, u32_cases


def _pad(vals, block):
    padn = (-len(vals)) % block
    return np.concatenate([vals, np.zeros(padn, vals.dtype)]), padn


@pytest.mark.parametrize("differential", [False, True])
@pytest.mark.parametrize("n", [128, 256, 1024])
def test_device_encode_roundtrip(rng, differential, n):
    if differential:
        vals = np.sort(rng.integers(0, 2**31, size=n)).astype(np.uint64)
    else:
        vals = make_valid_stream(rng, n)
    out = encode_blocked_device(jnp.asarray(vals.astype(np.uint32)),
                                block_size=128, stride=640,
                                differential=differential)
    dec = decode_blocked(out["payload"], out["counts"], out["bases"],
                         block_size=128, differential=differential)
    np.testing.assert_array_equal(
        np.asarray(dec).reshape(-1)[:n].astype(np.uint64), vals)
    ker = vbyte_decode_blocked(out["payload"], out["counts"], out["bases"],
                               block_size=128, differential=differential)
    np.testing.assert_array_equal(np.asarray(ker), np.asarray(dec))


def test_device_encoder_matches_host_bytes(rng):
    vals = make_valid_stream(rng, 256)
    host = host_enc.encode_blocked(vals, block_size=128, differential=False,
                                   stride_multiple=640, min_stride=640)
    dev = encode_blocked_device(jnp.asarray(vals.astype(np.uint32)),
                                block_size=128, stride=640)
    np.testing.assert_array_equal(np.asarray(dev["payload"]), host.payload)
    np.testing.assert_array_equal(np.asarray(dev["bases"]), host.bases)


def test_prop_device_encode_roundtrip():
    for case, vals in u32_cases(n_cases=8, max_len=200, min_len=1, seed=21):
        padded, _ = _pad(vals, 64)
        out = encode_blocked_device(jnp.asarray(padded.astype(np.uint32)),
                                    block_size=64, stride=320)
        dec = decode_blocked(out["payload"], out["counts"], out["bases"],
                             block_size=64, differential=False)
        np.testing.assert_array_equal(
            np.asarray(dec).reshape(-1)[:len(vals)].astype(np.uint64), vals,
            err_msg=case)
