"""Telemetry subsystem: null fast path, metrics merge algebra, span trees,
exporter round-trips, and the end-to-end wiring contracts.

The load-bearing guarantees (docs/observability.md):

* With nothing installed, ``trace()`` returns the shared ``NULL_SPAN``
  singleton — no allocation, no recording — and query results are
  bit-identical with telemetry on, off, and after uninstall.
* Registry merges are associative/commutative (histograms merge bucket
  counts, counters add, gauges last-write), so per-shard registries fold
  in any order.
* A ``mode="maxscore"`` topk produces one span tree whose stage durations
  sum to the root wall time, with decode spans carrying
  (format, plan, epilogue) attribution.
* ``QueryStats.merge`` iterates dataclass fields — adding a field of an
  unmergeable type fails loudly instead of silently dropping counts.
"""
import json

import numpy as np
import pytest

from repro import obs
from repro.obs.exporters import (chrome_trace_events, parse_prometheus,
                                 read_chrome_trace, read_jsonl)


@pytest.fixture(autouse=True)
def _clean_telemetry():
    """Every test starts and ends with no telemetry installed."""
    obs.uninstall()
    yield
    obs.uninstall()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# null fast path
# ---------------------------------------------------------------------------
def test_null_recorder_is_identity_singleton():
    s1 = obs.trace("decode", format="vbyte")
    s2 = obs.trace("anything")
    assert s1 is obs.NULL_SPAN and s2 is obs.NULL_SPAN  # no allocation
    assert not s1  # falsy: `if span:` guards attr computation
    with s1 as sp:
        sp.set(a=1).event("x", b=2)  # all no-ops, chainable, re-entrant
    assert obs.current() is obs.NULL_SPAN
    # metric helpers are no-ops too
    obs.counter_inc("c", 5, lbl="x")
    obs.gauge_set("g", 3)
    obs.histogram_observe("h", 0.5)
    assert obs.installed() is None


def test_install_uninstall_and_nesting():
    t1, t2 = obs.Telemetry(), obs.Telemetry()
    with obs.install(t1):
        assert obs.installed() is t1
        with obs.install(t2):
            assert obs.installed() is t2
            with obs.trace("inner"):
                pass
        assert obs.installed() is t1  # nested install restored the outer
        with obs.trace("outer"):
            pass
    assert obs.installed() is None
    assert [s["name"] for s in t1.tracer.spans] == ["outer"]
    assert [s["name"] for s in t2.tracer.spans] == ["inner"]


def test_null_path_allocates_no_span_records():
    tele = obs.Telemetry()
    with obs.install(tele):
        with obs.trace("on"):
            pass
    # uninstalled again: tracing leaves no trace anywhere
    before = len(tele.tracer.spans)
    for _ in range(100):
        with obs.trace("off"):
            obs.counter_inc("c")
    assert len(tele.tracer.spans) == before == 1
    assert not tele.registry.snapshot()["metrics"]


# ---------------------------------------------------------------------------
# metrics algebra
# ---------------------------------------------------------------------------
def test_histogram_buckets_exact_boundaries():
    from repro.obs.metrics import MIN_EXP, bucket_exp

    assert bucket_exp(0.25) == -2  # exact power of two: its own bucket
    assert bucket_exp(8) == 3
    assert bucket_exp(8.0001) == 4
    assert bucket_exp(9) == 4
    assert bucket_exp(0) == MIN_EXP
    assert bucket_exp(-5) == MIN_EXP


def test_injected_clock_pins_exact_histogram_buckets():
    """A simulated clock drives timer() durations, so the test pins the
    exact bucket each observation lands in — no real-time flakiness."""
    now = [0.0]
    reg = obs.MetricsRegistry(clock=lambda: now[0])
    for dt in (0.25, 0.25, 0.1, 3.0):
        with reg.timer("stage_seconds"):
            now[0] += dt
    snap = reg.snapshot()["metrics"]["stage_seconds"]
    # 0.25 = 2^-2 exactly (twice); 0.1 in (2^-4, 2^-3]; 3.0 in (2, 4]
    assert snap["buckets"] == {"-3": 1, "-2": 2, "2": 1}
    assert snap["count"] == 4 and snap["max"] == 3.0
    assert snap["min"] == pytest.approx(0.1)
    assert reg.histogram("stage_seconds").quantile(0.5) == 0.25


def test_histogram_merge_associative_across_shard_order(rng):
    """Folding per-shard histograms must give one aggregate regardless of
    merge order/grouping — the property that lets shards and benchmark
    subprocesses aggregate without coordination."""
    from repro.obs.metrics import Histogram

    shard_samples = [rng.exponential(0.01, size=50) for _ in range(4)]

    def fold(order, grouping):
        hs = []
        for i in order:
            h = Histogram()
            for v in shard_samples[i]:
                h.observe(float(v))
            hs.append(h)
        if grouping == "left":  # ((0+1)+2)+3
            acc = hs[0]
            for h in hs[1:]:
                acc.merge(h)
        else:  # (0+1) + (2+3)
            hs[0].merge(hs[1])
            hs[2].merge(hs[3])
            hs[0].merge(hs[2])
            acc = hs[0]
        return acc.snapshot()

    ref = fold([0, 1, 2, 3], "left")
    assert fold([3, 1, 0, 2], "left") == ref
    assert fold([2, 0, 3, 1], "pairs") == ref
    assert ref["count"] == 200


def test_registry_merge_counters_gauges_events():
    a, b = obs.MetricsRegistry(), obs.MetricsRegistry()
    a.counter("reqs", engine="search").inc(3)
    b.counter("reqs", engine="search").inc(4)
    b.counter("reqs", engine="live").inc(1)
    a.gauge("epoch").set(1)
    b.gauge("epoch").set(7)  # gauge: last write (the merged-in side) wins
    a.record_event("recovery", replayed=2)
    b.record_event("recovery", replayed=5)
    a.merge(b)
    m = a.snapshot()
    assert m["metrics"]["reqs{engine=search}"]["value"] == 7
    assert m["metrics"]["reqs{engine=live}"]["value"] == 1
    assert m["metrics"]["epoch"]["value"] == 7
    assert [e["replayed"] for e in m["events"]] == [2, 5]


def test_metric_kind_conflict_raises():
    reg = obs.MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("x")


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------
def test_prometheus_exposition_parses():
    reg = obs.MetricsRegistry()
    reg.counter("decode_calls_total", plan="fused", format="vbyte").inc(9)
    reg.gauge("delta_docs").set(4)
    reg.histogram("wal_append_seconds", fsync=True).observe(0.25)
    text = reg.to_prometheus()
    parsed = parse_prometheus(text)
    assert parsed['decode_calls_total{format="vbyte",plan="fused"}'] == 9.0
    assert parsed["delta_docs"] == 4.0
    # cumulative le buckets: the 0.25 observation is in le="0.25" exactly
    assert parsed['wal_append_seconds_bucket{fsync="True",le="0.25"}'] == 1.0
    assert parsed['wal_append_seconds_bucket{fsync="True",le="+Inf"}'] == 1.0
    assert parsed['wal_append_seconds_count{fsync="True"}'] == 1.0


def test_chrome_trace_roundtrips_parent_child_nesting(tmp_path):
    now = [0.0]
    tele = obs.Telemetry(clock=lambda: now[0])
    with obs.install(tele):
        with obs.trace("request") as root:
            now[0] += 0.001
            with obs.trace("admission"):
                now[0] += 0.002
            with obs.trace("execute"):
                with obs.trace("decode", format="vbyte"):
                    now[0] += 0.004
            root.event("crash_point", phase="after_rotate")
    p = tmp_path / "trace.json"
    tele.tracer.write_chrome_trace(str(p))
    spans = {e["name"]: e for e in read_chrome_trace(str(p))
             if e["ph"] == "X"}
    assert set(spans) == {"request", "admission", "execute", "decode"}
    req = spans["request"]
    assert spans["admission"]["args"]["parent_id"] == req["args"]["span_id"]
    assert spans["execute"]["args"]["parent_id"] == req["args"]["span_id"]
    assert (spans["decode"]["args"]["parent_id"]
            == spans["execute"]["args"]["span_id"])
    assert spans["decode"]["args"]["format"] == "vbyte"
    # microsecond timeline survives exactly (injected clock)
    assert req["dur"] == pytest.approx(7000.0)
    assert spans["decode"]["dur"] == pytest.approx(4000.0)
    # all spans share one tid = trace id; instant event rode along
    assert len({e["tid"] for e in spans.values()}) == 1
    assert any(e["ph"] == "i" and e["name"] == "crash_point"
               for e in read_chrome_trace(str(p)))


def test_jsonl_roundtrip_and_trees(tmp_path):
    tele = obs.Telemetry()
    with obs.install(tele):
        for _ in range(3):
            with obs.trace("request"):
                with obs.trace("execute"):
                    pass
    p = tmp_path / "trace.jsonl"
    tele.tracer.write_jsonl(str(p))
    recs = read_jsonl(str(p))
    assert len(recs) == 6
    trees = tele.tracer.trees()
    assert len(trees) == 3  # one trace per request
    for tid, spans in trees.items():
        names = {s["name"] for s in spans}
        assert names == {"request", "execute"}
        root = next(s for s in spans if s["parent_id"] is None)
        assert root["span_id"] == tid


def test_span_exception_tags_error_and_unwinds():
    tele = obs.Telemetry()
    with obs.install(tele):
        with pytest.raises(ValueError):
            with obs.trace("request"):
                with obs.trace("execute"):
                    raise ValueError("boom")
        with obs.trace("next"):
            pass
    by_name = {s["name"]: s for s in tele.tracer.spans}
    assert by_name["execute"]["attrs"]["error"] == "ValueError"
    assert by_name["request"]["attrs"]["error"] == "ValueError"
    # the stack unwound: the next root starts a fresh trace
    assert by_name["next"]["parent_id"] is None


# ---------------------------------------------------------------------------
# shared percentile/latency helpers
# ---------------------------------------------------------------------------
def test_percentile_matches_numpy(rng):
    from repro.obs.stats import latency_summary, percentile

    xs = rng.exponential(1.0, size=137).tolist()
    for q in (0, 13.7, 50, 90, 99, 100):
        assert percentile(xs, q) == pytest.approx(
            float(np.percentile(xs, q)), abs=1e-12)
    with pytest.raises(ValueError):
        percentile([], 50)
    s = latency_summary([0.001, 0.002, 0.004], 0.01, 3)
    assert s["qps"] == 300.0 and s["p50_ms"] == 2.0


# ---------------------------------------------------------------------------
# QueryStats merge-by-fields contract
# ---------------------------------------------------------------------------
def test_querystats_merge_new_field_fails_loudly():
    """Adding a field without a merge rule must raise, not silently drop."""
    import dataclasses

    from repro.index import QueryStats

    @dataclasses.dataclass
    class Extended(QueryStats):
        mystery: object = None

    a, b = Extended(), Extended()
    with pytest.raises(TypeError, match="mystery"):
        a.merge(b)


def test_querystats_merge_covers_every_current_field():
    from repro.index import QueryStats

    a, b = QueryStats(), QueryStats()
    a.blocks_decoded, b.blocks_decoded = 3, 4
    b.degraded = True
    b.degraded_reasons.append("deadline:gallop")
    a.merge(b)
    assert a.blocks_decoded == 7
    assert a.degraded is True
    assert a.degraded_reasons == ["deadline:gallop"]
    a.merge(b)  # list fields dedup on re-merge
    assert a.degraded_reasons == ["deadline:gallop"]


# ---------------------------------------------------------------------------
# end-to-end wiring: query spans, decode attribution, bit-exactness
# ---------------------------------------------------------------------------
def _small_index(rng, n_terms=6, universe=50_000):
    from repro.data.synthetic import posting_tfs
    from repro.index import build_index

    lists = {t: np.sort(rng.choice(universe, size=int(s), replace=False))
             .astype(np.uint32)
             for t, s in enumerate(rng.integers(200, 800, size=n_terms))}
    tfs = {t: posting_tfs(rng, len(v)) for t, v in lists.items()}
    return build_index(lists, tfs=tfs, block_size=32, n_docs=universe)


def test_maxscore_span_tree_sums_to_request_wall_time(rng):
    """ISSUE acceptance: one span tree per maxscore topk whose direct
    children durations sum (within tolerance) to the root wall time, and
    decode spans attributed to (format, plan, epilogue)."""
    from repro.index import topk
    from repro.launch.serve import SearchEngine

    index = _small_index(rng)
    engine = SearchEngine(index, top_k=10)
    terms = [0, 2, 4]
    engine.search(terms, "topk_maxscore")  # compile outside the capture

    tele = obs.Telemetry()
    with obs.install(tele):
        ids, scores = engine.search(terms, "topk_maxscore")
    off_ids, off_scores = engine.search(terms, "topk_maxscore")
    np.testing.assert_array_equal(ids, off_ids)
    np.testing.assert_array_equal(scores, off_scores)

    trees = tele.tracer.trees()
    assert len(trees) == 1  # one trace for the one request
    spans = next(iter(trees.values()))
    root = next(s for s in spans if s["parent_id"] is None)
    assert root["name"] == "request"
    children = [s for s in spans if s["parent_id"] == root["span_id"]]
    assert {c["name"] for c in children} == {"admission", "execute",
                                            "finalize"}
    # the stages partition the request: their durations sum to the root
    # wall time (tolerance: the span-open/close code between stages)
    child_sum = sum(c["dur"] for c in children)
    assert child_sum <= root["dur"] + 1e-9
    assert child_sum >= 0.90 * root["dur"]

    decode_spans = [s for s in spans if s["name"] == "decode"]
    assert decode_spans, "no decode spans under the request tree"
    for d in decode_spans:
        assert d["attrs"]["format"] == index.terms[0].arr.format
        assert isinstance(d["attrs"]["plan"], str) and d["attrs"]["plan"]
        assert "epilogue" in d["attrs"]
        assert d["attrs"]["blocks"] >= 1
    # topk span got the QueryStats attribute dump
    tk = next(s for s in spans if s["name"] == "topk")
    assert tk["attrs"]["mode"] == "maxscore"
    assert tk["attrs"]["blocks_decoded"] >= 1

    # with telemetry uninstalled nothing further records
    engine.search(terms, "topk_maxscore")
    assert len(tele.tracer.trees()) == 1


def test_topk_bit_identical_with_and_without_telemetry(rng):
    from repro.index import topk

    index = _small_index(rng)
    cases = [([0, 1], "or"), ([0, 2, 4], "maxscore"), ([1, 3], "and")]
    base = [topk(index, t, 10, mode=m) for t, m in cases]
    tele = obs.Telemetry()
    with obs.install(tele):
        on = [topk(index, t, 10, mode=m) for t, m in cases]
    after = [topk(index, t, 10, mode=m) for t, m in cases]
    for (bi, bs), (oi, os_), (ai, as_) in zip(base, on, after):
        np.testing.assert_array_equal(bi, oi)
        np.testing.assert_array_equal(bs, os_)
        np.testing.assert_array_equal(bi, ai)
        np.testing.assert_array_equal(bs, as_)
    assert len(tele.tracer.trees()) == len(cases)


def test_serve_counters_mirror_serve_stats(rng):
    """SearchEngine keeps the serve_stats dict API and mirrors increments
    into labeled registry counters."""
    from repro.launch.serve import SearchEngine

    index = _small_index(rng)
    engine = SearchEngine(index, top_k=5)
    tele = obs.Telemetry()
    with obs.install(tele):
        engine.search([0, 1], "or")
        engine.search([2], "topk")
    m = tele.registry.snapshot()["metrics"]
    key = 'serve_requests_total{engine=search,mode=or}'
    assert m[key]["value"] == 1
    assert m['serve_requests_total{engine=search,mode=topk}']["value"] == 1
    assert any(k.startswith("decode_calls_total") for k in m)
    assert any(k.startswith("plan_cache_total") for k in m)


def test_wal_and_recovery_metrics(tmp_path, rng):
    from repro.index.ingest import LiveIndex

    tele = obs.Telemetry()
    with obs.install(tele):
        d = str(tmp_path / "live")
        li = LiveIndex(d, n_docs=1 << 12)
        for doc in range(40):
            li.add(doc, {int(t): 1 for t in rng.choice(8, 2, replace=False)})
        li.merge()
        li.close()
        li = LiveIndex(d)
        li.add(50, {0: 1})
        li.close()
        LiveIndex(d).close()  # replays the unmerged op
    snap = tele.registry.snapshot()
    m = snap["metrics"]
    assert m["wal_append_seconds{fsync=True}"]["count"] == 41
    assert m["wal_record_bytes"]["count"] == 41
    phases = [k for k in m if k.startswith("ingest_merge_phase_seconds")]
    assert len(phases) == 8  # one histogram per crash point
    assert m["ingest_merges_total"]["value"] == 1
    recov = [e for e in snap["events"] if e["event"] == "ingest_recovery"]
    assert len(recov) == 3  # one structured record per reopen
    assert recov[-1]["replayed_ops"] == 1


# ---------------------------------------------------------------------------
# report CLI
# ---------------------------------------------------------------------------
def test_report_cli_renders_stage_table(tmp_path, capsys):
    from repro.obs import report

    now = [0.0]
    tele = obs.Telemetry(clock=lambda: now[0])
    with obs.install(tele):
        with obs.trace("topk", term=3):
            with obs.trace("decode", term=3, blocks_decoded=4,
                           ints_decoded=512, blocks=[0, 1]):
                now[0] += 0.004
            with obs.trace("score", term=3):
                now[0] += 0.001
    p = tmp_path / "cap.jsonl"
    tele.tracer.write_jsonl(str(p))
    assert report.main([str(p)]) == 0
    out = capsys.readouterr().out
    assert "decode" in out and "p50" in out
    assert "hottest" in out.lower()
    assert report.main([str(tmp_path / "missing.jsonl")]) == 1
