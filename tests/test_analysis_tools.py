"""Unit tests for the measurement tooling: HLO collective parser, cost model,
roofline math, dry-run (subprocess smoke on the smallest cell)."""
import json
import os
import subprocess
import sys

import pytest

from repro.distributed.hlo_analysis import collective_stats
from repro.launch import roofline_math as rm


HLO_SAMPLE = """
  %ar = f32[2048,4096]{1,0} all-reduce(f32[2048,4096]{1,0} %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag.1 = bf16[128,1024]{1,0} all-gather(bf16[128,64]{1,0} %y), replica_groups=[8,16]<=[128], dimensions={1}
  %rs = f32[64]{0} reduce-scatter(f32[1024]{0} %z), replica_groups={{0,1,2,3,5,6,7,8}}, dimensions={0}
  %cp = u32[10]{0} collective-permute(u32[10]{0} %w), source_target_pairs={{0,1}}
  %a2a = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(f32[4,4]{1,0} %p, f32[4,4]{1,0} %q), replica_groups={{0,1}}
  %ags = bf16[64]{0} all-gather-start(bf16[32]{0} %h), replica_groups={{0,1}}
  %agd = bf16[64]{0} all-gather-done(bf16[64]{0} %ags)
"""


def test_collective_parser_kinds_and_sizes():
    st = collective_stats(HLO_SAMPLE)
    ops = st["ops"]
    assert ops["all-reduce"]["count"] == 1
    assert ops["all-reduce"]["result_bytes"] == 2048 * 4096 * 4
    # ring factor 2*(n-1)/n with n=4
    assert ops["all-reduce"]["wire_bytes"] == pytest.approx(
        2 * 3 / 4 * 2048 * 4096 * 4)
    assert ops["all-gather"]["count"] == 2  # plain + -start (done skipped)
    assert ops["reduce-scatter"]["wire_bytes"] == pytest.approx(7 * 64 * 4)
    assert ops["all-to-all"]["result_bytes"] == 2 * 16 * 4  # tuple result
    assert ops["collective-permute"]["wire_bytes"] == 40
    assert st["total_wire_bytes"] > 0


def test_iota_replica_groups():
    st = collective_stats(HLO_SAMPLE)
    # the all-gather with iota groups [8,16] has group size 16
    ag = st["ops"]["all-gather"]
    assert ag["wire_bytes"] == pytest.approx(
        (15 / 16) * 128 * 1024 * 2 + (1 / 2) * 64 * 2)


def test_roofline_terms_and_dominance():
    r = rm.make_roofline(flops=197e12, bytes_=819e9 * 2, wire_bytes=50e9 * 3,
                         model_flops_per_device=98.5e12)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(2.0)
    assert r.collective_s == pytest.approx(3.0)
    assert r.dominant == "collective"
    assert r.useful_ratio == pytest.approx(0.5)
    assert r.roofline_fraction == pytest.approx(98.5e12 / (3.0 * 197e12))


def test_cost_model_zero1_reduces_opt_state_traffic():
    from repro.configs.mixtral_8x7b import CONFIG
    from repro.configs.shapes import LM_SHAPES
    from repro.launch import cost_model as cm

    base = cm.lm_cost(CONFIG, LM_SHAPES["train_4k"], n_chips=256, dp=16)
    z1 = cm.lm_cost(CONFIG, LM_SHAPES["train_4k"], n_chips=256, dp=16,
                    assembly={"zero1": True})
    assert z1.flops < base.flops  # sharded AdamW
    assert base.flops > 0 and base.bytes > 0 and base.wire_bytes > 0


def test_cost_model_decode_memory_bound():
    from repro.configs.glm4_9b import CONFIG
    from repro.configs.shapes import LM_SHAPES
    from repro.launch import cost_model as cm
    from repro.launch.roofline_math import make_roofline

    c = cm.lm_cost(CONFIG, LM_SHAPES["decode_32k"], n_chips=256, dp=16)
    r = make_roofline(c.flops, c.bytes, c.wire_bytes, c.flops)
    assert r.dominant in ("memory", "collective")  # decode is never compute-bound


@pytest.mark.slow
def test_dryrun_subprocess_smallest_cell(tmp_path):
    """End-to-end dry-run smoke: 512 fake devices, lower+compile+analyze."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "gin-tu",
         "--shape", "molecule", "--out", str(tmp_path)],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))), timeout=480)
    assert out.returncode == 0, out.stdout + out.stderr
    rec = json.load(open(tmp_path / "gin-tu__molecule__single.json"))
    assert rec["n_chips"] == 256
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert rec["hlo_flops_per_device"] > 0
