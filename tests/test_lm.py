"""LM: train/prefill/decode parity, MoE, SWA ring cache, microbatching."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.nn.layers as nnl
from repro.models import lm
from repro.train import OptimizerConfig, init_train_state, make_train_step

pytestmark = pytest.mark.slow  # heavyweight model/system tier (deselected from tier-1)


def tiny_cfg(**kw):
    base = dict(name="tiny", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                d_ff=64, vocab=97, q_chunk=16, kv_chunk=16, loss_chunk=8)
    base.update(kw)
    return lm.LMConfig(**base)


def all_logits(params, tokens, cfg):
    hidden, _, _ = lm.forward(params, tokens, cfg, dtype=jnp.float32)
    return nnl.dense(params["lm_head"], hidden, dtype=jnp.float32)


@pytest.fixture(params=["dense", "moe", "swa"])
def cfg(request):
    if request.param == "moe":
        return tiny_cfg(d_ff=0, n_kv_heads=4,
                        moe=lm.MoESettings(n_experts=4, top_k=2, d_ff=48,
                                           capacity_factor=2.0))
    if request.param == "swa":
        return tiny_cfg(window=8)
    return tiny_cfg()


def test_train_step_decreases_loss(cfg):
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params)
    step = jax.jit(make_train_step(
        lambda p, b: lm.loss_fn(p, b, cfg), OptimizerConfig(peak_lr=1e-2, warmup_steps=1)
    ))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 17), 0, cfg.vocab)}
    state, m0 = step(state, batch)
    for _ in range(5):
        state, m = step(state, batch)
    assert float(m["loss"]) < float(m0["loss"])
    assert np.isfinite(float(m["grad_norm"]))


def test_prefill_decode_parity(cfg):
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab)
    ref = all_logits(params, toks, cfg)
    lg, cache = lm.prefill(params, toks[:, :8], cfg, cache_capacity=16,
                           dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref[:, 7]), atol=2e-3)
    for t in range(8, 16):
        lg, cache = lm.decode_step(params, cache, toks[:, t], cfg, dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(ref[:, t]), atol=2e-3,
                                   err_msg=f"position {t}")


def test_microbatch_equivalence():
    cfg = tiny_cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (8, 17), 0, cfg.vocab)}
    opt = OptimizerConfig(peak_lr=1e-3, warmup_steps=1)
    s1 = init_train_state(params)
    s2 = init_train_state(params)
    step1 = jax.jit(make_train_step(lambda p, b: lm.loss_fn(p, b, cfg), opt))
    step4 = jax.jit(make_train_step(lambda p, b: lm.loss_fn(p, b, cfg), opt,
                                    microbatch=4))
    s1, m1 = step1(s1, batch)
    s2, m4 = step4(s2, batch)
    # microbatch averages per-microbatch means; with equal-size microbatches
    # the loss matches the full-batch mean
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-3
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-3)


def test_swa_equals_full_when_window_ge_seq():
    c_full = tiny_cfg()
    c_swa = tiny_cfg(window=64)  # window > seq: identical
    params = lm.init_params(jax.random.PRNGKey(0), c_full)
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0, 97)
    np.testing.assert_allclose(np.asarray(all_logits(params, toks, c_full)),
                               np.asarray(all_logits(params, toks, c_swa)),
                               atol=1e-5)


def test_banded_attention_same_loss():
    c0 = tiny_cfg(window=8, banded_attention=False)
    c1 = tiny_cfg(window=8, banded_attention=True)
    params = lm.init_params(jax.random.PRNGKey(0), c0)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(5), (2, 33), 0, 97)}
    l0, _ = lm.loss_fn(params, batch, c0, dtype=jnp.float32)
    l1, _ = lm.loss_fn(params, batch, c1, dtype=jnp.float32)
    assert abs(float(l0) - float(l1)) < 1e-4


def test_param_count_formula():
    cfg = tiny_cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    actual = sum(x.size for x in jax.tree.leaves(params))
    assert actual == cfg.param_count()


def test_moe_param_count_formula():
    cfg = tiny_cfg(d_ff=0, moe=lm.MoESettings(n_experts=4, top_k=2, d_ff=48))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    actual = sum(x.size for x in jax.tree.leaves(params))
    assert actual == cfg.param_count()
