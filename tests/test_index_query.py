"""Inverted-index query engine vs a numpy brute-force oracle.

Conjunctive / disjunctive / top-k results must be bit-identical to the
oracle on both formats, dense and banded cores, fused and unfused plans,
single-device and sharded — and the skip-table decode accounting must
prove that blocks whose docid range overlaps no probe are never decoded.
"""
from collections import Counter
from functools import reduce

import numpy as np
import pytest

import jax

from repro.core import CompressedIntArray
from repro.data.synthetic import posting_list, posting_tfs
from repro.index import (QueryStats, build_index, conjunctive, disjunctive,
                         quantize_impacts, topk)
from repro.kernels.vbyte_decode import dispatch, normalize_probe
from repro.kernels.vbyte_decode.dispatch import DecodePlan

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >1 device; run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")

FMTS = ["vbyte", "streamvbyte"]
B = 32  # block size (multiple of 4 for streamvbyte)
U = 100_000  # docid universe


def make_lists(rng, sizes, universe=U):
    """Per-term sorted distinct docid lists (ragged vs B on purpose)."""
    return {t: np.sort(rng.choice(universe, size=s, replace=False))
            .astype(np.uint32) for t, s in enumerate(sizes)}


def oracle_and(lists, terms):
    return reduce(np.intersect1d,
                  [lists.get(t, np.zeros(0, np.uint32)) for t in terms]
                  ).astype(np.uint32)


def oracle_or(lists, terms):
    return reduce(np.union1d,
                  [lists.get(t, np.zeros(0, np.uint32)) for t in terms]
                  ).astype(np.uint32)


def oracle_topk(index, lists, terms, k, mode="or"):
    c = Counter()
    for t in dict.fromkeys(terms):
        for d in lists.get(t, ()):
            c[int(d)] += index.impact(t)
    if mode == "and":
        inter = set(oracle_and(lists, terms).tolist())
        c = Counter({d: s for d, s in c.items() if d in inter})
    elif mode == "driver":
        req = set(np.asarray(lists.get(terms[0], ())).tolist())
        c = Counter({d: s for d, s in c.items() if d in req})
    order = sorted(c.items(), key=lambda kv: (-kv[1], kv[0]))[:k]
    return (np.array([d for d, _ in order], np.uint32),
            np.array([s for _, s in order], np.int32))


def assert_query_matches(index, lists, terms, k=10, **kw):
    np.testing.assert_array_equal(conjunctive(index, terms, **kw),
                                  oracle_and(lists, terms))
    np.testing.assert_array_equal(disjunctive(index, terms, **kw),
                                  oracle_or(lists, terms))
    # TAAT union scoring / constant conjunctive / fused DAAT probing
    for mode in ("or", "and", "driver"):
        ids, scores = topk(index, terms, k, mode=mode, **kw)
        eids, escores = oracle_topk(index, lists, terms, k, mode=mode)
        np.testing.assert_array_equal(ids, eids, err_msg=mode)
        np.testing.assert_array_equal(scores, escores, err_msg=mode)


# ---------------------------------------------------------------------------
# golden vectors
# ---------------------------------------------------------------------------
def test_golden_intersection_union():
    lists = {0: np.array([3, 40, 41, 127, 128, 900, 4000], np.uint32),
             1: np.array([40, 127, 129, 900, 5000], np.uint32),
             2: np.array([1, 40, 900], np.uint32)}
    idx = build_index(lists, block_size=4, n_docs=10_000)
    np.testing.assert_array_equal(
        conjunctive(idx, [0, 1], plan="jnp"),
        np.array([40, 127, 900], np.uint32))
    np.testing.assert_array_equal(
        conjunctive(idx, [0, 1, 2], plan="jnp"),
        np.array([40, 900], np.uint32))
    np.testing.assert_array_equal(
        disjunctive(idx, [1, 2], plan="jnp"),
        np.array([1, 40, 127, 129, 900, 5000], np.uint32))
    # single-term queries are the postings themselves
    np.testing.assert_array_equal(conjunctive(idx, [2], plan="jnp"),
                                  lists[2])
    np.testing.assert_array_equal(disjunctive(idx, [2], plan="jnp"),
                                  lists[2])


# ---------------------------------------------------------------------------
# randomized oracle parity: formats × plans × query widths
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("plan", ["fused", "unfused"])
def test_boolean_and_topk_vs_oracle(rng, fmt, plan):
    # ragged sizes (not multiples of B) + a rare term + a dominating term
    lists = make_lists(rng, (45, 300, 701, 1150, 37))
    idx = build_index(lists, format=fmt, block_size=B, n_docs=U)
    for terms in ([1], [0, 3], [4, 1], [0, 1, 2], [0, 1, 2, 3, 4]):
        assert_query_matches(idx, lists, terms, plan=plan)


@pytest.mark.parametrize("fmt", FMTS)
def test_terms_missing_and_empty(rng, fmt):
    lists = make_lists(rng, (60, 200))
    lists[2] = np.zeros(0, np.uint32)  # empty term: one count-0 block
    idx = build_index(lists, format=fmt, block_size=B, n_docs=U)
    assert idx.df(2) == 0 and idx.impact(2) == 0
    assert conjunctive(idx, [0, 2], plan="jnp").size == 0
    assert conjunctive(idx, [0, 99], plan="jnp").size == 0  # unknown term
    np.testing.assert_array_equal(disjunctive(idx, [0, 2, 99], plan="jnp"),
                                  lists[0])
    ids, scores = topk(idx, [0, 2, 99], 5, plan="jnp")
    eids, escores = oracle_topk(idx, lists, [0, 2, 99], 5)
    np.testing.assert_array_equal(ids, eids)
    np.testing.assert_array_equal(scores, escores)


@pytest.mark.parametrize("fmt", FMTS)
def test_topk_ties_deterministic(rng, fmt):
    """Equal dfs ⇒ equal impacts ⇒ exact score ties, broken by docid asc."""
    a = np.sort(rng.choice(U, size=64, replace=False)).astype(np.uint32)
    b = np.sort(rng.choice(U, size=64, replace=False)).astype(np.uint32)
    lists = {0: a, 1: b}
    idx = build_index(lists, format=fmt, block_size=B, n_docs=U)
    assert idx.impact(0) == idx.impact(1)
    for k in (3, 10, 500):  # k < #ties, k within, k > all results
        ids, scores = topk(idx, [0, 1], k, plan="fused")
        eids, escores = oracle_topk(idx, lists, [0, 1], k)
        np.testing.assert_array_equal(ids, eids)
        np.testing.assert_array_equal(scores, escores)
    # repeated query terms must not double-count impacts
    ids, scores = topk(idx, [0, 0, 1], 10, plan="fused")
    eids, escores = oracle_topk(idx, lists, [0, 0, 1], 10)
    np.testing.assert_array_equal(ids, eids)
    np.testing.assert_array_equal(scores, escores)


# ---------------------------------------------------------------------------
# skip-table pruning: decode-count accounting
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fmt", FMTS)
def test_non_overlapping_blocks_never_decoded(fmt):
    # term 1 spans two far-apart clusters; term 0 overlaps only cluster 1,
    # so every cluster-2 block of term 1 must be pruned by the skip table
    t0 = np.arange(100, 800, 3, dtype=np.uint32)
    t1 = np.concatenate([np.arange(0, 1500, 2, dtype=np.uint32),
                         np.arange(60_000, 63_000, 2, dtype=np.uint32)])
    lists = {0: t0, 1: t1}
    idx = build_index(lists, format=fmt, block_size=B, n_docs=U)
    tp1 = idx.terms[1]
    overlapping = int(np.sum((tp1.first_doc <= t0[-1])
                             & (tp1.last_doc >= t0[0])))
    assert overlapping < tp1.n_blocks  # the scenario is non-trivial
    st = QueryStats()
    got = conjunctive(idx, [0, 1], plan="jnp", stats=st)
    np.testing.assert_array_equal(got, oracle_and(lists, [0, 1]))
    # term 1 was probed per chunk: cluster-2 blocks never entered a decode
    assert st.per_term_decoded[1] <= overlapping * \
        (len(t0) // 128 + 1)  # ≤ overlapping blocks per probe chunk
    assert st.blocks_skipped > 0
    # globally disjoint ranges: nothing is decoded at all
    far = {0: np.arange(0, 900, 2, dtype=np.uint32),
           1: np.arange(50_000, 51_000, 2, dtype=np.uint32)}
    idx2 = build_index(far, format=fmt, block_size=B, n_docs=U)
    st2 = QueryStats()
    assert conjunctive(idx2, [0, 1], plan="jnp", stats=st2).size == 0
    assert st2.blocks_decoded == 0 and st2.decode_calls == 0


def test_topk_skip_accounting(rng):
    lists = make_lists(rng, (50, 900))
    idx = build_index(lists, block_size=B, n_docs=U)
    st = QueryStats()
    ids, scores = topk(idx, [0, 1], 10, mode="driver", plan="jnp", stats=st)
    total = st.blocks_decoded + st.blocks_skipped
    assert total > 0 and st.blocks_skipped > 0
    assert st.ints_decoded > 0 and st.decode_calls > 0
    # DAAT scores genuinely vary: driver docs in both terms outrank
    # driver-only docs (non-constant expected output for the bm25 path)
    eids, escores = oracle_topk(idx, lists, [0, 1], 10, mode="driver")
    np.testing.assert_array_equal(ids, eids)
    np.testing.assert_array_equal(scores, escores)


# ---------------------------------------------------------------------------
# plan-space parity: Pallas kernel, dense vs banded cores
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fmt", FMTS)
def test_kernel_plan_parity(rng, fmt):
    lists = make_lists(rng, (40, 180), universe=4000)
    idx = build_index(lists, format=fmt, block_size=B, n_docs=4000)
    for terms in ([0, 1], [1]):
        np.testing.assert_array_equal(
            conjunctive(idx, terms, plan="kernel", probe_width=64),
            conjunctive(idx, terms, plan="jnp", probe_width=64))
    for mode in ("or", "and", "driver"):
        ids_k, sc_k = topk(idx, [0, 1], 7, mode=mode, plan="kernel",
                           probe_width=64)
        ids_j, sc_j = topk(idx, [0, 1], 7, mode=mode, plan="jnp",
                           probe_width=64)
        np.testing.assert_array_equal(ids_k, ids_j, err_msg=mode)
        np.testing.assert_array_equal(sc_k, sc_j, err_msg=mode)


@pytest.mark.parametrize("fmt", FMTS)
def test_dense_vs_banded_cores(rng, fmt):
    lists = make_lists(rng, (90, 800, 350))
    idx = build_index(lists, format=fmt, block_size=B, n_docs=U)
    dense = DecodePlan("jnp", True)
    banded = DecodePlan("jnp", True, chunk=16)
    for terms in ([0, 1], [0, 1, 2]):
        np.testing.assert_array_equal(
            conjunctive(idx, terms, plan=dense),
            conjunctive(idx, terms, plan=banded))
        ids_d, sc_d = topk(idx, terms, 9, plan=dense)
        ids_b, sc_b = topk(idx, terms, 9, plan=banded)
        np.testing.assert_array_equal(ids_d, ids_b)
        np.testing.assert_array_equal(sc_d, sc_b)
        np.testing.assert_array_equal(conjunctive(idx, terms, plan=dense),
                                      oracle_and(lists, terms))


# ---------------------------------------------------------------------------
# the epilogues themselves (all plans, count-0 blocks, probe padding)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fmt", FMTS)
def test_membership_bm25_epilogue_parity(rng, fmt):
    vals = np.sort(rng.choice(3000, size=2 * B + 7, replace=False)
                   ).astype(np.uint64)
    arr = CompressedIntArray.encode(vals, format=fmt, block_size=B,
                                    differential=True)
    ops = {k: np.pad(np.asarray(v),
                     ((0, 2),) + ((0, 0),) * (np.asarray(v).ndim - 1))
           for k, v in arr.device_operands().items()}  # + 2 count-0 blocks
    probe_ids = np.sort(rng.choice(3000, size=50, replace=False))
    probe = normalize_probe(probe_ids, 64)
    assert probe.shape == (1, 64) and (probe[0, 50:] == -1).all()
    outs = {}
    for plan in ("kernel", "jnp", "unfused"):
        outs[plan] = np.asarray(dispatch.decode(
            ops, format=fmt, block_size=B, differential=True,
            epilogue="membership", epilogue_operands={"probe": probe},
            plan=plan))
    for plan, o in outs.items():
        np.testing.assert_array_equal(o, outs["kernel"], err_msg=plan)
    hits = outs["jnp"].any(axis=0)[:50]
    np.testing.assert_array_equal(
        hits.astype(bool), np.isin(probe_ids, vals.astype(np.int64)))
    assert not outs["jnp"][:, 50:].any()  # pad probes never match
    for plan in ("kernel", "jnp", "unfused"):
        sc = np.asarray(dispatch.decode(
            ops, format=fmt, block_size=B, differential=True,
            epilogue="bm25_accum",
            epilogue_operands={"probe": probe,
                               "impact": np.asarray([[11]], np.int32)},
            plan=plan))
        np.testing.assert_array_equal(
            sc.sum(axis=0)[:50], hits[:50].astype(np.int32) * 11,
            err_msg=plan)


def test_normalize_probe_validation():
    with pytest.raises(ValueError, match="sorted"):
        normalize_probe(np.array([5, 3]), 8)
    with pytest.raises(ValueError, match="width"):
        normalize_probe(np.arange(9), 8)
    with pytest.raises(ValueError, match="2\\^31"):
        normalize_probe(np.array([1 << 31], np.int64), 8)
    out = normalize_probe(np.zeros(0, np.uint32), 4)
    assert (out == -1).all()


# ---------------------------------------------------------------------------
# builder + slice_blocks
# ---------------------------------------------------------------------------
def test_builder_validation_and_stats(rng):
    with pytest.raises(ValueError, match="strictly increasing"):
        build_index({0: np.array([4, 4, 5], np.uint32)})
    with pytest.raises(ValueError, match="2\\^31"):
        build_index({0: np.array([1 << 31], np.uint64)})
    lists = make_lists(rng, (70, 300))
    idx = build_index(lists, block_size=B, n_docs=U)
    s = idx.stats()
    assert s["n_terms"] == 2 and s["n_postings"] == 370
    assert 0 < idx.bits_per_int <= 40
    assert idx.impact(0) > idx.impact(1) > 0  # rarer term scores higher
    tp = idx.terms[1]
    assert tp.n_blocks == -(-300 // B)
    np.testing.assert_array_equal(tp.first_doc[0], lists[1][0])
    np.testing.assert_array_equal(tp.last_doc[-1], lists[1][-1])


@pytest.mark.parametrize("fmt", FMTS)
def test_slice_blocks_decode(rng, fmt):
    vals = np.sort(rng.choice(U, size=5 * B + 11, replace=False)
                   ).astype(np.uint64)
    arr = CompressedIntArray.encode(vals, format=fmt, block_size=B,
                                    differential=True)
    sub = arr.slice_blocks(2, 5)
    np.testing.assert_array_equal(sub.decode(plan="jnp").astype(np.uint64),
                                  vals[2 * B: 5 * B])
    # tail slice (ragged last block) + count-0 padding blocks
    sub = arr.slice_blocks(4, 6, pad_to=4)
    assert sub.n_blocks == 4 and sub.n == B + 11
    np.testing.assert_array_equal(sub.decode(plan="jnp").astype(np.uint64),
                                  vals[4 * B:])
    # non-contiguous gather with the partial block FIRST: decode() must
    # concatenate valid prefixes per block, not flat-trim to n
    sub = arr.take_blocks([5, 0])
    np.testing.assert_array_equal(
        sub.decode(plan="jnp").astype(np.uint64),
        np.concatenate([vals[5 * B:], vals[:B]]))


# ---------------------------------------------------------------------------
# synthetic posting lists (satellite: long lists + uint32 contract)
# ---------------------------------------------------------------------------
def test_posting_list_dtype_and_short(rng):
    ids = posting_list(rng, 500, universe=10_000)
    assert ids.dtype == np.uint32 and len(ids) == 500
    assert np.all(np.diff(ids.astype(np.int64)) > 0)


def test_posting_list_long_sorted_gap_path(rng):
    n = 1 << 22  # the length that used to raise ValueError("list too long")
    ids = posting_list(rng, n, universe=1 << 23)
    assert ids.dtype == np.uint32 and len(ids) == n
    d = np.diff(ids.astype(np.int64))
    assert d.min() >= 1  # strictly increasing ⇒ distinct
    assert int(ids[-1]) < 1 << 23
    # degenerate: length == universe
    full = posting_list(rng, 16, universe=16)
    np.testing.assert_array_equal(full, np.arange(16, dtype=np.uint32))


# ---------------------------------------------------------------------------
# per-posting impacts + block-max pruned top-k (mode="maxscore")
# ---------------------------------------------------------------------------
def make_tfs(rng, lists):
    return {t: posting_tfs(rng, len(v)) for t, v in lists.items()}


def oracle_topk_weighted(index, lists, tfs, terms, k):
    """Weighted TAAT oracle: per-posting quantized impacts, numpy only."""
    c = Counter()
    for t in dict.fromkeys(terms):
        docs = lists.get(t)
        if docs is None or len(docs) == 0 or t not in index:
            continue
        tf = tfs.get(t, np.ones(len(docs), np.int64))
        q = quantize_impacts(index.impact(t), tf, index.impact_bits)
        for d, s in zip(docs, q):
            c[int(d)] += int(s)
    order = sorted(c.items(), key=lambda kv: (-kv[1], kv[0]))[:k]
    return (np.array([d for d, _ in order], np.uint32),
            np.array([s for _, s in order], np.int32))


def test_impacts_stream_roundtrip_and_block_max(rng):
    lists = make_lists(rng, (40, 500))
    tfs = make_tfs(rng, lists)
    idx = build_index(lists, tfs=tfs, block_size=B, n_docs=U)
    assert idx.has_tf and idx.stats()["has_tf"]
    for t, docs in lists.items():
        tp = idx.terms[t]
        q = quantize_impacts(idx.impact(t), tfs[t], idx.impact_bits)
        np.testing.assert_array_equal(
            tp.impacts.decode(plan="jnp").astype(np.int32), q)
        # impacts blocks align 1:1 with the docid blocks
        assert tp.impacts.n_blocks == tp.n_blocks
        assert tp.impacts.block_size == tp.arr.block_size
        nb = tp.n_blocks
        want = [int(q[b * B:(b + 1) * B].max()) for b in range(nb)]
        np.testing.assert_array_equal(tp.max_impact, want)
        assert tp.ub == max(want)
    # tf-free build degenerates to the constant impact (sat(1) == 1)
    plain = build_index(lists, block_size=B, n_docs=U)
    assert not plain.has_tf
    for t in lists:
        tp = plain.terms[t]
        assert (tp.impacts.decode(plan="jnp") == plain.impact(t)).all()
        assert tp.ub == plain.impact(t)


def test_topk_k_validation(rng):
    lists = make_lists(rng, (30, 60))
    idx = build_index(lists, block_size=B, n_docs=U)
    for bad in (0, -1, -7, 1.5, 2.0, True, False, "3", None):
        with pytest.raises(ValueError, match="positive integer"):
            topk(idx, [0, 1], bad)
    # numpy integers are fine (np.argmax etc. produce them)
    ids, _ = topk(idx, [0, 1], np.int64(3), plan="jnp")
    assert ids.size == 3


def test_builder_rejects_non_integer_inputs(rng):
    with pytest.raises(ValueError, match="integer dtype"):
        build_index({0: np.array([1.0, 2.0, 4.0])})
    with pytest.raises(ValueError, match="integer dtype"):
        build_index({0: np.array([1, 2], np.uint32)},
                    tfs={0: np.array([1.0, 2.0])})
    with pytest.raises(ValueError, match="non-negative"):
        build_index({0: np.array([-3, 5], np.int64)})
    with pytest.raises(ValueError, match="≥ 1"):
        build_index({0: np.array([1, 2], np.uint32)},
                    tfs={0: np.array([0, 2])})
    with pytest.raises(ValueError, match="length"):
        build_index({0: np.array([1, 2], np.uint32)},
                    tfs={0: np.array([1, 2, 3])})


@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("plan", ["fused", "unfused"])
def test_maxscore_vs_oracle(rng, fmt, plan):
    lists = make_lists(rng, (45, 300, 701, 1150, 37))
    tfs = make_tfs(rng, lists)
    idx = build_index(lists, tfs=tfs, format=fmt, block_size=B, n_docs=U)
    for terms in ([1], [0, 3], [4, 1], [0, 1, 2], [0, 1, 2, 3, 4]):
        for k in (1, 3, 10, 100):
            ids, scores = topk(idx, terms, k, mode="maxscore", plan=plan)
            eids, escores = oracle_topk_weighted(idx, lists, tfs, terms, k)
            msg = f"terms={terms} k={k}"
            np.testing.assert_array_equal(ids, eids, err_msg=msg)
            np.testing.assert_array_equal(scores, escores, err_msg=msg)
            # and bit-identical to the exhaustive TAAT mode
            oids, oscores = topk(idx, terms, k, mode="or", plan=plan)
            np.testing.assert_array_equal(ids, oids, err_msg=msg)
            np.testing.assert_array_equal(scores, oscores, err_msg=msg)


@pytest.mark.parametrize("fmt", FMTS)
def test_maxscore_ties_and_k_beyond_candidates(rng, fmt):
    """tf-free index (all impacts equal per term): exact ties break by
    docid ascending under maxscore exactly as under TAAT."""
    a = np.sort(rng.choice(U, size=64, replace=False)).astype(np.uint32)
    b = np.sort(rng.choice(U, size=64, replace=False)).astype(np.uint32)
    lists = {0: a, 1: b}
    idx = build_index(lists, format=fmt, block_size=B, n_docs=U)
    for k in (3, 10, 500):  # k < #ties, k within, k > all candidates
        ids, scores = topk(idx, [0, 1], k, mode="maxscore", plan="fused")
        eids, escores = oracle_topk(idx, lists, [0, 1], k)
        np.testing.assert_array_equal(ids, eids)
        np.testing.assert_array_equal(scores, escores)
    # repeated query terms must not double-count impacts
    ids, scores = topk(idx, [0, 0, 1], 10, mode="maxscore", plan="fused")
    eids, escores = oracle_topk(idx, lists, [0, 0, 1], 10)
    np.testing.assert_array_equal(ids, eids)
    np.testing.assert_array_equal(scores, escores)


@pytest.mark.parametrize("fmt", FMTS)
def test_maxscore_seed_path_parity_and_pruning(rng, fmt):
    """Selective shape (tiny saturated term + long tf=1 lists) exercises
    the seed phase: the tiny list is decoded up front, θ matures past the
    long terms' upper bounds before they ever stream, and every long
    block not gathered by a candidate probe is threshold-pruned — never
    decoded by any pass (the long lists carry tf=1 so their bounds sit
    strictly under θ; saturated tfs would ceiling them at the quantizer
    max and erase the selective gap)."""
    lists = {0: np.sort(rng.choice(U, 40, replace=False)).astype(np.uint32),
             1: np.sort(rng.choice(U, 1500, replace=False)).astype(np.uint32),
             2: np.sort(rng.choice(U, 2000, replace=False)).astype(np.uint32)}
    tfs = {0: np.full(40, 50, np.int64),  # saturated: rare term dominates
           1: np.ones(1500, np.int64), 2: np.ones(2000, np.int64)}
    idx = build_index(lists, tfs=tfs, format=fmt, block_size=B, n_docs=U)
    # seed phase requires a strip-sized term next to a much longer one,
    # and pruning requires the long terms' combined bound under θ
    strip_blocks = 64 // B
    assert idx.terms[0].n_blocks <= strip_blocks
    assert idx.terms[2].n_blocks > 4 * strip_blocks
    assert idx.terms[1].ub + idx.terms[2].ub < idx.terms[0].ub
    st = QueryStats()
    ids, scores = topk(idx, [0, 1, 2], 10, mode="maxscore", plan="fused",
                       probe_width=64, stats=st)
    eids, escores = oracle_topk_weighted(idx, lists, tfs, [0, 1, 2], 10)
    np.testing.assert_array_equal(ids, eids)
    np.testing.assert_array_equal(scores, escores)
    # the seed term was fully decoded; the long lists were partly pruned
    assert st.per_term_decoded[0] >= idx.terms[0].n_blocks
    assert st.blocks_pruned > 0 and st.postings_pruned > 0
    assert st.impact_ints_decoded > 0  # weighted epilogues actually ran
    # pruned/decoded block sets partition each term exactly: a block is
    # threshold-pruned iff NO pass (strip pull, probe, merge) decoded it
    for t, tp in idx.terms.items():
        got = len(st.per_term_blocks.get(t, ()))
        assert st.per_term_pruned.get(t, 0) + got == tp.n_blocks


def test_maxscore_all_blocks_pruned_zero_decode(rng):
    """Docid-disjoint long term whose upper bound is under θ: every one of
    its blocks is threshold-pruned and none is ever decoded."""
    rare = np.sort(rng.choice(3000, 30, replace=False)).astype(np.uint32)
    heavy = np.sort(50_000 + rng.choice(50_000, 2000, replace=False)
                    ).astype(np.uint32)
    lists = {0: rare, 1: heavy}
    tfs = {0: np.full(30, 50, np.int64), 1: np.ones(2000, np.int64)}
    idx = build_index(lists, tfs=tfs, block_size=B, n_docs=U)
    st = QueryStats()
    ids, scores = topk(idx, [0, 1], 3, mode="maxscore", plan="fused",
                       probe_width=64, stats=st)
    eids, escores = oracle_topk_weighted(idx, lists, tfs, [0, 1], 3)
    np.testing.assert_array_equal(ids, eids)
    np.testing.assert_array_equal(scores, escores)
    # scenario precondition: the heavy term alone cannot reach the top-3
    assert idx.terms[1].ub <= int(escores[-1])
    tp1 = idx.terms[1]
    assert st.per_term_decoded.get(1, 0) == 0
    assert st.blocks_pruned == tp1.n_blocks
    assert st.postings_pruned == len(heavy)
    # only the rare seed term's postings (and impacts) were ever decoded
    assert st.ints_decoded == len(rare)


@pytest.mark.parametrize("fmt", FMTS)
def test_maxscore_threshold_tie_parity(fmt):
    """A candidate whose exact score TIES the running θ at a smaller
    docid than the tied incumbent must still be returned first — every
    MaxScore bound comparison has to be strict, else the non-essential
    split / block prune / probe dead-check silently drops it.

    Engineered shape: a tiny seed term puts incumbent D (large docid)
    into the heap with score θ; a long-list doc d* < D, sharing no term
    with the seed, has tfs tuned so its exact score equals θ. The final
    (score desc, docid asc) order must rank d* ahead of D."""
    U2 = 100_000
    d_star, D = 50, 90_000
    t1 = np.unique(np.concatenate(
        [np.arange(100, 8100, 4), [d_star, D]])).astype(np.uint32)
    t2 = np.unique(np.concatenate(
        [np.arange(102, 8102, 4), [d_star]])).astype(np.uint32)
    t0 = np.array([D, 90_050, 90_100], np.uint32)
    lists = {0: t0, 1: t1, 2: t2}
    probe = build_index(lists, block_size=B, n_docs=U2)
    b0, b1, b2 = (probe.impact(t) for t in (0, 1, 2))
    # quantized impacts reachable from integer tfs, per term
    def reach(base):
        out = {}
        for tf in range(1, 401):
            out.setdefault(int(quantize_impacts(base, [tf])[0]), tf)
        return out
    r1, r2 = reach(b1), reach(b2)
    # tie construction: θ = score(D) = b0 + q1D  ==  qa + qb = score(d*)
    found = next(((q1D, qa, b0 + q1D - qa) for q1D in sorted(r1)
                  for qa in sorted(r1) if b0 + q1D - qa in r2), None)
    assert found, "no exact tie constructible from these impact bases"
    q1D, qa, qb = found
    tf1 = np.ones(t1.size, np.int64)
    tf1[np.searchsorted(t1, D)] = r1[q1D]
    tf1[np.searchsorted(t1, d_star)] = r1[qa]
    tf2 = np.ones(t2.size, np.int64)
    tf2[np.searchsorted(t2, d_star)] = r2[qb]
    tfs = {0: np.ones(3, np.int64), 1: tf1, 2: tf2}
    idx = build_index(lists, tfs=tfs, format=fmt, block_size=B, n_docs=U2)
    # seed-phase preconditions: t0 is strip-sized next to long lists
    assert idx.terms[0].n_blocks <= 64 // B
    assert idx.terms[1].n_blocks > 4 * (64 // B)
    theta = b0 + q1D
    for k in (1, 2, 3, 5):
        ids, scores = topk(idx, [0, 1, 2], k, mode="maxscore",
                           plan="fused", probe_width=64)
        eids, escores = oracle_topk_weighted(idx, lists, tfs, [0, 1, 2], k)
        np.testing.assert_array_equal(ids, eids, err_msg=f"k={k}")
        np.testing.assert_array_equal(scores, escores, err_msg=f"k={k}")
    # the tie really exists and resolves toward the smaller docid
    ids, scores = topk(idx, [0, 1, 2], 2, mode="maxscore", plan="fused",
                       probe_width=64)
    np.testing.assert_array_equal(ids, [d_star, D])
    np.testing.assert_array_equal(scores, [theta, theta])


def test_maxscore_pruned_accounting_partition(rng):
    """Dense-overlap workload (nearly every block of every term ends up
    decoded by some pass): a block gathered by a non-essential
    probe/merge pass is NOT threshold-pruned even though the strip cursor
    never reached it, so per term the pruned/decoded block sets partition
    the list exactly and ``blocks_pruned + unique-decoded == total`` —
    the old accounting double-booked probe-decoded blocks as pruned
    (decoded + pruned exceeded the whole index)."""
    lists = make_lists(rng, (40, 1500, 2000))
    tfs = make_tfs(rng, lists)  # zipf tfs saturate the quantizer: the
    #   long terms' bounds tie θ, so nothing is strictly prunable
    idx = build_index(lists, tfs=tfs, block_size=B, n_docs=U)
    st = QueryStats()
    ids, scores = topk(idx, [0, 1, 2], 10, mode="maxscore", plan="jnp",
                       probe_width=64, stats=st)
    oids, oscores = topk(idx, [0, 1, 2], 10, mode="or", plan="jnp")
    np.testing.assert_array_equal(ids, oids)
    np.testing.assert_array_equal(scores, oscores)
    total_blocks = sum(tp.n_blocks for tp in idx.terms.values())
    for t, tp in idx.terms.items():
        got = len(st.per_term_blocks.get(t, ()))
        assert st.per_term_pruned.get(t, 0) + got == tp.n_blocks
    # pruned and decoded are disjoint, so pruned can never exceed the
    # index minus what was decoded (the old accounting double-booked
    # probe-decoded blocks as pruned: decoded + pruned > total)
    uniq_decoded = sum(len(s) for s in st.per_term_blocks.values())
    assert st.blocks_pruned + uniq_decoded == total_blocks


def test_probe_rows_accounting(rng):
    """Row-gathered probe passes count per-probe row gathers separately
    from the unique decoded/skipped block partition, and ints follow
    rows (the real decode work), not unique blocks."""
    lists = make_lists(rng, (40, 1200))
    idx = build_index(lists, block_size=B, n_docs=U)
    st = QueryStats()
    got = conjunctive(idx, [0, 1], plan="jnp", stats=st)
    np.testing.assert_array_equal(got, oracle_and(lists, [0, 1]))
    tp1 = idx.terms[1]
    # driver decode pass + probe pass both account term 1's blocks once
    assert st.rows_gathered > 0
    # every gathered row decodes a nonempty block
    assert st.ints_decoded >= st.rows_gathered
    # unique blocks considered per pass never exceed the term's total
    assert st.per_term_decoded[1] <= tp1.n_blocks


def test_search_engine_maxscore_mode(rng):
    from repro.launch.serve import SearchEngine

    lists = make_lists(rng, (50, 600, 900))
    tfs = make_tfs(rng, lists)
    idx = build_index(lists, tfs=tfs, block_size=B, n_docs=U)
    engine = SearchEngine(idx, top_k=5)
    for terms in ([0, 1], [0, 1, 2]):
        ids_m, sc_m = engine.search(terms, "topk_maxscore")
        ids_t, sc_t = engine.search(terms, "topk")
        np.testing.assert_array_equal(ids_m, ids_t)
        np.testing.assert_array_equal(sc_m, sc_t)
    stats = engine.run_workload([("topk_maxscore", [0, 1, 2]),
                                 ("topk", [0, 2])])
    assert {"pruned_block_rate", "pruned_impact_rate"} <= stats.keys()
    assert 0 <= stats["pruned_block_rate"] <= 1
    assert 0 <= stats["pruned_impact_rate"] <= 1


# ---------------------------------------------------------------------------
# SearchEngine: workload driver + sharded parity
# ---------------------------------------------------------------------------
def test_search_engine_workload(rng):
    from repro.launch.serve import SearchEngine, search_queries

    lists = make_lists(rng, (60, 250, 400))
    idx = build_index(lists, block_size=B, n_docs=U)
    engine = SearchEngine(idx, top_k=5)
    qs = search_queries(rng, idx, 9)
    engine.warmup(qs[:3])
    stats = engine.run_workload(qs)
    assert stats["n_queries"] == 9 and stats["qps"] > 0
    assert stats["blocks_decoded"] > 0
    assert 0 <= stats["block_skip_rate"] <= 1
    np.testing.assert_array_equal(engine.search([0, 1], "and"),
                                  oracle_and(lists, [0, 1]))


@multi_device
@pytest.mark.parametrize("fmt", FMTS)
def test_sharded_vs_single_parity(rng, fmt):
    """Sharded engine (block-parallel shard_map, no skip slicing) must be
    bit-identical to the single-device skip-pruned engine."""
    from repro.launch.serve import SearchEngine

    lists = make_lists(rng, (45, 300, 700))
    idx = build_index(lists, format=fmt, block_size=B, n_docs=U)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    single = SearchEngine(idx, top_k=8)
    sharded = SearchEngine(idx, mesh=mesh, top_k=8)
    assert not sharded.use_skip
    for terms in ([0, 1], [0, 1, 2]):
        np.testing.assert_array_equal(sharded.search(terms, "and"),
                                      single.search(terms, "and"))
        np.testing.assert_array_equal(sharded.search(terms, "or"),
                                      single.search(terms, "or"))
        for mode in ("topk", "topk_driver"):
            ids_s, sc_s = sharded.search(terms, mode)
            ids_1, sc_1 = single.search(terms, mode)
            np.testing.assert_array_equal(ids_s, ids_1, err_msg=mode)
            np.testing.assert_array_equal(sc_s, sc_1, err_msg=mode)
        np.testing.assert_array_equal(ids_1,
                                      oracle_topk(idx, lists, terms, 8,
                                                  mode="driver")[0])
