"""Pytree semantics of CompressedIntArray: flatten/unflatten round-trips,
jit-argument stability (no retrace on new data of the same shape), grad and
scan pass-through, and the ``use_kernel`` deprecation surface."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import CompressedIntArray
from repro.core.compressed_array import FORMAT_LEAVES

FMTS = ["vbyte", "streamvbyte"]


def _encode(rng, fmt, n=300, *, differential=True, block_size=32, small=False):
    hi = 120 if small else 2**20  # small=True pins every int to 1 byte
    vals = np.sort(rng.integers(0, hi, n)).astype(np.uint64)
    return CompressedIntArray.encode(vals, format=fmt, block_size=block_size,
                                     differential=differential), vals


# ---------------------------------------------------------------------------
# flatten / unflatten
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fmt", FMTS)
def test_tree_roundtrip(rng, fmt):
    arr, vals = _encode(rng, fmt)
    leaves, treedef = jax.tree_util.tree_flatten(arr)
    assert len(leaves) == len(FORMAT_LEAVES[fmt])
    arr2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(arr2, CompressedIntArray)
    # static aux survives; the host encoding deliberately does not
    assert (arr2.format, arr2.block_size, arr2.differential, arr2.n,
            arr2.ragged) == (fmt, 32, True, arr.n, False)
    assert arr2.host_enc is None
    np.testing.assert_array_equal(arr2.decode(), vals.astype(np.uint32))
    with pytest.raises(RuntimeError, match="host-side encoding"):
        _ = arr2.bits_per_int


@pytest.mark.parametrize("fmt", FMTS)
def test_tree_map_preserves_type(rng, fmt):
    arr, _ = _encode(rng, fmt)
    arr2 = jax.tree.map(jnp.asarray, arr)
    assert isinstance(arr2, CompressedIntArray)
    assert arr2.format == fmt and arr2.n == arr.n
    np.testing.assert_array_equal(arr2.decode(), arr.decode())


def test_two_formats_have_distinct_treedefs(rng):
    a, _ = _encode(rng, "vbyte")
    b, _ = _encode(rng, "streamvbyte")
    assert (jax.tree_util.tree_structure(a)
            != jax.tree_util.tree_structure(b))


def test_from_operands_validation(rng):
    arr, _ = _encode(rng, "vbyte")
    ops = arr.device_operands()
    rebuilt = CompressedIntArray.from_operands(
        ops, format="vbyte", block_size=32, differential=True)
    assert rebuilt.n == arr.n  # n defaults to sum(counts)
    np.testing.assert_array_equal(rebuilt.decode(), arr.decode())
    with pytest.raises(ValueError, match="missing"):
        CompressedIntArray.from_operands(
            {"counts": ops["counts"], "bases": ops["bases"]}, format="vbyte")
    with pytest.raises(ValueError, match="unknown format"):
        CompressedIntArray.from_operands(ops, format="pfor")
    with pytest.raises(ValueError, match="n= is required"):
        CompressedIntArray.from_operands(
            {"payload": jax.ShapeDtypeStruct((2, 128), jnp.uint8),
             "counts": jax.ShapeDtypeStruct((2,), jnp.int32),
             "bases": jax.ShapeDtypeStruct((2,), jnp.uint32)},
            format="vbyte")


# ---------------------------------------------------------------------------
# jit / grad / scan pass-through
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fmt", FMTS)
def test_jit_pass_through_and_no_retrace(rng, fmt):
    """Same-shape arrays with different data share ONE jit trace."""
    traces = []

    @jax.jit
    def f(arr):
        traces.append(1)  # trace-time side effect
        return arr.decode_blocked(plan="jnp")

    # small=True keeps every int at 1 encoded byte, so both arrays get the
    # same payload stride (shape) no matter the data
    a1, v1 = _encode(rng, fmt, small=True)
    a2, v2 = _encode(rng, fmt, small=True)
    out1 = np.asarray(f(a1)).reshape(-1)[: a1.n]
    out2 = np.asarray(f(a2)).reshape(-1)[: a2.n]
    np.testing.assert_array_equal(out1, v1.astype(np.uint32))
    np.testing.assert_array_equal(out2, v2.astype(np.uint32))
    assert len(traces) == 1, "same-shape CompressedIntArray must not retrace"


def test_jit_retraces_on_static_aux_change(rng):
    traces = []

    @jax.jit
    def f(arr):
        traces.append(1)
        return arr.decode_blocked(plan="jnp")

    a_diff, _ = _encode(rng, "vbyte", small=True, differential=True)
    a_abs, _ = _encode(rng, "vbyte", small=True, differential=False)
    f(a_diff)
    f(a_abs)  # differential flips -> different static aux -> new trace
    assert len(traces) == 2


@pytest.mark.parametrize("fmt", FMTS)
def test_grad_through_fused_bag(rng, fmt):
    """The array passes through grad as a jit arg; gradients flow to the
    table through the fused bag_sum epilogue."""
    from repro.nn.embedding_bag import embedding_bag_compressed

    lists = [np.sort(rng.choice(np.arange(1, 64), size=k, replace=False))
             for k in (3, 0, 5)]
    bags = CompressedIntArray.encode_ragged(lists, format=fmt, block_size=8,
                                            differential=True)
    table = jnp.asarray(rng.standard_normal((64, 4)).astype(np.float32))

    @jax.jit
    def loss(tab, arr):
        return embedding_bag_compressed(tab, arr, dtype=jnp.float32).sum()

    g = jax.grad(loss)(table, bags)
    # every id that appears in a bag contributes exactly 1.0 per output dim
    expect = np.zeros((64, 4), np.float32)
    for lst in lists:
        for i in lst:
            expect[i] += 1.0
    np.testing.assert_allclose(np.asarray(g), expect, atol=1e-6)


def test_scan_carries_array(rng):
    arr, vals = _encode(rng, "vbyte", small=True)
    arr = jax.tree.map(jnp.asarray, arr)

    def body(carry, _):
        return carry, carry.counts.sum()

    out, sums = jax.lax.scan(body, arr, xs=jnp.arange(3))
    assert isinstance(out, CompressedIntArray)
    np.testing.assert_array_equal(np.asarray(sums), [arr.n] * 3)


# ---------------------------------------------------------------------------
# use_kernel deprecation surface
# ---------------------------------------------------------------------------
def test_decode_use_kernel_warns(rng):
    arr, vals = _encode(rng, "vbyte")
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        out = arr.decode(use_kernel=True)
    np.testing.assert_array_equal(out, vals.astype(np.uint32))
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        out = arr.decode(use_kernel=False)
    np.testing.assert_array_equal(out, vals.astype(np.uint32))


def test_pipeline_use_kernel_warns(rng):
    from repro.data.pipeline import CompressedTokenPipeline

    toks = rng.integers(0, 100, 2 * 9 * 3).astype(np.uint64)
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        pipe = CompressedTokenPipeline(toks, batch=2, seq_len=8,
                                       use_kernel=False)
    assert pipe.plan == "jnp"
    pipe2 = CompressedTokenPipeline(toks, batch=2, seq_len=8, plan="kernel")
    np.testing.assert_array_equal(
        np.asarray(pipe.get_batch(0)["tokens"]),
        np.asarray(pipe2.get_batch(0)["tokens"]))


def test_decode_compressed_edges_use_kernel_warns(rng):
    from repro.data.graph import compress_adjacency
    from repro.data.sampler import CSRGraph
    from repro.data.synthetic import random_graph
    from repro.nn.gnn import decode_compressed_edges

    g = random_graph(rng, 20, 60, 4, 2)
    csr = CSRGraph.from_edges(g["edge_src"], g["edge_dst"], 20)
    comp = compress_adjacency(csr)
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        src, dst = decode_compressed_edges(
            comp["gaps"], jnp.asarray(comp["row_offsets"]), csr.n_edges,
            use_kernel=False)
    np.testing.assert_array_equal(np.asarray(src), csr.indices)


def test_legacy_cand_batch_keys_warn(rng):
    from repro.models.recsys import _cand_array

    arr, _ = _encode(rng, "vbyte", block_size=128)
    ops = arr.device_operands()
    with pytest.warns(DeprecationWarning, match="cand_payload"):
        rebuilt = _cand_array({"cand_payload": ops["payload"],
                               "cand_counts": ops["counts"],
                               "cand_bases": ops["bases"]})
    assert rebuilt.format == "vbyte"
    assert rebuilt.n == arr.n  # real count, not block capacity
    batch = {"cands": arr}
    assert _cand_array(batch) is arr  # pytree-native path: no warning, no copy
