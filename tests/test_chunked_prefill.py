"""Chunked prefill == full prefill (logits + cache + subsequent decode)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models import lm

pytestmark = pytest.mark.slow  # heavyweight model/system tier (deselected from tier-1)


@pytest.mark.parametrize("window", [None, 8])
@pytest.mark.parametrize("moe", [False, True])
def test_chunked_prefill_parity(window, moe):
    cfg = lm.LMConfig(
        name="t", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=4 if moe else 2, d_ff=64, vocab=97,
        q_chunk=8, kv_chunk=8, loss_chunk=8, window=window,
        moe=lm.MoESettings(n_experts=4, top_k=2, d_ff=48,
                           capacity_factor=4.0) if moe else None,
    )
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 97)

    lg_full, cache_full = lm.prefill(params, toks, cfg, dtype=jnp.float32)
    lg_chunk, cache_chunk = lm.prefill_chunked(params, toks, cfg, chunk=8,
                                               dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lg_full), np.asarray(lg_chunk),
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(cache_full["k"], np.float32),
                               np.asarray(cache_chunk["k"], np.float32),
                               atol=2e-3)
    assert int(cache_chunk["index"]) == 32

    # decoding from either cache produces the same next-token logits
    nxt = jax.random.randint(jax.random.PRNGKey(2), (2,), 0, 97)
    d_full, _ = lm.decode_step(params, cache_full, nxt, cfg, dtype=jnp.float32)
    d_chunk, _ = lm.decode_step(params, cache_chunk, nxt, cfg, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(d_full), np.asarray(d_chunk), atol=2e-3)
