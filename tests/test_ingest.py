"""Crash-safe streaming ingestion: WAL, LiveIndex, recovery fuzzing.

The contract under test (docs/ingestion.md):

* every acknowledged add/delete survives any crash (WAL-append-before-ack),
* recovery reopens to query results **bit-identical** to an index rebuilt
  from scratch from the acknowledged logical state (the oracle),
* every named crash point in the merge sequence recovers,
* every durability fault class is detect-or-recover — never a silent
  wrong answer,
* queries served during a background merge equal quiescent results.

The interleaving oracle's seed count scales with ``INGEST_ORACLE_SEEDS``
(default keeps tier-1 fast; the CI ingestion job sets 200+ to meet the
acceptance bar ≥200 interleavings × every crash point).
"""
from __future__ import annotations

import json
import os
import shutil

import numpy as np
import pytest

from repro.index import (CRASH_POINTS, CrashPoint, LiveIndex, QueryStats,
                         build_index, conjunctive, disjunctive, topk)
from repro.index.wal import WalWriter, open_wal, read_wal
from repro.robustness import (CheckpointError, SegmentError, WalError,
                              atomic_write_bytes, atomic_write_dir,
                              atomic_write_json, clean_tmp, crc32_file)
from repro.robustness.faultgen import DURABILITY_CLASSES, corrupt_dir

ORACLE_SEEDS = int(os.environ.get("INGEST_ORACLE_SEEDS", "12"))
UNIVERSE = 5000
N_TERMS = 8


# ---------------------------------------------------------------------------
# helpers: op streams and the rebuilt-from-scratch oracle
# ---------------------------------------------------------------------------
def rand_terms(rng):
    k = int(rng.integers(1, 4))
    return {int(t): int(rng.integers(1, 5))
            for t in rng.choice(N_TERMS, size=k, replace=False)}


def apply_stream(rng, live, state, n_ops, *, p_del=0.3):
    """Drive random acked ops into ``live``, mirroring them in ``state``."""
    for _ in range(n_ops):
        if state and rng.random() < p_del:
            doc = int(rng.choice(sorted(state)))
            live.delete(doc)
            del state[doc]
        else:
            doc = int(rng.integers(UNIVERSE))
            if doc in state:
                continue
            terms = rand_terms(rng)
            live.add(doc, terms)
            state[doc] = terms


def oracle_index(state):
    lists, tfs = {}, {}
    for doc in sorted(state):
        for t, tf in state[doc].items():
            lists.setdefault(t, []).append(doc)
            tfs.setdefault(t, []).append(tf)
    return build_index(
        {t: np.asarray(v, np.int64) for t, v in lists.items()},
        tfs={t: np.asarray(v, np.int64) for t, v in tfs.items()},
        format="auto", n_docs=UNIVERSE, checksum=True)


QUERY_SETS = ([0, 3], [1], [2, 5, 7], [4, 6], [0, 1, 2])


def assert_parity(live, state, *, tag="", queries=QUERY_SETS, k=5):
    """live results == rebuilt-from-scratch results, bit for bit, for
    AND / OR / top-k over the given query term sets."""
    idx = oracle_index(state)
    for q in queries:
        a = live.search(q, mode="and")
        b = conjunctive(idx, q)
        assert np.array_equal(a, b) and a.dtype == b.dtype, (tag, "and", q)
        a = live.search(q, mode="or")
        b = disjunctive(idx, q)
        assert np.array_equal(a, b) and a.dtype == b.dtype, (tag, "or", q)
        ad, asc = live.search(q, mode="topk", k=k)
        bd, bsc = topk(idx, q, k, mode="or")
        assert np.array_equal(ad, bd) and np.array_equal(asc, bsc), \
            (tag, "topk", q, (ad, asc), (bd, bsc))


def fresh_live(path, **kw):
    kw.setdefault("n_docs", UNIVERSE)
    kw.setdefault("fsync", False)  # tests hammer the disk; torn-tail
    #   semantics are injected explicitly, not left to the page cache
    return LiveIndex(str(path), **kw)


# ---------------------------------------------------------------------------
# WAL framing
# ---------------------------------------------------------------------------
class TestWal:
    def test_roundtrip(self, tmp_path):
        p = str(tmp_path / "w.log")
        w = WalWriter(p, fsync=False)
        ops = [{"op": "add", "doc": i, "terms": {"0": 1}} for i in range(7)]
        ops.append({"op": "del", "doc": 3})
        for op in ops:
            w.append(op)
        w.close()
        got, valid = read_wal(p)
        assert got == ops and valid == os.path.getsize(p)

    def test_torn_tail_truncated_on_open(self, tmp_path):
        p = str(tmp_path / "w.log")
        w = WalWriter(p, fsync=False)
        w.append({"op": "add", "doc": 1, "terms": {"0": 1}})
        w.append({"op": "add", "doc": 2, "terms": {"0": 1}})
        w.close()
        size = os.path.getsize(p)
        with open(p, "ab") as f:  # half-written header
            f.write(b"\x99\x01")
        ops, valid = read_wal(p)
        assert len(ops) == 2 and valid == size
        ops2, w2 = open_wal(p, fsync=False)
        w2.close()
        assert ops2 == ops and os.path.getsize(p) == size  # tail gone

    def test_tail_cut_inside_final_record_recovers_prefix(self, tmp_path):
        p = str(tmp_path / "w.log")
        w = WalWriter(p, fsync=False)
        w.append({"op": "add", "doc": 1, "terms": {"0": 1}})
        end1 = w.append({"op": "add", "doc": 2, "terms": {"0": 1}})
        w.close()
        with open(p, "r+b") as f:
            f.truncate(end1 - 3)
        ops, valid = read_wal(p)
        assert [op["doc"] for op in ops] == [1]
        assert valid < end1 - 3  # the sheared record doesn't count

    def test_midlog_corruption_detected(self, tmp_path):
        p = str(tmp_path / "w.log")
        w = WalWriter(p, fsync=False)
        w.append({"op": "add", "doc": 1, "terms": {"0": 1}})
        w.append({"op": "add", "doc": 2, "terms": {"0": 1}})
        w.close()
        with open(p, "r+b") as f:  # flip a payload byte of record 0
            f.seek(10)
            b = f.read(1)[0]
            f.seek(10)
            f.write(bytes([b ^ 0x40]))
        with pytest.raises(WalError):
            read_wal(p)

    def test_final_record_crc_garbage_is_torn(self, tmp_path):
        p = str(tmp_path / "w.log")
        w = WalWriter(p, fsync=False)
        w.append({"op": "add", "doc": 1, "terms": {"0": 1}})
        w.append({"op": "add", "doc": 2, "terms": {"0": 1}})
        w.close()
        size = os.path.getsize(p)
        with open(p, "r+b") as f:  # corrupt the FINAL record's payload
            f.seek(size - 1)
            b = f.read(1)[0]
            f.seek(size - 1)
            f.write(bytes([b ^ 1]))
        ops, valid = read_wal(p)  # final record = possibly-torn append
        assert [op["doc"] for op in ops] == [1] and valid < size

    def test_bad_length_midlog_detected(self, tmp_path):
        p = str(tmp_path / "w.log")
        w = WalWriter(p, fsync=False)
        for i in range(40):  # enough bytes after record 0
            w.append({"op": "add", "doc": i,
                      "terms": {str(j): 1 for j in range(8)}})
        w.close()
        with open(p, "r+b") as f:  # misframe record 0: wrong in-file length
            f.write((2000).to_bytes(4, "little"))
        with pytest.raises(WalError):
            read_wal(p)

    def test_oversize_length_past_eof_is_torn(self, tmp_path):
        # documented limitation (wal.py): a bogus length claiming an
        # extent past EOF is indistinguishable from a torn append — the
        # reader recovers the shorter prefix instead of erroring
        p = str(tmp_path / "w.log")
        w = WalWriter(p, fsync=False)
        w.append({"op": "add", "doc": 1, "terms": {"0": 1}})
        end1 = w.tell()
        w.append({"op": "add", "doc": 2, "terms": {"0": 1}})
        w.close()
        with open(p, "r+b") as f:
            f.seek(end1)
            f.write((1 << 24).to_bytes(4, "little"))
        ops, valid = read_wal(p)
        assert [op["doc"] for op in ops] == [1] and valid == end1


# ---------------------------------------------------------------------------
# atomic_io
# ---------------------------------------------------------------------------
class TestAtomicIO:
    def test_atomic_write_bytes_replaces(self, tmp_path):
        p = str(tmp_path / "f.bin")
        atomic_write_bytes(p, b"one", fsync=False)
        atomic_write_bytes(p, b"two", fsync=False)
        assert open(p, "rb").read() == b"two"
        assert os.listdir(tmp_path) == ["f.bin"]  # no tmp leftovers

    def test_atomic_write_json(self, tmp_path):
        p = str(tmp_path / "m.json")
        atomic_write_json(p, {"a": 1}, fsync=False)
        assert json.load(open(p)) == {"a": 1}

    def test_atomic_write_dir_fill_failure_leaves_old(self, tmp_path):
        d = str(tmp_path / "seg")

        def ok(t):
            open(os.path.join(t, "x"), "w").write("v1")

        atomic_write_dir(d, ok, fsync=False)

        def boom(t):
            open(os.path.join(t, "x"), "w").write("v2")
            raise RuntimeError("die mid-fill")

        with pytest.raises(RuntimeError):
            atomic_write_dir(d, boom, fsync=False)
        assert open(os.path.join(d, "x")).read() == "v1"
        assert [e for e in os.listdir(tmp_path)
                if e.startswith(".tmp_")] == []

    def test_atomic_write_dir_replaces(self, tmp_path):
        d = str(tmp_path / "seg")
        atomic_write_dir(
            d, lambda t: open(os.path.join(t, "x"), "w").write("v1"),
            fsync=False)
        atomic_write_dir(
            d, lambda t: open(os.path.join(t, "x"), "w").write("v2"),
            fsync=False)
        assert open(os.path.join(d, "x")).read() == "v2"
        assert [e for e in os.listdir(tmp_path)
                if e.startswith(".tmp_")] == []

    def test_atomic_write_dir_replace_never_drops_old_first(
            self, tmp_path, monkeypatch):
        """Replacing an existing dir renames the old version away (it is
        deleted only after the new one carries the final name); a failed
        rename-in restores the old version under its name."""
        d = str(tmp_path / "seg")
        atomic_write_dir(
            d, lambda t: open(os.path.join(t, "x"), "w").write("v1"),
            fsync=False)
        real, hits = os.rename, []

        def flaky(src, dst):
            if os.path.abspath(dst) == os.path.abspath(d):
                hits.append(dst)
                if len(hits) == 1:  # fail the first rename-in only
                    raise OSError("injected rename failure")
            return real(src, dst)

        monkeypatch.setattr(os, "rename", flaky)
        with pytest.raises(OSError):
            atomic_write_dir(
                d, lambda t: open(os.path.join(t, "x"), "w").write("v2"),
                fsync=False)
        assert open(os.path.join(d, "x")).read() == "v1"  # old restored

    def test_clean_tmp(self, tmp_path):
        os.makedirs(tmp_path / ".tmp_seg_1_2")
        open(tmp_path / ".tmp_f", "w").write("x")
        open(tmp_path / "keep", "w").write("x")
        assert clean_tmp(str(tmp_path)) == 2
        assert sorted(os.listdir(tmp_path)) == ["keep"]

    def test_crc32_file_detects_any_change(self, tmp_path):
        p = str(tmp_path / "f")
        open(p, "wb").write(b"hello world" * 100)
        c0 = crc32_file(p)
        with open(p, "r+b") as f:
            f.seek(500)
            f.write(b"\x00")
        assert crc32_file(p) != c0


# ---------------------------------------------------------------------------
# LiveIndex basics
# ---------------------------------------------------------------------------
class TestLiveIndexBasics:
    def test_ops_and_query_parity(self, tmp_path):
        rng = np.random.default_rng(0)
        live = fresh_live(tmp_path / "ix")
        state = {}
        apply_stream(rng, live, state, 80)
        assert_parity(live, state, tag="pre-merge")
        assert live.doc_count() == len(state)
        live.merge()
        assert_parity(live, state, tag="post-merge")
        apply_stream(rng, live, state, 40)
        assert_parity(live, state, tag="delta-over-segment")
        live.close()

    def test_wal_before_ack_add_validation(self, tmp_path):
        live = fresh_live(tmp_path / "ix")
        live.add(5, {0: 2})
        with pytest.raises(ValueError):
            live.add(5, {1: 1})  # exists
        with pytest.raises(ValueError):
            live.add(UNIVERSE + 1, {0: 1})  # out of universe
        with pytest.raises(ValueError):
            live.add(7, {})  # no terms
        with pytest.raises(ValueError):
            live.add(7, {0: 0})  # tf < 1
        with pytest.raises(KeyError):
            live.delete(999)  # absent
        # failed ops were never logged: replay sees exactly one add
        live.close()
        live2 = fresh_live(tmp_path / "ix")
        assert live2.counters["replayed_ops"] == 1 and 5 in live2
        live2.close()

    def test_delete_then_readd(self, tmp_path):
        live = fresh_live(tmp_path / "ix")
        live.add(10, {0: 1})
        live.merge()  # 10 now lives in the main segment
        live.delete(10)  # tombstone
        assert 10 not in live
        live.add(10, {1: 3})  # re-add: delta copy shadows the tombstone
        assert 10 in live
        assert_parity(live, {10: {1: 3}}, tag="readd")
        live.merge()
        assert_parity(live, {10: {1: 3}}, tag="readd-merged")
        live.close()

    def test_restart_replays_to_identical_results(self, tmp_path):
        rng = np.random.default_rng(1)
        live = fresh_live(tmp_path / "ix")
        state = {}
        apply_stream(rng, live, state, 60)
        live.close()
        live2 = fresh_live(tmp_path / "ix")
        assert live2.counters["replayed_ops"] == live.counters["acked_ops"]
        assert_parity(live2, state, tag="restart")
        live2.close()

    def test_replaying_state_flags_queries_degraded(self, tmp_path):
        live = fresh_live(tmp_path / "ix")
        for i in range(5):
            live.add(i, {0: 1})
        live.close()
        seen = []

        def hook(ix, i, op):
            st = QueryStats()
            ix.search([0], mode="or", stats=st)
            seen.append((ix.state, st.degraded, tuple(st.degraded_reasons)))

        live2 = fresh_live(tmp_path / "ix", replay_hook=hook)
        assert len(seen) == 5
        assert all(s == ("replaying", True, ("replaying",)) for s in seen)
        st = QueryStats()
        live2.search([0], mode="or", stats=st)
        assert live2.state == "serving" and not st.degraded
        live2.close()

    def test_delta_stats_accounting(self, tmp_path):
        live = fresh_live(tmp_path / "ix")
        for i in range(20):
            live.add(i, {0: 1})
        live.merge()
        live.delete(3)  # tombstone against main
        live.add(1000, {0: 2})  # delta doc
        st = QueryStats()
        out = live.search([0], mode="or", stats=st)
        assert 3 not in out and 1000 in out
        assert st.tombstones_applied == 1
        assert st.delta_postings == 1 and st.delta_hits == 1
        assert st.blocks_decoded > 0  # main postings went through decode
        live.close()

    def test_snapshot_isolation_across_merge(self, tmp_path):
        live = fresh_live(tmp_path / "ix")
        for i in range(10):
            live.add(i, {0: i % 3 + 1})
        snap = live.snapshot()
        assert live.readers() == {0: 1}
        live.merge()  # epoch swap while a reader is out
        assert live.epoch == 1
        # the old snapshot still answers from epoch-0 state
        docs, tfs, _ = live._term_merged(snap, 0, None)
        assert list(docs) == list(range(10))
        live.add(2000, {0: 1})
        docs2, _, _ = live._term_merged(snap, 0, None)
        assert 2000 not in docs2  # invisible to the old snapshot
        live.release(snap)
        assert 0 not in live.readers()
        live.close()

    def test_writes_during_merge_stay_live(self, tmp_path):
        live = fresh_live(tmp_path / "ix")
        state = {}
        for i in range(30):
            live.add(i, {int(i % N_TERMS): 1})
            state[i] = {int(i % N_TERMS): 1}

        def hook(name):
            # mutate mid-merge: ops land in the rotated WAL + active delta
            if name == "after_build":
                live.add(4000, {0: 9})
                state[4000] = {0: 9}
                live.delete(7)
                del state[7]
                assert_parity(live, state, tag="mid-merge-writes")

        live.merge(step_hook=hook)
        assert_parity(live, state, tag="post-merge-writes")
        # and they survive a restart (they were WAL-acked, not merged)
        live.close()
        live2 = fresh_live(tmp_path / "ix")
        assert live2.counters["replayed_ops"] == 2
        assert_parity(live2, state, tag="post-merge-writes-restart")
        live2.close()

    def test_merge_during_merge_rejected(self, tmp_path):
        live = fresh_live(tmp_path / "ix")
        live.add(1, {0: 1})

        def hook(name):
            if name == "after_rotate":
                with pytest.raises(RuntimeError):
                    live.merge()

        live.merge(step_hook=hook)
        live.close()


# ---------------------------------------------------------------------------
# crash-point recovery + the randomized interleaving oracle
# ---------------------------------------------------------------------------
def crash_and_recover(src_dir, tmp_path, cp, state, *, tag):
    """Copy the closed index dir, crash a merge at ``cp``, reopen, check
    parity, then complete the merge and check again."""
    dd = str(tmp_path / f"crash_{tag}_{cp}")
    shutil.copytree(src_dir, dd)
    lc = LiveIndex(dd, fsync=False)
    with pytest.raises(CrashPoint):
        lc.merge(crash_at=cp)
    assert lc.state == "merge_in_progress"  # the carcass stays poisoned
    lc.close()
    lr = LiveIndex(dd, fsync=False)
    assert_parity(lr, state, tag=f"{tag}:{cp}:recovered")
    lr.merge()
    assert_parity(lr, state, tag=f"{tag}:{cp}:post-retry-merge")
    lr.close()
    shutil.rmtree(dd)


@pytest.mark.parametrize("seed", range(ORACLE_SEEDS))
def test_interleaving_oracle(seed, tmp_path):
    """≥N seeded add/delete/query interleavings; each checked against the
    rebuilt-from-scratch oracle at every query step, then crashed at EVERY
    named crash point and recovered to bit-identical results — including
    interleavings that already contain a committed merge."""
    rng = np.random.default_rng(1000 + seed)
    base = str(tmp_path / "ix")
    live = LiveIndex(base, n_docs=UNIVERSE, fsync=False)
    state = {}
    # op stream with interleaved queries; some seeds merge mid-stream so
    # the crash sweep below exercises delta-over-segment states
    n_rounds = int(rng.integers(3, 6))
    for r in range(n_rounds):
        apply_stream(rng, live, state, int(rng.integers(8, 20)))
        qs = [sorted(int(t) for t in
                     rng.choice(N_TERMS, size=rng.integers(1, 4),
                                replace=False))]
        assert_parity(live, state, tag=f"seed{seed}:round{r}", queries=qs)
        if r == 1 and rng.random() < 0.5:
            live.merge()
            assert_parity(live, state, tag=f"seed{seed}:merged{r}",
                          queries=qs)
    live.close()

    for cp in CRASH_POINTS:
        crash_and_recover(base, tmp_path, cp, state, tag=f"seed{seed}")


@pytest.mark.parametrize("cp", CRASH_POINTS)
def test_mid_merge_queries_bit_identical(cp, tmp_path):
    """Queries served at every point of an in-flight merge equal the
    quiescent (pre- and post-merge) results bit-for-bit."""
    rng = np.random.default_rng(7)
    live = fresh_live(tmp_path / "ix")
    state = {}
    apply_stream(rng, live, state, 50)
    live.merge()
    apply_stream(rng, live, state, 30)  # delta over segment
    ran = []

    def hook(name):
        if name == cp:
            assert_parity(live, state, tag=f"at:{name}")
            ran.append(name)

    live.merge(step_hook=hook)
    assert ran == [cp]
    assert_parity(live, state, tag="quiescent-after")
    live.close()


def test_double_crash_then_recover(tmp_path):
    """Crash a merge, then crash the RETRY at a later point; recovery must
    still replay to the oracle (crashes compose)."""
    rng = np.random.default_rng(11)
    live = fresh_live(tmp_path / "ix")
    state = {}
    apply_stream(rng, live, state, 40)
    with pytest.raises(CrashPoint):
        live.merge(crash_at="after_rotate")
    live.close()
    live = fresh_live(tmp_path / "ix")
    with pytest.raises(CrashPoint):
        live.merge(crash_at="manifest_tmp_written")
    live.close()
    live = fresh_live(tmp_path / "ix")
    assert_parity(live, state, tag="double-crash")
    live.merge()
    assert_parity(live, state, tag="double-crash-merged")
    live.close()


# ---------------------------------------------------------------------------
# writer/rotation atomicity and merge-failure rollback
# ---------------------------------------------------------------------------
def test_merge_precommit_failure_rolls_back_to_serving(tmp_path):
    """A real (non-injected) failure before the commit point must not
    poison the index: state returns to ``serving``, the frozen delta folds
    back, parity holds, and a retried merge succeeds."""

    class Boom(RuntimeError):
        pass

    rng = np.random.default_rng(23)
    live = fresh_live(tmp_path / "ix")
    state = {}
    apply_stream(rng, live, state, 40)

    def hook(name):
        if name == "segment_tmp_written":
            # ops racing the doomed merge: a fresh add plus a delete of a
            # frozen doc — both must survive the rollback
            live.add(4100, {0: 3})
            state[4100] = {0: 3}
            victim = next(d for d in sorted(state) if d != 4100)
            live.delete(victim)
            del state[victim]
            raise Boom("transient disk error")

    with pytest.raises(Boom):
        live.merge(step_hook=hook)
    assert live.state == "serving"
    assert_parity(live, state, tag="post-failed-merge")
    live.merge()  # retry is allowed and drains everything
    assert live.state == "serving" and live.epoch == 1
    assert_parity(live, state, tag="post-retried-merge")

    # failure before anything rotated: plain state restore, retry works
    def hook2(name):
        if name == "before_rotate":
            raise Boom("hook failure")

    with pytest.raises(Boom):
        live.merge(step_hook=hook2)
    assert live.state == "serving"
    live.merge()
    assert_parity(live, state, tag="post-unrotated-failure")

    # every acked op (incl. those racing the failed merge) survives restart
    live.close()
    live2 = fresh_live(tmp_path / "ix")
    assert_parity(live2, state, tag="post-failure-restart")
    live2.close()


def test_concurrent_writers_during_merge(tmp_path):
    """Writer threads racing background merges: every acked op lands on
    the same side of the WAL rotation as its delta placement, so restart
    replay reproduces exactly the acked state (no stranded/lost ops, no
    append-after-close errors)."""
    import threading

    live = fresh_live(tmp_path / "ix")
    state = {}
    for i in range(40):
        live.add(i, {int(i % N_TERMS): 1})
        state[i] = {int(i % N_TERMS): 1}
    acked, errs = [], []

    def writer(base):
        rng = np.random.default_rng(base)
        try:
            for doc in range(1000 * (base + 1), 1000 * (base + 1) + 200):
                terms = {int(rng.integers(N_TERMS)): int(rng.integers(1, 4))}
                live.add(doc, terms)
                acked.append((doc, terms))
        except Exception as e:  # pragma: no cover - failure detail
            errs.append(e)

    threads = [threading.Thread(target=writer, args=(b,)) for b in range(3)]
    for t in threads:
        t.start()
    for _ in range(3):
        live.merge()
    for t in threads:
        t.join()
    assert errs == []
    for doc, terms in acked:
        state[doc] = terms
    assert_parity(live, state, tag="concurrent-writers-quiescent")
    live.close()
    live2 = fresh_live(tmp_path / "ix")
    assert_parity(live2, state, tag="concurrent-writers-restart")
    live2.close()


def test_concurrent_duplicate_adds_one_wins(tmp_path):
    """Two racing adds of the same doc: exactly one is acked and exactly
    one WAL record exists — recovery must replay cleanly, not detect a
    duplicate-add divergence."""
    import threading

    live = fresh_live(tmp_path / "ix")
    for doc in range(50):
        barrier = threading.Barrier(2)
        outcomes = []

        def attempt(d=doc):
            barrier.wait()
            try:
                live.add(d, {0: 1})
                outcomes.append("ok")
            except ValueError:
                outcomes.append("dup")

        ts = [threading.Thread(target=attempt) for _ in range(2)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert sorted(outcomes) == ["dup", "ok"], (doc, outcomes)
    live.close()
    live2 = fresh_live(tmp_path / "ix")
    assert live2.counters["replayed_ops"] == 50
    live2.close()


def test_reopen_conflicting_args_rejected(tmp_path):
    """Explicit constructor arguments that disagree with a recovered
    manifest raise instead of being silently ignored."""
    d = str(tmp_path / "ix")
    live = LiveIndex(d, n_docs=UNIVERSE, fsync=False)
    live.add(1, {0: 1})
    live.close()
    for kw in ({"n_docs": UNIVERSE + 1}, {"block_size": 64},
               {"impact_bits": 4}, {"format": "vbyte"},
               {"checksum": False}):
        with pytest.raises(ValueError, match="conflict"):
            LiveIndex(d, fsync=False, **kw)
    # matching explicit args — and no args — both reopen fine
    live = LiveIndex(d, n_docs=UNIVERSE, block_size=128, format="auto",
                     impact_bits=8, checksum=True, fsync=False)
    assert 1 in live
    live.close()
    live = LiveIndex(d, fsync=False)
    assert 1 in live
    live.close()


# ---------------------------------------------------------------------------
# durability fault classes: detect-or-recover, never silent wrong answers
# ---------------------------------------------------------------------------
def _prepped_dir(tmp_path, seed, *, merged: bool):
    """A closed LiveIndex dir with a committed segment + unmerged WAL."""
    rng = np.random.default_rng(seed)
    d = str(tmp_path / f"ix{seed}{int(merged)}")
    live = LiveIndex(d, n_docs=UNIVERSE, fsync=False)
    state = {}
    apply_stream(rng, live, state, 40)
    if merged:
        live.merge()
        apply_stream(rng, live, state, 25)
    live.close()
    return d, state


@pytest.mark.parametrize("cls", sorted(DURABILITY_CLASSES))
@pytest.mark.parametrize("seed", range(3))
def test_durability_class_detect_or_recover(cls, seed, tmp_path):
    d, state = _prepped_dir(tmp_path, seed, merged=True)
    fault = corrupt_dir(d, cls, seed=seed * 7 + 1)
    assert fault is not None, (cls, "did not apply to a merged dir")
    if fault.expect == "detect":
        with pytest.raises((WalError, SegmentError)):
            LiveIndex(d, fsync=False)
        return
    live = LiveIndex(d, fsync=False)
    if fault.ops_lost:
        # the sheared trailing record is treated as an in-flight append
        # that was never acknowledged: recovery serves the acked prefix
        # (at most ops_lost trailing ops rolled back, never more)
        assert abs(live.doc_count() - len(state)) <= fault.ops_lost
        assert live.counters["wal_bytes_truncated"] > 0
    else:
        assert_parity(live, state, tag=cls)
    if cls in ("manifest_stale", "manifest_missing"):
        assert live.counters["rolled_forward"] == 1
    live.close()


def test_wal_faults_apply_premerge(tmp_path):
    """The WAL classes also apply before any merge exists (epoch 0)."""
    d, state = _prepped_dir(tmp_path, 5, merged=False)
    fault = corrupt_dir(d, "wal_record_flip", seed=3)
    assert fault is not None and fault.expect == "detect"
    with pytest.raises(WalError):
        LiveIndex(d, fsync=False)


def test_torn_tail_recovers_acked_prefix_exactly(tmp_path):
    """wal_tail_shear: the one in-flight op rolls back; every *acked* op
    before it survives bit-exactly."""
    d = str(tmp_path / "ix")
    live = LiveIndex(d, n_docs=UNIVERSE, fsync=False)
    state = {}
    rng = np.random.default_rng(21)
    apply_stream(rng, live, state, 30, p_del=0.0)
    last_doc = sorted(state)[-1]
    # make the final record a known add so the expected prefix is state
    # minus that doc
    probe = next(D for D in range(4900, UNIVERSE) if D not in state)
    live.add(probe, {0: 1})
    live.close()
    fault = corrupt_dir(d, "wal_tail_shear", seed=1)
    assert fault is not None and fault.ops_lost == 1
    live2 = LiveIndex(d, fsync=False)
    assert probe not in live2 and last_doc in live2
    assert_parity(live2, state, tag="shear-prefix")
    live2.close()


def test_stale_manifest_rolls_forward(tmp_path):
    """The named 'stale manifest' fault class end to end: manifest rolled
    back + drained WALs gone → recovery adopts the newer segment and
    serves the acknowledged state."""
    d, state = _prepped_dir(tmp_path, 9, merged=True)
    fault = corrupt_dir(d, "manifest_stale", seed=2)
    assert fault is not None and fault.expect == "recover"
    live = LiveIndex(d, fsync=False)
    assert live.counters["rolled_forward"] == 1
    assert_parity(live, state, tag="rolled-forward")
    # and the adopted manifest is durable: a second reopen is clean
    live.close()
    live2 = LiveIndex(d, fsync=False)
    assert live2.counters["rolled_forward"] == 0
    assert_parity(live2, state, tag="rolled-forward-reopen")
    live2.close()


def test_uncommitted_segment_discarded_when_wals_present(tmp_path):
    """The mirror case of roll-forward: an orphan segment whose WALs are
    all still present is an *uncommitted* merge — replay wins, the orphan
    is discarded (no double-apply)."""
    rng = np.random.default_rng(13)
    d = str(tmp_path / "ix")
    live = LiveIndex(d, n_docs=UNIVERSE, fsync=False)
    state = {}
    apply_stream(rng, live, state, 30)
    with pytest.raises(CrashPoint):
        live.merge(crash_at="after_segment_rename")
    live.close()
    seg_dirs = os.listdir(os.path.join(d, "segments"))
    assert any(nm.startswith("seg_") for nm in seg_dirs)  # orphan exists
    live2 = LiveIndex(d, fsync=False)
    assert live2.epoch == 0 and live2.counters["rolled_forward"] == 0
    assert not os.listdir(os.path.join(d, "segments"))  # orphan discarded
    assert_parity(live2, state, tag="orphan-discarded")
    live2.close()


def test_corrupt_orphan_with_wals_present_still_recovers(tmp_path):
    """A crash tore the uncommitted segment AND storage mangled it: with
    the WALs intact, replay recovers; the broken orphan is garbage."""
    rng = np.random.default_rng(17)
    d = str(tmp_path / "ix")
    live = LiveIndex(d, n_docs=UNIVERSE, fsync=False)
    state = {}
    apply_stream(rng, live, state, 25)
    with pytest.raises(CrashPoint):
        live.merge(crash_at="after_segment_rename")
    live.close()
    seg = os.path.join(d, "segments", os.listdir(
        os.path.join(d, "segments"))[0])
    with open(os.path.join(seg, "segment.json"), "w") as f:
        f.write("garbage{")
    live2 = LiveIndex(d, fsync=False)
    assert_parity(live2, state, tag="corrupt-orphan")
    live2.close()


def test_corrupt_orphan_with_wals_gone_detects(tmp_path):
    """Roll-forward candidate is itself corrupt and its WALs are gone:
    history is unrecoverable — typed error, not silent loss."""
    d, state = _prepped_dir(tmp_path, 19, merged=True)
    # stale the manifest (so the committed segment becomes an orphan)...
    assert corrupt_dir(d, "manifest_stale", seed=4) is not None
    # ...and corrupt the orphan segment too
    seg = os.path.join(d, "segments", sorted(os.listdir(
        os.path.join(d, "segments")))[-1])
    with open(os.path.join(seg, "segment.json"), "w") as f:
        f.write("not json")
    with pytest.raises(SegmentError):
        LiveIndex(d, fsync=False)


# ---------------------------------------------------------------------------
# checkpoint hardening (satellite: typed error + skip to intact step)
# ---------------------------------------------------------------------------
class TestCheckpointHardening:
    def _mgr(self, tmp_path):
        from repro.checkpoint.manager import CheckpointManager
        return CheckpointManager(str(tmp_path / "ckpt"), keep=5)

    def _state(self, i):
        return {"w": np.arange(10, dtype=np.int32) + i,
                "b": np.float32(i) * np.ones(3, np.float32)}

    def test_truncated_leaves_falls_back(self, tmp_path):
        mgr = self._mgr(tmp_path)
        mgr.save(1, self._state(1))
        mgr.save(2, self._state(2))
        npz = os.path.join(mgr.dir, "step_00000002", "leaves.npz")
        with open(npz, "r+b") as f:
            f.truncate(os.path.getsize(npz) // 2)
        with pytest.raises(CheckpointError):
            mgr.restore(2, self._state(0))
        state, step = mgr.restore_latest(self._state(0))
        assert step == 1
        assert np.array_equal(state["w"], self._state(1)["w"])

    def test_corrupt_manifest_falls_back(self, tmp_path):
        mgr = self._mgr(tmp_path)
        mgr.save(1, self._state(1))
        mgr.save(2, self._state(2))
        with open(os.path.join(mgr.dir, "step_00000002",
                               "manifest.json"), "w") as f:
            f.write("{broken")
        state, step = mgr.restore_latest(self._state(0))
        assert step == 1
        assert np.array_equal(state["b"], self._state(1)["b"])

    def test_all_steps_corrupt_returns_none(self, tmp_path):
        mgr = self._mgr(tmp_path)
        mgr.save(1, self._state(1))
        npz = os.path.join(mgr.dir, "step_00000001", "leaves.npz")
        with open(npz, "wb") as f:
            f.write(b"junk")
        state, step = mgr.restore_latest(self._state(0))
        assert state is None and step == -1

    def test_atomic_write_no_partial_step_dirs(self, tmp_path):
        mgr = self._mgr(tmp_path)
        mgr.save(3, self._state(3))
        entries = os.listdir(mgr.dir)
        assert entries == ["step_00000003"]
        state, step = mgr.restore_latest(self._state(0))
        assert step == 3


# ---------------------------------------------------------------------------
# segment loader typed errors
# ---------------------------------------------------------------------------
def test_segment_loader_errors_are_typed(tmp_path):
    from repro.index.ingest import load_segment
    rng = np.random.default_rng(3)
    d = str(tmp_path / "ix")
    live = LiveIndex(d, n_docs=UNIVERSE, fsync=False)
    state = {}
    apply_stream(rng, live, state, 30)
    live.merge()
    live.close()
    seg = os.path.join(d, "segments", sorted(os.listdir(
        os.path.join(d, "segments")))[0])
    # clean load works and round-trips the index
    idx, tfs, docs = load_segment(seg)
    assert idx.n_postings > 0 and set(tfs) == set(idx.terms)
    # truncation → SegmentError (whole-file CRC)
    npz = os.path.join(seg, "postings.npz")
    blob = open(npz, "rb").read()
    with open(npz, "wb") as f:
        f.write(blob[:-7])
    with pytest.raises(SegmentError):
        load_segment(seg)
    with open(npz, "wb") as f:  # restore, then flip one byte
        f.write(blob)
    mid = len(blob) // 2
    with open(npz, "r+b") as f:
        f.seek(mid)
        b = f.read(1)[0]
        f.seek(mid)
        f.write(bytes([b ^ 0x10]))
    with pytest.raises(SegmentError):
        load_segment(seg)
