"""Shared fixtures + a small dependency-free property harness.

The tier-1 suite must collect and run with no packages beyond the baked-in
toolchain, so instead of ``hypothesis`` the property tests iterate over
seeded case generators: deterministic edge cases first (empty, zeros,
every byte-length boundary, all-max), then ``np.random.Generator``-seeded
random arrays whose per-value bit widths are mixed so every encoded length
regime appears. Failures print the generator seed + case index, which is
all that's needed to reproduce.
"""
import numpy as np
import pytest

# every byte-length boundary of BOTH formats: VByte switches lengths at
# 2^7/2^14/2^21/2^28, Stream VByte at 2^8/2^16/2^24 — plus 0, 1 and the
# uint32 maximum.
BOUNDARY_VALUES = np.array(
    [0, 1,
     2**7 - 1, 2**7, 2**8 - 1, 2**8,
     2**14 - 1, 2**14, 2**16 - 1, 2**16,
     2**21 - 1, 2**21, 2**24 - 1, 2**24,
     2**28 - 1, 2**28, 2**31, 2**32 - 1],
    dtype=np.uint64,
)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_valid_stream(rng, n, max_bits=32):
    """Random values spanning all byte-lengths 1..5."""
    bits = rng.integers(1, max_bits + 1, size=n)
    vals = rng.integers(0, 2 ** 63, size=n, dtype=np.uint64) % (1 << bits.astype(np.uint64))
    return vals.astype(np.uint64)


def u32_cases(*, n_cases=40, max_len=300, max_value=2**32 - 1, min_len=0,
              seed=1234, sort=False):
    """Yield ``(case_id, uint64 array)`` pairs — the hypothesis stand-in.

    Edge cases come first, then ``n_cases`` seeded random arrays with mixed
    bit widths (so 1..5-byte VByte / 1..4-byte Stream-VByte encodings all
    appear). ``sort=True`` produces non-decreasing sequences for
    differential coding. ``case_id`` strings make failures reproducible.
    """
    mv = np.uint64(max_value)
    edges = [
        ("empty", np.zeros(0, np.uint64)),
        ("single-zero", np.zeros(1, np.uint64)),
        ("boundaries", np.minimum(BOUNDARY_VALUES, mv)),
        ("all-max", np.full(5, mv, np.uint64)),
        ("all-zero", np.zeros(9, np.uint64)),
    ]
    for name, vals in edges:
        if len(vals) >= min_len:
            yield name, np.sort(vals) if sort else vals
    rng = np.random.default_rng(seed)
    for i in range(n_cases):
        n = int(rng.integers(min_len, max_len + 1))
        bits = rng.integers(0, 33, size=n).astype(np.uint64)
        vals = rng.integers(0, 1 << 62, size=n, dtype=np.uint64) >> (
            np.uint64(62) - bits)
        vals = np.minimum(vals, mv)
        yield f"seed{seed}-case{i}", np.sort(vals) if sort else vals


def sorted_u32_cases(*, n_cases=40, max_len=300, max_value=2**31 - 1,
                     min_len=0, seed=1234):
    """Non-decreasing sequences (differential-coding inputs)."""
    return u32_cases(n_cases=n_cases, max_len=max_len, max_value=max_value,
                     min_len=min_len, seed=seed, sort=True)
