import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_valid_stream(rng, n, max_bits=32):
    """Random values spanning all byte-lengths 1..5."""
    bits = rng.integers(1, max_bits + 1, size=n)
    vals = rng.integers(0, 2 ** 63, size=n, dtype=np.uint64) % (1 << bits.astype(np.uint64))
    return vals.astype(np.uint64)
