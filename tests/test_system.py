"""End-to-end behaviour tests for the paper's system: the full loop of
encode → ship → decode-on-device → train → checkpoint → restart, exercising
the public API the way examples/ and launch/ do."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.core import CompressedIntArray
from repro.data.pipeline import CompressedTokenPipeline
from repro.data.synthetic import token_stream
from repro.models import lm
from repro.train import OptimizerConfig, init_train_state, make_train_step

pytestmark = pytest.mark.slow  # heavyweight model/system tier (deselected from tier-1)


def test_end_to_end_compressed_training_with_restart(tmp_path, rng):
    """Train an LM on a VByte-compressed token pipeline, checkpoint, kill,
    restore, continue — losses must be finite and the restart must resume
    from the saved state bit-exactly."""
    cfg = lm.LMConfig(name="e2e", n_layers=2, d_model=32, n_heads=4,
                      n_kv_heads=2, d_ff=64, vocab=256,
                      q_chunk=16, kv_chunk=16, loss_chunk=8)
    toks = token_stream(rng, 4 * 33 * 8, cfg.vocab)
    pipe = CompressedTokenPipeline(toks, batch=4, seq_len=32, plan="kernel")
    assert pipe.compression_ratio() > 1.0

    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params)
    step_fn = jax.jit(make_train_step(
        lambda p, b: lm.loss_fn(p, b, cfg),
        OptimizerConfig(peak_lr=1e-3, warmup_steps=2, total_steps=10)))

    mgr = CheckpointManager(str(tmp_path), keep=2)
    losses = []
    for step in range(4):
        state, m = step_fn(state, pipe.get_batch(step))
        losses.append(float(m["loss"]))
        if step == 2:
            mgr.save(step, state)
    assert all(np.isfinite(l) for l in losses)

    # "crash" and restart from step 2
    restored, at = mgr.restore_latest(state)
    assert at == 2
    state2 = jax.tree.map(jnp.asarray, restored)
    state2, m2 = step_fn(state2, pipe.get_batch(3))
    # deterministic replay: identical to the uninterrupted run's step 3
    assert abs(float(m2["loss"]) - losses[3]) < 1e-5


def test_end_to_end_serving_compressed_candidates(rng):
    """Retrieval serving: decode a compressed candidate list in-graph and
    verify the returned top-k ids are real candidates with sorted scores."""
    from repro.models import recsys
    from repro.models.registry import reduced_config

    cfg = reduced_config("two-tower-retrieval")
    params = recsys.init_params(jax.random.PRNGKey(0), cfg)
    cands = np.sort(rng.choice(np.arange(1, cfg.n_items), 512, replace=False))
    arr = CompressedIntArray.encode(cands.astype(np.uint64), differential=True)
    batch = {"cands": arr,  # pytree-native: the array itself rides the batch
             "user_id": jnp.asarray([3], jnp.int32),
             "hist": jnp.asarray(rng.integers(1, cfg.n_items, (1, cfg.seq_len)),
                                 jnp.int32)}
    scores, (top_s, top_i) = recsys.retrieval_scores_compressed(
        params, batch, cfg, top_k=10)
    top_ids = np.asarray(top_i)
    assert np.all(np.isin(top_ids, np.concatenate([cands, [0]])))
    s = np.asarray(top_s)
    assert np.all(s[:-1] >= s[1:])  # descending top-k
