"""Pallas kernel sweeps: shapes/dtypes/strides vs the pure-jnp gather oracle
(interpret=True on CPU). Three implementations must agree bit-exactly:
kernel (MXU one-hot) == ref (gather) == masked (segment-sum) == scalar."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import CompressedIntArray
from repro.core.vbyte import encode as venc
from repro.core.vbyte.masked import decode_blocked
from repro.kernels.vbyte_decode import vbyte_decode_blocked, vbyte_decode_blocked_ref

from conftest import make_valid_stream


def _roundtrip(vals, block_size, differential, block_tile=8, stride_multiple=128):
    arr = CompressedIntArray.encode(vals, block_size=block_size,
                                    differential=differential,
                                    stride_multiple=stride_multiple)
    ops = arr.device_operands()
    kw = dict(block_size=block_size, differential=differential)
    ker = vbyte_decode_blocked(**ops, block_tile=block_tile, **kw)
    ref = vbyte_decode_blocked_ref(**ops, **kw)
    msk = decode_blocked(**ops, **kw)
    np.testing.assert_array_equal(np.asarray(ker), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(ker), np.asarray(msk))
    flat = np.asarray(ker).reshape(-1)[: len(vals)].astype(np.uint64)
    np.testing.assert_array_equal(flat, vals)


@pytest.mark.parametrize("differential", [False, True])
@pytest.mark.parametrize("block_size", [8, 32, 128])
@pytest.mark.parametrize("n", [1, 7, 128, 129, 1000])
def test_kernel_shape_sweep(rng, differential, block_size, n):
    if differential:
        vals = np.sort(rng.integers(0, 2**31, size=n)).astype(np.uint64)
    else:
        vals = make_valid_stream(rng, n)
    _roundtrip(vals, block_size, differential)


@pytest.mark.parametrize("block_tile", [1, 4, 8, 16])
def test_kernel_tile_sweep(rng, block_tile):
    vals = make_valid_stream(rng, 777)
    _roundtrip(vals, 64, False, block_tile=block_tile)


@pytest.mark.parametrize("max_bits", [7, 14, 21, 28, 32])
def test_kernel_byte_length_regimes(rng, max_bits):
    """All 1..5-byte encodings, incl. blocks of uniform length (paper's fast
    path) and the 2^32-1 edge."""
    vals = make_valid_stream(rng, 512, max_bits=max_bits)
    vals[0] = (1 << max_bits) - 1
    _roundtrip(vals, 128, False)


def test_kernel_all_zeros():
    _roundtrip(np.zeros(300, np.uint64), 128, False)


def test_kernel_max_values():
    _roundtrip(np.full(257, 2**32 - 1, np.uint64), 128, False)


def test_kernel_stride_multiple_8(rng):
    # tight strides (stride_multiple=8) exercise non-128-aligned payloads
    vals = make_valid_stream(rng, 333)
    _roundtrip(vals, 64, False, stride_multiple=8)


@given(st.lists(st.integers(min_value=0, max_value=2**32 - 1),
                min_size=1, max_size=400))
@settings(max_examples=30, deadline=None)
def test_prop_kernel_equals_oracle(values):
    vals = np.array(values, np.uint64)
    _roundtrip(vals, 32, False)


@given(st.lists(st.integers(min_value=0, max_value=2**31 - 1),
                min_size=1, max_size=400))
@settings(max_examples=20, deadline=None)
def test_prop_kernel_differential(values):
    vals = np.sort(np.array(values, np.uint64))
    _roundtrip(vals, 32, True)
