"""Pallas kernel sweeps: shapes/dtypes/strides vs the pure-jnp oracles
(interpret=True on CPU). For VByte, three implementations must agree
bit-exactly: kernel (MXU one-hot) == ref (gather) == masked (segment-sum)
== scalar. For Stream VByte: kernel == stream_masked (gather) == scalar.
Seeded case generators from conftest — no hypothesis dependency."""
import numpy as np
import pytest

from repro.core import CompressedIntArray
from repro.core.vbyte.masked import decode_blocked
from repro.core.vbyte.stream_masked import decode_blocked as svb_decode_blocked
from repro.kernels.vbyte_decode import (stream_vbyte_decode_blocked,
                                        vbyte_decode_blocked,
                                        vbyte_decode_blocked_ref)

from conftest import make_valid_stream, sorted_u32_cases, u32_cases


def _roundtrip(vals, block_size, differential, block_tile=8, stride_multiple=128):
    arr = CompressedIntArray.encode(vals, block_size=block_size,
                                    differential=differential,
                                    stride_multiple=stride_multiple)
    ops = arr.device_operands()
    kw = dict(block_size=block_size, differential=differential)
    ker = vbyte_decode_blocked(**ops, block_tile=block_tile, **kw)
    ref = vbyte_decode_blocked_ref(**ops, **kw)
    msk = decode_blocked(**ops, **kw)
    np.testing.assert_array_equal(np.asarray(ker), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(ker), np.asarray(msk))
    flat = np.asarray(ker).reshape(-1)[: len(vals)].astype(np.uint64)
    np.testing.assert_array_equal(flat, vals)


def _roundtrip_svb(vals, block_size, differential, block_tile=8,
                   stride_multiple=128):
    arr = CompressedIntArray.encode(vals, format="streamvbyte",
                                    block_size=block_size,
                                    differential=differential,
                                    stride_multiple=stride_multiple)
    ops = arr.device_operands()
    kw = dict(block_size=block_size, differential=differential)
    ker = stream_vbyte_decode_blocked(**ops, block_tile=block_tile, **kw)
    msk = svb_decode_blocked(**ops, **kw)
    np.testing.assert_array_equal(np.asarray(ker), np.asarray(msk))
    flat = np.asarray(ker).reshape(-1)[: len(vals)].astype(np.uint64)
    np.testing.assert_array_equal(flat, vals)


@pytest.mark.parametrize("differential", [False, True])
@pytest.mark.parametrize("block_size", [8, 128])  # 32 covered by prop tests
@pytest.mark.parametrize("n", [7, 129, 1000])
def test_kernel_shape_sweep(rng, differential, block_size, n):
    if differential:
        vals = np.sort(rng.integers(0, 2**31, size=n)).astype(np.uint64)
    else:
        vals = make_valid_stream(rng, n)
    _roundtrip(vals, block_size, differential)


@pytest.mark.parametrize("differential", [False, True])
@pytest.mark.parametrize("block_size", [8, 128])  # 32 covered by prop tests
@pytest.mark.parametrize("n", [7, 129, 1000])
def test_stream_kernel_shape_sweep(rng, differential, block_size, n):
    if differential:
        vals = np.sort(rng.integers(0, 2**31, size=n)).astype(np.uint64)
    else:
        vals = make_valid_stream(rng, n)
    _roundtrip_svb(vals, block_size, differential)


@pytest.mark.parametrize("block_tile", [1, 4, 8, 16])
def test_kernel_tile_sweep(rng, block_tile):
    vals = make_valid_stream(rng, 777)
    _roundtrip(vals, 64, False, block_tile=block_tile)


@pytest.mark.parametrize("block_tile", [1, 4, 8, 16])
def test_stream_kernel_tile_sweep(rng, block_tile):
    vals = make_valid_stream(rng, 777)
    _roundtrip_svb(vals, 64, False, block_tile=block_tile)


@pytest.mark.parametrize("max_bits", [7, 14, 21, 28, 32])
def test_kernel_byte_length_regimes(rng, max_bits):
    """All 1..5-byte encodings, incl. blocks of uniform length (paper's fast
    path) and the 2^32-1 edge."""
    vals = make_valid_stream(rng, 512, max_bits=max_bits)
    vals[0] = (1 << max_bits) - 1
    _roundtrip(vals, 128, False)


@pytest.mark.parametrize("max_bits", [8, 16, 24, 32])
def test_stream_kernel_byte_length_regimes(rng, max_bits):
    """All 1..4-byte Stream-VByte encodings, incl. uniform-length blocks."""
    vals = make_valid_stream(rng, 512, max_bits=max_bits)
    vals[0] = (1 << max_bits) - 1
    _roundtrip_svb(vals, 128, False)


@pytest.mark.parametrize("fmt", ["vbyte", "streamvbyte"])
def test_kernel_all_zeros(fmt):
    fn = _roundtrip if fmt == "vbyte" else _roundtrip_svb
    fn(np.zeros(300, np.uint64), 128, False)


@pytest.mark.parametrize("fmt", ["vbyte", "streamvbyte"])
def test_kernel_max_values(fmt):
    fn = _roundtrip if fmt == "vbyte" else _roundtrip_svb
    fn(np.full(257, 2**32 - 1, np.uint64), 128, False)


@pytest.mark.parametrize("fmt", ["vbyte", "streamvbyte"])
def test_kernel_stride_multiple_8(rng, fmt):
    # tight strides (stride_multiple=8) exercise non-128-aligned payloads
    vals = make_valid_stream(rng, 333)
    fn = _roundtrip if fmt == "vbyte" else _roundtrip_svb
    fn(vals, 64, False, stride_multiple=8)


def test_prop_kernel_equals_oracle():
    for case, vals in u32_cases(n_cases=6, max_len=300, min_len=1, seed=7):
        _roundtrip(vals, 32, False)


def test_prop_kernel_differential():
    for case, vals in sorted_u32_cases(n_cases=5, max_len=300, min_len=1, seed=8):
        _roundtrip(vals, 32, True)


def test_prop_stream_kernel_equals_oracle():
    for case, vals in u32_cases(n_cases=6, max_len=300, min_len=1, seed=9):
        _roundtrip_svb(vals, 32, False)


def test_prop_stream_kernel_differential():
    for case, vals in sorted_u32_cases(n_cases=5, max_len=300, min_len=1, seed=10):
        _roundtrip_svb(vals, 32, True)
