"""Fused decode→consume epilogue parity: the Pallas-fused kernel path and the
jnp-fused path must match the unfused decode→jnp reference bit-exactly, for
both formats, including count=0 blocks and ragged tails. The reference is
``plan="unfused"``: decode the uint32 grid, then the epilogue as a separate
dispatch — exactly the chain the fusion removes."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import CompressedIntArray
from repro.kernels.vbyte_decode import dispatch

FMTS = ["vbyte", "streamvbyte"]
B = 32  # block size (multiple of 4 for streamvbyte)
VOCAB = 512
D = 16


@pytest.fixture(scope="module")
def table():
    rng = np.random.default_rng(42)
    return jnp.asarray(rng.standard_normal((VOCAB, D)).astype(np.float32))


@pytest.fixture(scope="module")
def query():
    rng = np.random.default_rng(43)
    return jnp.asarray(rng.standard_normal((1, D)).astype(np.float32))


def _operands(rng, fmt, n, *, pad_zero_blocks=0):
    """Blocked operands for n sorted ids; optionally append count=0 blocks."""
    vals = np.sort(rng.integers(0, VOCAB, size=n)).astype(np.uint64)
    arr = CompressedIntArray.encode(vals, format=fmt, block_size=B,
                                    differential=True)
    ops = {k: np.asarray(v) for k, v in arr.device_operands().items()}
    if pad_zero_blocks:
        p = pad_zero_blocks
        for k in ops:
            ops[k] = np.pad(ops[k], ((0, p),) + ((0, 0),) * (ops[k].ndim - 1))
    return {k: jnp.asarray(v) for k, v in ops.items()}, vals


def _assert_all_plans_equal(ops, fmt, epilogue, eops):
    ref = dispatch.decode(ops, format=fmt, block_size=B, differential=True,
                          epilogue=epilogue, epilogue_operands=eops,
                          plan="unfused")
    ref = [np.asarray(x) for x in (ref if isinstance(ref, tuple) else (ref,))]
    for plan in ("kernel", "jnp"):
        out = dispatch.decode(ops, format=fmt, block_size=B, differential=True,
                              epilogue=epilogue, epilogue_operands=eops,
                              plan=plan)
        out = [np.asarray(x) for x in (out if isinstance(out, tuple) else (out,))]
        for r, o in zip(ref, out):
            np.testing.assert_array_equal(r, o, err_msg=f"{fmt}/{epilogue}/{plan}")
    return ref


@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("n,zero_blocks", [(4 * B, 0), (2 * B + 7, 0), (B, 2)])
def test_bag_sum_parity(rng, table, fmt, n, zero_blocks):
    ops, vals = _operands(rng, fmt, n, pad_zero_blocks=zero_blocks)
    (bag,) = _assert_all_plans_equal(ops, fmt, "bag_sum", {"table": table})
    # against a from-scratch numpy reference (per-block gather-sum)
    tab = np.asarray(table)
    nb = bag.shape[0]
    expect = np.zeros((nb, D), np.float32)
    for b in range(nb):
        blk = vals[b * B:(b + 1) * B].astype(np.int64)
        expect[b] = tab[blk].sum(axis=0, dtype=np.float32) if blk.size else 0
    np.testing.assert_allclose(bag, expect, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("n,zero_blocks", [(2 * B + 7, 0), (B, 2)])
def test_dot_score_parity(rng, table, query, fmt, n, zero_blocks):
    ops, vals = _operands(rng, fmt, n, pad_zero_blocks=zero_blocks)
    ids, scores = _assert_all_plans_equal(
        ops, fmt, "dot_score", {"table": table, "query": query})
    flat = ids.reshape(-1)
    np.testing.assert_array_equal(flat[: len(vals)], vals.astype(np.int32))
    assert not flat[len(vals):].any()  # padded slots are id 0
    expect = np.asarray(table)[flat] @ np.asarray(query)[0]
    np.testing.assert_allclose(scores.reshape(-1), expect, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("n,zero_blocks", [(2 * B + 7, 0), (B, 2)])
def test_adjacency_rebase_parity(rng, fmt, n, zero_blocks):
    ops, vals = _operands(rng, fmt, n, pad_zero_blocks=zero_blocks)
    nb = ops["counts"].shape[0]
    eb = jnp.asarray(rng.integers(0, VOCAB, (nb, B)).astype(np.int32))
    (out,) = _assert_all_plans_equal(ops, fmt, "adjacency_rebase",
                                     {"edge_base": eb})
    flat = out.reshape(-1)[: len(vals)]
    expect = (vals.astype(np.int64)
              - np.asarray(eb).reshape(-1)[: len(vals)]).astype(np.int32)
    np.testing.assert_array_equal(flat, expect)


@pytest.mark.parametrize("fmt", FMTS)
def test_ragged_encode_roundtrip_and_fused_bag(rng, fmt, table):
    """encode_ragged: one bag per block; fused bag == padded-bag reference."""
    from repro.nn.embedding_bag import bag_from_padded, embedding_bag_compressed

    lists = [np.sort(rng.choice(np.arange(1, VOCAB), size=k, replace=False))
             .astype(np.uint64)
             for k in rng.integers(0, B + 1, size=9)]
    lists[3] = np.zeros(0, np.uint64)  # explicit count=0 bag
    arr = CompressedIntArray.encode_ragged(lists, format=fmt, block_size=B,
                                           differential=True)
    assert arr.ragged and arr.n == sum(len(x) for x in lists)
    np.testing.assert_array_equal(arr.decode().astype(np.uint64),
                                  np.concatenate(lists))

    padded = np.zeros((len(lists), B), np.int32)
    for i, l in enumerate(lists):
        padded[i, : len(l)] = l
    for mode in ("sum", "mean"):
        ref = bag_from_padded(table, jnp.asarray(padded), mode=mode,
                              dtype=jnp.float32)
        out = embedding_bag_compressed(table, arr, mode=mode,
                                       dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
        # the raw operand-dict form still works with explicit metadata
        out2 = embedding_bag_compressed(
            table, arr.device_operands(), format=fmt, block_size=B,
            differential=True, mode=mode, dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_fused_adjacency_equals_legacy_and_raw(rng):
    """decode_compressed_edges: fused rebase == legacy global path == CSR."""
    from repro.data.graph import compress_adjacency
    from repro.data.sampler import CSRGraph
    from repro.data.synthetic import random_graph
    from repro.nn.gnn import decode_compressed_edges

    g = random_graph(rng, 80, 400, 4, 3)
    csr = CSRGraph.from_edges(g["edge_src"], g["edge_dst"], 80)
    comp = compress_adjacency(csr)
    args = (comp["gaps"], jnp.asarray(comp["row_offsets"]), csr.n_edges)
    outs = {}
    for label, kw in (
        ("fused_auto", dict(row_gap_bases=jnp.asarray(comp["row_gap_bases"]))),
        ("fused_kernel", dict(row_gap_bases=jnp.asarray(comp["row_gap_bases"]),
                              plan="kernel")),
        ("fused_unfused", dict(row_gap_bases=jnp.asarray(comp["row_gap_bases"]),
                               plan="unfused")),
        ("legacy_global", {}),
    ):
        src, dst = decode_compressed_edges(*args, **kw)
        outs[label] = (np.asarray(src), np.asarray(dst))
    own = np.repeat(np.arange(80), np.diff(csr.indptr))
    for label, (src, dst) in outs.items():
        np.testing.assert_array_equal(src, csr.indices, err_msg=label)
        np.testing.assert_array_equal(dst, own, err_msg=label)


@pytest.mark.parametrize("fmt", FMTS)
def test_retrieval_dot_score_matches_unfused(rng, fmt):
    """The fused dot_score serving path == decode-then-lookup scoring."""
    n_cand = 100
    cands = np.sort(rng.choice(np.arange(1, VOCAB), n_cand, replace=False)
                    ).astype(np.uint64)
    arr = CompressedIntArray.encode(cands, format=fmt, block_size=B,
                                    differential=True)
    ops = arr.device_operands()
    rng2 = np.random.default_rng(5)
    table = jnp.asarray(rng2.standard_normal((VOCAB, D)).astype(np.float32))
    q = jnp.asarray(rng2.standard_normal((1, D)).astype(np.float32))
    ids, scores = dispatch.decode(
        ops, format=fmt, block_size=B, differential=True, epilogue="dot_score",
        epilogue_operands={"table": table, "query": q}, plan="kernel")
    flat_ids = np.asarray(ids).reshape(-1)
    direct = np.asarray(jnp.take(table, jnp.asarray(flat_ids), axis=0)
                        @ q.reshape(-1))
    np.testing.assert_allclose(np.asarray(scores).reshape(-1), direct,
                               rtol=1e-5, atol=1e-5)
