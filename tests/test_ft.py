"""Direct unit tests for the fault-tolerance primitives (repro.ft).

``StragglerDetector`` and the elastic re-meshing helpers were orphaned
(zero direct coverage) until the robustness PR wired them into the serving
engines; these tests pin their contracts with simulated timelines — no
wall-clock dependence, every ``now`` is injected.
"""
import numpy as np
import pytest

from repro.ft import (MeshPlan, StragglerDetector, plan_mesh, reshard_plan,
                      shard_intervals)


# ---------------------------------------------------------------------------
# StragglerDetector
# ---------------------------------------------------------------------------
class TestStragglerDetector:
    def test_healthy_hosts_unflagged(self):
        det = StragglerDetector()
        for step in range(5):
            for h in ("a", "b", "c"):
                det.heartbeat(h, step, now=float(step))
        assert det.median_step_time() == 1.0
        assert det.stragglers(now=4.1) == {}

    def test_no_heartbeats_median_inf(self):
        det = StragglerDetector()
        assert det.median_step_time() == float("inf")
        assert det.stragglers(now=100.0) == {}

    def test_slow_host_flagged(self):
        det = StragglerDetector(slow_factor=2.0)
        for step in range(6):
            for h in ("a", "b", "c"):
                det.heartbeat(h, step, now=float(step))
            if step < 5:
                det.heartbeat("slow", step, now=float(step))
        det.heartbeat("slow", 5, now=9.0)  # final step took 5s vs median 1s
        report = det.stragglers(now=9.2)
        assert report.get("slow") == "slow"
        assert not any(h in report for h in ("a", "b", "c"))

    def test_dead_host_flagged_by_staleness(self):
        det = StragglerDetector(dead_factor=5.0)
        for step in range(4):
            for h in ("a", "b"):
                det.heartbeat(h, step, now=float(step))
        det.heartbeat("a", 4, now=4.0)  # b goes silent at t=3
        # at t=9, b is 6s stale > dead_factor (5) x median step (1s)
        assert det.stragglers(now=9.0).get("b") == "dead"
        assert det.stragglers(now=9.0).get("a") is None

    def test_window_trims_history(self):
        det = StragglerDetector(window=4)
        for step in range(20):
            det.heartbeat("a", step, now=float(step))
        assert len(det.hosts["a"].step_times) == 4

    def test_skipped_steps_average(self):
        det = StragglerDetector()
        det.heartbeat("a", 0, now=0.0)
        det.heartbeat("a", 4, now=8.0)  # 4 steps in 8s -> 2s/step
        assert det.hosts["a"].step_times == [2.0]


# ---------------------------------------------------------------------------
# elastic: plan_mesh / shard_intervals / reshard_plan
# ---------------------------------------------------------------------------
class TestPlanMesh:
    def test_single_pod(self):
        plan = plan_mesh(64, model_parallel=16, multi_pod_size=256)
        assert plan == MeshPlan((4, 16), ("data", "model"))
        assert plan.n_chips == 64

    def test_multi_pod(self):
        plan = plan_mesh(512, model_parallel=16, multi_pod_size=256)
        assert plan.axis_names == ("pod", "data", "model")
        assert plan.n_chips == 512

    def test_degraded_chip_count_shrinks_data_axis(self):
        # losing chips keeps TP degree fixed; the data axis absorbs it
        full = plan_mesh(64, model_parallel=16)
        degraded = plan_mesh(63, model_parallel=16)
        assert full.shape[-1] == degraded.shape[-1] == 16
        assert degraded.shape[0] < full.shape[0]

    def test_too_few_chips_raises(self):
        with pytest.raises(ValueError, match="TP"):
            plan_mesh(8, model_parallel=16)


class TestShardIntervals:
    @pytest.mark.parametrize("dim,parts", [(16, 8), (17, 8), (5, 8), (1, 1)])
    def test_partition_covers_dim(self, dim, parts):
        ivs = shard_intervals(dim, parts)
        assert len(ivs) == parts
        covered = [i for lo, hi in ivs for i in range(lo, hi)]
        assert covered == list(range(dim))  # complete, ordered, disjoint

    def test_equal_chunks(self):
        assert shard_intervals(16, 4) == [(0, 4), (4, 8), (8, 12), (12, 16)]


class TestReshardPlan:
    @pytest.mark.parametrize("dim,old,new", [(16, 8, 7), (16, 8, 4),
                                             (100, 8, 3), (7, 4, 2)])
    def test_coverage_complete_and_disjoint(self, dim, old, new):
        old_ivs = shard_intervals(dim, old)
        plan = reshard_plan(dim, old, new)
        assert len(plan) == new
        for (lo, hi), srcs in zip(shard_intervals(dim, new), plan):
            got = []
            for s, a, b in srcs:
                olo, ohi = old_ivs[s]
                assert 0 <= a < b <= ohi - olo  # offsets local to old shard
                got.extend(range(olo + a, olo + b))
            assert got == list(range(lo, hi))

    def test_data_round_trips_through_plan(self):
        # resharding a concrete array through the plan is the identity
        dim, old, new = 23, 6, 4
        data = np.arange(dim)
        old_shards = [data[lo:hi] for lo, hi in shard_intervals(dim, old)]
        rebuilt = np.concatenate([
            np.concatenate([old_shards[s][a:b] for s, a, b in srcs])
            if srcs else np.zeros(0, data.dtype)
            for srcs in reshard_plan(dim, old, new)])
        np.testing.assert_array_equal(rebuilt, data)
