"""Core codec: format vectors (paper Table 1), round-trips, property tests
(seeded case generators from conftest — no hypothesis dependency)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import CompressedIntArray
from repro.core.vbyte import encode as venc
from repro.core.vbyte import masked as vmask
from repro.core.vbyte import ref as vref

from conftest import (BOUNDARY_VALUES, make_valid_stream, sorted_u32_cases,
                      u32_cases)


# -- paper Table 1: exact byte-level vectors ---------------------------------
TABLE1 = {
    1: [0b00000001],
    2: [0b00000010],
    4: [0b00000100],
    128: [0b10000000, 0b00000001],
    256: [0b10000000, 0b00000010],
    512: [0b10000000, 0b00000100],
    16384: [0b10000000, 0b10000000, 0b00000001],
    32768: [0b10000000, 0b10000000, 0b00000010],
}


@pytest.mark.parametrize("value,expected", sorted(TABLE1.items()))
def test_paper_table1_format(value, expected):
    assert venc.encode_stream(np.array([value], np.uint64)).tolist() == expected


def test_lengths_match_stream():
    vals = np.array([0, 127, 128, 16383, 16384, 2**21 - 1, 2**21, 2**28 - 1,
                     2**28, 2**32 - 1], np.uint64)
    lens = venc.vbyte_lengths(vals)
    assert lens.tolist() == [1, 1, 2, 2, 3, 3, 4, 4, 5, 5]
    assert venc.encode_stream(vals).size == lens.sum()


def test_scalar_roundtrip(rng):
    vals = make_valid_stream(rng, 500)
    s = venc.encode_stream(vals)
    assert np.array_equal(vref.decode_stream_scalar(s, len(vals)), vals)


def test_masked_stream_matches_scalar(rng):
    vals = make_valid_stream(rng, 300)
    s = venc.encode_stream(vals)
    data = np.concatenate([s, np.zeros(32, np.uint8)])
    out, n = vmask.decode_stream(jnp.asarray(data), 512, nbytes=len(s))
    assert int(n) == 300
    assert np.array_equal(np.asarray(out[:300], np.uint64), vals)


def test_lax_scalar_matches(rng):
    vals = make_valid_stream(rng, 200)
    s = venc.encode_stream(vals)
    out, n = vref.decode_stream_scalar_jax(jnp.asarray(s), 256)
    assert int(n) == 200
    assert np.array_equal(np.asarray(out[:200], np.uint64), vals)


@pytest.mark.parametrize("differential", [False, True])
@pytest.mark.parametrize("n,block_size", [(1, 128), (127, 128), (128, 128),
                                          (129, 128), (1000, 64), (4096, 128)])
def test_blocked_roundtrip(rng, differential, n, block_size):
    if differential:
        vals = np.sort(rng.integers(0, 2**31, size=n)).astype(np.uint64)
    else:
        vals = make_valid_stream(rng, n)
    arr = CompressedIntArray.encode(vals, block_size=block_size,
                                    differential=differential)
    assert np.array_equal(arr.decode().astype(np.uint64), vals)
    assert np.array_equal(arr.decode_scalar_oracle().astype(np.uint64), vals)


def test_differential_requires_sorted():
    with pytest.raises(ValueError):
        venc.delta_encode(np.array([5, 3], np.uint64))


def test_differential_compresses_sorted_ids(rng):
    ids = np.sort(rng.choice(50_000_000, size=1 << 14, replace=False)).astype(np.uint64)
    plain = CompressedIntArray.encode(ids, differential=False)
    delta = CompressedIntArray.encode(ids, differential=True)
    assert delta.bits_per_int < plain.bits_per_int
    assert delta.compression_ratio > 1.5  # gaps ~3000 → ≤2 bytes/int


def test_count_integers(rng):
    vals = make_valid_stream(rng, 77)
    s = venc.encode_stream(vals)
    data = np.concatenate([s, np.zeros(16, np.uint8)])
    assert int(vmask.count_integers(jnp.asarray(data), len(s))) == 77


# -- seeded property tests (conftest harness) --------------------------------
def test_prop_stream_roundtrip():
    for case, vals in u32_cases(n_cases=60, max_len=300):
        s = venc.encode_stream(vals)
        got = vref.decode_stream_scalar(s, len(vals))
        assert np.array_equal(got, vals), case


def test_prop_blocked_masked_equals_scalar():
    for case, vals in u32_cases(n_cases=40, max_len=300):
        arr = CompressedIntArray.encode(vals, block_size=32)
        assert np.array_equal(arr.decode(), arr.decode_scalar_oracle()), case


def test_prop_differential_roundtrip():
    for case, vals in sorted_u32_cases(n_cases=40, max_len=200):
        arr = CompressedIntArray.encode(vals, block_size=32, differential=True)
        assert np.array_equal(arr.decode().astype(np.uint64), vals), case


def test_prop_length_formula(rng):
    # every byte-length threshold (±1 via BOUNDARY_VALUES) plus random draws
    samples = np.concatenate([
        BOUNDARY_VALUES,
        rng.integers(0, 2**32, size=100, dtype=np.uint64),
    ])
    for v in samples:
        n = venc.vbyte_lengths(np.array([v], np.uint64))[0]
        assert n == max(1, -(-int(v).bit_length() // 7)), v
