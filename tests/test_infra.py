"""Training infra: optimizer, checkpointing, fault tolerance, grad compression,
embedding bag, data pipeline, neighbor sampler."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import CompressedTokenPipeline
from repro.data.sampler import CSRGraph, NeighborSampler
from repro.data.synthetic import random_graph, token_stream
from repro.ft import StragglerDetector, plan_mesh, reshard_plan
from repro.nn.embedding_bag import bag_from_padded, embedding_bag
from repro.train.grad_compress import (compress_grads_with_ef, compressed_psum,
                                       dequantize, init_ef_state, quantize)
from repro.train.optimizer import (OptimizerConfig, adamw_update, init_opt_state,
                                   lr_schedule)


# -- optimizer ----------------------------------------------------------------
def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = init_opt_state(params)
    cfg = OptimizerConfig(peak_lr=0.3, warmup_steps=1, total_steps=200,
                          weight_decay=0.0, grad_clip=10.0)
    for _ in range(150):
        g = {"w": 2 * params["w"]}
        params, opt, m = adamw_update(params, g, opt, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.2
    assert int(opt["step"]) == 150


def test_grad_clip_applied():
    params = {"w": jnp.zeros(3)}
    opt = init_opt_state(params)
    cfg = OptimizerConfig(grad_clip=1.0, peak_lr=1.0, warmup_steps=0)
    _, _, m = adamw_update(params, {"w": jnp.full(3, 100.0)}, opt, cfg)
    assert float(m["grad_norm"]) > 100.0  # reported pre-clip


def test_lr_schedule_shape():
    cfg = OptimizerConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    assert float(lr_schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(lr_schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(lr_schedule(cfg, jnp.int32(100))) == pytest.approx(0.1)


# -- checkpoint ---------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    state = {
        "params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                   "emb": jnp.ones((4, 2), jnp.bfloat16)},
        "steps": jnp.arange(1000, dtype=jnp.int32),  # vbyte-compressed leaf
        "neg": jnp.array([-5, 3, -1], jnp.int32),  # zigzag path
    }
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(3, state)
    mgr.save(7, state)
    restored, step = mgr.restore_latest(state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_prune_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"x": jnp.ones(10)}
    for s in (1, 2, 3, 4):
        mgr.save(s, state, async_=True)
    mgr.wait()
    assert mgr.steps() == [3, 4]


def test_checkpoint_atomicity_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": jnp.ones(3)})
    assert all(not d.startswith(".tmp") for d in os.listdir(tmp_path))


# -- fault tolerance ----------------------------------------------------------
def test_straggler_detection():
    det = StragglerDetector(slow_factor=2.0, dead_factor=5.0)
    clocks = {"host0": 0.0, "host1": 0.0, "host2": 0.0}
    for step in range(10):
        for h in clocks:
            dt = 3.0 if h == "host2" and step >= 5 else 1.0  # host2 slows down
            clocks[h] += dt
            det.heartbeat(h, step, now=clocks[h])
    assert det.stragglers(now=max(clocks.values())).get("host2") == "slow"
    # host1 goes silent
    t = max(clocks.values())
    for step in range(10, 14):
        t += 1.0
        det.heartbeat("host0", step, now=t)
        det.heartbeat("host2", step, now=t)
    assert det.stragglers(now=t + 10).get("host1") == "dead"


def test_plan_mesh_degraded():
    full = plan_mesh(512)
    assert full.shape == (2, 16, 16) and full.axis_names[0] == "pod"
    degraded = plan_mesh(512 - 16)  # lost a host of 16 chips
    assert degraded.n_chips <= 496 and degraded.shape[-1] == 16
    assert plan_mesh(256).shape == (16, 16)
    with pytest.raises(ValueError):
        plan_mesh(8)


def test_reshard_plan_covers_exactly():
    for dim, old, new in [(64, 16, 8), (64, 8, 16), (96, 16, 12), (128, 4, 4)]:
        plan = reshard_plan(dim, old, new)
        covered = []
        news = [(i * -(-dim // new), min((i + 1) * -(-dim // new), dim))
                for i in range(new)]
        for (lo, hi), srcs in zip(news, plan):
            olds = [(s * -(-dim // old), min((s + 1) * -(-dim // old), dim))
                    for s in range(old)]
            got = sorted((olds[s][0] + a, olds[s][0] + b) for s, a, b in srcs)
            total = sum(b - a for a, b in got)
            assert total == hi - lo, (dim, old, new)
            covered.extend(got)
        assert sum(b - a for a, b in covered) == dim


# -- grad compression ----------------------------------------------------------
def test_quantize_error_bound(rng):
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, s = quantize(x)
    err = np.abs(np.asarray(dequantize(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-7


def test_error_feedback_preserves_signal():
    g = {"w": jnp.full((100,), 1e-4)}  # tiny grads: quantizer would zero them
    ef = init_ef_state(g)
    total = np.zeros(100, np.float32)
    for _ in range(50):
        deq, ef = compress_grads_with_ef(g, ef)
        total += np.asarray(deq["w"])
    # with EF the accumulated update approaches the true sum
    np.testing.assert_allclose(total.mean(), 50 * 1e-4, rtol=0.05)


def test_compressed_psum_single_device():
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
    x = jnp.asarray(np.random.default_rng(0).standard_normal(64), jnp.float32)
    f = shard_map(lambda v: compressed_psum(v, "d"), mesh=mesh,
                  in_specs=P(), out_specs=P())
    np.testing.assert_allclose(np.asarray(f(x)), np.asarray(x), atol=2e-2)


# -- embedding bag -------------------------------------------------------------
def test_embedding_bag_matches_numpy(rng):
    table = rng.standard_normal((50, 8), dtype=np.float32)
    ids = rng.integers(0, 50, 40).astype(np.int32)
    segs = np.sort(rng.integers(0, 6, 40)).astype(np.int32)
    out = embedding_bag(jnp.asarray(table), jnp.asarray(ids), jnp.asarray(segs),
                        6, mode="sum", dtype=jnp.float32)
    ref = np.zeros((6, 8), np.float32)
    np.add.at(ref, segs, table[ids])
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-5)


def test_bag_from_padded_ignores_pad(rng):
    table = rng.standard_normal((20, 4), dtype=np.float32)
    ids = np.array([[1, 2, 0, 0], [3, 0, 0, 0]], np.int32)
    out = bag_from_padded(jnp.asarray(table), jnp.asarray(ids), mode="sum",
                          dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out)[0], table[1] + table[2], atol=1e-6)
    np.testing.assert_allclose(np.asarray(out)[1], table[3], atol=1e-6)


# -- data pipeline -------------------------------------------------------------
def test_token_pipeline_roundtrip(rng):
    toks = token_stream(rng, 4096, 1000)
    pipe = CompressedTokenPipeline(toks, batch=4, seq_len=63, plan="kernel")
    b0 = pipe.get_batch(0)
    assert b0["tokens"].shape == (4, 64)
    np.testing.assert_array_equal(np.asarray(b0["tokens"]).reshape(-1),
                                  toks[:256].astype(np.int32))
    assert pipe.compression_ratio() > 1.5  # zipf tokens are small ints


# -- neighbor sampler ----------------------------------------------------------
def test_neighbor_sampler(rng):
    g = random_graph(rng, 500, 5000, 4, 3)
    csr = CSRGraph.from_edges(g["edge_src"], g["edge_dst"], 500)
    samp = NeighborSampler(csr, fanouts=(5, 3))
    seeds = rng.choice(500, 32, replace=False)
    out = samp.sample(seeds, rng)
    e_cap = samp.edge_capacity(32)
    assert out["edge_src"].shape == (e_cap,)
    assert out["edge_valid"].sum() <= e_cap
    n_valid = int(out["edge_valid"].sum())
    # every sampled edge must exist in the CSR (dst row contains src)
    node_ids = out["node_ids"]
    for i in rng.choice(n_valid, size=min(50, n_valid), replace=False):
        s, d = node_ids[out["edge_src"][i]], node_ids[out["edge_dst"][i]]
        row = csr.indices[csr.indptr[d]:csr.indptr[d + 1]]
        assert s in row
    assert set(out["seed_ids"].tolist()) <= set(range(len(node_ids)))
