"""Golden-vector tests: hand-written byte streams with expected decodes.

Covers, for BOTH on-device formats:
  * every byte-length boundary (VByte: 2^7/2^14/2^21/2^28/2^32-1,
    Stream VByte: 2^8/2^16/2^24/2^32-1) with the exact expected bytes,
  * empty blocks and count=0 rows (garbage payload must not leak),
  * padding bytes that look like terminators (0x00 decodes as a 0 if the
    count mask ever breaks),
  * differential wrap-around mod 2^32.

These are the vectors a from-scratch reimplementation must reproduce; every
decoder (scalar oracle, vectorized jnp, Pallas kernel in interpret mode) is
checked against them.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import CompressedIntArray
from repro.core.vbyte import binpack as bpk
from repro.core.vbyte import binpack_masked as bpkm
from repro.core.vbyte import encode as venc
from repro.core.vbyte import masked as vmask
from repro.core.vbyte import ref as vref
from repro.core.vbyte import stream_masked as svbm
from repro.core.vbyte import stream_vbyte as svb
from repro.kernels.vbyte_decode import (binpack_decode_blocked,
                                        stream_vbyte_decode_blocked,
                                        vbyte_decode_blocked)

# -- exact encodings at the byte-length boundaries ---------------------------
VBYTE_GOLDEN = [
    (0, [0x00]),
    (2**7 - 1, [0x7F]),
    (2**7, [0x80, 0x01]),
    (2**14 - 1, [0xFF, 0x7F]),
    (2**14, [0x80, 0x80, 0x01]),
    (2**21 - 1, [0xFF, 0xFF, 0x7F]),
    (2**21, [0x80, 0x80, 0x80, 0x01]),
    (2**28 - 1, [0xFF, 0xFF, 0xFF, 0x7F]),
    (2**28, [0x80, 0x80, 0x80, 0x80, 0x01]),
    (2**32 - 1, [0xFF, 0xFF, 0xFF, 0xFF, 0x0F]),
]

SVB_GOLDEN = [  # (value, code, data bytes little-endian)
    (0, 0, [0x00]),
    (2**8 - 1, 0, [0xFF]),
    (2**8, 1, [0x00, 0x01]),
    (2**16 - 1, 1, [0xFF, 0xFF]),
    (2**16, 2, [0x00, 0x00, 0x01]),
    (2**24 - 1, 2, [0xFF, 0xFF, 0xFF]),
    (2**24, 3, [0x00, 0x00, 0x00, 0x01]),
    (2**32 - 1, 3, [0xFF, 0xFF, 0xFF, 0xFF]),
]


@pytest.mark.parametrize("value,expected", VBYTE_GOLDEN)
def test_vbyte_boundary_bytes(value, expected):
    assert venc.encode_stream(np.array([value], np.uint64)).tolist() == expected
    assert vref.decode_stream_scalar(np.array(expected, np.uint8), 1)[0] == value


@pytest.mark.parametrize("value,code,expected", SVB_GOLDEN)
def test_svb_boundary_bytes(value, code, expected):
    control, data = svb.encode_stream(np.array([value], np.uint64))
    assert control.tolist() == [code]  # codes 1..3 pack into bits 0-1
    assert data.tolist() == expected
    assert svb.decode_stream_scalar(control, data, 1)[0] == value


def test_svb_control_packing_order():
    """Four codes per control byte, LSB-first: lengths (1,2,3,4) -> 0xE4."""
    vals = np.array([1, 300, 70000, 2**32 - 1], np.uint64)
    control, data = svb.encode_stream(vals)
    assert control.tolist() == [0xE4]  # 0 | 1<<2 | 2<<4 | 3<<6
    assert data.tolist() == [0x01, 0x2C, 0x01, 0x70, 0x11, 0x01,
                             0xFF, 0xFF, 0xFF, 0xFF]
    assert np.array_equal(svb.decode_stream_scalar(control, data, 4), vals)


def test_svb_stream_decode_matches_scalar(rng):
    """stream_masked.decode_stream on tight (control, data) streams — the
    single-stream analogue of masked.decode_stream."""
    bits = rng.integers(0, 33, size=37).astype(np.uint64)
    vals = np.minimum(
        rng.integers(0, 1 << 62, size=37, dtype=np.uint64) >> (np.uint64(62) - bits),
        np.uint64(2**32 - 1))
    control, data = svb.encode_stream(vals)
    ctrl_p = np.concatenate([control, np.zeros(16, np.uint8)])
    data_p = np.concatenate([data, np.zeros(16, np.uint8)])
    out = svbm.decode_stream(jnp.asarray(ctrl_p), jnp.asarray(data_p), 64, n=37)
    assert np.array_equal(np.asarray(out[:37], np.uint64), vals)
    assert np.all(np.asarray(out[37:]) == 0)


# -- hand-written blocked layouts, decoded by every implementation ----------
def _vbyte_all_decoders(payload, counts, bases, block_size, differential):
    oracle = vref.decode_blocked_scalar(payload, counts, bases, block_size,
                                        differential=differential)
    ops = dict(payload=jnp.asarray(payload), counts=jnp.asarray(counts),
               bases=jnp.asarray(bases))
    msk = vmask.decode_blocked(**ops, block_size=block_size,
                               differential=differential)
    ker = vbyte_decode_blocked(**ops, block_size=block_size,
                               differential=differential)
    np.testing.assert_array_equal(np.asarray(msk, np.uint64), oracle)
    np.testing.assert_array_equal(np.asarray(ker, np.uint64), oracle)
    return oracle


def _svb_all_decoders(control, data, counts, bases, block_size, differential):
    oracle = svb.decode_blocked_scalar(control, data, counts, bases, block_size,
                                       differential=differential)
    ops = dict(control=jnp.asarray(control), data=jnp.asarray(data),
               counts=jnp.asarray(counts), bases=jnp.asarray(bases))
    msk = svbm.decode_blocked(**ops, block_size=block_size,
                              differential=differential)
    ker = stream_vbyte_decode_blocked(**ops, block_size=block_size,
                                      differential=differential)
    np.testing.assert_array_equal(np.asarray(msk, np.uint64), oracle)
    np.testing.assert_array_equal(np.asarray(ker, np.uint64), oracle)
    return oracle


def test_vbyte_blocked_golden_with_terminator_lookalike_padding():
    """Row 0: [133, 3] then zero padding — every pad byte is a valid
    0-terminator, so only the count mask keeps them out of the output.
    Row 1: count=0 with garbage bytes — must decode to all zeros."""
    payload = np.zeros((2, 16), np.uint8)
    payload[0, :3] = [0x85, 0x01, 0x03]  # 133 = (0x85&0x7F) | 0x01<<7, then 3
    payload[1, :4] = [0x99, 0xAA, 0x7F, 0x05]  # garbage: count=0 row
    counts = np.array([2, 0], np.int32)
    bases = np.zeros(2, np.uint32)
    out = _vbyte_all_decoders(payload, counts, bases, 8, False)
    expected = np.zeros((2, 8), np.uint64)
    expected[0, :2] = [133, 3]
    np.testing.assert_array_equal(out, expected)


def test_svb_blocked_golden_with_zero_code_padding():
    """Padding control codes are 0 (= 1-byte integers): only the count mask
    keeps them from decoding the data-stream padding as zeros/garbage."""
    control = np.zeros((2, 2), np.uint8)
    control[0, 0] = 0xE4  # lengths (1,2,3,4) for the 4 valid ints
    data = np.zeros((2, 16), np.uint8)
    data[0, :10] = [0x01, 0x2C, 0x01, 0x70, 0x11, 0x01, 0xFF, 0xFF, 0xFF, 0xFF]
    data[1, :3] = [0xDE, 0xAD, 0xBE]  # garbage: count=0 row
    counts = np.array([4, 0], np.int32)
    bases = np.zeros(2, np.uint32)
    out = _svb_all_decoders(control, data, counts, bases, 8, False)
    expected = np.zeros((2, 8), np.uint64)
    expected[0, :4] = [1, 300, 70000, 2**32 - 1]
    np.testing.assert_array_equal(out, expected)


def _binpack_all_decoders(widths, data, counts, bases, block_size,
                          differential):
    oracle = bpk.decode_blocked_scalar(widths, data, counts, bases,
                                       block_size,
                                       differential=differential)
    ops = dict(widths=jnp.asarray(widths, jnp.uint8).reshape(-1, 1),
               data=jnp.asarray(data), counts=jnp.asarray(counts),
               bases=jnp.asarray(bases))
    msk = bpkm.decode_blocked(**ops, block_size=block_size,
                              differential=differential)
    ker = binpack_decode_blocked(**ops, block_size=block_size,
                                 differential=differential)
    np.testing.assert_array_equal(np.asarray(msk, np.uint64), oracle)
    np.testing.assert_array_equal(np.asarray(ker, np.uint64), oracle)
    return oracle


BINPACK_GOLDEN = [
    # (width, values, packed bytes LSB-first within and across values)
    (0, [0, 0, 0], []),
    (1, [1, 0, 1, 1, 0, 1, 1, 1], [0xED]),
    (7, [1, 127, 64], [0x81, 0x3F, 0x10]),
    (32, [0xDEADBEEF], [0xEF, 0xBE, 0xAD, 0xDE]),
]


@pytest.mark.parametrize("width,values,expected", BINPACK_GOLDEN)
def test_binpack_boundary_bytes(width, values, expected):
    vals = np.array(values, np.uint64).reshape(1, -1)
    assert int(bpk.block_widths(vals, np.array([len(values)]))[0]) == width
    packed = bpk.pack_rows(vals, width)
    assert packed[0].tolist() == expected
    out = bpk.decode_block_scalar(
        np.pad(packed[0], (0, 8)), width, len(values))
    np.testing.assert_array_equal(out, np.array(values, np.uint64))


def test_binpack_blocked_golden_ragged_tail_and_empty_block():
    """Row 0: width 7, ragged count=3 — packed bits end mid-byte, pad bits
    zero. Row 1: width 5 but count=0 with garbage data — the lane mask
    alone must keep every decoder at zero. Row 2: width 0, count=4 —
    decodes to zeros without touching data at all."""
    data = np.zeros((3, 16), np.uint8)
    data[0, :3] = [0x81, 0x3F, 0x10]  # [1, 127, 64] at w=7
    data[1, :4] = [0xDE, 0xAD, 0xBE, 0xEF]  # garbage: count=0 row
    widths = np.array([[7], [5], [0]], np.uint8)
    counts = np.array([3, 0, 4], np.int32)
    bases = np.zeros(3, np.uint32)
    out = _binpack_all_decoders(widths, data, counts, bases, 8, False)
    expected = np.zeros((3, 8), np.uint64)
    expected[0, :3] = [1, 127, 64]
    np.testing.assert_array_equal(out, expected)


def test_binpack_width32_blocked_golden():
    """Full-width lanes: 2^32-1 and a mixed word survive the 24/16-bit
    split recombination exactly."""
    data = np.zeros((1, 128), np.uint8)
    data[0, :8] = [0xFF, 0xFF, 0xFF, 0xFF, 0xEF, 0xBE, 0xAD, 0xDE]
    widths = np.array([[32]], np.uint8)
    counts = np.array([2], np.int32)
    bases = np.zeros(1, np.uint32)
    out = _binpack_all_decoders(widths, data, counts, bases, 8, False)
    np.testing.assert_array_equal(out[0, :2], [2**32 - 1, 0xDEADBEEF])


def test_binpack_differential_wraparound_golden():
    """base=2^32-2, w=3 gaps [1, 5]: absolutes wrap mod 2^32."""
    data = np.zeros((1, 16), np.uint8)
    data[0, 0] = 0x29  # bits: 1,0,0 then 1,0,1 LSB-first = 0b101001
    widths = np.array([[3]], np.uint8)
    counts = np.array([2], np.int32)
    bases = np.array([2**32 - 2], np.uint32)
    out = _binpack_all_decoders(widths, data, counts, bases, 8, True)
    np.testing.assert_array_equal(out[0, :2], [2**32 - 1, 4])


@pytest.mark.parametrize("fmt", ["vbyte", "streamvbyte", "binpack"])
def test_empty_block_layout(fmt):
    """n=0 encodes to a single block with count 0 and decodes to nothing."""
    arr = CompressedIntArray.encode(np.zeros(0, np.uint64), format=fmt)
    assert arr.n == 0 and arr.n_blocks == 1
    assert arr.decode().size == 0
    assert arr.decode(plan="kernel").size == 0
    assert arr.decode_scalar_oracle().size == 0


def test_vbyte_differential_wraparound_golden():
    """base=2^32-2, gaps [1, 5]: absolute values wrap mod 2^32 -> [2^32-1, 4]."""
    payload = np.zeros((1, 16), np.uint8)
    payload[0, :2] = [0x01, 0x05]
    counts = np.array([2], np.int32)
    bases = np.array([2**32 - 2], np.uint32)
    out = _vbyte_all_decoders(payload, counts, bases, 8, True)
    np.testing.assert_array_equal(out[0, :2], [2**32 - 1, 4])


def test_svb_differential_wraparound_golden():
    control = np.zeros((1, 2), np.uint8)  # codes 0,0: two 1-byte gaps
    data = np.zeros((1, 16), np.uint8)
    data[0, :2] = [0x01, 0x05]
    counts = np.array([2], np.int32)
    bases = np.array([2**32 - 2], np.uint32)
    out = _svb_all_decoders(control, data, counts, bases, 8, True)
    np.testing.assert_array_equal(out[0, :2], [2**32 - 1, 4])


def test_vbyte_five_byte_wraparound_golden():
    """A 5-byte encoding whose 35 payload bits exceed 32: decoders must agree
    with the scalar oracle's mod-2^32 semantics (paper's 32-bit lanes)."""
    payload = np.zeros((1, 16), np.uint8)
    payload[0, :5] = [0xFF, 0xFF, 0xFF, 0xFF, 0x7F]  # 2^35-1 ≡ 2^32-1 (mod 2^32)
    counts = np.array([1], np.int32)
    bases = np.zeros(1, np.uint32)
    out = _vbyte_all_decoders(payload, counts, bases, 8, False)
    assert out[0, 0] == 2**32 - 1
