"""Compression-rate table: bits/int by posting-list length group (paper §V:
'this value ranges from 8 to slightly less than 16'), plus blocked-layout
metadata overhead and the framework integrations (tokens, adjacency,
candidate lists). Both on-device formats are reported side by side: classic
VByte (7 payload bits/byte) and Stream VByte (whole payload bytes + 2-bit
control codes) — the latter trades a small bits/int penalty for scan-free
decoding (docs/formats.md)."""
from __future__ import annotations

import numpy as np

from repro.core.compressed_array import CompressedIntArray
from repro.data.graph import compress_adjacency
from repro.data.sampler import CSRGraph
from repro.data.synthetic import CLUEWEB_DOCS, random_graph, token_stream


def run(groups=(10, 12, 14, 16, 18, 20, 22), lists_per_group: int = 4):
    rng = np.random.default_rng(11)
    rows = []
    for k in groups:
        bits, ratios, overheads = [], [], []
        svb_bits, svb_ratios = [], []
        for _ in range(lists_per_group):
            length = int(rng.integers(1 << k, 1 << (k + 1)))
            length = min(length, 1 << 21)
            ids = np.sort(rng.choice(CLUEWEB_DOCS, size=length,
                                     replace=False)).astype(np.uint64)
            arr = CompressedIntArray.encode(ids, differential=True)
            bits.append(arr.bits_per_int)
            ratios.append(arr.compression_ratio)
            overheads.append(arr.enc.device_bytes / max(arr.enc.payload_bytes, 1) - 1)
            svb = CompressedIntArray.encode(ids, format="streamvbyte",
                                            differential=True)
            svb_bits.append(svb.bits_per_int)
            svb_ratios.append(svb.compression_ratio)
        rows.append({"group_K": k, "bits_per_int": round(float(np.mean(bits)), 2),
                     "svb_bits_per_int": round(float(np.mean(svb_bits)), 2),
                     "ratio_vs_u32": round(float(np.mean(ratios)), 2),
                     "svb_ratio_vs_u32": round(float(np.mean(svb_ratios)), 2),
                     "block_overhead": round(float(np.mean(overheads)), 3)})
    return rows


def run_posting_index(groups=(10, 12, 14, 16), lists_per_group: int = 4):
    """Index-level compression per length group K, next to decode speed.

    Builds a real inverted index per group (``repro.index.build_index``:
    d-gaps + skip tables, both formats) from the same ClueWeb09-style
    posting lists and reports corpus-weighted bits/int against the paper's
    §V figure ('this value ranges from 8 to slightly less than 16').
    """
    from repro.data.synthetic import posting_list_group
    from repro.index import build_index

    rng = np.random.default_rng(17)
    rows = []
    for k in groups:
        lists = posting_list_group(rng, k, lists_per_group,
                                   universe=CLUEWEB_DOCS)
        row = {"group_K": k, "paper_range_bits": [8, 16]}
        for fmt, key in (("vbyte", "bits_per_int"),
                         ("streamvbyte", "svb_bits_per_int")):
            idx = build_index(lists, format=fmt, n_docs=CLUEWEB_DOCS)
            row[key] = round(idx.bits_per_int, 2)
        rows.append(row)
    return rows


def run_integrations():
    rng = np.random.default_rng(5)
    out = {}
    toks = token_stream(rng, 1 << 18, 50304)
    out["lm_tokens_zipf"] = round(
        CompressedIntArray.encode(toks).compression_ratio, 2)
    g = random_graph(rng, 20000, 300000, 8, 4)
    csr = CSRGraph.from_edges(g["edge_src"], g["edge_dst"], 20000)
    out["gnn_adjacency_bits_per_edge"] = round(
        compress_adjacency(csr)["_bits_per_edge"], 2)
    cands = np.sort(rng.choice(1 << 23, size=1 << 20, replace=False)).astype(np.uint64)
    out["retrieval_candidates_ratio"] = round(
        CompressedIntArray.encode(cands, differential=True).compression_ratio, 2)
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
    print(run_integrations())
