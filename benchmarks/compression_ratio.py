"""Compression-rate table: bits/int by posting-list length group (paper §V:
'this value ranges from 8 to slightly less than 16'), plus blocked-layout
metadata overhead and the framework integrations (tokens, adjacency,
candidate lists). Every registered on-device format is reported side by
side — classic VByte (7 payload bits/byte), Stream VByte (whole payload
bytes + 2-bit control codes) and binary packing (per-block bit width) —
plus the DP-partitioned mixed-codec index (``format="auto"``), so the
compression-vs-throughput trade (docs/formats.md, docs/index.md) is one
table per group."""
from __future__ import annotations

import numpy as np

from repro.core.compressed_array import CompressedIntArray
from repro.data.graph import compress_adjacency
from repro.data.sampler import CSRGraph
from repro.data.synthetic import CLUEWEB_DOCS, random_graph, token_stream

FORMATS = ("vbyte", "streamvbyte", "binpack")


def run(groups=(10, 12, 14, 16, 18, 20, 22), lists_per_group: int = 4):
    rng = np.random.default_rng(11)
    rows = []
    for k in groups:
        stats = {f: {"bits": [], "ratio": []} for f in FORMATS}
        overheads = []
        for _ in range(lists_per_group):
            length = int(rng.integers(1 << k, 1 << (k + 1)))
            length = min(length, 1 << 21)
            ids = np.sort(rng.choice(CLUEWEB_DOCS, size=length,
                                     replace=False)).astype(np.uint64)
            for f in FORMATS:
                arr = CompressedIntArray.encode(ids, format=f,
                                                differential=True)
                stats[f]["bits"].append(arr.bits_per_int)
                stats[f]["ratio"].append(arr.compression_ratio)
                if f == "vbyte":
                    overheads.append(
                        arr.enc.device_bytes / max(arr.enc.payload_bytes, 1) - 1)
        rows.append({
            "group_K": k,
            "formats": {f: {
                "bits_per_int": round(float(np.mean(stats[f]["bits"])), 2),
                "ratio_vs_u32": round(float(np.mean(stats[f]["ratio"])), 2),
            } for f in FORMATS},
            "block_overhead": round(float(np.mean(overheads)), 3),
        })
    return rows


def run_posting_index(groups=(10, 12, 14, 16, 18), lists_per_group: int = 4):
    """Index-level compression per length group K, next to decode speed.

    Builds a real inverted index per group (``repro.index.build_index``:
    d-gaps + skip tables) from the same ClueWeb09-style posting lists for
    every uniform format AND the DP-partitioned mixed-codec ``auto`` path,
    and reports corpus-weighted bits/int against the paper's §V figure
    ('this value ranges from 8 to slightly less than 16'). The tracked
    scoreboard claim: ``auto`` ≤ every uniform single-codec at every K.
    """
    from repro.data.synthetic import posting_list_group
    from repro.index import build_index

    rng = np.random.default_rng(17)
    rows = []
    for k in groups:
        lists = posting_list_group(rng, k, lists_per_group,
                                   universe=CLUEWEB_DOCS)
        row = {"group_K": k, "paper_range_bits": [8, 16], "formats": {}}
        for fmt in FORMATS + ("auto",):
            idx = build_index(lists, format=fmt, n_docs=CLUEWEB_DOCS)
            row["formats"][fmt] = round(idx.bits_per_int, 2)
        rows.append(row)
    return rows


def run_integrations():
    rng = np.random.default_rng(5)
    out = {}
    toks = token_stream(rng, 1 << 18, 50304)
    out["lm_tokens_zipf"] = round(
        CompressedIntArray.encode(toks).compression_ratio, 2)
    g = random_graph(rng, 20000, 300000, 8, 4)
    csr = CSRGraph.from_edges(g["edge_src"], g["edge_dst"], 20000)
    out["gnn_adjacency_bits_per_edge"] = round(
        compress_adjacency(csr)["_bits_per_edge"], 2)
    cands = np.sort(rng.choice(1 << 23, size=1 << 20, replace=False)).astype(np.uint64)
    out["retrieval_candidates_ratio"] = round(
        CompressedIntArray.encode(cands, differential=True).compression_ratio, 2)
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
    print(run_integrations())
