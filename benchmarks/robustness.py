"""Robustness benchmark: validation overhead + degraded-serving rates.

Two sections (``python -m benchmarks.run --only robustness``):

* **decode** — validated vs unvalidated decode throughput per format. The
  ``checksum`` epilogue computes the per-block position-weighted sum in the
  same decode pass (no second HBM round-trip), so its device-side cost is
  one fused multiply-add per slot; ``decode_checked`` adds the host-side
  compare against the stored column. Quick mode asserts the in-pass
  checksum overhead stays under 15% — the number docs/robustness.md quotes.
* **serving** — a flaky workload through the hardened ``SearchEngine``:
  startup validation quarantines deliberately corrupted terms, a fault hook
  injects transient decode failures, and the reported serve stats give the
  retry / quarantine / degraded-response rates.
"""
from __future__ import annotations

import time

import numpy as np

CHECKSUM_OVERHEAD_LIMIT = 0.15  # quick-mode gate (docs/robustness.md)


def _bench(fn, *, reps: int, warmup: int = 2):
    """Best-of-reps wall time — the standard microbenchmark noise floor."""
    import jax

    for _ in range(warmup):
        out = jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best, out


def run_decode(*, n_ints: int, reps: int = 5) -> list[dict]:
    """Unvalidated vs checksum-validated decode throughput (Mis)."""
    from repro.core import CompressedIntArray
    from repro.kernels.vbyte_decode import dispatch
    from repro.robustness import decode_checked

    rng = np.random.default_rng(0)
    bits = rng.integers(1, 31, size=n_ints)
    vals = (rng.integers(0, 2**63, n_ints, dtype=np.uint64)
            % (1 << bits.astype(np.uint64))).astype(np.uint64)
    rows = []
    for fmt in ("vbyte", "streamvbyte"):
        arr = CompressedIntArray.encode(vals, format=fmt, checksum=True)
        dt_plain, _ = _bench(lambda: dispatch.decode(arr, plan="jnp"),
                             reps=reps)
        dt_cs, _ = _bench(
            lambda: dispatch.decode(arr, epilogue="checksum", plan="jnp"),
            reps=reps)
        # full checked path: fused epilogue + host compare of the column
        dt_checked = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            decode_checked(arr, plan="jnp")
            dt_checked = min(dt_checked, time.perf_counter() - t0)
        rows.append({
            "format": fmt,
            "n_ints": n_ints,
            "unvalidated_mis": round(n_ints / dt_plain / 1e6, 1),
            "validated_mis": round(n_ints / dt_cs / 1e6, 1),
            "checked_mis": round(n_ints / dt_checked / 1e6, 1),
            "checksum_overhead": round(dt_cs / dt_plain - 1.0, 4),
            "host_verify_overhead": round(dt_checked / dt_plain - 1.0, 4),
        })
    return rows


def run_serving(*, n_queries: int = 48, seed: int = 0) -> dict:
    """Flaky-workload serve stats: retry / quarantine / degraded rates."""
    import dataclasses

    from repro.data.synthetic import posting_list_group, posting_tfs
    from repro.index import build_index
    from repro.launch.serve import SearchEngine, search_queries
    from repro.robustness import ChecksumError
    from repro.robustness import faultgen

    rng = np.random.default_rng(seed)
    lists = dict(enumerate(
        posting_list_group(rng, 8, 16, universe=1 << 20)))
    tfs = {t: posting_tfs(rng, len(v)) for t, v in lists.items()}
    index = build_index(lists, tfs=tfs, n_docs=1 << 20, checksum=True)

    # two terms ship corrupted: startup validation must quarantine them
    terms = dict(index.terms)
    for t in (2, 9):
        c = faultgen.corrupt(terms[t].arr, "bit_flip", seed=t)
        terms[t] = dataclasses.replace(terms[t], arr=c.arr)
    index = dataclasses.replace(index, terms=terms)

    def flaky(attempt, q_terms, mode):
        # every 4th query hits one transient fault, then succeeds
        if attempt == 0 and flaky.q % 4 == 0:
            raise ChecksumError("transient decode fault (injected)")
    flaky.q = 0

    engine = SearchEngine(index, validate=True, fault_hook=flaky,
                          max_retries=2)
    qs = search_queries(rng, index, n_queries)
    engine.warmup(qs)
    for k in engine.serve_stats:  # warmup faults don't count
        if k not in ("quarantined_terms", "quarantined_blocks"):
            engine.serve_stats[k] = 0
    flaky.q = 0
    stats = {}
    t0 = time.perf_counter()
    for mode, q_terms in qs:
        engine.search(q_terms, mode)
        flaky.q += 1
    wall = time.perf_counter() - t0
    s = engine.serve_stats
    total_blocks = sum(tp.n_blocks for tp in index.terms.values())
    stats = {
        "n_queries": len(qs),
        "qps": round(len(qs) / wall, 1),
        "errors": s["errors"],
        "retries": s["retries"],
        "retry_rate": round(s["retries"] / len(qs), 3),
        "quarantined_terms": s["quarantined_terms"],
        "quarantined_blocks": s["quarantined_blocks"],
        "quarantined_block_rate": round(
            s["quarantined_blocks"] / total_blocks, 3),
        "degraded_responses": s["degraded_responses"],
        "degraded_rate": round(s["degraded_responses"] / len(qs), 3),
        "bound_fallbacks": s["bound_fallbacks"],
    }
    assert stats["quarantined_terms"] == 2
    assert stats["retries"] > 0 and stats["degraded_responses"] > 0
    return stats


def run(*, quick: bool = False) -> dict:
    # quick still measures 2^17 ints: below that, fixed per-call dispatch
    # cost dominates and the overhead ratio is pure noise
    decode_rows = run_decode(n_ints=1 << 17 if quick else 1 << 18,
                             reps=5 if quick else 8)
    if quick:
        for r in decode_rows:
            assert r["checksum_overhead"] < CHECKSUM_OVERHEAD_LIMIT, (
                f"{r['format']}: in-pass checksum overhead "
                f"{r['checksum_overhead']:.1%} exceeds "
                f"{CHECKSUM_OVERHEAD_LIMIT:.0%}")
    return {"decode": decode_rows,
            "serving": run_serving(n_queries=24 if quick else 48)}
